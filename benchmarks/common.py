"""Shared benchmark fixtures: the full trained testbed + routing episodes."""
from __future__ import annotations

import functools
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import (EdgeDetectionEstimator, Gateway, GreedyEstimateRouter,
                        HighestMAPPerGroupRouter, HighestMAPRouter,
                        LowestEnergyRouter, LowestInferenceRouter,
                        OracleEstimator, OracleRouter, OutputBasedEstimator,
                        RandomRouter, RoundRobinRouter)
from repro.core.estimators import SSDFrontEndEstimator
from repro.detection import scenes as sc


@functools.lru_cache(maxsize=1)
def testbed():
    from repro.detection.train import default_testbed
    return default_testbed()


def router_matrix(table, params, delta: float = 5.0):
    """All (router, estimator) combos of the paper's evaluation."""
    return [
        ("Orc", OracleRouter(table, delta), OracleEstimator()),
        ("RR", RoundRobinRouter(table, delta), None),
        ("Rnd", RandomRouter(table, delta), None),
        ("LE", LowestEnergyRouter(table, delta), None),
        ("LI", LowestInferenceRouter(table, delta), None),
        ("HM", HighestMAPRouter(table, delta), None),
        ("HMG", HighestMAPPerGroupRouter(table, delta), None),
        ("ED", GreedyEstimateRouter(table, delta), EdgeDetectionEstimator()),
        ("SF", GreedyEstimateRouter(table, delta),
         SSDFrontEndEstimator(params["ssd_v1"], "ssd_v1")),
        ("OB", GreedyEstimateRouter(table, delta), OutputBasedEstimator()),
    ]


def run_all_routers(scenes, delta: float = 5.0, subset: Optional[set] = None):
    params, table = testbed()
    rows = []
    for name, router, est in router_matrix(table, params, delta):
        if subset and name not in subset:
            continue
        router.name = name
        t0 = time.perf_counter()
        stats = Gateway(router, table, params, est).process_stream(scenes)
        wall = time.perf_counter() - t0
        rows.append({
            "router": name,
            "map": stats.map_pct,
            "backend_energy_mwh": stats.backend_energy_mwh,
            "gateway_energy_mwh": stats.gateway_energy_mwh,
            "total_energy_mwh": stats.total_energy_mwh,
            "backend_time_ms": stats.backend_time_ms,
            "gateway_time_ms": stats.gateway_time_ms,
            "total_time_ms": stats.total_time_ms,
            "wall_s": wall,
            "pairs": stats.pair_histogram,
        })
    return rows


def print_rows(name: str, rows: List[Dict]):
    print(f"\n== {name} ==")
    print("router,mAP,total_energy_mWh,total_time_ms,gateway_energy_mWh,"
          "gateway_time_ms")
    for r in rows:
        print(f"{r['router']},{r['map']:.2f},{r['total_energy_mwh']:.4f},"
              f"{r['total_time_ms']:.1f},{r['gateway_energy_mwh']:.5f},"
              f"{r['gateway_time_ms']:.2f}")

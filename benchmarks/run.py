"""Benchmark harness: one function per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]

Prints ``name,metric,...`` CSV blocks per figure and writes JSON artifacts
to artifacts/bench/.  Figure map (see DESIGN.md §7):

  motivation    — Fig. 2  energy/accuracy crossover by object count
  pareto        — Fig. 5  all 64 (model x device) pairs
  full_dataset  — Fig. 6  routers on the full corpus, delta=5
  balanced      — Fig. 7  balanced-sorted corpus
  video         — Fig. 8  temporally-correlated stream
  delta_sweep   — Fig. 9  Orc/ED/SF/OB across delta in {0,5,10,15,20,25}
  overhead      — gateway-overhead metric (per estimator)
  serve         — end-to-end EcoreService throughput (req/s, flush counts,
                  p50/p95 queue wait under the threaded deadline flusher)
  cluster       — sharded req/s scaling over EcoreCluster pods (1/2/4) +
                  jitted shard-selection overhead vs the scalar reference
  load          — open-loop SLOs (p50/p95/p99, goodput, J/request) under
                  {steady Poisson, flash crowd} x {fixed, autoscaled} fleets
                  on the virtual-time LoadDriver (repro.traffic)
  kernels       — kernel timings (CPU oracle path; Pallas checked in tests)
  pool_routing  — framework-level: ECORE over the TPU dry-run pool
  roofline      — per (arch x shape x mesh) roofline terms from the dry-run
  adaptive      — BEYOND-PAPER: static-profile vs closed-loop routing under
                  device drift (thermal throttle), regret vs a drift oracle
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

from benchmarks import common
from repro.detection import scenes as sc

ART = "artifacts/bench"


def _save(name, obj):
    os.makedirs(ART, exist_ok=True)
    with open(os.path.join(ART, f"{name}.json"), "w") as f:
        json.dump(obj, f, indent=1, default=str)


# ----------------------------------------------------------- Fig. 2 analog

def bench_motivation(quick=False):
    from repro.core.metrics import MAPAccumulator
    from repro.detection.train import run_detector
    from repro.detection.detectors import DETECTOR_CONFIGS
    from repro.detection.devices import DEVICES
    params, _ = common.testbed()
    n = 80 if quick else 240
    scenes = [s for s in sc.full_dataset(n, seed=21)]
    single = [s for s in scenes if s.count == 1]
    many = [s for s in scenes if s.count >= 4]
    print("\n== motivation (Fig 2) ==")
    print("model,group,mAP,energy_mwh_per_image")
    rows = []
    for model in ("ssd_lite", "yolov8_n"):
        for label, group in (("1 object", single), ("4+ objects", many)):
            acc = MAPAccumulator(sc.NUM_CLASSES)
            imgs = np.stack([s.image for s in group])
            for s, (b, sc_, c) in zip(group, run_detector(params[model], imgs)):
                acc.add_image(b, sc_, c, s.boxes, s.classes)
            e = DEVICES["pi5"].energy_mwh(DETECTOR_CONFIGS[model].flops)
            rows.append((model, label, acc.map(), e))
            print(f"{model},{label},{acc.map():.1f},{e:.5f}")
    _save("motivation", rows)


# ----------------------------------------------------------- Fig. 5 analog

def bench_pareto(quick=False):
    from repro.detection.train import profile_pairs
    from repro.detection.devices import DEVICES
    from repro.detection.detectors import DETECTOR_CONFIGS
    params, _ = common.testbed()
    pairs = [(m, d) for m in DETECTOR_CONFIGS for d in DEVICES]
    val = sc.full_dataset(60 if quick else 150, seed=23)
    table = profile_pairs(params, pairs, val_scenes=val)
    print("\n== pareto (Fig 5): 64 model-device pairs ==")
    print("model,device,mean_mAP,energy_mwh,time_ms")
    rows = []
    for m, d in table.pairs():
        e = table.entry((m, d), 4)
        mm = table.mean_map((m, d))
        rows.append((m, d, mm, e.energy_mwh, e.time_ms))
        print(f"{m},{d},{mm:.1f},{e.energy_mwh:.5f},{e.time_ms:.2f}")
    front = []
    for r in rows:
        if not any(o[3] <= r[3] and o[2] >= r[2] and o != r for o in rows):
            front.append(r[:2])
    print("pareto_front:", front)
    _save("pareto", {"rows": rows, "front": front})


# -------------------------------------------------- Fig. 6 / 7 / 8 analogs

def bench_full_dataset(quick=False):
    scenes = sc.full_dataset(100 if quick else 300, seed=31)
    rows = common.run_all_routers(scenes, delta=5.0)
    common.print_rows("full dataset (Fig 6), delta=5", rows)
    _save("full_dataset", rows)
    return rows


def bench_balanced(quick=False):
    scenes = sc.balanced_sorted_dataset(per_group=20 if quick else 50,
                                        seed=32)
    rows = common.run_all_routers(scenes, delta=5.0)
    common.print_rows("balanced sorted (Fig 7), delta=5", rows)
    _save("balanced", rows)
    return rows


def bench_video(quick=False):
    scenes = sc.video_dataset(n_frames=100 if quick else 300, seed=33)
    rows = common.run_all_routers(scenes, delta=5.0)
    common.print_rows("video (Fig 8), delta=5", rows)
    _save("video", rows)
    return rows


# ----------------------------------------------------------- Fig. 9 analog

def bench_delta_sweep(quick=False):
    scenes = sc.full_dataset(80 if quick else 200, seed=34)
    out = {}
    print("\n== delta sweep (Fig 9) ==")
    print("delta,router,mAP,total_energy_mWh,total_time_ms")
    for delta in (0, 5, 10, 15, 20, 25):
        rows = common.run_all_routers(scenes, delta=float(delta),
                                      subset={"Orc", "ED", "SF", "OB"})
        out[delta] = rows
        for r in rows:
            print(f"{delta},{r['router']},{r['map']:.2f},"
                  f"{r['total_energy_mwh']:.4f},{r['total_time_ms']:.1f}")
    _save("delta_sweep", out)
    return out


# -------------------------------------------------------- gateway overhead

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


#: production frame sizes the 2D lane-tiled kernel exists for
HIRES_FRAMES = (("1080p", 1080, 1920), ("1440p", 1440, 2560),
                ("4k", 2160, 3840))


def _hires_canny_rows(quick=False):
    """Fused-vs-staged Canny at production frame sizes (1080p/1440p/4K).

    The 2D lane-tiled kernel serves these with no width fallback, so the
    bench measures the real fused path at every size.  Alongside measured
    µs/frame, each row reports modeled frames/J on the gateway device:
    joules come from the device model (``gateway_cost`` over the ED
    estimator's per-pixel FLOPs on ``GATEWAY_DEVICE``) for the fused
    launch, with the staged pipeline charged the same power for its
    measured staged/fused time ratio — the energy spread routing actually
    sees between one launch and ~6 HBM round trips."""
    import jax
    import jax.numpy as jnp
    from repro.core.energy import gateway_cost, mwh_to_joules
    from repro.core.estimators import EdgeDetectionEstimator
    from repro.kernels.canny_fused import ref as canny_ref
    from repro.kernels.canny_fused.ops import canny_edge

    def timeit(fn, *args, n=None):
        n = n or (1 if quick else 3)
        jax.block_until_ready(fn(*args))  # compile/warm
        t0 = time.perf_counter()
        for _ in range(n):
            jax.block_until_ready(fn(*args))
        return (time.perf_counter() - t0) / n * 1e6

    print("\n== canny hi-res (fused vs staged; 2D lane-tiled grid) ==")
    print("frame,impl,us_per_frame,frames_per_joule_modeled")
    rows = []
    for label, h, w in HIRES_FRAMES:
        img = jax.random.uniform(jax.random.PRNGKey(2), (1, h, w),
                                 jnp.float32)
        staged_us = timeit(lambda x: canny_ref.canny_edge_staged(x), img)
        fused_us = timeit(lambda x: canny_edge(x), img)
        flops = h * w * EdgeDetectionEstimator.FLOPS_PER_PIXEL
        fused_j = mwh_to_joules(gateway_cost(flops)["energy_mwh"])
        staged_j = fused_j * (staged_us / fused_us)
        row = {"frame": label, "h": h, "w": w,
               "staged_us_per_frame": staged_us,
               "fused_us_per_frame": fused_us,
               "speedup": staged_us / fused_us,
               "fused_frames_per_j": 1.0 / fused_j,
               "staged_frames_per_j": 1.0 / staged_j}
        rows.append(row)
        print(f"{label},staged,{staged_us:.0f},{1.0 / staged_j:.1f}")
        print(f"{label},fused,{fused_us:.0f},{1.0 / fused_j:.1f}")
    return rows


def bench_gateway_hotpath(quick=False):
    """Fused-vs-unfused gateway latency + batched-vs-scalar routing
    throughput: the two per-frame hot-path costs this repo optimizes.

    Canny: 'unfused' runs the same maths stage-per-dispatch (a device sync
    between blur/Sobel/NMS/hysteresis — the per-stage HBM-round-trip cost
    model); 'fused' is one launch (the jnp oracle under one jit on CPU, the
    Pallas megakernel on TPU).  Routing: B python greedy_route calls vs one
    tensorized route_batch call, with a per-frame exact-match check."""
    import jax
    import jax.numpy as jnp
    from repro.core.router import greedy_route, route_batch
    from repro.detection.devices import nominal_profile_table
    from repro.kernels.canny_fused import ref as canny_ref
    from repro.kernels.canny_fused.ops import canny_edge

    def timeit(fn, *args, n=None):
        n = n or (5 if quick else 20)
        jax.block_until_ready(fn(*args))  # compile/warm
        t0 = time.perf_counter()
        for _ in range(n):
            jax.block_until_ready(fn(*args))
        return (time.perf_counter() - t0) / n * 1e6

    backend = jax.default_backend()
    b, h, w = (4, 64, 64) if quick else (8, 96, 96)
    img = jax.random.uniform(jax.random.PRNGKey(0), (b, h, w), jnp.float32)
    unfused_us = timeit(lambda x: canny_ref.canny_edge_staged(x), img)
    fused_us = timeit(lambda x: canny_edge(x), img)
    # bit-identical gate for the 2D grid: a frame bigger than one tile in
    # BOTH dims (80x600 under 32x256 tiles -> a 3x3 program grid) so lane
    # tiling, the column halo, and the ragged right/bottom edges are all
    # exercised on CPU CI via interpret mode
    pimg = jax.random.uniform(jax.random.PRNGKey(1), (2, 80, 600),
                              jnp.float32)
    fused_matches = bool(np.array_equal(
        np.asarray(canny_edge(pimg, impl="interpret", tile_rows=32,
                              tile_lanes=256)),
        np.asarray(canny_ref.canny_edge(pimg))))

    print("\n== gateway hot path (fused vs unfused) ==")
    print("stage,impl,us_per_batch,us_per_frame")
    print(f"canny,unfused_staged,{unfused_us:.0f},{unfused_us / b:.0f}")
    print(f"canny,fused_{backend},{fused_us:.0f},{fused_us / b:.0f}")
    print(f"canny_fused_bit_identical_to_oracle,{fused_matches}")

    hires = _hires_canny_rows(quick)

    # routing: nominal profile over the paper testbed (routing dynamics
    # only — no trained detectors needed)
    table = nominal_profile_table()
    nb = 1024 if quick else 4096
    counts = np.random.default_rng(0).integers(0, 9, size=nb)
    t0 = time.perf_counter()
    scalar_pairs = [greedy_route(int(c), table, 5.0).pair for c in counts]
    scalar_s = time.perf_counter() - t0
    route_batch(counts, table, 5.0)  # warm the jit
    t0 = time.perf_counter()
    idx = route_batch(counts, table, 5.0)
    batched_s = time.perf_counter() - t0
    batched_pairs = [table.entries[i].pair for i in idx]
    match = batched_pairs == scalar_pairs
    print("routing,impl,requests_per_s")
    print(f"routing,scalar_python,{nb / scalar_s:.0f}")
    print(f"routing,batched_xla,{nb / batched_s:.0f}")
    print(f"routing_batched_matches_scalar,{match}")

    return {
        "backend": backend,
        "canny": {"batch": b, "frame": [h, w],
                  "unfused_staged_us_per_frame": unfused_us / b,
                  "fused_us_per_frame": fused_us / b,
                  "speedup": unfused_us / fused_us,
                  "fused_bit_identical_to_oracle": fused_matches},
        "canny_hires": hires,
        "routing": {"batch": nb,
                    "scalar_requests_per_s": nb / scalar_s,
                    "batched_requests_per_s": nb / batched_s,
                    "speedup": scalar_s / batched_s,
                    "batched_matches_scalar": match},
    }


def _run_meta():
    """Attribution stamp for trajectory records: which commit produced the
    numbers, when, under which record schema.  Git being unavailable (tar
    export, shallow CI) degrades to "unknown" rather than failing a bench."""
    import datetime
    import subprocess
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], cwd=REPO_ROOT,
            capture_output=True, text=True, timeout=10).stdout.strip()
    except (OSError, subprocess.SubprocessError):
        sha = ""
    return {
        "git_sha": sha or "unknown",
        "timestamp": datetime.datetime.now(datetime.timezone.utc)
                             .isoformat(timespec="seconds"),
        "schema": "bench_gateway/v1",
    }


def _append_gateway_bench(record):
    """Persist the perf trajectory at the repo root (append-only across
    PRs); the smoke target relies on a FAILED write exiting nonzero.
    New records are stamped with run metadata (git sha, UTC timestamp,
    schema tag); pre-existing entries are never rewritten."""
    path = os.path.join(REPO_ROOT, "BENCH_gateway.json")
    record.setdefault("meta", _run_meta())
    try:
        history = []
        if os.path.exists(path) and os.path.getsize(path) > 0:
            with open(path) as f:
                history = json.load(f)
        history.append(record)
        with open(path, "w") as f:
            json.dump(history, f, indent=1)
        print(f"wrote {path} ({len(history)} run(s))")
    except (OSError, ValueError) as exc:
        raise SystemExit(f"cannot write {path}: {exc}")


def bench_overhead(quick=False):
    hotpath = bench_gateway_hotpath(quick)
    _append_gateway_bench(hotpath)

    if quick:
        # the router table below needs trained detectors (common.testbed
        # takes ~10 min); the CI bench-smoke job runs --quick for the
        # kernel-parity gate + the append-only BENCH contract only
        print("\n== gateway overhead: router table skipped under --quick ==")
        return

    scenes = sc.full_dataset(150, seed=35)
    rows = common.run_all_routers(scenes, delta=5.0,
                                  subset={"Orc", "ED", "SF", "OB", "RR"})
    print("\n== gateway overhead ==")
    print("router,gateway_energy_mWh,gateway_time_ms,share_of_total_energy")
    for r in rows:
        share = r["gateway_energy_mwh"] / max(r["total_energy_mwh"], 1e-12)
        print(f"{r['router']},{r['gateway_energy_mwh']:.5f},"
              f"{r['gateway_time_ms']:.2f},{share:.3f}")
    _save("overhead", rows)


# ------------------------------------------------------------ kernel bench

def bench_kernels(quick=False):
    import jax
    import jax.numpy as jnp
    print("\n== kernels (us_per_call; CPU xla-oracle path — Pallas kernels "
          "validated via interpret mode in tests/test_kernels.py) ==")
    print("name,us_per_call,derived")

    def timeit(fn, *args, n=5):
        jax.block_until_ready(fn(*args))  # compile
        t0 = time.perf_counter()
        for _ in range(n):
            jax.block_until_ready(fn(*args))
        return (time.perf_counter() - t0) / n * 1e6

    from repro.kernels.flash_attention.ops import attention
    from repro.kernels.decode_attention.ops import decode
    from repro.kernels.sobel.ops import sobel_grad
    from repro.kernels.rglru_scan import ref as lru_ref
    from repro.kernels.ssd_scan import ref as ssd_ref

    ks = jax.random.split(jax.random.PRNGKey(0), 8)
    B, H, KV, S, D = 1, 8, 2, (256 if quick else 1024), 64
    q = jax.random.normal(ks[0], (B, H, S, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, KV, S, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, KV, S, D), jnp.float32)
    us = timeit(lambda a, b, c: attention(a, b, c, impl="xla"), q, k, v)
    flops = 2 * 2 * B * H * S * S * D / 2  # causal half
    print(f"flash_attention_s{S},{us:.0f},{flops/us*1e-6:.2f}GFLOP/s")

    qd = jax.random.normal(ks[3], (8, H, D), jnp.float32)
    kd = jax.random.normal(ks[4], (8, KV, S, D), jnp.float32)
    lengths = jnp.full((8,), S, jnp.int32)
    us = timeit(lambda a, b, c, l: decode(a, b, c, l, impl="xla"),
                qd, kd, kd, lengths)
    print(f"decode_attention_t{S},{us:.0f},{8*KV*S*D*8/us*1e-3:.1f}MB/s-cache")

    img = jax.random.uniform(ks[5], (8, 64, 64))
    us = timeit(lambda a: sobel_grad(a, impl="xla"), img)
    print(f"sobel_64x64x8,{us:.0f},{8*64*64/us:.2f}Mpx/s")

    a = jax.random.uniform(ks[6], (2, 512, 256), minval=0.5, maxval=0.99)
    b = jax.random.normal(ks[7], (2, 512, 256))
    us = timeit(lambda x, y: lru_ref.linear_scan(x, y), a, b)
    print(f"rglru_scan_512x256,{us:.0f},{2*512*256/us:.2f}Melem/s")

    x2 = jax.random.normal(ks[2], (1, 512, 4, 32))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (1, 512, 4)))
    A = -jnp.exp(jax.random.normal(ks[3], (4,)))
    Bm = jax.random.normal(ks[4], (1, 512, 16))
    Cm = jax.random.normal(ks[5], (1, 512, 16))
    Dv = jnp.ones((4,))
    us = timeit(lambda *args: ssd_ref.ssd_chunked(*args, chunk=64),
                x2, dt, A, Bm, Cm, Dv)
    print(f"ssd_scan_512,{us:.0f},chunked")


# ------------------------------------------------- end-to-end service

def bench_serve(quick=False):
    """End-to-end EcoreService throughput: requests/s through route ->
    dispatch -> batched serve on real (reduced) backends, flush counts, and
    the p50/p95 queue wait a request pays for batching under the threaded
    deadline-bounded flusher.  Appended to BENCH_gateway.json.

    Two separated latency planes (the service accounts them apart): queue
    wait is submit -> flush TRIGGERED (deadline expiry / full batch —
    bounded by max_wait_ms under a healthy flusher), service time is
    trigger -> completion (waiting behind other flushes under the service
    lock + the serve itself).  On this CPU container service time is
    dominated by first-batch jit compiles and collapses on a TPU pod;
    queue wait genuinely tracks the deadline."""
    from repro.configs import get_config
    from repro.core.policy import PoolPolicy, RouteRequest
    from repro.launch.serve import PROMPT_CAP, synthetic_pool_table
    from repro.serving.engine import Backend
    from repro.serving.pool import ServingPool
    from repro.serving.service import EcoreService

    archs = ["mamba2-370m", "qwen2.5-3b"]
    n = 12 if quick else 32
    max_wait_ms = 25.0
    policy = PoolPolicy(ServingPool(synthetic_pool_table(archs), delta=5.0))

    def factory(decision):
        cfg = get_config(decision.backend).reduced()
        return Backend(decision.backend, cfg, max_batch=4, max_seq=96,
                       seed=0)

    rng = np.random.default_rng(0)
    reqs = []
    for uid in range(n):
        plen = int(rng.choice([32, 128, 1024, 40_000], p=[.4, .3, .2, .1]))
        reqs.append(RouteRequest(
            uid=uid, complexity=plen, max_new_tokens=4,
            payload=rng.integers(0, 1000, size=min(plen, PROMPT_CAP))))

    # futures are the only consumer here: don't buffer for results()/drain()
    service = EcoreService(policy, factory, max_wait_ms=max_wait_ms,
                           retain_results=False)
    try:
        t0 = time.perf_counter()
        futs = [service.submit(r) for r in reqs]
        served = [f.result(timeout=600) for f in futs]  # flusher drains all
        wall_s = time.perf_counter() - t0
        stats = service.stats()
    finally:
        service.close()
    assert len(served) == n

    def pcts(xs):
        xs = sorted(xs)
        return (xs[len(xs) // 2],
                xs[min(int(len(xs) * 0.95), len(xs) - 1)])

    wait_p50, wait_p95 = pcts(stats["queue_wait_ms"])
    svc_p50, svc_p95 = pcts(stats["service_ms"])
    row = {"serve": {
        "requests": n,
        "backends": stats["backends"],
        "requests_per_s": n / wall_s,
        "serve_calls": stats["serve_calls"],
        "deadline_flushes": stats["deadline_flushes"],
        "max_wait_ms": max_wait_ms,
        "queue_wait_p50_ms": wait_p50,
        "queue_wait_p95_ms": wait_p95,
        "service_p50_ms": svc_p50,
        "service_p95_ms": svc_p95,
    }}
    print("\n== serve (EcoreService end-to-end) ==")
    print("metric,value")
    for k, v in row["serve"].items():
        print(f"{k},{v if isinstance(v, int) else f'{v:.2f}'}")
    _append_gateway_bench(row)
    return row


# ------------------------------------------------- sharded cluster serving

def bench_cluster(quick=False):
    """EcoreCluster req/s scaling (1/2/4 pods) + shard-selection overhead.

    Backends are DetectorBackends with ``realtime_scale=1``: serve_batch
    OCCUPIES the wall clock for the modeled edge-device latency (sleep
    releases the GIL), so pods genuinely overlap — what's measured is the
    cluster plane's ability to shard and serve concurrently, with the
    device model as the load generator.  Appended to BENCH_gateway.json."""
    from repro.core.policy import DetectionPolicy, RouteRequest
    from repro.core.router import OracleRouter
    from repro.detection.devices import nominal_profile_table
    from repro.serving.backend import make_backend, null_run
    from repro.serving.cluster import (EcoreCluster, select_pods,
                                       select_pods_reference)

    n = 48 if quick else 128
    rng = np.random.default_rng(0)
    counts = rng.integers(0, 9, size=n)
    frame = np.zeros((8, 8), np.float32)

    def factory(decision):
        return make_backend("detector", decision.pair[0], decision.pair[1],
                            None, max_batch=4, run_fn=null_run,
                            realtime_scale=1.0)

    def episode(pods):
        def policy_factory(i):
            table = nominal_profile_table()
            return DetectionPolicy(OracleRouter(table, 5.0), table)

        with EcoreCluster(policy_factory, factory, pods=pods) as cluster:
            reqs = [RouteRequest(uid=i, payload=frame,
                                 true_complexity=int(c))
                    for i, c in enumerate(counts)]
            t0 = time.perf_counter()
            futs = cluster.submit_batch(reqs)
            cluster.drain()
            served = [f.result(timeout=120) for f in futs]
            wall = time.perf_counter() - t0
            assert len(served) == n
            shard_counts = cluster.stats()["shard_counts"]
        return n / wall, shard_counts

    print("\n== cluster (sharded EcoreService pods; modeled device load) ==")
    print("pods,requests_per_s,shard_counts")
    rps = {}
    for pods in (1, 2, 4):
        rps[pods], shard_counts = episode(pods)
        print(f"{pods},{rps[pods]:.0f},{shard_counts}")
    scaling = rps[4] / rps[1]
    print(f"scaling_4pod_vs_1pod,{scaling:.2f}x")

    # shard-selection overhead: one jitted XLA call for the whole batch vs
    # the scalar reference loop, plus exact-parity check
    nb = 2048
    uids = np.random.default_rng(1).integers(0, 2**31, size=nb)
    depths = np.zeros(4, np.int64)
    overhead = {}
    for mode in ("least_loaded", "rendezvous"):
        select_pods(uids, depths, mode)  # warm the jit
        t0 = time.perf_counter()
        picks = select_pods(uids, depths, mode)
        jit_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        ref_picks = select_pods_reference(uids, depths, mode)
        ref_s = time.perf_counter() - t0
        parity = bool(np.array_equal(picks, ref_picks))
        overhead[mode] = {"jitted_us_per_req": jit_s / nb * 1e6,
                          "scalar_us_per_req": ref_s / nb * 1e6,
                          "parity": parity}
        print(f"shard_{mode},jitted_us_per_req,"
              f"{overhead[mode]['jitted_us_per_req']:.2f},"
              f"scalar_us_per_req,{overhead[mode]['scalar_us_per_req']:.2f},"
              f"parity,{parity}")

    record = {"cluster": {
        "requests": n,
        "requests_per_s_by_pods": {str(p): v for p, v in rps.items()},
        "scaling_4pod_vs_1pod": scaling,
        "shard_selection": overhead,
    }}
    _append_gateway_bench(record)
    _save("cluster", record)
    return record


# ------------------------------------------------- fault storm resilience

def bench_faults(quick=False):
    """Goodput under an injected fault storm: error + stall + crash-window
    faults on the fleet's favorite device, resilient service (deadline +
    retry + hedged re-dispatch) vs the bare EcoreService baseline.

    Everything is deterministic — faults key on request uid, retry jitter
    on (uid, attempt), backoff runs on a manual clock — so the goodput/
    availability numbers are exactly reproducible run to run.  Appended to
    BENCH_gateway.json."""
    from repro.core.policy import DetectionPolicy, RouteRequest
    from repro.core.router import OracleRouter
    from repro.detection.devices import nominal_profile_table
    from repro.serving.backend import make_backend, null_run
    from repro.serving.faults import FaultSpec
    from repro.serving.resilience import ResilientService, RetryPolicy
    from repro.serving.service import EcoreService

    n = 120 if quick else 400
    deadline_ms = 500.0
    storm_device = "orin_nano"   # the zero-fault energy favorite
    storm = [FaultSpec("error", rate=0.4, seed=3),
             FaultSpec("stall", rate=0.3, seed=5, stall_ms=10_000.0),
             FaultSpec("crash_window", start=n // 2, end=n // 2 + n // 5)]

    def factory(decision):
        model, device = decision.pair
        return make_backend(
            "faulty:detector", model, device, max_batch=4, run_fn=null_run,
            faults=storm if device == storm_device else [])

    rng = np.random.default_rng(1)
    reqs = [RouteRequest(uid=u, payload=np.zeros((4, 4), np.float32),
                         true_complexity=int(rng.integers(1, 20)))
            for u in range(n)]

    def episode(resilient):
        table = nominal_profile_table()
        policy = DetectionPolicy(OracleRouter(table, 2.0), table)
        clock = time.monotonic
        if resilient:
            svc = ResilientService(
                policy, factory, clock=clock,
                retry=RetryPolicy(deadline_ms=deadline_ms, max_retries=3))
        else:
            svc = EcoreService(policy, factory, clock=clock,
                               retain_results=False, buffer_errors=False)
        futs, failed = [], 0
        t0 = time.perf_counter()
        for r in reqs:
            try:
                futs.append(svc.submit(r))
            except Exception:   # bare service: inline flush error raises
                failed += 1
        try:
            svc.drain()
        except Exception:
            pass
        good = 0
        for f in futs:
            if f.exception() is not None:
                failed += 1
                continue
            t_ms = f.result().result.time_ms
            if t_ms is not None and np.isfinite(t_ms) and t_ms <= deadline_ms:
                good += 1
        wall_s = time.perf_counter() - t0
        stats = svc.stats() if resilient else {}
        svc.close()
        return {"goodput_under_deadline": good / n,
                "availability": (n - failed) / n,
                "failed": failed,
                "wall_s": wall_s,
                "retries": stats.get("retries", 0),
                "hedges": stats.get("hedges", 0),
                "deadline_misses": stats.get("deadline_misses", 0)}

    resilient = episode(resilient=True)
    baseline = episode(resilient=False)
    print("\n== faults (storm: error+stall+crash on the favorite device) ==")
    print("service,goodput_under_deadline,availability,retries,hedges,"
          "deadline_misses")
    for name, r in (("resilient", resilient), ("baseline", baseline)):
        print(f"{name},{r['goodput_under_deadline']:.3f},"
              f"{r['availability']:.3f},{r['retries']},{r['hedges']},"
              f"{r['deadline_misses']}")
    record = {"faults": {
        "requests": n,
        "deadline_ms": deadline_ms,
        "storm_device": storm_device,
        "resilient": resilient,
        "baseline": baseline,
    }}
    _append_gateway_bench(record)
    _save("faults", record)
    return record


# ------------------------------------------------- open-loop load harness

def bench_load(quick=False):
    """Open-loop SLO bench: {steady Poisson, flash crowd} x {fixed 2-pod,
    autoscaled} on the virtual-time LoadDriver (repro.traffic).

    Arrival rates are tuned from the profile itself: the steady rate puts
    the fixed 2-pod fleet at ~50% modeled utilization, the flash spike
    (4x) pushes it past saturation — so the fixed fleet's queue grows for
    the spike's duration while the autoscaler bursts to max_pods and
    drains.  Everything rides the ManualClock: a multi-second episode
    replays in milliseconds and every number is bit-reproducible.  Each
    cell's summary + per-window SLO records + autoscaler events are
    appended to BENCH_gateway.json."""
    from repro.core.policy import DetectionPolicy
    from repro.core.router import OracleRouter, greedy_route
    from repro.detection.devices import nominal_profile_table
    from repro.serving.backend import make_backend, null_run
    from repro.serving.cluster import Autoscaler, EcoreCluster
    import repro.traffic as tr

    duration_s = 6.0 if quick else 12.0
    window_s = 2.0
    max_wait_ms = 20.0
    pods, max_pods = 2, 6

    # modeled mean service time of the drift mix -> rates and deadline
    rng = np.random.default_rng(0)
    table = nominal_profile_table()
    mix = rng.choice(len(sc.COUNT_PROBS), p=sc.COUNT_PROBS, size=256)
    mean_ms = float(np.mean([greedy_route(int(c), table, 5.0).time_ms
                             for c in mix]))
    steady_hz = 0.5 * pods * 1e3 / mean_ms      # ~50% fleet utilization
    deadline_ms = 4.0 * (max_wait_ms + mean_ms)

    def backend_for(decision):
        return make_backend("detector", decision.pair[0], decision.pair[1],
                            None, max_batch=4, run_fn=null_run)

    def policy_for(i):
        t = nominal_profile_table()
        return DetectionPolicy(OracleRouter(t, 5.0), t)

    def episode(pattern, autoscale):
        clock = tr.ManualClock()
        cluster = EcoreCluster(policy_for, backend_for, pods=pods,
                               max_pods=max_pods, max_wait_ms=max_wait_ms,
                               clock=clock, retain_results=False,
                               flusher=False)
        auto = Autoscaler(cluster, clock, min_pods=pods, max_pods=max_pods,
                          high_backlog_per_pod=10.0, low_backlog_per_pod=1.0,
                          cooldown_s=0.5) if autoscale else None
        arrivals = tr.make_arrivals(pattern, steady_hz, duration_s, seed=7)
        work = tr.merge_tenants([tr.detector_tenant(
            "cams", arrivals, seed=1, deadline_ms=deadline_ms)])
        driver = tr.LoadDriver(cluster, clock, autoscaler=auto,
                               window_s=window_s)
        try:
            driver.run(work)
        finally:
            cluster.close()
        return {"summary": driver.slo.summary(),
                "windows": driver.slo.window_records(),
                "autoscaler_events": auto.events if auto else [],
                "requests": len(work)}

    print("\n== load (open-loop SLOs; virtual time) ==")
    print(f"steady_hz,{steady_hz:.0f},deadline_ms,{deadline_ms:.0f},"
          f"duration_s,{duration_s:.0f}")
    print("pattern,fleet,requests,p50_ms,p95_ms,p99_ms,goodput_fraction,"
          "goodput_rps,joules_per_request,scale_events")
    runs = {}
    for pattern in ("poisson", "flash"):
        for fleet, autoscale in (("fixed", False), ("autoscaled", True)):
            r = episode(pattern, autoscale)
            runs[f"{pattern}_{fleet}"] = r
            s = r["summary"]
            print(f"{pattern},{fleet},{r['requests']},{s['p50_ms']:.1f},"
                  f"{s['p95_ms']:.1f},{s['p99_ms']:.1f},"
                  f"{s['goodput_fraction']:.3f},{s['goodput_rps']:.1f},"
                  f"{s['joules_per_request']:.4f},"
                  f"{len(r['autoscaler_events'])}")

    fixed, auto = runs["flash_fixed"]["summary"], \
        runs["flash_autoscaled"]["summary"]
    better = {"p99": auto["p99_ms"] < fixed["p99_ms"],
              "goodput": auto["goodput_fraction"]
              >= fixed["goodput_fraction"]}
    print(f"flash_autoscaled_beats_fixed,p99,{better['p99']},"
          f"goodput,{better['goodput']}")

    record = {"load": {
        "settings": {"duration_s": duration_s, "window_s": window_s,
                     "max_wait_ms": max_wait_ms, "pods": pods,
                     "max_pods": max_pods, "steady_hz": steady_hz,
                     "deadline_ms": deadline_ms,
                     "mean_service_ms": mean_ms},
        "runs": runs,
        "flash_autoscaled_beats_fixed": better,
    }}
    _append_gateway_bench(record)
    _save("load", record)
    return record


# ------------------------------------------------- framework pool routing

def bench_pool_routing(quick=False):
    path = "artifacts/dryrun.jsonl"
    if not os.path.exists(path):
        print("\n== pool_routing: no dry-run artifact; skipping ==")
        return
    from repro.serving.pool import ServingPool, bucket_of, pool_table_from_dryrun
    table = pool_table_from_dryrun(path)
    pool = ServingPool(table, delta=5.0)
    rng = np.random.default_rng(0)
    print("\n== TPU pool routing (framework; profiles from dry-run) ==")
    print("bucket,arch,score,time_ms,energy_mwh")
    chosen = {}
    for plen in (64, 1000, 5000, 20_000, 100_000):
        d = pool.route(plen)
        chosen[d.bucket] = d.arch
        print(f"{d.bucket},{d.arch},{d.score:.1f},{d.time_ms:.2f},"
              f"{d.energy_mwh:.4f}")
    total_greedy = total_max = 0.0
    biggest = max(table.pairs(), key=table.mean_map)
    for _ in range(200):
        plen = int(rng.choice([64, 512, 4096, 40_000], p=[.4, .3, .2, .1]))
        d = pool.route(plen)
        total_greedy += d.energy_mwh
        total_max += table.entry(biggest, min(bucket_of(plen), 4)).energy_mwh
    print(f"energy_vs_always_{biggest[0]}: "
          f"{100 * (1 - total_greedy / total_max):.1f}% saved")
    _save("pool_routing", chosen)


# ------------------------------------------------- adaptive closed loop

def bench_adaptive(quick=False):
    """Static profile vs closed-loop (EWMA-adapted) routing while a device
    drifts, and the SCANNED closed loop (one jitted lax.scan over
    ProfileState) vs the scalar Python loop it replaces.  Pure routing
    dynamics — nominal per-model mAPs stand in for trained detectors so the
    bench isolates WHERE requests go, not how well the detector draws
    boxes.  Regret = actual energy paid minus what an oracle that always
    sees the true drifted costs would pay; the scanned loop must land on
    the SAME decisions and regret as the scalar loop (drift-recovery
    parity), only faster.  Appended to BENCH_gateway.json."""
    from repro.core.closed_loop import measurements_from_fleet, scan_stream
    from repro.core.router import feasible_for_count, greedy_route
    from repro.detection.detectors import DETECTOR_CONFIGS
    from repro.detection.devices import drift_scenario, nominal_profile_table

    base_table = nominal_profile_table   # fresh table per episode

    steps = 150 if quick else 400
    delta, alpha = 5.0, 0.15
    rng = np.random.default_rng(7)
    counts = rng.choice(len(sc.COUNT_PROBS), p=sc.COUNT_PROBS, size=steps)

    # throttle whatever device the profile initially favors for the modal
    # group — the worst case for a frozen profile
    modal_count = int(np.argmax(np.bincount(counts)))
    favorite = greedy_route(modal_count, base_table(), delta).device
    fleet = drift_scenario("thermal", device=favorite, start=steps // 4)
    print(f"\n== adaptive (closed loop vs static; thermal drift on "
          f"{favorite} from step {steps // 4}) ==")

    def episode(adapt: bool):
        table = base_table()
        energy = time_ms = 0.0
        picks = []
        for t, count in enumerate(counts):
            e = greedy_route(int(count), table, delta)
            picks.append(e.pair)
            flops = DETECTOR_CONFIGS[e.model].flops
            t_ms, e_mwh = fleet.cost(e.device, flops, t)
            energy += e_mwh
            time_ms += t_ms
            if adapt:
                table.observe_pair(e.pair, time_ms=t_ms, energy_mwh=e_mwh,
                                   alpha=alpha)
        return energy, time_ms, picks

    def oracle_episode():
        table = base_table()  # mAP feasibility unaffected by drift
        energy = time_ms = 0.0
        for t, count in enumerate(counts):
            feas = feasible_for_count(int(count), table, delta)
            e = min(feas, key=lambda e: fleet.cost(
                e.device, DETECTOR_CONFIGS[e.model].flops, t)[1])
            t_ms, e_mwh = fleet.cost(
                e.device, DETECTOR_CONFIGS[e.model].flops, t)
            energy += e_mwh
            time_ms += t_ms
        return energy, time_ms

    e_static, t_static, _ = episode(adapt=False)
    t0 = time.perf_counter()
    e_adapt, t_adapt, scalar_picks = episode(adapt=True)
    scalar_s = time.perf_counter() - t0
    e_oracle, t_oracle = oracle_episode()

    # scanned closed loop: precompute the decision-independent per-step,
    # per-pair drifted costs, then run estimate->route->observe as ONE
    # jitted lax.scan over the ProfileState pytree.  The timed region is
    # END-TO-END (measurement precompute + scan) — what Gateway(adapt=True)
    # actually pays per episode — with one warm pass to exclude the
    # one-time jit compile.
    arrays = base_table().as_arrays()

    def scanned_episode():
        meas = measurements_from_fleet(arrays.pairs, steps, fleet)
        return meas, scan_stream(arrays.state, counts, meas, arrays=arrays,
                                 delta=delta, alpha=alpha)[1]
    scanned_episode()  # warm the jit
    t0 = time.perf_counter()
    meas, trace = scanned_episode()
    scanned_s = time.perf_counter() - t0
    e_scan = float(meas.energy_mwh[np.arange(steps), trace.pair_idx].sum())
    t_scan = float(meas.time_ms[np.arange(steps), trace.pair_idx].sum())
    decisions_match = [arrays.pairs[j] for j in trace.pair_idx] == scalar_picks

    print("policy,total_energy_mwh,total_time_ms,energy_regret_mwh")
    rows = {}
    for name, (e, t) in (("static", (e_static, t_static)),
                         ("closed_loop", (e_adapt, t_adapt)),
                         ("scanned_closed_loop", (e_scan, t_scan)),
                         ("oracle", (e_oracle, t_oracle))):
        rows[name] = {"energy_mwh": e, "time_ms": t,
                      "energy_regret_mwh": e - e_oracle}
        print(f"{name},{e:.4f},{t:.1f},{e - e_oracle:.4f}")
    saved = 1 - (e_adapt - e_oracle) / max(e_static - e_oracle, 1e-12)
    print(f"closed_loop_regret_reduction: {100 * saved:.1f}%")
    print("loop,impl,requests_per_s")
    print(f"closed_loop,scalar_python,{steps / scalar_s:.0f}")
    print(f"closed_loop,scanned_lax_scan,{steps / scanned_s:.0f}")
    print(f"scanned_decisions_match_scalar,{decisions_match}")
    print(f"scanned_regret_matches_scalar,"
          f"{np.isclose(e_scan, e_adapt, rtol=1e-5)}")
    rows["throughput"] = {
        "steps": steps,
        "scalar_requests_per_s": steps / scalar_s,
        "scanned_requests_per_s": steps / scanned_s,
        "speedup": scalar_s / scanned_s,
        "decisions_match_scalar": decisions_match,
        "regret_matches_scalar": bool(np.isclose(e_scan, e_adapt,
                                                 rtol=1e-5)),
    }
    _append_gateway_bench({"adaptive": rows})
    _save("adaptive", rows)
    return rows


# ------------------------------------------------------------ roofline dump

def bench_roofline(quick=False):
    path = "artifacts/dryrun.jsonl"
    if not os.path.exists(path):
        print("\n== roofline: no dry-run artifact; run repro.launch.dryrun ==")
        return
    rows = [json.loads(l) for l in open(path)]
    print("\n== roofline (from dry-run; per chip) ==")
    print("arch,shape,mesh,t_compute_ms,t_memory_ms,t_collective_ms,"
          "bottleneck,useful_flops,mem_gb,energy_j")
    for r in rows:
        if r.get("status") == "ok":
            print(f"{r['arch']},{r['shape']},{r['mesh']},"
                  f"{r['t_compute_s']*1e3:.2f},{r['t_memory_s']*1e3:.2f},"
                  f"{r['t_collective_s']*1e3:.2f},{r['bottleneck']},"
                  f"{r['useful_flops_ratio']:.3f},"
                  f"{r['per_device_memory_gb']:.2f},{r['energy_j']:.1f}")
        elif r.get("status") == "skip":
            print(f"{r['arch']},{r['shape']},{r['mesh']},skip,,,,,,")


BENCHES = {
    "motivation": bench_motivation,
    "pareto": bench_pareto,
    "full_dataset": bench_full_dataset,
    "balanced": bench_balanced,
    "video": bench_video,
    "delta_sweep": bench_delta_sweep,
    "overhead": bench_overhead,
    "serve": bench_serve,
    "cluster": bench_cluster,
    "faults": bench_faults,
    "load": bench_load,
    "kernels": bench_kernels,
    "pool_routing": bench_pool_routing,
    "roofline": bench_roofline,
    "adaptive": bench_adaptive,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    names = [args.only] if args.only else list(BENCHES)
    for name in names:
        t0 = time.time()
        BENCHES[name](quick=args.quick)
        print(f"[{name}: {time.time()-t0:.1f}s]")


if __name__ == "__main__":
    main()

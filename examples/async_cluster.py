"""One execution plane, three drivers: sync service, asyncio facade,
sharded cluster — all over the same policy + ExecutionBackend pair.

  PYTHONPATH=src python examples/async_cluster.py

Uses DetectorBackends over the edge-device models (no training needed: a
stub detector stands in, the device energy/latency models are real), so
the example runs in seconds on CPU.
"""
import asyncio

import numpy as np

from repro.core.policy import DetectionPolicy, Observation, RouteRequest
from repro.core.router import OracleRouter
from repro.detection.devices import nominal_profile_table
from repro.serving.aio import AsyncEcoreService
from repro.serving.backend import make_backend, null_run
from repro.serving.cluster import EcoreCluster
from repro.serving.service import EcoreService


def policy_for(_pod: int) -> DetectionPolicy:
    table = nominal_profile_table()
    return DetectionPolicy(OracleRouter(table, 5.0), table)


def factory(decision):
    return make_backend("detector", decision.pair[0], decision.pair[1],
                        None, max_batch=4, run_fn=null_run)


def requests(n: int):
    rng = np.random.default_rng(0)
    frame = np.zeros((8, 8), np.float32)
    return [RouteRequest(uid=i, payload=frame,
                         true_complexity=int(rng.integers(0, 9)))
            for i in range(n)]


def main():
    # 1) sync service: futures + drain
    with EcoreService(policy_for(0), factory) as service:
        futs = [service.submit(r) for r in requests(8)]
        service.drain()
        hist = {}
        for f in futs:
            hist[f.result().decision.pair_name] = \
                hist.get(f.result().decision.pair_name, 0) + 1
        print("sync service pairs:", hist)

    # 2) asyncio facade: the same plane, awaitable
    async def drive():
        async with AsyncEcoreService(policy_for(0), factory) as svc:
            futs = [svc.submit_nowait(r) for r in requests(8)]
            await svc.drain()
            served = await asyncio.gather(*futs)
            # the single observation plane works here too
            svc.observe(Observation(pair=served[0].decision.pair,
                                    uid=served[0].request.uid,
                                    time_ms=99.0))
            return [s.decision.pair_name for s in served]

    print("async served:", sorted(set(asyncio.run(drive()))))

    # 3) cluster: shard one stream over 4 pods, aggregate stats
    with EcoreCluster(policy_for, factory, pods=4) as cluster:
        futs = cluster.submit_batch(requests(32))
        cluster.drain()
        assert all(f.done() for f in futs)
        stats = cluster.stats()
        print(f"cluster: {stats['served']} served over {stats['pods']} pods, "
              f"shard_counts={stats['shard_counts']}")


if __name__ == "__main__":
    main()

"""Open-loop load test: flash crowd vs an autoscaled detector fleet.

  PYTHONPATH=src python examples/load_test.py

Everything runs on a ManualClock — the whole episode (a 6-second flash
crowd at hundreds of requests/second) replays in well under a second of
wall time, deterministically.  The LoadDriver fires batch deadlines at
their exact virtual times (services are built with ``flusher=False``),
books per-pod occupancy from the device latency model, and feeds the
resulting backlog to an Autoscaler that grows the fleet through the
spike and retires pods once it passes.
"""
import numpy as np

from repro.core.policy import DetectionPolicy
from repro.core.router import OracleRouter
from repro.detection.devices import nominal_profile_table
from repro.serving.backend import make_backend, null_run
from repro.serving.cluster import Autoscaler, EcoreCluster
from repro.traffic import (LoadDriver, ManualClock, detector_tenant,
                           flash_crowd_arrivals, merge_tenants)


def policy_for(_pod: int) -> DetectionPolicy:
    table = nominal_profile_table()
    return DetectionPolicy(OracleRouter(table, 5.0), table)


def factory(decision):
    return make_backend("detector", decision.pair[0], decision.pair[1],
                        None, max_batch=4, run_fn=null_run)


def episode(autoscale: bool):
    clock = ManualClock()
    cluster = EcoreCluster(policy_for, factory, pods=2, max_pods=6,
                           max_wait_ms=20.0, clock=clock,
                           retain_results=False, flusher=False)
    auto = Autoscaler(cluster, clock, min_pods=2, max_pods=6,
                      high_backlog_per_pod=10.0, low_backlog_per_pod=1.0,
                      cooldown_s=0.5) if autoscale else None
    arrivals = flash_crowd_arrivals(300.0, 6.0, spike_hz=1200.0, seed=7)
    work = merge_tenants([
        detector_tenant("cam", arrivals, seed=1, deadline_ms=100.0)])
    driver = LoadDriver(cluster, clock, autoscaler=auto, window_s=1.0)
    try:
        driver.run(work)
    finally:
        cluster.close()
    return driver, auto


def main():
    for name, autoscale in (("fixed 2-pod", False), ("autoscaled", True)):
        driver, auto = episode(autoscale)
        print(f"=== {name} ===")
        for rec in driver.slo.window_records():
            print(f"  t={rec['t_start_s']:4.1f}s  n={rec['n']:4d}  "
                  f"p99={rec['p99_ms']:8.1f}ms  "
                  f"goodput={rec['goodput_rps']:7.1f}/s")
        s = driver.slo.summary()
        print(f"  summary: p99={s['p99_ms']:.1f}ms  "
              f"goodput={s['goodput_fraction']:.3f}  "
              f"J/req={s['joules_per_request']:.4f}")
        if auto is not None:
            acts = ", ".join(f"{e['action']}@{e['t_s']:.1f}s"
                             for e in auto.events)
            print(f"  autoscaler: {acts or '(no events)'}")


if __name__ == "__main__":
    main()

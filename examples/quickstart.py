"""Quickstart: route a stream of scenes through the ECORE gateway.

  PYTHONPATH=src python examples/quickstart.py

Trains (or loads cached) detectors, builds the profiling table, and compares
the paper's proposed ED router against the accuracy-centric (HMG) and
energy-centric (LE) baselines on a small scene stream — the 60-second
version of the paper's Figure 6 experiment.
"""
import numpy as np

from repro.core import (EdgeDetectionEstimator, Gateway, GreedyEstimateRouter,
                        HighestMAPPerGroupRouter, LowestEnergyRouter)
from repro.detection.scenes import full_dataset
from repro.detection.train import default_testbed


def main():
    print("loading testbed (first run trains 8 detectors, ~10 min) ...")
    params, table = default_testbed(verbose=True)
    scenes = full_dataset(60, seed=1)
    print(f"\nrouting {len(scenes)} scenes, delta_mAP = 5\n")

    for router, est, label in [
        (HighestMAPPerGroupRouter(table, 5.0), None, "HMG (accuracy-centric)"),
        (GreedyEstimateRouter(table, 5.0), EdgeDetectionEstimator(),
         "ED (ECORE, proposed)"),
        (LowestEnergyRouter(table, 5.0), None, "LE (energy floor)"),
    ]:
        stats = Gateway(router, table, params, est).process_stream(scenes)
        print(f"{label:26s} mAP={stats.map_pct:5.1f}  "
              f"energy={stats.total_energy_mwh:7.4f} mWh  "
              f"latency={stats.total_time_ms:6.0f} ms")
        for pair, n in sorted(stats.pair_histogram.items()):
            print(f"    {pair:26s} x{n}")
    print("\nED should sit near HMG's accuracy at a fraction of its energy.")


if __name__ == "__main__":
    main()

"""End-to-end serving driver: ECORE routing over a pool of LLM backends.

  PYTHONPATH=src python examples/serve_pool.py --requests 16

The production-framework face of the paper (DESIGN.md §2b): backends are the
assigned architectures, profiled from the multi-pod dry-run roofline
(artifacts/dryrun.jsonl); the gateway buckets each request by prompt length
(the serving analog of the object count) and greedily picks the
lowest-energy backend within the delta accuracy tolerance.  Requests are
then actually served — batched prefill + greedy decode — on reduced variants
of the chosen architectures (this container is CPU-only; on a TPU pod the
same Backend wraps the full configs under the production mesh).

The driver is a thin loop over ``serving.service.EcoreService``
(``PoolPolicy`` + per-backend dispatch queues + threaded deadline flusher);
see examples/service_quickstart.py for the service API in isolation.
"""
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    sys.exit(main(sys.argv[1:] if len(sys.argv) > 1 else ["--requests", "16"]))

"""EcoreService in ~30 lines: the request-centric serving API.

  PYTHONPATH=src python examples/service_quickstart.py

Build a routing policy (here: Algorithm 1 over prompt-length buckets),
hand it to an ``EcoreService`` with a backend factory, and stream typed
``RouteRequest``s at it — batching, per-backend queues, the deadline-
bounded background flusher and the ``Observation`` feedback plane are all
inside the service.  The detection face speaks the exact same policy API
(``core.policy.DetectionPolicy`` behind ``Gateway``).
"""
import numpy as np

from repro.configs import get_config
from repro.core.policy import Observation, PoolPolicy, RouteRequest
from repro.launch.serve import synthetic_pool_table
from repro.serving.engine import Backend
from repro.serving.pool import ServingPool
from repro.serving.service import EcoreService


def main():
    pool = ServingPool(synthetic_pool_table(["qwen2.5-3b", "mamba2-370m"]),
                       delta=5.0)

    def backend_factory(decision):
        cfg = get_config(decision.backend).reduced()
        return Backend(decision.backend, cfg, max_batch=4, max_seq=96)

    rng = np.random.default_rng(0)
    with EcoreService(PoolPolicy(pool), backend_factory,
                      max_wait_ms=25.0) as service:
        futures = [service.submit(RouteRequest(
            uid=uid, complexity=plen, max_new_tokens=4,
            payload=rng.integers(0, 1000, size=min(plen, 48))))
            for uid, plen in enumerate((32, 64, 2048, 50_000, 128, 96))]
        for fut in futures:
            s = fut.result(timeout=600)
            print(f"req {s.request.uid} (len {s.request.complexity:6d}) -> "
                  f"{s.decision.pair_name:22s} bucket={s.decision.group} "
                  f"batch={s.result.batch_size} tokens={s.result.tokens}")
            # close the loop: measured latency feeds the next decision
            service.observe(Observation(
                pair=s.decision.pair,
                time_ms=(s.result.prefill_s + s.result.decode_s) * 1e3
                / s.result.batch_size))
        print("flushes:", service.stats()["serve_calls"],
              "| deadline flushes:", service.deadline_flushes)


if __name__ == "__main__":
    main()

"""Train a reduced assigned-architecture LM end to end on CPU.

  PYTHONPATH=src python examples/train_lm.py --arch mamba2-370m --steps 100

Any of the 10 assigned architectures works (--arch recurrentgemma-2b,
deepseek-v2-lite-16b, ...); the model is the reduced smoke variant by
default.  Loss decreases on the synthetic Markov-bigram corpus.  On a TPU
pod, pass --full to train the exact assigned config under the production
mesh (see repro/launch/train.py).
"""
import sys

from repro.launch.train import main

if __name__ == "__main__":
    args = sys.argv[1:] or ["--arch", "qwen2.5-3b", "--steps", "60",
                            "--batch", "8", "--seq", "128"]
    sys.exit(main(args))

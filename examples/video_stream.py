"""Video-stream routing: the Output-Based (OB) estimator on temporal data.

  PYTHONPATH=src python examples/video_stream.py

Reproduces the paper's Insight #3: on temporally-correlated streams, reusing
the previous frame's detected object count (OB) routes as accurately as
running an estimator per frame (ED), at near-zero gateway overhead.
"""
from repro.core import (EdgeDetectionEstimator, Gateway, GreedyEstimateRouter,
                        OracleEstimator, OracleRouter, OutputBasedEstimator)
from repro.detection.scenes import video_dataset
from repro.detection.train import default_testbed


def main():
    params, table = default_testbed()
    frames = video_dataset(n_frames=150, seed=4)
    counts = [s.count for s in frames]
    print(f"{len(frames)} frames; object counts drift: "
          f"{counts[:10]} ... {counts[-10:]}\n")

    for router, est, label in [
        (OracleRouter(table, 5.0), OracleEstimator(), "Orc (ideal)"),
        (GreedyEstimateRouter(table, 5.0), OutputBasedEstimator(), "OB"),
        (GreedyEstimateRouter(table, 5.0), EdgeDetectionEstimator(), "ED"),
    ]:
        stats = Gateway(router, table, params, est).process_stream(frames)
        print(f"{label:12s} mAP={stats.map_pct:5.1f}  "
              f"backendE={stats.backend_energy_mwh:7.4f} mWh  "
              f"gatewayE={stats.gateway_energy_mwh:8.5f} mWh  "
              f"latency={stats.total_time_ms:6.0f} ms")
    print("\nOB ~ Orc accuracy with ~zero gateway energy (Insight #3).")


if __name__ == "__main__":
    main()

"""repro.analysis: the AST-based architectural lint plane.

PRs 1-5 concentrated the ECORE reproduction into a few load-bearing
invariants — a pure scanned closed loop, one serving dispatch plane,
bit-exact jnp oracles per kernel, and a pinned jax 0.4.37 environment.
This package turns those prose rules into enforced ones:

* family ECO1xx — scan/jit purity (host syncs, impure calls, mutation)
* family ECO2xx — hot-path discipline (loops, profile facade, forked
  serving loops)
* family ECO3xx — serving thread/async safety
* family ECO4xx — kernel oracle contract (ops.py + ref.py + parity test)
* family ECO5xx — environment pins (AxisType / make_mesh / hypothesis)

CLI: ``python -m repro.analysis [paths] [--format text|json]``.
Suppress one finding with ``# repro-lint: disable=<rule>`` (justification
text after the ids is encouraged); configure via ``[tool.repro-lint]`` in
pyproject.toml.  Library surface: ``run_paths`` (disk), ``check_source``/
``check_sources`` (in-memory fixtures, used by tests/test_analysis.py).
"""
from repro.analysis.engine import (Report, Violation,  # noqa: F401
                                   check_source, check_sources, run_paths)

__all__ = ["Report", "Violation", "check_source", "check_sources",
           "run_paths"]

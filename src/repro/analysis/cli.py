"""CLI: ``python -m repro.analysis [paths] [--format text|json]
[--select/--ignore IDS] [--list-rules]``.

Exit codes: 0 clean, 1 violations found, 2 usage error.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List, Optional

from repro.analysis.engine import run_paths
from repro.analysis.registry import all_rules

DEFAULT_PATHS = ("src", "tests", "benchmarks", "examples")


def _split(values: List[str]) -> List[str]:
    out: List[str] = []
    for v in values:
        out.extend(s.strip() for s in v.split(",") if s.strip())
    return out


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repro architectural lint: scan/jit purity (ECO1xx), "
                    "hot-path discipline (ECO2xx), serving thread safety "
                    "(ECO3xx), kernel oracle contract (ECO4xx), "
                    "environment pins (ECO5xx).")
    ap.add_argument("paths", nargs="*",
                    help="files/directories to lint (default: whichever of "
                         f"{'/'.join(DEFAULT_PATHS)} exist)")
    ap.add_argument("--format", choices=("text", "json"), default="text",
                    dest="fmt", metavar="text|json")
    ap.add_argument("--select", action="append", default=[], metavar="IDS",
                    help="only run rules matching these comma-separated id "
                         "prefixes or names (e.g. ECO1,ECO302)")
    ap.add_argument("--ignore", action="append", default=[], metavar="IDS",
                    help="skip rules matching these id prefixes or names")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalogue and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rid, cls in all_rules().items():
            print(f"{rid}  {cls.name}")
            print(f"       {cls.description}")
        return 0

    paths = args.paths or [p for p in DEFAULT_PATHS if os.path.exists(p)]
    if not paths:
        print("repro-lint: no paths given and none of "
              f"{', '.join(DEFAULT_PATHS)} exist here", file=sys.stderr)
        return 2
    missing = [p for p in paths if not os.path.exists(p)]
    if missing:
        print(f"repro-lint: no such path: {', '.join(missing)}",
              file=sys.stderr)
        return 2

    report = run_paths(paths, select=_split(args.select) or None,
                       ignore=_split(args.ignore) or None)

    if args.fmt == "json":
        print(json.dumps(report.as_dict(), indent=2, sort_keys=True))
    else:
        for v in report.violations:
            print(v.render())
        n = len(report.violations)
        print(f"repro-lint: {report.files} files, {len(report.rules)} "
              f"rules, {n} violation{'' if n == 1 else 's'} "
              f"({report.suppressed} suppressed)")
    return 1 if report.violations else 0

"""``[tool.repro-lint]`` configuration from pyproject.toml.

Python 3.11+ reads pyproject via ``tomllib``; the container pins 3.10, so a
minimal TOML-subset parser (dotted section headers, string/bool/int
scalars, possibly-multiline arrays of strings — all this block needs) is
the fallback.  It is NOT a general TOML parser.
"""
from __future__ import annotations

import os
import re
from typing import Dict, Optional

DEFAULTS: Dict[str, object] = {
    # collection excludes, added to engine.DEFAULT_EXCLUDE
    "exclude": [],
    # rule id prefixes; CLI flags override select, extend ignore
    "select": [],
    "ignore": [],
    # function names treated as jit-traced scopes even without a decorator
    # (the pure core the scanned closed loop threads state through)
    "pure-functions": ["observe_state", "decide_state", "_mix32"],
    # functions that must stay Python-loop-free in the hot core modules
    "hot-functions": ["scan_stream", "route_batch", "decide_state",
                      "observe_state"],
    # the only files allowed to call .serve_batch(...) directly
    "dispatch-plane": ["*/repro/serving/service.py",
                       "*/repro/serving/engine.py"],
    # extra roots for the ECO12x transitive-purity walk: host-boundary
    # functions whose own bodies AND whole call chains must stay clean of
    # impure calls (jit entries and pure-functions are roots automatically,
    # but per-file ECO1xx already covers their direct bodies)
    "transitive-roots": ["add_pair", "retire_pair"],
}


def find_pyproject(start: str = ".") -> Optional[str]:
    d = os.path.abspath(start)
    if os.path.isfile(d):
        d = os.path.dirname(d)
    while True:
        cand = os.path.join(d, "pyproject.toml")
        if os.path.isfile(cand):
            return cand
        parent = os.path.dirname(d)
        if parent == d:
            return None
        d = parent


def load_config(start: str = ".") -> Dict[str, object]:
    cfg = {k: (list(v) if isinstance(v, list) else v)
           for k, v in DEFAULTS.items()}
    pp = find_pyproject(start)
    if pp is None:
        return cfg
    with open(pp, "r", encoding="utf-8") as fh:
        data = _load_toml(fh.read())
    section = data.get("tool", {}).get("repro-lint", {})
    if isinstance(section, dict):
        cfg.update(section)
    return cfg


def _load_toml(text: str) -> Dict:
    try:
        import tomllib
    except ImportError:  # Python 3.10 (this container)
        return _parse_minimal(text)
    return tomllib.loads(text)


_KEY_RE = re.compile(r"""^\s*([A-Za-z0-9_\-."']+)\s*=\s*(.*)$""")
_STRING_RE = re.compile(r'"((?:[^"\\]|\\.)*)"')


def _strip_strings(s: str) -> str:
    return _STRING_RE.sub("", s)


def _parse_minimal(text: str) -> Dict:
    root: Dict = {}
    table = root
    lines = text.splitlines()
    i = 0
    while i < len(lines):
        line = lines[i].strip()
        i += 1
        if not line or line.startswith("#"):
            continue
        if line.startswith("[") and line.endswith("]"):
            table = root
            for part in line[1:-1].strip().split("."):
                table = table.setdefault(part.strip().strip("\"'"), {})
            continue
        m = _KEY_RE.match(line)
        if m is None:
            continue
        key = m.group(1).strip().strip("\"'")
        value = m.group(2).strip()
        if value.startswith("["):
            buf = value
            while (_strip_strings(buf).count("[")
                   > _strip_strings(buf).count("]")) and i < len(lines):
                buf += " " + lines[i].strip()
                i += 1
            table[key] = [s.replace('\\"', '"')
                          for s in _STRING_RE.findall(buf)]
        else:
            table[key] = _scalar(value)
    return root


def _scalar(value: str):
    if not value.startswith(("\"", "'")):
        value = value.split("#", 1)[0].strip()
    if value in ("true", "false"):
        return value == "true"
    if len(value) >= 2 and value[0] == value[-1] and value[0] in "\"'":
        return value[1:-1]
    try:
        return int(value)
    except ValueError:
        return value

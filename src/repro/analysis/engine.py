"""repro.analysis engine: source loading, suppressions, rule execution.

The engine is deliberately stdlib-only (``ast`` + ``re`` + ``fnmatch``): it
runs in CI before anything heavy is importable, and it must never import
jax — the linted tree includes modules whose import would initialise
device state.

Suppression grammar (free-text justification may follow the id list)::

    x = risky()  # repro-lint: disable=ECO101
    # repro-lint: disable=ECO101, ECO110 -- why this is sanctioned
    x = risky()
    # repro-lint: disable-file=ECO503

An inline marker suppresses its own line; a standalone comment marker
suppresses the next non-comment line (so a justification block may follow
it); ``disable-file`` suppresses the whole file.  ``all`` (or ``*``) as an
id disables every rule.
"""
from __future__ import annotations

import ast
import dataclasses
import fnmatch
import os
import re
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

_SUPPRESS_RE = re.compile(
    r"#\s*repro-lint:\s*(disable|disable-file)\s*=\s*"
    r"([A-Za-z0-9_*]+(?:\s*,\s*[A-Za-z0-9_*]+)*)")

#: always skipped during file collection (config ``exclude`` adds to this)
DEFAULT_EXCLUDE = ("*/__pycache__/*", "*/.git/*", "*/build/*", "*/dist/*",
                   "*.egg-info/*")


def norm_path(path) -> str:
    p = str(path).replace(os.sep, "/")
    while p.startswith("./"):
        p = p[2:]
    return p


def match_path(path, patterns: Sequence[str]) -> bool:
    """fnmatch against both the path and a ``/``-anchored form, so
    ``*/core/*.py`` patterns match repo-relative paths (``src/repro/core/
    x.py`` and ``core/x.py`` alike) as well as absolute ones."""
    p = norm_path(path)
    anchored = p if p.startswith("/") else "/" + p
    return any(fnmatch.fnmatch(anchored, pat) or fnmatch.fnmatch(p, pat)
               for pat in patterns)


@dataclasses.dataclass(frozen=True)
class Violation:
    rule: str
    path: str
    line: int
    col: int
    message: str

    def sort_key(self) -> Tuple[str, int, int, str]:
        return (self.path, self.line, self.col, self.rule)

    def as_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)

    def render(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: "
                f"{self.rule} {self.message}")


class SourceFile:
    """A parsed source file plus its suppression map."""

    def __init__(self, path: str, text: str):
        self.path = norm_path(path)
        self.text = text
        self.lines = text.splitlines()
        self.tree = ast.parse(text)  # caller converts SyntaxError to E001
        self.file_suppress: Set[str] = set()
        #: lineno -> rule ids suppressed on that line.  An inline marker
        #: maps to its own line; a standalone comment marker maps to the
        #: next non-comment line (a justification block may sit between).
        self.line_suppress: Dict[int, Set[str]] = {}
        for lineno, line in enumerate(self.lines, start=1):
            m = _SUPPRESS_RE.search(line)
            if m is None:
                continue
            ids = {s.strip() for s in m.group(2).split(",") if s.strip()}
            if m.group(1) == "disable-file":
                self.file_suppress |= ids
                continue
            target = lineno
            if line.lstrip().startswith("#"):
                for nxt in range(lineno + 1, len(self.lines) + 1):
                    stripped = self.lines[nxt - 1].strip()
                    if stripped and not stripped.startswith("#"):
                        target = nxt
                        break
                else:
                    continue  # trailing comment block: nothing to suppress
            self.line_suppress.setdefault(target, set()).update(ids)

    def suppressed(self, rule_id: str, line: int) -> bool:
        for ids in (self.file_suppress, self.line_suppress.get(line, ())):
            if rule_id in ids or "all" in ids or "*" in ids:
                return True
        return False


@dataclasses.dataclass
class Report:
    files: int
    rules: List[str]
    violations: List[Violation]
    suppressed: int

    def counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for v in self.violations:
            out[v.rule] = out.get(v.rule, 0) + 1
        return out

    def as_dict(self) -> Dict[str, object]:
        """JSON schema v1 — stable; covered by tests/test_analysis.py."""
        return {"version": 1,
                "files": self.files,
                "rules": list(self.rules),
                "violations": [v.as_dict() for v in self.violations],
                "counts": self.counts(),
                "suppressed": self.suppressed}


def parse_source(path: str, text: str):
    """-> ``(SourceFile, None)`` or ``(None, E001 Violation)``."""
    try:
        return SourceFile(path, text), None
    except SyntaxError as e:
        return None, Violation("E001", norm_path(path), e.lineno or 1,
                               max((e.offset or 1) - 1, 0),
                               f"syntax error: {e.msg}")


def collect_paths(paths: Sequence[str],
                  exclude: Sequence[str] = DEFAULT_EXCLUDE) -> List[str]:
    out: List[str] = []
    for p in paths:
        p = norm_path(p)
        if os.path.isfile(p):
            if p.endswith(".py") and not match_path(p, exclude):
                out.append(p)
            continue
        for dirpath, dirnames, filenames in os.walk(p):
            dirnames[:] = sorted(d for d in dirnames
                                 if d not in (".git", "__pycache__"))
            for fn in sorted(filenames):
                if not fn.endswith(".py"):
                    continue
                fp = norm_path(os.path.join(dirpath, fn))
                if not match_path(fp, exclude):
                    out.append(fp)
    seen: Set[str] = set()
    uniq = []
    for p in out:
        if p not in seen:
            seen.add(p)
            uniq.append(p)
    return uniq


def run_rules(sources: Sequence[SourceFile], rules,
              extra_violations: Iterable[Violation] = ()):
    """-> (sorted violations, suppressed count)."""
    by_path = {s.path: s for s in sources}
    violations = list(extra_violations)
    suppressed = 0
    for rule in rules:
        targets = [s for s in sources if rule.applies_to(s.path)]
        if rule.project_level:
            found = list(rule.check_project(targets))
        else:
            found = [v for src in targets for v in rule.check(src)]
        for v in found:
            src = by_path.get(v.path)
            if src is not None and src.suppressed(v.rule, v.line):
                suppressed += 1
            else:
                violations.append(v)
    violations.sort(key=Violation.sort_key)
    return violations, suppressed


def run_paths(paths: Sequence[str], *, select: Optional[Sequence[str]] = None,
              ignore: Optional[Sequence[str]] = None,
              config: Optional[Dict[str, object]] = None) -> Report:
    """Lint files/directories on disk (the CLI entry point)."""
    from repro.analysis.config import load_config
    from repro.analysis.registry import make_rules
    cfg = dict(config) if config is not None else load_config(
        paths[0] if paths else ".")
    exclude = tuple(DEFAULT_EXCLUDE) + tuple(cfg.get("exclude") or ())
    files = collect_paths(paths, exclude)
    sources, errors = [], []
    for fp in files:
        with open(fp, "r", encoding="utf-8") as fh:
            text = fh.read()
        src, err = parse_source(fp, text)
        if src is not None:
            sources.append(src)
        else:
            errors.append(err)
    rules = make_rules(select=list(select or ()) or None,
                       ignore=list(ignore or ()) + list(cfg.get("ignore")
                                                        or ()),
                       options=cfg)
    violations, suppressed = run_rules(sources, rules, errors)
    return Report(files=len(files), rules=[r.id for r in rules],
                  violations=violations, suppressed=suppressed)


def check_sources(named: Dict[str, str], *,
                  select: Optional[Sequence[str]] = None,
                  ignore: Optional[Sequence[str]] = None,
                  options: Optional[Dict[str, object]] = None) -> Report:
    """Lint in-memory sources (``{path: text}``) — the fixture-test surface.

    Paths are virtual but still drive per-rule include/exclude matching, so
    fixtures choose which plane they pretend to live in (e.g.
    ``src/repro/core/x.py``).
    """
    from repro.analysis.config import DEFAULTS
    from repro.analysis.registry import make_rules
    cfg = {k: (list(v) if isinstance(v, list) else v)
           for k, v in DEFAULTS.items()}
    cfg.update(options or {})
    sources, errors = [], []
    for path, text in named.items():
        src, err = parse_source(path, text)
        if src is not None:
            sources.append(src)
        else:
            errors.append(err)
    rules = make_rules(select=list(select or ()) or None,
                       ignore=list(ignore or ()) or None, options=cfg)
    violations, suppressed = run_rules(sources, rules, errors)
    return Report(files=len(named), rules=[r.id for r in rules],
                  violations=violations, suppressed=suppressed)


def check_source(text: str, path: str = "src/repro/core/snippet.py",
                 **kw) -> List[Violation]:
    """Lint one in-memory snippet; returns the violation list."""
    return check_sources({path: text}, **kw).violations

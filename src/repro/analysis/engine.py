"""repro.analysis engine: source loading, suppressions, rule execution.

The engine is deliberately stdlib-only (``ast`` + ``re`` + ``fnmatch``): it
runs in CI before anything heavy is importable, and it must never import
jax — the linted tree includes modules whose import would initialise
device state.

Suppression grammar (free-text justification may follow the id list)::

    x = risky()  # repro-lint: disable=ECO101
    # repro-lint: disable=ECO101, ECO110 -- why this is sanctioned
    x = risky()
    # repro-lint: disable-file=ECO503

An inline marker suppresses its own line; a standalone comment marker
suppresses the next non-comment line (so a justification block may follow
it), and when that line starts a decorator stack the decorated ``def`` /
``class`` line is covered too; ``disable-file`` suppresses the whole file.
``all`` (or ``*``) as an id disables every rule.  Markers are read from
real COMMENT tokens only — marker-shaped text inside docstrings or string
literals is inert (it used to register phantom suppressions).
"""
from __future__ import annotations

import ast
import dataclasses
import fnmatch
import io
import os
import re
import tokenize
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

_SUPPRESS_RE = re.compile(
    r"#\s*repro-lint:\s*(disable|disable-file)\s*=\s*"
    r"([A-Za-z0-9_*]+(?:\s*,\s*[A-Za-z0-9_*]+)*)")

#: always skipped during file collection (config ``exclude`` adds to this)
DEFAULT_EXCLUDE = ("*/__pycache__/*", "*/.git/*", "*/build/*", "*/dist/*",
                   "*.egg-info/*")


def norm_path(path) -> str:
    p = str(path).replace(os.sep, "/")
    while p.startswith("./"):
        p = p[2:]
    return p


def match_path(path, patterns: Sequence[str]) -> bool:
    """fnmatch against both the path and a ``/``-anchored form, so
    ``*/core/*.py`` patterns match repo-relative paths (``src/repro/core/
    x.py`` and ``core/x.py`` alike) as well as absolute ones."""
    p = norm_path(path)
    anchored = p if p.startswith("/") else "/" + p
    return any(fnmatch.fnmatch(anchored, pat) or fnmatch.fnmatch(p, pat)
               for pat in patterns)


@dataclasses.dataclass(frozen=True)
class Violation:
    rule: str
    path: str
    line: int
    col: int
    message: str

    def sort_key(self) -> Tuple[str, int, int, str]:
        return (self.path, self.line, self.col, self.rule)

    def as_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)

    def render(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: "
                f"{self.rule} {self.message}")


@dataclasses.dataclass
class Marker:
    """One ``# repro-lint: disable[-file]=...`` comment marker."""
    lineno: int
    ids: Tuple[str, ...]
    file_level: bool
    targets: Set[int]                   # lines this marker covers
    used_for: Set[str] = dataclasses.field(default_factory=set)

    def names(self, rule_id: str) -> bool:
        return rule_id in self.ids or "all" in self.ids or "*" in self.ids


def _comment_tokens(text: str):
    """(lineno, col, comment-text) for every real COMMENT token.  The text
    already parsed under ``ast``, so tokenize errors are tail-only; comments
    gathered before one are kept."""
    out = []
    try:
        for tok in tokenize.generate_tokens(io.StringIO(text).readline):
            if tok.type == tokenize.COMMENT:
                out.append((tok.start[0], tok.start[1], tok.string))
    except (tokenize.TokenError, IndentationError, SyntaxError):
        pass
    return out


class SourceFile:
    """A parsed source file plus its suppression markers."""

    def __init__(self, path: str, text: str):
        self.path = norm_path(path)
        self.text = text
        self.lines = text.splitlines()
        self.tree = ast.parse(text)  # caller converts SyntaxError to E001
        #: decorator-stack start line -> decorated def/class line, so a
        #: standalone marker above ``@decorator`` also covers the def line
        #: (rules report at the def, not the decorator).
        dec_spans = {}
        for node in ast.walk(self.tree):
            decs = getattr(node, "decorator_list", None)
            if decs:
                first = min(d.lineno for d in decs)
                for ln in range(first, node.lineno):
                    dec_spans[ln] = node.lineno
        self.markers: List[Marker] = []
        for lineno, col, comment in _comment_tokens(text):
            m = _SUPPRESS_RE.search(comment)
            if m is None:
                continue
            ids = tuple(dict.fromkeys(
                s.strip() for s in m.group(2).split(",") if s.strip()))
            if m.group(1) == "disable-file":
                self.markers.append(Marker(lineno, ids, True, set()))
                continue
            targets = {lineno}
            standalone = self.lines[lineno - 1][:col].strip() == ""
            if standalone:
                targets = set()
                for nxt in range(lineno + 1, len(self.lines) + 1):
                    stripped = self.lines[nxt - 1].strip()
                    if stripped and not stripped.startswith("#"):
                        targets = {nxt}
                        if nxt in dec_spans:
                            targets.add(dec_spans[nxt])
                        break
                if not targets:
                    continue  # trailing comment block: nothing to suppress
            self.markers.append(Marker(lineno, ids, False, targets))

    def suppressed(self, rule_id: str, line: int,
                   explicit_only: bool = False) -> bool:
        """True when a marker covers (rule, line); records marker usage so
        the ECO900 meta-rule can flag markers that never matched.  With
        ``explicit_only`` (used for ECO900's own findings) blanket
        ``all``/``*`` markers do not match — a stale blanket marker must
        not be able to swallow its own audit."""
        hit = False
        for m in self.markers:
            if not (m.file_level or line in m.targets):
                continue
            if rule_id in m.ids or (not explicit_only and m.names(rule_id)):
                m.used_for.add(rule_id)
                hit = True
        return hit


@dataclasses.dataclass
class Report:
    files: int
    rules: List[str]
    violations: List[Violation]
    suppressed: int

    def counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for v in self.violations:
            out[v.rule] = out.get(v.rule, 0) + 1
        return out

    def as_dict(self) -> Dict[str, object]:
        """JSON schema v1 — stable; covered by tests/test_analysis.py."""
        return {"version": 1,
                "files": self.files,
                "rules": list(self.rules),
                "violations": [v.as_dict() for v in self.violations],
                "counts": self.counts(),
                "suppressed": self.suppressed}


def parse_source(path: str, text: str):
    """-> ``(SourceFile, None)`` or ``(None, E001 Violation)``."""
    try:
        return SourceFile(path, text), None
    except SyntaxError as e:
        return None, Violation("E001", norm_path(path), e.lineno or 1,
                               max((e.offset or 1) - 1, 0),
                               f"syntax error: {e.msg}")


def collect_paths(paths: Sequence[str],
                  exclude: Sequence[str] = DEFAULT_EXCLUDE) -> List[str]:
    out: List[str] = []
    for p in paths:
        p = norm_path(p)
        if os.path.isfile(p):
            if p.endswith(".py") and not match_path(p, exclude):
                out.append(p)
            continue
        for dirpath, dirnames, filenames in os.walk(p):
            dirnames[:] = sorted(d for d in dirnames
                                 if d != "__pycache__"
                                 and not d.startswith("."))
            for fn in sorted(filenames):
                if not fn.endswith(".py"):
                    continue
                fp = norm_path(os.path.join(dirpath, fn))
                if not match_path(fp, exclude):
                    out.append(fp)
    seen: Set[str] = set()
    uniq = []
    for p in out:
        if p not in seen:
            seen.add(p)
            uniq.append(p)
    return uniq


def run_rules(sources: Sequence[SourceFile], rules,
              extra_violations: Iterable[Violation] = ()):
    """-> (sorted violations, suppressed count).

    Rules flagged ``runs_after`` (the ECO900 suppression audit) execute
    once every other rule has consulted the suppression maps.  Rules
    flagged ``requires_project`` share one lazily-built ``Project`` graph
    — the single whole-tree parse pass the interprocedural families run
    on.
    """
    by_path = {s.path: s for s in sources}
    violations = list(extra_violations)
    suppressed = 0
    project = None
    enabled = frozenset(r.id for r in rules)
    ordered = ([r for r in rules if not r.runs_after]
               + [r for r in rules if r.runs_after])
    for rule in ordered:
        rule.enabled_ids = enabled
        if rule.requires_project:
            if project is None:
                from repro.analysis.project import build_project
                project = build_project(sources)
            rule.project = project
        targets = [s for s in sources if rule.applies_to(s.path)]
        if rule.project_level:
            found = list(rule.check_project(targets))
        else:
            found = [v for src in targets for v in rule.check(src)]
        for v in found:
            src = by_path.get(v.path)
            if src is not None and src.suppressed(
                    v.rule, v.line, explicit_only=rule.runs_after):
                suppressed += 1
            else:
                violations.append(v)
    violations.sort(key=Violation.sort_key)
    return violations, suppressed


def run_paths(paths: Sequence[str], *, select: Optional[Sequence[str]] = None,
              ignore: Optional[Sequence[str]] = None,
              config: Optional[Dict[str, object]] = None,
              project: bool = False) -> Report:
    """Lint files/directories on disk (the CLI entry point)."""
    from repro.analysis.config import load_config
    from repro.analysis.registry import make_rules
    cfg = dict(config) if config is not None else load_config(
        paths[0] if paths else ".")
    exclude = tuple(DEFAULT_EXCLUDE) + tuple(cfg.get("exclude") or ())
    files = collect_paths(paths, exclude)
    sources, errors = [], []
    loaded = 0
    for fp in files:
        try:
            with open(fp, "r", encoding="utf-8") as fh:
                text = fh.read()
        except (UnicodeDecodeError, OSError):
            continue  # binary / non-UTF8 / unreadable: not lintable source
        loaded += 1
        src, err = parse_source(fp, text)
        if src is not None:
            sources.append(src)
        else:
            errors.append(err)
    rules = make_rules(select=list(select or ()) or None,
                       ignore=list(ignore or ()) + list(cfg.get("ignore")
                                                        or ()),
                       options=cfg, project=project)
    violations, suppressed = run_rules(sources, rules, errors)
    return Report(files=loaded, rules=[r.id for r in rules],
                  violations=violations, suppressed=suppressed)


def check_sources(named: Dict[str, str], *,
                  select: Optional[Sequence[str]] = None,
                  ignore: Optional[Sequence[str]] = None,
                  options: Optional[Dict[str, object]] = None,
                  project: bool = False) -> Report:
    """Lint in-memory sources (``{path: text}``) — the fixture-test surface.

    Paths are virtual but still drive per-rule include/exclude matching, so
    fixtures choose which plane they pretend to live in (e.g.
    ``src/repro/core/x.py``).
    """
    from repro.analysis.config import DEFAULTS
    from repro.analysis.registry import make_rules
    cfg = {k: (list(v) if isinstance(v, list) else v)
           for k, v in DEFAULTS.items()}
    cfg.update(options or {})
    sources, errors = [], []
    for path, text in named.items():
        src, err = parse_source(path, text)
        if src is not None:
            sources.append(src)
        else:
            errors.append(err)
    rules = make_rules(select=list(select or ()) or None,
                       ignore=list(ignore or ()) or None, options=cfg,
                       project=project)
    violations, suppressed = run_rules(sources, rules, errors)
    return Report(files=len(named), rules=[r.id for r in rules],
                  violations=violations, suppressed=suppressed)


def check_source(text: str, path: str = "src/repro/core/snippet.py",
                 **kw) -> List[Violation]:
    """Lint one in-memory snippet; returns the violation list."""
    return check_sources({path: text}, **kw).violations

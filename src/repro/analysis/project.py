"""Whole-project symbol graph: the interprocedural layer under ECO6xx/7xx/12x.

Per-file AST rules cannot see the failure shapes that actually threaten the
serving plane after PRs 7/8 — a drain reached under a lock through two call
hops, two locks taken in opposite orders from different entry points, a host
sync buried three calls below ``decide_state``.  This module parses NOTHING
itself: it reuses the engine's already-parsed ``SourceFile`` trees (one
parse pass total) and builds, in one walk per file:

  * a module-level symbol table (top-level defs, classes + methods + base
    links, import aliases including lazy function-local imports, module
    globals assigned from factory calls);
  * a conservative call graph — bare names resolve through lexical scope,
    imports and module globals; ``self.m()`` through the enclosing class
    and its bases; ``self.attr.m()`` / ``var.m()`` through constructor
    assignments and parameter annotations; everything else stays OPAQUE
    (an unresolved call creates no edge, so absence of a finding never
    rests on a guessed target).  Function references passed as values
    (``lax.scan(step, ...)``, ``executor.submit(fn)``, callbacks, lambda
    bodies) become DEFERRED edges: reachability rules follow them, lock
    rules do not (the callee runs later, on some other stack);
  * a lock-region model — which ``with <lockish>`` locks are held at every
    call site and acquisition, plus the blocking surface (``.join``,
    ``.result``, ``.drain``, ``.close``, ``.wait``, sleeps, queue gets)
    with ``Condition.wait`` on the currently-held lock sanctioned.

Stdlib-only, like the rest of the analysis plane.  Rules receive one shared
``Project`` per run (built lazily by the engine, cached); the whole-tree
build stays well under the 5 s budget because it is a single O(nodes) pass
plus memoized fix-points.
"""
from __future__ import annotations

import ast
import dataclasses
import re
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from repro.analysis.engine import SourceFile
from repro.analysis.rules.common import dotted_name

_LOCKISH = re.compile(r"lock|cond|mutex|sem", re.I)
_QUEUEISH = frozenset({"q", "_q", "queue", "_queue"})
_THREADISH = ("Thread",)


def module_name(path: str) -> str:
    """``src/repro/serving/service.py`` -> ``repro.serving.service``."""
    p = path[:-3] if path.endswith(".py") else path
    parts = [s for s in p.split("/") if s not in (".", "")]
    if "src" in parts:
        parts = parts[parts.index("src") + 1:]
    if parts and parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


@dataclasses.dataclass
class CallSite:
    """One resolved-or-opaque call (or function reference) in a body."""
    node: ast.AST
    raw: str                        # the dotted text as written
    target: Optional["FunctionInfo"]
    held: Tuple[str, ...]           # lock ids held at this site
    deferred: bool                  # passed as a value / inside a lambda


@dataclasses.dataclass
class Acquire:
    lock: str                       # canonical lock id
    raw: str                        # dotted receiver as written
    node: ast.AST
    held: Tuple[str, ...]           # locks already held when acquiring


@dataclasses.dataclass
class Blocking:
    node: ast.AST
    kind: str                       # "result"|"join"|"sleep"|"get"|"wait"|
                                    # "drain"|"close"
    raw: str
    held: Tuple[str, ...]
    sanctioned: bool                # Condition.wait on a held lock


@dataclasses.dataclass
class FunctionInfo:
    qualname: str                   # "repro.x.y:Class.method" / ":f.inner"
    name: str
    path: str
    node: ast.AST
    module: "ModuleInfo"
    cls: Optional["ClassInfo"] = None
    calls: List[CallSite] = dataclasses.field(default_factory=list)
    acquires: List[Acquire] = dataclasses.field(default_factory=list)
    blocking: List[Blocking] = dataclasses.field(default_factory=list)
    #: (node, receiver last segment) for asyncio-future set_result/
    #: set_exception sites
    completions: List[Tuple[ast.AST, str]] = dataclasses.field(
        default_factory=list)
    returns_fn: Optional["FunctionInfo"] = None
    nested: Dict[str, "FunctionInfo"] = dataclasses.field(
        default_factory=dict)

    @property
    def jit_decorated(self) -> bool:
        from repro.analysis.rules.common import is_jit_decorator
        decs = getattr(self.node, "decorator_list", ())
        return any(is_jit_decorator(d) for d in decs)


@dataclasses.dataclass
class ClassInfo:
    name: str
    node: ast.ClassDef
    module: "ModuleInfo"
    bases: List[str] = dataclasses.field(default_factory=list)
    methods: Dict[str, FunctionInfo] = dataclasses.field(default_factory=dict)
    #: instance attr -> raw class name (``self.x = Cls(...)`` in __init__,
    #: or the annotation of the parameter assigned into the attr)
    attr_types: Dict[str, str] = dataclasses.field(default_factory=dict)
    #: class-level assignments (``batchable = True``) name -> value expr
    class_assigns: Dict[str, ast.expr] = dataclasses.field(
        default_factory=dict)
    #: names bound by class-level AnnAssign (with or without a value)
    annotations: Set[str] = dataclasses.field(default_factory=set)
    #: every ``self.X`` assigned anywhere in ``__init__``
    init_attrs: Set[str] = dataclasses.field(default_factory=set)
    #: method names defined as @property
    properties: Set[str] = dataclasses.field(default_factory=set)


@dataclasses.dataclass
class ModuleInfo:
    name: str
    path: str
    src: SourceFile
    defs: Dict[str, FunctionInfo] = dataclasses.field(default_factory=dict)
    classes: Dict[str, ClassInfo] = dataclasses.field(default_factory=dict)
    #: local alias -> (module, symbol|None); symbol None = module alias
    imports: Dict[str, Tuple[str, Optional[str]]] = dataclasses.field(
        default_factory=dict)
    #: module-global name -> candidate value exprs (module level first;
    #: later function-body rebinds of an existing global are appended, so
    #: ``_scan_kernel = _scan_jit()`` inside the wrapper resolves)
    assigns: Dict[str, List[ast.expr]] = dataclasses.field(
        default_factory=dict)
    #: names known to hold asyncio futures (bound from ``.create_future()``)
    afut_names: Set[str] = dataclasses.field(default_factory=set)


def _is_lockish(expr) -> Optional[str]:
    """Dotted receiver text when ``expr`` looks like a lock, else None."""
    if isinstance(expr, ast.Call):
        expr = expr.func
    raw = dotted_name(expr)
    if raw is None:
        return None
    last = raw.rsplit(".", 1)[-1]
    return raw if _LOCKISH.search(last) else None


def _blocking_kind(call: ast.Call) -> Optional[Tuple[str, str]]:
    """(kind, receiver-dotted) for calls that can park the calling thread."""
    f = call.func
    if isinstance(f, ast.Name):
        return ("sleep", "") if f.id == "sleep" else None
    if not isinstance(f, ast.Attribute):
        return None
    raw = dotted_name(f.value) or ""
    if dotted_name(f) == "time.sleep":
        return ("sleep", raw)
    if f.attr in ("result", "join", "drain", "close", "wait"):
        return (f.attr, raw)
    if f.attr == "get":
        recv = raw.rsplit(".", 1)[-1]
        if recv in _QUEUEISH or recv.endswith("_queue"):
            return ("get", raw)
    return None


class Project:
    """The built graph; rules receive one shared instance per run."""

    def __init__(self, sources: Sequence[SourceFile]):
        self.modules: Dict[str, ModuleInfo] = {}
        self.functions: Dict[str, FunctionInfo] = {}
        #: functions handed to Thread(target=...)/executor.submit/
        #: add_done_callback — they run on a foreign thread
        self.foreign_entries: Set[str] = set()
        #: functions scheduled via call_soon_threadsafe — loop-thread safe
        self.scheduled: Set[str] = set()
        for src in sources:
            mod = ModuleInfo(module_name(src.path), src.path, src)
            # first module wins on a name collision (virtual fixture paths
            # can alias); real trees have unique module names
            self.modules.setdefault(mod.name, mod)
            self._collect_symbols(mod)
        for mod in self.modules.values():
            for fi in self._module_functions(mod):
                self._scan_function(fi)
        self._block_memo: Dict[str, Optional[Tuple[str, Tuple[str, ...]]]] \
            = {}
        self._acq_memo: Dict[str, Dict[str, Tuple[str, ...]]] = {}

    # ------------------------------------------------------------ pass 1

    def _collect_symbols(self, mod: ModuleInfo) -> None:
        tree = mod.src.tree
        for node in tree.body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                fi = self._register_function(mod, None, node, prefix="")
                mod.defs[node.name] = fi
            elif isinstance(node, ast.ClassDef):
                mod.classes[node.name] = self._register_class(mod, node)
            elif isinstance(node, ast.Assign):
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name):
                        mod.assigns.setdefault(tgt.id, []).append(node.value)
        # imports anywhere (this repo leans on lazy function-local imports)
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    mod.imports[alias.asname or alias.name.split(".")[0]] = (
                        alias.name, None)
            elif isinstance(node, ast.ImportFrom):
                base = node.module or ""
                if node.level:
                    parent = mod.name.split(".")
                    parent = parent[:len(parent) - node.level]
                    base = ".".join(parent + ([node.module]
                                              if node.module else []))
                for alias in node.names:
                    if alias.name == "*":
                        continue
                    mod.imports[alias.asname or alias.name] = (
                        base, alias.name)
            elif isinstance(node, ast.Assign) and isinstance(
                    node.value, ast.Call):
                # function-body rebinding of an existing module global
                # (``global _scan_kernel; _scan_kernel = _scan_jit()``)
                for tgt in node.targets:
                    if (isinstance(tgt, ast.Name) and tgt.id in mod.assigns
                            and node.value is not mod.assigns[tgt.id][0]):
                        cands = mod.assigns[tgt.id]
                        if node.value not in cands:
                            cands.append(node.value)
            # asyncio future bindings: x = loop.create_future() /
            # self._afut = loop.create_future()
            value = getattr(node, "value", None)
            if (isinstance(node, (ast.Assign, ast.AnnAssign))
                    and isinstance(value, ast.Call)
                    and isinstance(value.func, ast.Attribute)
                    and value.func.attr == "create_future"):
                targets = (node.targets if isinstance(node, ast.Assign)
                           else [node.target])
                for tgt in targets:
                    if isinstance(tgt, ast.Name):
                        mod.afut_names.add(tgt.id)
                    elif isinstance(tgt, ast.Attribute):
                        mod.afut_names.add(tgt.attr)

    def _register_function(self, mod: ModuleInfo, cls: Optional[ClassInfo],
                           node, prefix: str) -> FunctionInfo:
        qual = f"{mod.name}:{prefix}{node.name}"
        fi = FunctionInfo(qualname=qual, name=node.name, path=mod.path,
                          node=node, module=mod, cls=cls)
        self.functions[qual] = fi
        for child in node.body:
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                fi.nested[child.name] = self._register_function(
                    mod, cls, child, prefix=f"{prefix}{node.name}.")
        # factory shape: every ``return <name>`` of a nested def, one name
        returned = {s.value.id for s in node.body
                    if isinstance(s, ast.Return)
                    and isinstance(s.value, ast.Name)
                    and s.value.id in fi.nested}
        if len(returned) == 1:
            fi.returns_fn = fi.nested[returned.pop()]
        return fi

    def _register_class(self, mod: ModuleInfo, node: ast.ClassDef
                        ) -> ClassInfo:
        ci = ClassInfo(name=node.name, node=node, module=mod,
                       bases=[d for b in node.bases
                              if (d := dotted_name(b)) is not None])
        for child in node.body:
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                fi = self._register_function(mod, ci, child,
                                             prefix=f"{node.name}.")
                ci.methods[child.name] = fi
                if any(dotted_name(d) in ("property", "cached_property",
                                          "functools.cached_property")
                       for d in child.decorator_list):
                    ci.properties.add(child.name)
            elif isinstance(child, ast.Assign):
                for tgt in child.targets:
                    if isinstance(tgt, ast.Name):
                        ci.class_assigns[tgt.id] = child.value
            elif isinstance(child, ast.AnnAssign) and isinstance(
                    child.target, ast.Name):
                ci.annotations.add(child.target.id)
                if child.value is not None:
                    ci.class_assigns[child.target.id] = child.value
        init = ci.methods.get("__init__")
        if init is not None:
            self._collect_attr_types(ci, init.node)
        return ci

    @staticmethod
    def _collect_attr_types(ci: ClassInfo, init) -> None:
        """``self.x = Cls(...)`` and ``self.x = <annotated param>``."""
        ann = {}
        args = init.args
        for a in (args.posonlyargs + args.args + args.kwonlyargs):
            if a.annotation is not None:
                raw = dotted_name(a.annotation)
                if raw is None and isinstance(a.annotation, ast.Constant) \
                        and isinstance(a.annotation.value, str):
                    raw = a.annotation.value
                if raw:
                    ann[a.arg] = raw
        for node in ast.walk(init):
            if isinstance(node, (ast.AnnAssign, ast.AugAssign)):
                tgt = node.target
                if (isinstance(tgt, ast.Attribute)
                        and isinstance(tgt.value, ast.Name)
                        and tgt.value.id == "self"):
                    ci.init_attrs.add(tgt.attr)
                continue
            if not isinstance(node, ast.Assign):
                continue
            for tgt in node.targets:
                if not (isinstance(tgt, ast.Attribute)
                        and isinstance(tgt.value, ast.Name)
                        and tgt.value.id == "self"):
                    continue
                ci.init_attrs.add(tgt.attr)
                if isinstance(node.value, ast.Call):
                    raw = dotted_name(node.value.func)
                    if raw:
                        ci.attr_types.setdefault(tgt.attr, raw)
                elif isinstance(node.value, ast.Name) \
                        and node.value.id in ann:
                    ci.attr_types.setdefault(tgt.attr, ann[node.value.id])

    def _module_functions(self, mod: ModuleInfo) -> Iterable[FunctionInfo]:
        for fi in self.functions.values():
            if fi.module is mod:
                yield fi

    # ------------------------------------------------------ pass 2: edges

    def _scan_function(self, fi: FunctionInfo) -> None:
        # local instance types: ``svc = EcoreService(...)`` inside the body
        local_insts: Dict[str, str] = {}
        for node in ast.walk(fi.node):
            if isinstance(node, ast.Assign) and isinstance(
                    node.value, ast.Call):
                raw = dotted_name(node.value.func)
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name) and raw:
                        local_insts.setdefault(tgt.id, raw)
        scope = []
        cur: Optional[FunctionInfo] = fi
        while cur is not None:   # innermost-first chain of nested-def scopes
            scope.append(cur.nested)
            cur = self._parent_of(cur)
        self._visit_body(fi, list(ast.iter_child_nodes(fi.node)),
                         held=(), deferred=False,
                         scope=scope, local_insts=local_insts)

    def _parent_of(self, fi: FunctionInfo) -> Optional[FunctionInfo]:
        if "." not in fi.qualname.split(":", 1)[1]:
            return None
        parent_qual = fi.qualname.rsplit(".", 1)[0]
        parent = self.functions.get(parent_qual)
        # class-qualified method names are not nesting parents
        if parent is not None and fi.qualname in (
                f"{parent.qualname}.{fi.name}",):
            if fi.node in getattr(parent.node, "body", ()):
                return parent
        return None

    def _visit_body(self, fi, nodes, held, deferred, scope, local_insts):
        for node in nodes:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue        # separate FunctionInfo, scanned on its own
            if isinstance(node, ast.Lambda):
                self._visit_body(fi, [node.body], held, True,
                                 scope, local_insts)
                continue
            if isinstance(node, (ast.With, ast.AsyncWith)):
                self._visit_with(fi, node, held, deferred, scope,
                                 local_insts)
                continue
            if isinstance(node, ast.Call):
                self._visit_call(fi, node, held, deferred, scope,
                                 local_insts)
            self._visit_body(fi, list(ast.iter_child_nodes(node)),
                             held, deferred, scope, local_insts)

    def _visit_with(self, fi, node, held, deferred, scope, local_insts):
        new_held = list(held)
        for item in node.items:
            self._visit_body(fi, [item.context_expr], tuple(new_held),
                             deferred, scope, local_insts)
            raw = _is_lockish(item.context_expr)
            if raw is not None:
                lock = self._lock_id(fi, raw)
                fi.acquires.append(Acquire(lock=lock, raw=raw, node=node,
                                           held=tuple(new_held)))
                new_held.append(lock)
        self._visit_body(fi, node.body, tuple(new_held), deferred,
                         scope, local_insts)

    def _lock_id(self, fi: FunctionInfo, raw: str) -> str:
        """Canonical id: ``self.X`` -> ``module.Class.X``; else module.raw."""
        if raw.startswith("self.") and fi.cls is not None:
            return f"{fi.module.name}.{fi.cls.name}.{raw[5:]}"
        return f"{fi.module.name}.{raw}"

    def _visit_call(self, fi, node, held, deferred, scope, local_insts):
        raw = dotted_name(node.func) or "<expr>"
        blk = _blocking_kind(node)
        if blk is not None:
            kind, recv = blk
            sanctioned = kind == "wait" and recv in {
                a.raw for a in fi.acquires if a.lock in held}
            fi.blocking.append(Blocking(node=node, kind=kind, raw=raw,
                                        held=held, sanctioned=sanctioned))
        if (isinstance(node.func, ast.Attribute)
                and node.func.attr in ("set_result", "set_exception")):
            recv = node.func.value
            key = recv.id if isinstance(recv, ast.Name) else (
                recv.attr if isinstance(recv, ast.Attribute) else None)
            if key is not None and key in fi.module.afut_names:
                fi.completions.append((node, key))
        target = self._resolve(node.func, fi, scope, local_insts,
                               as_call=True)
        fi.calls.append(CallSite(node=node, raw=raw, target=target,
                                 held=held, deferred=deferred))
        # function references passed as values -> deferred edges + intent
        # markers (thread targets, scheduled callbacks)
        refs: List[Tuple[Optional[str], ast.AST]] = []
        for arg in node.args:
            refs.append((None, arg))
        for kw in node.keywords:
            refs.append((kw.arg, kw.value))
        fname = raw.rsplit(".", 1)[-1]
        for kwname, expr in refs:
            if not isinstance(expr, (ast.Name, ast.Attribute)):
                continue
            t = self._resolve(expr, fi, scope, local_insts, as_call=False)
            if t is None:
                continue
            fi.calls.append(CallSite(node=expr, raw=dotted_name(expr) or "",
                                     target=t, held=held, deferred=True))
            if fname in _THREADISH and kwname == "target":
                self.foreign_entries.add(t.qualname)
            elif fname in ("submit", "add_done_callback") and kwname is None:
                self.foreign_entries.add(t.qualname)
            elif fname == "call_soon_threadsafe" and kwname is None:
                self.scheduled.add(t.qualname)

    # -------------------------------------------------------- resolution

    def _resolve(self, expr, fi: FunctionInfo, scope, local_insts,
                 as_call: bool) -> Optional[FunctionInfo]:
        out = self._resolve_value(expr, fi, scope, local_insts)
        if isinstance(out, ClassInfo):
            return out.methods.get("__init__") if as_call else None
        return out

    def _resolve_value(self, expr, fi, scope, local_insts, depth: int = 0):
        if depth > 8:
            return None
        mod = fi.module
        if isinstance(expr, ast.Name):
            for layer in scope:
                if expr.id in layer:
                    return layer[expr.id]
            return self._module_symbol(mod, expr.id, fi, scope,
                                       local_insts, depth)
        if isinstance(expr, ast.Attribute):
            raw = dotted_name(expr)
            if raw is None:
                return None
            parts = raw.split(".")
            if parts[0] == "self" and fi.cls is not None:
                if len(parts) == 2:
                    return self._method_of(fi.cls, parts[1], set())
                if len(parts) == 3:
                    cls_raw = fi.cls.attr_types.get(parts[1])
                    ci = self._class_by_raw(mod, cls_raw)
                    if ci is not None:
                        return self._method_of(ci, parts[2], set())
                return None
            head = parts[0]
            if head in local_insts and len(parts) == 2:
                ci = self._class_by_raw(mod, local_insts[head])
                if ci is not None:
                    return self._method_of(ci, parts[1], set())
            if head in mod.imports:
                tgt_mod, sym = mod.imports[head]
                if sym is None and len(parts) == 2:
                    return self._external_symbol(tgt_mod, parts[1])
                if sym is not None:
                    obj = self._external_symbol(tgt_mod, sym)
                    if isinstance(obj, ClassInfo) and len(parts) == 2:
                        return self._method_of(obj, parts[1], set())
            local = mod.classes.get(head)
            if local is not None and len(parts) == 2:
                return self._method_of(local, parts[1], set())
            return None
        return None

    def _module_symbol(self, mod: ModuleInfo, name: str, fi, scope,
                       local_insts, depth: int = 0):
        if name in mod.defs:
            return mod.defs[name]
        if name in mod.classes:
            return mod.classes[name]
        if name in mod.imports:
            tgt_mod, sym = mod.imports[name]
            if sym is not None:
                return self._external_symbol(tgt_mod, sym)
            return None
        for cand in mod.assigns.get(name, ()):
            got = self._resolve_assigned(cand, fi, scope, local_insts, depth)
            if got is not None:
                return got
        return None

    def _resolve_assigned(self, expr, fi, scope, local_insts, depth):
        """``g = factory()`` / ``g = jax.jit(f)`` / ``g = f`` aliases."""
        if depth > 8:
            return None
        if isinstance(expr, (ast.Name, ast.Attribute)):
            return self._resolve_value(expr, fi, scope, local_insts,
                                       depth + 1)
        if isinstance(expr, ast.Call):
            fn = dotted_name(expr.func)
            if fn in ("jit", "jax.jit") and expr.args:
                return self._resolve_value(expr.args[0], fi, scope,
                                           local_insts, depth + 1)
            target = self._resolve_value(expr.func, fi, scope, local_insts,
                                         depth + 1)
            if isinstance(target, FunctionInfo):
                return target.returns_fn
            if isinstance(target, ClassInfo):
                return target
        return None

    def _external_symbol(self, mod_name: str, sym: str):
        tgt = self.modules.get(mod_name)
        if tgt is None:
            return None
        return tgt.defs.get(sym) or tgt.classes.get(sym)

    def _class_by_raw(self, mod: ModuleInfo, raw: Optional[str]
                      ) -> Optional[ClassInfo]:
        if not raw:
            return None
        head = raw.split(".")[0]
        if raw in mod.classes:
            return mod.classes[raw]
        if head in mod.imports:
            tgt_mod, sym = mod.imports[head]
            if sym is None and "." in raw:
                obj = self._external_symbol(tgt_mod, raw.split(".", 1)[1])
            else:
                obj = self._external_symbol(tgt_mod, sym or head)
            if isinstance(obj, ClassInfo):
                return obj
        return None

    def _method_of(self, ci: ClassInfo, name: str, visited: Set[str]
                   ) -> Optional[FunctionInfo]:
        key = f"{ci.module.name}.{ci.name}"
        if key in visited:
            return None
        visited.add(key)
        if name in ci.methods:
            return ci.methods[name]
        for base_raw in ci.bases:
            base = self._class_by_raw(ci.module, base_raw)
            if base is not None:
                m = self._method_of(base, name, visited)
                if m is not None:
                    return m
        return None

    # ------------------------------------------------- contract queries

    def method(self, ci: ClassInfo, name: str) -> Optional[FunctionInfo]:
        """Method lookup through resolvable bases (None when unknown)."""
        return self._method_of(ci, name, set())

    def has_attr(self, ci: ClassInfo, name: str,
                 _visited: Optional[Set[str]] = None) -> bool:
        """Instance attribute presence: class assign/annotation, a
        ``self.X = ...`` in ``__init__``, a @property, or a method —
        searched through resolvable bases."""
        visited = _visited if _visited is not None else set()
        key = f"{ci.module.name}.{ci.name}"
        if key in visited:
            return False
        visited.add(key)
        if (name in ci.class_assigns or name in ci.annotations
                or name in ci.init_attrs or name in ci.properties
                or name in ci.methods):
            return True
        for braw in ci.bases:
            base = self._class_by_raw(ci.module, braw)
            if base is not None and self.has_attr(base, name, visited):
                return True
        return False

    # -------------------------------------------------------- fix-points

    def acquired_closure(self, fi: FunctionInfo
                         ) -> Dict[str, Tuple[str, ...]]:
        """lock id -> witness call chain (qualnames) for every lock this
        function may acquire, directly or through direct (non-deferred)
        calls.  Memoized; cycles contribute nothing new."""
        memo = self._acq_memo
        if fi.qualname in memo:
            return memo[fi.qualname]
        memo[fi.qualname] = {}          # cycle guard: in-progress = empty
        out: Dict[str, Tuple[str, ...]] = {
            a.lock: (fi.qualname,) for a in fi.acquires}
        for cs in fi.calls:
            if cs.deferred or cs.target is None:
                continue
            for lock, chain in self.acquired_closure(cs.target).items():
                out.setdefault(lock, (fi.qualname,) + chain)
        memo[fi.qualname] = out
        return out

    def may_block(self, fi: FunctionInfo
                  ) -> Optional[Tuple[str, Tuple[str, ...]]]:
        """(description, witness chain) when calling this function can park
        the calling thread — its own blocking surface (a sanctioned wait on
        its OWN condition still blocks a caller holding a DIFFERENT lock)
        or any direct callee's.  Memoized; cycles resolve to non-blocking.
        """
        memo = self._block_memo
        if fi.qualname in memo:
            return memo[fi.qualname]
        memo[fi.qualname] = None        # cycle guard
        out: Optional[Tuple[str, Tuple[str, ...]]] = None
        for b in fi.blocking:
            out = (f"{b.raw}(...) [{b.kind}]", (fi.qualname,))
            break
        if out is None:
            for cs in fi.calls:
                if cs.deferred or cs.target is None:
                    continue
                sub = self.may_block(cs.target)
                if sub is not None:
                    out = (sub[0], (fi.qualname,) + sub[1])
                    break
        memo[fi.qualname] = out
        return out

    def reachable(self, roots: Sequence[FunctionInfo], *,
                  deferred: bool = True
                  ) -> Dict[str, Tuple[FunctionInfo, Tuple[str, ...]]]:
        """BFS over call edges: qualname -> (fn, chain from its root)."""
        from collections import deque
        seen: Dict[str, Tuple[FunctionInfo, Tuple[str, ...]]] = {}
        dq = deque((r, (r.qualname,)) for r in roots)
        for r in roots:
            seen.setdefault(r.qualname, (r, (r.qualname,)))
        while dq:
            fi, chain = dq.popleft()
            for cs in fi.calls:
                if cs.target is None or (cs.deferred and not deferred):
                    continue
                t = cs.target
                if t.qualname not in seen:
                    seen[t.qualname] = (t, chain + (t.qualname,))
                    dq.append((t, chain + (t.qualname,)))
        return seen


def build_project(sources: Sequence[SourceFile]) -> Project:
    return Project(sources)

"""Rule base class + registry.

A rule is a class with a unique ``id`` (``ECO<family><nn>``), a short
``name``, path ``include``/``exclude`` globs, and either ``check(src)``
(per-file) or ``check_project(sources)`` (cross-file, ``project_level =
True``).  ``@register`` adds it to the catalogue; ``make_rules`` builds the
enabled, configured instances for a run.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Sequence, Tuple, Type

from repro.analysis.engine import SourceFile, Violation, match_path


class Rule:
    id: str = ""
    name: str = ""
    description: str = ""
    include: Tuple[str, ...] = ("*.py",)
    exclude: Tuple[str, ...] = ()
    project_level: bool = False
    #: needs the interprocedural graph — only runs under ``--project``;
    #: the engine injects the shared, lazily-built ``Project`` here
    requires_project: bool = False
    #: runs after every other rule (the ECO900 suppression-usage audit)
    runs_after: bool = False

    def __init__(self) -> None:
        self.project = None             # engine-injected Project graph
        self.enabled_ids: frozenset = frozenset()

    def configure(self, options: Dict[str, object]) -> None:
        """Consume ``[tool.repro-lint]`` options (called once per run)."""

    def applies_to(self, path: str) -> bool:
        return (match_path(path, self.include)
                and not match_path(path, self.exclude))

    def check(self, src: SourceFile) -> Iterable[Violation]:
        return ()

    def check_project(self, sources: Sequence[SourceFile]
                      ) -> Iterable[Violation]:
        return ()

    def hit(self, node, path: str, message: str) -> Violation:
        return Violation(self.id, path, getattr(node, "lineno", 1),
                         getattr(node, "col_offset", 0), message)


_RULES: Dict[str, Type[Rule]] = {}


def register(cls: Type[Rule]) -> Type[Rule]:
    if not cls.id:
        raise ValueError(f"rule {cls.__name__} has no id")
    if cls.id in _RULES and _RULES[cls.id] is not cls:
        raise ValueError(f"duplicate rule id {cls.id!r}")
    _RULES[cls.id] = cls
    return cls


def all_rules() -> Dict[str, Type[Rule]]:
    import repro.analysis.rules  # noqa: F401  (registers the catalogue)
    return dict(sorted(_RULES.items()))


def _enabled(rule_id: str, name: str, select: Optional[Sequence[str]],
             ignore: Optional[Sequence[str]]) -> bool:
    """id prefixes (``ECO1`` = the whole family) or exact rule names."""
    def matches(spec: str) -> bool:
        spec = spec.strip()
        return bool(spec) and (rule_id.startswith(spec.upper())
                               or name == spec)

    sel = [s for s in (select or ()) if s.strip()]
    if sel and not any(matches(s) for s in sel):
        return False
    return not any(matches(s) for s in (ignore or ()))


def make_rules(select: Optional[Sequence[str]] = None,
               ignore: Optional[Sequence[str]] = None,
               options: Optional[Dict[str, object]] = None,
               project: bool = False) -> List[Rule]:
    out: List[Rule] = []
    for rid, cls in all_rules().items():
        if cls.requires_project and not project:
            continue
        if not _enabled(rid, cls.name, select, ignore):
            continue
        rule = cls()
        rule.configure(options or {})
        out.append(rule)
    return out

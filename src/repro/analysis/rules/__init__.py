"""Rule catalogue: importing this package registers every rule family."""
from repro.analysis.rules import (hotpath, kernels, pins,  # noqa: F401
                                  purity, threads)

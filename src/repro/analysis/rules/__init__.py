"""Rule catalogue: importing this package registers every rule family."""
from repro.analysis.rules import (concurrency, contracts,  # noqa: F401
                                  hotpath, kernels, meta, pins, purity,
                                  threads, transitive)

"""Shared AST helpers for the rule catalogue."""
from __future__ import annotations

import ast
from typing import Iterator, List, Optional, Sequence

#: list/set/dict methods that mutate the receiver in place
MUTATORS = frozenset({"append", "extend", "insert", "remove", "pop",
                      "popitem", "update", "setdefault", "clear", "discard",
                      "sort", "reverse"})

#: array-API reductions whose per-item scalarisation marks a hot-loop sync
REDUCERS = frozenset({"sum", "mean", "max", "min", "prod", "all", "any",
                      "argmin", "argmax", "item"})

#: receivers whose reductions are explicitly host-side (never tracers)
NP_NAMES = frozenset({"np", "numpy"})


def dotted_name(node) -> Optional[str]:
    """``"jax.sharding.AxisType"`` for Attribute/Name chains, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def call_name(node: ast.Call) -> Optional[str]:
    return dotted_name(node.func)


def annotate_parents(tree: ast.AST) -> None:
    """Attach ``_repro_parent`` links (idempotent) for upward walks."""
    for parent in ast.walk(tree):
        for child in ast.iter_child_nodes(parent):
            child._repro_parent = parent  # type: ignore[attr-defined]


def parents(node) -> Iterator[ast.AST]:
    cur = getattr(node, "_repro_parent", None)
    while cur is not None:
        yield cur
        cur = getattr(cur, "_repro_parent", None)


_LOOPS = (ast.For, ast.AsyncFor, ast.While, ast.ListComp, ast.SetComp,
          ast.DictComp, ast.GeneratorExp)
_FUNCS = (ast.FunctionDef, ast.AsyncFunctionDef)


def in_loop(node) -> bool:
    """Is ``node`` lexically inside a loop/comprehension, without crossing
    a nested function boundary above the loop?  Requires
    ``annotate_parents`` on the tree first."""
    for p in parents(node):
        if isinstance(p, _LOOPS):
            return True
        if isinstance(p, _FUNCS):
            return False
    return False


def enclosing_function(node) -> Optional[ast.AST]:
    for p in parents(node):
        if isinstance(p, _FUNCS):
            return p
    return None


def is_jit_decorator(dec) -> bool:
    """``@jax.jit`` / ``@jit``, or ``@(functools.)partial(jax.jit, ...)``."""
    if dotted_name(dec) in ("jit", "jax.jit"):
        return True
    if isinstance(dec, ast.Call):
        f = dotted_name(dec.func)
        if f in ("jit", "jax.jit"):
            return True
        if f in ("partial", "functools.partial") and dec.args:
            return dotted_name(dec.args[0]) in ("jit", "jax.jit")
    return False


def jit_entry_functions(tree, pure_names: Sequence[str] = ()) -> List:
    """Top-most jit-traced functions: jit-decorated defs plus the configured
    always-pure names.  Nested defs inside an entry belong to the entry's
    trace and are covered by walking the entry, so they are not returned
    separately."""
    pure = set(pure_names)
    out: List = []

    def visit(node, inside: bool) -> None:
        for child in ast.iter_child_nodes(node):
            child_inside = inside
            if isinstance(child, _FUNCS):
                entry = (child.name in pure or any(
                    is_jit_decorator(d) for d in child.decorator_list))
                if entry and not inside:
                    out.append(child)
                child_inside = inside or entry
            visit(child, child_inside)

    visit(tree, False)
    return out

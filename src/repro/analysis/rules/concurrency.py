"""Family 6 — interprocedural concurrency (ECO601/602/603, ``--project``).

The serving plane coordinates a cluster lock, per-pod service conditions,
flusher/retrier threads, an executor, and an asyncio bridge.  ECO3xx sees
one file at a time; the deadlocks that actually bite cross call and file
boundaries:

* ECO601 — two locks acquired in opposite orders on two different
  call-graph paths (the classic ABBA deadlock; PR 7's pod-retire path
  avoids it only by convention until now);
* ECO602 — a blocking call (``drain``/``close``/``result``/``join``/
  ``Future.result``/queue ``get``/foreign ``wait``) reachable while a lock
  is held, through any chain of direct calls — "drain outside the lock"
  (PR 8 prose) as an enforced rule.  ``Condition.wait`` on the lock being
  held is the sanctioned consumer idiom, but the enclosing function still
  counts as may-block for callers holding a DIFFERENT lock;
* ECO603 — completing an asyncio future from a function reachable from a
  thread entry point (``Thread(target=...)``, ``executor.submit``,
  ``add_done_callback``) without going through ``call_soon_threadsafe``.
  ECO302 catches the syntactic same-function case; this one follows the
  call graph.

Direct edges only: a deferred reference runs on some other stack, so the
lock is no longer held there.
"""
from __future__ import annotations

from typing import Dict, Tuple

from repro.analysis.registry import Rule, register

_SERVING = ("*/repro/serving/*.py", "*/repro/traffic/*.py")


class _ProjectRule(Rule):
    requires_project = True
    project_level = True
    include = _SERVING


@register
class LockOrderInversion(_ProjectRule):
    id = "ECO601"
    name = "lock-order-inversion"
    description = ("two locks acquired in opposite orders on two call-graph "
                   "paths — an ABBA deadlock waiting for the right "
                   "interleaving of serving threads (--project)")

    def check_project(self, sources):
        proj = self.project
        if proj is None:
            return
        linted = {s.path for s in sources}
        # ordered-pair edge (A, B): B acquirable while A is held, with one
        # witness (function, node, human chain) per edge
        edges: Dict[Tuple[str, str], Tuple[object, object, str]] = {}
        for fi in proj.functions.values():
            for acq in fi.acquires:
                for held in acq.held:
                    if held != acq.lock:
                        edges.setdefault(
                            (held, acq.lock),
                            (fi, acq.node,
                             f"{fi.qualname} takes {acq.lock} while "
                             f"holding {held}"))
            for cs in fi.calls:
                if cs.deferred or cs.target is None or not cs.held:
                    continue
                for lock, chain in proj.acquired_closure(cs.target).items():
                    for held in cs.held:
                        if lock != held:
                            via = " -> ".join((fi.qualname,) + chain)
                            edges.setdefault(
                                (held, lock),
                                (fi, cs.node,
                                 f"{via} takes {lock} while holding "
                                 f"{held}"))
        reported = set()
        for (a, b) in sorted(edges):
            if (b, a) not in edges or frozenset((a, b)) in reported:
                continue
            reported.add(frozenset((a, b)))
            fi, node, fwd = edges[(a, b)]
            _, _, rev = edges[(b, a)]
            path = fi.path
            if path in linted and self.applies_to(path):
                yield self.hit(node, path,
                               f"lock-order inversion between {a} and {b}: "
                               f"{fwd}; but {rev}")


@register
class BlockingUnderLock(_ProjectRule):
    id = "ECO602"
    name = "lock-held-blocking-call"
    description = ("a blocking call (drain/close/result/join/queue get/"
                   "foreign wait) is reachable while a lock is held — "
                   "every thread needing that lock stalls behind the "
                   "blocked holder; move the blocking step outside the "
                   "with block (--project)")

    #: lexical kinds flagged here; result/join/sleep/get stay ECO301's
    #: per-file territory and are only flagged transitively (depth >= 1)
    _LEXICAL = frozenset({"drain", "close", "wait"})

    def check_project(self, sources):
        proj = self.project
        if proj is None:
            return
        linted = {s.path for s in sources}
        for fi in proj.functions.values():
            if fi.path not in linted or not self.applies_to(fi.path):
                continue
            for b in fi.blocking:
                if b.held and not b.sanctioned and b.kind in self._LEXICAL:
                    yield self.hit(
                        b.node, fi.path,
                        f"{b.raw}(...) [{b.kind}] under lock "
                        f"{b.held[-1]} in {fi.qualname} parks the thread "
                        "while holding the lock")
            for cs in fi.calls:
                if cs.deferred or cs.target is None or not cs.held:
                    continue
                blocked = proj.may_block(cs.target)
                if blocked is None:
                    continue
                what, chain = blocked
                yield self.hit(
                    cs.node, fi.path,
                    f"{cs.raw}(...) under lock {cs.held[-1]} in "
                    f"{fi.qualname} reaches blocking {what} via "
                    f"{' -> '.join(chain)}")


@register
class CrossThreadFutureCompletion(_ProjectRule):
    id = "ECO603"
    name = "cross-thread-future-completion"
    description = ("an asyncio future is completed from a function "
                   "reachable from a thread entry (Thread target, "
                   "executor.submit, done-callback) without "
                   "call_soon_threadsafe — set_result off the owning loop "
                   "thread races the event loop (--project)")

    def check_project(self, sources):
        proj = self.project
        if proj is None:
            return
        linted = {s.path for s in sources}
        entries = [proj.functions[q] for q in sorted(proj.foreign_entries)
                   if q in proj.functions]
        reach = proj.reachable(entries, deferred=False)
        for fi, chain in reach.values():
            if fi.qualname in proj.scheduled:
                continue  # explicitly hopped onto the loop thread
            if fi.path not in linted or not self.applies_to(fi.path):
                continue
            for node, name in fi.completions:
                yield self.hit(
                    node, fi.path,
                    f"asyncio future {name!r} completed in {fi.qualname}, "
                    f"reachable from thread entry {chain[0]} via "
                    f"{' -> '.join(chain)} — schedule it with "
                    "loop.call_soon_threadsafe")

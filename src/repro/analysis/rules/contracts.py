"""Family 7 — protocol contract conformance (ECO701..704, ``--project``).

The extension points are protocols, not base classes: ``ExecutionBackend``
(``serving/backend.py`` registry) and ``RoutingPolicy`` (``core/policy.py``)
are satisfied structurally, so a drifted method name or arity only fails at
dispatch time deep inside a serving thread.  These rules check the protocol
surface statically: every registered or duck-typed backend/policy exposes
the required methods with compatible arity, a literal ``batchable = True``
is honest (``decide_batch`` must not degrade to a per-request
``self.decide`` loop), and every public ``kernels/<name>/ops.py`` entry
point dispatches to a ``ref.py`` oracle whose signature accepts the call.

Emission is limited to ``src/repro`` — test doubles are intentionally
partial.
"""
from __future__ import annotations

import ast
from typing import List, Optional, Set, Tuple

from repro.analysis.project import module_name
from repro.analysis.registry import Rule, register
from repro.analysis.rules.common import dotted_name
from repro.analysis.rules.kernels import kernel_packages

_FUNCS = (ast.FunctionDef, ast.AsyncFunctionDef)


def _arity_ok(fnode, expected: int) -> bool:
    """Can the method be called with ``expected`` positional args (self
    included)?"""
    a = fnode.args
    pos = len(getattr(a, "posonlyargs", ())) + len(a.args)
    required = pos - len(a.defaults)
    if a.vararg is not None:
        return required <= expected
    return required <= expected <= pos


class _ContractRule(Rule):
    requires_project = True
    project_level = True
    include = ("*/repro/*.py",)
    exclude = ("*/repro/analysis/*",)

    def _classes(self, sources):
        proj = self.project
        if proj is None:
            return
        linted = {s.path for s in sources}
        for mod in proj.modules.values():
            if mod.path not in linted or not self.applies_to(mod.path):
                continue
            for ci in mod.classes.values():
                yield mod, ci

    def _missing_method(self, ci, name: str, expected: int
                        ) -> Optional[str]:
        m = self.project.method(ci, name)
        if m is None:
            return f"has no {name}() method"
        if not _arity_ok(m.node, expected):
            return (f"{name}() cannot be called with {expected - 1} "
                    f"argument{'s' if expected != 2 else ''} (plus self)")
        return None


@register
class BackendConformance(_ContractRule):
    id = "ECO701"
    name = "backend-conformance"
    description = ("a registered or duck-typed ExecutionBackend must expose "
                   "serve_batch(self, requests), profile_row(self), and "
                   "name/max_batch attributes — a drifted surface only "
                   "fails at dispatch time inside a serving thread "
                   "(--project)")

    def check_project(self, sources):
        proj = self.project
        if proj is None:
            return
        registered: Set[Tuple[str, str]] = set()
        for src in sources:
            mod = proj.modules.get(module_name(src.path))
            if mod is None:
                continue
            for node in ast.walk(src.tree):
                if not isinstance(node, ast.Call):
                    continue
                fname = (dotted_name(node.func) or "").rsplit(".", 1)[-1]
                if fname == "register_backend" and len(node.args) >= 2:
                    cls_name = dotted_name(node.args[1])
                    if cls_name and cls_name in mod.classes:
                        registered.add((mod.name, cls_name))
        for mod, ci in self._classes(sources):
            is_registered = (mod.name, ci.name) in registered
            duck = "serve_batch" in ci.methods and "profile_row" in ci.methods
            if not (is_registered or duck):
                continue
            problems: List[str] = []
            for meth, expected in (("serve_batch", 2), ("profile_row", 1)):
                msg = self._missing_method(ci, meth, expected)
                if msg:
                    problems.append(msg)
            for attr in ("name", "max_batch"):
                if not proj.has_attr(ci, attr):
                    problems.append(f"defines no {attr!r} attribute")
            for p in problems:
                yield self.hit(ci.node, mod.path,
                               f"backend {ci.name!r} {p} — the "
                               "ExecutionBackend surface is serve_batch/"
                               "profile_row/name/max_batch")


@register
class PolicyConformance(_ContractRule):
    id = "ECO702"
    name = "policy-conformance"
    description = ("a RoutingPolicy face must expose decide(self, request), "
                   "decide_batch(self, requests), observe(self, "
                   "observation), reset(self), and a batchable attribute "
                   "(--project)")

    def check_project(self, sources):
        proj = self.project
        if proj is None:
            return
        for mod, ci in self._classes(sources):
            if "decide" not in ci.methods:
                continue
            if not ("decide_batch" in ci.methods or "observe" in ci.methods):
                continue  # a lone decide() is not a policy face
            problems: List[str] = []
            for meth, expected in (("decide", 2), ("decide_batch", 2),
                                   ("observe", 2), ("reset", 1)):
                msg = self._missing_method(ci, meth, expected)
                if msg:
                    problems.append(msg)
            if not proj.has_attr(ci, "batchable"):
                problems.append("defines no 'batchable' attribute")
            for p in problems:
                yield self.hit(ci.node, mod.path,
                               f"policy {ci.name!r} {p} — the "
                               "RoutingPolicy surface is decide/"
                               "decide_batch/observe/reset/batchable")


@register
class BatchableHonesty(_ContractRule):
    id = "ECO703"
    name = "batchable-honesty"
    description = ("batchable = True but decide_batch loops self.decide "
                   "per request — callers batch on that promise and get "
                   "serialized per-item routing (--project)")

    _LOOPS = (ast.For, ast.AsyncFor, ast.While, ast.ListComp, ast.SetComp,
              ast.DictComp, ast.GeneratorExp)

    def check_project(self, sources):
        for mod, ci in self._classes(sources):
            flag = ci.class_assigns.get("batchable")
            if not (isinstance(flag, ast.Constant) and flag.value is True):
                continue
            db = ci.methods.get("decide_batch")
            if db is None:
                continue
            for loop in ast.walk(db.node):
                if not isinstance(loop, self._LOOPS):
                    continue
                for call in ast.walk(loop):
                    if (isinstance(call, ast.Call)
                            and dotted_name(call.func) == "self.decide"):
                        yield self.hit(
                            call, mod.path,
                            f"{ci.name}.decide_batch loops self.decide "
                            "per request while advertising batchable = "
                            "True — vectorise it or set batchable = False")
                        break
                else:
                    continue
                break


@register
class KernelOracleSignature(_ContractRule):
    id = "ECO704"
    name = "kernel-oracle-signature"
    description = ("every public ops.py entry point must dispatch to a "
                   "ref.py oracle with a signature that accepts the call — "
                   "an entry without a matching oracle is unverifiable "
                   "(--project)")
    include = ("*/repro/kernels/*.py",)
    exclude = ()

    def check_project(self, sources):
        for (pkg_dir, name), files in sorted(kernel_packages(sources)
                                             .items()):
            ops, ref = files.get("ops.py"), files.get("ref.py")
            if ops is None or ref is None:
                continue  # ECO402's finding, not ours
            ref_defs = {n.name: n for n in ref.tree.body
                        if isinstance(n, _FUNCS)}
            for node in ops.tree.body:
                if isinstance(node, _FUNCS):
                    if node.name.startswith("_"):
                        continue
                    refs = [(sub.func.attr, sub)
                            for sub in ast.walk(node)
                            if isinstance(sub, ast.Call)
                            and isinstance(sub.func, ast.Attribute)
                            and isinstance(sub.func.value, ast.Name)
                            and sub.func.value.id == "ref"]
                    if not refs:
                        yield self.hit(
                            node, ops.path,
                            f"kernel {name!r} entry point {node.name}() "
                            "never dispatches to a ref.* oracle — parity "
                            "is unverifiable")
                        continue
                    for fn, call in refs:
                        yield from self._check_call(name, node.name, fn,
                                                    call, ref_defs,
                                                    ops.path)
                elif isinstance(node, ast.Assign):
                    # module-level alias: entry = jax.jit(ref.fn)
                    for sub in ast.walk(node.value):
                        d = dotted_name(sub) if isinstance(
                            sub, ast.Attribute) else None
                        if d and d.startswith("ref."):
                            fn = d.split(".", 1)[1]
                            if fn not in ref_defs:
                                yield self.hit(
                                    node, ops.path,
                                    f"kernel {name!r} aliases ref.{fn} "
                                    "which does not exist in ref.py")

    def _check_call(self, kernel, entry, fn, call, ref_defs, path):
        if fn not in ref_defs:
            yield self.hit(call, path,
                           f"kernel {kernel!r} entry point {entry}() "
                           f"dispatches to ref.{fn} which does not exist "
                           "in ref.py")
            return
        a = ref_defs[fn].args
        if any(isinstance(x, ast.Starred) for x in call.args) or any(
                kw.arg is None for kw in call.keywords):
            return  # *args/**kwargs forwarding: not statically checkable
        pos_params = [p.arg for p in
                      (list(getattr(a, "posonlyargs", ())) + list(a.args))]
        given_pos = len(call.args)
        kw_names = {kw.arg for kw in call.keywords}
        if given_pos > len(pos_params) and a.vararg is None:
            yield self.hit(call, path,
                           f"ref.{fn} takes {len(pos_params)} positional "
                           f"argument(s) but {entry}() passes {given_pos}")
            return
        if a.kwarg is None:
            valid = set(pos_params) | {p.arg for p in a.kwonlyargs}
            for kw in sorted(kw_names - valid):
                yield self.hit(call, path,
                               f"ref.{fn} has no parameter {kw!r} "
                               f"(passed by {entry}())")
        required_pos = pos_params[:len(pos_params) - len(a.defaults)]
        for p in required_pos[given_pos:]:
            if p not in kw_names:
                yield self.hit(call, path,
                               f"ref.{fn} requires argument {p!r} which "
                               f"{entry}() does not pass")
        required_kwonly = {p.arg for p, d in zip(a.kwonlyargs, a.kw_defaults)
                           if d is None}
        for p in sorted(required_kwonly - kw_names):
            yield self.hit(call, path,
                           f"ref.{fn} requires keyword argument {p!r} "
                           f"which {entry}() does not pass")

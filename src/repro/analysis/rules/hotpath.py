"""Family 2 — hot-path discipline (ECO201/202/203).

ECORE's wins live or die on routing staying O(1) per frame: the closed
loop is ONE jitted lax.scan, routing is a masked argmin, and dispatch is
the single DispatchQueue plane.  A Python per-frame loop, a ProfileTable
facade call, or a forked serving loop re-introduces exactly the overhead
PRs 3-5 removed.
"""
from __future__ import annotations

import ast

from repro.analysis.registry import Rule, register
from repro.analysis.rules.common import MUTATORS, dotted_name

_HOT_MODULES = ("*/repro/core/closed_loop.py", "*/repro/core/router.py",
                "*/repro/core/profiles.py")


@register
class HotPathLoop(Rule):
    id = "ECO201"
    name = "hot-python-loop"
    description = ("Python for/while in a hot routing function — per-frame "
                   "work belongs inside the jitted scan/argmin, not the "
                   "interpreter")
    include = _HOT_MODULES

    hot = ()

    def configure(self, options):
        self.hot = tuple(options.get("hot-functions") or ())

    def check(self, src):
        for node in ast.walk(src.tree):
            if not (isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef))
                    and node.name in self.hot):
                continue
            for sub in ast.walk(node):
                if not isinstance(sub, (ast.For, ast.While)):
                    continue
                if (isinstance(sub, ast.For)
                        and isinstance(sub.iter, (ast.Tuple, ast.List))):
                    continue  # literal unroll: length fixed at write time
                yield self.hit(sub, src.path,
                               "Python loop in hot function "
                               f"{node.name!r} runs once per frame — move "
                               "the work into the jitted scan or hoist it "
                               "out of the streaming path")


@register
class HotProfileMutation(Rule):
    id = "ECO202"
    name = "hot-profile-mutation"
    description = ("ProfileTable facade traffic in a hot module — the scan "
                   "folds observations into the ProfileState pytree; the "
                   "scalar mirrors (.observe/.observe_pair/.load_state) "
                   "are for the eager edges only")
    include = ("*/repro/core/closed_loop.py", "*/repro/core/router.py")

    _CALLS = frozenset({"observe", "observe_pair", "load_state"})

    def check(self, src):
        for node in ast.walk(src.tree):
            if (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)):
                if node.func.attr in self._CALLS:
                    yield self.hit(node, src.path,
                                   f".{node.func.attr}(...) drives the "
                                   "mutable ProfileTable facade from a hot "
                                   "module — fold through observe_state/"
                                   "ProfileState inside the scan")
                elif (node.func.attr in MUTATORS
                      and (dotted_name(node.func.value) or ""
                           ).endswith("entries")):
                    yield self.hit(node, src.path,
                                   f".entries.{node.func.attr}(...) "
                                   "mutates profile rows in a hot module")
            elif isinstance(node, ast.Assign):
                for tgt in node.targets:
                    if self._entries_target(tgt):
                        yield self.hit(node, src.path,
                                       "assignment into ProfileTable"
                                       ".entries in a hot module — profile "
                                       "state is the scanned pytree here")

    @staticmethod
    def _entries_target(tgt) -> bool:
        if isinstance(tgt, ast.Attribute) and tgt.attr == "entries":
            return True
        return (isinstance(tgt, ast.Subscript)
                and isinstance(tgt.value, ast.Attribute)
                and tgt.value.attr == "entries")


@register
class ForkedServingLoop(Rule):
    id = "ECO203"
    name = "forked-serving-loop"
    description = ("direct .serve_batch(...) outside the dispatch plane — "
                   "submit through EcoreService so batching, observation, "
                   "and accounting stay on one path")
    include = ("*/repro/*.py", "*/benchmarks/*.py", "*/examples/*.py")
    # tests exercise backends directly by design
    exclude = ("*/tests/*",)

    def configure(self, options):
        plane = tuple(options.get("dispatch-plane") or ())
        self.exclude = tuple(ForkedServingLoop.exclude) + plane

    def check(self, src):
        for node in ast.walk(src.tree):
            if (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr == "serve_batch"):
                yield self.hit(node, src.path,
                               "direct serve_batch(...) call forks a "
                               "serving loop — route it through the "
                               "EcoreService dispatch plane")

"""Family 4 — kernel oracle contract (ECO401-ECO405).

Every Pallas kernel package ``kernels/<name>/`` ships as: ``__init__.py``
(importable without path tricks), ``ops.py`` (the dispatching public
surface), ``ref.py`` (the jnp-only oracle the parity tests compare
against), and at least one test under ``tests/`` that references it.  A
kernel without an oracle or without a parity test is unverifiable; an
oracle that imports pallas can no longer disagree with the kernel.
ECO405 (per-file) keeps ops.py honest the other way: a shape-guarded
branch that silently rewrites dispatch to the oracle hides exactly the
frames the kernel exists to accelerate — fall-backs must carry a
``# repro-lint`` justification or be deleted.
"""
from __future__ import annotations

import ast
import re
from typing import Dict, List, Sequence, Tuple

from repro.analysis.engine import SourceFile, Violation, match_path
from repro.analysis.registry import Rule, register


def kernel_packages(sources: Sequence[SourceFile]
                    ) -> Dict[Tuple[str, str], Dict[str, SourceFile]]:
    """``(pkg_dir, name) -> {filename: SourceFile}`` for the immediate
    children of each ``kernels/<name>/`` directory in the collected set."""
    pkgs: Dict[Tuple[str, str], Dict[str, SourceFile]] = {}
    for src in sources:
        parts = src.path.split("/")
        if "kernels" not in parts:
            continue
        i = parts.index("kernels")
        if len(parts) != i + 3:  # exactly kernels/<name>/<file>.py
            continue
        name = parts[i + 1]
        pkg_dir = "/".join(parts[:i + 2])
        pkgs.setdefault((pkg_dir, name), {})[parts[-1]] = src
    return pkgs


def test_sources(sources: Sequence[SourceFile]) -> List[SourceFile]:
    return [s for s in sources if match_path(s.path, ("*/tests/*.py",))]


class _KernelRule(Rule):
    project_level = True


@register
class KernelMissingInit(_KernelRule):
    id = "ECO401"
    name = "kernel-missing-init"
    description = ("kernels/<name>/ without __init__.py — the package must "
                   "import as repro.kernels.<name> without path tricks")

    def check_project(self, sources):
        for (pkg_dir, name), files in sorted(kernel_packages(sources)
                                             .items()):
            if "__init__.py" not in files:
                yield Violation(self.id, f"{pkg_dir}/__init__.py", 1, 0,
                                f"kernel package {name!r} has no "
                                "__init__.py — add one re-exporting the "
                                "ops entry points")


@register
class KernelMissingContract(_KernelRule):
    id = "ECO402"
    name = "kernel-missing-contract"
    description = ("kernels/<name>/ must expose ops.py (public dispatch "
                   "surface) and ref.py (jnp oracle)")

    def check_project(self, sources):
        for (pkg_dir, name), files in sorted(kernel_packages(sources)
                                             .items()):
            for required in ("ops.py", "ref.py"):
                if required not in files:
                    role = ("public dispatch surface"
                            if required == "ops.py" else "jnp oracle")
                    yield Violation(self.id, f"{pkg_dir}/{required}", 1, 0,
                                    f"kernel {name!r} is missing "
                                    f"{required} (its {role})")


@register
class KernelUntested(_KernelRule):
    id = "ECO403"
    name = "kernel-untested"
    description = ("kernel not referenced by any test under tests/ — every "
                   "kernel needs a parity test against its ref.py oracle")

    def check_project(self, sources):
        tests = test_sources(sources)
        if not tests:
            return  # tests/ not in the linted set: nothing to assert
        for (pkg_dir, name), files in sorted(kernel_packages(sources)
                                             .items()):
            pat = re.compile(r"kernels[./]" + re.escape(name) + r"\b")
            if any(pat.search(t.text) for t in tests):
                continue
            anchor = files.get("ops.py") or next(iter(sorted(
                files.items())))[1]
            yield Violation(self.id, anchor.path, 1, 0,
                            f"kernel {name!r} is not referenced by any "
                            "file under tests/ — add a parity test "
                            f"importing repro.kernels.{name}")


_LIMIT_NAME = re.compile(r"^[A-Z][A-Z0-9_]*$")
_LIMIT_HINT = re.compile(r"MAX|WIDTH|HEIGHT|LIMIT|CAP|SIZE")


def _shape_guard(test: ast.expr) -> bool:
    """Does this ``if`` test consult the input's geometry — a
    ``.shape``/``.size``/``.ndim`` attribute or an ALL-CAPS limit
    constant (``MAX_WIDTH``-style)?"""
    for node in ast.walk(test):
        if isinstance(node, ast.Attribute) and node.attr in ("shape",
                                                             "size", "ndim"):
            return True
        if isinstance(node, ast.Name) and _LIMIT_NAME.match(node.id) \
                and _LIMIT_HINT.search(node.id):
            return True
    return False


def _falls_back_to_oracle(body: List[ast.stmt]) -> bool:
    """Does the guarded branch reroute dispatch to the oracle — assign an
    ``impl``-style variable a string constant, or return/call ``ref.*``?"""
    for stmt in body:
        for node in ast.walk(stmt):
            if isinstance(node, ast.Assign):
                targets = [t.id for t in node.targets
                           if isinstance(t, ast.Name)]
                consts = [n.value for n in ast.walk(node.value)
                          if isinstance(n, ast.Constant)
                          and isinstance(n.value, str)]
                if any("impl" in t for t in targets) and consts:
                    return True
            if isinstance(node, ast.Attribute) \
                    and isinstance(node.value, ast.Name) \
                    and node.value.id == "ref":
                return True
    return False


@register
class KernelSilentFallback(Rule):
    id = "ECO405"
    name = "kernel-silent-fallback"
    description = ("ops.py silently reroutes dispatch to the oracle behind "
                   "a shape guard — the kernel quietly stops serving "
                   "exactly the inputs it exists for; delete the guard or "
                   "justify it with a # repro-lint disable")
    include = ("*/kernels/*/ops.py",)

    def check(self, src: SourceFile):
        for node in ast.walk(src.tree):
            if not isinstance(node, ast.If) or not _shape_guard(node.test):
                continue
            if _falls_back_to_oracle(node.body):
                yield self.hit(
                    node, src.path,
                    "shape-guarded branch silently falls back to the "
                    "oracle — every input the kernel claims to serve must "
                    "reach it, or the fallback needs a # repro-lint "
                    "justification naming why")


@register
class KernelImpureOracle(_KernelRule):
    id = "ECO404"
    name = "kernel-impure-oracle"
    description = ("ref.py imports pallas — an oracle that shares the "
                   "kernel's machinery can no longer disagree with it; "
                   "oracles are jnp-only")

    def check_project(self, sources):
        for (pkg_dir, name), files in sorted(kernel_packages(sources)
                                             .items()):
            ref = files.get("ref.py")
            if ref is None:
                continue
            for node in ast.walk(ref.tree):
                if isinstance(node, ast.Import):
                    for alias in node.names:
                        if "pallas" in alias.name:
                            yield Violation(
                                self.id, ref.path, node.lineno,
                                node.col_offset,
                                f"oracle for kernel {name!r} imports "
                                f"{alias.name} — ref.py must stay jnp-only")
                elif isinstance(node, ast.ImportFrom):
                    module = node.module or ""
                    hits = [a.name for a in node.names
                            if "pallas" in a.name]
                    if "pallas" in module or hits:
                        what = module or ", ".join(hits)
                        yield Violation(
                            self.id, ref.path, node.lineno,
                            node.col_offset,
                            f"oracle for kernel {name!r} imports "
                            f"{what} — ref.py must stay jnp-only")

"""Family 9 — lint-plane hygiene (ECO900, ``--project``).

A suppression that no longer matches a finding is worse than dead code: it
documents a hazard that moved, and when the hazard comes back on a nearby
line the stale marker quietly eats the new finding.  This rule runs after
every other enabled rule has consulted the suppression maps (the engine
orders ``runs_after`` rules last) and flags markers that never fired:
unused ids, blanket ``all`` markers that matched nothing, and ids that
name no known rule (typos).  Ids naming known-but-disabled rules are
skipped — under ``--select`` there is no way to judge them.
"""
from __future__ import annotations

from repro.analysis.registry import Rule, all_rules, register


@register
class UnusedSuppression(Rule):
    id = "ECO900"
    name = "unused-suppression"
    description = ("a # repro-lint: disable=... marker matched no finding — "
                   "remove it, or fix the rule id / target line it drifted "
                   "away from (--project)")
    requires_project = True
    project_level = True
    runs_after = True

    def check_project(self, sources):
        known = set(all_rules()) | {"E001"}
        enabled = set(self.enabled_ids)
        for src in sources:
            for m in src.markers:
                for rid in m.ids:
                    if rid in ("all", "*"):
                        if not m.used_for:
                            yield self._flag(src, m,
                                             "blanket suppression matched "
                                             "no finding")
                    elif rid not in known:
                        yield self._flag(src, m,
                                         f"{rid!r} names no known rule")
                    elif rid in enabled and rid not in m.used_for:
                        yield self._flag(src, m,
                                         f"no {rid} finding on the target "
                                         "line")

    def _flag(self, src, marker, why):
        from repro.analysis.engine import Violation
        scope = "disable-file" if marker.file_level else "disable"
        return Violation(self.id, src.path, marker.lineno, 0,
                         f"unused suppression ({scope}): {why} — remove "
                         "the marker or repair it")

"""Family 5 — environment pins (ECO501/502/503).

The container pins jax 0.4.37: ``jax.sharding.AxisType`` does not exist
(0.5+), ``jax.make_mesh`` takes no ``axis_types`` kwarg, and ``hypothesis``
is not installed.  ``launch/mesh.py`` and ``tests/_propcheck.py`` are the
sanctioned compat shims — they carry inline justified suppressions, and
everything else must route through them (so a future un-pin is a
two-file change).
"""
from __future__ import annotations

import ast

from repro.analysis.registry import Rule, register
from repro.analysis.rules.common import call_name, dotted_name


@register
class AxisTypePin(Rule):
    id = "ECO501"
    name = "axistype-pin"
    description = ("direct jax.sharding.AxisType access — absent in the "
                   "pinned jax 0.4.37; launch.mesh.make_mesh version-gates "
                   "it via getattr")

    def check(self, src):
        for node in ast.walk(src.tree):
            if (isinstance(node, ast.Attribute)
                    and dotted_name(node) == "jax.sharding.AxisType"):
                yield self.hit(node, src.path,
                               "jax.sharding.AxisType does not exist in "
                               "the pinned jax 0.4.37 — go through "
                               "repro.launch.mesh.make_mesh")
            elif (isinstance(node, ast.ImportFrom)
                  and node.module == "jax.sharding"
                  and any(a.name == "AxisType" for a in node.names)):
                yield self.hit(node, src.path,
                               "importing AxisType breaks on the pinned "
                               "jax 0.4.37 — go through "
                               "repro.launch.mesh.make_mesh")


@register
class BareMakeMesh(Rule):
    id = "ECO502"
    name = "bare-make-mesh"
    description = ("bare jax.make_mesh call — repro.launch.mesh.make_mesh "
                   "is the one call site that version-gates axis_types "
                   "across the 0.4.37 pin")

    def check(self, src):
        for node in ast.walk(src.tree):
            if (isinstance(node, ast.Call)
                    and call_name(node) == "jax.make_mesh"):
                yield self.hit(node, src.path,
                               "bare jax.make_mesh(...) — call "
                               "repro.launch.mesh.make_mesh so axis_types "
                               "stays version-gated")
            elif (isinstance(node, ast.ImportFrom)
                  and node.module == "jax"
                  and any(a.name == "make_mesh" for a in node.names)):
                yield self.hit(node, src.path,
                               "importing make_mesh from jax bypasses the "
                               "version gate — use "
                               "repro.launch.mesh.make_mesh")


@register
class HypothesisImport(Rule):
    id = "ECO503"
    name = "hypothesis-import"
    description = ("direct hypothesis import — the container does not ship "
                   "it; tests/_propcheck.py is the shim that falls back to "
                   "the deterministic substitute")

    def check(self, src):
        for node in ast.walk(src.tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    root = alias.name.split(".", 1)[0]
                    if root == "hypothesis":
                        yield self.hit(node, src.path,
                                       f"import {alias.name} fails where "
                                       "hypothesis is absent — import the "
                                       "tests/_propcheck.py shim instead")
            elif (isinstance(node, ast.ImportFrom)
                  and (node.module or "").split(".", 1)[0] == "hypothesis"):
                yield self.hit(node, src.path,
                               f"from {node.module} import ... fails "
                               "where hypothesis is absent — import the "
                               "tests/_propcheck.py shim instead")

"""Family 1 — scan/jit purity (ECO101/102/103/110).

jax traces a jit scope once; Python side effects inside it either force a
device->host sync per call (stalling the stream the closed loop is trying
to keep cheap) or run at trace time only and silently bake stale state
into the compiled program.  The scanned closed loop (PR 5) depends on
``observe_state``/``decide_state`` staying pure, so those names are jit
scopes even without a decorator (``pure-functions`` in pyproject).
"""
from __future__ import annotations

import ast

from repro.analysis.registry import Rule, register
from repro.analysis.rules.common import (MUTATORS, NP_NAMES, REDUCERS,
                                         annotate_parents, call_name,
                                         dotted_name, in_loop,
                                         jit_entry_functions)

_HOST_CASTS = frozenset({"float", "int", "bool", "complex"})
_HOST_METHODS = frozenset({"item", "tolist"})
_IMPURE_ROOTS = ("random.", "time.", "os.")


class _JitScopeRule(Rule):
    """Base: run ``check_node`` over every node of every jit entry."""

    pure = ()

    def configure(self, options):
        self.pure = tuple(options.get("pure-functions") or ())

    def check(self, src):
        for entry in jit_entry_functions(src.tree, self.pure):
            for node in ast.walk(entry):
                yield from self.check_node(node, src, entry)

    def check_node(self, node, src, entry):
        return ()


@register
class HostSyncInJit(_JitScopeRule):
    id = "ECO101"
    name = "jit-host-sync"
    description = ("host synchronisation inside a jit/scan scope: "
                   "float()/int()/bool() on tracers, .item()/.tolist(), "
                   "or np.* calls materialise device values per trace")

    def check_node(self, node, src, entry):
        if not isinstance(node, ast.Call):
            return
        func = node.func
        if (isinstance(func, ast.Name) and func.id in _HOST_CASTS
                and node.args):
            yield self.hit(node, src.path,
                           f"{func.id}(...) in jit scope {entry.name!r} "
                           "forces a host sync on traced values")
        elif isinstance(func, ast.Attribute) and func.attr in _HOST_METHODS:
            yield self.hit(node, src.path,
                           f".{func.attr}() in jit scope {entry.name!r} "
                           "pulls the array to host")
        else:
            name = call_name(node) or ""
            if name.split(".", 1)[0] in NP_NAMES:
                yield self.hit(node, src.path,
                               f"{name}(...) in jit scope {entry.name!r} "
                               "is a host-side numpy call — use jnp")


@register
class ImpureCallInJit(_JitScopeRule):
    id = "ECO102"
    name = "jit-impure-call"
    description = ("print/random./time./os. inside a jit scope runs at "
                   "trace time only — the compiled program replays a "
                   "stale value (or nothing at all)")

    def check_node(self, node, src, entry):
        if not isinstance(node, ast.Call):
            return
        if isinstance(node.func, ast.Name) and node.func.id == "print":
            yield self.hit(node, src.path,
                           f"print(...) in jit scope {entry.name!r} fires "
                           "once at trace time — use jax.debug.print")
            return
        name = call_name(node) or ""
        if any(name.startswith(root) for root in _IMPURE_ROOTS):
            yield self.hit(node, src.path,
                           f"{name}(...) in jit scope {entry.name!r} is "
                           "trace-time-only impurity — thread randomness/"
                           "clocks in as arguments")


@register
class MutationInJit(_JitScopeRule):
    id = "ECO103"
    name = "jit-python-mutation"
    description = ("in-place Python mutation inside a jit scope (dict/list "
                   "writes, global/nonlocal rebinding) is invisible to the "
                   "trace — return new values or use .at[] updates")
    # pallas kernel bodies assign o_ref[...] by design: that is the
    # sanctioned mutation surface, so the kernel tree is out of scope
    exclude = ("*/repro/kernels/*",)

    def check_node(self, node, src, entry):
        if isinstance(node, (ast.Global, ast.Nonlocal)):
            kind = "global" if isinstance(node, ast.Global) else "nonlocal"
            yield self.hit(node, src.path,
                           f"{kind} rebinding inside jit scope "
                           f"{entry.name!r} leaks trace-time state")
        elif isinstance(node, ast.Assign):
            for tgt in node.targets:
                yield from self._target(tgt, node, src, entry)
        elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
            yield from self._target(node.target, node, src, entry)
        elif (isinstance(node, ast.Call)
              and isinstance(node.func, ast.Attribute)
              and node.func.attr in MUTATORS
              and not self._is_at_update(node.func.value)):
            yield self.hit(node, src.path,
                           f".{node.func.attr}(...) mutates a Python "
                           f"container inside jit scope {entry.name!r}")

    def _target(self, tgt, node, src, entry):
        if isinstance(tgt, (ast.Subscript, ast.Attribute)):
            yield self.hit(node, src.path,
                           "subscript/attribute assignment inside jit "
                           f"scope {entry.name!r} mutates in place — "
                           "build a new value or use .at[] updates")
        elif isinstance(tgt, (ast.Tuple, ast.List)):
            for el in tgt.elts:
                yield from self._target(el, node, src, entry)

    @staticmethod
    def _is_at_update(receiver) -> bool:
        # x.at[i].<op>(...) is jax's functional update — never a mutation
        return (isinstance(receiver, ast.Subscript)
                and isinstance(receiver.value, ast.Attribute)
                and receiver.value.attr == "at")


@register
class LoopHostScalarize(Rule):
    id = "ECO110"
    name = "loop-host-scalarize"
    description = ("per-item host scalarisation in a loop — int(x.sum()) "
                   "and friends sync once per iteration; batch the "
                   "reduction (np.count_nonzero / one vectorised call) or "
                   "make the host-side contract explicit")
    include = ("*/repro/core/*.py", "*/repro/serving/*.py")

    def check(self, src):
        annotate_parents(src.tree)
        for node in ast.walk(src.tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Name)
                    and node.func.id in ("int", "float", "bool")
                    and node.args):
                continue
            red = self._find_reduction(node.args[0])
            if red is None or not in_loop(node):
                continue
            yield self.hit(node, src.path,
                           f"{node.func.id}(….{red}()) scalarises one "
                           "item per loop iteration — hoist the reduction "
                           "out of the loop or use an explicitly host-side "
                           "form (np.count_nonzero)")

    @staticmethod
    def _find_reduction(expr):
        for sub in ast.walk(expr):
            if (isinstance(sub, ast.Call)
                    and isinstance(sub.func, ast.Attribute)
                    and sub.func.attr in REDUCERS):
                root = (dotted_name(sub.func.value) or "").split(".", 1)[0]
                if root not in NP_NAMES:
                    return sub.func.attr
        return None

"""Family 3 — serving thread/async safety (ECO301/302/303/304).

The serving plane runs a background flusher thread plus caller threads
plus (behind the asyncio facade) an event loop.  The historical failure
shapes: blocking while holding the service lock (stalls every submitter),
completing an asyncio future from a foreign thread (corrupts loop state),
blind exception handlers that let the flusher die silently, and wall-clock
sleeps / unbounded spin loops that bypass the injectable clock the whole
fault plane is tested against.
"""
from __future__ import annotations

import ast
import re

from repro.analysis.registry import Rule, register
from repro.analysis.rules.common import (annotate_parents, dotted_name,
                                         enclosing_function)

_LOCKISH = re.compile(r"lock|cond|mutex|sem", re.I)
_QUEUEISH = frozenset({"q", "_q", "queue", "_queue"})


@register
class BlockingUnderLock(Rule):
    id = "ECO301"
    name = "lock-blocking-call"
    description = ("blocking call (.result()/.join()/sleep()/queue .get()) "
                   "while holding a lock stalls every submitter — "
                   "Condition.wait, which releases the lock, is the "
                   "sanctioned way to sleep")
    include = ("*/repro/serving/*.py",)

    def check(self, src):
        for node in ast.walk(src.tree):
            if not isinstance(node, ast.With):
                continue
            if not any(self._lockish(item.context_expr)
                       for item in node.items):
                continue
            for stmt in node.body:
                for sub in ast.walk(stmt):
                    if isinstance(sub, ast.Call):
                        what = self._blocking(sub)
                        if what:
                            yield self.hit(sub, src.path,
                                           f"{what} while holding a lock "
                                           "blocks every other submitter "
                                           "— release first (Condition"
                                           ".wait releases and is fine)")

    @staticmethod
    def _lockish(expr) -> bool:
        name = dotted_name(expr.func) if isinstance(expr, ast.Call) \
            else dotted_name(expr)
        last = (name or "").rsplit(".", 1)[-1]
        return bool(_LOCKISH.search(last))

    @staticmethod
    def _blocking(call):
        f = call.func
        if isinstance(f, ast.Name) and f.id == "sleep":
            return "sleep(...)"
        if not isinstance(f, ast.Attribute):
            return None
        if dotted_name(f) == "time.sleep":
            return "time.sleep(...)"
        if f.attr in ("result", "join"):
            return f".{f.attr}(...)"
        if f.attr == "get":
            recv = (dotted_name(f.value) or "").rsplit(".", 1)[-1]
            if recv in _QUEUEISH or recv.endswith("_queue"):
                return f"{recv}.get(...)"
        return None


@register
class CrossThreadFutureCompletion(Rule):
    id = "ECO302"
    name = "cross-thread-future"
    description = ("asyncio future completed outside a "
                   "call_soon_threadsafe-scheduled callback — asyncio "
                   "futures are not thread-safe; a foreign-thread "
                   "set_result/set_exception corrupts loop state")
    include = ("*/repro/serving/*.py",)

    def check(self, src):
        annotate_parents(src.tree)
        afut_names = set()
        scheduled_fns = set()
        for node in ast.walk(src.tree):
            value = getattr(node, "value", None)
            if (isinstance(node, (ast.Assign, ast.AnnAssign))
                    and isinstance(value, ast.Call)
                    and isinstance(value.func, ast.Attribute)
                    and value.func.attr == "create_future"):
                targets = (node.targets if isinstance(node, ast.Assign)
                           else [node.target])
                for tgt in targets:
                    if isinstance(tgt, ast.Name):
                        afut_names.add(tgt.id)
            elif (isinstance(node, ast.Call)
                  and isinstance(node.func, ast.Attribute)
                  and node.func.attr == "call_soon_threadsafe"):
                for arg in node.args:
                    if isinstance(arg, ast.Name):
                        scheduled_fns.add(arg.id)
        if not afut_names:
            return
        for node in ast.walk(src.tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr in ("set_result", "set_exception")
                    and isinstance(node.func.value, ast.Name)
                    and node.func.value.id in afut_names):
                continue
            fn = enclosing_function(node)
            if fn is None or fn.name not in scheduled_fns:
                yield self.hit(node, src.path,
                               f"{node.func.value.id}."
                               f"{node.func.attr}(...) completes an "
                               "asyncio future outside a callback handed "
                               "to call_soon_threadsafe — unsafe unless "
                               "already on the loop thread")


@register
class BlindExcept(Rule):
    id = "ECO303"
    name = "blind-except"
    description = ("bare except / except BaseException / pass-only handler "
                   "in the serving plane lets the flusher thread die "
                   "silently — name the exception and surface it")
    include = ("*/repro/serving/*.py",)

    def check(self, src):
        for node in ast.walk(src.tree):
            if not isinstance(node, ast.ExceptHandler):
                continue
            if node.type is None:
                yield self.hit(node, src.path,
                               "bare except: swallows everything, "
                               "including the flusher thread's death — "
                               "catch Exception at most and record it")
            elif dotted_name(node.type) == "BaseException":
                yield self.hit(node, src.path,
                               "except BaseException traps "
                               "KeyboardInterrupt/SystemExit in serving "
                               "code — catch Exception")
            elif len(node.body) == 1 and isinstance(node.body[0], ast.Pass):
                yield self.hit(node, src.path,
                               "exception silently dropped (pass-only "
                               "handler) — record it or re-raise so "
                               "serving failures stay observable")


@register
class WallClockRetry(Rule):
    id = "ECO304"
    name = "wall-clock-retry"
    description = ("time.sleep or an unbounded ``while True`` loop in the "
                   "serving plane bypasses the injectable clock — retries "
                   "and backoff must condition-wait on the clock the fault "
                   "tests control, and every spin loop needs an exit")
    # the traffic plane (arrivals, LoadDriver, autoscaler episodes) is
    # virtual-time by contract: a wall-clock sleep there silently turns a
    # millisecond replay into real seconds, so it gets the same rule
    include = ("*/repro/serving/*.py", "*/repro/traffic/*.py")

    def check(self, src):
        for node in ast.walk(src.tree):
            if isinstance(node, ast.Call) and self._is_sleep(node):
                yield self.hit(node, src.path,
                               "wall-clock sleep in the serving plane — "
                               "backoff/polling must ride the injectable "
                               "clock (Condition.wait with a timeout "
                               "derived from it), or fault tests that "
                               "drive a fake clock hang for real seconds")
            elif (isinstance(node, ast.While)
                    and isinstance(node.test, ast.Constant)
                    and node.test.value is True
                    and not self._has_exit(node)):
                yield self.hit(node, src.path,
                               "while True with no break/return — an "
                               "unbounded retry/poll loop cannot be "
                               "drained or closed; bound it (attempt "
                               "budget, _closed flag, or an explicit "
                               "break on the empty condition)")

    @staticmethod
    def _is_sleep(call) -> bool:
        f = call.func
        if isinstance(f, ast.Name):
            return f.id == "sleep"
        return isinstance(f, ast.Attribute) and dotted_name(f) == "time.sleep"

    @staticmethod
    def _has_exit(loop) -> bool:
        """break/return anywhere in the loop body, not counting nested
        functions (their control flow cannot exit THIS loop) or nested
        loops' own breaks (a break there exits the inner loop only)."""
        stack = list(loop.body)
        while stack:
            node = stack.pop()
            if isinstance(node, (ast.Break, ast.Return)):
                return True
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda)):
                continue
            if isinstance(node, (ast.While, ast.For)):
                # the inner loop's orelse runs in OUR scope; its body's
                # breaks do not — but a return inside still exits us
                stack.extend(node.orelse)
                stack.extend(n for b in node.body for n in ast.walk(b)
                             if isinstance(n, ast.Return))
                continue
            stack.extend(ast.iter_child_nodes(node))
        return False

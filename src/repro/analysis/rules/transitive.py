"""Family 12 — transitive scan/jit purity (ECO120/121, ``--project`` only).

ECO101/102 inspect jit-scope bodies; jax traces the whole call CHAIN.  A
host sync two helpers below ``decide_state`` stalls the scanned closed loop
exactly as badly as one written inline, and nothing per-file can see it.
These rules walk the project call graph from every jit entry, every
configured pure function, and every configured transitive root
(``add_pair``/``retire_pair`` — the host-boundary halves of fleet
elasticity), following deferred edges too (a ``lax.scan`` step function or
a factory-built kernel is still traced), and flag impure primitives in any
reachable callee.  Root bodies of jit entries / pure functions are NOT
re-scanned — ECO101/102 own those — but transitive-root bodies are, since
no per-file rule covers them.
"""
from __future__ import annotations

import ast
from typing import Iterable, List, Set, Tuple

from repro.analysis.registry import Rule, register
from repro.analysis.rules.common import NP_NAMES, call_name

_HOST_CASTS = frozenset({"float", "int", "bool", "complex"})
_HOST_METHODS = frozenset({"item", "tolist"})
_IMPURE_ROOTS = ("random.", "time.", "os.")
_FUNCS = (ast.FunctionDef, ast.AsyncFunctionDef)


def _own_body_nodes(fnode) -> Iterable[ast.AST]:
    """Walk a function body without descending into nested defs (separate
    graph functions, scanned when reached); lambda bodies stay in."""
    stack = list(ast.iter_child_nodes(fnode))
    while stack:
        node = stack.pop()
        if isinstance(node, _FUNCS):
            continue
        yield node
        stack.extend(ast.iter_child_nodes(node))


class _TransitiveRule(Rule):
    """Base: BFS the call graph from the purity roots, scan reached
    callees' own bodies with ``check_node``, prefix the witness chain."""

    requires_project = True
    project_level = True
    # pallas kernel packages do trace-time np math on static grids by
    # design, and have their own contract family (ECO4xx/ECO704)
    exclude = ("*/repro/kernels/*",)

    pure: Tuple[str, ...] = ()
    roots: Tuple[str, ...] = ()

    def configure(self, options):
        self.pure = tuple(options.get("pure-functions") or ())
        self.roots = tuple(options.get("transitive-roots") or ())

    def check_project(self, sources):
        proj = self.project
        if proj is None:
            return
        linted = {s.path for s in sources}
        entries: List = []
        for fi in proj.functions.values():
            if (fi.jit_decorated or fi.name in self.pure
                    or fi.name in self.roots):
                entries.append(fi)
        reach = proj.reachable(entries, deferred=True)
        seen: Set[Tuple[str, int, int]] = set()
        for fi, chain in reach.values():
            # jit-entry / pure bodies are per-file ECO101/102 territory;
            # everything else reached — including transitive roots — is
            # invisible to per-file rules and scanned here
            if fi.jit_decorated or fi.name in self.pure:
                continue
            if fi.path not in linted or not self.applies_to(fi.path):
                continue
            via = " -> ".join(chain)
            for node in _own_body_nodes(fi.node):
                for v in self.check_node(node, fi, via):
                    key = (v.path, v.line, v.col)
                    if key not in seen:
                        seen.add(key)
                        yield v

    def check_node(self, node, fi, via):
        return ()


@register
class TransitiveHostSync(_TransitiveRule):
    id = "ECO120"
    name = "transitive-host-sync"
    description = ("host synchronisation reachable from a jit/scan root "
                   "through the call graph: a helper calling int()/float() "
                   "on traced values, .item()/.tolist(), or np.* stalls "
                   "the stream exactly like doing it inline (--project)")

    def check_node(self, node, fi, via):
        if not isinstance(node, ast.Call):
            return
        func = node.func
        if (isinstance(func, ast.Name) and func.id in _HOST_CASTS
                and node.args):
            yield self.hit(node, fi.path,
                           f"{func.id}(...) reachable from a jit root via "
                           f"{via} forces a host sync on traced values")
        elif isinstance(func, ast.Attribute) and func.attr in _HOST_METHODS:
            yield self.hit(node, fi.path,
                           f".{func.attr}() reachable from a jit root via "
                           f"{via} pulls the array to host")
        else:
            name = call_name(node) or ""
            if name.split(".", 1)[0] in NP_NAMES:
                yield self.hit(node, fi.path,
                               f"{name}(...) reachable from a jit root via "
                               f"{via} is a host-side numpy call — use jnp")


@register
class TransitiveImpureCall(_TransitiveRule):
    id = "ECO121"
    name = "transitive-impure-call"
    description = ("print/random./time./os. reachable from a jit/scan root "
                   "through the call graph runs at trace time only — the "
                   "compiled chain replays a stale value (--project)")

    def check_node(self, node, fi, via):
        if not isinstance(node, ast.Call):
            return
        if isinstance(node.func, ast.Name) and node.func.id == "print":
            yield self.hit(node, fi.path,
                           f"print(...) reachable from a jit root via {via} "
                           "fires once at trace time — use jax.debug.print")
            return
        name = call_name(node) or ""
        if any(name.startswith(root) for root in _IMPURE_ROOTS):
            yield self.hit(node, fi.path,
                           f"{name}(...) reachable from a jit root via "
                           f"{via} is trace-time-only impurity — thread "
                           "randomness/clocks in as arguments")

"""Flat-key pytree checkpointing to a single .npz (offline-friendly)."""
from __future__ import annotations

import os
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

SEP = "/"


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save(path: str, tree: Any) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez(path, **_flatten(tree))


def load(path: str, like: Any) -> Any:
    """Restore into the structure of ``like`` (shapes must match)."""
    data = np.load(path)
    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(like)
    new_leaves = []
    for pth, leaf in leaves_with_path:
        key = SEP.join(_path_str(p) for p in pth)
        arr = data[key]
        if arr.shape != leaf.shape:
            raise ValueError(f"{key}: ckpt {arr.shape} != model {leaf.shape}")
        new_leaves.append(jnp.asarray(arr, leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, new_leaves)

"""Config registry: one module per assigned architecture (+ variants).

``get_config(name)`` returns the exact assigned full-size config;
``get_config(name).reduced()`` is the CPU smoke-test variant.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.base import ModelConfig

_MODULES = {
    "recurrentgemma-2b": "recurrentgemma_2b",
    "deepseek-7b": "deepseek_7b",
    "gemma2-9b": "gemma2_9b",
    "whisper-small": "whisper_small",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "qwen2.5-3b": "qwen2_5_3b",
    "mamba2-370m": "mamba2_370m",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "llama3-8b": "llama3_8b",
    "llava-next-34b": "llava_next_34b",
    # beyond-assignment sliding-window variants (enable long_500k on dense)
    "gemma2-9b-swa": "gemma2_9b",
    "llama3-8b-swa": "llama3_8b",
}
# The paper's own edge testbed (detection model-device pairs) is not a
# transformer config; it lives in repro.detection.devices.TESTBED.


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch '{name}'; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    if name.endswith("-swa"):
        return mod.SWA_VARIANT
    return mod.CONFIG


def list_configs(include_variants: bool = False) -> List[str]:
    names = list(_MODULES)
    if not include_variants:
        names = [n for n in names if not n.endswith("-swa")]
    return names

"""DeepSeek-7B [arXiv:2401.02954] — llama-architecture dense, MHA (kv=32)."""
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-7b",
    family="dense",
    num_layers=30,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    head_dim=128,
    d_ff=11008,
    vocab_size=102_400,
    block_layout=("attn",),
    mlp_variant="swiglu",
    rope_theta=10_000.0,
    source="arXiv:2401.02954 (DeepSeek LLM 7B)",
)

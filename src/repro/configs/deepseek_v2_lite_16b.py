"""DeepSeek-V2-Lite-16B [arXiv:2405.04434] — MLA + fine-grained MoE.

MLA: kv_lora_rank 512, rope dim 64, nope dim 128, v dim 128 (16 heads).
MoE: 64 routed experts top-6 + 2 shared experts, expert d_ff 1408.
Note: the assigned spec line says both "64e top-6" and "160 routed"; the
published V2-Lite has 64 routed + 2 shared, matching the "MoE 64e top-6"
field, which we follow.  All 27 layers are MoE (the published model's first
layer is a dense MLP; unified here for scan homogeneity — <1% FLOP delta).
"""
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    moe_d_ff=1408,
    num_experts=64,
    moe_top_k=6,
    num_shared_experts=2,
    use_mla=True,
    kv_lora_rank=512,
    qk_rope_dim=64,
    qk_nope_dim=128,
    v_head_dim=128,
    vocab_size=102_400,
    block_layout=("attn",),
    mlp_variant="swiglu",
    rope_theta=10_000.0,
    source="arXiv:2405.04434 (DeepSeek-V2-Lite)",
)

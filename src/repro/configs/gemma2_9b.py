"""Gemma2-9B [arXiv:2408.00118] — alternating local/global attention,
logit softcaps, sandwich norms, GeGLU, gemma-scaled embeddings.

42 layers = 21 x (local window-4096, global) pairs.
``gemma2-9b-swa`` variant makes every layer sliding-window (all-local) to
exercise the dense-sub-quadratic long_500k path (beyond-assignment).
"""
import dataclasses

from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    num_layers=42,
    d_model=3584,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab_size=256_000,
    sliding_window=4096,
    block_layout=("local", "attn"),
    attn_softcap=50.0,
    final_softcap=30.0,
    post_norm=True,
    mlp_variant="geglu",
    embed_scale=True,
    rope_theta=10_000.0,
    source="arXiv:2408.00118 (Gemma 2)",
)

SWA_VARIANT = dataclasses.replace(
    CONFIG, name="gemma2-9b-swa", block_layout=("local",))

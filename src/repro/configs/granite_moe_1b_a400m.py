"""Granite-3.0-1B-A400M [hf:ibm-granite/granite-3.0-1b-a400m-base] —
32-expert top-8 MoE, GQA kv=8, expert d_ff=512."""
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    head_dim=64,
    d_ff=512,
    moe_d_ff=512,
    num_experts=32,
    moe_top_k=8,
    num_shared_experts=0,
    vocab_size=49_155,
    block_layout=("attn",),
    mlp_variant="swiglu",
    rope_theta=10_000.0,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
)

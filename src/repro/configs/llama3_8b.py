"""Llama-3-8B [arXiv:2407.21783] — dense GQA kv=8, 128k vocab.

``llama3-8b-swa`` variant adds a 4096 sliding window on every layer
(beyond-assignment: enables the long_500k sub-quadratic decode path).
"""
import dataclasses

from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-8b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128_256,
    block_layout=("attn",),
    mlp_variant="swiglu",
    rope_theta=500_000.0,
    source="arXiv:2407.21783 (Llama 3 8B)",
)

SWA_VARIANT = dataclasses.replace(
    CONFIG, name="llama3-8b-swa", block_layout=("local",), sliding_window=4096)

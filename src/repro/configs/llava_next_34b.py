"""LLaVA-NeXT-34B [hf:llava-hf/llava-v1.6] — VLM; vision tower STUBBED.

Language backbone: 60L, d=7168, 56 heads GQA kv=8 (Yi-34B-class).  AnyRes
tiling produces up to 2880 patch embeddings which arrive PRECOMPUTED
[B, 2880, 1152] (SigLIP-dim stub per the assignment carve-out) and pass
through a learned linear projector before being prepended to text tokens.
"""
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab_size=64_000,
    num_prefix_embeds=2880,
    vision_dim=1152,
    block_layout=("attn",),
    mlp_variant="swiglu",
    rope_theta=5_000_000.0,
    source="hf:llava-hf/llava-v1.6 (34B backbone; anyres tiling)",
)

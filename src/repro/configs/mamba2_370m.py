"""Mamba2-370M [arXiv:2405.21060] — attention-free SSD (state-space duality).

48 layers, d=1024, expand 2 (d_inner 2048), headdim 64 (32 SSD heads),
state 128, depthwise conv width 4, chunked scan (chunk 256).
"""
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    num_layers=48,
    d_model=1024,
    num_heads=1,   # unused (attention-free)
    num_kv_heads=1,
    head_dim=64,
    d_ff=0,        # mamba block has no separate MLP
    vocab_size=50_280,
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_conv_width=4,
    ssm_chunk=256,
    block_layout=("ssm",),
    source="arXiv:2405.21060 (Mamba-2 370m)",
)

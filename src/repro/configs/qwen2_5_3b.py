"""Qwen2.5-3B [hf:Qwen/Qwen2.5-0.5B family] — GQA kv=2, QKV bias."""
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b",
    family="dense",
    num_layers=36,
    d_model=2048,
    num_heads=16,
    num_kv_heads=2,
    head_dim=128,
    d_ff=11008,
    vocab_size=151_936,
    qkv_bias=True,
    block_layout=("attn",),
    mlp_variant="swiglu",
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen2.5-3B (arch per assigned spec; QKV bias per Qwen2)",
)

"""RecurrentGemma-2B [arXiv:2402.19427] — Griffin hybrid: RG-LRU + local attn.

26 layers, 1:2 attention:recurrence -> 8 x (rec, rec, local-attn) blocks plus
a trailing (rec, rec) pair (18 recurrent + 8 attention layers).  GQA kv=1
(MQA), sliding window 2048, GeGLU MLP, gemma-scaled embeddings.
"""
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256_000,
    lru_width=2560,
    conv_width=4,
    sliding_window=2048,
    block_layout=("rec", "rec", "local"),
    trailing_layout=("rec", "rec"),
    mlp_variant="geglu",
    embed_scale=True,
    rope_theta=10_000.0,
    source="arXiv:2402.19427 (RecurrentGemma); Griffin arXiv:2402.19427",
)

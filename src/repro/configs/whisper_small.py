"""Whisper-small [arXiv:2212.04356] — enc-dec; conv/mel frontend STUBBED.

12 encoder + 12 decoder layers (the published small config), d=768, 12 heads.
``input_specs`` provides precomputed frame embeddings [B, 1500, 768] in place
of the mel-spectrogram + conv feature extractor (assignment carve-out).
Sinusoidal absolute positions (no RoPE), GELU MLP.
"""
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="encdec",
    num_layers=24,  # 12 enc + 12 dec
    enc_layers=12,
    dec_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=51_865,
    enc_seq=1500,
    vision_dim=768,  # stub frame-embedding dim
    mlp_variant="gelu",
    use_rope=False,
    block_layout=("attn",),
    source="arXiv:2212.04356 (Whisper small: 12+12 layers)",
)

"""ECORE core: profiling table, routing algorithms, estimators, gateway."""
from .groups import DEFAULT_GROUP_RULES, group_of
from .profiles import ProfileEntry, ProfileTable
from .router import (BASELINE_ROUTERS, GreedyEstimateRouter,
                     HighestMAPPerGroupRouter, HighestMAPRouter,
                     LowestEnergyRouter, LowestInferenceRouter, OracleRouter,
                     RandomRouter, RoundRobinRouter, greedy_route)
from .estimators import (EdgeDetectionEstimator, OracleEstimator,
                         OutputBasedEstimator, SSDFrontEndEstimator)
from .gateway import EpisodeStats, Gateway
from .metrics import MAPAccumulator, average_precision, iou

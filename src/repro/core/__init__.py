"""ECORE core: profiling table, routing algorithms, estimators, gateway."""
from .groups import DEFAULT_GROUP_RULES, group_of
from .profiles import ProfileArrays, ProfileEntry, ProfileTable
from .router import (BASELINE_ROUTERS, GreedyEstimateRouter,
                     HighestMAPPerGroupRouter, HighestMAPRouter,
                     LowestEnergyRouter, LowestInferenceRouter, OracleRouter,
                     RandomRouter, RoundRobinRouter, feasible_for_count,
                     feasible_set, greedy_route, pareto_front, route_batch)
from .estimators import (EdgeDetectionEstimator, OracleEstimator,
                         OutputBasedEstimator, SSDFrontEndEstimator)
from .policy import (DetectionPolicy, Observation, PoolPolicy, RouteDecision,
                     RouteRequest, RoutingPolicy)
from .gateway import EpisodeStats, Gateway
from .metrics import MAPAccumulator, average_precision, iou

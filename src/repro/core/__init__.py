"""ECORE core: profile state/table, routing algorithms, estimators,
the fused closed loop, gateway."""
from .groups import DEFAULT_GROUP_RULES, group_of
from .profiles import (ProfileArrays, ProfileEntry, ProfileState,
                       ProfileTable, add_pair, observe_state,
                       retire_pair)
from .router import (BASELINE_ROUTERS, GreedyEstimateRouter,
                     HighestMAPPerGroupRouter, HighestMAPRouter,
                     LowestEnergyRouter, LowestInferenceRouter, OracleRouter,
                     RandomRouter, RoundRobinRouter, decide_state,
                     feasible_for_count, feasible_set, greedy_route,
                     pareto_front, route_batch)
from .closed_loop import ScanDecisions, StreamMeasurements, scan_stream
from .estimators import (EdgeDetectionEstimator, OracleEstimator,
                         OutputBasedEstimator, SSDFrontEndEstimator)
from .policy import (DetectionPolicy, Observation, PoolPolicy, RouteDecision,
                     RouteRequest, RoutingPolicy)
from .gateway import EpisodeStats, Gateway
from .metrics import MAPAccumulator, average_precision, iou

"""The fused closed loop: estimate->route->observe as ONE jitted lax.scan.

The repo's adaptive path was its last scalar-Python hot loop: under
``adapt=True`` every frame ran a Python ``greedy_route`` followed by a
Python ``ProfileTable.observe_pair`` dict mutation, because each observation
changes the table the NEXT decision reads — a loop-carried dependency the
open-loop batched router could not express.  ``ProfileState`` removes the
obstacle: profile state is a pytree VALUE, so the whole sequential loop
compiles to one ``lax.scan`` XLA program whose carry is the state —
``decide_state`` (Algorithm-1 masked argmin) then ``observe_state`` (EWMA
fold) per step, with zero host round-trips between frames.

The contract that makes this possible: per-step measurements must be
DECISION-INDEPENDENT.  A ``DriftingFleet``'s cost at step t depends only on
(device, step), never on which pair was routed, so the caller precomputes
``measurements[t, j]`` — what pair j WOULD have cost at step t — and the
scan gathers the routed pair's column.  (Measured per-frame mAP is
decision-dependent — the served detector draws the boxes — which is exactly
why ``adapt_map`` stays on the scalar path.)

Exact parity with the scalar loop is the design invariant, not an
aspiration: same routed pairs, same EWMA folds in the same order
(``tests/test_closed_loop.py`` asserts decision equality and
``assert_allclose`` on the final state against ``DetectionPolicy``'s scalar
loop under drift; the only divergence is f32-vs-float64 rounding).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np

from .groups import DEFAULT_GROUP_RULES, group_of
from .profiles import (ProfileArrays, ProfileState, observe_state,
                       probe_state, quarantine_state, with_fails)
from .router import decide_state, rules_arrays


@dataclasses.dataclass(frozen=True)
class StreamMeasurements:
    """Decision-independent per-step, per-pair runtime measurements.

    ``time_ms``/``energy_mwh`` are [T, n_pairs] float arrays aligned to the
    snapshot's ``pairs`` order: row t holds what EACH pair would have
    measured serving step t (a drifting fleet's cost is a function of
    (device, step) only).  ``map_pct`` is optional ([T, n_pairs] or None);
    NaN cells mean "no measurement" — the scan's observe skips them.

    An INF ``time_ms`` cell is the failure sentinel: the pair did not
    answer at step t (hard dropout — ``DriftingFleet.cost_profile`` emits
    it for ``DriftEvent(hard=True)`` windows).  A failed step folds NO
    measurement into the EWMA and instead bumps the routed cell's
    quarantine counter (``quarantine_state``), so the breaker opens after
    ``quarantine_after`` consecutive failures.
    """
    time_ms: np.ndarray
    energy_mwh: np.ndarray
    map_pct: Optional[np.ndarray] = None


@dataclasses.dataclass(frozen=True)
class ScanDecisions:
    """One closed-loop scan's routing trace, mapped back to table identity:
    ``pair_idx[t]`` indexes the snapshot's ``pairs``, ``group_row[t]`` the
    state rows, ``entry_idx[t]`` the table's ``entries`` (-1 when an
    explored pair has no row for that step's group), ``explored[t]`` marks
    round-robin exploration overrides."""
    pair_idx: np.ndarray    # [T] int32 into arrays.pairs
    group_row: np.ndarray   # [T] int32 state row
    entry_idx: np.ndarray   # [T] int32 into table.entries; -1 = no row
    explored: np.ndarray    # [T] bool


def measurements_from_fleet(pairs, n_steps: int,
                            fleet=None) -> StreamMeasurements:
    """THE builder of the scan's measurement matrices — the one place the
    decision-independence contract is turned into arrays.

    For each (model, device) pair, the cost at step t is
    ``fleet.cost(device, model_flops, t)`` (vectorized via
    ``DriftingFleet.cost_profile``) — a function of (device, step) only,
    exactly what ``DetectorBackend`` charges request uid t however dispatch
    batches.  Without a fleet, measurements equal the offline device model
    (adaptation is a fixed point, like the scalar loop).  ``pairs`` must be
    the snapshot's ``arrays.pairs`` order.  Gateway, benches and tests all
    build through here, so the matrices cannot silently drift apart.
    """
    import numpy as np
    from repro.detection.detectors import DETECTOR_CONFIGS  # lazy: keeps
    from repro.detection.devices import DEVICES              # core importable
    t = np.empty((n_steps, len(pairs)))
    e = np.empty((n_steps, len(pairs)))
    for j, (model, device) in enumerate(pairs):
        flops = DETECTOR_CONFIGS[model].flops
        if fleet is not None:
            t[:, j], e[:, j] = fleet.cost_profile(device, flops, n_steps)
        else:
            t[:, j] = DEVICES[device].time_ms(flops)
            e[:, j] = DEVICES[device].energy_mwh(flops)
    return StreamMeasurements(time_ms=t, energy_mwh=e)


_scan_kernel = None


def _scan_jit():
    import jax
    import jax.numpy as jnp

    @jax.jit
    def kernel(state, counts, t_meas, e_meas, m_meas, explore,
               lo, hi, rule_rows, col_of_pair, delta, alpha, quarantine):
        def step(st, xs):
            count, t_row, e_row, m_row, expl = xs
            g, col, _ = decide_state(st, count, delta, lo, hi, rule_rows,
                                     quarantine_after=quarantine)
            pair = st.pair_id[g, col]
            # round-robin exploration override (expl = -1: router's pick);
            # the explored pair's column within this group row maps the
            # decision back to an entry (-1 when the pair has no row here).
            # Under quarantine this IS the half-open probe: the override
            # serves an OPEN pair the breaker would have excluded.
            pair = jnp.where(expl >= 0, expl, pair)
            col = jnp.where(expl >= 0, col_of_pair[g, pair], col)
            # inf time = the pair did not answer: no EWMA evidence, one
            # more consecutive failure at the routed cell
            failed = jnp.isinf(t_row[pair])
            nan = jnp.float32(jnp.nan)
            st = observe_state(st, pair, g,
                               time_ms=jnp.where(failed, nan, t_row[pair]),
                               energy_mwh=jnp.where(failed, nan,
                                                    e_row[pair]),
                               map_pct=jnp.where(failed, nan, m_row[pair]),
                               alpha=alpha)
            st = quarantine_state(st, pair, g, failed)
            st = probe_state(st, pair, (expl >= 0) & ~failed)
            return st, (g, col, pair)
        return jax.lax.scan(step, state,
                            (counts, t_meas, e_meas, m_meas, explore))

    return kernel


def scan_stream(state: ProfileState, counts, measurements: StreamMeasurements,
                *, arrays: ProfileArrays, delta: float, alpha: float = 0.1,
                group_rules: Sequence = DEFAULT_GROUP_RULES,
                explore_pairs=None, quarantine_after: Optional[int] = None
                ) -> Tuple[ProfileState, ScanDecisions]:
    """Run estimate->route->observe for a whole frame sequence inside one
    jitted ``lax.scan``; returns the final state and the routing trace.

    Per step t: Algorithm 1 routes ``counts[t]`` against the CURRENT state
    (``decide_state``), the routed pair's decision-independent measurement
    ``measurements[t, pair]`` is gathered, and ``observe_state`` EWMA-folds
    it before step t+1 decides — bit-for-bit the scalar closed loop's
    order of operations, minus T Python iterations and T dict mutations.

    ``arrays`` is the snapshot ``state`` was exported from (identity:
    ``row_of`` for the group rules, ``pairs``/``col_of_pair``/
    ``entry_index`` to map the trace back).  ``explore_pairs`` (optional
    [T] int32, -1 = no override) serves step t on that pair index instead
    of the router's pick — the deterministic round-robin schedule
    ``DetectionPolicy`` uses for post-transient recovery.

    ``quarantine_after`` (optional) arms the per-(group, pair) circuit
    breaker: after that many CONSECUTIVE failed steps (inf ``time_ms``
    sentinel in the measurements) the cell is excluded from routing until
    an ``explore_pairs`` probe of the pair succeeds (half-open recovery
    riding the existing schedule).  Off (None) it compiles to a threshold
    no counter reaches — decisions stay bit-identical to the
    pre-quarantine kernel, so zero-fault parity with the scalar loop is
    structural, not coincidental.

    Raises the scalar path's ``ValueError`` when any count lands in an
    unprofiled group (checked eagerly — a jitted program cannot raise).
    """
    import jax.numpy as jnp
    global _scan_kernel
    if _scan_kernel is None:
        _scan_kernel = _scan_jit()
    counts = np.asarray(counts, np.int32)
    T = len(counts)
    # repro-lint: disable=ECO201 -- eager pre-validation, not per-frame
    # work: a jitted program cannot raise, so unprofiled groups must be
    # rejected on the host BEFORE the scan is entered (documented above)
    for c in counts:
        group = group_of(int(c), group_rules)
        if group not in arrays.row_of:
            raise ValueError(
                f"no profile rows for group {group} (table covers groups "
                f"{sorted(arrays.groups)}); profile every group the router "
                f"can be asked for")
    n_pairs = len(arrays.pairs)
    t_meas = np.asarray(measurements.time_ms, np.float32)
    e_meas = np.asarray(measurements.energy_mwh, np.float32)
    m_meas = (np.full((T, n_pairs), np.nan, np.float32)
              if measurements.map_pct is None
              else np.asarray(measurements.map_pct, np.float32))
    for name, arr in (("time_ms", t_meas), ("energy_mwh", e_meas),
                      ("map_pct", m_meas)):
        if arr.shape != (T, n_pairs):
            raise ValueError(
                f"measurements.{name} has shape {arr.shape}, expected "
                f"({T}, {n_pairs}) — one row per step, one column per "
                f"profiled pair in arrays.pairs order")
    explore = (np.full(T, -1, np.int32) if explore_pairs is None
               else np.asarray(explore_pairs, np.int32))
    lo, hi, rule_rows = rules_arrays(group_rules, arrays.row_of)
    # one kernel for both modes: "off" is a threshold no counter reaches
    quarantine = (np.iinfo(np.int32).max if quarantine_after is None
                  else int(quarantine_after))
    state, (g, col, pair) = _scan_kernel(
        with_fails(state), jnp.asarray(counts), jnp.asarray(t_meas),
        jnp.asarray(e_meas), jnp.asarray(m_meas), jnp.asarray(explore),
        jnp.asarray(lo), jnp.asarray(hi), jnp.asarray(rule_rows),
        jnp.asarray(arrays.col_of_pair), jnp.float32(delta),
        jnp.float32(alpha), jnp.int32(quarantine))
    g, col, pair = np.asarray(g), np.asarray(col), np.asarray(pair)
    entry_idx = np.where(col >= 0, arrays.entry_index[g, np.maximum(col, 0)],
                         -1).astype(np.int32)
    return state, ScanDecisions(pair_idx=pair, group_row=g,
                                entry_idx=entry_idx,
                                explored=np.asarray(explore) >= 0)

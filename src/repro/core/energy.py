"""Energy accounting: edge devices (paper testbed) + TPU roofline backends.

Edge energy comes from the device models in repro.detection.devices; the
gateway host is modeled as a Pi5-class device.  TPU pool backends derive
latency/energy from the dry-run roofline terms (repro.launch.roofline).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.detection.devices import DEVICES, EdgeDevice

GATEWAY_DEVICE = DEVICES["pi5"]

#: 1 mWh = 3.6 J
MWH_TO_J = 3.6


def mwh_to_joules(mwh: float) -> float:
    """Convert milliwatt-hours (the profile/bench unit) to joules (the
    paper's reporting unit, and what the SLO plane charges per request)."""
    return mwh * MWH_TO_J


def gateway_cost(flops: float) -> Dict[str, float]:
    """Latency/energy of an estimator invocation at the gateway.

    In-process estimation: pure compute time on the gateway host (no
    per-request dispatch overhead — that applies to backend requests)."""
    if flops <= 0:
        return {"time_ms": 0.02, "energy_mwh": 1e-6}  # table lookup only
    t_ms = flops / (GATEWAY_DEVICE.gflops * 1e9) * 1e3 + 0.05
    return {"time_ms": t_ms,
            "energy_mwh": GATEWAY_DEVICE.watts * t_ms / 1e3 / 3600.0 * 1e3}


def roofline_backend_profile(row: Dict, *, requests_per_step: int = 1) -> Dict[str, float]:
    """Convert a dry-run roofline row (launch.roofline.Roofline.row()) into
    per-request latency/energy for the serving pool."""
    t = row["t_step_s"]
    e = row["energy_j"]
    per = max(requests_per_step, 1)
    return {"time_ms": t * 1e3 / per,
            "energy_mwh": e / 3.6 / per}  # J -> mWh

"""Object-count estimators (paper §3.3): ED, SF, OB.

Each estimator returns (count, gateway_flops) — the FLOPs drive the
gateway-overhead energy/latency accounting the paper reports separately.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from repro.detection.canny import canny_count, canny_count_batch
from repro.detection.detectors import DETECTOR_CONFIGS
from repro.detection.scenes import IMG


class Estimator:
    name = "base"
    #: True if estimate_batch is a real batched launch with no per-frame
    #: feedback dependency (lets the gateway estimate+route whole batches)
    batchable = False

    def estimate(self, image: np.ndarray) -> Tuple[int, float]:
        raise NotImplementedError

    def estimate_batch(self, images: np.ndarray
                       ) -> Tuple[np.ndarray, np.ndarray]:
        """images [B,H,W] -> (counts [B], gateway_flops [B]).  The generic
        fallback loops ``estimate``; batchable estimators override with one
        device launch for the whole batch."""
        pairs = [self.estimate(im) for im in images]
        return (np.asarray([c for c, _ in pairs]),
                np.asarray([f for _, f in pairs], np.float64))

    def observe(self, detected_count: int) -> None:
        """Feedback from the backend's detection result (used by OB)."""

    def observe_batch(self, detected_counts) -> None:
        """Fold a whole stream's backend feedback in completion order.  The
        generic fallback loops ``observe``; estimators whose fold telescopes
        (OB keeps only the LAST count) override with one assignment."""
        for c in detected_counts:
            self.observe(int(c))

    def reset(self) -> None:
        pass


class EdgeDetectionEstimator(Estimator):
    """ED: Canny edges -> connected-component count.  Cheapest, coarse."""
    name = "ED"
    batchable = True
    # gaussian+sobel+nms+hysteresis: ~60 flops/pixel
    FLOPS_PER_PIXEL = 60.0

    def estimate(self, image):
        return canny_count(image), image.size * self.FLOPS_PER_PIXEL

    def estimate_batch(self, images):
        flops = np.full(len(images), images[0].size * self.FLOPS_PER_PIXEL)
        return canny_count_batch(images), flops


class SSDFrontEndEstimator(Estimator):
    """SF: a lightweight detector AT THE GATEWAY counts objects.  More
    accurate than ED, at a higher gateway cost."""
    name = "SF"
    batchable = True

    def __init__(self, detector_params, model: str = "ssd_v1",
                 score_thr: float = 0.5):
        from repro.detection.train import run_detector
        self._run = run_detector
        self._params = detector_params
        self._flops = DETECTOR_CONFIGS[model].flops
        self._thr = score_thr

    def estimate(self, image):
        boxes, scores, classes = self._run(self._params, image[None])[0]
        return int((scores >= self._thr).sum()), self._flops

    def estimate_batch(self, images):
        outs = self._run(self._params, np.asarray(images))
        counts = np.asarray([np.count_nonzero(s >= self._thr)
                             for _, s, _ in outs])
        return counts, np.full(len(images), self._flops, np.float64)


class OutputBasedEstimator(Estimator):
    """OB: reuse the object count detected by the backend for the previous
    frame (temporal continuity); near-zero gateway cost."""
    name = "OB"

    def __init__(self, default: int = 0):
        self._default = default
        self._last: Optional[int] = None

    def estimate(self, image):
        return (self._last if self._last is not None else self._default), 0.0

    def observe(self, detected_count: int) -> None:
        self._last = int(detected_count)

    def observe_batch(self, detected_counts) -> None:
        # the EWMA-free fold telescopes: only the last count survives
        if len(detected_counts):
            self._last = int(detected_counts[-1])

    def reset(self) -> None:
        self._last = None


class OracleEstimator(Estimator):
    """Ground-truth count passthrough (for the Orc router wiring)."""
    name = "GT"

    def __init__(self):
        self.true_count: Optional[int] = None

    def estimate(self, image):
        return int(self.true_count), 0.0

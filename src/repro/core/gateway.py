"""The ECORE gateway: estimate -> route -> dispatch -> account.

Mirrors Figure 3: cameras send frames to the gateway, which runs a
lightweight estimator, feeds the count to the routing algorithm, forwards
the frame to the selected (model, device) backend, and returns detections.
Energy/latency for backends come from the profiled device models; gateway
overhead (estimator cost) is accounted separately, exactly like the paper's
"Gateway Overhead" metric.

Decision-making lives in ``core.policy.DetectionPolicy`` (estimate+route+
explore/adapt behind the shared ``RoutingPolicy`` API); this class is the
thin stream driver on top of it: it executes the chosen detector, charges
fleet/device costs, accumulates ``EpisodeStats``, and feeds measurements
back through the single ``Observation`` plane.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

from repro.core.estimators import Estimator
from repro.core.metrics import MAPAccumulator
from repro.core.policy import DetectionPolicy, Observation, RouteRequest
from repro.core.profiles import ProfileTable
from repro.core.router import Router
from repro.detection.devices import DEVICES
from repro.detection.detectors import DETECTOR_CONFIGS
from repro.detection.scenes import NUM_CLASSES, Scene


@dataclasses.dataclass
class EpisodeStats:
    router: str
    estimator: Optional[str]
    map_pct: float
    backend_energy_mwh: float
    backend_time_ms: float       # sum over requests (piggybacked => total)
    gateway_energy_mwh: float
    gateway_time_ms: float
    pair_histogram: Dict[str, int]

    @property
    def total_energy_mwh(self) -> float:
        return self.backend_energy_mwh + self.gateway_energy_mwh

    @property
    def total_time_ms(self) -> float:
        return self.backend_time_ms + self.gateway_time_ms


class Gateway:
    """Routes a stream of scenes through detector backends.

    Closed loop (BEYOND-PAPER, §6 future work): with ``adapt=True`` every
    request's MEASURED backend latency/energy is EWMA-folded back into the
    profile table (``ProfileTable.observe_pair``), so the router tracks
    runtime drift.  Pass a ``fleet`` (``detection.devices.DriftingFleet``) to
    make the measured costs diverge from the offline profile — without one,
    measurements equal the profile and adaptation is a fixed point.

    Pure exploitation cannot recover from TRANSIENT drift: once a pair's
    observed cost spikes, routing abandons it and its rows are never
    re-measured, so it stays poisoned after the device recovers.
    ``explore_every=N`` serves every Nth request on a round-robin pair
    instead of the router's pick (a small accuracy/energy tax), keeping
    every pair's profile fresh.

    Batched hot path: when the policy is ``batchable`` (ED/SF estimator,
    greedy/oracle router, loop open), ``process_stream`` decides the WHOLE
    stream in one ``DetectionPolicy.decide_batch`` call (one estimator
    launch + one XLA routing call) instead of per-frame Python — decisions
    are identical to the scalar path (tested).  Set ``batch_routing=False``
    to force the scalar path.

    mAP closed loop: ``adapt_map=True`` (requires ``adapt=True``) folds each
    request's MEASURED per-frame detection quality back into the served
    pair's row for the scene's TRUE group via the observation plane — the
    third profile column (after latency/energy) the runtime keeps fresh."""

    def __init__(self, router: Router, table: ProfileTable,
                 detector_params: Dict[str, Dict],
                 estimator: Optional[Estimator] = None, *,
                 fleet=None, adapt: bool = False, alpha: float = 0.1,
                 explore_every: int = 0, adapt_map: bool = False,
                 batch_routing: bool = True):
        from repro.detection.train import run_detector  # lazy: heavy import
        self._run = run_detector
        self.policy = DetectionPolicy(router, table, estimator, adapt=adapt,
                                      alpha=alpha, explore_every=explore_every,
                                      adapt_map=adapt_map,
                                      batch_routing=batch_routing)
        self.params = detector_params
        self.fleet = fleet

    # single source of truth for routing state is the policy — read-only
    # mirrors here, so a post-construction toggle can't drift the two apart
    @property
    def router(self) -> Router:
        return self.policy.router

    @property
    def table(self) -> ProfileTable:
        return self.policy.table

    @property
    def estimator(self) -> Optional[Estimator]:
        return self.policy.estimator

    @property
    def adapt(self) -> bool:
        return self.policy.adapt

    @property
    def adapt_map(self) -> bool:
        return self.policy.adapt_map

    def observe(self, pair: Tuple[str, str], group: int, *,
                map_pct: Optional[float] = None,
                time_ms: Optional[float] = None,
                energy_mwh: Optional[float] = None) -> None:
        """Fold runtime measurements into the profile (compat shim over the
        policy's ``Observation`` plane): latency/energy are group-independent
        (every row of the pair moves, like the serving pool); detection
        quality is per-group, so a measured mAP only touches the observed
        group's row."""
        self.policy.observe(Observation(pair=pair, group=group,
                                        time_ms=time_ms,
                                        energy_mwh=energy_mwh,
                                        map_pct=map_pct))

    def process_stream(self, stream: Sequence[Scene]) -> EpisodeStats:
        scenes = list(stream)
        acc = MAPAccumulator(NUM_CLASSES)
        be_energy = be_time = gw_energy = gw_time = 0.0
        hist: Dict[str, int] = {}
        self.policy.reset()
        reqs = [RouteRequest(uid=i, payload=s.image, true_complexity=s.count)
                for i, s in enumerate(scenes)]
        # batched estimate->route fast path: one decide_batch call for the
        # whole stream when per-frame semantics (closed loop, feedback
        # estimators) don't force the scalar loop
        decisions = (self.policy.decide_batch(reqs)
                     if self.policy.batchable and reqs else None)
        for step, (scene, req) in enumerate(zip(scenes, reqs)):
            d = (decisions[step] if decisions is not None
                 else self.policy.decide(req))
            gw_energy += d.gateway_energy_mwh
            gw_time += d.gateway_time_ms
            model, device = d.pair
            hist[d.pair_name] = hist.get(d.pair_name, 0) + 1
            boxes, scores, classes = self._run(self.params[model],
                                               scene.image[None])[0]
            acc.add_image(boxes, scores, classes, scene.boxes, scene.classes)
            flops = DETECTOR_CONFIGS[model].flops
            if self.fleet is not None:
                t_ms, e_mwh = self.fleet.cost(device, flops, step)
            else:
                dev = DEVICES[device]
                t_ms, e_mwh = dev.time_ms(flops), dev.energy_mwh(flops)
            be_energy += e_mwh
            be_time += t_ms
            obs = Observation(pair=d.pair)
            if self.adapt:
                if self.adapt_map:
                    one = MAPAccumulator(NUM_CLASSES)
                    one.add_image(boxes, scores, classes, scene.boxes,
                                  scene.classes)
                    obs.map_pct = one.map()
                obs.group = self.policy.group_for(scene.count)
                obs.time_ms, obs.energy_mwh = t_ms, e_mwh
            if self.estimator is not None:
                # OB feedback: the count the BACKEND detected
                obs.detected_count = int((scores >= 0.5).sum())
            if not obs.empty:
                self.policy.observe(obs)
        return EpisodeStats(
            router=self.router.name,
            estimator=self.estimator.name if self.estimator else None,
            map_pct=acc.map(),
            backend_energy_mwh=be_energy,
            backend_time_ms=be_time,
            gateway_energy_mwh=gw_energy,
            gateway_time_ms=gw_time,
            pair_histogram=hist,
        )

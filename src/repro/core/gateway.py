"""The ECORE gateway: estimate -> route -> dispatch -> account.

Mirrors Figure 3: cameras send frames to the gateway, which runs a
lightweight estimator, feeds the count to the routing algorithm, forwards
the frame to the selected (model, device) backend, and returns detections.
Energy/latency for backends come from the profiled device models; gateway
overhead (estimator cost) is accounted separately, exactly like the paper's
"Gateway Overhead" metric.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.energy import gateway_cost
from repro.core.estimators import Estimator, OracleEstimator
from repro.core.groups import DEFAULT_GROUP_RULES, group_of
from repro.core.metrics import MAPAccumulator
from repro.core.profiles import ProfileTable
from repro.core.router import Router
from repro.detection.devices import DEVICES
from repro.detection.detectors import DETECTOR_CONFIGS
from repro.detection.scenes import NUM_CLASSES, Scene


@dataclasses.dataclass
class EpisodeStats:
    router: str
    estimator: Optional[str]
    map_pct: float
    backend_energy_mwh: float
    backend_time_ms: float       # sum over requests (piggybacked => total)
    gateway_energy_mwh: float
    gateway_time_ms: float
    pair_histogram: Dict[str, int]

    @property
    def total_energy_mwh(self) -> float:
        return self.backend_energy_mwh + self.gateway_energy_mwh

    @property
    def total_time_ms(self) -> float:
        return self.backend_time_ms + self.gateway_time_ms


class Gateway:
    """Routes a stream of scenes through detector backends.

    Closed loop (BEYOND-PAPER, §6 future work): with ``adapt=True`` every
    request's MEASURED backend latency/energy is EWMA-folded back into the
    profile table (``ProfileTable.observe_pair``), so the router tracks
    runtime drift.  Pass a ``fleet`` (``detection.devices.DriftingFleet``) to
    make the measured costs diverge from the offline profile — without one,
    measurements equal the profile and adaptation is a fixed point.

    Pure exploitation cannot recover from TRANSIENT drift: once a pair's
    observed cost spikes, routing abandons it and its rows are never
    re-measured, so it stays poisoned after the device recovers.
    ``explore_every=N`` serves every Nth request on a round-robin pair
    instead of the router's pick (a small accuracy/energy tax), keeping
    every pair's profile fresh.

    Batched hot path: with a ``batchable`` estimator (ED/SF) and a
    ``batchable`` router (greedy/oracle) and the loop open (``adapt=False``),
    ``process_stream`` estimates the WHOLE stream in one device launch and
    routes it in one XLA call (``Router.route_batch``) instead of per-frame
    Python — decisions are identical to the scalar path (tested).  Set
    ``batch_routing=False`` to force the scalar path.

    mAP closed loop: ``adapt_map=True`` (requires ``adapt=True``) folds each
    request's MEASURED per-frame detection quality back into the served
    pair's row for the scene's TRUE group via ``observe`` — the third
    profile column (after latency/energy) the runtime keeps fresh."""

    def __init__(self, router: Router, table: ProfileTable,
                 detector_params: Dict[str, Dict],
                 estimator: Optional[Estimator] = None, *,
                 fleet=None, adapt: bool = False, alpha: float = 0.1,
                 explore_every: int = 0, adapt_map: bool = False,
                 batch_routing: bool = True):
        from repro.detection.train import run_detector  # lazy: heavy import
        self._run = run_detector
        self.router = router
        self.table = table
        self.params = detector_params
        self.estimator = estimator
        self.fleet = fleet
        self.adapt = adapt
        self.alpha = alpha
        self.explore_every = explore_every
        self.adapt_map = adapt_map
        self.batch_routing = batch_routing
        if adapt and getattr(router, "table", None) is not table:
            raise ValueError(
                "adapt=True requires router.table to BE the gateway's table "
                "(same object): observe_pair updates would otherwise never "
                "reach the router's decisions")
        if adapt_map and not adapt:
            raise ValueError("adapt_map=True requires adapt=True")

    def observe(self, pair: Tuple[str, str], group: int, *,
                map_pct: Optional[float] = None,
                time_ms: Optional[float] = None,
                energy_mwh: Optional[float] = None) -> None:
        """Fold runtime measurements into the profile: latency/energy are
        group-independent (every row of the pair moves, like the serving
        pool); detection quality is per-group, so a measured mAP only
        touches the observed group's row."""
        if time_ms is not None or energy_mwh is not None:
            self.table.observe_pair(pair, time_ms=time_ms,
                                    energy_mwh=energy_mwh, alpha=self.alpha)
        if map_pct is not None:
            self.table.observe(pair, group, map_pct=map_pct,
                               alpha=self.alpha)

    def _route_all(self, scenes: List[Scene]):
        """The batched estimate->route fast path, or None when per-frame
        semantics (closed loop, exploration, feedback estimators) force the
        scalar loop."""
        # note: explore_every only fires under adapt (see the scalar loop),
        # so adapt alone decides; exploration never disables this path on
        # an open-loop stream
        if (not self.batch_routing or self.adapt
                or self.estimator is None or not self.estimator.batchable
                or not self.router.batchable or not scenes):
            return None
        images = np.stack([s.image for s in scenes])
        counts, flops = self.estimator.estimate_batch(images)
        pairs = self.router.route_batch(
            estimated_counts=counts,
            true_counts=[s.count for s in scenes])
        return list(zip(counts, flops, pairs))

    def process_stream(self, stream: Sequence[Scene]) -> EpisodeStats:
        scenes = list(stream)
        acc = MAPAccumulator(NUM_CLASSES)
        be_energy = be_time = gw_energy = gw_time = 0.0
        hist: Dict[str, int] = {}
        if self.estimator is not None:
            self.estimator.reset()
        self.router.reset()
        routed = self._route_all(scenes)
        for step, scene in enumerate(scenes):
            est_count = None
            if routed is not None:
                est_count, est_flops, pair = routed[step]
                gc = gateway_cost(float(est_flops))
                gw_energy += gc["energy_mwh"]
                gw_time += gc["time_ms"]
            else:
                if self.estimator is not None:
                    if isinstance(self.estimator, OracleEstimator):
                        self.estimator.true_count = scene.count
                    est_count, est_flops = self.estimator.estimate(
                        scene.image)
                    gc = gateway_cost(est_flops)
                    gw_energy += gc["energy_mwh"]
                    gw_time += gc["time_ms"]
                else:
                    gc = gateway_cost(0.0)  # routing-table lookup only
                    gw_energy += gc["energy_mwh"]
                    gw_time += gc["time_ms"]
                pair = self.router.route(estimated_count=est_count,
                                         true_count=scene.count)
                if (self.adapt and self.explore_every
                        and step % self.explore_every
                        == self.explore_every - 1):
                    pairs = self.table.pairs()
                    pair = pairs[(step // self.explore_every) % len(pairs)]
            model, device = pair
            hist[f"{model}@{device}"] = hist.get(f"{model}@{device}", 0) + 1
            boxes, scores, classes = self._run(self.params[model],
                                               scene.image[None])[0]
            acc.add_image(boxes, scores, classes, scene.boxes, scene.classes)
            flops = DETECTOR_CONFIGS[model].flops
            if self.fleet is not None:
                t_ms, e_mwh = self.fleet.cost(device, flops, step)
            else:
                dev = DEVICES[device]
                t_ms, e_mwh = dev.time_ms(flops), dev.energy_mwh(flops)
            be_energy += e_mwh
            be_time += t_ms
            if self.adapt:
                measured_map = None
                if self.adapt_map:
                    one = MAPAccumulator(NUM_CLASSES)
                    one.add_image(boxes, scores, classes, scene.boxes,
                                  scene.classes)
                    measured_map = one.map()
                group = group_of(scene.count,
                                 getattr(self.router, "rules",
                                         None) or DEFAULT_GROUP_RULES)
                self.observe(pair, group, time_ms=t_ms, energy_mwh=e_mwh,
                             map_pct=measured_map)
            if self.estimator is not None:
                # OB feedback: the count the BACKEND detected
                self.estimator.observe(int((scores >= 0.5).sum()))
        return EpisodeStats(
            router=self.router.name,
            estimator=self.estimator.name if self.estimator else None,
            map_pct=acc.map(),
            backend_energy_mwh=be_energy,
            backend_time_ms=be_time,
            gateway_energy_mwh=gw_energy,
            gateway_time_ms=gw_time,
            pair_histogram=hist,
        )

"""The ECORE gateway: estimate -> route -> dispatch -> account.

Mirrors Figure 3: cameras send frames to the gateway, which runs a
lightweight estimator, feeds the count to the routing algorithm, forwards
the frame to the selected (model, device) backend, and returns detections.
Energy/latency for backends come from the profiled device models; gateway
overhead (estimator cost) is accounted separately, exactly like the paper's
"Gateway Overhead" metric.

Decision-making lives in ``core.policy.DetectionPolicy`` (estimate+route+
explore/adapt behind the shared ``RoutingPolicy`` API); EXECUTION lives in
``serving.backend.DetectorBackend`` behind the shared ``ExecutionBackend``
protocol.  This class is the thin stream driver over ``EcoreService``: it
submits the stream as ``RouteRequest``s, lets the service's per-pair
``DispatchQueue``s batch the dispatch, accumulates ``EpisodeStats`` from the
``Served`` completions, and feeds measurements back through the single
``Observation`` plane — there is no detection-private serving loop.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core.closed_loop import measurements_from_fleet
from repro.core.estimators import Estimator
from repro.core.metrics import MAPAccumulator
from repro.core.policy import DetectionPolicy, Observation, RouteRequest
from repro.core.profiles import ProfileTable
from repro.core.router import Router
from repro.detection.scenes import NUM_CLASSES, Scene


@dataclasses.dataclass
class EpisodeStats:
    router: str
    estimator: Optional[str]
    map_pct: float
    backend_energy_mwh: float
    backend_time_ms: float       # sum over requests (piggybacked => total)
    gateway_energy_mwh: float
    gateway_time_ms: float
    pair_histogram: Dict[str, int]

    @property
    def total_energy_mwh(self) -> float:
        return self.backend_energy_mwh + self.gateway_energy_mwh

    @property
    def total_time_ms(self) -> float:
        return self.backend_time_ms + self.gateway_time_ms


class Gateway:
    """Routes a stream of scenes through detector backends via EcoreService.

    Closed loop (BEYOND-PAPER, §6 future work): with ``adapt=True`` every
    request's MEASURED backend latency/energy is EWMA-folded back into the
    profile table (``ProfileTable.observe_pair``), so the router tracks
    runtime drift.  Pass a ``fleet`` (``detection.devices.DriftingFleet``) to
    make the measured costs diverge from the offline profile — without one,
    measurements equal the profile and adaptation is a fixed point.

    Pure exploitation cannot recover from TRANSIENT drift: once a pair's
    observed cost spikes, routing abandons it and its rows are never
    re-measured, so it stays poisoned after the device recovers.
    ``explore_every=N`` serves every Nth request on a round-robin pair
    instead of the router's pick (a small accuracy/energy tax), keeping
    every pair's profile fresh.

    Batched hot path: when the policy is ``batchable`` (ED/SF estimator,
    greedy/oracle router, loop open), ``process_stream`` decides the WHOLE
    stream in one ``EcoreService.submit_batch`` call (one estimator launch +
    one XLA routing call) and the per-pair dispatch queues batch detector
    execution up to ``max_batch`` frames per launch — decisions and stats
    are identical to the scalar path (tested).  Set ``batch_routing=False``
    to force the scalar path.

    Scanned closed loop: when the policy is ``scannable`` (adapt on, greedy
    routing, batchable/oracle estimator, no ``adapt_map``), the per-frame
    estimate->route->observe dependency chain runs as ONE jitted
    ``lax.scan`` over the profile's ``ProfileState``
    (``DetectionPolicy.decide_scan``): the fleet's drifted costs are
    decision-independent, so the gateway precomputes every pair's would-be
    measurement per step and the scan gathers + EWMA-folds the routed
    pair's column between decisions.  Decisions, adapted profile and
    EpisodeStats are identical to the scalar closed loop (tested), and
    dispatch batches detector execution up to ``max_batch`` — the closed
    loop no longer forces frame-at-a-time serving.  Feedback estimators
    (OB) and ``adapt_map`` still serve one request at a time, since their
    inputs depend on each frame's served result.

    mAP closed loop: ``adapt_map=True`` (requires ``adapt=True``) folds each
    request's MEASURED per-frame detection quality back into the served
    pair's row for the scene's TRUE group via the observation plane — the
    third profile column (after latency/energy) the runtime keeps fresh."""

    def __init__(self, router: Router, table: ProfileTable,
                 detector_params: Dict[str, Dict],
                 estimator: Optional[Estimator] = None, *,
                 fleet=None, adapt: bool = False, alpha: float = 0.1,
                 explore_every: int = 0, adapt_map: bool = False,
                 batch_routing: bool = True, max_batch: int = 1):
        # lazy: heavy imports (detector training stack, serving engine)
        from repro.detection.train import run_detector
        from repro.serving.backend import DetectorBackend
        from repro.serving.service import EcoreService
        self._run = run_detector
        self._DetectorBackend = DetectorBackend
        self._EcoreService = EcoreService
        self.policy = DetectionPolicy(router, table, estimator, adapt=adapt,
                                      alpha=alpha, explore_every=explore_every,
                                      adapt_map=adapt_map,
                                      batch_routing=batch_routing)
        self.params = detector_params
        self.fleet = fleet
        #: frames per detector launch on the batched paths (open-loop
        #: decide_batch and the scanned closed loop); 1 = bit-exact with
        #: per-frame execution
        self.max_batch = max_batch

    # single source of truth for routing state is the policy — read-only
    # mirrors here, so a post-construction toggle can't drift the two apart
    @property
    def router(self) -> Router:
        return self.policy.router

    @property
    def table(self) -> ProfileTable:
        return self.policy.table

    @property
    def estimator(self) -> Optional[Estimator]:
        return self.policy.estimator

    @property
    def adapt(self) -> bool:
        return self.policy.adapt

    @property
    def adapt_map(self) -> bool:
        return self.policy.adapt_map

    def observe(self, pair: Tuple[str, str], group: int, *,
                map_pct: Optional[float] = None,
                time_ms: Optional[float] = None,
                energy_mwh: Optional[float] = None) -> None:
        """Fold runtime measurements into the profile (compat shim over the
        policy's ``Observation`` plane): latency/energy are group-independent
        (every row of the pair moves, like the serving pool); detection
        quality is per-group, so a measured mAP only touches the observed
        group's row."""
        self.policy.observe(Observation(pair=pair, group=group,
                                        time_ms=time_ms,
                                        energy_mwh=energy_mwh,
                                        map_pct=map_pct))

    def process_stream(self, stream: Sequence[Scene]) -> EpisodeStats:
        scenes = list(stream)
        acc = MAPAccumulator(NUM_CLASSES)
        totals = {"be_e": 0.0, "be_t": 0.0, "gw_e": 0.0, "gw_t": 0.0}
        hist: Dict[str, int] = {}
        self.policy.reset()
        # request uid = stream position: DetectorBackend uses it as the
        # fleet timestep, so drifted costs are identical however dispatch
        # batches the frames
        reqs = [RouteRequest(uid=i, payload=s.image, true_complexity=s.count)
                for i, s in enumerate(scenes)]
        batchable = self.policy.batchable
        scannable = not batchable and self.policy.scannable
        # the remaining scalar closed loops (OB feedback, adapt_map) serve
        # frame-at-a-time: each observation mutates the table the next
        # decision must read
        max_batch = self.max_batch if (batchable or scannable) else 1

        def factory(decision):
            model, device = decision.pair
            return self._DetectorBackend(model, device, self.params[model],
                                         max_batch=max_batch,
                                         fleet=self.fleet, run_fn=self._run,
                                         table=self.table)

        # does the estimator CONSUME backend feedback?  Today's scannable
        # estimators (ED/SF/oracle/None) all inherit the no-op observe, so
        # the scanned path skips computing per-frame detected counts
        wants_feedback = (self.estimator is not None
                          and type(self.estimator).observe
                          is not Estimator.observe)

        def handle(service, served_batch, folded=False):
            # uid order = stream order: accumulation is identical to the
            # longhand per-frame loop however the dispatch queues batched
            detected = []
            for served in sorted(served_batch, key=lambda s: s.request.uid):
                d, res = served.decision, served.result
                scene = scenes[served.request.uid]
                totals["gw_e"] += d.gateway_energy_mwh
                totals["gw_t"] += d.gateway_time_ms
                hist[d.pair_name] = hist.get(d.pair_name, 0) + 1
                boxes, scores, classes = res.detections
                acc.add_image(boxes, scores, classes, scene.boxes,
                              scene.classes)
                totals["be_e"] += res.energy_mwh
                totals["be_t"] += res.time_ms
                if folded:
                    # the scan already EWMA-folded every cost observation;
                    # backend-detected counts only matter to an estimator
                    # that actually consumes feedback
                    if wants_feedback:
                        detected.append(int(np.count_nonzero(scores >= 0.5)))
                    continue
                obs = Observation(pair=d.pair, uid=served.request.uid)
                if self.adapt:
                    if self.adapt_map:
                        one = MAPAccumulator(NUM_CLASSES)
                        one.add_image(boxes, scores, classes, scene.boxes,
                                      scene.classes)
                        obs.map_pct = one.map()
                    obs.group = self.policy.group_for(scene.count)
                    obs.time_ms, obs.energy_mwh = res.time_ms, res.energy_mwh
                if self.estimator is not None:
                    # OB feedback: the count the BACKEND detected
                    obs.detected_count = int(np.count_nonzero(scores >= 0.5))
                if not obs.empty:
                    service.observe(obs)
            if folded and detected and self.estimator is not None:
                self.estimator.observe_batch(detected)

        service = self._EcoreService(self.policy, factory)
        try:
            if batchable and reqs:
                # one decide_batch for the whole stream, batched dispatch;
                # open loop, so deferring the (estimator-feedback-only)
                # observations to completion order is semantics-preserving
                service.submit_batch(reqs)
                handle(service, service.results() + service.drain())
            elif scannable and reqs:
                # closed loop as ONE jitted lax.scan: decisions and EWMA
                # folds happen inside decide_scan, so dispatch receives
                # pre-routed requests and batches execution freely; the
                # fleet's per-step costs are decision-independent, which is
                # what lets them be precomputed
                measurements = measurements_from_fleet(
                    self.table.as_arrays().pairs, len(reqs), self.fleet)
                decisions = self.policy.decide_scan(reqs, measurements)
                service.submit_batch(reqs, decisions=decisions)
                handle(service, service.results() + service.drain(),
                       folded=True)
            else:
                for req in reqs:
                    # max_batch=1: the request is served inline, so the
                    # observation lands before the next decision
                    service.submit(req)
                    handle(service, service.results())
                handle(service, service.drain())
        finally:
            service.close()
        return EpisodeStats(
            router=self.router.name,
            estimator=self.estimator.name if self.estimator else None,
            map_pct=acc.map(),
            backend_energy_mwh=totals["be_e"],
            backend_time_ms=totals["be_t"],
            gateway_energy_mwh=totals["gw_e"],
            gateway_time_ms=totals["gw_t"],
            pair_histogram=hist,
        )

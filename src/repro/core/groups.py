"""Object-count group rules (paper §3: groups '0','1','2','3','4 or more')."""
from __future__ import annotations

from typing import List, Sequence, Tuple

# (lo, hi_inclusive, label); hi = None means unbounded
DEFAULT_GROUP_RULES: Tuple[Tuple[int, int, int], ...] = (
    (0, 0, 0),
    (1, 1, 1),
    (2, 2, 2),
    (3, 3, 3),
    (4, None, 4),
)

GROUP_LABELS = {0: "0", 1: "1", 2: "2", 3: "3", 4: "4+"}


def group_of(count: int, rules: Sequence[Tuple[int, int, int]] = DEFAULT_GROUP_RULES) -> int:
    """Algorithm 1 lines 1-7: find the group whose range contains count."""
    for lo, hi, label in rules:
        if count >= lo and (hi is None or count <= hi):
            return label
    return rules[-1][2]


def all_groups(rules: Sequence[Tuple[int, int, int]] = DEFAULT_GROUP_RULES) -> List[int]:
    return [label for _, _, label in rules]

"""Detection metrics: AP@0.5 mAP (greedy matching, all-point interpolation).

The paper evaluates with FiftyOne's COCO-style mAP; AP@0.5 with greedy
score-ordered matching is the same family of metric and is computed here
from scratch (no external deps).
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import numpy as np

Box = np.ndarray  # [x0, y0, x1, y1]


def iou(a: Box, b: Box) -> float:
    x0, y0 = max(a[0], b[0]), max(a[1], b[1])
    x1, y1 = min(a[2], b[2]), min(a[3], b[3])
    inter = max(0.0, x1 - x0) * max(0.0, y1 - y0)
    area_a = max(0.0, a[2] - a[0]) * max(0.0, a[3] - a[1])
    area_b = max(0.0, b[2] - b[0]) * max(0.0, b[3] - b[1])
    union = area_a + area_b - inter
    return inter / union if union > 0 else 0.0


def match_image(pred_boxes, pred_scores, gt_boxes, thr: float = 0.5):
    """Greedy match by descending score.  Returns (tp flags, n_gt)."""
    order = np.argsort(-np.asarray(pred_scores))
    used = set()
    tp = np.zeros(len(order), bool)
    for rank, i in enumerate(order):
        best, best_j = thr, -1
        for j, g in enumerate(gt_boxes):
            if j in used:
                continue
            v = iou(np.asarray(pred_boxes[i]), np.asarray(g))
            if v >= best:
                best, best_j = v, j
        if best_j >= 0:
            used.add(best_j)
            tp[rank] = True
    return tp, len(gt_boxes)


def average_precision(scores, tp_flags, n_gt: int) -> float:
    """All-point interpolated AP from pooled detections."""
    if n_gt == 0:
        return 1.0 if len(scores) == 0 else 0.0
    if len(scores) == 0:
        return 0.0
    order = np.argsort(-np.asarray(scores))
    tp = np.asarray(tp_flags)[order]
    cum_tp = np.cumsum(tp)
    cum_fp = np.cumsum(~tp)
    recall = cum_tp / n_gt
    precision = cum_tp / np.maximum(cum_tp + cum_fp, 1)
    # all-point interpolation
    mrec = np.concatenate([[0.0], recall, [recall[-1] if len(recall) else 0.0]])
    mpre = np.concatenate([[1.0], precision, [0.0]])
    for i in range(len(mpre) - 2, -1, -1):
        mpre[i] = max(mpre[i], mpre[i + 1])
    idx = np.where(mrec[1:] != mrec[:-1])[0]
    return float(np.sum((mrec[idx + 1] - mrec[idx]) * mpre[idx + 1]))


class MAPAccumulator:
    """Pools detections across images, per class; .map() -> [0, 100]."""

    def __init__(self, num_classes: int, iou_thr: float = 0.5):
        self.num_classes = num_classes
        self.thr = iou_thr
        self._scores: Dict[int, List[float]] = {c: [] for c in range(num_classes)}
        self._tp: Dict[int, List[bool]] = {c: [] for c in range(num_classes)}
        self._n_gt: Dict[int, int] = {c: 0 for c in range(num_classes)}
        self._n_empty = 0        # images with no ground-truth objects
        self._n_empty_clean = 0  # ... on which the model emitted no FPs

    def add_image(self, pred_boxes, pred_scores, pred_classes,
                  gt_boxes, gt_classes) -> None:
        pred_boxes = np.asarray(pred_boxes).reshape(-1, 4)
        gt_boxes = np.asarray(gt_boxes).reshape(-1, 4)
        pred_classes = np.asarray(pred_classes, int).reshape(-1)
        gt_classes = np.asarray(gt_classes, int).reshape(-1)
        if len(gt_classes) == 0:
            self._n_empty += 1
            if len(pred_classes) == 0:
                self._n_empty_clean += 1
        for c in range(self.num_classes):
            pi = pred_classes == c
            gi = gt_classes == c
            tp, n_gt = match_image(pred_boxes[pi], np.asarray(pred_scores)[pi],
                                   gt_boxes[gi], self.thr)
            # match_image returns flags ordered by score; keep that order
            order = np.argsort(-np.asarray(pred_scores)[pi])
            self._scores[c].extend(np.asarray(pred_scores)[pi][order].tolist())
            self._tp[c].extend(tp.tolist())
            self._n_gt[c] += n_gt

    def map(self) -> float:
        aps = []
        for c in range(self.num_classes):
            if self._n_gt[c] == 0:
                continue  # COCO convention: classes absent from GT ignored
            aps.append(average_precision(self._scores[c], self._tp[c],
                                         self._n_gt[c]))
        if aps:
            return 100.0 * float(np.mean(aps))
        # group with NO ground truth anywhere (the '0 objects' group):
        # score = fraction of images kept free of false positives
        if self._n_empty:
            return 100.0 * self._n_empty_clean / self._n_empty
        return 0.0

"""Request-centric routing policies: ONE decision/observation plane.

The paper's pipeline (Fig. 3) is estimate -> route -> dispatch -> observe.
This module gives every face of the repo the same typed vocabulary for the
first, second and fourth stages:

  * ``RouteRequest``   — what arrives at the gateway (a camera frame or an
                         LLM prompt, plus whatever complexity signal exists)
  * ``RouteDecision``  — where it goes: the (model, device) pair, the group
                         it was routed under, profiled costs, and the
                         gateway-side estimation cost
  * ``Observation``    — what came back: measured latency/energy/quality and
                         the backend-detected count (OB estimator feedback)

A ``RoutingPolicy`` turns requests into decisions (``decide`` /
``decide_batch``) and folds observations back into its profile
(``observe``).  Two implementations cover both faces of the repo:

  * ``DetectionPolicy`` — estimator + router + explore/adapt closed loop
    (the branchy core that used to live inline in ``Gateway.process_stream``)
  * ``PoolPolicy``      — ``ServingPool`` over dry-run-profiled LLM backends

so greedy/weighted/Pareto/baseline routers, the tensorized ``route_batch``
fast path, and the EWMA latency/energy/mAP loops all sit behind one entry
point; ``EcoreService`` (repro.serving.service) dispatches over any of them.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Protocol, Sequence, Tuple, runtime_checkable

import numpy as np

from .closed_loop import StreamMeasurements, scan_stream
from .energy import gateway_cost
from .estimators import Estimator, OracleEstimator
from .groups import DEFAULT_GROUP_RULES, group_of
from .profiles import ProfileTable
from .router import Router

Pair = Tuple[str, str]


@dataclasses.dataclass
class RouteRequest:
    """One unit of work arriving at the gateway.

    ``payload`` is whatever the backend consumes (a [H, W] frame for the
    detection face, an int32 token prompt for the serving face).
    ``complexity`` is the known complexity signal the router consumes
    directly (the serving face's prompt length); the detection face instead
    ESTIMATES complexity from the payload.  ``true_complexity`` is ground
    truth (oracle routers, per-group quality observation)."""
    uid: int
    payload: Any = None
    complexity: Optional[int] = None
    true_complexity: Optional[int] = None
    max_new_tokens: int = 8


@dataclasses.dataclass
class RouteDecision:
    """Where one request goes, plus the costs known at decision time."""
    uid: int
    pair: Pair                               # (model/arch, device/mesh)
    group: Optional[int] = None              # group/bucket routed under
    est_complexity: Optional[int] = None     # estimator output (detection)
    time_ms: Optional[float] = None          # profiled backend latency
    energy_mwh: Optional[float] = None       # profiled backend energy
    score: Optional[float] = None            # profiled mAP / capability
    gateway_time_ms: float = 0.0             # estimation cost at the gateway
    gateway_energy_mwh: float = 0.0
    explored: bool = False                   # round-robin exploration pick

    @property
    def backend(self) -> str:
        return self.pair[0]

    @property
    def pair_name(self) -> str:
        return f"{self.pair[0]}@{self.pair[1]}"


@dataclasses.dataclass
class Observation:
    """Measured runtime signals for one served request (the single observe
    plane): latency/energy are pair-wide, quality is per-group.  ``group``
    may be omitted when ``true_complexity`` is given — the policy derives
    the group under its own rules.  ``uid`` (optional) names the request
    that produced the measurement — ``EcoreCluster.observe`` uses it to
    fold the observation into the OWNING pod's policy."""
    pair: Pair
    uid: Optional[int] = None
    group: Optional[int] = None
    true_complexity: Optional[int] = None
    time_ms: Optional[float] = None
    energy_mwh: Optional[float] = None
    map_pct: Optional[float] = None
    detected_count: Optional[int] = None     # backend count (OB feedback)

    @property
    def empty(self) -> bool:
        return (self.time_ms is None and self.energy_mwh is None
                and self.map_pct is None and self.detected_count is None)


@runtime_checkable
class RoutingPolicy(Protocol):
    """The one routing surface every face implements."""
    #: True when decide_batch is a single tensorized call whose decisions
    #: are independent of per-request feedback
    batchable: bool

    def decide(self, req: RouteRequest) -> RouteDecision: ...

    def decide_batch(self, reqs: Sequence[RouteRequest]
                     ) -> List[RouteDecision]: ...

    def observe(self, obs: Observation) -> None: ...

    def reset(self) -> None: ...


class DetectionPolicy:
    """Estimator + router + explore/adapt closed loop behind the policy API.

    Subsumes the branchy core of the old ``Gateway.process_stream``: the
    per-request estimate->route scalar path (with the round-robin
    exploration override under ``adapt``), the batched estimate->route fast
    path (one device launch + one XLA routing call for a whole stream), and
    the EWMA observation plumbing for latency, energy and measured mAP."""

    def __init__(self, router: Router, table: ProfileTable,
                 estimator: Optional[Estimator] = None, *,
                 adapt: bool = False, alpha: float = 0.1,
                 explore_every: int = 0, adapt_map: bool = False,
                 batch_routing: bool = True,
                 quarantine_after: Optional[int] = None):
        self.router = router
        self.table = table
        self.estimator = estimator
        self.adapt = adapt
        self.alpha = alpha
        self.explore_every = explore_every
        self.adapt_map = adapt_map
        self.batch_routing = batch_routing
        #: circuit-breaker threshold for the scanned closed loop: after this
        #: many consecutive failed steps on a (group, pair) cell the scan
        #: quarantines it (None = off); half-open probes ride explore_every
        self.quarantine_after = quarantine_after
        self._step = 0
        if adapt and getattr(router, "table", None) is not table:
            raise ValueError(
                "adapt=True requires router.table to BE the policy's table "
                "(same object): observe_pair updates would otherwise never "
                "reach the router's decisions")
        if adapt_map and not adapt:
            raise ValueError("adapt_map=True requires adapt=True")

    @property
    def batchable(self) -> bool:
        """True when a whole stream can be decided in one shot: open loop
        (per-request observations never change later decisions) and both
        estimator and router expose real batched implementations."""
        return (self.batch_routing and not self.adapt
                and self.estimator is not None and self.estimator.batchable
                and self.router.batchable)

    @property
    def scannable(self) -> bool:
        """True when the CLOSED loop can run as one jitted ``lax.scan``
        (``decide_scan``): adapt on, the router's decision rule is the
        tensorized Algorithm-1 argmin (``batchable`` routers), the counts
        are computable up front (batchable/oracle/no estimator — OB's
        feedback counts depend on each frame's served result), and no
        quality feedback (measured mAP depends on which detector served the
        frame, so ``adapt_map`` is decision-dependent and stays scalar)."""
        return (self.batch_routing and self.adapt and not self.adapt_map
                and self.router.batchable
                and (self.estimator is None or self.estimator.batchable
                     or isinstance(self.estimator, OracleEstimator)))

    def _scan_inputs(self, reqs: Sequence[RouteRequest]):
        """(est_counts, routing_counts, gateway_flops) for ``decide_scan``
        — the estimate stage, hoisted out of the loop: one batched device
        launch (or a ground-truth passthrough) for the whole stream."""
        if self.estimator is None:
            est = None
            flops = np.zeros(len(reqs))
        elif isinstance(self.estimator, OracleEstimator):
            est = np.asarray([int(r.true_complexity) for r in reqs])
            flops = np.zeros(len(reqs))
        else:
            images = np.stack([r.payload for r in reqs])
            est, flops = self.estimator.estimate_batch(images)
        if self.router.uses_ground_truth:
            routing = np.asarray([int(r.true_complexity) for r in reqs])
        elif est is None:
            # no estimator: the scalar route sees estimated_count=None -> 0
            routing = np.zeros(len(reqs), np.int32)
        else:
            routing = np.asarray([int(c or 0) for c in est])
        return est, routing, flops

    def decide_scan(self, reqs: Sequence[RouteRequest],
                    measurements: StreamMeasurements
                    ) -> List[RouteDecision]:
        """The closed-loop fast path: decide AND observe a whole stream in
        one jitted ``lax.scan`` over the profile's ``ProfileState``.

        ``measurements`` carries the decision-independent per-step, per-pair
        runtime signals (``closed_loop.StreamMeasurements``, columns in
        ``table.pairs()`` order); each step's routed column is gathered and
        EWMA-folded before the next step decides — the exact scalar
        ``decide``/``observe`` interleaving, compiled.  The final state is
        folded back into the table (``load_state``), so subsequent scalar
        decisions and ``profile_row`` reads see the adapted values.  The
        round-robin exploration schedule (``explore_every``) is precomputed
        — it depends only on the step counter — and honored inside the scan.
        """
        reqs = list(reqs)
        if not self.scannable:
            raise ValueError("decide_scan requires a scannable policy "
                             "(adapt=True, batchable router/estimator, "
                             "no adapt_map)")
        if not reqs:
            return []
        est, routing, flops = self._scan_inputs(reqs)
        arrays = self.table.as_arrays()
        T, E = len(reqs), self.explore_every
        explore = np.full(T, -1, np.int32)
        if E:
            steps = self._step + np.arange(T)
            fire = steps % E == E - 1
            explore[fire] = (steps[fire] // E) % len(arrays.pairs)
        self._step += T
        state, trace = scan_stream(
            arrays.state, routing, measurements, arrays=arrays,
            delta=self.router.delta, alpha=self.alpha,
            group_rules=self.rules, explore_pairs=explore,
            quarantine_after=self.quarantine_after)
        self.table.load_state(state)
        out = []
        for t, req in enumerate(reqs):
            gc = gateway_cost(float(flops[t]))
            out.append(RouteDecision(
                uid=req.uid, pair=arrays.pairs[trace.pair_idx[t]],
                est_complexity=None if est is None else int(est[t]),
                gateway_time_ms=gc["time_ms"],
                gateway_energy_mwh=gc["energy_mwh"],
                explored=bool(trace.explored[t])))
        return out

    @property
    def rules(self):
        return getattr(self.router, "rules", None) or DEFAULT_GROUP_RULES

    def group_for(self, true_count: int) -> int:
        """The group an observation lands in — derived from the TRUE count
        under the ROUTER's rules (custom labels must hit the right row)."""
        return group_of(int(true_count), self.rules)

    def decide(self, req: RouteRequest) -> RouteDecision:
        step, self._step = self._step, self._step + 1
        if self.estimator is not None:
            if isinstance(self.estimator, OracleEstimator):
                self.estimator.true_count = req.true_complexity
            est_count, est_flops = self.estimator.estimate(req.payload)
            gc = gateway_cost(est_flops)
        else:
            est_count = None
            gc = gateway_cost(0.0)  # routing-table lookup only
        pair = self.router.route(estimated_count=est_count,
                                 true_count=req.true_complexity)
        explored = False
        if (self.adapt and self.explore_every
                and step % self.explore_every == self.explore_every - 1):
            pairs = self.table.pairs()
            pair = pairs[(step // self.explore_every) % len(pairs)]
            explored = True
        return RouteDecision(
            uid=req.uid, pair=pair,
            est_complexity=None if est_count is None else int(est_count),
            gateway_time_ms=gc["time_ms"],
            gateway_energy_mwh=gc["energy_mwh"], explored=explored)

    def decide_batch(self, reqs: Sequence[RouteRequest]
                     ) -> List[RouteDecision]:
        """One device launch (``estimate_batch``) + one XLA call
        (``route_batch``) for the whole batch when ``batchable``; the
        generic fallback loops ``decide`` so non-batchable faces (closed
        loop, feedback estimators, stateful routers) expose the same API."""
        reqs = list(reqs)
        if not reqs:
            return []
        if not self.batchable:
            return [self.decide(r) for r in reqs]
        self._step += len(reqs)
        images = np.stack([r.payload for r in reqs])
        counts, flops = self.estimator.estimate_batch(images)
        pairs = self.router.route_batch(
            estimated_counts=counts,
            true_counts=[r.true_complexity for r in reqs])
        out = []
        for req, count, fl, pair in zip(reqs, counts, flops, pairs):
            gc = gateway_cost(float(fl))
            out.append(RouteDecision(
                uid=req.uid, pair=pair, est_complexity=int(count),
                gateway_time_ms=gc["time_ms"],
                gateway_energy_mwh=gc["energy_mwh"]))
        return out

    def observe(self, obs: Observation) -> None:
        """Fold runtime measurements into the profile: latency/energy are
        group-independent (every row of the pair moves), detection quality
        is per-group; a backend-detected count feeds the estimator (OB).

        Non-finite latency/energy (the fault plane's did-not-answer
        sentinel) is NOT evidence about the pair's cost and is dropped here
        — one inf folded into the EWMA would poison the profile forever;
        failures reroute traffic through the resilience/quarantine planes
        instead."""
        if obs.detected_count is not None and self.estimator is not None:
            self.estimator.observe(int(obs.detected_count))
        t_ms = obs.time_ms if (obs.time_ms is None
                               or np.isfinite(obs.time_ms)) else None
        e_mwh = obs.energy_mwh if (obs.energy_mwh is None
                                   or np.isfinite(obs.energy_mwh)) else None
        if t_ms is not None or e_mwh is not None:
            self.table.observe_pair(obs.pair, time_ms=t_ms,
                                    energy_mwh=e_mwh, alpha=self.alpha)
        if obs.map_pct is not None:
            group = obs.group
            if group is None:
                if obs.true_complexity is None:
                    raise ValueError(
                        "map_pct is per-group: pass group= or "
                        "true_complexity= with the measurement")
                group = self.group_for(obs.true_complexity)
            self.table.observe(obs.pair, group, map_pct=obs.map_pct,
                               alpha=self.alpha)

    def reset(self) -> None:
        self._step = 0
        if self.estimator is not None:
            self.estimator.reset()
        self.router.reset()


class PoolPolicy:
    """The LLM serving face behind the policy API: wraps a ``ServingPool``
    (Algorithm 1 over prompt-length buckets).  ``decide_batch`` is the
    tensorized one-XLA-call path; ``observe`` EWMA-folds measured serving
    signals through ``ServingPool.observe``."""

    batchable = True  # decisions depend only on prompt length

    def __init__(self, pool, alpha: float = 0.1):
        self.pool = pool
        self.alpha = alpha

    def _decision(self, req: RouteRequest, d) -> RouteDecision:
        return RouteDecision(uid=req.uid, pair=(d.arch, d.device),
                             group=d.bucket, time_ms=d.time_ms,
                             energy_mwh=d.energy_mwh, score=d.score)

    def decide(self, req: RouteRequest) -> RouteDecision:
        return self._decision(req, self.pool.route(int(req.complexity)))

    def decide_batch(self, reqs: Sequence[RouteRequest]
                     ) -> List[RouteDecision]:
        reqs = list(reqs)
        if not reqs:
            return []
        pool_decisions = self.pool.route_batch(
            [int(r.complexity) for r in reqs])
        return [self._decision(r, d) for r, d in zip(reqs, pool_decisions)]

    def observe(self, obs: Observation) -> None:
        bucket = obs.group
        if bucket is None and obs.true_complexity is not None:
            from repro.serving.pool import bucket_of  # lazy: no import cycle
            bucket = bucket_of(int(obs.true_complexity))
        self.pool.observe(obs.pair[0], time_ms=obs.time_ms,
                          energy_mwh=obs.energy_mwh, map_pct=obs.map_pct,
                          bucket=bucket, alpha=self.alpha)

    def reset(self) -> None:
        pass

"""Profile plane: ProfileState (the canonical device-resident arrays) and
ProfileTable (the Python-facing facade Algorithm 1's scalar faces consume).

Each row profiles one (model, device) pair for one object-count group:
mAP (per group — accuracy depends on scene complexity), inference time and
energy (group-independent in the paper's testbed, replicated per group).

Ownership is inverted relative to the seed: the adaptation plane's state of
record is ``ProfileState`` — an immutable pytree of padded per-group jnp
arrays that PURE functions thread (``observe_state`` EWMA-folds a runtime
measurement and returns a NEW state; ``core.router.decide_state`` is the
jit-safe Algorithm-1 argmin over it; ``core.closed_loop.scan_stream`` runs
the whole estimate->route->observe loop inside one ``lax.scan``).
``ProfileTable`` remains as the compatibility facade every scalar face
(greedy_route, Weighted/Pareto, the serving pool, json io) keeps using:
``as_state()`` exports the pytree, ``load_state()`` folds an updated pytree
back into the entries, and the mutating ``observe``/``observe_pair`` methods
are the scalar mirrors of ``observe_state``.
"""
from __future__ import annotations

import dataclasses
import json
from typing import (Dict, Iterable, List, NamedTuple, Optional, Sequence,
                    Tuple)


@dataclasses.dataclass(frozen=True)
class ProfileEntry:
    model: str
    device: str
    group: int
    map_pct: float       # mean Average Precision in [0, 100]
    time_ms: float       # inference latency
    energy_mwh: float    # energy per request

    @property
    def pair(self) -> Tuple[str, str]:
        return (self.model, self.device)

    @property
    def pair_name(self) -> str:
        return f"{self.model}@{self.device}"


class ProfileState(NamedTuple):
    """The adaptation plane's device-resident state: one [G, P] array per
    profile column, padded to the widest group (pads carry -inf mAP /
    +inf cost, ``valid=False``, ``pair_id=-1``).

    A NamedTuple of jnp arrays is a pytree, so a ProfileState flows through
    ``jax.jit``/``lax.scan`` unchanged: jitted programs THREAD it as a value
    instead of mutating a Python object.  Within a row, entries keep the
    originating table's order, so a masked argmin breaks ties exactly like
    the scalar ``min`` over ``for_group``.  ``pair_id[g, p]`` indexes the
    table's ``pairs()`` list — the mask ``observe_state`` uses to update
    every group row of one (model, device) pair at once.

    Static identity (group labels, entry names, the entry_index map back
    into ``ProfileTable.entries``) lives on the ``ProfileArrays`` snapshot,
    NOT here: state is pure numbers, metadata never enters the jit.

    ``fails`` is the quarantine plane: consecutive failed attempts per
    (group, pair) cell — the circuit-breaker counter ``quarantine_state``
    increments on a failed observation and ``probe_state`` clears on a
    successful half-open probe.  ``decide_state(quarantine_after=K)``
    excludes cells with ``fails >= K`` from the feasible set (breaker OPEN),
    falling back to the unquarantined mask when a whole group would be
    masked out.  All zeros = every breaker CLOSED, decisions identical to a
    state without the field (exact-parity invariant, tested).
    """
    map_pct: object      # jnp [G, P] f32
    time_ms: object      # jnp [G, P] f32
    energy_mwh: object   # jnp [G, P] f32
    valid: object        # jnp [G, P] bool
    pair_id: object      # jnp [G, P] int32; -1 on pads
    fails: object = None  # jnp [G, P] int32 consecutive failures; None = off


def observe_state(state: ProfileState, pair_idx, group_row, *,
                  time_ms=None, energy_mwh=None, map_pct=None,
                  alpha=0.1) -> ProfileState:
    """Pure EWMA fold of one runtime measurement — the jit/scan-safe mirror
    of ``ProfileTable.observe_pair`` + ``observe``.

    Latency/energy are group-independent (the table replicates them per
    group), so they update EVERY row of ``pair_idx``; measured quality is
    per-group, so ``map_pct`` only touches the (``group_row``, pair) cell.
    Any measurement may be None (skipped statically) or NaN (skipped inside
    the jit — the traced no-measurement sentinel ``scan_stream`` relies on).
    """
    import jax
    import jax.numpy as jnp
    pair_mask = state.pair_id == jnp.int32(pair_idx)
    rows = jax.lax.broadcasted_iota(jnp.int32, state.map_pct.shape, 0)
    cell_mask = pair_mask & (rows == jnp.int32(group_row))

    def fold(old, new, mask):
        if new is None:
            return old
        new = jnp.float32(new)
        upd = (1.0 - alpha) * old + alpha * new
        return jnp.where(mask & ~jnp.isnan(new), upd, old)

    return state._replace(
        time_ms=fold(state.time_ms, time_ms, pair_mask),
        energy_mwh=fold(state.energy_mwh, energy_mwh, pair_mask),
        map_pct=fold(state.map_pct, map_pct, cell_mask))


def with_fails(state: ProfileState) -> ProfileState:
    """State with the quarantine counter materialized (all breakers
    CLOSED); identity when ``fails`` is already an array."""
    import jax.numpy as jnp
    if state.fails is not None:
        return state
    return state._replace(fails=jnp.zeros(jnp.shape(state.pair_id),
                                          jnp.int32))


def quarantine_state(state: ProfileState, pair_idx, group_row,
                     failed) -> ProfileState:
    """Pure circuit-breaker fold of ONE attempt outcome at the routed
    (group, pair) cell: a failure increments the cell's consecutive-failure
    count, a success resets it to zero (breaker closes).  ``failed`` may be
    a traced bool — jit/scan-safe, the quarantine twin of
    ``observe_state``."""
    import jax
    import jax.numpy as jnp
    state = with_fails(state)
    pair_mask = state.pair_id == jnp.int32(pair_idx)
    rows = jax.lax.broadcasted_iota(jnp.int32, state.pair_id.shape, 0)
    cell = pair_mask & (rows == jnp.int32(group_row))
    upd = jnp.where(failed, state.fails + 1, jnp.int32(0))
    return state._replace(fails=jnp.where(cell, upd, state.fails))


def probe_state(state: ProfileState, pair_idx, success) -> ProfileState:
    """Pure half-open-probe fold: a SUCCESSFUL probe of ``pair_idx`` closes
    the breaker on EVERY group row of the pair (the device answered — like
    latency/energy, reachability is group-independent evidence); a failed
    probe (``success`` False) is the identity — the per-cell count already
    moved through ``quarantine_state``.  The scanned closed loop applies
    this on its ``explore_every`` steps, which is how an OPEN breaker gets
    its half-open recovery path without leaving the ``lax.scan``."""
    import jax.numpy as jnp
    state = with_fails(state)
    pair_mask = state.pair_id == jnp.int32(pair_idx)
    return state._replace(
        fails=jnp.where(pair_mask & success, jnp.int32(0), state.fails))


def add_pair(state: ProfileState, *, map_pct, time_ms, energy_mwh,
             pair_idx: Optional[int] = None) -> Tuple[ProfileState, int]:
    """Pure fleet-elasticity op: a NEW (model, device) pair joins the
    profile as one appended column on every group row.  Returns the new
    state and the pair's index (default: one past the current maximum).

    Each profile argument is a scalar (replicated across groups) or a
    length-[G] vector (per-group values, e.g. measured mAP).  The column is
    appended LAST, so the masked argmin in ``decide_state`` sees every
    existing cell at the same position with the same tie-break order —
    decisions over the original pairs are bit-identical unless the new pair
    strictly wins.  Host-side (shapes change, so this cannot run under
    jit); its inverse ``retire_pair`` is shape-preserving and jit-safe."""
    import jax.numpy as jnp
    G, _ = jnp.shape(state.pair_id)
    if pair_idx is None:
        # repro-lint: disable=ECO120 -- add_pair is the host-side half of
        # fleet elasticity by contract (shapes change, so it cannot run
        # under jit; retire_pair is the in-scan inverse) — the sync picks
        # the next free index
        pair_idx = int(jnp.max(state.pair_id)) + 1

    def col(v, dtype=jnp.float32):
        return jnp.broadcast_to(jnp.asarray(v, dtype), (G,)).reshape(G, 1)

    new = state._replace(
        map_pct=jnp.concatenate([state.map_pct, col(map_pct)], axis=1),
        time_ms=jnp.concatenate([state.time_ms, col(time_ms)], axis=1),
        energy_mwh=jnp.concatenate([state.energy_mwh, col(energy_mwh)],
                                   axis=1),
        valid=jnp.concatenate([state.valid, jnp.ones((G, 1), bool)], axis=1),
        pair_id=jnp.concatenate(
            [state.pair_id, jnp.full((G, 1), pair_idx, jnp.int32)], axis=1),
        fails=(None if state.fails is None else
               jnp.concatenate([state.fails, jnp.zeros((G, 1), jnp.int32)],
                               axis=1)))
    return new, int(pair_idx)  # repro-lint: disable=ECO120 -- host contract


def retire_pair(state: ProfileState, pair_idx) -> ProfileState:
    """Pure fleet-elasticity op: every cell of ``pair_idx`` becomes a pad
    (-inf mAP, +inf costs, invalid, ``pair_id=-1``, breaker reset) — the
    pair leaves the feasible set of every group without changing any array
    shape, so this is jit/scan-safe and ``pair_idx`` may be traced.
    ``add_pair`` followed by ``retire_pair`` of the same index restores
    decisions bit-identically (the extra column is all pads, which the
    valid mask already ignores)."""
    import jax.numpy as jnp
    gone = state.pair_id == jnp.int32(pair_idx)
    return state._replace(
        map_pct=jnp.where(gone, -jnp.inf, state.map_pct),
        time_ms=jnp.where(gone, jnp.inf, state.time_ms),
        energy_mwh=jnp.where(gone, jnp.inf, state.energy_mwh),
        valid=jnp.where(gone, False, state.valid),
        pair_id=jnp.where(gone, jnp.int32(-1), state.pair_id),
        fails=(None if state.fails is None else
               jnp.where(gone, jnp.int32(0), state.fails)))


@dataclasses.dataclass(frozen=True)
class ProfileArrays:
    """Snapshot view binding a ``ProfileState`` to one table's identity.

    ``state`` holds the numbers; this object holds what jitted code must
    never see: group labels, the ``row_of`` group->row map, ``pairs`` (the
    ``pair_id`` index space, in ``ProfileTable.pairs()`` order),
    ``col_of_pair[g, j]`` (the column of pair j inside group row g; -1 when
    the pair has no row for that group) and ``entry_index[g, p]`` back into
    ``ProfileTable.entries``.

    Snapshot semantics: built for one table ``version`` and cached until an
    ``observe`` bumps it (see ``ProfileTable.as_arrays``).
    """
    groups: Tuple[int, ...]
    row_of: Dict[int, int]
    pairs: Tuple[Tuple[str, str], ...]
    state: ProfileState
    entry_index: object  # np  [G, P] int32
    col_of_pair: object  # np  [G, n_pairs] int32; -1 = pair absent in group
    version: int

    # compat: the seed exposed the columns directly on the snapshot
    @property
    def map_pct(self):
        return self.state.map_pct

    @property
    def energy_mwh(self):
        return self.state.energy_mwh

    @property
    def time_ms(self):
        return self.state.time_ms

    @property
    def valid(self):
        return self.state.valid


class ProfileTable:
    def __init__(self, entries: Iterable[ProfileEntry]):
        self.entries: List[ProfileEntry] = list(entries)
        if not self.entries:
            raise ValueError("empty profiling table")
        #: bumped on every observe()/load_state(); invalidates as_arrays()
        self.version = 0
        self._arrays: Optional[ProfileArrays] = None

    def for_group(self, group: int) -> List[ProfileEntry]:
        return [e for e in self.entries if e.group == group]

    def pairs(self) -> List[Tuple[str, str]]:
        seen, out = set(), []
        for e in self.entries:
            if e.pair not in seen:
                seen.add(e.pair)
                out.append(e.pair)
        return out

    def entry(self, pair: Tuple[str, str], group: int) -> ProfileEntry:
        for e in self.entries:
            if e.pair == pair and e.group == group:
                return e
        raise KeyError((pair, group))

    def mean_map(self, pair: Tuple[str, str]) -> float:
        rows = [e.map_pct for e in self.entries if e.pair == pair]
        return sum(rows) / len(rows)

    def as_arrays(self) -> ProfileArrays:
        """Padded per-group snapshot for the tensorized faces (cached;
        rebuilt lazily after an ``observe``/``load_state`` bumps
        ``version``)."""
        if self._arrays is not None and self._arrays.version == self.version:
            return self._arrays
        import numpy as np
        import jax.numpy as jnp
        groups = sorted({e.group for e in self.entries})
        row_of = {g: i for i, g in enumerate(groups)}
        pairs = tuple(self.pairs())
        pair_col = {p: j for j, p in enumerate(pairs)}
        per_row = [[i for i, e in enumerate(self.entries) if e.group == g]
                   for g in groups]
        G, P = len(groups), max(len(r) for r in per_row)
        map_pct = np.full((G, P), -np.inf, np.float32)
        energy = np.full((G, P), np.inf, np.float32)
        time_ms = np.full((G, P), np.inf, np.float32)
        valid = np.zeros((G, P), bool)
        pair_id = np.full((G, P), -1, np.int32)
        entry_index = np.zeros((G, P), np.int32)
        col_of_pair = np.full((G, len(pairs)), -1, np.int32)
        for r, idxs in enumerate(per_row):
            for p, i in enumerate(idxs):
                e = self.entries[i]
                map_pct[r, p] = e.map_pct
                energy[r, p] = e.energy_mwh
                time_ms[r, p] = e.time_ms
                valid[r, p] = True
                pair_id[r, p] = pair_col[e.pair]
                entry_index[r, p] = i
                col_of_pair[r, pair_col[e.pair]] = p
        state = ProfileState(
            map_pct=jnp.asarray(map_pct), time_ms=jnp.asarray(time_ms),
            energy_mwh=jnp.asarray(energy), valid=jnp.asarray(valid),
            pair_id=jnp.asarray(pair_id),
            fails=jnp.zeros((G, P), jnp.int32))
        self._arrays = ProfileArrays(
            groups=tuple(groups), row_of=row_of, pairs=pairs, state=state,
            entry_index=entry_index, col_of_pair=col_of_pair,
            version=self.version)
        return self._arrays

    # ------------------------------------------------ state plane round trip

    def as_state(self) -> ProfileState:
        """Export the device-resident pytree (see ``as_arrays`` for the
        snapshot carrying its identity metadata)."""
        return self.as_arrays().state

    def load_state(self, state: ProfileState) -> None:
        """Fold a (scan-updated) ``ProfileState`` back into the entries.

        The state must have been derived from THIS table at its current
        version (``as_state`` -> jitted updates -> ``load_state``): the
        cell->entry mapping is the snapshot's ``entry_index``.  Bumps
        ``version`` so every cached view rebuilds from the folded values.
        """
        import numpy as np
        arrays = self.as_arrays()
        if np.asarray(state.valid).shape != arrays.entry_index.shape:
            raise ValueError(
                f"state shape {np.asarray(state.valid).shape} does not match "
                f"this table's layout {arrays.entry_index.shape}; load_state "
                f"expects a state derived from this table's as_state()")
        m = np.asarray(state.map_pct)
        t = np.asarray(state.time_ms)
        e = np.asarray(state.energy_mwh)
        valid = np.asarray(arrays.state.valid)
        for g, p in zip(*np.nonzero(valid)):
            i = int(arrays.entry_index[g, p])
            self.entries[i] = dataclasses.replace(
                self.entries[i], map_pct=float(m[g, p]),
                time_ms=float(t[g, p]), energy_mwh=float(e[g, p]))
        self.version += 1

    def with_state(self, state: ProfileState) -> "ProfileTable":
        """Independent table with ``state``'s values folded in — the
        non-mutating half of the state<->table round trip."""
        out = ProfileTable(self.entries)
        out.load_state(state)
        return out

    # ----------------------------------------------------- dynamic profiling
    def observe(self, pair: Tuple[str, str], group: int, *,
                time_ms: Optional[float] = None,
                energy_mwh: Optional[float] = None,
                map_pct: Optional[float] = None,
                alpha: float = 0.1) -> None:
        """BEYOND-PAPER (paper §6 future work): EWMA-update a profile row
        from runtime observations, so the router tracks drift (thermal
        throttling, background load, battery state).  Scalar mirror of the
        ``map_pct`` leg of ``observe_state``."""
        import dataclasses as _dc
        for i, e in enumerate(self.entries):
            if e.pair == pair and e.group == group:
                upd = {}
                if time_ms is not None:
                    upd["time_ms"] = (1 - alpha) * e.time_ms + alpha * time_ms
                if energy_mwh is not None:
                    upd["energy_mwh"] = ((1 - alpha) * e.energy_mwh
                                         + alpha * energy_mwh)
                if map_pct is not None:
                    upd["map_pct"] = (1 - alpha) * e.map_pct + alpha * map_pct
                self.entries[i] = _dc.replace(e, **upd)
                self.version += 1
                return
        raise KeyError((pair, group))

    def observe_pair(self, pair: Tuple[str, str], *,
                     time_ms: Optional[float] = None,
                     energy_mwh: Optional[float] = None,
                     alpha: float = 0.1) -> None:
        """EWMA-update latency/energy for EVERY group row of ``pair``.

        Latency and energy are group-independent in the profiling model (the
        table replicates them per group), so a runtime measurement taken
        while serving one group is evidence for all of them — updating only
        the observed group's row would leave the others stale and let the
        router keep picking a drifted backend for other groups.  Scalar
        mirror of the latency/energy leg of ``observe_state``."""
        groups = [e.group for e in self.entries if e.pair == pair]
        if not groups:
            raise KeyError(pair)
        for g in groups:
            self.observe(pair, g, time_ms=time_ms, energy_mwh=energy_mwh,
                         alpha=alpha)

    def copy(self) -> "ProfileTable":
        """Independent table with the same (immutable) entries — lets a
        static-profile baseline and a closed-loop run share one offline
        profile without the EWMA updates leaking between them."""
        return ProfileTable(self.entries)

    # ------------------------------------------------------------------ io
    def to_json(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump([dataclasses.asdict(e) for e in self.entries], f,
                      indent=1)

    @classmethod
    def from_json(cls, path: str) -> "ProfileTable":
        with open(path) as f:
            return cls(ProfileEntry(**row) for row in json.load(f))

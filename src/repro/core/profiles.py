"""Offline profiling table: the data structure Algorithm 1 consumes.

Each row profiles one (model, device) pair for one object-count group:
mAP (per group — accuracy depends on scene complexity), inference time and
energy (group-independent in the paper's testbed, replicated per group).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, Iterable, List, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class ProfileEntry:
    model: str
    device: str
    group: int
    map_pct: float       # mean Average Precision in [0, 100]
    time_ms: float       # inference latency
    energy_mwh: float    # energy per request

    @property
    def pair(self) -> Tuple[str, str]:
        return (self.model, self.device)

    @property
    def pair_name(self) -> str:
        return f"{self.model}@{self.device}"


@dataclasses.dataclass(frozen=True)
class ProfileArrays:
    """Array-backed view of a ProfileTable for tensorized routing.

    One row per group, padded to the widest group: within a row, entries
    keep the TABLE's order (so a masked argmin breaks ties exactly like the
    scalar ``min`` over ``for_group``).  Pads carry -inf mAP / +inf cost and
    ``valid=False``.  ``entry_index[g, p]`` maps back into
    ``ProfileTable.entries``; ``row_of`` maps a group label to its row.

    Snapshot semantics: built for one table ``version`` and cached until an
    ``observe`` bumps it (see ``ProfileTable.as_arrays``).
    """
    groups: Tuple[int, ...]
    row_of: Dict[int, int]
    map_pct: object      # jnp [G, P] f32
    energy_mwh: object   # jnp [G, P] f32
    time_ms: object      # jnp [G, P] f32
    valid: object        # jnp [G, P] bool
    entry_index: object  # np  [G, P] int32
    version: int


class ProfileTable:
    def __init__(self, entries: Iterable[ProfileEntry]):
        self.entries: List[ProfileEntry] = list(entries)
        if not self.entries:
            raise ValueError("empty profiling table")
        #: bumped on every observe(); invalidates the as_arrays() cache
        self.version = 0
        self._arrays: Optional[ProfileArrays] = None

    def for_group(self, group: int) -> List[ProfileEntry]:
        return [e for e in self.entries if e.group == group]

    def pairs(self) -> List[Tuple[str, str]]:
        seen, out = set(), []
        for e in self.entries:
            if e.pair not in seen:
                seen.add(e.pair)
                out.append(e.pair)
        return out

    def entry(self, pair: Tuple[str, str], group: int) -> ProfileEntry:
        for e in self.entries:
            if e.pair == pair and e.group == group:
                return e
        raise KeyError((pair, group))

    def mean_map(self, pair: Tuple[str, str]) -> float:
        rows = [e.map_pct for e in self.entries if e.pair == pair]
        return sum(rows) / len(rows)

    def as_arrays(self) -> ProfileArrays:
        """Padded per-group arrays for the tensorized router (cached; rebuilt
        lazily after an ``observe`` bumps ``version``)."""
        if self._arrays is not None and self._arrays.version == self.version:
            return self._arrays
        import numpy as np
        import jax.numpy as jnp
        groups = sorted({e.group for e in self.entries})
        row_of = {g: i for i, g in enumerate(groups)}
        per_row = [[i for i, e in enumerate(self.entries) if e.group == g]
                   for g in groups]
        G, P = len(groups), max(len(r) for r in per_row)
        map_pct = np.full((G, P), -np.inf, np.float32)
        energy = np.full((G, P), np.inf, np.float32)
        time_ms = np.full((G, P), np.inf, np.float32)
        valid = np.zeros((G, P), bool)
        entry_index = np.zeros((G, P), np.int32)
        for r, idxs in enumerate(per_row):
            for p, i in enumerate(idxs):
                e = self.entries[i]
                map_pct[r, p] = e.map_pct
                energy[r, p] = e.energy_mwh
                time_ms[r, p] = e.time_ms
                valid[r, p] = True
                entry_index[r, p] = i
        self._arrays = ProfileArrays(
            groups=tuple(groups), row_of=row_of,
            map_pct=jnp.asarray(map_pct), energy_mwh=jnp.asarray(energy),
            time_ms=jnp.asarray(time_ms), valid=jnp.asarray(valid),
            entry_index=entry_index, version=self.version)
        return self._arrays

    # ----------------------------------------------------- dynamic profiling
    def observe(self, pair: Tuple[str, str], group: int, *,
                time_ms: Optional[float] = None,
                energy_mwh: Optional[float] = None,
                map_pct: Optional[float] = None,
                alpha: float = 0.1) -> None:
        """BEYOND-PAPER (paper §6 future work): EWMA-update a profile row
        from runtime observations, so the router tracks drift (thermal
        throttling, background load, battery state)."""
        import dataclasses as _dc
        for i, e in enumerate(self.entries):
            if e.pair == pair and e.group == group:
                upd = {}
                if time_ms is not None:
                    upd["time_ms"] = (1 - alpha) * e.time_ms + alpha * time_ms
                if energy_mwh is not None:
                    upd["energy_mwh"] = ((1 - alpha) * e.energy_mwh
                                         + alpha * energy_mwh)
                if map_pct is not None:
                    upd["map_pct"] = (1 - alpha) * e.map_pct + alpha * map_pct
                self.entries[i] = _dc.replace(e, **upd)
                self.version += 1
                return
        raise KeyError((pair, group))

    def observe_pair(self, pair: Tuple[str, str], *,
                     time_ms: Optional[float] = None,
                     energy_mwh: Optional[float] = None,
                     alpha: float = 0.1) -> None:
        """EWMA-update latency/energy for EVERY group row of ``pair``.

        Latency and energy are group-independent in the profiling model (the
        table replicates them per group), so a runtime measurement taken
        while serving one group is evidence for all of them — updating only
        the observed group's row would leave the others stale and let the
        router keep picking a drifted backend for other groups."""
        groups = [e.group for e in self.entries if e.pair == pair]
        if not groups:
            raise KeyError(pair)
        for g in groups:
            self.observe(pair, g, time_ms=time_ms, energy_mwh=energy_mwh,
                         alpha=alpha)

    def copy(self) -> "ProfileTable":
        """Independent table with the same (immutable) entries — lets a
        static-profile baseline and a closed-loop run share one offline
        profile without the EWMA updates leaking between them."""
        return ProfileTable(self.entries)

    # ------------------------------------------------------------------ io
    def to_json(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump([dataclasses.asdict(e) for e in self.entries], f,
                      indent=1)

    @classmethod
    def from_json(cls, path: str) -> "ProfileTable":
        with open(path) as f:
            return cls(ProfileEntry(**row) for row in json.load(f))

"""Offline profiling table: the data structure Algorithm 1 consumes.

Each row profiles one (model, device) pair for one object-count group:
mAP (per group — accuracy depends on scene complexity), inference time and
energy (group-independent in the paper's testbed, replicated per group).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, Iterable, List, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class ProfileEntry:
    model: str
    device: str
    group: int
    map_pct: float       # mean Average Precision in [0, 100]
    time_ms: float       # inference latency
    energy_mwh: float    # energy per request

    @property
    def pair(self) -> Tuple[str, str]:
        return (self.model, self.device)

    @property
    def pair_name(self) -> str:
        return f"{self.model}@{self.device}"


class ProfileTable:
    def __init__(self, entries: Iterable[ProfileEntry]):
        self.entries: List[ProfileEntry] = list(entries)
        if not self.entries:
            raise ValueError("empty profiling table")

    def for_group(self, group: int) -> List[ProfileEntry]:
        return [e for e in self.entries if e.group == group]

    def pairs(self) -> List[Tuple[str, str]]:
        seen, out = set(), []
        for e in self.entries:
            if e.pair not in seen:
                seen.add(e.pair)
                out.append(e.pair)
        return out

    def entry(self, pair: Tuple[str, str], group: int) -> ProfileEntry:
        for e in self.entries:
            if e.pair == pair and e.group == group:
                return e
        raise KeyError((pair, group))

    def mean_map(self, pair: Tuple[str, str]) -> float:
        rows = [e.map_pct for e in self.entries if e.pair == pair]
        return sum(rows) / len(rows)

    # ----------------------------------------------------- dynamic profiling
    def observe(self, pair: Tuple[str, str], group: int, *,
                time_ms: Optional[float] = None,
                energy_mwh: Optional[float] = None,
                map_pct: Optional[float] = None,
                alpha: float = 0.1) -> None:
        """BEYOND-PAPER (paper §6 future work): EWMA-update a profile row
        from runtime observations, so the router tracks drift (thermal
        throttling, background load, battery state)."""
        import dataclasses as _dc
        for i, e in enumerate(self.entries):
            if e.pair == pair and e.group == group:
                upd = {}
                if time_ms is not None:
                    upd["time_ms"] = (1 - alpha) * e.time_ms + alpha * time_ms
                if energy_mwh is not None:
                    upd["energy_mwh"] = ((1 - alpha) * e.energy_mwh
                                         + alpha * energy_mwh)
                if map_pct is not None:
                    upd["map_pct"] = (1 - alpha) * e.map_pct + alpha * map_pct
                self.entries[i] = _dc.replace(e, **upd)
                return
        raise KeyError((pair, group))

    def observe_pair(self, pair: Tuple[str, str], *,
                     time_ms: Optional[float] = None,
                     energy_mwh: Optional[float] = None,
                     alpha: float = 0.1) -> None:
        """EWMA-update latency/energy for EVERY group row of ``pair``.

        Latency and energy are group-independent in the profiling model (the
        table replicates them per group), so a runtime measurement taken
        while serving one group is evidence for all of them — updating only
        the observed group's row would leave the others stale and let the
        router keep picking a drifted backend for other groups."""
        groups = [e.group for e in self.entries if e.pair == pair]
        if not groups:
            raise KeyError(pair)
        for g in groups:
            self.observe(pair, g, time_ms=time_ms, energy_mwh=energy_mwh,
                         alpha=alpha)

    def copy(self) -> "ProfileTable":
        """Independent table with the same (immutable) entries — lets a
        static-profile baseline and a closed-loop run share one offline
        profile without the EWMA updates leaking between them."""
        return ProfileTable(self.entries)

    # ------------------------------------------------------------------ io
    def to_json(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump([dataclasses.asdict(e) for e in self.entries], f,
                      indent=1)

    @classmethod
    def from_json(cls, path: str) -> "ProfileTable":
        with open(path) as f:
            return cls(ProfileEntry(**row) for row in json.load(f))

"""Routing algorithms: the paper's greedy Algorithm 1 + all baselines.

Algorithm 1 (faithful):
  1-7   determine group from the (estimated) object count via group rules
  8-9   filter profiling data to that group
  10-11 mAP_max over the group; mAP_min = mAP_max - delta_mAP
  12-13 keep pairs with mAP >= mAP_min (feasible set F)
  14-15 return argmin energy over F

Theorem 3.1: after the threshold filters the problem is a 1-D minimization,
so the greedy argmin-energy pick is globally optimal — property-tested in
tests/test_router.py.
"""
from __future__ import annotations

import random
from typing import List, Optional, Sequence, Tuple

import numpy as np

from .groups import DEFAULT_GROUP_RULES, group_of
from .profiles import ProfileArrays, ProfileEntry, ProfileState, ProfileTable

Pair = Tuple[str, str]


def feasible_set(group: int, profiling_data: ProfileTable,
                 delta_map: float) -> List[ProfileEntry]:
    """Algorithm 1 lines 8-13: the SINGLE implementation of the feasible-set
    computation (group filter -> mAP threshold) that every routing face
    shares — ``greedy_route``, ``WeightedRouter``, ``ParetoRouter``, and the
    serving pool all call this instead of re-inlining the filter."""
    group_data = profiling_data.for_group(group)            # lines 8-9
    if not group_data:
        known = sorted({e.group for e in profiling_data.entries})
        raise ValueError(
            f"no profile rows for group {group} (table covers groups "
            f"{known}); profile every group the router can be asked for")
    max_map = max(e.map_pct for e in group_data)            # line 10
    map_min = max_map - delta_map                           # line 11
    return [e for e in group_data if e.map_pct >= map_min]  # lines 12-13


def feasible_for_count(count: int, profiling_data: ProfileTable,
                       delta_map: float,
                       group_rules: Sequence = DEFAULT_GROUP_RULES
                       ) -> List[ProfileEntry]:
    """Algorithm 1 lines 1-13: group lookup + feasible set."""
    group = group_of(count, group_rules)                    # lines 1-7
    return feasible_set(group, profiling_data, delta_map)


def pareto_front(entries: Sequence[ProfileEntry]) -> List[ProfileEntry]:
    """Entries not dominated in BOTH (energy, time) by another entry."""
    return [e for e in entries
            if not any(o.energy_mwh <= e.energy_mwh and o.time_ms <= e.time_ms
                       and o is not e
                       and (o.energy_mwh < e.energy_mwh
                            or o.time_ms < e.time_ms)
                       for o in entries)]


def greedy_route(number_of_objects: int, profiling_data: ProfileTable,
                 delta_map: float,
                 group_rules: Sequence = DEFAULT_GROUP_RULES) -> ProfileEntry:
    """Algorithm 1, line for line."""
    refined = feasible_for_count(number_of_objects, profiling_data,
                                 delta_map, group_rules)    # lines 1-13
    return min(refined, key=lambda e: e.energy_mwh)         # lines 14-15


def runner_up_route(number_of_objects: int, profiling_data: ProfileTable,
                    delta_map: float, exclude: Sequence[Pair],
                    group_rules: Sequence = DEFAULT_GROUP_RULES
                    ) -> Optional[ProfileEntry]:
    """Algorithm 1's NEXT pick: the argmin-energy entry of the feasible set
    with the ``exclude``d pairs removed — what hedged re-dispatch routes to
    when the primary pick's device fails (``serving.resilience``).  Same
    masked ranking as lines 14-15, so the runner-up of an empty exclusion
    IS the greedy pick; returns None when every feasible pair is excluded
    (nothing left to hedge onto)."""
    excluded = set(exclude)
    refined = [e for e in feasible_for_count(number_of_objects,
                                             profiling_data, delta_map,
                                             group_rules)
               if e.pair not in excluded]
    return min(refined, key=lambda e: e.energy_mwh) if refined else None


# ------------------------------------------------------- tensorized routing

def decide_state(state: ProfileState, count, delta, lo, hi, rule_rows,
                 quarantine_after=None):
    """Algorithm 1 for ONE count against a ``ProfileState`` — pure and
    jit/scan-safe, the routing step ``core.closed_loop.scan_stream`` folds
    into its ``lax.scan`` body (and, vmapped, the whole ``route_batch``
    kernel).

    ``lo``/``hi``/``rule_rows`` are the group rules in array form (see
    ``rules_arrays``).  Returns ``(group_row, col, ok)``: the state row the
    count landed in (-1 = unprofiled group), the masked-argmin column
    (lines 14-15; ties break like the scalar ``min`` because rows keep
    table order), and whether the feasible set was non-empty.

    ``quarantine_after`` (static; None = off) is the circuit-breaker
    threshold: cells whose ``state.fails`` count has reached it (breaker
    OPEN) are excluded from both the mAP_max scan and the feasible set —
    a dead device must stop receiving traffic IMMEDIATELY, not after the
    EWMA drifts.  The breaker fails OPEN-loop-safe: when every pair of the
    group is quarantined, the unquarantined mask is restored (serving the
    least-bad pair beats serving nobody).  With all-zero ``fails`` the
    decision is bit-identical to the unquarantined path (parity-tested).
    """
    import jax.numpy as jnp
    m = (count >= lo) & (count <= hi)                       # lines 1-7
    rule = jnp.where(m.any(), jnp.argmax(m), lo.shape[0] - 1)
    g = rule_rows[rule]                                     # lines 8-9
    g_safe = jnp.maximum(g, 0)
    gm = state.map_pct[g_safe]                              # [P]
    v = state.valid[g_safe]
    if quarantine_after is not None:
        qv = v & (state.fails[g_safe] < jnp.int32(quarantine_after))
        v = jnp.where(qv.any(), qv, v)      # fail open, never route to void
        max_map = jnp.max(jnp.where(v, gm, -jnp.inf))       # line 10
    else:
        max_map = jnp.max(gm)               # line 10 (pads already -inf)
    feasible = v & (gm >= max_map - delta)                  # lines 11-13
    e = jnp.where(feasible, state.energy_mwh[g_safe], jnp.inf)
    col = jnp.argmin(e)                                     # lines 14-15
    return g, col, feasible.any()


def rules_arrays(group_rules: Sequence, row_of) -> Tuple[np.ndarray, ...]:
    """Group rules as (lo, hi, rule_rows) int32 arrays for the jitted faces
    (``decide_state``, ``route_batch``, ``scan_stream``)."""
    lo = np.asarray([r[0] for r in group_rules], np.int32)
    hi = np.asarray([r[1] if r[1] is not None else np.iinfo(np.int32).max
                     for r in group_rules], np.int32)
    rule_rows = np.asarray([row_of.get(label, -1)
                            for _, _, label in group_rules], np.int32)
    return lo, hi, rule_rows


def _route_batch_jit():
    """Build (once) the jitted Algorithm-1-over-state kernel: one
    ``decide_state`` vmapped over the batch — one XLA call for the whole
    batch instead of B Python loops."""
    import jax

    @jax.jit
    def kernel(state, counts, lo, hi, rule_rows, delta):
        return jax.vmap(
            lambda c: decide_state(state, c, delta, lo, hi, rule_rows)
        )(counts)

    return kernel


_route_batch_kernel = None


def route_batch(counts, profiling_data, delta_map: float,
                group_rules: Sequence = DEFAULT_GROUP_RULES) -> np.ndarray:
    """Algorithm 1 lines 1-15 over a whole batch of counts in one XLA call.

    ``profiling_data`` is either a ``ProfileTable`` or a ``ProfileArrays``
    snapshot (the state face): both resolve to the same ``ProfileState``
    the kernel consumes.  Returns indices into the table's ``entries`` —
    one per count, exactly the entry scalar ``greedy_route`` would pick
    (ties break identically: state rows keep table order and argmin takes
    the first minimum; property-tested in tests/test_batched_routing.py).
    The comparisons run in f32, so mAP/energy values that only differ
    beyond f32 precision could in principle diverge from the float64 scalar
    path — real profiles are far coarser than that.

    Raises the same ``ValueError`` as the scalar path when any count lands
    in an unprofiled group.
    """
    import jax.numpy as jnp
    global _route_batch_kernel
    if _route_batch_kernel is None:
        _route_batch_kernel = _route_batch_jit()
    arrays = (profiling_data if isinstance(profiling_data, ProfileArrays)
              else profiling_data.as_arrays())
    lo, hi, rule_rows = rules_arrays(group_rules, arrays.row_of)
    counts = np.asarray(counts, np.int32)
    g, pick, ok = _route_batch_kernel(
        arrays.state, jnp.asarray(counts), jnp.asarray(lo), jnp.asarray(hi),
        jnp.asarray(rule_rows), jnp.float32(delta_map))
    g, pick, ok = np.asarray(g), np.asarray(pick), np.asarray(ok)
    if (bad := ~(ok & (g >= 0))).any():
        group = group_of(int(counts[np.argmax(bad)]), group_rules)
        known = sorted(arrays.groups)
        raise ValueError(
            f"no profile rows for group {group} (table covers groups "
            f"{known}); profile every group the router can be asked for")
    return arrays.entry_index[g, pick]


class Router:
    """Base: given request metadata, pick a (model, device) pair."""
    name = "base"
    #: True if the router consumes an object-count estimate
    uses_estimate = False
    #: True if the router consumes the ground-truth count (oracle-class)
    uses_ground_truth = False
    #: True if route_batch is a single tensorized call (stateless routers
    #: whose per-frame decision depends only on the count)
    batchable = False

    def __init__(self, table: ProfileTable, delta_map: float = 5.0,
                 group_rules: Sequence = DEFAULT_GROUP_RULES):
        self.table = table
        self.delta = delta_map
        self.rules = group_rules

    def route(self, *, estimated_count: Optional[int] = None,
              true_count: Optional[int] = None) -> Pair:
        raise NotImplementedError

    def route_batch(self, *, estimated_counts=None,
                    true_counts=None) -> List[Pair]:
        """Route a whole batch.  Tensorized (one XLA call) for ``batchable``
        routers; the generic fallback loops ``route`` so every router face
        exposes the same API."""
        n = len(estimated_counts if estimated_counts is not None
                else true_counts)
        est = ([None] * n if estimated_counts is None
               else list(estimated_counts))
        true = [None] * n if true_counts is None else list(true_counts)
        return [self.route(estimated_count=e, true_count=t)
                for e, t in zip(est, true)]

    def _route_batch_greedy(self, counts) -> List[Pair]:
        idx = route_batch(counts, self.table, self.delta, self.rules)
        entries = self.table.entries
        return [entries[i].pair for i in idx]

    def reset(self):
        pass


class GreedyEstimateRouter(Router):
    """The ECORE router: Algorithm 1 over an ESTIMATED count (ED/SF/OB feed
    this; the estimator lives in the gateway)."""
    name = "greedy"
    uses_estimate = True
    batchable = True

    def route(self, *, estimated_count=None, true_count=None) -> Pair:
        return greedy_route(int(estimated_count or 0), self.table, self.delta,
                            self.rules).pair

    def route_batch(self, *, estimated_counts=None, true_counts=None):
        counts = [int(c or 0) for c in estimated_counts]
        return self._route_batch_greedy(counts)


class OracleRouter(Router):
    """Orc: Algorithm 1 with perfect knowledge of the object count."""
    name = "Orc"
    uses_ground_truth = True
    batchable = True

    def route(self, *, estimated_count=None, true_count=None) -> Pair:
        return greedy_route(int(true_count), self.table, self.delta,
                            self.rules).pair

    def route_batch(self, *, estimated_counts=None, true_counts=None):
        return self._route_batch_greedy([int(c) for c in true_counts])


class RoundRobinRouter(Router):
    name = "RR"

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self._i = 0
        self._pairs = self.table.pairs()

    def route(self, **_) -> Pair:
        p = self._pairs[self._i % len(self._pairs)]
        self._i += 1
        return p

    def reset(self):
        self._i = 0


class RandomRouter(Router):
    name = "Rnd"

    def __init__(self, *a, seed: int = 0, **kw):
        super().__init__(*a, **kw)
        self._seed = seed
        self._rng = random.Random(seed)
        self._pairs = self.table.pairs()

    def route(self, **_) -> Pair:
        return self._rng.choice(self._pairs)

    def reset(self):
        # reseed so back-to-back episodes with one router are reproducible
        self._rng = random.Random(self._seed)


class LowestEnergyRouter(Router):
    name = "LE"

    def route(self, **_) -> Pair:
        return min(self.table.entries, key=lambda e: e.energy_mwh).pair


class LowestInferenceRouter(Router):
    name = "LI"

    def route(self, **_) -> Pair:
        return min(self.table.entries, key=lambda e: e.time_ms).pair


class HighestMAPRouter(Router):
    """HM: highest overall mAP, independent of object count."""
    name = "HM"

    def route(self, **_) -> Pair:
        return max(self.table.pairs(), key=self.table.mean_map)


class HighestMAPPerGroupRouter(Router):
    """HMG: best mAP within the (true) object-count group; the paper's
    accuracy upper bound."""
    name = "HMG"
    uses_ground_truth = True

    def route(self, *, estimated_count=None, true_count=None) -> Pair:
        group = group_of(int(true_count), self.rules)
        return max(self.table.for_group(group), key=lambda e: e.map_pct).pair


class WeightedRouter(Router):
    """BEYOND-PAPER (the paper's §6 future work): multi-objective greedy —
    min  w_e * energy/energy_max + w_t * time/time_max
    s.t. group match and mAP >= mAP_max - delta.

    Setting (w_e, w_t) = (1, 0) recovers Algorithm 1 exactly; Theorem 3.1's
    argument still applies because the filtered selection remains a 1-D
    minimization of a fixed scalar score."""
    name = "Wgt"
    uses_estimate = True
    # honest capability flag: the normalizers are recomputed per call from a
    # possibly-mutated table, so batching goes through the generic
    # route-per-item fallback (parity-tested in tests/test_batched_routing)
    batchable = False

    def __init__(self, table: ProfileTable, delta_map: float = 5.0,
                 group_rules: Sequence = DEFAULT_GROUP_RULES,
                 w_energy: float = 0.5, w_time: float = 0.5):
        super().__init__(table, delta_map, group_rules)
        self.w_energy, self.w_time = w_energy, w_time

    def route(self, *, estimated_count=None, true_count=None) -> Pair:
        feasible = feasible_for_count(int(estimated_count or 0), self.table,
                                      self.delta, self.rules)
        # normalizers recomputed per call: closed-loop observe() mutates the
        # table, and stale maxes would silently rebalance the weights
        e_max = max(e.energy_mwh for e in self.table.entries)
        t_max = max(e.time_ms for e in self.table.entries)
        score = lambda e: (self.w_energy * e.energy_mwh / e_max
                           + self.w_time * e.time_ms / t_max)
        return min(feasible, key=score).pair


class ParetoRouter(Router):
    """BEYOND-PAPER: restrict the feasible set to its (energy, time) Pareto
    front before the greedy pick — never selects a pair dominated in both
    objectives."""
    name = "Par"
    uses_estimate = True
    # honest capability flag: the Pareto-front filter is not tensorized, so
    # batching goes through the generic route-per-item fallback
    batchable = False

    def route(self, *, estimated_count=None, true_count=None) -> Pair:
        feasible = feasible_for_count(int(estimated_count or 0), self.table,
                                      self.delta, self.rules)
        front = pareto_front(feasible)
        return min(front, key=lambda e: e.energy_mwh).pair


BASELINE_ROUTERS = (OracleRouter, RoundRobinRouter, RandomRouter,
                    LowestEnergyRouter, LowestInferenceRouter,
                    HighestMAPRouter, HighestMAPPerGroupRouter)

"""Synthetic LM data pipeline.

Deterministic on-the-fly token streams (Zipf-distributed vocabulary with a
Markov bigram structure so the loss actually decreases during training), plus
stub modality frontends: patch/frame embeddings for the VLM/audio archs per
the assignment carve-out.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.base import ModelConfig


@dataclasses.dataclass
class DataConfig:
    seq_len: int = 512
    batch_size: int = 8
    seed: int = 0


def _zipf_probs(vocab: int, alpha: float = 1.1) -> np.ndarray:
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    p = ranks ** -alpha
    return (p / p.sum()).astype(np.float64)


class TokenStream:
    """Markov-bigram synthetic corpus: learnable structure for smoke training."""

    def __init__(self, cfg: ModelConfig, data: DataConfig):
        self.cfg, self.data = cfg, data
        self.rng = np.random.default_rng(data.seed)
        v = min(cfg.vocab_size, 4096)  # active vocabulary slice
        self.v = v
        self.base = _zipf_probs(v)
        # each token biases the next toward a fixed random successor set
        self.succ = self.rng.integers(0, v, size=(v, 4))

    def _sample_seq(self, length: int) -> np.ndarray:
        out = np.empty(length, np.int32)
        tok = int(self.rng.choice(self.v, p=self.base))
        for i in range(length):
            out[i] = tok
            if self.rng.random() < 0.7:
                tok = int(self.succ[tok, self.rng.integers(0, 4)])
            else:
                tok = int(self.rng.choice(self.v, p=self.base))
        return out

    def batches(self) -> Iterator[Dict[str, jax.Array]]:
        s, b = self.data.seq_len, self.data.batch_size
        while True:
            arr = np.stack([self._sample_seq(s + 1) for _ in range(b)])
            batch = {
                "tokens": jnp.asarray(arr[:, :-1]),
                "labels": jnp.asarray(arr[:, 1:]),
            }
            extra = modality_inputs(self.cfg, b, self.rng)
            batch.update(extra)
            yield batch


def modality_inputs(cfg: ModelConfig, batch: int, rng) -> Dict[str, jax.Array]:
    """Stub frontend outputs (assignment carve-out: no ViT/conv codec)."""
    if cfg.family == "vlm" and cfg.num_prefix_embeds:
        return {"prefix_embeds": jnp.asarray(
            rng.standard_normal((batch, cfg.num_prefix_embeds, cfg.vision_dim),
                                dtype=np.float32))}
    if cfg.family == "encdec":
        return {"prefix_embeds": jnp.asarray(
            rng.standard_normal((batch, cfg.enc_seq, cfg.vision_dim),
                                dtype=np.float32))}
    return {}

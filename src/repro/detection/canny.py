"""Canny edge detection + connected-component object counting (ED estimator).

Pipeline (paper §3.3 approach 1): gaussian blur -> Sobel gradients (Pallas
kernel on TPU, jnp oracle on CPU) -> direction-quantized non-maximum
suppression -> double-threshold hysteresis -> connected components of the
dilated edge map, filtered by size, as the object-count estimate.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.sobel.ops import sobel_grad


def gaussian_blur(img, sigma: float = 1.0):
    """Separable 5-tap gaussian, batch [B,H,W]."""
    r = 2
    xs = jnp.arange(-r, r + 1)
    k = jnp.exp(-0.5 * (xs / sigma) ** 2)
    k = k / k.sum()
    pad = jnp.pad(img, ((0, 0), (0, 0), (r, r)), mode="edge")
    h = sum(pad[:, :, i:i + img.shape[2]] * k[i] for i in range(2 * r + 1))
    padv = jnp.pad(h, ((0, 0), (r, r), (0, 0)), mode="edge")
    return sum(padv[:, i:i + img.shape[1], :] * k[i]
               for i in range(2 * r + 1))


@jax.jit
def _canny_map(img, lo: float = 0.6, hi: float = 1.0):
    """img [B,H,W] -> edge map [B,H,W] bool (jit-compiled gateway stage)."""
    sm = gaussian_blur(img)
    mag, q = sobel_grad(sm)
    # non-maximum suppression along quantized direction
    p = jnp.pad(mag, ((0, 0), (1, 1), (1, 1)))
    h, w = img.shape[1], img.shape[2]
    c = p[:, 1:h + 1, 1:w + 1]
    neigh = [
        (p[:, 1:h + 1, 2:], p[:, 1:h + 1, :w]),        # 0: E/W
        (p[:, 2:, 2:], p[:, :h, :w]),                  # 1: SE/NW
        (p[:, 2:, 1:w + 1], p[:, :h, 1:w + 1]),        # 2: S/N
        (p[:, 2:, :w], p[:, :h, 2:]),                  # 3: SW/NE
    ]
    keep = jnp.zeros_like(c, bool)
    for d, (a, b2) in enumerate(neigh):
        m = (q == d) & (c >= a) & (c >= b2)
        keep = keep | m
    thin = mag * keep
    strong = thin > hi
    weak = thin > lo
    # hysteresis: grow strong into weak (fixed-iteration dilation)
    def grow(s, _):
        sp = jnp.pad(s, ((0, 0), (1, 1), (1, 1)))
        dil = (sp[:, :h, 1:w + 1] | sp[:, 2:, 1:w + 1] | sp[:, 1:h + 1, :w]
               | sp[:, 1:h + 1, 2:] | sp[:, :h, :w] | sp[:, :h, 2:]
               | sp[:, 2:, :w] | sp[:, 2:, 2:] | s)
        return dil & weak, None
    strong, _ = jax.lax.scan(grow, strong, None, length=8)
    return strong


def _label_count(edge: np.ndarray, min_size: int = 20,
                 dilate: int = 0) -> int:
    """Connected components (8-conn) of the dilated edge map, size-filtered."""
    e = edge.copy()
    for _ in range(dilate):
        p = np.pad(e, 1)
        e = (p[:-2, 1:-1] | p[2:, 1:-1] | p[1:-1, :-2] | p[1:-1, 2:]
             | p[:-2, :-2] | p[:-2, 2:] | p[2:, :-2] | p[2:, 2:] | e)
    h, w = e.shape
    seen = np.zeros_like(e, bool)
    count = 0
    for y in range(h):
        for x in range(w):
            if not e[y, x] or seen[y, x]:
                continue
            # BFS
            stack = [(y, x)]
            seen[y, x] = True
            size = 0
            while stack:
                cy, cx = stack.pop()
                size += 1
                for dy in (-1, 0, 1):
                    for dx in (-1, 0, 1):
                        ny, nx = cy + dy, cx + dx
                        if 0 <= ny < h and 0 <= nx < w and e[ny, nx] \
                                and not seen[ny, nx]:
                            seen[ny, nx] = True
                            stack.append((ny, nx))
            if size >= min_size:
                count += 1
    return count


def canny_count(img: np.ndarray) -> int:
    """Estimate the number of objects in one [H, W] image."""
    edge = np.asarray(_canny_map(jnp.asarray(img)[None]))[0]
    return _label_count(edge)


def canny_count_batch(imgs: np.ndarray) -> np.ndarray:
    edges = np.asarray(_canny_map(jnp.asarray(imgs)))
    return np.asarray([_label_count(e) for e in edges])

"""Canny edge detection + connected-component object counting (ED estimator).

Pipeline (paper §3.3 approach 1): gaussian blur -> Sobel gradients ->
direction-quantized non-maximum suppression -> double-threshold hysteresis ->
connected components of the dilated edge map, filtered by size, as the
object-count estimate.

The edge-map stage is the gateway's per-frame hot path and lives in
``repro.kernels.canny_fused``: one fused Pallas megakernel launch on TPU
(no intermediate map ever round-trips to HBM; only the bool edge map is
written), the bit-identical jnp oracle everywhere else.  This module adds
the (host-side) component counting on top.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.kernels.canny_fused.ops import canny_edge, canny_edge_batch
from repro.kernels.canny_fused.ref import gaussian_blur  # noqa: F401  (re-export)


def _canny_map(img, lo: float = 0.6, hi: float = 1.0):
    """img [B,H,W] -> edge map [B,H,W] bool (fused gateway stage)."""
    return canny_edge(img, lo, hi)


def _label_count(edge: np.ndarray, min_size: int = 20,
                 dilate: int = 0) -> int:
    """Connected components (8-conn) of the dilated edge map, size-filtered."""
    e = edge.copy()
    for _ in range(dilate):
        p = np.pad(e, 1)
        e = (p[:-2, 1:-1] | p[2:, 1:-1] | p[1:-1, :-2] | p[1:-1, 2:]
             | p[:-2, :-2] | p[:-2, 2:] | p[2:, :-2] | p[2:, 2:] | e)
    h, w = e.shape
    seen = np.zeros_like(e, bool)
    count = 0
    for y in range(h):
        for x in range(w):
            if not e[y, x] or seen[y, x]:
                continue
            # BFS
            stack = [(y, x)]
            seen[y, x] = True
            size = 0
            while stack:
                cy, cx = stack.pop()
                size += 1
                for dy in (-1, 0, 1):
                    for dx in (-1, 0, 1):
                        ny, nx = cy + dy, cx + dx
                        if 0 <= ny < h and 0 <= nx < w and e[ny, nx] \
                                and not seen[ny, nx]:
                            seen[ny, nx] = True
                            stack.append((ny, nx))
            if size >= min_size:
                count += 1
    return count


def canny_count(img: np.ndarray) -> int:
    """Estimate the number of objects in one [H, W] image."""
    edge = np.asarray(_canny_map(jnp.asarray(img)[None]))[0]
    return _label_count(edge)


def canny_count_batch(imgs) -> np.ndarray:
    """Estimate object counts for a whole batch: edge maps first (as few
    kernel launches as the frame shapes allow), then per-image component
    counting.

    Accepts a uniform [B, H, W] ndarray (ONE launch, unchanged fast path)
    or a sequence of [H, W] frames of mixed sizes, which is routed through
    the ragged pad-and-mask bucket path (one launch per size bucket)."""
    if getattr(imgs, "ndim", None) == 3:
        edges = np.asarray(_canny_map(jnp.asarray(imgs)))
    else:
        edges = canny_edge_batch(imgs)
    return np.asarray([_label_count(e) for e in edges])

"""The 8-model detector family (YOLO/SSD/EfficientDet capacity analogs).

Single-scale grid detectors in pure JAX: conv backbone (stride-2 stages) to
an 8x8 grid over the 64x64 scene, head predicting per cell
[objectness, dx, dy, log w, log h, class logits].  Variants differ in width
and depth exactly like the paper's nano/small/medium families, producing the
Fig. 2 accuracy-vs-complexity crossover after real training.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.detection.scenes import IMG, NUM_CLASSES

GRID = 8
CELL = IMG // GRID


@dataclasses.dataclass(frozen=True)
class DetectorConfig:
    name: str
    channels: Tuple[int, ...]     # per stage (each stage: conv3x3 s1 + s2)
    head_channels: int

    @property
    def flops(self) -> float:
        """Analytic MACs*2 per image (for the device energy model)."""
        total, res, cin = 0.0, IMG, 1
        for c in self.channels:
            total += 2 * res * res * 9 * cin * c          # 3x3 s1
            total += 2 * (res // 2) ** 2 * 9 * c * c      # 3x3 s2
            res //= 2
            cin = c
        total += 2 * GRID * GRID * 9 * cin * self.head_channels
        total += 2 * GRID * GRID * self.head_channels * (5 + NUM_CLASSES)
        return total


# capacity ladder ~ paper's 8 models (SSDv1 ... YOLOv8m)
DETECTOR_CONFIGS: Dict[str, DetectorConfig] = {
    "ssd_v1":       DetectorConfig("ssd_v1", (4, 8, 8), 16),
    "ssd_lite":     DetectorConfig("ssd_lite", (6, 12, 12), 24),
    "effdet_lite0": DetectorConfig("effdet_lite0", (8, 16, 16), 32),
    "effdet_lite1": DetectorConfig("effdet_lite1", (12, 24, 24), 48),
    "effdet_lite2": DetectorConfig("effdet_lite2", (16, 32, 32), 64),
    "yolov8_n":     DetectorConfig("yolov8_n", (16, 32, 64), 96),
    "yolov8_s":     DetectorConfig("yolov8_s", (24, 48, 96), 128),
    "yolov8_m":     DetectorConfig("yolov8_m", (32, 64, 128), 192),
}

OUT_PER_CELL = 5 + NUM_CLASSES


def _conv_init(key, kh, kw, cin, cout):
    std = 1.0 / math.sqrt(kh * kw * cin)
    return jax.random.truncated_normal(key, -2, 2, (kh, kw, cin, cout)) * std


def init_detector(cfg: DetectorConfig, key) -> Dict:
    params = {"convs": [], "head": {}}
    cin = 1
    for i, c in enumerate(cfg.channels):
        k1, k2, key = jax.random.split(key, 3)
        params["convs"].append({
            "w1": _conv_init(k1, 3, 3, cin, c), "b1": jnp.zeros((c,)),
            "w2": _conv_init(k2, 3, 3, c, c), "b2": jnp.zeros((c,)),
        })
        cin = c
    k1, k2, key = jax.random.split(key, 3)
    params["head"] = {
        "w1": _conv_init(k1, 3, 3, cin, cfg.head_channels),
        "b1": jnp.zeros((cfg.head_channels,)),
        "w2": _conv_init(k2, 1, 1, cfg.head_channels, OUT_PER_CELL),
        "b2": jnp.zeros((OUT_PER_CELL,)),
    }
    return params


def _conv(x, w, b, stride=1):
    out = jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return out + b[None, None, None]


def detector_forward(params, x):
    """x [B, IMG, IMG, 1] -> raw head [B, GRID, GRID, 5+C]."""
    h = x
    for st in params["convs"]:
        h = jax.nn.relu(_conv(h, st["w1"], st["b1"], 1))
        h = jax.nn.relu(_conv(h, st["w2"], st["b2"], 2))
    h = jax.nn.relu(_conv(h, params["head"]["w1"], params["head"]["b1"], 1))
    return _conv(h, params["head"]["w2"], params["head"]["b2"], 1)


# ------------------------------------------------------------- target/loss


def encode_targets(boxes: np.ndarray, classes: np.ndarray):
    """GT -> grid targets: obj [G,G], box [G,G,4] (dx,dy,logw,logh), cls [G,G]."""
    obj = np.zeros((GRID, GRID), np.float32)
    box = np.zeros((GRID, GRID, 4), np.float32)
    cls = np.zeros((GRID, GRID), np.int32)
    for b, c in zip(boxes.reshape(-1, 4), classes.reshape(-1)):
        cx, cy = (b[0] + b[2]) / 2, (b[1] + b[3]) / 2
        gx, gy = min(int(cx // CELL), GRID - 1), min(int(cy // CELL), GRID - 1)
        obj[gy, gx] = 1.0
        box[gy, gx] = [cx / CELL - gx, cy / CELL - gy,
                       math.log(max(b[2] - b[0], 1) / CELL),
                       math.log(max(b[3] - b[1], 1) / CELL)]
        cls[gy, gx] = c
    return obj, box, cls


def detection_loss(params, batch):
    """batch: imgs [B,H,W,1], obj [B,G,G], box [B,G,G,4], cls [B,G,G]."""
    raw = detector_forward(params, batch["image"])
    obj_logit = raw[..., 0]
    box_pred = raw[..., 1:5]
    cls_logit = raw[..., 5:]
    obj = batch["obj"]
    # objectness BCE (balanced)
    bce = jnp.maximum(obj_logit, 0) - obj_logit * obj + jnp.log1p(
        jnp.exp(-jnp.abs(obj_logit)))
    w = obj * 4.0 + (1 - obj)
    loss_obj = jnp.sum(bce * w) / jnp.sum(w)
    # box l2 + class CE on positive cells
    pos = obj[..., None]
    loss_box = jnp.sum(jnp.square(box_pred - batch["box"]) * pos) / (
        jnp.sum(pos) * 4 + 1e-6)
    logp = jax.nn.log_softmax(cls_logit, axis=-1)
    gold = jnp.take_along_axis(logp, batch["cls"][..., None], axis=-1)[..., 0]
    loss_cls = -jnp.sum(gold * obj) / (jnp.sum(obj) + 1e-6)
    return loss_obj + 2.0 * loss_box + loss_cls


# ------------------------------------------------------------------ decode


def decode_detections(raw: np.ndarray, score_thr: float = 0.5,
                      nms_iou: float = 0.45):
    """raw [G,G,5+C] -> (boxes [N,4], scores [N], classes [N])."""
    raw = np.asarray(raw)
    obj = 1 / (1 + np.exp(-raw[..., 0]))
    boxes, scores, classes = [], [], []
    for gy in range(GRID):
        for gx in range(GRID):
            if obj[gy, gx] < score_thr:
                continue
            dx, dy, lw, lh = raw[gy, gx, 1:5]
            cx, cy = (gx + float(dx)) * CELL, (gy + float(dy)) * CELL
            w = math.exp(min(float(lw), 3.0)) * CELL
            h = math.exp(min(float(lh), 3.0)) * CELL
            boxes.append([cx - w / 2, cy - h / 2, cx + w / 2, cy + h / 2])
            scores.append(float(obj[gy, gx]))
            classes.append(int(np.argmax(raw[gy, gx, 5:])))
    if not boxes:
        return (np.zeros((0, 4), np.float32), np.zeros((0,), np.float32),
                np.zeros((0,), np.int32))
    boxes = np.asarray(boxes, np.float32)
    scores = np.asarray(scores, np.float32)
    classes = np.asarray(classes, np.int32)
    # simple class-agnostic NMS
    keep = []
    order = np.argsort(-scores)
    from repro.core.metrics import iou as _iou
    for i in order:
        if all(_iou(boxes[i], boxes[j]) < nms_iou for j in keep):
            keep.append(i)
    keep = np.asarray(keep, int)
    return boxes[keep], scores[keep], classes[keep]

"""Edge-device energy/latency models + the paper's testbed construction.

Each device is parameterized by (effective GFLOP/s for small convnets,
active power W, fixed per-request overhead ms).  The constants are chosen to
reproduce the ORDERING in the paper's Table 1 / Fig. 5 (Jetson Orin Nano =
lowest energy; Pi5+Coral TPU = lowest latency; accelerators fast but
power-hungry relative to their speed on small models; plain Pis slow).
Absolute numbers are representative; every paper-claim validation in
EXPERIMENTS.md is a ratio, which is insensitive to the absolute scale
(DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple


@dataclasses.dataclass(frozen=True)
class EdgeDevice:
    name: str
    gflops: float       # sustained for small conv nets
    watts: float        # active power above idle
    overhead_ms: float  # request handling / runtime dispatch

    def time_ms(self, flops: float) -> float:
        return flops / (self.gflops * 1e9) * 1e3 + self.overhead_ms

    def energy_mwh(self, flops: float) -> float:
        hours = self.time_ms(flops) / 1e3 / 3600.0
        return self.watts * hours * 1e3  # W * h * 1000 = mWh


DEVICES: Dict[str, EdgeDevice] = {
    "pi3":        EdgeDevice("pi3", 1.2, 3.2, 9.0),
    "pi3_tpu":    EdgeDevice("pi3_tpu", 16.0, 5.4, 6.0),
    "pi4":        EdgeDevice("pi4", 2.8, 4.2, 6.0),
    "pi4_tpu":    EdgeDevice("pi4_tpu", 22.0, 6.4, 4.0),
    "pi5":        EdgeDevice("pi5", 6.5, 5.6, 3.5),
    "pi5_tpu":    EdgeDevice("pi5_tpu", 32.0, 7.8, 1.2),  # lowest latency
    "pi5_aihat":  EdgeDevice("pi5_aihat", 26.0, 7.2, 2.0),
    "orin_nano":  EdgeDevice("orin_nano", 40.0, 6.8, 2.6),  # lowest energy
}

# The paper's finalized testbed (Table 1) pairs — each strong in >=1 metric.
# We profile ALL (8 models x 8 devices) = 64 pairs for the Fig. 5 Pareto
# analog, then select this subset for routing experiments.
TESTBED_PAIRS: Tuple[Tuple[str, str], ...] = (
    ("ssd_v1", "orin_nano"),     # lowest energy        (Table 1 row 1)
    ("ssd_v1", "pi5_tpu"),       # lowest latency       (row 2)
    ("ssd_lite", "pi5"),         # mAP group 2          (row 4)
    ("yolov8_s", "orin_nano"),   # mAP group 3          (row 5)
    ("yolov8_s", "pi5_aihat"),   # mAP groups 4/5       (rows 6-7)
    ("yolov8_n", "pi5_tpu"),     # extra pareto point
)

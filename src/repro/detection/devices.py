"""Edge-device energy/latency models + the paper's testbed construction.

Each device is parameterized by (effective GFLOP/s for small convnets,
active power W, fixed per-request overhead ms).  The constants are chosen to
reproduce the ORDERING in the paper's Table 1 / Fig. 5 (Jetson Orin Nano =
lowest energy; Pi5+Coral TPU = lowest latency; accelerators fast but
power-hungry relative to their speed on small models; plain Pis slow).
Absolute numbers are representative; every paper-claim validation in
EXPERIMENTS.md is a ratio, which is insensitive to the absolute scale
(DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class EdgeDevice:
    name: str
    gflops: float       # sustained for small conv nets
    watts: float        # active power above idle
    overhead_ms: float  # request handling / runtime dispatch

    def time_ms(self, flops: float) -> float:
        return flops / (self.gflops * 1e9) * 1e3 + self.overhead_ms

    def energy_mwh(self, flops: float) -> float:
        hours = self.time_ms(flops) / 1e3 / 3600.0
        return self.watts * hours * 1e3  # W * h * 1000 = mWh


DEVICES: Dict[str, EdgeDevice] = {
    "pi3":        EdgeDevice("pi3", 1.2, 3.2, 9.0),
    "pi3_tpu":    EdgeDevice("pi3_tpu", 16.0, 5.4, 6.0),
    "pi4":        EdgeDevice("pi4", 2.8, 4.2, 6.0),
    "pi4_tpu":    EdgeDevice("pi4_tpu", 22.0, 6.4, 4.0),
    "pi5":        EdgeDevice("pi5", 6.5, 5.6, 3.5),
    "pi5_tpu":    EdgeDevice("pi5_tpu", 32.0, 7.8, 1.2),  # lowest latency
    "pi5_aihat":  EdgeDevice("pi5_aihat", 26.0, 7.2, 2.0),
    "orin_nano":  EdgeDevice("orin_nano", 40.0, 6.8, 2.6),  # lowest energy
}

# The paper's finalized testbed (Table 1) pairs — each strong in >=1 metric.
# We profile ALL (8 models x 8 devices) = 64 pairs for the Fig. 5 Pareto
# analog, then select this subset for routing experiments.
TESTBED_PAIRS: Tuple[Tuple[str, str], ...] = (
    ("ssd_v1", "orin_nano"),     # lowest energy        (Table 1 row 1)
    ("ssd_v1", "pi5_tpu"),       # lowest latency       (row 2)
    ("ssd_lite", "pi5"),         # mAP group 2          (row 4)
    ("yolov8_s", "orin_nano"),   # mAP group 3          (row 5)
    ("yolov8_s", "pi5_aihat"),   # mAP groups 4/5       (rows 6-7)
    ("yolov8_n", "pi5_tpu"),     # extra pareto point
)


# ------------------------------------------------------- nominal profiling
# Routing-dynamics fixtures (benches, examples, tests) need a profile with
# the testbed's SHAPE but no trained detectors: nominal per-model mAPs that
# degrade mildly with the group, device costs from the real energy models.

NOMINAL_MAP: Dict[str, float] = {"ssd_v1": 52.0, "ssd_lite": 55.0,
                                 "yolov8_n": 57.0, "yolov8_s": 60.0}


def nominal_profile_table(pairs: Sequence[Tuple[str, str]] = TESTBED_PAIRS,
                          groups: int = 5):
    """Fresh ProfileTable over ``pairs`` with nominal mAPs and modeled
    device costs — isolates WHERE requests go from how well boxes are
    drawn.  Callers that EWMA-adapt get their own instance per call."""
    from repro.core.profiles import ProfileEntry, ProfileTable
    from repro.detection.detectors import DETECTOR_CONFIGS
    entries = []
    for m, d in pairs:
        flops = DETECTOR_CONFIGS[m].flops
        for g in range(groups):
            entries.append(ProfileEntry(
                m, d, g, NOMINAL_MAP[m] - 1.5 * g,
                DEVICES[d].time_ms(flops), DEVICES[d].energy_mwh(flops)))
    return ProfileTable(entries)


# --------------------------------------------------------------- drift model
# BEYOND-PAPER (paper §6 / AyE-Edge 2408.05363): the offline profile goes
# stale at runtime — devices throttle, share CPU with other tenants, or drop
# off the network.  A DriftingFleet is a time-varying view of DEVICES that
# the gateway can charge ACTUAL costs against while the routers still consult
# the (possibly EWMA-adapted) profile table.

class DeviceDropout(RuntimeError):
    """A hard-dropout device was asked to serve while unreachable
    (``DriftEvent(kind="dropout", hard=True)`` active at this step).  The
    dispatch plane turns this into a failed batch the resilience layer
    retries elsewhere — unlike the soft penalty, the request does NOT
    complete on this device."""

    def __init__(self, device: str, step: int):
        super().__init__(f"device {device!r} is unreachable at step {step} "
                         "(hard dropout window)")
        self.device = device
        self.step = step


@dataclasses.dataclass(frozen=True)
class DriftEvent:
    """One runtime condition change on one device.

    kind:
      * ``thermal``    — sustained throttling: the latency multiplier ramps
                         linearly from 1 to ``severity`` over ``ramp`` steps
                         after ``start`` and stays there
      * ``background`` — co-tenant load: square wave alternating between
                         ``severity`` and 1 with ``period`` steps per cycle
      * ``dropout``    — device unreachable in [start, end): requests pay a
                         flat ``severity``x retry/timeout penalty — or, with
                         ``hard=True``, FAIL outright: the scalar ``cost``
                         raises ``DeviceDropout`` (the serving path's batch
                         error) and the vectorized faces report ``inf``
                         (the scanned closed loop's failure sentinel that
                         drives the quarantine breaker)
    Energy scales with the same multiplier (active power x longer busy time).
    """
    device: str
    kind: str
    start: int = 0
    end: Optional[int] = None   # exclusive; None = never ends
    severity: float = 2.0
    ramp: int = 40              # thermal ramp-up length, steps
    period: int = 60            # background-load cycle length, steps
    hard: bool = False          # dropout only: raise instead of penalizing

    def active(self, step: int) -> bool:
        return step >= self.start and (self.end is None or step < self.end)

    def failing(self, step: int) -> bool:
        """True when a HARD dropout makes the device unreachable at
        ``step`` (soft events never fail — they only cost more)."""
        return self.hard and self.kind == "dropout" and self.active(step)

    def multiplier(self, step: int) -> float:
        if not self.active(step):
            return 1.0
        if self.kind == "thermal":
            frac = min((step - self.start) / max(self.ramp, 1), 1.0)
            return 1.0 + (self.severity - 1.0) * frac
        if self.kind == "background":
            phase = ((step - self.start) % self.period) / self.period
            return self.severity if phase < 0.5 else 1.0
        if self.kind == "dropout":
            return float("inf") if self.hard else self.severity
        raise ValueError(f"unknown drift kind {self.kind!r}")

    def multipliers(self, steps: int):
        """``multiplier(t)`` for every t in [0, steps) in one shot — the
        vectorized face the scanned closed loop's measurement precompute
        uses (exact-parity with the scalar method, tested)."""
        import numpy as np
        t = np.arange(steps)
        if self.kind == "thermal":
            frac = np.minimum((t - self.start) / max(self.ramp, 1), 1.0)
            m = 1.0 + (self.severity - 1.0) * frac
        elif self.kind == "background":
            phase = ((t - self.start) % self.period) / self.period
            m = np.where(phase < 0.5, self.severity, 1.0)
        elif self.kind == "dropout":
            m = np.full(steps, np.inf if self.hard else self.severity)
        else:
            raise ValueError(f"unknown drift kind {self.kind!r}")
        active = t >= self.start
        if self.end is not None:
            active &= t < self.end
        return np.where(active, m, 1.0)


class DriftingFleet:
    """Time-varying device fleet: actual per-request cost at step t is the
    profiled cost times the product of every active drift event's multiplier."""

    def __init__(self, events: Sequence[DriftEvent] = (),
                 devices: Dict[str, EdgeDevice] = DEVICES):
        self.events = tuple(events)
        self.devices = devices

    def multiplier(self, device: str, step: int) -> float:
        m = 1.0
        for ev in self.events:
            if ev.device == device:
                m *= ev.multiplier(step)
        return m

    def failing(self, device: str, step: int) -> bool:
        """True when a hard-dropout event makes ``device`` unreachable at
        ``step`` — ``cost`` raises instead of quoting a price."""
        return any(ev.device == device and ev.failing(step)
                   for ev in self.events)

    def cost(self, device: str, flops: float, step: int
             ) -> Tuple[float, float]:
        """(time_ms, energy_mwh) actually paid at ``step``; energy is linear
        in busy time, so both scale by the same multiplier.  Raises
        ``DeviceDropout`` when a hard-dropout window covers ``step`` — the
        request did not complete, so there IS no cost to report."""
        if self.failing(device, step):
            raise DeviceDropout(device, step)
        dev = self.devices[device]
        m = self.multiplier(device, step)
        return dev.time_ms(flops) * m, dev.energy_mwh(flops) * m

    def cost_profile(self, device: str, flops: float, steps: int):
        """``cost(device, flops, t)`` for every t in [0, steps) as two [T]
        arrays — the vectorized precompute for the scanned closed loop
        (one numpy pass instead of T Python calls per pair)."""
        import numpy as np
        m = np.ones(steps)
        for ev in self.events:
            if ev.device == device:
                m = m * ev.multipliers(steps)
        dev = self.devices[device]
        return dev.time_ms(flops) * m, dev.energy_mwh(flops) * m


def drift_scenario(name: str, device: str = "orin_nano",
                   start: int = 0) -> DriftingFleet:
    """Named single-event scenarios used by tests and the adaptive bench."""
    if name == "thermal":
        events = (DriftEvent(device, "thermal", start=start, severity=4.0),)
    elif name == "background":
        events = (DriftEvent(device, "background", start=start, severity=3.0,
                             period=80),)
    elif name == "dropout":
        events = (DriftEvent(device, "dropout", start=start, end=start + 120,
                             severity=30.0),)
    else:
        raise ValueError(f"unknown drift scenario {name!r}")
    return DriftingFleet(events)

"""Synthetic scene corpus (the COCO-val / pedestrian-video stand-in).

Images are [H, W] grayscale in [0, 1] with K objects from 3 shape classes
(rectangle, ellipse, triangle), plus background noise and small clutter dots
that are NOT objects (so counting is non-trivial).  Three dataset variants
mirror the paper's:

  * full            — natural object-count mix (COCO-like distribution)
  * balanced_sorted — 5 groups x n images, ordered by group (paper §4.1)
  * video           — temporally-correlated sequence: counts random-walk and
                      objects move smoothly between frames
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

IMG = 64
CLASSES = ("rect", "ellipse", "triangle")
NUM_CLASSES = len(CLASSES)

# COCO-val-like count distribution (paper Fig. 4: long tail, mode at 1-3)
COUNT_PROBS = np.array([0.08, 0.22, 0.20, 0.15, 0.12, 0.09, 0.06, 0.05, 0.03])


@dataclasses.dataclass
class Scene:
    image: np.ndarray          # [IMG, IMG] float32
    boxes: np.ndarray          # [K, 4] x0,y0,x1,y1
    classes: np.ndarray        # [K] int
    count: int


def _draw_object(img, rng, cls: int, x0, y0, w, h, intensity):
    x1, y1 = x0 + w, y0 + h
    yy, xx = np.mgrid[y0:y1, x0:x1]
    if cls == 0:  # rectangle
        img[y0:y1, x0:x1] = intensity
    elif cls == 1:  # ellipse
        cy, cx = (y0 + y1) / 2, (x0 + x1) / 2
        mask = (((yy - cy) / (h / 2)) ** 2 + ((xx - cx) / (w / 2)) ** 2) <= 1
        img[y0:y1, x0:x1][mask] = intensity
    else:  # triangle
        mask = (yy - y0) >= np.abs(xx - (x0 + x1) / 2) * 2 * h / max(w, 1)
        img[y0:y1, x0:x1][mask] = intensity
    return np.array([x0, y0, x1, y1], np.float32)


def make_scene(rng: np.random.Generator, count: Optional[int] = None,
               positions: Optional[List[Tuple]] = None) -> Scene:
    img = rng.normal(0.12, 0.04, (IMG, IMG)).astype(np.float32)
    # clutter: tiny dots that must not be counted as objects
    for _ in range(rng.integers(3, 9)):
        cy, cx = rng.integers(2, IMG - 2, 2)
        img[cy - 1:cy + 1, cx - 1:cx + 1] += rng.uniform(0.15, 0.3)
    if count is None:
        count = int(rng.choice(len(COUNT_PROBS), p=COUNT_PROBS))
    boxes, classes = [], []
    specs = positions if positions is not None else [None] * count
    for k in range(count):
        if specs[k] is None:
            w, h = rng.integers(10, 22, 2)
            x0 = int(rng.integers(1, IMG - w - 1))
            y0 = int(rng.integers(1, IMG - h - 1))
            cls = int(rng.integers(0, NUM_CLASSES))
        else:
            x0, y0, w, h, cls = specs[k]
        inten = float(rng.uniform(0.55, 0.95))
        boxes.append(_draw_object(img, rng, cls, x0, y0, int(w), int(h), inten))
        classes.append(cls)
    img = np.clip(img + rng.normal(0, 0.02, img.shape), 0, 1).astype(np.float32)
    return Scene(image=img,
                 boxes=np.asarray(boxes, np.float32).reshape(-1, 4),
                 classes=np.asarray(classes, np.int32).reshape(-1),
                 count=count)


def full_dataset(n: int, seed: int = 0) -> List[Scene]:
    rng = np.random.default_rng(seed)
    return [make_scene(rng) for _ in range(n)]


def balanced_sorted_dataset(per_group: int = 40, seed: int = 1) -> List[Scene]:
    """paper §4.1: equal-size groups 0,1,2,3,4+, ordered by group."""
    rng = np.random.default_rng(seed)
    out = []
    for g in range(5):
        for _ in range(per_group):
            count = g if g < 4 else int(rng.integers(4, 8))
            out.append(make_scene(rng, count=count))
    return out


def drifting_dataset(n: int = 200, seed: int = 4,
                     shift_at: Optional[int] = None) -> List[Scene]:
    """Workload drift: the count distribution flips mid-stream from the
    sparse COCO-like mix to its crowded mirror image (rush hour at the
    pedestrian crossing), so the dominant object-count group changes and
    adaptive routing has something to chase."""
    rng = np.random.default_rng(seed)
    shift_at = n // 2 if shift_at is None else shift_at
    crowded = COUNT_PROBS[::-1]
    out = []
    for i in range(n):
        probs = COUNT_PROBS if i < shift_at else crowded
        out.append(make_scene(rng, count=int(rng.choice(len(probs), p=probs))))
    return out


def video_dataset(n_frames: int = 200, seed: int = 2) -> List[Scene]:
    """Pedestrian-crossing analog: counts random-walk; objects drift."""
    rng = np.random.default_rng(seed)
    count = 2
    objs: List[Tuple] = []  # (x0, y0, w, h, cls, vx, vy)
    out = []
    for _ in range(n_frames):
        # random-walk the target count occasionally
        if rng.random() < 0.15:
            count = int(np.clip(count + rng.choice([-1, 1]), 0, 8))
        while len(objs) < count:
            w, h = rng.integers(10, 22, 2)
            objs.append([int(rng.integers(1, IMG - w - 1)),
                         int(rng.integers(1, IMG - h - 1)),
                         int(w), int(h), int(rng.integers(0, NUM_CLASSES)),
                         float(rng.uniform(-2, 2)), float(rng.uniform(-2, 2))])
        while len(objs) > count:
            objs.pop(rng.integers(0, len(objs)))
        positions = []
        for o in objs:  # drift
            o[0] = int(np.clip(o[0] + o[5], 1, IMG - o[2] - 1))
            o[1] = int(np.clip(o[1] + o[6], 1, IMG - o[3] - 1))
            positions.append((o[0], o[1], o[2], o[3], o[4]))
        out.append(make_scene(rng, count=count, positions=positions))
    return out

"""Train the detector family on the synthetic scene corpus + profile it.

``train_all`` trains all 8 models (cached to .npz checkpoints); ``profile``
measures per-group mAP for every (model, device) pair and assembles the
ProfileTable the routers consume — this is the paper's offline profiling
stage [1] (their arXiv:2409.16808 benchmarking study).
"""
from __future__ import annotations

import os
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.core.groups import all_groups, group_of
from repro.core.metrics import MAPAccumulator
from repro.core.profiles import ProfileEntry, ProfileTable
from repro.detection import scenes as sc
from repro.detection.detectors import (DETECTOR_CONFIGS, DetectorConfig,
                                       decode_detections, detection_loss,
                                       detector_forward, encode_targets,
                                       init_detector)
from repro.detection.devices import DEVICES, TESTBED_PAIRS
from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state


def _batch_from_scenes(batch_scenes: Sequence[sc.Scene]):
    imgs = np.stack([s.image for s in batch_scenes])[..., None]
    objs, boxes, clss = [], [], []
    for s in batch_scenes:
        o, b, c = encode_targets(s.boxes, s.classes)
        objs.append(o); boxes.append(b); clss.append(c)
    return {
        "image": jnp.asarray(imgs),
        "obj": jnp.asarray(np.stack(objs)),
        "box": jnp.asarray(np.stack(boxes)),
        "cls": jnp.asarray(np.stack(clss)),
    }


def train_detector(cfg: DetectorConfig, *, steps: int = 700,
                   batch_size: int = 16, seed: int = 0,
                   lr: float = 5e-3, verbose: bool = False) -> Dict:
    params = init_detector(cfg, jax.random.PRNGKey(seed))
    opt_cfg = AdamWConfig(peak_lr=lr, warmup_steps=20, total_steps=steps,
                          weight_decay=1e-4)
    opt = init_opt_state(params)
    rng = np.random.default_rng(seed + 17)

    @jax.jit
    def step(params, opt, batch):
        loss, grads = jax.value_and_grad(detection_loss)(params, batch)
        params, opt, _ = adamw_update(opt_cfg, params, grads, opt)
        return params, opt, loss

    for i in range(steps):
        batch = _batch_from_scenes([sc.make_scene(rng) for _ in range(batch_size)])
        params, opt, loss = step(params, opt, batch)
        if verbose and i % 100 == 0:
            print(f"  {cfg.name} step {i} loss {float(loss):.4f}")
    return params


def train_all(cache_dir: str = "artifacts/detectors", *, steps: int = 700,
              verbose: bool = False) -> Dict[str, Dict]:
    os.makedirs(cache_dir, exist_ok=True)
    out = {}
    for name, cfg in DETECTOR_CONFIGS.items():
        path = os.path.join(cache_dir, f"{name}.npz")
        if os.path.exists(path):
            params = ckpt.load(path, init_detector(cfg, jax.random.PRNGKey(0)))
        else:
            if verbose:
                print(f"training {name} ...")
            params = train_detector(cfg, steps=steps, verbose=verbose)
            ckpt.save(path, params)
        out[name] = params
    return out


def run_detector(params, images: np.ndarray):
    """images [B,H,W] -> list of (boxes, scores, classes)."""
    raw = np.asarray(jax.jit(detector_forward)(params,
                                               jnp.asarray(images)[..., None]))
    return [decode_detections(r) for r in raw]


def profile_pairs(detector_params: Dict[str, Dict],
                  pairs: Sequence[Tuple[str, str]],
                  val_scenes: Optional[List[sc.Scene]] = None,
                  verbose: bool = False) -> ProfileTable:
    """Measure per-group mAP for each pair; energy/time from device models."""
    if val_scenes is None:
        val_scenes = sc.full_dataset(250, seed=99)
    by_group: Dict[int, List[sc.Scene]] = {g: [] for g in all_groups()}
    for s in val_scenes:
        by_group[group_of(s.count)].append(s)

    # batch-evaluate each model once per group
    entries = []
    models = sorted({m for m, _ in pairs})
    model_group_map: Dict[Tuple[str, int], float] = {}
    for m in models:
        for g, group_scenes in by_group.items():
            acc = MAPAccumulator(sc.NUM_CLASSES)
            if group_scenes:
                imgs = np.stack([s.image for s in group_scenes])
                dets = run_detector(detector_params[m], imgs)
                for s, (b, s_, c) in zip(group_scenes, dets):
                    acc.add_image(b, s_, c, s.boxes, s.classes)
            model_group_map[(m, g)] = acc.map()
            if verbose:
                print(f"  {m} group {g}: mAP {acc.map():.1f}")
    for m, d in pairs:
        dev = DEVICES[d]
        flops = DETECTOR_CONFIGS[m].flops
        for g in all_groups():
            entries.append(ProfileEntry(
                model=m, device=d, group=g,
                map_pct=model_group_map[(m, g)],
                time_ms=dev.time_ms(flops),
                energy_mwh=dev.energy_mwh(flops)))
    return ProfileTable(entries)


def default_testbed(cache_dir: str = "artifacts/detectors",
                    profile_path: str = "artifacts/profile_table.json",
                    verbose: bool = False):
    """Train (or load) detectors + build (or load) the testbed profile."""
    params = train_all(cache_dir, verbose=verbose)
    if os.path.exists(profile_path):
        table = ProfileTable.from_json(profile_path)
    else:
        table = profile_pairs(params, TESTBED_PAIRS, verbose=verbose)
        os.makedirs(os.path.dirname(profile_path), exist_ok=True)
        table.to_json(profile_path)
    return params, table

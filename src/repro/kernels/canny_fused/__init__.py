from .ops import canny_edge  # noqa: F401

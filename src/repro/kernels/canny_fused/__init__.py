from .ops import bucket_shape, canny_edge, canny_edge_batch  # noqa: F401

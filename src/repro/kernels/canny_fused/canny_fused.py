"""Pallas TPU megakernel: the whole Canny gateway stage in one ``pallas_call``.

The gateway runs this on EVERY incoming frame, so the seed pipeline's shape —
one small Sobel kernel sandwiched between ~6 separate jnp stages, each a full
HBM round-trip of the frame — put a hard floor under per-frame latency.  This
kernel fuses gaussian blur -> Sobel -> direction-quantized NMS -> double
threshold -> fixed-iteration hysteresis into ONE launch: no intermediate
(blurred / magnitude / thinned) map ever round-trips to HBM — only the final
bool edge map is written back.

Tiling / halo scheme
--------------------
Grid = (batch, row_tiles): each program owns ``tile_rows`` output rows and
sees three stacked input blocks — the PREVIOUS, CURRENT and NEXT row-tile
(index maps clamped at the frame edges) — from which it assembles a
``tile_rows + 2*HALO`` row window.  Fetching whole neighbour tiles (rather
than an overlapping element-offset window, which BlockSpec's block-index
granularity cannot express) means each input tile is DMA'd up to 3x, but
that is input traffic only — still far below the staged pipeline's ~6 full
frame read+write round-trips, and the win grows with everything that never
leaves VMEM.  HALO = 12 rows per side is exactly the receptive-field height
of one output row:

    2 (gaussian blur) + 1 (Sobel) + 1 (NMS) + 8 (hysteresis dilations) = 12

so every window row that influences an emitted row is computed from real
neighbour data; window rows closer than HALO to the window edge may be
corrupt (they see the window's own replicated/zero padding instead of the
true neighbour tile) and are discarded.  This is why ``tile_rows >= HALO`` is
required: the halo must fit inside one neighbouring block.

Frame-boundary parity: the jnp oracle pads each stage differently (blur and
Sobel replicate their INPUT at the frame edge; NMS and hysteresis zero-pad),
and replicating the raw frame before blurring is NOT the same as replicating
the blurred frame before Sobel.  The kernel therefore re-applies the
per-stage semantics to the out-of-frame window rows between stages — edge
rows re-replicated after blur, magnitudes zeroed outside the frame — which
makes the emitted rows bit-identical to ``ref.canny_edge`` (tested exactly,
not to a tolerance, in tests/test_canny_fused.py).

VMEM budget: the working set is the window (~[tile_rows+24, W]) in f32 for
the frame/blur/magnitude stages plus a few bool maps — ~5 f32-equivalent
buffers.  At the default tile_rows=128 and W=1024 that is ~3 MB, well inside
the ~16 MB/core budget; frames wider than ~4k columns should shrink
``tile_rows`` (the grid already scales to any frame HEIGHT).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import HYSTERESIS_ITERS

#: rows of neighbour context one output row depends on (see module docstring)
HALO = 2 + 1 + 1 + HYSTERESIS_ITERS

#: widest frame the row-tiled kernel accepts: the working set is ~5
#: f32-equivalent [tile_rows + 2*HALO, W] buffers, so at the minimum
#: tile_rows=HALO a 4096-column frame is ~3 MB of VMEM — comfortably inside
#: the ~16 MB/core budget; wider frames need lane-dim (width) tiling, which
#: this kernel does not implement (ROADMAP: "lane-dim (width) tiling for
#: frames wider than ~4k columns" is an open item)
MAX_WIDTH = 4096


def _canny_kernel(prev_ref, cur_ref, next_ref, out_ref, *,
                  h: int, tile: int, lo: float, hi: float):
    i = pl.program_id(1)
    win = jnp.concatenate([prev_ref[0][tile - HALO:], cur_ref[0],
                           next_ref[0][:HALO]], axis=0)  # [tile+2*HALO, W]
    rows, w = win.shape
    # global frame row of every window row; rows outside [0, h) only occur in
    # frame-edge tiles (or grid padding past a non-tile-multiple height)
    gr = (jax.lax.broadcasted_iota(jnp.int32, (rows, 1), 0)
          + i * tile - HALO)
    oob_top = gr < 0
    oob_bot = gr > h - 1
    oob = oob_top | oob_bot
    # frame row 0 sits at window index HALO whenever oob_top is non-empty
    # (only tile 0); frame row h-1 sits at HALO + (h-1) - i*tile whenever
    # oob_bot is non-empty (clamped to a no-op position otherwise)
    bot_pos = jnp.clip(HALO + (h - 1) - i * tile, 0, rows - 1)

    def replicate_frame_edges(a):
        top = a[HALO][None, :]
        bot = jax.lax.dynamic_slice_in_dim(a, bot_pos, 1, axis=0)
        return jnp.where(oob_bot, bot, jnp.where(oob_top, top, a))

    # ---- gaussian blur (oracle pads the INPUT with edge replication)
    win = replicate_frame_edges(win)
    r = 2
    # same maths as ref.gauss_kernel, but built from an in-kernel iota —
    # Pallas kernels cannot capture traced constants like jnp.arange
    xs = jax.lax.broadcasted_iota(jnp.float32, (2 * r + 1, 1), 0) - r
    k = jnp.exp(-0.5 * (xs / 1.0) ** 2)
    k = (k / k.sum())[:, 0]
    padh = jnp.pad(win, ((0, 0), (r, r)), mode="edge")
    blur_h = sum(padh[:, j:j + w] * k[j] for j in range(2 * r + 1))
    padv = jnp.pad(blur_h, ((r, r), (0, 0)), mode="edge")
    sm = sum(padv[j:j + rows, :] * k[j] for j in range(2 * r + 1))

    # ---- Sobel (oracle pads the BLURRED map with edge replication)
    sm = replicate_frame_edges(sm)
    xp = jnp.pad(sm, ((1, 1), (1, 1)), mode="edge")
    tl = xp[:-2, :-2]; tc = xp[:-2, 1:-1]; tr = xp[:-2, 2:]
    ml = xp[1:-1, :-2];                     mr = xp[1:-1, 2:]
    bl = xp[2:, :-2];  bc = xp[2:, 1:-1];  br = xp[2:, 2:]
    gx = (tr + 2 * mr + br) - (tl + 2 * ml + bl)
    gy = (bl + 2 * bc + br) - (tl + 2 * tc + tr)
    mag = jnp.sqrt(gx * gx + gy * gy)
    q = jnp.round(jnp.arctan2(gy, gx) / (jnp.pi / 4)).astype(jnp.int32) % 4

    # ---- NMS (oracle zero-pads the magnitude at the frame border)
    mag = jnp.where(oob, 0.0, mag)
    p = jnp.pad(mag, ((1, 1), (1, 1)))
    c = p[1:rows + 1, 1:w + 1]
    neigh = [
        (p[1:rows + 1, 2:], p[1:rows + 1, :w]),        # 0: E/W
        (p[2:, 2:], p[:rows, :w]),                     # 1: SE/NW
        (p[2:, 1:w + 1], p[:rows, 1:w + 1]),           # 2: S/N
        (p[2:, :w], p[:rows, 2:]),                     # 3: SW/NE
    ]
    keep = jnp.zeros_like(c, bool)
    for d, (a, b2) in enumerate(neigh):
        keep = keep | ((q == d) & (c >= a) & (c >= b2))
    thin = mag * keep

    # ---- double threshold + hysteresis (zero-padded at the frame border:
    # out-of-frame rows must stay False so growth matches the oracle)
    strong = (thin > hi) & ~oob
    weak = (thin > lo) & ~oob
    for _ in range(HYSTERESIS_ITERS):
        sp = jnp.pad(strong, ((1, 1), (1, 1)))
        dil = (sp[:rows, 1:w + 1] | sp[2:, 1:w + 1] | sp[1:rows + 1, :w]
               | sp[1:rows + 1, 2:] | sp[:rows, :w] | sp[:rows, 2:]
               | sp[2:, :w] | sp[2:, 2:] | strong)
        strong = dil & weak

    out_ref[0] = strong[HALO:HALO + tile]


@functools.partial(jax.jit,
                   static_argnames=("lo", "hi", "tile_rows", "interpret"))
def canny_edge_pallas(img, *, lo: float = 0.6, hi: float = 1.0,
                      tile_rows: int | None = None, interpret: bool = False):
    """img [B,H,W] f32 -> edge map [B,H,W] bool, one fused pallas_call.

    ``tile_rows`` picks the row-tile height (defaults to whole-frame up to
    128 rows); any frame height works, including non-multiples of the tile.
    """
    b, h, w = img.shape
    if w > MAX_WIDTH:
        raise ValueError(
            f"frame width {w} exceeds the fused kernel's column limit "
            f"({MAX_WIDTH}): the row-tiled megakernel keeps whole rows in "
            f"VMEM and only tiles the HEIGHT; frames this wide need "
            f"lane-dim (width) tiling — an open ROADMAP item ('lane-dim "
            f"(width) tiling for frames wider than ~4k columns').  Use "
            f"impl='xla' (the staged oracle) for now.")
    tile = tile_rows if tile_rows is not None else min(max(h, HALO), 128)
    if tile < HALO:
        raise ValueError(
            f"tile_rows={tile} < HALO={HALO}: the halo must fit inside one "
            f"neighbouring row-tile block")
    n = pl.cdiv(h, tile)
    kernel = functools.partial(_canny_kernel, h=h, tile=tile, lo=lo, hi=hi)
    block = lambda f: pl.BlockSpec((1, tile, w), f)  # noqa: E731
    return pl.pallas_call(
        kernel,
        grid=(b, n),
        in_specs=[block(lambda bi, i: (bi, jnp.maximum(i - 1, 0), 0)),
                  block(lambda bi, i: (bi, i, 0)),
                  block(lambda bi, i: (bi, jnp.minimum(i + 1, n - 1), 0))],
        out_specs=block(lambda bi, i: (bi, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, w), jnp.bool_),
        interpret=interpret,
    )(img, img, img)

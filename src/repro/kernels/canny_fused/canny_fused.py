"""Pallas TPU megakernel: the whole Canny gateway stage in one ``pallas_call``.

The gateway runs this on EVERY incoming frame, so the seed pipeline's shape —
one small Sobel kernel sandwiched between ~6 separate jnp stages, each a full
HBM round-trip of the frame — put a hard floor under per-frame latency.  This
kernel fuses gaussian blur -> Sobel -> direction-quantized NMS -> double
threshold -> fixed-iteration hysteresis into ONE launch: no intermediate
(blurred / magnitude / thinned) map ever round-trips to HBM — only the final
bool edge map is written back.

Tiling / halo scheme
--------------------
Grid = (batch, row_tiles, lane_tiles): each program owns a
``tile_rows x tile_lanes`` output window and sees its 3x3 neighbourhood of
input blocks (index maps clamped at the frame edges), from which it
assembles a ``(tile_rows + 2*HALO, tile_lanes + 2*HALO)`` window — 12 halo
rows above/below plus 12 halo lanes left/right.  Fetching whole neighbour
blocks (rather than an overlapping element-offset window, which BlockSpec's
block-index granularity cannot express) means each input block is DMA'd up
to 9x, but that is input traffic only — still far below the staged
pipeline's ~6 full frame read+write round-trips, and the win grows with
everything that never leaves VMEM.  HALO = 12 rows/lanes per side is
exactly the receptive field of one output pixel in each dimension:

    2 (gaussian blur) + 1 (Sobel) + 1 (NMS) + 8 (hysteresis dilations) = 12

so every window pixel that influences an emitted pixel is computed from
real neighbour data; window rows/lanes closer than HALO to the window edge
may be corrupt (they see the window's own replicated/zero padding instead
of the true neighbour block) and are discarded.  This is why
``tile_rows >= HALO`` and ``tile_lanes >= HALO`` are required: the halo
must fit inside one neighbouring block in each dimension.

Frame-boundary parity: the jnp oracle pads each stage differently (blur and
Sobel replicate their INPUT at the frame edge; NMS and hysteresis zero-pad),
and replicating the raw frame before blurring is NOT the same as replicating
the blurred frame before Sobel.  The kernel therefore re-applies the
per-stage semantics to the out-of-frame window pixels between stages — edge
rows/lanes re-replicated after blur, magnitudes zeroed outside the frame —
which makes the emitted pixels bit-identical to ``ref.canny_edge`` (tested
exactly, not to a tolerance, in tests/test_canny_fused.py).

Ragged batches (pad-and-mask): the per-frame TRUE extent is carried by the
``dims`` input ([B, 2] int32 (height, width) per frame), so a batch of
mixed-resolution frames zero-padded to a common bucket shape streams
through ONE launch — every pixel at or beyond a frame's true extent is
out-of-frame for that frame (replicated for blur/Sobel, zeroed for
NMS/hysteresis) and the emitted map is False there, so callers just crop.
When ``dims`` is omitted every frame spans the full array.  (On a real TPU
``dims`` belongs in SMEM / scalar prefetch; the plain input keeps the
kernel portable to interpret mode, and the two scalar reads per program are
noise next to the window compute.)

VMEM budget model (``pick_tiles``): the working set is ~6 f32-equivalent
``(tile_rows + 24, tile_lanes + 24)`` window buffers (frame/blur/gradients/
magnitude stages plus bool maps) + the 9 fetched ``(tile_rows, tile_lanes)``
input blocks + the bool output block.  When tile sizes are not given,
``pick_tiles`` starts from the widest lane tile (whole width up to 2048
lanes, 128-lane granularity — fewer lane tiles means less halo refetch) and
the tallest row tile (up to 128 rows, 8-row granularity), then shrinks rows
first and lanes second until the working set fits ``VMEM_BUDGET_BYTES``
(8 MiB — half the ~16 MiB/core, leaving room for pipelining).  A 4K
(2160x3840) frame lands on (56, 2048): ~7.8 MiB resident, 39x2 programs.
Arbitrary frame sizes stream through VMEM — there is no width limit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import HYSTERESIS_ITERS

#: rows/lanes of neighbour context one output pixel depends on per side
#: (see module docstring)
HALO = 2 + 1 + 1 + HYSTERESIS_ITERS

#: working-set ceiling pick_tiles fits the default tile sizes into — half
#: the ~16 MiB/core VMEM, leaving headroom for double-buffered pipelines
VMEM_BUDGET_BYTES = 8 * 2 ** 20

#: TPU-native tile granularities (f32): 128-wide lanes, 8-row sublanes
LANE = 128
SUBLANE = 8

#: f32-equivalent window-sized buffers live at the working-set peak
#: (frame/blur/magnitude/direction stages + bool maps)
_WINDOW_BUFFERS = 6


def _round_up(x: int, q: int) -> int:
    return -(-x // q) * q


def tile_bytes(tile_rows: int, tile_lanes: int) -> int:
    """Modeled VMEM working set of one program at these tile sizes."""
    rows, cols = tile_rows + 2 * HALO, tile_lanes + 2 * HALO
    window = _WINDOW_BUFFERS * 4 * rows * cols
    blocks = 9 * 4 * tile_rows * tile_lanes  # the 3x3 neighbour input blocks
    out = tile_rows * tile_lanes             # bool output block
    return window + blocks + out


def pick_tiles(h: int, w: int, *, tile_rows: int | None = None,
               tile_lanes: int | None = None,
               vmem_budget_bytes: int = VMEM_BUDGET_BYTES
               ) -> tuple[int, int]:
    """(tile_rows, tile_lanes) for an [h, w] frame from the VMEM budget
    model (see module docstring); explicit values are honored as-is, and a
    missing dimension is auto-picked around the fixed one."""
    if tile_rows is not None and tile_lanes is not None:
        return tile_rows, tile_lanes
    max_tl = (tile_lanes if tile_lanes is not None
              else min(_round_up(max(w, 1), LANE), 16 * LANE))
    max_tr = (tile_rows if tile_rows is not None
              else min(_round_up(max(h, HALO), SUBLANE), 128))
    # the smallest tile the auto-picker may shrink to: 2 sublanes (>= HALO)
    floor_tr = max_tr if tile_rows is not None else min(max_tr, 2 * SUBLANE)
    tl = max_tl
    while True:
        tr = max_tr
        while tr > floor_tr and tile_bytes(tr, tl) > vmem_budget_bytes:
            tr -= SUBLANE
        if (tile_bytes(tr, tl) <= vmem_budget_bytes
                or tile_lanes is not None or tl <= LANE):
            return tr, tl
        tl -= LANE


def _canny_kernel(dims_ref, tl_ref, tc_ref, tr_ref, ml_ref, mc_ref, mr_ref,
                  bl_ref, bc_ref, br_ref, out_ref, *,
                  tile_r: int, tile_l: int, lo: float, hi: float):
    i = pl.program_id(1)
    j = pl.program_id(2)
    h = dims_ref[0, 0]   # this frame's TRUE extent (<= the padded array)
    w = dims_ref[0, 1]

    def slab(left, mid, right, rs):
        """One window row-slab: halo lanes from the left/right neighbour
        blocks around the middle block, over row slice ``rs``."""
        return jnp.concatenate(
            [left[0][rs, tile_l - HALO:], mid[0][rs],
             right[0][rs, :HALO]], axis=1)

    win = jnp.concatenate(
        [slab(tl_ref, tc_ref, tr_ref, slice(tile_r - HALO, None)),
         slab(ml_ref, mc_ref, mr_ref, slice(None, None)),
         slab(bl_ref, bc_ref, br_ref, slice(None, HALO))],
        axis=0)  # [tile_r + 2*HALO, tile_l + 2*HALO]
    rows, cols = win.shape
    # global frame row/lane of every window pixel; positions outside
    # [0, h) x [0, w) only occur in frame-edge tiles, grid padding past a
    # non-tile-multiple extent, or a ragged frame's pad region
    gr = (jax.lax.broadcasted_iota(jnp.int32, (rows, 1), 0)
          + i * tile_r - HALO)
    gc = (jax.lax.broadcasted_iota(jnp.int32, (1, cols), 1)
          + j * tile_l - HALO)
    oob_top = gr < 0
    oob_bot = gr > h - 1
    oob_left = gc < 0
    oob_right = gc > w - 1
    oob = (oob_top | oob_bot) | (oob_left | oob_right)
    # frame row 0 sits at window index HALO whenever oob_top is non-empty
    # (only tile i=0), and symmetrically lane 0 at HALO for tile j=0; frame
    # row h-1 sits at HALO + (h-1) - i*tile_r whenever oob_bot is non-empty
    # (clamped to a no-op position otherwise), lane w-1 likewise
    bot_pos = jnp.clip(HALO + (h - 1) - i * tile_r, 0, rows - 1)
    right_pos = jnp.clip(HALO + (w - 1) - j * tile_l, 0, cols - 1)

    def replicate_frame_edges(a):
        top = a[HALO][None, :]
        bot = jax.lax.dynamic_slice_in_dim(a, bot_pos, 1, axis=0)
        a = jnp.where(oob_bot, bot, jnp.where(oob_top, top, a))
        left = a[:, HALO][:, None]
        right = jax.lax.dynamic_slice_in_dim(a, right_pos, 1, axis=1)
        return jnp.where(oob_right, right, jnp.where(oob_left, left, a))

    # ---- gaussian blur (oracle pads the INPUT with edge replication)
    win = replicate_frame_edges(win)
    r = 2
    # same maths as ref.gauss_kernel, but built from an in-kernel iota —
    # Pallas kernels cannot capture traced constants like jnp.arange
    xs = jax.lax.broadcasted_iota(jnp.float32, (2 * r + 1, 1), 0) - r
    k = jnp.exp(-0.5 * (xs / 1.0) ** 2)
    k = (k / k.sum())[:, 0]
    padh = jnp.pad(win, ((0, 0), (r, r)), mode="edge")
    blur_h = sum(padh[:, t:t + cols] * k[t] for t in range(2 * r + 1))
    padv = jnp.pad(blur_h, ((r, r), (0, 0)), mode="edge")
    sm = sum(padv[t:t + rows, :] * k[t] for t in range(2 * r + 1))

    # ---- Sobel (oracle pads the BLURRED map with edge replication)
    sm = replicate_frame_edges(sm)
    xp = jnp.pad(sm, ((1, 1), (1, 1)), mode="edge")
    tl = xp[:-2, :-2]; tc = xp[:-2, 1:-1]; tr = xp[:-2, 2:]
    ml = xp[1:-1, :-2];                     mr = xp[1:-1, 2:]
    bl = xp[2:, :-2];  bc = xp[2:, 1:-1];  br = xp[2:, 2:]
    gx = (tr + 2 * mr + br) - (tl + 2 * ml + bl)
    gy = (bl + 2 * bc + br) - (tl + 2 * tc + tr)
    mag = jnp.sqrt(gx * gx + gy * gy)
    q = jnp.round(jnp.arctan2(gy, gx) / (jnp.pi / 4)).astype(jnp.int32) % 4

    # ---- NMS (oracle zero-pads the magnitude at the frame border)
    mag = jnp.where(oob, 0.0, mag)
    p = jnp.pad(mag, ((1, 1), (1, 1)))
    c = p[1:rows + 1, 1:cols + 1]
    neigh = [
        (p[1:rows + 1, 2:], p[1:rows + 1, :cols]),     # 0: E/W
        (p[2:, 2:], p[:rows, :cols]),                  # 1: SE/NW
        (p[2:, 1:cols + 1], p[:rows, 1:cols + 1]),     # 2: S/N
        (p[2:, :cols], p[:rows, 2:]),                  # 3: SW/NE
    ]
    keep = jnp.zeros_like(c, bool)
    for d, (a, b2) in enumerate(neigh):
        keep = keep | ((q == d) & (c >= a) & (c >= b2))
    thin = mag * keep

    # ---- double threshold + hysteresis (zero-padded at the frame border:
    # out-of-frame pixels must stay False so growth matches the oracle)
    strong = (thin > hi) & ~oob
    weak = (thin > lo) & ~oob
    for _ in range(HYSTERESIS_ITERS):
        sp = jnp.pad(strong, ((1, 1), (1, 1)))
        dil = (sp[:rows, 1:cols + 1] | sp[2:, 1:cols + 1]
               | sp[1:rows + 1, :cols] | sp[1:rows + 1, 2:]
               | sp[:rows, :cols] | sp[:rows, 2:]
               | sp[2:, :cols] | sp[2:, 2:] | strong)
        strong = dil & weak

    out_ref[0] = strong[HALO:HALO + tile_r, HALO:HALO + tile_l]


@functools.partial(jax.jit, static_argnames=("lo", "hi", "tile_rows",
                                             "tile_lanes", "interpret"))
def canny_edge_pallas(img, dims=None, *, lo: float = 0.6, hi: float = 1.0,
                      tile_rows: int | None = None,
                      tile_lanes: int | None = None,
                      interpret: bool = False):
    """img [B,H,W] f32 -> edge map [B,H,W] bool, one fused pallas_call.

    ``tile_rows``/``tile_lanes`` pick the 2D tile (default: the VMEM budget
    model, ``pick_tiles``); any frame size works — heights AND widths that
    are odd, non-square, or non-multiples of the tile simply leave the last
    tile ragged.  ``dims`` ([B, 2] int32 (height, width) per frame, default
    whole-array) is the pad-and-mask plane for ragged batches: pixels at or
    beyond a frame's true extent come back False.
    """
    b, h, w = img.shape
    tile_r, tile_l = pick_tiles(h, w, tile_rows=tile_rows,
                                tile_lanes=tile_lanes)
    for name, t in (("tile_rows", tile_r), ("tile_lanes", tile_l)):
        if t < HALO:
            raise ValueError(
                f"{name}={t} < HALO={HALO}: the halo must fit inside one "
                f"neighbouring block")
    if dims is None:
        dims = jnp.broadcast_to(jnp.asarray([h, w], jnp.int32), (b, 2))
    nr = pl.cdiv(h, tile_r)
    nl = pl.cdiv(w, tile_l)
    kernel = functools.partial(_canny_kernel, tile_r=tile_r, tile_l=tile_l,
                               lo=lo, hi=hi)
    block = lambda f: pl.BlockSpec((1, tile_r, tile_l), f)  # noqa: E731

    def neighbour(di, dj):
        return block(lambda bi, i, j: (bi, jnp.clip(i + di, 0, nr - 1),
                                       jnp.clip(j + dj, 0, nl - 1)))

    in_specs = [pl.BlockSpec((1, 2), lambda bi, i, j: (bi, 0))]
    in_specs += [neighbour(di, dj) for di in (-1, 0, 1) for dj in (-1, 0, 1)]
    return pl.pallas_call(
        kernel,
        grid=(b, nr, nl),
        in_specs=in_specs,
        out_specs=block(lambda bi, i, j: (bi, i, j)),
        out_shape=jax.ShapeDtypeStruct((b, h, w), jnp.bool_),
        interpret=interpret,
    )(dims, *([img] * 9))

"""Dispatch wrapper for the fused Canny gateway kernel.

The 2D lane-tiled kernel accepts arbitrary frame sizes (the old
``MAX_WIDTH`` column limit is gone), so ``impl='auto'`` never falls back
to the staged oracle for shape reasons — backend availability alone picks
the implementation.

``canny_edge_batch`` is the ragged entry point the serving plane uses:
frames of mixed sizes are grouped into pad-and-mask buckets (one
``pallas_call`` per bucket, per-frame true dims masked in-kernel) instead
of launching once per frame.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import ref


def canny_edge(img, lo: float = 0.6, hi: float = 1.0, *,
               impl: str = "auto", tile_rows: int | None = None,
               tile_lanes: int | None = None):
    """img [B,H,W] f32 -> edge map [B,H,W] bool.

    impl: 'auto' (pallas on TPU, xla oracle elsewhere) | 'xla' | 'pallas'
    (TPU megakernel) | 'interpret' (CPU parity check).  The 2D-tiled
    kernel serves any frame size, so auto never falls back on width.
    """
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"
    if impl == "xla":
        return ref.canny_edge(img, lo, hi)
    from .canny_fused import canny_edge_pallas
    return canny_edge_pallas(img, lo=lo, hi=hi, tile_rows=tile_rows,
                             tile_lanes=tile_lanes,
                             interpret=(impl == "interpret"))


# repro-lint: disable=ECO704 -- host-side bucket geometry, no kernel
# dispatch to verify against an oracle
def bucket_shape(h: int, w: int) -> tuple[int, int]:
    """Padded bucket shape for a ragged frame: rounds h up to 64 and w up
    to 128 so nearby frame sizes share one compiled kernel instance
    instead of triggering a recompile per unique (h, w)."""
    return (-(-h // 64) * 64, -(-w // 128) * 128)


def canny_edge_batch(frames, lo: float = 0.6, hi: float = 1.0, *,
                     impl: str = "auto", tile_rows: int | None = None,
                     tile_lanes: int | None = None) -> list[np.ndarray]:
    """Ragged batch entry point: frames is a sequence of [H,W] f32 arrays
    of possibly different sizes; returns per-frame [H,W] bool edge maps in
    input order.

    Pallas/interpret path: frames are grouped by ``bucket_shape``,
    zero-padded into one [Nb,Hb,Wb] tensor per bucket, and served by ONE
    ``pallas_call`` per bucket with per-frame true dims passed through the
    kernel's pad-and-mask plane (out-of-frame output is guaranteed False;
    the host crop just drops it).  XLA path: one oracle call per
    exact-shape group.
    """
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"
    frames = [np.asarray(f, np.float32) for f in frames]
    out: list[np.ndarray | None] = [None] * len(frames)

    if impl == "xla":
        groups: dict[tuple[int, int], list[int]] = {}
        for i, f in enumerate(frames):
            groups.setdefault(f.shape, []).append(i)
        for shape, idxs in groups.items():
            batch = jnp.asarray(np.stack([frames[i] for i in idxs]))
            maps = np.asarray(ref.canny_edge(batch, lo, hi))
            for j, i in enumerate(idxs):
                out[i] = maps[j]
        return out  # type: ignore[return-value]

    from .canny_fused import canny_edge_pallas
    buckets: dict[tuple[int, int], list[int]] = {}
    for i, f in enumerate(frames):
        buckets.setdefault(bucket_shape(*f.shape), []).append(i)
    for (bh, bw), idxs in buckets.items():
        batch = np.zeros((len(idxs), bh, bw), np.float32)
        dims = np.empty((len(idxs), 2), np.int32)
        for j, i in enumerate(idxs):
            h, w = frames[i].shape
            batch[j, :h, :w] = frames[i]
            dims[j] = (h, w)
        maps = np.asarray(canny_edge_pallas(
            jnp.asarray(batch), jnp.asarray(dims), lo=lo, hi=hi,
            tile_rows=tile_rows, tile_lanes=tile_lanes,
            interpret=(impl == "interpret")))
        for j, i in enumerate(idxs):
            h, w = frames[i].shape
            out[i] = maps[j, :h, :w]
    return out  # type: ignore[return-value]

"""Dispatch wrapper for the fused Canny gateway kernel."""
from __future__ import annotations

import jax

from . import ref


def canny_edge(img, lo: float = 0.6, hi: float = 1.0, *,
               impl: str = "auto", tile_rows: int | None = None):
    """img [B,H,W] f32 -> edge map [B,H,W] bool.

    impl: 'auto' (pallas on TPU, xla oracle elsewhere; frames wider than
    the row-tiled kernel's ``MAX_WIDTH`` column limit fall back to the xla
    oracle) | 'xla' | 'pallas' (TPU megakernel; fails fast on wide frames)
    | 'interpret' (CPU parity check).
    """
    if impl == "auto":
        from .canny_fused import MAX_WIDTH
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"
        if img.shape[-1] > MAX_WIDTH:
            # auto picks the implementation that can serve the frame;
            # explicit impl='pallas' keeps the fail-fast ValueError
            impl = "xla"
    if impl == "xla":
        return ref.canny_edge(img, lo, hi)
    from .canny_fused import canny_edge_pallas
    return canny_edge_pallas(img, lo=lo, hi=hi, tile_rows=tile_rows,
                             interpret=(impl == "interpret"))

"""jnp parity oracle for the fused Canny gateway kernel.

``canny_edge`` is the SINGLE semantic definition of the gateway's edge-map
stage: gaussian blur -> Sobel gradients -> direction-quantized non-maximum
suppression -> double threshold -> fixed-iteration hysteresis.  The Pallas
megakernel (canny_fused.py) must reproduce it bit-for-bit; the detection
pipeline (detection/canny.py) routes through ops.canny_edge which picks the
oracle on CPU and the kernel on TPU.

``canny_edge_staged`` runs the SAME stages as separate jit calls with a
device sync between each — the "unfused" baseline benchmarks/run.py times
against the fused paths.  Stage-per-dispatch is how the seed pipeline
behaved from the scheduler's point of view: every stage a full HBM
round-trip of the frame.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.sobel import ref as sobel_ref

HYSTERESIS_ITERS = 8


def gauss_kernel(sigma: float = 1.0, radius: int = 2):
    xs = jnp.arange(-radius, radius + 1)
    k = jnp.exp(-0.5 * (xs / sigma) ** 2)
    return k / k.sum()


def gaussian_blur(img, sigma: float = 1.0):
    """Separable 5-tap gaussian, batch [B,H,W] (horizontal then vertical)."""
    r = 2
    k = gauss_kernel(sigma, r)
    pad = jnp.pad(img, ((0, 0), (0, 0), (r, r)), mode="edge")
    h = sum(pad[:, :, i:i + img.shape[2]] * k[i] for i in range(2 * r + 1))
    padv = jnp.pad(h, ((0, 0), (r, r), (0, 0)), mode="edge")
    return sum(padv[:, i:i + img.shape[1], :] * k[i]
               for i in range(2 * r + 1))


def nms(mag, q):
    """Thin edges: keep pixels that are maxima along their quantized
    gradient direction (zero-padded neighbours at the frame border)."""
    h, w = mag.shape[1], mag.shape[2]
    p = jnp.pad(mag, ((0, 0), (1, 1), (1, 1)))
    c = p[:, 1:h + 1, 1:w + 1]
    neigh = [
        (p[:, 1:h + 1, 2:], p[:, 1:h + 1, :w]),        # 0: E/W
        (p[:, 2:, 2:], p[:, :h, :w]),                  # 1: SE/NW
        (p[:, 2:, 1:w + 1], p[:, :h, 1:w + 1]),        # 2: S/N
        (p[:, 2:, :w], p[:, :h, 2:]),                  # 3: SW/NE
    ]
    keep = jnp.zeros_like(c, bool)
    for d, (a, b2) in enumerate(neigh):
        m = (q == d) & (c >= a) & (c >= b2)
        keep = keep | m
    return mag * keep


def hysteresis(thin, lo: float, hi: float):
    """Double threshold, then grow strong edges into weak ones for a fixed
    number of dilation rounds (zero-padded at the frame border)."""
    h, w = thin.shape[1], thin.shape[2]
    strong = thin > hi
    weak = thin > lo

    def grow(s, _):
        sp = jnp.pad(s, ((0, 0), (1, 1), (1, 1)))
        dil = (sp[:, :h, 1:w + 1] | sp[:, 2:, 1:w + 1] | sp[:, 1:h + 1, :w]
               | sp[:, 1:h + 1, 2:] | sp[:, :h, :w] | sp[:, :h, 2:]
               | sp[:, 2:, :w] | sp[:, 2:, 2:] | s)
        return dil & weak, None

    strong, _ = jax.lax.scan(grow, strong, None, length=HYSTERESIS_ITERS)
    return strong


@functools.partial(jax.jit, static_argnames=("lo", "hi"))
def canny_edge(img, lo: float = 0.6, hi: float = 1.0):
    """img [B,H,W] f32 -> edge map [B,H,W] bool (one fused XLA program)."""
    sm = gaussian_blur(img)
    mag, q = sobel_ref.sobel_grad(sm)
    thin = nms(mag, q)
    return hysteresis(thin, lo, hi)


# ------------------------------------------------- unfused benchmark baseline

_blur_jit = jax.jit(gaussian_blur)
_sobel_jit = jax.jit(sobel_ref.sobel_grad)
_nms_jit = jax.jit(nms)
_hyst_jit = jax.jit(hysteresis, static_argnames=("lo", "hi"))


def canny_edge_staged(img, lo: float = 0.6, hi: float = 1.0):
    """Stage-per-dispatch Canny: same maths as ``canny_edge`` but each stage
    is its own jit call with a sync in between (the per-stage-HBM-round-trip
    cost model the fused paths eliminate).  Benchmark baseline only."""
    sm = jax.block_until_ready(_blur_jit(img))
    mag, q = _sobel_jit(sm)
    jax.block_until_ready(mag)
    thin = jax.block_until_ready(_nms_jit(mag, q))
    return jax.block_until_ready(_hyst_jit(thin, lo, hi))

from .ops import decode  # noqa: F401

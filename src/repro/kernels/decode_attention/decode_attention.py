"""Pallas TPU flash-decode kernel: one query token vs. a long KV cache.

Grid: (batch, q_heads, num_k_blocks); the k-block axis iterates sequentially
with the flash (max, denom, acc) state in VMEM scratch.  The per-request
valid length lives in SMEM; blocks past the length (or before the sliding
window) are skipped entirely — this is the memory-bound kernel the
decode_32k/long_500k roofline terms are about, so skipping dead blocks is
the point.

The §Perf flash-decode sharding splits the cache's sequence dim over the
'model' mesh axis and merges per-shard (m, l, acc) with a tiny all-reduce;
this kernel is the per-shard worker in that scheme.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref,
                   l_ref, *, scale: float, window: Optional[int],
                   softcap: Optional[float], bk: int, nk: int):
    ik = pl.program_id(2)
    length = len_ref[0]

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    col0 = ik * bk
    needed = col0 < length
    if window is not None:
        needed = jnp.logical_and(needed, col0 + bk > length - window)

    @pl.when(needed)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)        # [1, D]
        k = k_ref[0, 0].astype(jnp.float32)        # [BK, D]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = jnp.tanh(s / softcap) * softcap    # [1, BK]
        cols = col0 + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
        ok = cols < length
        if window is not None:
            ok = jnp.logical_and(ok, cols >= length - window)
        s = jnp.where(ok, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _fin():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("scale", "window", "softcap", "block_k", "interpret"))
def decode_attention(q, k, v, lengths, *, scale: Optional[float] = None,
                     window: Optional[int] = None,
                     softcap: Optional[float] = None, block_k: int = 256,
                     interpret: bool = False):
    """q [B, H, D]; k, v [B, KV, T, D]; lengths [B] -> [B, H, D]."""
    b, h, d = q.shape
    kv, t = k.shape[1], k.shape[2]
    g = h // kv
    scale = scale if scale is not None else d ** -0.5
    bk = min(block_k, t)
    assert t % bk == 0
    nk = t // bk
    q4 = q[:, :, None, :]  # [B, H, 1, D]

    kernel = functools.partial(_decode_kernel, scale=scale, window=window,
                               softcap=softcap, bk=bk, nk=nk)
    out = pl.pallas_call(
        kernel,
        grid=(b, h, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda b_, h_, ik: (b_,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, 1, d), lambda b_, h_, ik: (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h_, ik: (b_, h_ // g, ik, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h_, ik: (b_, h_ // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, d), lambda b_, h_, ik: (b_, h_, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, 1, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, d), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
        ],
        interpret=interpret,
    )(lengths, q4, k, v)
    return out[:, :, 0, :]

"""Dispatch wrapper for flash-decode attention."""
from __future__ import annotations

import functools

import jax

from . import ref


@functools.partial(jax.jit, static_argnames=("scale", "window", "softcap",
                                             "impl"))
def decode(q, k, v, lengths, *, scale=None, window=None, softcap=None,
           impl: str = "xla"):
    """q [B,H,D]; k,v [B,KV,T,D]; lengths [B].  impl: xla|pallas|interpret."""
    if impl == "xla":
        return ref.decode_reference(q, k, v, lengths, scale=scale,
                                    window=window, softcap=softcap)
    from .decode_attention import decode_attention
    return decode_attention(q, k, v, lengths, scale=scale, window=window,
                            softcap=softcap, interpret=(impl == "interpret"))

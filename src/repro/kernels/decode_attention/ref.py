"""Pure-jnp oracle for single-token GQA decode attention over a KV cache.

q [B, H, D]; k, v [B, KV, T, D]; lengths [B] (attend to positions < len).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_reference(q, k, v, lengths, *, scale: Optional[float] = None,
                     window: Optional[int] = None,
                     softcap: Optional[float] = None):
    b, h, d = q.shape
    kv, t = k.shape[1], k.shape[2]
    g = h // kv
    scale = scale if scale is not None else d ** -0.5
    qg = q.reshape(b, kv, g, d)
    s = jnp.einsum("bkgd,bktd->bkgt", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap
    cols = jnp.arange(t)[None, :]
    ok = cols < lengths[:, None]
    if window is not None:
        ok &= cols >= (lengths[:, None] - window)
    s = jnp.where(ok[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,bktd->bkgd", p, v.astype(jnp.float32))
    return out.reshape(b, h, d).astype(q.dtype)

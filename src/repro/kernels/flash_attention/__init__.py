from .ops import attention  # noqa: F401

"""Pallas TPU flash attention (prefill/train): causal GQA with optional
sliding window and logit softcap.

Grid: (batch, q_heads, num_q_blocks, num_k_blocks) — the last dim iterates
sequentially on TPU, carrying the running (max, denom, acc) flash state in
VMEM scratch.  K/V blocks index the kv head ``h // group`` (GQA).  Blocks
that the causal/window mask fully excludes are skipped via ``pl.when``
(this is what makes sliding-window attention sub-quadratic on TPU).

BlockSpec tiling: q/o [1, 1, BQ, D]; k/v [1, 1, BK, D]; all MXU-aligned for
D in {64, 128, 256} and BQ = BK = 128/256.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window: Optional[int],
                  softcap: Optional[float], bq: int, bk: int, nk: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    row0 = iq * bq
    col0 = ik * bk
    # is any element of this (q-block, k-block) tile visible?
    needed = True
    if causal:
        needed = col0 <= row0 + bq - 1
    if window is not None:
        needed = jnp.logical_and(needed, col0 + bk - 1 > row0 - window)

    @pl.when(needed)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = jnp.tanh(s / softcap) * softcap
        rows = row0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        cols = col0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        ok = jnp.ones((bq, bk), bool)
        if causal:
            ok &= cols <= rows
        if window is not None:
            ok &= cols > rows - window
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _fin():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("scale", "causal", "window", "softcap", "block_q",
                     "block_k", "interpret"))
def flash_attention(q, k, v, *, scale: Optional[float] = None,
                    causal: bool = True, window: Optional[int] = None,
                    softcap: Optional[float] = None, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False):
    """q [B, H, S, D]; k, v [B, KV, T, D] -> [B, H, S, D]."""
    b, h, s, d = q.shape
    kv, t = k.shape[1], k.shape[2]
    g = h // kv
    scale = scale if scale is not None else d ** -0.5
    bq = min(block_q, s)
    bk = min(block_k, t)
    assert s % bq == 0 and t % bk == 0, (s, bq, t, bk)
    nq, nk = s // bq, t // bk

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, bq=bq, bk=bk, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h_, iq, ik: (b_, h_ // g, ik, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h_, iq, ik: (b_, h_ // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),   # acc
            pltpu.VMEM((bq,), jnp.float32),     # running max
            pltpu.VMEM((bq,), jnp.float32),     # running denom
        ],
        interpret=interpret,
    )(q, k, v)

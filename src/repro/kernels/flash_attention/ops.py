"""Dispatch wrapper for flash attention."""
from __future__ import annotations

import functools

import jax

from . import ref


@functools.partial(jax.jit, static_argnames=("scale", "causal", "window",
                                             "softcap", "impl"))
def attention(q, k, v, *, scale=None, causal=True, window=None, softcap=None,
              impl: str = "xla"):
    """q [B,H,S,D]; k,v [B,KV,T,D].  impl: xla | pallas | interpret."""
    if impl == "xla":
        return ref.mha_reference(q, k, v, scale=scale, causal=causal,
                                 window=window, softcap=softcap)
    from .flash_attention import flash_attention
    return flash_attention(q, k, v, scale=scale, causal=causal,
                           window=window, softcap=softcap,
                           interpret=(impl == "interpret"))

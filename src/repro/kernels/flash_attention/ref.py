"""Pure-jnp oracle for causal (optionally sliding-window, softcapped) GQA
flash attention.  Layout: q [B, H, S, D]; k, v [B, KV, T, D]."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def mha_reference(q, k, v, *, scale: Optional[float] = None,
                  causal: bool = True, window: Optional[int] = None,
                  softcap: Optional[float] = None):
    b, h, s, d = q.shape
    kv = k.shape[1]
    g = h // kv
    scale = scale if scale is not None else d ** -0.5
    qg = q.reshape(b, kv, g, s, d)
    scores = jnp.einsum("bkgsd,bktd->bkgst", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if softcap is not None:
        scores = jnp.tanh(scores / softcap) * softcap
    t = k.shape[2]
    rows = jnp.arange(s)[:, None]
    cols = jnp.arange(t)[None, :]
    ok = jnp.ones((s, t), bool)
    if causal:
        ok &= cols <= rows
    if window is not None:
        ok &= cols > rows - window
    scores = jnp.where(ok, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,bktd->bkgsd", p, v.astype(jnp.float32))
    return out.reshape(b, h, s, d).astype(q.dtype)

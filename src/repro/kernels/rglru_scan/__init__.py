from .ops import rglru, rglru_decode_step  # noqa: F401

"""Jit-friendly dispatch wrapper for the RG-LRU linear recurrence."""
from __future__ import annotations

from functools import partial

import jax

from . import ref


@partial(jax.jit, static_argnames=("impl", "return_final_state"))
def rglru(x, w_a, b_a, w_x, b_x, log_lambda, *, h0=None, impl: str = "xla",
          return_final_state: bool = False):
    if impl == "xla":
        return ref.rglru(x, w_a, b_a, w_x, b_x, log_lambda, h0,
                         return_final_state=return_final_state)
    from .rglru_scan import rglru_pallas  # lazy: pallas import
    return rglru_pallas(x, w_a, b_a, w_x, b_x, log_lambda, h0=h0,
                        return_final_state=return_final_state,
                        interpret=(impl == "interpret"))


rglru_decode_step = jax.jit(ref.rglru_decode_step)

"""Pure-jnp oracle for the RG-LRU gated linear recurrence (RecurrentGemma).

    r_t = sigmoid(W_a x_t + b_a)            (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)            (input gate)
    log a_t = -c * softplus(Lambda) * r_t   (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The scan itself (given per-step a_t, b_t) is a first-order linear recurrence
computed with an associative scan; the Pallas kernel implements the same
recurrence with an in-VMEM sequential loop blocked over the width dim.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

RGLRU_C = 8.0


def rglru_gates(x, w_a, b_a, w_x, b_x, log_lambda):
    """Compute per-step (a, b) for the recurrence.  x [b, s, w]."""
    f32 = jnp.float32
    xf = x.astype(f32)
    r = jax.nn.sigmoid(xf @ w_a.astype(f32) + b_a.astype(f32))
    i = jax.nn.sigmoid(xf @ w_x.astype(f32) + b_x.astype(f32))
    log_a = -RGLRU_C * jax.nn.softplus(log_lambda.astype(f32)) * r
    a = jnp.exp(log_a)
    # sqrt(1 - a^2) computed stably via log: 0.5*log1p(-exp(2 log_a))
    sq = jnp.exp(0.5 * jnp.log1p(-jnp.exp(2.0 * log_a) + 1e-12))
    b = sq * (i * xf)
    return a, b


def linear_scan(a, b, h0: Optional[jax.Array] = None):
    """h_t = a_t h_{t-1} + b_t over axis 1.  a, b [bsz, s, w] fp32."""
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(lhs, rhs):
        a_l, b_l = lhs
        a_r, b_r = rhs
        return a_l * a_r, a_r * b_l + b_r

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def rglru(x, w_a, b_a, w_x, b_x, log_lambda, h0=None, *,
          return_final_state: bool = False):
    """x [bsz, s, w] -> h [bsz, s, w] (x.dtype), optional final state fp32."""
    a, b = rglru_gates(x, w_a, b_a, w_x, b_x, log_lambda)
    h = linear_scan(a, b, h0)
    if return_final_state:
        return h.astype(x.dtype), h[:, -1]
    return h.astype(x.dtype)


def rglru_decode_step(x, w_a, b_a, w_x, b_x, log_lambda, h_prev):
    """x [bsz, w]; h_prev [bsz, w] fp32 -> (y, new_state)."""
    a, b = rglru_gates(x[:, None], w_a, b_a, w_x, b_x, log_lambda)
    h = a[:, 0] * h_prev + b[:, 0]
    return h.astype(x.dtype), h

"""Pallas TPU kernel for the RG-LRU linear recurrence.

h_t = a_t * h_{t-1} + b_t, elementwise over the width dim.  The recurrence
is sequential in time but embarrassingly parallel over (batch, width), so
the kernel blocks over width lanes (128-aligned) and runs an in-VMEM
``fori_loop`` over time — one HBM read per (a, b) element and one write per
h element, vs. the log-depth associative scan's multiple passes.

Grid: (batch, width_blocks).  Block [1, S, BW] must fit VMEM: S x BW x 4 B
x 3 buffers; for S = 4096, BW = 128 that is 6 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rglru_kernel(a_ref, b_ref, h0_ref, out_ref, *, seq: int):
    a = a_ref[0]    # [S, BW] f32
    b = b_ref[0]
    h0 = h0_ref[0]  # [BW]

    def step(t, h):
        h = a[t] * h + b[t]
        out_ref[0, t, :] = h
        return h

    jax.lax.fori_loop(0, seq, step, h0)


@functools.partial(jax.jit, static_argnames=("block_w", "interpret"))
def rglru_scan_pallas(a, b, h0=None, *, block_w: int = 128,
                      interpret: bool = False):
    """a, b [B, S, W] f32; h0 [B, W] -> h [B, S, W]."""
    bsz, s, w = a.shape
    if h0 is None:
        h0 = jnp.zeros((bsz, w), jnp.float32)
    bw = min(block_w, w)
    assert w % bw == 0
    kernel = functools.partial(_rglru_kernel, seq=s)
    return pl.pallas_call(
        kernel,
        grid=(bsz, w // bw),
        in_specs=[
            pl.BlockSpec((1, s, bw), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, s, bw), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, bw), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, s, bw), lambda i, j: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((bsz, s, w), jnp.float32),
        interpret=interpret,
    )(a.astype(jnp.float32), b.astype(jnp.float32), h0.astype(jnp.float32))


def rglru_pallas(x, w_a, b_a, w_x, b_x, log_lambda, h0=None, *,
                 return_final_state: bool = False, interpret: bool = False):
    """Full RG-LRU layer: gates in XLA, recurrence in the Pallas kernel."""
    from . import ref
    a, b = ref.rglru_gates(x, w_a, b_a, w_x, b_x, log_lambda)
    h = rglru_scan_pallas(a, b, h0, interpret=interpret)
    if return_final_state:
        return h.astype(x.dtype), h[:, -1]
    return h.astype(x.dtype)

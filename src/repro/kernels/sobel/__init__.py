from .ops import sobel_grad  # noqa: F401

"""Dispatch wrapper for the Sobel gradient kernel."""
from __future__ import annotations

import functools

import jax

from . import ref


@functools.partial(jax.jit, static_argnames=("impl",))
def sobel_grad(img, *, impl: str = "xla"):
    """impl: 'xla' (jnp oracle) | 'pallas' (TPU) | 'interpret' (CPU check)."""
    if impl == "xla":
        return ref.sobel_grad(img)
    from .sobel import sobel_grad_pallas
    return sobel_grad_pallas(img, interpret=(impl == "interpret"))

"""Pure-jnp oracle for the Sobel gradient stage of Canny edge detection."""
from __future__ import annotations

import jax.numpy as jnp


def sobel_grad(img):
    """img [B, H, W] f32 -> (magnitude [B,H,W], direction [B,H,W] int32).

    Direction is the gradient angle quantized to 4 bins (0=E/W, 1=NE/SW,
    2=N/S, 3=NW/SE) for the non-maximum-suppression stage.
    """
    x = jnp.pad(img, ((0, 0), (1, 1), (1, 1)), mode="edge")
    # 3x3 sobel via shifted slices
    tl = x[:, :-2, :-2]; tc = x[:, :-2, 1:-1]; tr = x[:, :-2, 2:]
    ml = x[:, 1:-1, :-2];                       mr = x[:, 1:-1, 2:]
    bl = x[:, 2:, :-2];  bc = x[:, 2:, 1:-1];  br = x[:, 2:, 2:]
    gx = (tr + 2 * mr + br) - (tl + 2 * ml + bl)
    gy = (bl + 2 * bc + br) - (tl + 2 * tc + tr)
    mag = jnp.sqrt(gx * gx + gy * gy)
    ang = jnp.arctan2(gy, gx)  # [-pi, pi]
    # quantize to 4 direction bins (period pi)
    q = jnp.round(ang / (jnp.pi / 4)).astype(jnp.int32) % 4
    return mag, q

"""Pallas TPU kernel: Sobel gradient magnitude + quantized direction.

The gateway's Canny estimator runs on EVERY incoming frame, so the paper
treats it as the preprocessing hot-spot; this kernel keeps the whole image
tile resident in VMEM and fuses gradient, magnitude and direction
quantization in one pass (one HBM read, two writes).

Grid: one program per batch image (scene images are small: 64..256 px, so a
full [H, W] tile fits VMEM comfortably).  The gateway hot path no longer
calls this kernel: ``repro.kernels.canny_fused`` fuses blur/Sobel/NMS/
hysteresis into one row-tiled pallas_call with a 12-row halo, which also
covers the frames-larger-than-VMEM case.  This standalone kernel remains for
callers that want raw gradients.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sobel_kernel(img_ref, mag_ref, dir_ref):
    x = img_ref[0]  # [H, W] in VMEM
    h, w = x.shape
    # edge-replicated pad, then shifted slices (all in-register/VMEM)
    xp = jnp.pad(x, ((1, 1), (1, 1)), mode="edge")
    tl = xp[:-2, :-2]; tc = xp[:-2, 1:-1]; tr = xp[:-2, 2:]
    ml = xp[1:-1, :-2];                     mr = xp[1:-1, 2:]
    bl = xp[2:, :-2];  bc = xp[2:, 1:-1];  br = xp[2:, 2:]
    gx = (tr + 2 * mr + br) - (tl + 2 * ml + bl)
    gy = (bl + 2 * bc + br) - (tl + 2 * tc + tr)
    mag_ref[0] = jnp.sqrt(gx * gx + gy * gy)
    ang = jnp.arctan2(gy, gx)
    dir_ref[0] = jnp.round(ang / (jnp.pi / 4)).astype(jnp.int32) % 4


@functools.partial(jax.jit, static_argnames=("interpret",))
def sobel_grad_pallas(img, *, interpret: bool = False):
    """img [B, H, W] f32 -> (mag [B,H,W] f32, dir [B,H,W] int32)."""
    b, h, w = img.shape
    return pl.pallas_call(
        _sobel_kernel,
        grid=(b,),
        in_specs=[pl.BlockSpec((1, h, w), lambda i: (i, 0, 0))],
        out_specs=[pl.BlockSpec((1, h, w), lambda i: (i, 0, 0)),
                   pl.BlockSpec((1, h, w), lambda i: (i, 0, 0))],
        out_shape=[jax.ShapeDtypeStruct((b, h, w), jnp.float32),
                   jax.ShapeDtypeStruct((b, h, w), jnp.int32)],
        interpret=interpret,
    )(img)

from .ops import ssd, ssd_decode_step  # noqa: F401

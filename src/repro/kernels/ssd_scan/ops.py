"""Jit-friendly dispatch wrapper for the SSD scan.

``impl``:
  'xla'       — pure-jnp chunked reference (CPU tests, dry-run lowering)
  'pallas'    — TPU Pallas kernel (compiled for TPU)
  'interpret' — Pallas kernel in interpret mode (CPU correctness checks)
"""
from __future__ import annotations

from functools import partial

import jax

from . import ref


@partial(jax.jit, static_argnames=("chunk", "impl", "return_final_state"))
def ssd(x, dt, A, B, C, D, *, chunk: int = 256, impl: str = "xla",
        init_state=None, return_final_state: bool = False):
    if impl == "xla":
        return ref.ssd_chunked(x, dt, A, B, C, D, chunk=chunk,
                               init_state=init_state,
                               return_final_state=return_final_state)
    from .ssd_scan import ssd_pallas  # lazy: pallas import
    return ssd_pallas(x, dt, A, B, C, D, chunk=chunk,
                      init_state=init_state,
                      return_final_state=return_final_state,
                      interpret=(impl == "interpret"))


ssd_decode_step = jax.jit(ref.ssd_decode_step)

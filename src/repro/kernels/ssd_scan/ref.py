"""Pure-jnp oracle for the Mamba-2 SSD (state-space duality) chunked scan.

Math (per head, state dim N, head dim P):
    h_t = exp(A * dt_t) * h_{t-1} + dt_t * B_t x_t^T          (h in R^{P x N})
    y_t = C_t^T-contraction of h_t  + D * x_t

Chunked form [arXiv:2405.21060]: intra-chunk quadratic "attention" term with
decay matrix L, plus inter-chunk recurrence over per-chunk final states.
This file is the correctness oracle for the Pallas kernel and the XLA path
used by the Mamba2 model on CPU/dry-run.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def segsum(a):
    """a [..., Q] -> lower-triangular cumulative sums M[i,j] = sum_{j<k<=i} a_k."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    m = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool), k=0)
    return jnp.where(mask, m, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, D, *, chunk: int,
                init_state: Optional[jax.Array] = None,
                return_final_state: bool = False):
    """SSD forward.

    x:  [b, s, h, p]   inputs (already gated/conved)
    dt: [b, s, h]      positive step sizes (softplus applied by caller)
    A:  [h]            negative decay rates (A < 0)
    B:  [b, s, n]      input projection (n_groups = 1, broadcast over heads)
    C:  [b, s, n]      output projection
    D:  [h]            skip connection
    Returns y [b, s, h, p] (fp32 internally, cast to x.dtype).
    """
    b, s_orig, h, p = x.shape
    n = B.shape[-1]
    q = min(chunk, s_orig)
    # pad seq to a chunk multiple; dt=0 on pads => decay 1, no input => state
    # passes through unchanged and padded outputs are sliced off.
    s = ((s_orig + q - 1) // q) * q
    if s != s_orig:
        pad = ((0, 0), (0, s - s_orig), (0, 0))
        x = jnp.pad(x, pad + ((0, 0),))
        dt = jnp.pad(dt, pad)
        B = jnp.pad(B, pad)
        C = jnp.pad(C, pad)
    c = s // q
    f32 = jnp.float32

    xd = (x.astype(f32) * dt.astype(f32)[..., None]).reshape(b, c, q, h, p)
    a = (A.astype(f32) * dt.astype(f32)).reshape(b, c, q, h)  # log-decay per step
    Bc = B.astype(f32).reshape(b, c, q, n)
    Cc = C.astype(f32).reshape(b, c, q, n)

    a_cum = jnp.cumsum(a, axis=2)  # [b,c,q,h]

    # ---- intra-chunk (diagonal) term
    L = jnp.exp(segsum(jnp.moveaxis(a, 3, 2)))        # [b,c,h,q,q]
    scores = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)    # [b,c,q,q]
    y_diag = jnp.einsum("bchij,bcij,bcjhp->bcihp", L, scores, xd)

    # ---- per-chunk final states
    decay_out = jnp.exp(a_cum[:, :, -1:, :] - a_cum)  # [b,c,q,h]
    states = jnp.einsum("bcqn,bcqh,bcqhp->bchpn", Bc, decay_out, xd)

    # ---- inter-chunk recurrence over chunk states
    chunk_decay = jnp.exp(a_cum[:, :, -1, :])  # [b,c,h]

    def step(h_prev, inp):
        dec, st = inp  # dec [b,h], st [b,h,p,n]
        h_new = h_prev * dec[:, :, None, None] + st
        return h_new, h_prev  # emit state *entering* the chunk

    h0 = (init_state.astype(f32) if init_state is not None
          else jnp.zeros((b, h, p, n), f32))
    h_final, h_in = jax.lax.scan(
        step, h0,
        (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(states, 1, 0)))
    h_in = jnp.moveaxis(h_in, 0, 1)  # [b,c,h,p,n] state at chunk start

    # ---- inter-chunk (off-diagonal) output term
    decay_in = jnp.exp(a_cum)  # decay from chunk start to position q
    y_off = jnp.einsum("bcqn,bcqh,bchpn->bcqhp", Cc, decay_in, h_in)

    y = (y_diag + y_off).reshape(b, s, h, p)
    y = y + x.astype(f32) * D.astype(f32)[None, None, :, None]
    y = y[:, :s_orig].astype(x.dtype)
    if return_final_state:
        return y, h_final.astype(f32)
    return y


def ssd_decode_step(x, dt, A, B, C, D, state):
    """Single-token recurrence.

    x [b,h,p]; dt [b,h]; B,C [b,n]; state [b,h,p,n] -> (y [b,h,p], new_state).
    """
    f32 = jnp.float32
    xf, dtf = x.astype(f32), dt.astype(f32)
    decay = jnp.exp(A.astype(f32)[None] * dtf)  # [b,h]
    upd = jnp.einsum("bhp,bn->bhpn", xf * dtf[..., None], B.astype(f32))
    new_state = state * decay[:, :, None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", new_state, C.astype(f32))
    y = y + xf * D.astype(f32)[None, :, None]
    return y.astype(x.dtype), new_state

"""Pallas TPU kernel for the Mamba-2 SSD chunked scan.

Grid: (batch, heads, chunks) — the chunk axis iterates sequentially,
carrying the inter-chunk SSM state [P, N] in VMEM scratch.  Each program
computes one chunk's quadratic intra-term (two [Q, Q]-shaped MXU matmuls)
plus the contribution of the carried state, then updates the state — the
classic SSD dataflow [arXiv:2405.21060] with the state kept on-chip instead
of streamed through HBM.

Block shapes (Q = chunk 256, P = 64, N = 128) are MXU-aligned and total
< 1 MB VMEM per program.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, A_ref, B_ref, C_ref, D_ref, y_ref, state_ref,
                *, q: int, nc: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0].astype(jnp.float32)     # [Q, P]
    dt = dt_ref[0, 0].astype(jnp.float32)   # [Q]
    A = A_ref[0]                            # scalar (per head)
    Bm = B_ref[0].astype(jnp.float32)       # [Q, N]
    Cm = C_ref[0].astype(jnp.float32)       # [Q, N]
    D = D_ref[0]

    xd = x * dt[:, None]
    a = A * dt                               # [Q] log-decay
    a_cum = jnp.cumsum(a)                    # [Q]
    # intra-chunk decay matrix L[i,j] = exp(acum_i - acum_j) for j <= i
    seg = a_cum[:, None] - a_cum[None, :]
    mask = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    L = jnp.where(mask, jnp.exp(seg), 0.0)
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # [Q,Q]
    y_diag = jax.lax.dot(L * scores, xd,
                         preferred_element_type=jnp.float32)          # [Q,P]
    # contribution of the carried state
    decay_in = jnp.exp(a_cum)[:, None]                                # [Q,1]
    y_off = jax.lax.dot(Cm * decay_in, state_ref[...].T,
                        preferred_element_type=jnp.float32)           # [Q,P]
    y_ref[0, 0] = (y_diag + y_off + x * D).astype(y_ref.dtype)
    # state update: S' = exp(sum a) * S + sum_j exp(acum_Q - acum_j) xd_j B_j^T
    decay_out = jnp.exp(a_cum[-1] - a_cum)[:, None]                   # [Q,1]
    upd = jax.lax.dot_general(xd * decay_out, Bm, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)     # [P,N]
    state_ref[...] = state_ref[...] * jnp.exp(a_cum[-1]) + upd


@functools.partial(jax.jit, static_argnames=("chunk", "interpret",
                                             "return_final_state"))
def ssd_pallas(x, dt, A, B, C, D, *, chunk: int = 256, init_state=None,
               return_final_state: bool = False, interpret: bool = False):
    """Same contract as kernels.ssd_scan.ref.ssd_chunked (init_state=None)."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    q = min(chunk, s)
    assert s % q == 0, (s, q)
    nc = s // q
    assert init_state is None, "pallas path starts from zero state"
    # layouts: per-(batch, head, chunk) blocks
    xt = jnp.moveaxis(x, 2, 1)                        # [B, H, S, P]
    dtt = jnp.moveaxis(dt, 2, 1)                      # [B, H, S]
    kernel = functools.partial(_ssd_kernel, q=q, nc=nc)
    out = pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, q, p), lambda i, j, c: (i, j, c, 0)),
            pl.BlockSpec((1, 1, q), lambda i, j, c: (i, j, c)),
            pl.BlockSpec((1,), lambda i, j, c: (j,), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, q, n), lambda i, j, c: (i, c, 0)),
            pl.BlockSpec((1, q, n), lambda i, j, c: (i, c, 0)),
            pl.BlockSpec((1,), lambda i, j, c: (j,), memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((1, 1, q, p), lambda i, j, c: (i, j, c, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(xt, dtt, A.astype(jnp.float32), B, C, D.astype(jnp.float32))
    y = jnp.moveaxis(out, 1, 2)  # [B, S, H, P]
    if return_final_state:
        # final state is recomputed on the XLA path when needed (prefill);
        # kernel keeps it in scratch only.
        from . import ref
        _, st = ref.ssd_chunked(x, dt, A, B, C, D, chunk=chunk,
                                return_final_state=True)
        return y, st
    return y

import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
# NOTE: the two lines above MUST run before any jax import (jax locks the
# device count at first init).  Everything else follows.
"""Multi-pod dry-run: lower + compile every (arch x input-shape) combination
for the production meshes, prove memory fit, and extract roofline terms.

  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod --out artifacts/

Outputs one JSON row per combination (see repro.launch.roofline.Roofline.row)
plus the compiled memory analysis, appended to ``--out``/dryrun.jsonl.
"""
import argparse
import json
import sys
import time
import traceback
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, list_configs
from repro.launch import hlo_cost
from repro.launch import roofline as rl
from repro.launch import steps as st
from repro.launch.mesh import make_production_mesh
from repro.models.base import INPUT_SHAPES
from repro.optim.adamw import AdamWConfig
from repro.sharding import specs as sp
from repro.sharding import ctx

# per-arch gradient-accumulation factor for train_4k (keeps per-device
# activation memory ~<2 GB; see DESIGN.md §4)
MICROBATCHES = {
    "llava-next-34b": 16,
    "llama3-8b": 8, "llama3-8b-swa": 8,
    "gemma2-9b": 8, "gemma2-9b-swa": 8,
    "deepseek-7b": 8,
    "qwen2.5-3b": 4,
    "deepseek-v2-lite-16b": 4,
    "recurrentgemma-2b": 4,
    "mamba2-370m": 4,
    "granite-moe-1b-a400m": 8,
    "whisper-small": 16,
}


def skip_reason(cfg, shape) -> Optional[str]:
    if shape.name == "long_500k" and not cfg.is_subquadratic:
        return ("long_500k requires sub-quadratic attention; "
                f"{cfg.name} has unbounded full-attention layers "
                "(see DESIGN.md §5)")
    return None


def lower_combo(cfg, shape, mesh, *, microbatches: Optional[int] = None):
    """Returns the lowered step for one (arch, shape, mesh)."""
    baxes = sp.batch_axes(mesh)
    n_bshards = 1
    for a in baxes:
        n_bshards *= mesh.shape[a]
    mode = "train" if shape.kind == "train" else "serve"
    with ctx.activation_sharding(baxes, n_bshards, mesh=mesh, mode=mode):
        return _lower_combo(cfg, shape, mesh, baxes, microbatches)


def _lower_combo(cfg, shape, mesh, baxes, microbatches):
    if shape.kind == "train":
        mb = microbatches or MICROBATCHES.get(cfg.name, 4)
        step = st.make_train_step(cfg, AdamWConfig(), num_microbatches=mb,
                                  batch_axes=baxes)
        params = st.param_structs(cfg)
        pspecs = sp.param_specs(params, mode="train", mesh=mesh)
        opts = st.opt_structs(params)
        ospecs = st.OptState(step=P(), mu=pspecs, nu=pspecs)
        batch = st.batch_specs(cfg, shape)
        bspecs = {k: sp.batch_spec(mesh, shape.global_batch, v.ndim)
                  for k, v in batch.items()}
        fn = jax.jit(
            step,
            in_shardings=(sp.shard(mesh, pspecs), sp.shard(mesh, ospecs),
                          sp.shard(mesh, bspecs)),
            out_shardings=(sp.shard(mesh, pspecs), sp.shard(mesh, ospecs),
                           None),
            donate_argnums=(0, 1))
        with mesh:
            return fn.lower(params, opts, batch)
    if shape.kind == "prefill":
        step = st.make_prefill_step(cfg)
        params = st.param_structs(cfg, serve=True)
        pspecs = sp.param_specs(params, mode="serve", mesh=mesh)
        batch = st.batch_specs(cfg, shape)
        bspecs = {k: sp.batch_spec(mesh, shape.global_batch, v.ndim)
                  for k, v in batch.items()}
        fn = jax.jit(step,
                     in_shardings=(sp.shard(mesh, pspecs),
                                   sp.shard(mesh, bspecs)))
        with mesh:
            return fn.lower(params, batch)
    # decode
    step = st.make_decode_step(cfg)
    params = st.param_structs(cfg, serve=True)
    pspecs = sp.param_specs(params, mode="serve", mesh=mesh)
    token, cache = st.decode_input_specs(cfg, shape)
    tspec = sp.batch_spec(mesh, shape.global_batch, 2)
    cspecs = sp.cache_specs(cache, mesh, shape.global_batch)
    fn = jax.jit(step,
                 in_shardings=(sp.shard(mesh, pspecs),
                               NamedSharding(mesh, tspec),
                               sp.shard(mesh, cspecs)),
                 out_shardings=(None, sp.shard(mesh, cspecs)),
                 donate_argnums=(2,))
    with mesh:
        return fn.lower(params, token, cache)


def run_combo(arch: str, shape_name: str, *, multi_pod: bool = False,
              verbose: bool = True, save_hlo: Optional[str] = None):
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    reason = skip_reason(cfg, shape)
    if reason:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skip", "reason": reason}
    t0 = time.time()
    lowered = lower_combo(cfg, shape, mesh)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()

    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # jax <= 0.4.x wraps the dict in a list
        cost = cost[0] if cost else {}
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(hlo)
    # trip-count-aware per-chip cost (XLA cost_analysis counts loop bodies
    # once; see repro.launch.hlo_cost)
    hc = hlo_cost.analyze(hlo)
    counts = rl.count_params(cfg)
    r = rl.Roofline(
        arch=arch, shape=shape_name, mesh=mesh_name,
        chips=mesh.devices.size,
        flops=hc.flops,
        bytes_accessed=hc.bytes,
        coll_bytes=hc.coll_bytes,
        coll_by_kind=hc.coll,
        per_device_memory=rl.memory_bytes(mem),
        model_flops=rl.model_flops(cfg, shape, counts["total"],
                                   counts["active"]),
    )
    row = r.row()
    row.update(status="ok", lower_s=round(t1 - t0, 1),
               compile_s=round(t2 - t1, 1),
               params_total=counts["total"], params_active=counts["active"],
               xla_flops=float(cost.get("flops", 0.0)),
               xla_bytes=float(cost.get("bytes accessed", 0.0)))
    if verbose:
        print(f"--- {arch} x {shape_name} x {mesh_name} ---")
        print(f"memory_analysis: temp={getattr(mem,'temp_size_in_bytes',0)/2**30:.2f}GiB "
              f"args={getattr(mem,'argument_size_in_bytes',0)/2**30:.2f}GiB "
              f"out={getattr(mem,'output_size_in_bytes',0)/2**30:.2f}GiB")
        print(f"cost_analysis: flops/chip={r.flops:.3e} bytes/chip={r.bytes_accessed:.3e}")
        print(f"roofline: compute={r.t_compute*1e3:.2f}ms memory={r.t_memory*1e3:.2f}ms "
              f"collective={r.t_collective*1e3:.2f}ms -> {r.bottleneck}-bound; "
              f"useful-flops={r.useful_flops_ratio:.2f}")
    return row


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--include-variants", action="store_true")
    ap.add_argument("--out", default="artifacts")
    args = ap.parse_args(argv)

    archs = ([args.arch] if args.arch
             else list_configs(include_variants=args.include_variants))
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    os.makedirs(args.out, exist_ok=True)
    out_path = os.path.join(args.out, "dryrun.jsonl")
    failures = 0
    with open(out_path, "a") as f:
        for arch in archs:
            for shape in shapes:
                for mp in meshes:
                    try:
                        row = run_combo(arch, shape, multi_pod=mp)
                    except Exception as e:  # a failure here is a bug: report
                        traceback.print_exc()
                        row = {"arch": arch, "shape": shape,
                               "mesh": "2x16x16" if mp else "16x16",
                               "status": "fail", "error": repr(e)}
                        failures += 1
                    f.write(json.dumps(row) + "\n")
                    f.flush()
    print(f"wrote {out_path}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())

"""Trip-count-aware cost analysis of optimized HLO text.

``compiled.cost_analysis()`` counts a ``while`` body ONCE regardless of trip
count, which silently drops ~Nx the FLOPs/bytes of scan-over-layers models
(and misses per-layer FSDP all-gathers entirely).  This module re-derives
per-chip FLOPs / HBM bytes / collective bytes by walking the compiled HLO
text:

  * while ops are multiplied by ``backend_config.known_trip_count``
  * fusions contribute boundary bytes only (internal ops don't touch HBM)
    plus the dot FLOPs of their fused computation
  * dynamic-slice/-update-slice count slice bytes, not full-buffer bytes
    (XLA aliases the buffer; only the slice moves)
  * collectives are weighted per kind (all-reduce 2x for ring R-S + A-G)

The result is an approximation (elementwise FLOPs are counted 1/elem, sort
comparators ignored), but it is *consistent* across architectures and loop
structures, which is what the roofline comparison needs.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "c64": 8, "s64": 8, "u64": 8, "f64": 8, "c128": 16,
}

COLLECTIVE_FACTORS = {
    "all-gather": 1.0,
    "all-reduce": 2.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_SHAPE_TOK = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\((.*)\)\s*->")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([a-z][\w\-]*)\(")
_TRIP = re.compile(r'known_trip_count[":{\s]+n["\s:]+(\d+)')
_CALLS = re.compile(r"calls=%?([\w.\-]+)")
_BODY = re.compile(r"body=%?([\w.\-]+)")
_COND = re.compile(r"condition=%?([\w.\-]+)")
_TO_APPLY = re.compile(r"to_apply=%?([\w.\-]+)")
_LHS_C = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERANDS = re.compile(r"%([\w.\-]+)")
_PARAM = re.compile(r"([\w.\-]+):\s*((?:\([^)]*\)|[\w\[\],{}\s/]+?))(?:,(?=\s*[\w.\-]+:)|$)")


def shape_elems(shape_str: str) -> int:
    total = 0
    for _, dims in _SHAPE_TOK.findall(shape_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n
    return total


def shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_TOK.findall(shape_str):
        b = _DTYPE_BYTES.get(dtype)
        if b is None:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * b
    return total


def shape_dims(shape_str: str) -> List[int]:
    m = _SHAPE_TOK.search(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVE_FACTORS})

    def __iadd__(self, o: "Cost"):
        self.flops += o.flops
        self.bytes += o.bytes
        for k in self.coll:
            self.coll[k] += o.coll[k]
        return self

    def scaled(self, f: float) -> "Cost":
        return Cost(self.flops * f, self.bytes * f,
                    {k: v * f for k, v in self.coll.items()})

    @property
    def coll_bytes(self) -> float:
        return sum(self.coll.values())


@dataclasses.dataclass
class Instr:
    name: str
    shape: str
    op: str
    operands: List[str]
    attrs: str
    is_root: bool = False


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.comps: Dict[str, List[Instr]] = {}
        self.symbols: Dict[str, Dict[str, str]] = {}
        self.entry: Optional[str] = None
        self._memo: Dict[str, Cost] = {}
        self._parse(hlo_text)

    # ------------------------------------------------------------ parsing
    def _parse(self, text: str):
        cur: Optional[str] = None
        for raw in text.splitlines():
            line = raw.rstrip()
            if not line:
                continue
            if not line.startswith(" ") and "->" in line and "{" in line:
                m = _COMP_HDR.match(line.strip())
                if m:
                    cur = m.group(1)
                    self.comps[cur] = []
                    self.symbols[cur] = {}
                    if line.strip().startswith("ENTRY"):
                        self.entry = cur
                    # parameter shapes from the header
                    for pname, pshape in _PARAM.findall(m.group(2)):
                        self.symbols[cur][pname] = pshape.strip()
                    continue
            if cur is None:
                continue
            m = _INSTR.match(line)
            if not m:
                continue
            is_root = line.lstrip().startswith("ROOT ")
            name, shape, op = m.group(1), m.group(2), m.group(3)
            # operand region: balanced parens after op name
            start = m.end() - 1
            depth = 0
            end = start
            for i in range(start, len(line)):
                if line[i] == "(":
                    depth += 1
                elif line[i] == ")":
                    depth -= 1
                    if depth == 0:
                        end = i
                        break
            operand_str = line[start + 1:end]
            attrs = line[end + 1:]
            operands = _OPERANDS.findall(operand_str)
            self.comps[cur].append(Instr(name, shape, op, operands, attrs,
                                         is_root))
            self.symbols[cur][name] = shape

    # ------------------------------------------------------------- costing
    def _operand_bytes(self, comp: str, operands: List[str]) -> float:
        tbl = self.symbols.get(comp, {})
        return float(sum(shape_bytes(tbl.get(o, "")) for o in operands))

    def _dot_flops(self, comp: str, ins: Instr) -> float:
        out_elems = shape_elems(ins.shape)
        contract = 1
        m = _LHS_C.search(ins.attrs)
        dims = shape_dims(self.symbols.get(comp, {}).get(
            ins.operands[0] if ins.operands else "", ""))
        if m and dims:
            for d in m.group(1).split(","):
                if d and int(d) < len(dims):
                    contract *= dims[int(d)]
        return 2.0 * out_elems * max(contract, 1)

    _TRANSPARENT = ("convert", "bitcast", "copy", "reshape", "transpose")

    def _fusion_boundary_bytes(self, called: str, fusion_ins: Instr) -> float:
        """TPU-equivalent HBM traffic of a fusion.

        XLA:CPU stores bf16 but computes f32, wrapping buffers in
        convert chains that a TPU build does not emit; converts/bitcasts are
        treated as *transparent* when attributing reads/writes.  Parameter
        reads are slice-sized when the (effective) consumer is a
        dynamic-slice and free when it is the aliased buffer operand of a
        dynamic-update-slice; the root write is update-sized for DUS roots.
        """
        instrs = self.comps.get(called)
        if not instrs:
            return float(shape_bytes(fusion_ins.shape))
        tbl = self.symbols.get(called, {})
        # pure dtype-shuffle fusions are free on TPU
        if all(i.op in self._TRANSPARENT + ("parameter", "tuple",
                                            "get-tuple-element", "constant")
               for i in instrs):
            return 0.0
        producers = {i.name: i for i in instrs}
        consumer_map: Dict[str, List[Tuple[Instr, int]]] = {}
        root = None
        for ins in instrs:
            if ins.is_root:
                root = ins
            for idx, o in enumerate(ins.operands):
                consumer_map.setdefault(o, []).append((ins, idx))

        def effective_uses(name, depth=0):
            out = []
            if depth > 12:
                return out
            for ins, idx in consumer_map.get(name, []):
                if ins.op in ("convert", "bitcast", "copy"):
                    out.extend(effective_uses(ins.name, depth + 1))
                else:
                    out.append((ins, idx))
            return out

        total = 0.0
        for p in instrs:
            if p.op != "parameter":
                continue
            uses = effective_uses(p.name)
            if not uses:
                continue
            cost_p = 0.0
            for ins, idx in uses:
                if ins.op == "dynamic-slice" and idx == 0:
                    cost_p = max(cost_p, float(shape_bytes(ins.shape)))
                elif ins.op == "dynamic-update-slice" and idx == 0:
                    pass  # aliased in-place buffer: no full read
                else:
                    cost_p = max(cost_p, float(shape_bytes(tbl.get(p.name, ""))))
            total += cost_p
        if root is None:
            root = instrs[-1]
        r = root
        seen = set()
        while (r.op in ("convert", "bitcast", "copy") and r.operands
               and r.name not in seen):
            seen.add(r.name)
            r = producers.get(r.operands[0], r)
        if r.op == "dynamic-update-slice" and len(r.operands) > 1:
            total += shape_bytes(tbl.get(r.operands[1], ""))
        else:
            total += shape_bytes(fusion_ins.shape)
        return total

    def comp_cost(self, comp: str, *, fused: bool = False) -> Cost:
        key = f"{comp}|{fused}"
        if key in self._memo:
            return self._memo[key]
        total = Cost()
        for ins in self.comps.get(comp, []):
            total += self._instr_cost(comp, ins, fused=fused)
        self._memo[key] = total
        return total

    def _instr_cost(self, comp: str, ins: Instr, *, fused: bool) -> Cost:
        op = ins.op
        c = Cost()
        if op in ("parameter", "constant", "tuple", "get-tuple-element",
                  "bitcast", "iota", "after-all", "partition-id",
                  "replica-id", "convert"):
            # converts are CPU bf16-emulation artifacts: free on TPU
            return c
        if op == "while":
            trip = 1
            m = _TRIP.search(ins.attrs)
            if m:
                trip = int(m.group(1))
            body = _BODY.search(ins.attrs)
            cond = _COND.search(ins.attrs)
            if body:
                c += self.comp_cost(body.group(1)).scaled(trip)
            if cond:
                c += self.comp_cost(cond.group(1)).scaled(trip)
            return c
        if op in ("call", "custom-call", "map", "sort", "reduce",
                  "reduce-window", "scatter", "select-and-scatter"):
            m = _TO_APPLY.search(ins.attrs) or _CALLS.search(ins.attrs)
            if m and op == "call":
                c += self.comp_cost(m.group(1))
            if not fused:
                c.bytes += self._operand_bytes(comp, ins.operands) \
                    + shape_bytes(ins.shape)
            return c
        if op == "conditional":
            for b in re.findall(r"(?:true|false|branch)_computation[s]?="
                                r"[{]?%?([\w.\-]+)", ins.attrs):
                c += self.comp_cost(b)
            if not fused:
                c.bytes += self._operand_bytes(comp, ins.operands) \
                    + shape_bytes(ins.shape)
            return c
        if op == "fusion":
            m = _CALLS.search(ins.attrs)
            if m:
                inner = self.comp_cost(m.group(1), fused=True)
                c.flops += inner.flops
                for k in c.coll:
                    c.coll[k] += inner.coll[k]
                if not fused:
                    c.bytes += self._fusion_boundary_bytes(m.group(1), ins)
            elif not fused:
                c.bytes += self._operand_bytes(comp, ins.operands) \
                    + shape_bytes(ins.shape)
            return c
        base = op.replace("-start", "")
        if base in COLLECTIVE_FACTORS and not op.endswith("-done"):
            b = shape_bytes(ins.shape) * COLLECTIVE_FACTORS[base]
            c.coll[base] += b
            if not fused:
                c.bytes += shape_bytes(ins.shape)
            return c
        if op == "dot":
            c.flops += self._dot_flops(comp, ins)
            if not fused:
                c.bytes += self._operand_bytes(comp, ins.operands) \
                    + shape_bytes(ins.shape)
            return c
        if op == "convolution":
            kernel = shape_dims(self.symbols.get(comp, {}).get(
                ins.operands[1] if len(ins.operands) > 1 else "", ""))
            k_elems = 1
            for d in kernel:
                k_elems *= d
            out_ch = kernel[-1] if kernel else 1
            c.flops += 2.0 * shape_elems(ins.shape) * max(k_elems, 1) / max(out_ch, 1)
            if not fused:
                c.bytes += self._operand_bytes(comp, ins.operands) \
                    + shape_bytes(ins.shape)
            return c
        if op == "dynamic-update-slice":
            upd = ins.operands[1] if len(ins.operands) > 1 else ""
            ub = shape_bytes(self.symbols.get(comp, {}).get(upd, ""))
            if not fused:
                c.bytes += 2.0 * ub
            return c
        if op == "dynamic-slice":
            if not fused:
                c.bytes += 2.0 * shape_bytes(ins.shape)
            return c
        if op == "gather":
            if not fused:
                c.bytes += 2.0 * shape_bytes(ins.shape) \
                    + self._operand_bytes(comp, ins.operands[1:2])
            return c
        if op == "copy":
            # loop-carry copies (copy of a gte of the while parameter) are
            # elided by XLA:TPU's in-place while aliasing; XLA:CPU emits
            # them.  Treat copy-of-gte as free, other copies as real.
            if not fused and ins.operands:
                prod = {i.name: i for i in self.comps.get(comp, [])}
                src = prod.get(ins.operands[0])
                if src is not None and src.op == "get-tuple-element":
                    return c
                c.bytes += self._operand_bytes(comp, ins.operands) \
                    + shape_bytes(ins.shape)
            return c
        # generic elementwise / data-movement op
        c.flops += shape_elems(ins.shape)
        if not fused:
            c.bytes += self._operand_bytes(comp, ins.operands) \
                + shape_bytes(ins.shape)
        return c

    def total(self) -> Cost:
        if self.entry is None:
            # fall back: largest computation
            if not self.comps:
                return Cost()
            self.entry = max(self.comps, key=lambda c: len(self.comps[c]))
        return self.comp_cost(self.entry)


def analyze(hlo_text: str) -> Cost:
    return HloCostModel(hlo_text).total()

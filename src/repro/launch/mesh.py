"""Production mesh construction.

A FUNCTION (not module-level state) so importing this module never touches
jax device state.  Single pod = 256 chips (16 x 16, axes data x model);
multi-pod = 2 pods = 512 chips (2 x 16 x 16, axes pod x data x model).
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax


def make_mesh(shape: Sequence[int], axes: Sequence[str]):
    """``jax.make_mesh`` across JAX versions: ``jax.sharding.AxisType`` only
    exists from 0.5.x on; older releases (0.4.37 in this container) default to
    auto axis types, so the kwarg is passed only when available."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        # repro-lint: disable=ECO502 -- THE sanctioned call site: this
        # wrapper is the version gate every other module must go through
        return jax.make_mesh(tuple(shape), tuple(axes),
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(tuple(shape), tuple(axes))  # repro-lint: disable=ECO502


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1x1 mesh on the real local device (CPU tests/examples)."""
    return make_mesh((1, 1), ("data", "model"))

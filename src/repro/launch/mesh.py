"""Production mesh construction.

A FUNCTION (not module-level state) so importing this module never touches
jax device state.  Single pod = 256 chips (16 x 16, axes data x model);
multi-pod = 2 pods = 512 chips (2 x 16 x 16, axes pod x data x model).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh():
    """Degenerate 1x1 mesh on the real local device (CPU tests/examples)."""
    return jax.make_mesh(
        (1, 1), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)

"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), all in seconds:

  compute    = HLO_FLOPs / peak_FLOPs            (per-chip module FLOPs)
  memory     = HLO_bytes / HBM_bw
  collective = sum(op_factor x op_bytes) / link_bw

``compiled.cost_analysis()`` reports the per-device (post-SPMD) module, so
terms use per-chip constants directly.  Collective bytes are not in
cost_analysis: we parse the optimized HLO text and sum the output-shape
bytes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute, weighting all-reduce 2x (reduce-scatter + all-gather of
a ring) — a standard first-order model of link traffic per chip.
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, Optional

# TPU v5e, per chip
PEAK_FLOPS = 197e12       # bf16
HBM_BW = 819e9            # bytes/s
LINK_BW = 50e9            # bytes/s per ICI link
CHIP_POWER_IDLE = 60.0    # W (representative; see DESIGN.md §6)
CHIP_POWER_PEAK = 170.0   # W

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "c64": 8, "s64": 8, "u64": 8, "f64": 8,
    "token": 0, "opaque": 0,
}

# link-traffic weight per collective kind (ring algorithms, per chip)
_COLLECTIVE_FACTORS = {
    "all-gather": 1.0,
    "all-reduce": 2.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*((?:\(|)[\w\[\],\s{}:#*\"]*?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Weighted per-chip collective bytes by kind, from optimized HLO."""
    out: Dict[str, float] = {k: 0.0 for k in _COLLECTIVE_FACTORS}
    seen_done = set()
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        shapes, kind = m.group(1), m.group(2)
        if "-done(" in line:  # async pair: count only the start
            continue
        out[kind] += _shape_bytes(shapes) * _COLLECTIVE_FACTORS[kind]
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops: float                # per-chip HLO flops
    bytes_accessed: float       # per-chip HLO bytes
    coll_bytes: float           # per-chip weighted collective bytes
    coll_by_kind: Dict[str, float]
    per_device_memory: float    # bytes (peak buffer allocation)
    model_flops: float          # analytic 6ND / 2ND (global)

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_accessed / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_step(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (chips x per-chip HLO flops)."""
        total = self.flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def energy_j(self) -> float:
        """First-order energy per step: busy time x chip power x chips."""
        t = self.t_step
        if t == 0:
            return 0.0
        util = self.t_compute / t
        p = CHIP_POWER_IDLE + (CHIP_POWER_PEAK - CHIP_POWER_IDLE) * util
        return t * p * self.chips

    def row(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "flops_per_chip": self.flops,
            "bytes_per_chip": self.bytes_accessed,
            "coll_bytes_per_chip": self.coll_bytes,
            "coll_by_kind": self.coll_by_kind,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "t_step_s": self.t_step,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "per_device_memory_gb": self.per_device_memory / 2**30,
            "energy_j": self.energy_j,
        }


def model_flops(cfg, shape, n_params: int, n_active: int) -> float:
    """6·N·D for training, 2·N·D for inference (N = active params)."""
    tokens = shape.global_batch * (1 if shape.kind == "decode" else shape.seq_len)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_active * tokens


def count_params(cfg) -> Dict[str, int]:
    """Total and active (MoE top-k weighted) param counts from shapes."""
    import jax
    from repro.models import init_params
    shapes = jax.eval_shape(lambda k: init_params(cfg, k),
                            jax.random.PRNGKey(0))
    total = active = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        keys = "/".join(str(getattr(p, "key", getattr(p, "name", "")))
                        for p in path)
        n = math.prod(leaf.shape)
        total += n
        if "moe/w_" in keys and cfg.num_experts:
            active += n * cfg.moe_top_k / cfg.num_experts
        elif "embed/table" in keys:
            active += 0  # embedding lookups are not matmul FLOPs
        else:
            active += n
    return {"total": total, "active": int(active)}


def memory_bytes(mem_analysis) -> float:
    get = lambda a: float(getattr(mem_analysis, a, 0) or 0)
    return (get("temp_size_in_bytes") + get("argument_size_in_bytes")
            + get("output_size_in_bytes") + get("alias_size_in_bytes") * 0)

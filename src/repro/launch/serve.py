"""Serving driver: one request plane streams ECORE-routed requests.

  PYTHONPATH=src python -m repro.launch.serve --requests 24 --delta 5
  PYTHONPATH=src python -m repro.launch.serve --requests 24 --pods 4
  PYTHONPATH=src python -m repro.launch.serve --requests 24 --async
  PYTHONPATH=src python -m repro.launch.serve --rate 20 --duration 5 \
      --pattern flash --pods 2 --max-wait-ms 25   # open-loop SLO replay

On this CPU container backends are REDUCED variants of the assigned archs
(real prefill+decode runs, batched); the routing profile comes from the
production dry-run roofline (artifacts/dryrun.jsonl) when available, so the
router makes the same decisions it would on the pod.

The driver is a thin loop over ``EcoreService``: it builds a ``PoolPolicy``
(Algorithm 1 over prompt-length buckets), submits ``RouteRequest``s, and
handles ``Served`` completions — dispatch batching, per-backend queues and
the ``--max-wait-ms`` deadline all live inside the service.  With a static
profile the whole workload is routed in ONE tensorized ``decide_batch``
call (``submit_batch``); ``--adapt`` submits per request, since each
observation changes the table the next decision reads.  Deadline-expired
partial batches are served by the service's background flusher thread — the
driver never polls.

``--adapt`` closes the loop: each backend's measured per-request latency,
relative to its OWN first measurement (local CPU ms and pod-profile ms are
different scales, so only the relative slowdown transfers), rescales its
profiled time AND energy through the single ``Observation`` plane — so the
greedy argmin-energy routing reacts when a backend runs slower than its
profile claims.

``--pods N`` shards the stream over an ``EcoreCluster`` of N service pods
(each with its OWN PoolPolicy over a copy of the profile, so ``--adapt``
observations fold into the owning pod); ``--async`` drives a single pod
through the ``AsyncEcoreService`` asyncio facade instead of the sync API.

``--profile-out PATH`` persists the (possibly EWMA-adapted) routing profile
as json after the run — the same ``ProfileTable`` facade the
``ProfileState`` scan plane round-trips through, so a warm profile from one
session seeds the next (``pool_table_from_dryrun`` -> adapt -> json ->
``ProfileTable.from_json``).
"""
from __future__ import annotations

import argparse
import os
import time

import numpy as np

from repro.configs import get_config
from repro.core.policy import Observation, PoolPolicy, RouteRequest
from repro.core.profiles import ProfileEntry, ProfileTable
from repro.serving.engine import Backend
from repro.serving.pool import (LENGTH_BUCKETS, ServingPool,
                                capability_score, pool_table_from_dryrun)
from repro.serving.service import EcoreService

DEFAULT_POOL = ("qwen2.5-3b", "llama3-8b", "mamba2-370m",
                "granite-moe-1b-a400m", "recurrentgemma-2b")

# reduced CPU backends cap the materialized prompt (routing still sees the
# full requested length)
PROMPT_CAP = 48


def synthetic_pool_table(archs) -> ProfileTable:
    """Fallback profile when no dry-run artifact exists (analytic)."""
    entries = []
    for arch in archs:
        cfg = get_config(arch)
        n = cfg.num_layers * cfg.d_model * cfg.d_model * 8  # rough
        for _, _, bucket in LENGTH_BUCKETS:
            entries.append(ProfileEntry(
                model=arch, device="pod-16x16", group=bucket,
                map_pct=capability_score(n, cfg.is_subquadratic, bucket),
                time_ms=n / 1e9, energy_mwh=n / 1e10))
    return ProfileTable(entries)


def _run_open_loop(args, table: ProfileTable, backend_factory) -> int:
    """--rate mode: replay a generated open-loop arrival stream through the
    virtual-time LoadDriver and report windowed SLOs.  Arrival times are
    virtual (the episode replays as fast as the backends serve); the
    modeled service times come from the routing profile, so queue growth
    reflects the PROFILED fleet capacity at this rate."""
    import repro.traffic as tr

    clock = tr.ManualClock()
    arrivals = tr.make_arrivals(args.pattern, args.rate, args.duration,
                                seed=args.seed)
    work = tr.merge_tenants([tr.llm_tenant(
        "pool", arrivals, seed=args.seed, deadline_ms=args.deadline_ms,
        prompt_cap=PROMPT_CAP, max_new_tokens=args.max_new)])
    if args.pods > 1:
        from repro.serving.cluster import EcoreCluster
        service = EcoreCluster(
            lambda i: PoolPolicy(ServingPool(table.copy(),
                                             delta=args.delta)),
            backend_factory, pods=args.pods, shard=args.shard,
            max_wait_ms=args.max_wait_ms, clock=clock,
            retain_results=False, flusher=False)
        plane = f"{args.pods}-pod cluster ({args.shard})"
    else:
        service = EcoreService(
            PoolPolicy(ServingPool(table, delta=args.delta)),
            backend_factory, max_wait_ms=args.max_wait_ms, clock=clock,
            retain_results=False, buffer_errors=False, flusher=False)
        plane = "service"

    driver = tr.LoadDriver(service, clock,
                           window_s=max(args.duration / 10.0, 1.0))
    t0 = time.time()
    try:
        done = driver.run(work)
    finally:
        service.close()
    wall_s = time.time() - t0

    print(f"\nopen-loop replay [{plane}]: {len(done)} requests, "
          f"pattern={args.pattern}, rate={args.rate:.1f}/s, "
          f"duration={args.duration:.0f}s virtual ({wall_s:.1f}s wall)")
    print("window_t_s,n,goodput_rps,p50_ms,p99_ms,queue_wait_p99_ms,"
          "joules_per_request")
    for w in driver.slo.window_records():
        print(f"{w['t_start_s']:.0f},{w['n']},{w['goodput_rps']:.1f},"
              f"{w['p50_ms']:.1f},{w['p99_ms']:.1f},"
              f"{w['queue_wait_p99_ms']:.1f},"
              f"{w['joules_per_request']:.4f}")
    s = driver.slo.summary()
    print(f"summary: p50={s['p50_ms']:.1f}ms p95={s['p95_ms']:.1f}ms "
          f"p99={s['p99_ms']:.1f}ms goodput={s['goodput_fraction']:.3f} "
          f"({s['goodput_rps']:.1f}/s) "
          f"J/req={s['joules_per_request']:.4f} "
          f"failed={s['failed']}")
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="ECORE serving driver: closed-loop request stream by "
                    "default, open-loop load replay with --rate")

    serving = ap.add_argument_group(
        "serving", "workload shape, routing profile, dispatch batching")
    serving.add_argument("--requests", type=int, default=24)
    serving.add_argument("--delta", type=float, default=5.0)
    serving.add_argument("--archs", nargs="*", default=list(DEFAULT_POOL))
    serving.add_argument("--dryrun-artifact",
                         default="artifacts/dryrun.jsonl")
    serving.add_argument("--max-new", type=int, default=8)
    serving.add_argument("--max-batch", type=int, default=8)
    serving.add_argument("--max-wait-ms", type=float, default=None,
                         help="serve a partial batch once its oldest "
                              "request has waited this long (default: wait "
                              "for a full batch); honored by the service's "
                              "background flusher thread")
    serving.add_argument("--seed", type=int, default=0)
    serving.add_argument("--adapt", action="store_true",
                         help="EWMA-update the routing profile from "
                              "measured per-request latency (closed loop)")
    serving.add_argument("--profile-out", default=None,
                         help="write the routing profile (with any --adapt "
                              "updates folded in) to this json path after "
                              "the run, to warm-start a later session; "
                              "under --pods each pod adapts a PRIVATE copy, "
                              "so the shared source profile is written "
                              "unadapted")

    scale = ap.add_argument_group(
        "resilience / scale-out", "how many pods serve, and through which "
        "request plane")
    scale.add_argument("--pods", type=int, default=1,
                       help="shard the stream over an EcoreCluster of N "
                            "service pods (each pod: own policy over a "
                            "copy of the profile, own queues and backends)")
    scale.add_argument("--shard", default="least_loaded",
                       choices=["least_loaded", "rendezvous"],
                       help="cluster shard-selection policy (with "
                            "--pods > 1)")
    scale.add_argument("--async", dest="use_async", action="store_true",
                       help="drive one pod through the AsyncEcoreService "
                            "asyncio facade (incompatible with --pods > 1)")

    traffic = ap.add_argument_group(
        "traffic", "open-loop load replay (repro.traffic) — requests "
        "arrive at generated times on a virtual clock instead of the "
        "closed --requests loop")
    traffic.add_argument("--rate", type=float, default=None,
                         help="mean arrival rate in requests/s; turns the "
                              "driver into an open-loop LoadDriver replay")
    traffic.add_argument("--duration", type=float, default=None,
                         help="episode length in virtual seconds "
                              "(default 10; needs --rate)")
    traffic.add_argument("--pattern", default=None,
                         choices=["poisson", "diurnal", "flash"],
                         help="arrival process (default poisson; needs "
                              "--rate)")
    traffic.add_argument("--deadline-ms", type=float, default=None,
                         help="per-request SLO deadline for goodput "
                              "accounting (needs --rate)")

    args = ap.parse_args(argv)
    if args.pods < 1:
        ap.error(f"--pods {args.pods}: need at least one pod")
    if args.use_async and args.pods != 1:
        ap.error("--async drives a single pod; use --pods 1 with it")
    if args.rate is None:
        for flag, v in (("--duration", args.duration),
                        ("--pattern", args.pattern),
                        ("--deadline-ms", args.deadline_ms)):
            if v is not None:
                ap.error(f"{flag} is open-loop traffic shape; it needs "
                         f"--rate")
    else:
        if args.rate <= 0:
            ap.error(f"--rate {args.rate}: need > 0")
        if args.use_async:
            ap.error("--rate replays through the sync LoadDriver; "
                     "drop --async")
        if args.adapt:
            ap.error("--rate is an open-loop replay; --adapt's "
                     "per-request closed loop is not supported with it")
        args.duration = 10.0 if args.duration is None else args.duration
        args.pattern = args.pattern or "poisson"

    if os.path.exists(args.dryrun_artifact):
        table = pool_table_from_dryrun(args.dryrun_artifact)
        table = ProfileTable([e for e in table.entries
                              if e.model in args.archs])
        src = args.dryrun_artifact
    else:
        table = synthetic_pool_table(args.archs)
        src = "analytic fallback"
    pool = ServingPool(table, delta=args.delta)
    print(f"pool profile from {src}: {len(table.pairs())} backends")

    # (arch, batch_size, prompt_len) -> fastest local_ms: keyed per jit
    # shape, so a recompile for a new batch shape (or the compile-heavy
    # first batch) never masquerades as backend drift
    baselines = {}
    # observations rescale the PRISTINE profile (time/energy are
    # bucket-independent per arch), never the already-adapted one — basing
    # them on live decisions would compound drift and stop the profile from
    # recovering once a backend returns to its healthy speed
    pristine = {}
    for entry in table.entries:
        pristine.setdefault(entry.model, (entry.time_ms, entry.energy_mwh))
    totals = {"energy_mwh": 0.0, "time_ms": 0.0}
    t_start = time.time()

    def backend_factory(decision):
        cfg = get_config(decision.backend).reduced()
        return Backend(decision.backend, cfg, max_batch=args.max_batch,
                       max_seq=96, seed=args.seed)

    if args.rate is not None:
        return _run_open_loop(args, table, backend_factory)

    def handle(served):
        observed = set()  # one observation per serve_batch call, not result
        for s in served:
            d, res, plen = s.decision, s.result, s.request.complexity
            totals["energy_mwh"] += d.energy_mwh
            totals["time_ms"] += d.time_ms
            local_ms = (res.prefill_s + res.decode_s) * 1e3 / res.batch_size
            print(f"req {res.uid:3d} len={plen:6d} bucket={d.group} -> "
                  f"{d.backend:22s} score={d.score:5.1f} "
                  f"prof[t={d.time_ms:8.2f}ms e={d.energy_mwh:7.4f}mWh] "
                  f"local[{local_ms:6.1f}ms/req batch={res.batch_size}] "
                  f"tokens={res.tokens[:4]}")
            key = (d.backend, res.batch_size, min(plen, PROMPT_CAP))
            if args.adapt and key + (res.prefill_s,) not in observed:
                observed.add(key + (res.prefill_s,))
                base_ms = min(baselines.get(key, local_ms), local_ms)
                baselines[key] = base_ms
                slowdown = local_ms / max(base_ms, 1e-9)
                prof_t, prof_e = pristine[d.backend]
                # uid lets a cluster fold the observation into the pod
                # that actually made (and will remake) this decision
                service.observe(Observation(
                    pair=d.pair, uid=res.uid, time_ms=prof_t * slowdown,
                    energy_mwh=prof_e * slowdown))

    rng = np.random.default_rng(args.seed)
    plens = [int(rng.choice([32, 128, 1024, 4096, 40_000],
                            p=[.3, .3, .2, .1, .1]))
             for _ in range(args.requests)]
    reqs = [RouteRequest(uid=uid, complexity=plen,
                         payload=rng.integers(0, 1000,
                                              size=min(plen, PROMPT_CAP)),
                         max_new_tokens=args.max_new)
            for uid, plen in enumerate(plens)]

    if args.use_async:
        # asyncio facade: awaitable futures are the consumption plane
        import asyncio

        from repro.serving.aio import AsyncEcoreService

        async def drive_async():
            nonlocal service
            service = AsyncEcoreService(PoolPolicy(pool), backend_factory,
                                        max_wait_ms=args.max_wait_ms)
            try:
                if args.adapt:
                    # closed loop, same cadence as the sync driver: fold
                    # each batch's observations in as soon as it completes,
                    # BEFORE later requests are routed
                    pending = []
                    for req in reqs:
                        pending.append(service.submit_nowait(req))
                        await asyncio.sleep(0)  # let inline flushes land
                        done = [f for f in pending if f.done()]
                        pending = [f for f in pending if not f.done()]
                        handle([f.result() for f in done])
                    await service.drain()
                    handle(await asyncio.gather(*pending))
                else:
                    futs = service.submit_batch_nowait(reqs)
                    await service.drain()   # flush partials -> all resolve
                    handle(await asyncio.gather(*futs))
                return service.stats()
            finally:
                await service.close()

        service = None
        stats = asyncio.run(drive_async())
        plane = "async service"
    elif args.pods > 1:
        # sharded: each pod adapts its OWN copy of the profile
        from repro.serving.cluster import EcoreCluster
        service = EcoreCluster(
            lambda i: PoolPolicy(ServingPool(table.copy(), delta=args.delta)),
            backend_factory, pods=args.pods, shard=args.shard,
            max_wait_ms=args.max_wait_ms)
        plane = f"{args.pods}-pod cluster ({args.shard})"
    else:
        service = EcoreService(PoolPolicy(pool), backend_factory,
                               max_wait_ms=args.max_wait_ms)
        plane = "service"

    if not args.use_async:
        try:
            if args.adapt:
                # closed loop: route per request — each observation mutates
                # the table the next decision must read
                for req in reqs:
                    service.submit(req)
                    handle(service.results())
            else:
                # static profile: route the whole workload in one tensorized
                # XLA call (per pod, under a cluster)
                service.submit_batch(reqs)
                handle(service.results())
            handle(service.drain())
            stats = service.stats()
        finally:
            service.close()

    if args.profile_out:
        pool.table.to_json(args.profile_out)
        print(f"wrote adapted routing profile to {args.profile_out}")
    print(f"\n{args.requests} requests in {time.time()-t_start:.1f}s via "
          f"{stats['serve_calls']} serve_batch calls over "
          f"{stats['backends']} backends [{plane}] "
          f"(max_batch={args.max_batch}, "
          f"deadline_flushes={stats['deadline_flushes']}); "
          f"profiled totals: {totals['time_ms']:.1f}ms, "
          f"{totals['energy_mwh']:.3f}mWh "
          f"(delta={args.delta}, adapt={args.adapt})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Serving driver: ECORE-routed batched inference over a backend pool.

  PYTHONPATH=src python -m repro.launch.serve --requests 24 --delta 5

On this CPU container backends are REDUCED variants of the assigned archs
(real prefill+decode runs, batched); the routing profile comes from the
production dry-run roofline (artifacts/dryrun.jsonl) when available, so the
router makes the same decisions it would on the pod.
"""
from __future__ import annotations

import argparse
import os
import time

import numpy as np

from repro.configs import get_config
from repro.serving.engine import Backend, Request
from repro.serving.pool import (ServingPool, bucket_of,
                                pool_table_from_dryrun)
from repro.core.profiles import ProfileEntry, ProfileTable
from repro.serving.pool import capability_score, LENGTH_BUCKETS

DEFAULT_POOL = ("qwen2.5-3b", "llama3-8b", "mamba2-370m",
                "granite-moe-1b-a400m", "recurrentgemma-2b")


def synthetic_pool_table(archs) -> ProfileTable:
    """Fallback profile when no dry-run artifact exists (analytic)."""
    entries = []
    for a in archs:
        cfg = get_config(a)
        import math
        n = cfg.num_layers * cfg.d_model * cfg.d_model * 8  # rough
        for _, _, b in LENGTH_BUCKETS:
            entries.append(ProfileEntry(
                model=a, device="pod-16x16", group=b,
                map_pct=capability_score(n, cfg.is_subquadratic, b),
                time_ms=n / 1e9, energy_mwh=n / 1e10))
    return ProfileTable(entries)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--delta", type=float, default=5.0)
    ap.add_argument("--archs", nargs="*", default=list(DEFAULT_POOL))
    ap.add_argument("--dryrun-artifact", default="artifacts/dryrun.jsonl")
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if os.path.exists(args.dryrun_artifact):
        table = pool_table_from_dryrun(args.dryrun_artifact)
        table = ProfileTable([e for e in table.entries
                              if e.model in args.archs])
        src = args.dryrun_artifact
    else:
        table = synthetic_pool_table(args.archs)
        src = "analytic fallback"
    pool = ServingPool(table, delta=args.delta)
    print(f"pool profile from {src}: {len(table.pairs())} backends")

    backends = {}
    rng = np.random.default_rng(args.seed)
    routed_energy = routed_time = 0.0
    t_start = time.time()
    for uid in range(args.requests):
        plen = int(rng.choice([32, 128, 1024, 4096, 40_000],
                              p=[.3, .3, .2, .1, .1]))
        decision = pool.route(plen)
        routed_energy += decision.energy_mwh
        routed_time += decision.time_ms
        if decision.arch not in backends:
            cfg = get_config(decision.arch).reduced()
            backends[decision.arch] = Backend(decision.arch, cfg,
                                              max_seq=96, seed=uid)
        be = backends[decision.arch]
        prompt = rng.integers(0, 1000, size=min(plen, 48))
        res = be.serve_batch([Request(uid=uid, prompt=prompt,
                                      max_new_tokens=args.max_new)])[0]
        print(f"req {uid:3d} len={plen:6d} bucket={decision.bucket} -> "
              f"{decision.arch:22s} score={decision.score:5.1f} "
              f"prof[t={decision.time_ms:8.2f}ms e={decision.energy_mwh:7.4f}mWh] "
              f"local[prefill={res.prefill_s*1e3:6.1f}ms "
              f"decode={res.decode_s*1e3:6.1f}ms] tokens={res.tokens[:4]}")
    print(f"\n{args.requests} requests in {time.time()-t_start:.1f}s; "
          f"profiled totals: {routed_time:.1f}ms, {routed_energy:.3f}mWh "
          f"(delta={args.delta})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

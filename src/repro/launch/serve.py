"""Serving driver: ECORE-routed batched inference over a backend pool.

  PYTHONPATH=src python -m repro.launch.serve --requests 24 --delta 5

On this CPU container backends are REDUCED variants of the assigned archs
(real prefill+decode runs, batched); the routing profile comes from the
production dry-run roofline (artifacts/dryrun.jsonl) when available, so the
router makes the same decisions it would on the pod.

Dispatch is BATCHED: each backend owns a request queue that flushes up to
``--max-batch`` requests per ``serve_batch`` call, so N requests take far
fewer than N engine calls, and ``--max-wait-ms`` bounds how long a partial
batch waits for stragglers before being served anyway.  Routing is batched
too: with a static profile the whole workload is routed in ONE tensorized
``ServingPool.route_batch`` call (``--adapt`` forces per-request routing,
since each observation changes the table the next decision reads).
``--adapt`` closes the loop: each backend's
measured per-request latency, relative to its OWN first measurement (local
CPU ms and pod-profile ms are different scales, so only the relative
slowdown transfers), rescales its profiled time AND energy via
``ServingPool.observe`` — so the greedy argmin-energy routing reacts when a
backend runs slower than its profile claims.
"""
from __future__ import annotations

import argparse
import os
import time

import numpy as np

from repro.configs import get_config
from repro.serving.engine import Backend, DispatchQueue, Request
from repro.serving.pool import (ServingPool, bucket_of,
                                pool_table_from_dryrun)
from repro.core.profiles import ProfileEntry, ProfileTable
from repro.serving.pool import capability_score, LENGTH_BUCKETS

DEFAULT_POOL = ("qwen2.5-3b", "llama3-8b", "mamba2-370m",
                "granite-moe-1b-a400m", "recurrentgemma-2b")

# reduced CPU backends cap the materialized prompt (routing still sees the
# full requested length)
PROMPT_CAP = 48


def synthetic_pool_table(archs) -> ProfileTable:
    """Fallback profile when no dry-run artifact exists (analytic)."""
    entries = []
    for a in archs:
        cfg = get_config(a)
        import math
        n = cfg.num_layers * cfg.d_model * cfg.d_model * 8  # rough
        for _, _, b in LENGTH_BUCKETS:
            entries.append(ProfileEntry(
                model=a, device="pod-16x16", group=b,
                map_pct=capability_score(n, cfg.is_subquadratic, b),
                time_ms=n / 1e9, energy_mwh=n / 1e10))
    return ProfileTable(entries)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--delta", type=float, default=5.0)
    ap.add_argument("--archs", nargs="*", default=list(DEFAULT_POOL))
    ap.add_argument("--dryrun-artifact", default="artifacts/dryrun.jsonl")
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-wait-ms", type=float, default=None,
                    help="serve a partial batch once its oldest request "
                         "has waited this long (default: wait for a full "
                         "batch)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--adapt", action="store_true",
                    help="EWMA-update the routing profile from measured "
                         "per-request latency (closed loop)")
    args = ap.parse_args(argv)

    if os.path.exists(args.dryrun_artifact):
        table = pool_table_from_dryrun(args.dryrun_artifact)
        table = ProfileTable([e for e in table.entries
                              if e.model in args.archs])
        src = args.dryrun_artifact
    else:
        table = synthetic_pool_table(args.archs)
        src = "analytic fallback"
    pool = ServingPool(table, delta=args.delta)
    print(f"pool profile from {src}: {len(table.pairs())} backends")

    queues = {}
    decisions = {}
    # (arch, batch_size, prompt_len) -> fastest local_ms: keyed per jit
    # shape, so a recompile for a new batch shape (or the compile-heavy
    # first batch) never masquerades as backend drift
    baselines = {}
    # observations rescale the PRISTINE profile (time/energy are
    # bucket-independent per arch), never the already-adapted one — basing
    # them on live decisions would compound drift and stop the profile from
    # recovering once a backend returns to its healthy speed
    pristine = {}
    for e in table.entries:
        pristine.setdefault(e.model, (e.time_ms, e.energy_mwh))
    rng = np.random.default_rng(args.seed)
    routed_energy = routed_time = 0.0
    t_start = time.time()

    def handle(results):
        observed = set()  # one observation per serve_batch call, not result
        for res in results:
            d, plen = decisions[res.uid]
            local_ms = (res.prefill_s + res.decode_s) * 1e3 / res.batch_size
            print(f"req {res.uid:3d} len={plen:6d} bucket={d.bucket} -> "
                  f"{d.arch:22s} score={d.score:5.1f} "
                  f"prof[t={d.time_ms:8.2f}ms e={d.energy_mwh:7.4f}mWh] "
                  f"local[{local_ms:6.1f}ms/req batch={res.batch_size}] "
                  f"tokens={res.tokens[:4]}")
            key = (d.arch, res.batch_size, min(plen, PROMPT_CAP))
            if args.adapt and key + (res.prefill_s,) not in observed:
                observed.add(key + (res.prefill_s,))
                base_ms = min(baselines.get(key, local_ms), local_ms)
                baselines[key] = base_ms
                slowdown = local_ms / max(base_ms, 1e-9)
                prof_t, prof_e = pristine[d.arch]
                pool.observe(d.arch, time_ms=prof_t * slowdown,
                             energy_mwh=prof_e * slowdown)

    plens = [int(rng.choice([32, 128, 1024, 4096, 40_000],
                            p=[.3, .3, .2, .1, .1]))
             for _ in range(args.requests)]
    # static profile: route the whole workload in one tensorized XLA call;
    # --adapt routes per request because each observation mutates the table
    # the next decision must read
    batch_decisions = None if args.adapt else pool.route_batch(plens)
    for uid, plen in enumerate(plens):
        decision = (batch_decisions[uid] if batch_decisions is not None
                    else pool.route(plen))
        decisions[uid] = (decision, plen)
        routed_energy += decision.energy_mwh
        routed_time += decision.time_ms
        if decision.arch not in queues:
            cfg = get_config(decision.arch).reduced()
            queues[decision.arch] = DispatchQueue(
                Backend(decision.arch, cfg, max_batch=args.max_batch,
                        max_seq=96, seed=uid),
                max_wait_ms=args.max_wait_ms)
        prompt = rng.integers(0, 1000, size=min(plen, PROMPT_CAP))
        handle(queues[decision.arch].submit(
            Request(uid=uid, prompt=prompt, max_new_tokens=args.max_new)))
        for q in queues.values():  # deadline-bounded partial flushes
            handle(q.poll())
    for q in queues.values():
        handle(q.flush())

    calls = sum(q.calls for q in queues.values())
    print(f"\n{args.requests} requests in {time.time()-t_start:.1f}s via "
          f"{calls} serve_batch calls over {len(queues)} backends "
          f"(max_batch={args.max_batch}); "
          f"profiled totals: {routed_time:.1f}ms, {routed_energy:.3f}mWh "
          f"(delta={args.delta}, adapt={args.adapt})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Jittable step functions + ShapeDtypeStruct input specs for every
(architecture x workload-shape) combination.

``input_specs`` follows the dry-run pattern: weak-type-correct, shardable,
zero device allocation.  Decode shapes lower ``serve_step`` (one token
against a seq_len KV cache), train/prefill shapes lower full-sequence steps.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import (ModelConfig, InputShape, decode_step, forward,
                          init_cache, init_params, loss_fn, prefill)
from repro.optim.adamw import AdamWConfig, OptState, adamw_update, init_opt_state


# ------------------------------------------------------------------ steps


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    num_microbatches: int = 1,
                    batch_axes: Tuple[str, ...] = ("data",)):
    """Training step; ``num_microbatches > 1`` adds sequential gradient
    accumulation (keeps per-device activation memory bounded at large
    global_batch x seq, e.g. llava-34B train_4k)."""

    def grads_of(params, batch):
        return jax.value_and_grad(loss_fn, has_aux=True)(params, cfg, batch)

    def train_step(params, opt_state: OptState, batch):
        if num_microbatches == 1:
            (loss, metrics), grads = grads_of(params, batch)
        else:
            def split(x):
                x = x.reshape((num_microbatches, -1) + x.shape[1:])
                return jax.lax.with_sharding_constraint(
                    x, jax.sharding.PartitionSpec(None, batch_axes))
            micro = jax.tree.map(split, batch)
            acc0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def body(acc, mb):
                (l, m), g = grads_of(params, mb)
                acc = jax.tree.map(
                    lambda a, x: a + x.astype(jnp.float32), acc, g)
                return acc, (l, m)

            grads, (losses, ms) = jax.lax.scan(body, acc0, micro)
            grads = jax.tree.map(lambda g: g / num_microbatches, grads)
            loss = losses.mean()
            metrics = jax.tree.map(lambda x: x.mean(), ms)
        params, opt_state, opt_metrics = adamw_update(
            opt_cfg, params, grads, opt_state)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        return prefill(params, cfg, batch["tokens"],
                       batch.get("prefix_embeds"))
    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def serve_step(params, token, cache):
        return decode_step(params, cfg, token, cache)
    return serve_step


# ------------------------------------------------------------- input specs


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def text_len(cfg: ModelConfig, seq_len: int) -> int:
    """Text tokens after reserving prefix positions for stub modalities."""
    if cfg.family == "vlm" and cfg.num_prefix_embeds:
        return max(seq_len - cfg.num_prefix_embeds, 16)
    return seq_len


def batch_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    """Train/prefill batch as ShapeDtypeStructs."""
    b, s = shape.global_batch, shape.seq_len
    st = text_len(cfg, s)
    specs: Dict[str, Any] = {"tokens": _sds((b, st), jnp.int32)}
    if shape.kind == "train":
        specs["labels"] = _sds((b, st), jnp.int32)
    if cfg.family == "vlm" and cfg.num_prefix_embeds:
        specs["prefix_embeds"] = _sds((b, cfg.num_prefix_embeds, cfg.vision_dim),
                                      jnp.float32)
    if cfg.family == "encdec":
        specs["prefix_embeds"] = _sds((b, cfg.enc_seq, cfg.vision_dim),
                                      jnp.float32)
    return specs


def param_structs(cfg: ModelConfig, *, serve: bool = False):
    shapes = jax.eval_shape(
        functools.partial(init_params, cfg), jax.random.PRNGKey(0))
    if serve:  # serving runs in bf16
        shapes = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16), shapes)
    return shapes


def opt_structs(param_shapes) -> OptState:
    mu = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), param_shapes)
    return OptState(step=_sds((), jnp.int32), mu=mu,
                    nu=jax.tree.map(lambda s: s, mu))


def cache_structs(cfg: ModelConfig, shape: InputShape):
    """Decode cache ShapeDtypeStructs sized for shape.seq_len."""
    return jax.eval_shape(
        lambda: init_cache(cfg, shape.global_batch, shape.seq_len,
                           jnp.bfloat16))


def decode_input_specs(cfg: ModelConfig, shape: InputShape):
    token = _sds((shape.global_batch, 1), jnp.int32)
    return token, cache_structs(cfg, shape)


def input_specs(cfg: ModelConfig, shape: InputShape):
    """The complete kwargs-free positional input spec for the lowered step."""
    if shape.kind in ("train", "prefill"):
        return (batch_specs(cfg, shape),)
    return decode_input_specs(cfg, shape)

"""End-to-end training driver.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b --steps 50 \
      --d-model 128 --layers 2 --batch 8 --seq 128

Runs a REDUCED variant of the chosen architecture on the local device(s) by
default (this container is CPU-only); pass --full to train the exact
assigned config (requires a real TPU pod with the production mesh).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import ckpt
from repro.configs import get_config
from repro.data.tokens import DataConfig, TokenStream
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.steps import make_train_step
from repro.models import init_params
from repro.optim.adamw import AdamWConfig, init_opt_state
from repro.sharding import ctx, specs as sp


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--d-model", type=int, default=None)
    ap.add_argument("--layers", type=int, default=None)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--full", action="store_true",
                    help="train the exact assigned config (TPU pod required)")
    ap.add_argument("--save", default=None, help="checkpoint path (.npz)")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if not args.full:
        overrides = {}
        if args.d_model:
            overrides["d_model"] = args.d_model
        cfg = cfg.reduced(**overrides)
    print(f"arch={cfg.name} family={cfg.family} layers={cfg.num_layers} "
          f"d_model={cfg.d_model} vocab={cfg.vocab_size}")

    mesh = make_production_mesh() if args.full else make_host_mesh()
    baxes = sp.batch_axes(mesh)
    n_b = 1
    for a in baxes:
        n_b *= mesh.shape[a]

    opt_cfg = AdamWConfig(peak_lr=args.lr, warmup_steps=min(20, args.steps // 5 + 1),
                          total_steps=args.steps)
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt_state = init_opt_state(params)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"params: {n_params/1e6:.1f}M")

    stream = TokenStream(cfg, DataConfig(seq_len=args.seq, batch_size=args.batch))
    step_fn = make_train_step(cfg, opt_cfg, batch_axes=baxes)
    with ctx.activation_sharding(baxes, n_b, mesh=mesh), mesh:
        pspecs = sp.param_specs(params, mesh=mesh)
        jstep = jax.jit(step_fn,
                        in_shardings=(sp.shard(mesh, pspecs), None, None),
                        donate_argnums=(0, 1))
        t0 = time.time()
        for i, batch in enumerate(stream.batches()):
            if i >= args.steps:
                break
            params, opt_state, metrics = jstep(params, opt_state, batch)
            if i % args.log_every == 0 or i == args.steps - 1:
                m = jax.tree.map(float, metrics)
                print(f"step {i:4d} loss={m['loss']:.4f} ce={m['ce']:.4f} "
                      f"lr={m['lr']:.2e} gnorm={m['grad_norm']:.2f} "
                      f"({time.time()-t0:.1f}s)")
    if args.save:
        ckpt.save(args.save, params)
        print(f"saved {args.save}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

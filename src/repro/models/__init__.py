from .base import ModelConfig, InputShape, INPUT_SHAPES
from .model import init_params, forward, loss_fn, prefill, decode_step
from .kvcache import init_cache

"""Attention: GQA (global + sliding-window) and MLA (DeepSeek-V2).

XLA-native implementation used for training, dry-run lowering, and CPU tests.
Queries are processed in chunks (flash-style outer loop via ``lax.scan``) so
prefill at 32k/500k never materializes an S x S score matrix.  The Pallas
flash/decode kernels in ``repro.kernels`` implement the same math for real
TPU deployment and are validated against these semantics in tests.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .base import ModelConfig
from .layers import apply_rope, dense_init, softcap

NEG_INF = -1e30


# ------------------------------------------------------------------ params


def init_attention(key, cfg: ModelConfig, dtype):
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 8)
    p = {
        "wq": dense_init(ks[0], (d, h * hd), dtype),
        "wk": dense_init(ks[1], (d, kv * hd), dtype),
        "wv": dense_init(ks[2], (d, kv * hd), dtype),
        "wo": dense_init(ks[3], (h * hd, d), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dtype)
        p["bk"] = jnp.zeros((kv * hd,), dtype)
        p["bv"] = jnp.zeros((kv * hd,), dtype)
    return p


def init_mla(key, cfg: ModelConfig, dtype):
    d, h = cfg.d_model, cfg.num_heads
    r, dr, dn, dv = cfg.kv_lora_rank, cfg.qk_rope_dim, cfg.qk_nope_dim, cfg.v_head_dim
    ks = jax.random.split(key, 6)
    return {
        "wq": dense_init(ks[0], (d, h * (dn + dr)), dtype),
        "w_dkv": dense_init(ks[1], (d, r), dtype),
        "w_kr": dense_init(ks[2], (d, dr), dtype),
        "w_uk": dense_init(ks[3], (r, h * dn), dtype),
        "w_uv": dense_init(ks[4], (r, h * dv), dtype),
        "wo": dense_init(ks[5], (h * dv, d), dtype),
    }


# ------------------------------------------------------------------ core math


def _mask_bias(q_pos, k_pos, window: Optional[int]):
    """[..., S_q, S_k] additive bias: causal, optionally sliding-window."""
    ok = k_pos[..., None, :] <= q_pos[..., :, None]
    if window is not None:
        ok &= k_pos[..., None, :] > (q_pos[..., :, None] - window)
    return jnp.where(ok, 0.0, NEG_INF)


def _head_shard(x, dim: int):
    """Pin the given head dim to 'model' when inside the sharding context
    and divisible — GSPMD otherwise sometimes prefers sharding the head_dim
    CONTRACTION, which all-reduces full score tensors (§Perf)."""
    from repro.sharding import ctx
    from jax.sharding import PartitionSpec as P
    mesh = ctx.current_mesh()
    if mesh is None or "model" not in getattr(mesh, "axis_names", ()):
        return x
    if x.shape[dim] % mesh.shape["model"] != 0:
        return x
    spec = [None] * x.ndim
    spec[dim] = "model"
    return jax.lax.with_sharding_constraint(x, P(*spec))


def gqa_scores_softmax(q, k, v, bias, *, scale, cap,
                       force_head_shard: bool = False):
    """q [B,Sq,H,D], k/v [B,Sk,KV,D], bias [B?,Sq,Sk] -> [B,Sq,H,D].

    ``force_head_shard`` pins the KV-head dim to 'model' — used ONLY on the
    padded-expansion path (llava: 56 heads on a 16-way axis), where GSPMD
    otherwise shards the head_dim contraction and all-reduces full score
    tensors (§Perf pair 3).  Everywhere else GSPMD's native choice measured
    better, so no constraint is forced.
    """
    b, sq, h, d = q.shape
    kv = k.shape[2]
    g = h // kv
    qg = q.reshape(b, sq, kv, g, d)
    if force_head_shard:
        qg = _head_shard(qg, 2)
        k = _head_shard(k, 2)
        v = _head_shard(v, 2)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k, preferred_element_type=jnp.float32)
    scores = scores * scale
    if force_head_shard:
        scores = _head_shard(scores, 1)
    scores = softcap(scores, cap)
    scores = scores + bias[:, None, None] if bias.ndim == 3 else scores + bias
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(b, sq, h, v.shape[-1])


def _maybe_tp_expand(q, k, v):
    """Make the head dim tensor-parallel-friendly (§Perf).

    When q-heads don't divide the 'model' axis (llava: 56 heads on 16-way
    TP), GSPMD falls back to sharding the head_dim CONTRACTION and
    all-reduces full attention-score tensors per layer.  Padding q-heads to
    a multiple of the axis and expanding K/V to MHA layout keeps the whole
    attention shard-local (padded heads attend to kv-head 0 and are sliced
    off afterwards).  No-op outside the sharding context.
    """
    from repro.sharding import ctx
    mesh = ctx.current_mesh()
    h, kvh = q.shape[2], k.shape[2]
    if mesh is None or "model" not in getattr(mesh, "axis_names", ()):
        return q, k, v, h
    m = mesh.shape["model"]
    if h % m == 0 or ctx.current_mode() == "train":
        # q-heads shard natively, or we're training: expanding K/V
        # multiplies its bytes by the GQA group, which measured worse than
        # the baseline in training even for non-divisible heads (llava
        # train 104->175 s).  Expansion is serve-only, for head counts
        # that don't divide the axis (llava prefill: 56 on 16, 6.6x win).
        return q, k, v, h
    hp = -(-h // m) * m
    g = h // kvh
    mapping = jnp.array([min(i // g, kvh - 1) for i in range(hp)])
    if hp != h:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, hp - h), (0, 0)))
    k = jnp.take(k, mapping, axis=2)
    v = jnp.take(v, mapping, axis=2)
    return q, k, v, h


def chunked_causal_attention(q, k, v, *, q_offset, window: Optional[int],
                             scale: float, cap: Optional[float],
                             chunk: int = 1024,
                             force_head_shard: bool = False):
    """Causal (optionally windowed) attention, scanning over query chunks.

    q [B,S,H,D]; k, v [B,T,KV,D]; q position i attends to k positions
    j <= q_offset + i (and j > i - window if windowed).
    """
    b, s, h, d = q.shape
    t = k.shape[1]
    chunk = min(chunk, s)
    if s % chunk != 0:  # fall back to one chunk for odd smoke shapes
        chunk = s
    n_chunks = s // chunk
    k_pos = jnp.arange(t)

    def body(carry, qc_idx):
        qc = jax.lax.dynamic_slice_in_dim(q, qc_idx * chunk, chunk, axis=1)
        q_pos = q_offset + qc_idx * chunk + jnp.arange(chunk)
        bias = _mask_bias(q_pos, k_pos, window)  # [chunk, t]
        out = gqa_scores_softmax(qc, k, v, bias, scale=scale, cap=cap,
                                 force_head_shard=force_head_shard)
        return carry, out

    _, outs = jax.lax.scan(body, None, jnp.arange(n_chunks))
    # outs: [n_chunks, B, chunk, H, Dv] -> [B, S, H, Dv]
    return jnp.moveaxis(outs, 0, 1).reshape(b, s, h, v.shape[-1])


# ------------------------------------------------------------------ GQA layer


class KVEntry(NamedTuple):
    k: jax.Array
    v: jax.Array


def attention_forward(p, cfg: ModelConfig, x, positions, *, window=None,
                      return_kv: bool = False):
    """Full-sequence causal attention (train / prefill)."""
    b, s, _ = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    adt = x.dtype
    q = x @ p["wq"].astype(adt)
    k = x @ p["wk"].astype(adt)
    v = x @ p["wv"].astype(adt)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(adt)
        k = k + p["bk"].astype(adt)
        v = v + p["bv"].astype(adt)
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, kv, hd)
    v = v.reshape(b, s, kv, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    scale = 1.0 / math.sqrt(hd)
    qe, ke, ve, h_orig = _maybe_tp_expand(q, k, v)
    out = chunked_causal_attention(qe, ke, ve, q_offset=0, window=window,
                                   scale=scale, cap=cfg.attn_softcap,
                                   force_head_shard=qe.shape[2] != h_orig or
                                   ke.shape[2] != k.shape[2])
    out = out[:, :, :h_orig]
    out = out.reshape(b, s, h * hd) @ p["wo"].astype(adt)
    if return_kv:
        return out, KVEntry(k, v)
    return out


def attention_decode(p, cfg: ModelConfig, x, kv_cache: KVEntry, pos_buf, pos,
                     *, window=None, rope_pos=None):
    """One-token decode against a cache buffer.

    x [B,1,d]; kv_cache.k/v [B,W,KV,D] (W = full seq for global layers, the
    sliding window for local layers); pos_buf [W] absolute positions held in
    each buffer slot (-1 = empty); pos: scalar position of the new token.
    Returns (out [B,1,d], new_cache, new_pos_buf).
    """
    b = x.shape[0]
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    adt = x.dtype
    q = x @ p["wq"].astype(adt)
    k = x @ p["wk"].astype(adt)
    v = x @ p["wv"].astype(adt)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(adt)
        k = k + p["bk"].astype(adt)
        v = v + p["bv"].astype(adt)
    q = q.reshape(b, 1, h, hd)
    k = k.reshape(b, 1, kvh, hd)
    v = v.reshape(b, 1, kvh, hd)
    posn = jnp.full((b, 1), pos if rope_pos is None else rope_pos)
    q = apply_rope(q, posn, cfg.rope_theta)
    k = apply_rope(k, posn, cfg.rope_theta)

    w = kv_cache.k.shape[1]
    slot = pos % w  # ring-buffer slot (== pos when W covers the full seq)
    ck = jax.lax.dynamic_update_slice_in_dim(kv_cache.k, k, slot, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(kv_cache.v, v, slot, axis=1)
    new_pos_buf = jax.lax.dynamic_update_slice_in_dim(
        pos_buf, jnp.full((1,), pos, pos_buf.dtype), slot, axis=0)

    ok = (new_pos_buf >= 0) & (new_pos_buf <= pos)
    if window is not None:
        ok &= new_pos_buf > pos - window
    bias = jnp.where(ok, 0.0, NEG_INF)[None, :]  # [1(Sq), W]
    scale = 1.0 / math.sqrt(hd)
    out = gqa_scores_softmax(q, ck, cv, bias, scale=scale, cap=cfg.attn_softcap)
    out = out.reshape(b, 1, h * hd) @ p["wo"].astype(adt)
    return out, KVEntry(ck, cv), new_pos_buf


# ------------------------------------------------------------------ MLA layer


def attention_decode_v2(p, cfg: ModelConfig, x, ck, cv, pos_buf, pos, *,
                        window=None, rope_pos=None, sharded: bool = False):
    """Decode attention over the OLD cache + the new token, returning the
    new K/V columns instead of rewritten cache buffers (§Perf iteration 2:
    the caller column-DUSes a carried cache, so per-step HBM writes are one
    token column per layer instead of the whole layer slice).

    ck/cv [B, W, KV, hd] are the cache *before* this token; the ring slot
    being overwritten is masked out naturally (its pos_buf entry is either
    -1 or expired by the window).  Returns (out, k_col, v_col, slot).
    """
    from jax.sharding import PartitionSpec as P
    from repro.sharding import ctx

    b = x.shape[0]
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    adt = x.dtype
    q = x @ p["wq"].astype(adt)
    k = x @ p["wk"].astype(adt)
    v = x @ p["wv"].astype(adt)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(adt)
        k = k + p["bk"].astype(adt)
        v = v + p["bv"].astype(adt)
    q = q.reshape(b, 1, h, hd)
    k_col = k.reshape(b, 1, kvh, hd)
    v_col = v.reshape(b, 1, kvh, hd)
    posn = jnp.full((b, 1), pos if rope_pos is None else rope_pos)
    q = apply_rope(q, posn, cfg.rope_theta)
    k_col = apply_rope(k_col, posn, cfg.rope_theta)
    w = ck.shape[1]
    slot = pos % w
    scale = 1.0 / math.sqrt(hd)
    g = h // kvh
    cap = cfg.attn_softcap
    qg = q.reshape(b, 1, kvh, g, hd)

    def stats(ck_, cv_, pbuf_):
        """Partial flash stats over (a shard of) the old cache."""
        ok = jnp.logical_and(pbuf_ >= 0, pbuf_ <= pos)
        if window is not None:
            ok = jnp.logical_and(ok, pbuf_ > pos - window)
        bias = jnp.where(ok, 0.0, NEG_INF)
        s = jnp.einsum("bskgd,btkd->bkgst", qg, ck_,
                       preferred_element_type=jnp.float32) * scale
        s = softcap(s, cap) + bias
        m = s.max(axis=-1)                                   # [b,kv,g,1]
        pexp = jnp.exp(s - m[..., None])
        l = pexp.sum(axis=-1)
        acc = jnp.einsum("bkgst,btkd->bskgd", pexp.astype(cv_.dtype),
                         cv_).astype(jnp.float32)            # [b,1,kv,g,hd]
        return m, l, acc

    if sharded:
        mesh = ctx.current_mesh()

        def local(ck_, cv_, pbuf_):
            m, l, acc = stats(ck_, cv_, pbuf_)
            m_g = jax.lax.pmax(m, "model")
            corr = jnp.exp(m - m_g)
            l_g = jax.lax.psum(l * corr, "model")
            acc_g = jax.lax.psum(acc * jnp.moveaxis(corr, -1, 1)[..., None],
                                 "model")
            return m_g, l_g, acc_g

        m, l, acc = jax.shard_map(
            local, mesh=mesh,
            in_specs=(P(None, "model"), P(None, "model"), P("model")),
            out_specs=(P(), P(), P()),
            axis_names={"model"}, check_vma=False)(ck, cv, pos_buf)
    else:
        m, l, acc = stats(ck, cv, pos_buf)

    # merge the new token (always visible to itself)
    s_new = jnp.einsum("bskgd,bskd->bkgs", qg, k_col.astype(qg.dtype),
                       preferred_element_type=jnp.float32) * scale
    s_new = softcap(s_new, cap)                              # [b,kv,g,1]
    m2 = jnp.maximum(m, s_new)
    corr = jnp.exp(m - m2)
    p_new = jnp.exp(s_new - m2)
    l2 = l * corr + p_new
    acc2 = acc * jnp.moveaxis(corr, -1, 1)[..., None] + \
        jnp.moveaxis(p_new, -1, 1)[..., None] * \
        v_col[:, :, :, None, :].astype(jnp.float32)
    out = acc2 / jnp.maximum(jnp.moveaxis(l2, -1, 1), 1e-30)[..., None]
    out = out.reshape(b, 1, h * hd).astype(adt) @ p["wo"].astype(adt)
    return out, k_col, v_col, slot


def attention_decode_sharded(p, cfg: ModelConfig, x, kv_cache: KVEntry,
                             pos_buf, pos, *, window=None, rope_pos=None):
    """Flash-decode with the cache's SEQUENCE dim sharded over 'model'.

    §Perf optimization (beyond the baseline): instead of letting GSPMD
    all-gather the seq-sharded K/V per layer (the baseline's dominant
    memory/collective term at decode_32k), each model shard computes partial
    flash statistics (m, l, acc) over its local cache slice and the shards
    merge with an [B, H, D]-sized psum — cache bytes stay local.

    QKV/O projections remain outside (ordinary tensor-parallel matmuls).
    """
    from jax.sharding import PartitionSpec as P
    from repro.sharding import ctx

    mesh = ctx.current_mesh()
    b = x.shape[0]
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    adt = x.dtype
    q = x @ p["wq"].astype(adt)
    k = x @ p["wk"].astype(adt)
    v = x @ p["wv"].astype(adt)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(adt)
        k = k + p["bk"].astype(adt)
        v = v + p["bv"].astype(adt)
    q = q.reshape(b, 1, h, hd)
    k_new = k.reshape(b, 1, kvh, hd)
    v_new = v.reshape(b, 1, kvh, hd)
    posn = jnp.full((b, 1), pos if rope_pos is None else rope_pos)
    q = apply_rope(q, posn, cfg.rope_theta)
    k_new = apply_rope(k_new, posn, cfg.rope_theta)
    w = kv_cache.k.shape[1]
    scale = 1.0 / math.sqrt(hd)
    g = h // kvh
    cap = cfg.attn_softcap

    def local(qv, kn, vn, ck, cv, pbuf, pos_):
        widx = jax.lax.axis_index("model")
        wloc = ck.shape[1]
        slot = pos_ % w - widx * wloc
        in_range = jnp.logical_and(slot >= 0, slot < wloc)
        ls = jnp.clip(slot, 0, wloc - 1)
        old_k = jax.lax.dynamic_slice_in_dim(ck, ls, 1, axis=1)
        old_v = jax.lax.dynamic_slice_in_dim(cv, ls, 1, axis=1)
        ck = jax.lax.dynamic_update_slice_in_dim(
            ck, jnp.where(in_range, kn, old_k), ls, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(
            cv, jnp.where(in_range, vn, old_v), ls, axis=1)
        old_p = jax.lax.dynamic_slice_in_dim(pbuf, ls, 1, axis=0)
        pbuf = jax.lax.dynamic_update_slice_in_dim(
            pbuf, jnp.where(in_range, jnp.full((1,), pos_, pbuf.dtype),
                            old_p), ls, axis=0)
        ok = jnp.logical_and(pbuf >= 0, pbuf <= pos_)
        if window is not None:
            ok = jnp.logical_and(ok, pbuf > pos_ - window)
        bias = jnp.where(ok, 0.0, NEG_INF)  # [wloc]
        qg = qv.reshape(b, 1, kvh, g, hd)
        s = jnp.einsum("bskgd,btkd->bkgst", qg, ck,
                       preferred_element_type=jnp.float32) * scale
        s = softcap(s, cap) + bias
        m = s.max(axis=-1)                                  # [b,kv,g,1]
        m_g = jax.lax.pmax(m, "model")
        pexp = jnp.exp(s - m_g[..., None])
        l_g = jax.lax.psum(pexp.sum(axis=-1), "model")
        acc = jnp.einsum("bkgst,btkd->bskgd", pexp.astype(cv.dtype), cv)
        acc_g = jax.lax.psum(acc.astype(jnp.float32), "model")
        denom = jnp.maximum(jnp.moveaxis(l_g, -1, 1), 1e-30)  # [b,1,kv,g]
        out = acc_g / denom[..., None]
        return out.reshape(b, 1, h * hd).astype(adt), ck, cv, pbuf

    out, ck, cv, pbuf = jax.shard_map(
        local, mesh=mesh,
        in_specs=(P(), P(), P(), P(None, "model"), P(None, "model"),
                  P("model"), P()),
        out_specs=(P(), P(None, "model"), P(None, "model"), P("model")),
        axis_names={"model"}, check_vma=False,
    )(q, k_new, v_new, kv_cache.k, kv_cache.v, pos_buf,
      jnp.asarray(pos, jnp.int32))
    out = out @ p["wo"].astype(adt)
    return out, KVEntry(ck, cv), pbuf


def use_sharded_decode(cfg: ModelConfig, w: int) -> bool:
    """True when the decode cache's SEQ dim is model-sharded (shard_map
    flash-decode path).  When kv_heads divide the model axis the cache is
    kv-head-sharded instead and plain GSPMD attention is already local."""
    from repro.sharding import ctx, specs as sp
    mesh = ctx.current_mesh()
    if mesh is None or "model" not in getattr(mesh, "axis_names", ()):
        return False
    return sp.decode_cache_layout(cfg.num_kv_heads, w, mesh) == "seq"


def mla_forward(p, cfg: ModelConfig, x, positions, *, return_cache=False):
    """MLA full-sequence (train / prefill): expanded keys/values."""
    b, s, _ = x.shape
    h = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    adt = x.dtype
    q = (x @ p["wq"].astype(adt)).reshape(b, s, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    c_kv = x @ p["w_dkv"].astype(adt)  # [b,s,r]
    k_rope = apply_rope(x @ p["w_kr"].astype(adt), positions, cfg.rope_theta)  # [b,s,dr]
    k_nope = (c_kv @ p["w_uk"].astype(adt)).reshape(b, s, h, dn)
    v = (c_kv @ p["w_uv"].astype(adt)).reshape(b, s, h, dv)

    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (b, s, h, dr))], axis=-1)
    scale = 1.0 / math.sqrt(dn + dr)
    out = chunked_causal_attention(q_full, k_full, v, q_offset=0, window=None,
                                   scale=scale, cap=cfg.attn_softcap)
    out = out.reshape(b, s, h * dv) @ p["wo"].astype(adt)
    if return_cache:
        return out, (c_kv, k_rope)
    return out


def mla_decode(p, cfg: ModelConfig, x, c_cache, kr_cache, pos, *,
               absorbed: bool = True):
    """One-token MLA decode over the latent cache.

    c_cache [B,T,r]; kr_cache [B,T,dr]; new token written at slot ``pos``.
    ``absorbed=True`` uses the weight-absorption trick (attention in the
    r-dim latent space — the serving-optimal form); ``absorbed=False``
    re-expands keys/values (paper-faithful naive baseline, O(T·r·h·dn) work
    per step).
    """
    b = x.shape[0]
    h = cfg.num_heads
    r, dn, dr, dv = cfg.kv_lora_rank, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    adt = x.dtype
    q = (x @ p["wq"].astype(adt)).reshape(b, 1, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    posn = jnp.full((b, 1), pos)
    q_rope = apply_rope(q_rope, posn, cfg.rope_theta)

    c_new = x @ p["w_dkv"].astype(adt)  # [b,1,r]
    kr_new = apply_rope(x @ p["w_kr"].astype(adt), posn, cfg.rope_theta)
    c_cache = jax.lax.dynamic_update_slice_in_dim(c_cache, c_new, pos, axis=1)
    kr_cache = jax.lax.dynamic_update_slice_in_dim(kr_cache, kr_new, pos, axis=1)
    t = c_cache.shape[1]
    k_pos = jnp.arange(t)
    bias = jnp.where(k_pos <= pos, 0.0, NEG_INF)  # [t]
    scale = 1.0 / math.sqrt(dn + dr)

    w_uk = p["w_uk"].astype(adt).reshape(r, h, dn)
    if absorbed:
        # q_abs[b,h,r] = sum_dn q_nope * W_uk ; scores in latent space
        q_abs = jnp.einsum("bshd,rhd->bshr", q_nope, w_uk)[:, 0]  # [b,h,r]
        scores = jnp.einsum("bhr,btr->bht", q_abs, c_cache,
                            preferred_element_type=jnp.float32)
        scores += jnp.einsum("bshd,btd->bht", q_rope, kr_cache,
                             preferred_element_type=jnp.float32)
        probs = jax.nn.softmax(scores * scale + bias, axis=-1).astype(adt)
        ctx = jnp.einsum("bht,btr->bhr", probs, c_cache)  # latent context
        w_uv = p["w_uv"].astype(adt).reshape(r, h, dv)
        out = jnp.einsum("bhr,rhd->bhd", ctx, w_uv).reshape(b, 1, h * dv)
    else:
        k_nope = jnp.einsum("btr,rhd->bthd", c_cache, w_uk)
        w_uv = p["w_uv"].astype(adt).reshape(r, h, dv)
        v = jnp.einsum("btr,rhd->bthd", c_cache, w_uv)
        k_full = jnp.concatenate(
            [k_nope, jnp.broadcast_to(kr_cache[:, :, None, :], k_nope.shape[:3] + (dr,))],
            axis=-1)
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = gqa_scores_softmax(q_full, k_full, v, bias[None], scale=scale,
                                 cap=cfg.attn_softcap)
        out = out.reshape(b, 1, h * dv)
    out = out @ p["wo"].astype(adt)
    return out, c_cache, kr_cache


def mla_decode_v2(p, cfg: ModelConfig, x, c_old, kr_old, pos):
    """MLA absorbed decode over the OLD latent cache + new-token merge.

    Returns (out, c_col [b,1,r], kr_col [b,1,dr]) so the caller column-DUSes
    the carried cache (same §Perf pattern as attention_decode_v2).
    """
    b = x.shape[0]
    h = cfg.num_heads
    r, dn, dr, dv = cfg.kv_lora_rank, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    adt = x.dtype
    q = (x @ p["wq"].astype(adt)).reshape(b, 1, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    posn = jnp.full((b, 1), pos)
    q_rope = apply_rope(q_rope, posn, cfg.rope_theta)
    c_col = x @ p["w_dkv"].astype(adt)
    kr_col = apply_rope(x @ p["w_kr"].astype(adt), posn, cfg.rope_theta)

    t = c_old.shape[1]
    k_pos = jnp.arange(t)
    bias = jnp.where(k_pos < pos, 0.0, NEG_INF)
    scale = 1.0 / math.sqrt(dn + dr)
    w_uk = p["w_uk"].astype(adt).reshape(r, h, dn)
    q_abs = jnp.einsum("bshd,rhd->bshr", q_nope, w_uk)[:, 0]   # [b,h,r]
    s_old = jnp.einsum("bhr,btr->bht", q_abs, c_old,
                       preferred_element_type=jnp.float32)
    s_old += jnp.einsum("bshd,btd->bht", q_rope, kr_old,
                        preferred_element_type=jnp.float32)
    s_old = s_old * scale + bias
    s_new = (jnp.einsum("bhr,bsr->bhs", q_abs, c_col,
                        preferred_element_type=jnp.float32)
             + jnp.einsum("bshd,bsd->bhs", q_rope, kr_col,
                          preferred_element_type=jnp.float32)) * scale
    m = jnp.maximum(s_old.max(axis=-1, keepdims=True), s_new)  # [b,h,1]
    p_old = jnp.exp(s_old - m)
    p_new = jnp.exp(s_new - m)
    denom = p_old.sum(axis=-1, keepdims=True) + p_new
    ctx_lat = (jnp.einsum("bht,btr->bhr", p_old.astype(adt), c_old)
               + p_new.astype(adt) * c_col) / denom.astype(adt)
    w_uv = p["w_uv"].astype(adt).reshape(r, h, dv)
    out = jnp.einsum("bhr,rhd->bhd", ctx_lat, w_uv).reshape(b, 1, h * dv)
    out = out @ p["wo"].astype(adt)
    return out, c_col, kr_col


def cross_attention_forward(p, cfg: ModelConfig, x, enc_kv, *, positions=None):
    """Decoder cross-attention: q from x, k/v precomputed from encoder."""
    b, s, _ = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    adt = x.dtype
    q = (x @ p["wq"].astype(adt)).reshape(b, s, h, hd)
    k, v = enc_kv
    t = k.shape[1]
    bias = jnp.zeros((s, t))  # no mask: full cross attention
    scale = 1.0 / math.sqrt(hd)
    out = gqa_scores_softmax(q, k, v, bias[None], scale=scale, cap=None)
    return out.reshape(b, s, h * hd) @ p["wo"].astype(adt)


def encode_cross_kv(p, cfg: ModelConfig, enc_out):
    b, t, _ = enc_out.shape
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    adt = enc_out.dtype
    k = (enc_out @ p["wk"].astype(adt)).reshape(b, t, kv, hd)
    v = (enc_out @ p["wv"].astype(adt)).reshape(b, t, kv, hd)
    return k, v

"""Model configuration for every assigned architecture family.

One dataclass covers dense / moe / ssm / hybrid / encdec / vlm families; the
family field selects the forward implementation in ``model.py``.  Layer stacks
are organized as *blocks* (a tuple of sub-layer kinds) scanned ``n_blocks``
times, plus an optional trailing block — this keeps heterogeneous stacks
(gemma2's local/global alternation, recurrentgemma's rec/rec/attn pattern)
scannable with small HLO.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # attention details
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    sliding_window: Optional[int] = None  # window for 'local' layers
    # block layout: tuple of sub-layer kinds per scanned block.
    # kinds: 'attn' (global), 'local' (sliding window), 'rec' (RG-LRU), 'ssm'
    block_layout: Tuple[str, ...] = ("attn",)
    trailing_layout: Tuple[str, ...] = ()

    # mlp
    mlp_variant: str = "swiglu"  # swiglu | geglu | gelu
    tie_embeddings: bool = False
    post_norm: bool = False      # gemma2 sandwich norms
    embed_scale: bool = False    # gemma family: embeddings scaled by sqrt(d)
    use_rope: bool = True        # whisper uses sinusoidal abs positions instead
    vision_dim: int = 1152       # raw vision/audio embedding dim before projector

    # MoE
    num_experts: int = 0
    num_shared_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0  # expert hidden size (d_ff used for dense fallback)
    moe_capacity_factor: float = 2.0  # sharded path: cap = cf * balanced load

    # MLA (deepseek-v2)
    use_mla: bool = False
    kv_lora_rank: int = 512
    qk_rope_dim: int = 64
    qk_nope_dim: int = 128
    v_head_dim: int = 128

    # SSM (mamba2)
    ssm_state: int = 128
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 256

    # RG-LRU (recurrentgemma)
    lru_width: int = 0  # 0 -> d_model
    conv_width: int = 4

    # enc-dec (whisper)
    enc_layers: int = 0
    dec_layers: int = 0
    enc_seq: int = 1500  # stubbed frame-embedding count

    # vlm
    num_prefix_embeds: int = 0  # patch embeds prepended to text (0 = none)

    # numerics
    param_dtype: str = "float32"
    activ_dtype: str = "bfloat16"
    norm_eps: float = 1e-6

    # training
    remat: bool = True

    # citation of the source model card / paper for this config
    source: str = ""

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.num_heads, 1))
        if self.lru_width == 0:
            object.__setattr__(self, "lru_width", self.d_model)
        n_block_layers = (
            len(self.block_layout) * self.n_blocks + len(self.trailing_layout)
        )
        if self.family not in ("encdec",) and n_block_layers != self.num_layers:
            raise ValueError(
                f"{self.name}: block layout {self.block_layout}x{self.n_blocks}"
                f"+{self.trailing_layout} covers {n_block_layers} layers, "
                f"config says {self.num_layers}"
            )

    @property
    def n_blocks(self) -> int:
        return (self.num_layers - len(self.trailing_layout)) // len(self.block_layout)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def adtype(self):
        return jnp.dtype(self.activ_dtype)

    @property
    def d_inner(self) -> int:  # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def is_subquadratic(self) -> bool:
        """True if no sub-layer performs unbounded full attention."""
        kinds = set(self.block_layout) | set(self.trailing_layout)
        if self.family == "encdec":
            return False
        return "attn" not in kinds

    def reduced(self, **overrides) -> "ModelConfig":
        """A smoke-test-sized variant of the same family (<=2 blocks, small dims)."""
        small = dict(
            num_layers=len(self.block_layout) + len(self.trailing_layout),
            d_model=min(self.d_model, 128),
            num_heads=min(self.num_heads, 4),
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads else 0,
            head_dim=32,
            d_ff=min(self.d_ff, 256) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            sliding_window=min(self.sliding_window, 16) if self.sliding_window else None,
            num_experts=min(self.num_experts, 4) if self.num_experts else 0,
            moe_top_k=min(self.moe_top_k, 2) if self.moe_top_k else 0,
            moe_d_ff=min(self.moe_d_ff, 64) if self.moe_d_ff else 0,
            kv_lora_rank=64,
            qk_rope_dim=16,
            qk_nope_dim=32,
            v_head_dim=32,
            ssm_state=16,
            ssm_headdim=16,
            ssm_chunk=8,
            lru_width=min(self.lru_width, 128),
            enc_layers=min(self.enc_layers, 2) if self.enc_layers else 0,
            dec_layers=min(self.dec_layers, 2) if self.dec_layers else 0,
            enc_seq=16,
            num_prefix_embeds=min(self.num_prefix_embeds, 8) if self.num_prefix_embeds else 0,
            remat=False,
        )
        if self.num_kv_heads and self.num_kv_heads == self.num_heads:
            small["num_kv_heads"] = small["num_heads"]  # keep MHA archs MHA
        if self.family == "encdec":
            small["num_layers"] = small["enc_layers"] + small["dec_layers"]
        small.update(overrides)
        return dataclasses.replace(self, **small)


@dataclasses.dataclass(frozen=True)
class InputShape:
    """One of the four assigned workload shapes."""
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

"""Decode-time caches for every sub-layer kind.

Each *slot* of the scanned block layout owns a cache stacked over blocks
(leading dim = n_blocks).  Kinds:

  attn   — full-length ring buffer (W == max_seq)
  local  — sliding-window ring buffer (W == min(window, max_seq))
  mla    — latent cache (c_kv [r] + k_rope [dr]), no pos_buf (slot == pos)
  rec    — RG-LRU state + conv history
  ssm    — Mamba2 SSD state + conv history
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp

from .base import ModelConfig
from .rglru import RecState
from .ssm import SSMState, conv_channels


class AttnCache(NamedTuple):
    k: jax.Array        # [n, B, W, KV, hd]
    v: jax.Array        # [n, B, W, KV, hd]
    pos_buf: jax.Array  # [n, W] absolute position per ring slot, -1 empty


class MLACache(NamedTuple):
    c: jax.Array   # [n, B, S, r]
    kr: jax.Array  # [n, B, S, dr]


def _stack(n, fn):
    leaves = fn()
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (n,) + x.shape), leaves)


def slot_cache(kind: str, cfg: ModelConfig, n_blocks: int, bsz: int,
               max_seq: int, dtype) -> Any:
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    if kind == "attn":
        w = max_seq
    elif kind == "local":
        w = min(cfg.sliding_window or max_seq, max_seq)
    if kind in ("attn", "local"):
        return AttnCache(
            k=jnp.zeros((n_blocks, bsz, w, kv, hd), dtype),
            v=jnp.zeros((n_blocks, bsz, w, kv, hd), dtype),
            pos_buf=jnp.full((n_blocks, w), -1, jnp.int32),
        )
    if kind == "mla":
        return MLACache(
            c=jnp.zeros((n_blocks, bsz, max_seq, cfg.kv_lora_rank), dtype),
            kr=jnp.zeros((n_blocks, bsz, max_seq, cfg.qk_rope_dim), dtype),
        )
    if kind == "rec":
        return _stack(n_blocks, lambda: RecState(
            h=jnp.zeros((bsz, cfg.lru_width), jnp.float32),
            conv=jnp.zeros((bsz, cfg.conv_width - 1, cfg.lru_width), dtype)))
    if kind == "ssm":
        return _stack(n_blocks, lambda: SSMState(
            ssm=jnp.zeros((bsz, cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state),
                          jnp.float32),
            conv=jnp.zeros((bsz, cfg.ssm_conv_width - 1, conv_channels(cfg)), dtype)))
    raise ValueError(kind)


def resolve_kind(cfg: ModelConfig, kind: str) -> str:
    """Map layout kind to cache kind (attention layers of MLA archs use MLA)."""
    if kind == "attn" and cfg.use_mla:
        return "mla"
    return kind


def init_cache(cfg: ModelConfig, bsz: int, max_seq: int, dtype) -> Dict[str, Any]:
    """Zeroed cache pytree for ``decode_step``; ``pos`` counts tokens so far."""
    cache: Dict[str, Any] = {"pos": jnp.zeros((), jnp.int32)}
    n_blocks = cfg.dec_layers if cfg.family == "encdec" else cfg.n_blocks
    slots = {}
    for i, kind in enumerate(cfg.block_layout):
        slots[f"s{i}"] = slot_cache(resolve_kind(cfg, kind), cfg, n_blocks,
                                    bsz, max_seq, dtype)
    cache["blocks"] = slots
    if cfg.trailing_layout:
        cache["trailing"] = {
            f"s{i}": slot_cache(resolve_kind(cfg, kind), cfg, 1, bsz, max_seq, dtype)
            for i, kind in enumerate(cfg.trailing_layout)}
    if cfg.family == "encdec":
        # cross-attention K/V per decoder layer (from the encoder, fixed)
        cache["cross_k"] = jnp.zeros(
            (cfg.dec_layers, bsz, cfg.enc_seq, cfg.num_kv_heads, cfg.head_dim), dtype)
        cache["cross_v"] = jnp.zeros_like(cache["cross_k"])
    return cache

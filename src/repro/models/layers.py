"""Shared layer primitives: norms, MLPs, embeddings, RoPE.

Pure-function style: ``init_*`` builds a param sub-tree, ``apply`` takes
(params, x).  All matmuls run in the activation dtype with fp32 accumulation
where it matters (attention logits, softmax, norms).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def dense_init(key, shape, dtype, scale: Optional[float] = None):
    """Truncated-normal fan-in init (matches common LLM inits)."""
    fan_in = shape[0] if len(shape) >= 2 else max(shape[0], 1)
    std = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def rms_norm(x, weight, eps: float = 1e-6, *, plus_one: bool = False):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    w = weight.astype(jnp.float32)
    if plus_one:  # gemma convention: weight stored as (w - 1)
        w = w + 1.0
    return (x * w).astype(dtype)


def layer_norm(x, weight, bias, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


def softcap(x, cap: Optional[float]):
    if cap is None:
        return x
    return jnp.tanh(x / cap) * cap


# ---------------------------------------------------------------- MLP


def init_mlp(key, d_model: int, d_ff: int, variant: str, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    if variant in ("swiglu", "geglu"):
        return {
            "w_gate": dense_init(k1, (d_model, d_ff), dtype),
            "w_up": dense_init(k2, (d_model, d_ff), dtype),
            "w_down": dense_init(k3, (d_ff, d_model), dtype),
        }
    return {
        "w_up": dense_init(k1, (d_model, d_ff), dtype),
        "w_down": dense_init(k2, (d_ff, d_model), dtype),
    }


def apply_mlp(params, x, variant: str):
    adt = x.dtype
    if variant in ("swiglu", "geglu"):
        gate = x @ params["w_gate"].astype(adt)
        up = x @ params["w_up"].astype(adt)
        act = jax.nn.silu(gate) if variant == "swiglu" else jax.nn.gelu(gate, approximate=True)
        return (act * up) @ params["w_down"].astype(adt)
    h = jax.nn.gelu(x @ params["w_up"].astype(adt), approximate=True)
    return h @ params["w_down"].astype(adt)


# ---------------------------------------------------------------- embeddings


def init_embedding(key, vocab: int, d_model: int, dtype):
    # std 1/sqrt(d): keeps tied-unembedding logits O(1) at init (and, for the
    # gemma family, the sqrt(d)-scaled input embeddings O(1) per element).
    return {"table": dense_init(key, (vocab, d_model), dtype,
                                scale=d_model ** -0.5)}


def embed(params, tokens, *, scale_by_sqrt_dim: bool = False, adtype=jnp.bfloat16):
    table = params["table"]
    out = jnp.take(table, tokens, axis=0).astype(adtype)
    if scale_by_sqrt_dim:
        out = out * jnp.asarray(math.sqrt(table.shape[1]), adtype)
    return out


def unembed(params, x, *, cap: Optional[float] = None):
    logits = (x @ params["table"].astype(x.dtype).T).astype(jnp.float32)
    return softcap(logits, cap)


def sinusoidal_positions(num_pos: int, dim: int, dtype=jnp.float32):
    pos = jnp.arange(num_pos)[:, None].astype(jnp.float32)
    i = jnp.arange(dim // 2)[None, :].astype(jnp.float32)
    angle = pos / jnp.power(10_000.0, 2 * i / dim)
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1).astype(dtype)


# ---------------------------------------------------------------- RoPE


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: [..., S, H, D] (or [..., S, D]); positions: [..., S]."""
    freqs = rope_freqs(x.shape[-1], theta)  # [D/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, D/2]
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    if x.ndim == angles.ndim + 1:  # head axis present
        sin, cos = sin[..., None, :], cos[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def cross_entropy(logits, labels, *, ignore_id: int = -1):
    """Mean next-token CE over non-ignored labels. logits [..., V] fp32."""
    mask = (labels != ignore_id)
    labels = jnp.where(mask, labels, 0)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1)

"""Model assembly: init / forward / loss / prefill / decode for all families.

Layer stacks are scanned (`lax.scan` over params stacked on a leading
n_blocks dim) so HLO size is independent of depth — required to compile
42-layer models for 512 simulated devices on CPU in the dry-run.

Families:
  dense / vlm    — [pre-norm attn][pre-norm MLP] blocks (+ optional sandwich
                   norms, sliding-window or alternating local/global layouts)
  moe            — MLP replaced by top-k routed experts (+ shared experts);
                   attention may be GQA or MLA (deepseek-v2)
  ssm            — Mamba2 SSD blocks (no separate MLP)
  hybrid         — RecurrentGemma (rec, rec, attn) pattern
  encdec         — Whisper: bidirectional encoder + cross-attending decoder

VLM / audio frontends are stubbed per the assignment carve-out:
``prefix_embeds`` (patch / mel-frame embeddings) arrive precomputed and pass
through a learned projector.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.sharding.ctx import constrain_batch

from . import attention as attn_mod
from . import moe as moe_mod
from . import rglru as rec_mod
from . import ssm as ssm_mod
from .attention import KVEntry
from .base import ModelConfig
from .kvcache import AttnCache, MLACache, init_cache, resolve_kind
from .layers import (apply_mlp, cross_entropy, dense_init, embed,
                     init_embedding, init_mlp, rms_norm,
                     sinusoidal_positions, softcap, unembed)

# ===================================================================== init


def _init_sublayer(key, cfg: ModelConfig, kind: str, dtype, *, cross: bool = False):
    ks = jax.random.split(key, 6)
    p: Dict[str, Any] = {"norm1": jnp.zeros((cfg.d_model,), dtype)}
    if kind in ("attn", "local"):
        if cfg.use_mla and kind == "attn":
            p["mla"] = attn_mod.init_mla(ks[0], cfg, dtype)
        else:
            p["attn"] = attn_mod.init_attention(ks[0], cfg, dtype)
    elif kind == "rec":
        p["rec"] = rec_mod.init_rec(ks[0], cfg, dtype)
    elif kind == "ssm":
        p["ssm"] = ssm_mod.init_ssm(ks[0], cfg, dtype)
        return p  # mamba block has no separate MLP
    else:
        raise ValueError(kind)
    if cross:
        p["norm_x"] = jnp.zeros((cfg.d_model,), dtype)
        p["xattn"] = attn_mod.init_attention(ks[2], cfg, dtype)
    if cfg.post_norm:
        p["norm1b"] = jnp.zeros((cfg.d_model,), dtype)
    p["norm2"] = jnp.zeros((cfg.d_model,), dtype)
    if cfg.num_experts and kind != "rec":
        p["moe"] = moe_mod.init_moe(ks[1], cfg, dtype)
    else:
        p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp_variant, dtype)
    if cfg.post_norm:
        p["norm2b"] = jnp.zeros((cfg.d_model,), dtype)
    return p


def _stack_init(key, n: int, fn):
    return jax.vmap(fn)(jax.random.split(key, n))


def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    dtype = cfg.pdtype
    ks = jax.random.split(key, 8)
    params: Dict[str, Any] = {
        "embed": init_embedding(ks[0], cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    if cfg.family == "encdec":
        params["enc_blocks"] = _stack_init(
            ks[1], cfg.enc_layers,
            lambda k: _init_sublayer(k, cfg, "attn", dtype))
        params["dec_blocks"] = _stack_init(
            ks[2], cfg.dec_layers,
            lambda k: _init_sublayer(k, cfg, "attn", dtype, cross=True))
        params["enc_norm"] = jnp.zeros((cfg.d_model,), dtype)
        params["frame_proj"] = dense_init(ks[3], (cfg.vision_dim, cfg.d_model), dtype)
        return params
    if cfg.family == "vlm" or cfg.num_prefix_embeds:
        params["vision_proj"] = dense_init(ks[3], (cfg.vision_dim, cfg.d_model), dtype)
    blocks = {}
    for i, kind in enumerate(cfg.block_layout):
        blocks[f"s{i}"] = _stack_init(
            jax.random.fold_in(ks[4], i), cfg.n_blocks,
            lambda k, kind=kind: _init_sublayer(k, cfg, kind, dtype))
    params["blocks"] = blocks
    if cfg.trailing_layout:
        params["trailing"] = {
            f"s{i}": _stack_init(
                jax.random.fold_in(ks[5], i), 1,
                lambda k, kind=kind: _init_sublayer(k, cfg, kind, dtype))
            for i, kind in enumerate(cfg.trailing_layout)}
    return params


# ============================================================== full forward


def _apply_sublayer(p, cfg: ModelConfig, kind: str, x, positions, aux):
    """One residual sub-layer (full sequence)."""
    h = rms_norm(x, p["norm1"], cfg.norm_eps, plus_one=True)
    if kind in ("attn", "local"):
        window = cfg.sliding_window if kind == "local" else None
        if cfg.use_mla and kind == "attn":
            h = attn_mod.mla_forward(p["mla"], cfg, h, positions)
        else:
            h = attn_mod.attention_forward(p["attn"], cfg, h, positions,
                                           window=window)
    elif kind == "rec":
        h = rec_mod.rec_forward(p["rec"], cfg, h)
    elif kind == "ssm":
        h = ssm_mod.ssm_forward(p["ssm"], cfg, h)
        return x + h, aux  # mamba block: single residual, no MLP
    if cfg.post_norm:
        h = rms_norm(h, p["norm1b"], cfg.norm_eps, plus_one=True)
    x = x + h
    h = rms_norm(x, p["norm2"], cfg.norm_eps, plus_one=True)
    if "moe" in p and kind != "rec":
        h, a = moe_mod.apply_moe(p["moe"], cfg, h, return_aux=True)
        aux = aux + a
    else:
        h = apply_mlp(p["mlp"], h, cfg.mlp_variant)
    if cfg.post_norm:
        h = rms_norm(h, p["norm2b"], cfg.norm_eps, plus_one=True)
    return x + h, aux


def _scan_blocks(params_slot_dict, cfg: ModelConfig, layout, x, positions,
                 aux0):
    """Scan a (possibly multi-slot) block layout over its stacked params."""

    def block(carry, slot_params):
        h, aux = carry
        for i, kind in enumerate(layout):
            h, aux = _apply_sublayer(slot_params[f"s{i}"], cfg, kind, h,
                                     positions, aux)
        return (constrain_batch(h), aux), None

    if cfg.remat:
        block = jax.checkpoint(block)
    (x, aux), _ = jax.lax.scan(block, (x, aux0), params_slot_dict)
    return x, aux


def _embed_inputs(params, cfg: ModelConfig, tokens, prefix_embeds):
    x = embed(params["embed"], tokens, scale_by_sqrt_dim=cfg.embed_scale,
              adtype=cfg.adtype)
    if prefix_embeds is not None and cfg.family != "encdec":
        pre = (prefix_embeds.astype(cfg.adtype)
               @ params["vision_proj"].astype(cfg.adtype))
        x = jnp.concatenate([pre, x], axis=1)
    return x


def forward(params, cfg: ModelConfig, tokens, prefix_embeds=None,
            *, return_aux: bool = False):
    """Full-sequence logits.  tokens [B, S_text]; prefix_embeds [B, P, vdim]."""
    if cfg.family == "encdec":
        return _encdec_forward(params, cfg, tokens, prefix_embeds,
                               return_aux=return_aux)
    x = constrain_batch(_embed_inputs(params, cfg, tokens, prefix_embeds))
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    if not cfg.use_rope:
        x = x + sinusoidal_positions(s, cfg.d_model, x.dtype)[None]
        positions = jnp.zeros_like(positions)
    aux = jnp.zeros((), jnp.float32)
    x, aux = _scan_blocks(params["blocks"], cfg, cfg.block_layout, x,
                          positions, aux)
    if cfg.trailing_layout:
        x, aux = _scan_blocks(params["trailing"], cfg, cfg.trailing_layout, x,
                              positions, aux)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps, plus_one=True)
    logits = unembed(params["embed"], x, cap=cfg.final_softcap)
    if return_aux:
        return logits, aux
    return logits


def _encdec_forward(params, cfg: ModelConfig, tokens, frame_embeds,
                    *, return_aux: bool = False):
    adt = cfg.adtype
    enc = frame_embeds.astype(adt) @ params["frame_proj"].astype(adt)
    enc = enc + sinusoidal_positions(enc.shape[1], cfg.d_model, adt)[None]
    zero_pos = jnp.zeros(enc.shape[:2], jnp.int32)

    def enc_block(h, p):
        a = rms_norm(h, p["norm1"], cfg.norm_eps, plus_one=True)
        # bidirectional: no mask
        b_, s_, _ = a.shape
        q = (a @ p["attn"]["wq"].astype(adt)).reshape(b_, s_, cfg.num_heads, cfg.head_dim)
        k = (a @ p["attn"]["wk"].astype(adt)).reshape(b_, s_, cfg.num_kv_heads, cfg.head_dim)
        v = (a @ p["attn"]["wv"].astype(adt)).reshape(b_, s_, cfg.num_kv_heads, cfg.head_dim)
        bias = jnp.zeros((s_, s_))
        o = attn_mod.gqa_scores_softmax(q, k, v, bias[None],
                                        scale=cfg.head_dim ** -0.5, cap=None)
        h = h + o.reshape(b_, s_, -1) @ p["attn"]["wo"].astype(adt)
        m = rms_norm(h, p["norm2"], cfg.norm_eps, plus_one=True)
        return constrain_batch(h + apply_mlp(p["mlp"], m, cfg.mlp_variant)), None

    enc, _ = jax.lax.scan(enc_block, enc, params["enc_blocks"])
    enc = rms_norm(enc, params["enc_norm"], cfg.norm_eps, plus_one=True)

    x = embed(params["embed"], tokens, adtype=adt)
    s = x.shape[1]
    x = x + sinusoidal_positions(s, cfg.d_model, adt)[None]
    positions = jnp.broadcast_to(jnp.zeros((), jnp.int32), x.shape[:2])

    def dec_block(h, p):
        a = rms_norm(h, p["norm1"], cfg.norm_eps, plus_one=True)
        h = h + attn_mod.attention_forward(p["attn"], cfg, a, positions)
        a = rms_norm(h, p["norm_x"], cfg.norm_eps, plus_one=True)
        enc_kv = attn_mod.encode_cross_kv(p["xattn"], cfg, enc)
        h = h + attn_mod.cross_attention_forward(p["xattn"], cfg, a, enc_kv)
        m = rms_norm(h, p["norm2"], cfg.norm_eps, plus_one=True)
        return constrain_batch(h + apply_mlp(p["mlp"], m, cfg.mlp_variant)), None

    x, _ = jax.lax.scan(dec_block, x, params["dec_blocks"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps, plus_one=True)
    logits = unembed(params["embed"], x, cap=cfg.final_softcap)
    if return_aux:
        return logits, jnp.zeros((), jnp.float32)
    return logits


# ===================================================================== loss

AUX_WEIGHT = 0.01


def loss_fn(params, cfg: ModelConfig, batch) -> Tuple[jax.Array, Dict[str, Any]]:
    """batch: {'tokens', 'labels', optional 'prefix_embeds'}."""
    logits, aux = forward(params, cfg, batch["tokens"],
                          batch.get("prefix_embeds"), return_aux=True)
    labels = batch["labels"]
    if logits.shape[1] != labels.shape[1]:  # vlm prefix: no labels on patches
        pad = jnp.full(
            (labels.shape[0], logits.shape[1] - labels.shape[1]), -1,
            labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    ce = cross_entropy(logits, labels)
    loss = ce + AUX_WEIGHT * aux
    return loss, {"ce": ce, "aux": aux}


# =================================================================== prefill


def _prefill_fill_attn(cfg, kv: KVEntry, w: int, s: int):
    """Pack last-w tokens of prefill K/V into a ring buffer + pos_buf."""
    k, v = kv
    b = k.shape[0]
    if s >= w:
        pos = jnp.arange(s - w, s)
        slots = pos % w
        kk = jnp.zeros((b, w) + k.shape[2:], k.dtype).at[:, slots].set(k[:, -w:])
        vv = jnp.zeros((b, w) + v.shape[2:], v.dtype).at[:, slots].set(v[:, -w:])
        pos_buf = jnp.full((w,), -1, jnp.int32).at[slots].set(pos)
    else:
        kk = jnp.zeros((b, w) + k.shape[2:], k.dtype).at[:, :s].set(k)
        vv = jnp.zeros((b, w) + v.shape[2:], v.dtype).at[:, :s].set(v)
        pos_buf = jnp.full((w,), -1, jnp.int32).at[:s].set(jnp.arange(s))
    return kk, vv, pos_buf


def prefill(params, cfg: ModelConfig, tokens, prefix_embeds=None,
            *, max_seq: Optional[int] = None):
    """Run the prompt, returning (last-token logits, populated cache)."""
    if cfg.family == "encdec":
        return _encdec_prefill(params, cfg, tokens, prefix_embeds, max_seq)
    x = constrain_batch(_embed_inputs(params, cfg, tokens, prefix_embeds))
    b, s, _ = x.shape
    max_seq = max_seq or s
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    if not cfg.use_rope:
        x = x + sinusoidal_positions(s, cfg.d_model, x.dtype)[None]
        positions = jnp.zeros_like(positions)
    cache = init_cache(cfg, b, max_seq, cfg.adtype)
    cache["pos"] = jnp.asarray(s, jnp.int32)

    def run_layout(x, slot_params_dict, layout):
        new_slots = {}

        def block(h, slot_params):
            outs = {}
            for i, kind in enumerate(layout):
                p = slot_params[f"s{i}"]
                ck = resolve_kind(cfg, kind)
                hin = rms_norm(h, p["norm1"], cfg.norm_eps, plus_one=True)
                if ck == "mla":
                    o, (c_kv, k_rope) = attn_mod.mla_forward(
                        p["mla"], cfg, hin, positions, return_cache=True)
                    c = jnp.zeros((b, max_seq, cfg.kv_lora_rank), cfg.adtype
                                  ).at[:, :s].set(c_kv)
                    kr = jnp.zeros((b, max_seq, cfg.qk_rope_dim), cfg.adtype
                                   ).at[:, :s].set(k_rope)
                    outs[f"s{i}"] = MLACache(c=c, kr=kr)
                elif ck in ("attn", "local"):
                    window = cfg.sliding_window if kind == "local" else None
                    o, kv = attn_mod.attention_forward(
                        p["attn"], cfg, hin, positions, window=window,
                        return_kv=True)
                    w = max_seq if ck == "attn" else min(cfg.sliding_window, max_seq)
                    kk, vv, pos_buf = _prefill_fill_attn(cfg, kv, w, s)
                    outs[f"s{i}"] = AttnCache(k=kk, v=vv, pos_buf=pos_buf)
                elif ck == "rec":
                    o, st = rec_mod.rec_forward(p["rec"], cfg, hin,
                                                return_state=True)
                    outs[f"s{i}"] = st
                elif ck == "ssm":
                    o, st = ssm_mod.ssm_forward(p["ssm"], cfg, hin,
                                                return_state=True)
                    outs[f"s{i}"] = st
                    h = h + o
                    continue
                if cfg.post_norm:
                    o = rms_norm(o, p["norm1b"], cfg.norm_eps, plus_one=True)
                h = h + o
                m = rms_norm(h, p["norm2"], cfg.norm_eps, plus_one=True)
                if "moe" in p:
                    m = moe_mod.apply_moe(p["moe"], cfg, m)
                else:
                    m = apply_mlp(p["mlp"], m, cfg.mlp_variant)
                if cfg.post_norm:
                    m = rms_norm(m, p["norm2b"], cfg.norm_eps, plus_one=True)
                h = h + m
            return constrain_batch(h), outs

        x, slot_caches = jax.lax.scan(block, x, slot_params_dict)
        return x, slot_caches

    x, cache["blocks"] = run_layout(x, params["blocks"], cfg.block_layout)
    if cfg.trailing_layout:
        x, cache["trailing"] = run_layout(x, params["trailing"],
                                          cfg.trailing_layout)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps, plus_one=True)
    logits = unembed(params["embed"], x[:, -1:], cap=cfg.final_softcap)
    return logits, cache


def _encdec_prefill(params, cfg, tokens, frame_embeds, max_seq):
    """Encode frames once; prefill decoder self-attn cache with `tokens`."""
    adt = cfg.adtype
    b = tokens.shape[0]
    s = tokens.shape[1]
    max_seq = max_seq or s
    # reuse full forward for encoder by calling _encdec_forward pieces
    enc = frame_embeds.astype(adt) @ params["frame_proj"].astype(adt)
    enc = enc + sinusoidal_positions(enc.shape[1], cfg.d_model, adt)[None]

    def enc_block(h, p):
        a = rms_norm(h, p["norm1"], cfg.norm_eps, plus_one=True)
        b_, s_, _ = a.shape
        q = (a @ p["attn"]["wq"].astype(adt)).reshape(b_, s_, cfg.num_heads, cfg.head_dim)
        k = (a @ p["attn"]["wk"].astype(adt)).reshape(b_, s_, cfg.num_kv_heads, cfg.head_dim)
        v = (a @ p["attn"]["wv"].astype(adt)).reshape(b_, s_, cfg.num_kv_heads, cfg.head_dim)
        bias = jnp.zeros((s_, s_))
        o = attn_mod.gqa_scores_softmax(q, k, v, bias[None],
                                        scale=cfg.head_dim ** -0.5, cap=None)
        h = h + o.reshape(b_, s_, -1) @ p["attn"]["wo"].astype(adt)
        m = rms_norm(h, p["norm2"], cfg.norm_eps, plus_one=True)
        return constrain_batch(h + apply_mlp(p["mlp"], m, cfg.mlp_variant)), None

    enc, _ = jax.lax.scan(enc_block, enc, params["enc_blocks"])
    enc = rms_norm(enc, params["enc_norm"], cfg.norm_eps, plus_one=True)

    cache = init_cache(cfg, b, max_seq, adt)
    cache["pos"] = jnp.asarray(s, jnp.int32)
    x = embed(params["embed"], tokens, adtype=adt)
    x = x + sinusoidal_positions(s, cfg.d_model, adt)[None]
    positions = jnp.zeros((b, s), jnp.int32)

    def dec_block(h, p):
        a = rms_norm(h, p["norm1"], cfg.norm_eps, plus_one=True)
        o, kv = attn_mod.attention_forward(p["attn"], cfg, a, positions,
                                           return_kv=True)
        kk, vv, pos_buf = _prefill_fill_attn(cfg, kv, max_seq, s)
        h = h + o
        a = rms_norm(h, p["norm_x"], cfg.norm_eps, plus_one=True)
        ck, cv = attn_mod.encode_cross_kv(p["xattn"], cfg, enc)
        h = h + attn_mod.cross_attention_forward(p["xattn"], cfg, a, (ck, cv))
        m = rms_norm(h, p["norm2"], cfg.norm_eps, plus_one=True)
        h = h + apply_mlp(p["mlp"], m, cfg.mlp_variant)
        return constrain_batch(h), (AttnCache(k=kk, v=vv, pos_buf=pos_buf), ck, cv)

    x, (self_cache, cross_k, cross_v) = jax.lax.scan(dec_block, x,
                                                     params["dec_blocks"])
    cache["blocks"] = {"s0": self_cache}
    cache["cross_k"], cache["cross_v"] = cross_k, cross_v
    x = rms_norm(x, params["final_norm"], cfg.norm_eps, plus_one=True)
    logits = unembed(params["embed"], x[:, -1:], cap=cfg.final_softcap)
    return logits, cache


# ==================================================================== decode


def _prefer_carry_decode(cfg: ModelConfig, cache) -> bool:
    """Carry-based decode (column writes) wins only when every attention
    cache is kv-head sharded ('kv' layout); otherwise the xs/ys path
    measured better (EXPERIMENTS.md §Perf pair 1 iterations 2-3)."""
    from repro.sharding import ctx, specs as sp
    mesh = ctx.current_mesh()
    if mesh is None:
        return True  # single device: equivalent; carry is the tested path
    slots = list(cache.get("blocks", {}).values()) + \
        list(cache.get("trailing", {}).values())
    attn_slots = [s for s in slots if isinstance(s, AttnCache)]
    if not attn_slots or any(isinstance(s, MLACache) for s in slots):
        return False
    return all(
        sp.decode_cache_layout(s.k.shape[3], s.k.shape[2], mesh) == "kv"
        for s in attn_slots)


def decode_step(params, cfg: ModelConfig, token, cache):
    """One decode step.  token [B, 1] int32 -> (logits [B,1,V], new cache)."""
    pos = cache["pos"]
    b = token.shape[0]
    x = constrain_batch(embed(params["embed"], token,
                              scale_by_sqrt_dim=cfg.embed_scale,
                              adtype=cfg.adtype))
    if not cfg.use_rope:
        pe = sinusoidal_positions(1, cfg.d_model, x.dtype)  # position folded below
        # use true position via direct computation
        angle_pos = pos.astype(jnp.float32)
        i = jnp.arange(cfg.d_model // 2).astype(jnp.float32)
        ang = angle_pos / jnp.power(10_000.0, 2 * i / cfg.d_model)
        pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])[None, None].astype(x.dtype)
        x = x + pe
        rope_pos = jnp.zeros((), jnp.int32)
    else:
        rope_pos = pos

    if cfg.family == "encdec":
        return _encdec_decode(params, cfg, x, cache, rope_pos)

    def run_layout_ys(x, slot_params_dict, slot_cache_dict, layout):
        """Scan with caches as xs/ys (each step reads and re-emits its
        layer's cache slice).  Measured best for seq-sharded / replicated
        cache layouts and pure-state stacks, where the carry variant's
        column-DUS crosses a sharded dim (GSPMD full-buffer select) or the
        f32 carry round-trip dominates (see EXPERIMENTS.md §Perf pair 1)."""

        def block(h, inp):
            slot_params, slot_cache = inp
            new_cache = {}
            for i, kind in enumerate(layout):
                p = slot_params[f"s{i}"]
                c = slot_cache[f"s{i}"]
                ck = resolve_kind(cfg, kind)
                hin = rms_norm(h, p["norm1"], cfg.norm_eps, plus_one=True)
                if ck == "mla":
                    o, cc, kr = attn_mod.mla_decode(p["mla"], cfg, hin, c.c,
                                                    c.kr, pos)
                    new_cache[f"s{i}"] = MLACache(c=cc, kr=kr)
                elif ck in ("attn", "local"):
                    window = cfg.sliding_window if kind == "local" else None
                    if attn_mod.use_sharded_decode(cfg, c.k.shape[1]):
                        o, kv, pb = attn_mod.attention_decode_sharded(
                            p["attn"], cfg, hin, KVEntry(c.k, c.v),
                            c.pos_buf, pos, window=window)
                    else:
                        o, kv, pb = attn_mod.attention_decode(
                            p["attn"], cfg, hin, KVEntry(c.k, c.v),
                            c.pos_buf, pos, window=window)
                    new_cache[f"s{i}"] = AttnCache(k=kv.k, v=kv.v, pos_buf=pb)
                elif ck == "rec":
                    o, st = rec_mod.rec_decode_step(p["rec"], cfg, hin, c)
                    new_cache[f"s{i}"] = st
                elif ck == "ssm":
                    o, st = ssm_mod.ssm_decode_step(p["ssm"], cfg, hin, c)
                    new_cache[f"s{i}"] = st
                    h = h + o
                    continue
                if cfg.post_norm:
                    o = rms_norm(o, p["norm1b"], cfg.norm_eps, plus_one=True)
                h = h + o
                m = rms_norm(h, p["norm2"], cfg.norm_eps, plus_one=True)
                if "moe" in p:
                    m = moe_mod.apply_moe(p["moe"], cfg, m)
                else:
                    m = apply_mlp(p["mlp"], m, cfg.mlp_variant)
                if cfg.post_norm:
                    m = rms_norm(m, p["norm2b"], cfg.norm_eps, plus_one=True)
                h = h + m
            return constrain_batch(h), new_cache

        x, new_caches = jax.lax.scan(block, x, (slot_params_dict,
                                                slot_cache_dict))
        return x, new_caches

    def run_layout_carry(x, slot_params_dict, slot_cache_dict, layout):
        """Scan over blocks with the caches as scan CARRY.

        §Perf iteration 2: carrying the stacked caches (instead of scanning
        them as xs/ys) lets each step write only the new token's K/V COLUMN
        via dynamic-update-slice — per-step cache writes drop from the full
        per-layer slice to one column, leaving reads (the true decode
        roofline floor) as the only large term.
        """
        n_blocks = jax.tree_util.tree_leaves(slot_params_dict)[0].shape[0]

        def idx_slice(tree, idx):
            return jax.tree.map(
                lambda a: jax.lax.dynamic_index_in_dim(a, idx, 0,
                                                       keepdims=False), tree)

        def block(carry, inp):
            h, caches = carry
            slot_params, idx = inp
            for i, kind in enumerate(layout):
                p = slot_params[f"s{i}"]
                cfull = caches[f"s{i}"]
                ck = resolve_kind(cfg, kind)
                hin = rms_norm(h, p["norm1"], cfg.norm_eps, plus_one=True)
                if ck == "mla":
                    c_old = jax.lax.dynamic_index_in_dim(cfull.c, idx, 0, False)
                    kr_old = jax.lax.dynamic_index_in_dim(cfull.kr, idx, 0, False)
                    o, c_col, kr_col = attn_mod.mla_decode_v2(
                        p["mla"], cfg, hin, c_old, kr_old, pos)
                    caches[f"s{i}"] = MLACache(
                        c=jax.lax.dynamic_update_slice(
                            cfull.c, c_col[None].astype(cfull.c.dtype),
                            (idx, 0, pos, 0)),
                        kr=jax.lax.dynamic_update_slice(
                            cfull.kr, kr_col[None].astype(cfull.kr.dtype),
                            (idx, 0, pos, 0)))
                elif ck in ("attn", "local"):
                    window = cfg.sliding_window if kind == "local" else None
                    ck_old = jax.lax.dynamic_index_in_dim(cfull.k, idx, 0, False)
                    cv_old = jax.lax.dynamic_index_in_dim(cfull.v, idx, 0, False)
                    pb_old = jax.lax.dynamic_index_in_dim(cfull.pos_buf, idx,
                                                          0, False)
                    sharded = attn_mod.use_sharded_decode(cfg, ck_old.shape[1])
                    o, k_col, v_col, slot = attn_mod.attention_decode_v2(
                        p["attn"], cfg, hin, ck_old, cv_old, pb_old, pos,
                        window=window, sharded=sharded,
                        rope_pos=(jnp.zeros((), jnp.int32)
                                  if not cfg.use_rope else None))
                    caches[f"s{i}"] = AttnCache(
                        k=jax.lax.dynamic_update_slice(
                            cfull.k, k_col[None].astype(cfull.k.dtype),
                            (idx, 0, slot, 0, 0)),
                        v=jax.lax.dynamic_update_slice(
                            cfull.v, v_col[None].astype(cfull.v.dtype),
                            (idx, 0, slot, 0, 0)),
                        pos_buf=jax.lax.dynamic_update_slice(
                            cfull.pos_buf,
                            jnp.full((1, 1), pos, cfull.pos_buf.dtype),
                            (idx, slot)))
                elif ck == "rec":
                    st_old = idx_slice(cfull, idx)
                    o, st = rec_mod.rec_decode_step(p["rec"], cfg, hin, st_old)
                    caches[f"s{i}"] = jax.tree.map(
                        lambda full, new: jax.lax.dynamic_update_slice_in_dim(
                            full, new[None].astype(full.dtype), idx, axis=0),
                        cfull, st)
                elif ck == "ssm":
                    st_old = idx_slice(cfull, idx)
                    o, st = ssm_mod.ssm_decode_step(p["ssm"], cfg, hin, st_old)
                    caches[f"s{i}"] = jax.tree.map(
                        lambda full, new: jax.lax.dynamic_update_slice_in_dim(
                            full, new[None].astype(full.dtype), idx, axis=0),
                        cfull, st)
                    h = h + o
                    continue
                if cfg.post_norm:
                    o = rms_norm(o, p["norm1b"], cfg.norm_eps, plus_one=True)
                h = h + o
                m = rms_norm(h, p["norm2"], cfg.norm_eps, plus_one=True)
                if "moe" in p:
                    m = moe_mod.apply_moe(p["moe"], cfg, m)
                else:
                    m = apply_mlp(p["mlp"], m, cfg.mlp_variant)
                if cfg.post_norm:
                    m = rms_norm(m, p["norm2b"], cfg.norm_eps, plus_one=True)
                h = h + m
            return (constrain_batch(h), caches), None

        (x, new_caches), _ = jax.lax.scan(
            block, (x, slot_cache_dict),
            (slot_params_dict, jnp.arange(n_blocks)))
        return x, new_caches

    run_layout = (run_layout_carry if _prefer_carry_decode(cfg, cache)
                  else run_layout_ys)
    new_cache = {"pos": pos + 1}
    x, new_cache["blocks"] = run_layout(x, params["blocks"], cache["blocks"],
                                        cfg.block_layout)
    if cfg.trailing_layout:
        x, new_cache["trailing"] = run_layout(x, params["trailing"],
                                              cache["trailing"],
                                              cfg.trailing_layout)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps, plus_one=True)
    logits = unembed(params["embed"], x, cap=cfg.final_softcap)
    return logits, new_cache


def _encdec_decode(params, cfg, x, cache, pos):
    positions = jnp.zeros((x.shape[0], 1), jnp.int32)

    def block(h, inp):
        p, c, ck, cv = inp
        a = rms_norm(h, p["norm1"], cfg.norm_eps, plus_one=True)
        o, kv, pb = attn_mod.attention_decode(
            p["attn"], cfg, a, KVEntry(c.k, c.v), c.pos_buf, cache["pos"],
            rope_pos=jnp.zeros((), jnp.int32))
        h = h + o
        a = rms_norm(h, p["norm_x"], cfg.norm_eps, plus_one=True)
        h = h + attn_mod.cross_attention_forward(p["xattn"], cfg, a, (ck, cv))
        m = rms_norm(h, p["norm2"], cfg.norm_eps, plus_one=True)
        h = h + apply_mlp(p["mlp"], m, cfg.mlp_variant)
        return constrain_batch(h), AttnCache(k=kv.k, v=kv.v, pos_buf=pb)

    x, new_self = jax.lax.scan(
        block, x, (params["dec_blocks"], cache["blocks"]["s0"],
                   cache["cross_k"], cache["cross_v"]))
    new_cache = dict(cache)
    new_cache["pos"] = cache["pos"] + 1
    new_cache["blocks"] = {"s0": new_self}
    x = rms_norm(x, params["final_norm"], cfg.norm_eps, plus_one=True)
    logits = unembed(params["embed"], x, cap=cfg.final_softcap)
    return logits, new_cache

"""Mixture-of-Experts layer: top-k routing with two dispatch backends.

* ``moe_ragged`` — dropless sort + ``jax.lax.ragged_dot`` (exact; CPU tests
  and single-device runs.  XLA:CPU decomposes ragged_dot into dense
  per-group dots, so it cannot be used at production scale in the dry-run).
* ``moe_capacity_local`` — capacity-bounded expert scan over locally-sorted
  tokens, run under ``shard_map`` (manual over the batch axes — tokens stay
  device-local, no global sort / all-to-all; auto over 'model' — expert ff
  dims stay tensor-parallel).  FLOPs = capacity_factor x active FLOPs.

Expert weights live in one stacked array [E, d, ff] with the ff dim sharded
over the 'model' mesh axis (tensor-parallel experts — legitimate here
because the assigned MoE archs have small experts: d_ff 512 and 1408).
Shared experts (DeepSeek-V2 style) are a plain always-on MLP of width
``num_shared_experts * moe_d_ff``.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .base import ModelConfig
from .layers import dense_init, init_mlp, apply_mlp


def init_moe(key, cfg: ModelConfig, dtype):
    d, e, ff = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, e), jnp.float32),  # router kept fp32
        "w_gate": dense_init(ks[1], (e, d, ff), dtype),
        "w_up": dense_init(ks[2], (e, d, ff), dtype),
        "w_down": dense_init(ks[3], (e, ff, d), dtype),
    }
    if cfg.num_shared_experts:
        p["shared"] = init_mlp(ks[4], d, cfg.num_shared_experts * ff, "swiglu", dtype)
    return p


def route_topk(router_w, x_flat, top_k: int):
    """Returns (weights [T,k], expert_ids [T,k], router_probs [T,E])."""
    logits = (x_flat.astype(jnp.float32) @ router_w)  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    weights, ids = jax.lax.top_k(probs, top_k)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)  # renormalize
    return weights, ids, probs


def _dispatch(cfg: ModelConfig, router_w, x_flat):
    """Route + stable sort by expert id."""
    t = x_flat.shape[0]
    k, e = cfg.moe_top_k, cfg.num_experts
    weights, ids, probs = route_topk(router_w, x_flat, k)
    flat_ids = ids.reshape(t * k)
    flat_w = weights.reshape(t * k)
    token_idx = jnp.repeat(jnp.arange(t), k)
    order = jnp.argsort(flat_ids)
    sorted_ids = flat_ids[order]
    group_sizes = jnp.bincount(sorted_ids, length=e).astype(jnp.int32)
    return token_idx[order], flat_w[order], ids, group_sizes, probs


def _aux_loss(cfg: ModelConfig, ids, probs, t):
    """Switch-style load-balance loss: E * sum_e f_e * P_e / k."""
    counts = jnp.zeros((t, cfg.num_experts)).at[
        jnp.arange(t)[:, None], ids].set(1.0)
    f = counts.mean(axis=0)
    pbar = probs.mean(axis=0)
    return cfg.num_experts * jnp.sum(f * pbar) / cfg.moe_top_k


def moe_ragged(p, cfg: ModelConfig, x_flat):
    t, d = x_flat.shape
    adt = x_flat.dtype
    sorted_tok, sorted_w, ids, group_sizes, probs = _dispatch(
        cfg, p["router"], x_flat)
    x_sorted = jnp.take(x_flat, sorted_tok, axis=0)
    gate = jax.lax.ragged_dot(x_sorted, p["w_gate"].astype(adt), group_sizes)
    up = jax.lax.ragged_dot(x_sorted, p["w_up"].astype(adt), group_sizes)
    h = jax.nn.silu(gate) * up
    y_sorted = jax.lax.ragged_dot(h, p["w_down"].astype(adt), group_sizes)
    out = jnp.zeros((t, d), adt).at[sorted_tok].add(
        y_sorted * sorted_w.astype(adt)[:, None])
    return out, _aux_loss(cfg, ids, probs, t)


def moe_capacity_local(p, cfg: ModelConfig, x_flat):
    """Capacity-bounded expert scan over locally-sorted tokens.

    Each expert processes a static ``capacity`` window starting at its group
    offset; ascending expert order makes window overlaps self-correcting (a
    later expert's write overrides the masked tail of the previous window).
    Tokens beyond capacity are dropped (standard capacity-factor semantics).
    """
    t, d = x_flat.shape
    e, k = cfg.num_experts, cfg.moe_top_k
    adt = x_flat.dtype
    sorted_tok, sorted_w, ids, group_sizes, probs = _dispatch(
        cfg, p["router"], x_flat)
    cap = int(-(-t * k * cfg.moe_capacity_factor // e))  # ceil
    cap = max(((cap + 7) // 8) * 8, 8)
    offs = jnp.cumsum(group_sizes) - group_sizes
    xs = jnp.take(x_flat, sorted_tok, axis=0)
    xs = jnp.pad(xs, ((0, cap), (0, 0)))  # no tail clamping
    y0 = jnp.zeros_like(xs)

    def expert(y, inp):
        wg, wu, wd, off, size = inp
        rows = jax.lax.dynamic_slice_in_dim(xs, off, cap, axis=0)
        h = jax.nn.silu(rows @ wg) * (rows @ wu)
        o = h @ wd
        mask = (jnp.arange(cap) < size)[:, None].astype(adt)
        return jax.lax.dynamic_update_slice_in_dim(y, o * mask, off, axis=0), None

    y, _ = jax.lax.scan(
        expert, y0,
        (p["w_gate"].astype(adt), p["w_up"].astype(adt),
         p["w_down"].astype(adt), offs, group_sizes))
    y = y[:t * k]
    out = jnp.zeros((t, d), adt).at[sorted_tok].add(
        y * sorted_w.astype(adt)[:, None])
    return out, _aux_loss(cfg, ids, probs, t)


def apply_moe(p, cfg: ModelConfig, x, *, return_aux: bool = False):
    """x [B,S,d] -> [B,S,d] (+ aux load-balance loss)."""
    from repro.sharding import ctx  # local import to avoid cycles

    b, s, d = x.shape
    x_flat = x.reshape(b * s, d)
    baxes, mesh = ctx.batch_axes(), ctx.current_mesh()
    routed = {k_: p[k_] for k_ in ("router", "w_gate", "w_up", "w_down")}
    n_dev = mesh.devices.size if mesh is not None else 1
    if baxes and mesh is not None and x_flat.shape[0] % n_dev == 0:
        # Manual over batch axes AND 'model': expert ff dims stay
        # tensor-parallel, every expert's down-projection emits PARTIAL
        # sums, and a SINGLE psum per layer reduces them (§Perf: vs. one
        # all-reduce per expert when the reduction is left to GSPMD —
        # num_experts x less collective volume).
        tp = ("model",) if "model" in mesh.axis_names \
            and cfg.moe_d_ff % mesh.shape["model"] == 0 else ()
        manual = set(baxes) | set(tp)
        ffspec = tp[0] if tp else None
        in_specs = (
            {"router": P(None, None),
             "w_gate": P(None, None, ffspec),
             "w_up": P(None, None, ffspec),
             "w_down": P(None, ffspec, None)},
            P(baxes, None),
        )

        def local_fn(pp, xf):
            out, aux = moe_capacity_local(pp, cfg, xf)
            if tp:
                out = jax.lax.psum(out, tp[0])
                aux = jax.lax.pmean(aux, tp[0])
            return out, jax.lax.pmean(aux, baxes)

        out, aux = jax.shard_map(
            local_fn, mesh=mesh, in_specs=in_specs,
            out_specs=(P(baxes, None), P()),
            axis_names=manual, check_vma=False)(routed, x_flat)
    else:
        out, aux = moe_ragged(p, cfg, x_flat)
    if cfg.num_shared_experts:
        out = out + apply_mlp(p["shared"], x_flat, "swiglu")
    out = out.reshape(b, s, d)
    if return_aux:
        return out, aux
    return out

"""RecurrentGemma recurrent block: conv1d + RG-LRU gated linear recurrence.

Block (Griffin [arXiv:2402.19427]):
  branch1: W_gate(x) -> GeLU
  branch2: W_x(x) -> causal depthwise conv1d (width 4) -> RG-LRU
  out    : W_out(branch1 * branch2)
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .base import ModelConfig
from .layers import dense_init
from repro.kernels.rglru_scan import ref as lru_ref


class RecState(NamedTuple):
    h: jax.Array     # [bsz, w] fp32 recurrence state
    conv: jax.Array  # [bsz, conv_width-1, w]


def init_rec(key, cfg: ModelConfig, dtype):
    d, w = cfg.d_model, cfg.lru_width
    ks = jax.random.split(key, 6)
    lam = jax.random.uniform(ks[5], (w,), jnp.float32, 0.9, 0.999)
    # init Lambda so that a = lam^c at r=1 (griffin init)
    log_lambda = jnp.log(jnp.expm1(-jnp.log(lam) / lru_ref.RGLRU_C))
    return {
        "w_gate": dense_init(ks[0], (d, w), dtype),
        "w_x": dense_init(ks[1], (d, w), dtype),
        "conv_w": dense_init(ks[2], (cfg.conv_width, w), dtype, scale=0.5),
        "conv_b": jnp.zeros((w,), dtype),
        "lru_wa": dense_init(ks[3], (w, w), dtype),
        "lru_ba": jnp.zeros((w,), jnp.float32),
        "lru_wx": dense_init(ks[4], (w, w), dtype),
        "lru_bx": jnp.zeros((w,), jnp.float32),
        "log_lambda": log_lambda,
        "w_out": dense_init(jax.random.fold_in(key, 7), (w, d), dtype),
    }


def _conv(x, w, b, history=None):
    """Causal depthwise conv width K; optional [bsz, K-1, w] history."""
    k = w.shape[0]
    s = x.shape[1]
    pad = (jnp.concatenate([history, x], axis=1) if history is not None
           else jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0))))
    pad = pad[:, -(s + k - 1):]
    return sum(pad[:, i:i + s] * w[i][None, None] for i in range(k)) + b[None, None]


def rec_forward(p, cfg: ModelConfig, x, *, return_state: bool = False,
                init_state: RecState | None = None):
    """x [bsz, s, d] -> [bsz, s, d]."""
    adt = x.dtype
    gate = jax.nn.gelu(x @ p["w_gate"].astype(adt), approximate=True)
    u = x @ p["w_x"].astype(adt)
    u_c = _conv(u, p["conv_w"].astype(adt), p["conv_b"].astype(adt),
                init_state.conv.astype(adt) if init_state is not None else None)
    h = lru_ref.rglru(u_c, p["lru_wa"], p["lru_ba"], p["lru_wx"], p["lru_bx"],
                      p["log_lambda"],
                      init_state.h if init_state is not None else None,
                      return_final_state=return_state)
    if return_state:
        h, h_final = h
    out = (gate * h) @ p["w_out"].astype(adt)
    if return_state:
        kw = p["conv_w"].shape[0]
        full = (jnp.concatenate([init_state.conv.astype(adt), u], axis=1)
                if init_state is not None else
                jnp.pad(u, ((0, 0), (kw - 1, 0), (0, 0))))
        return out, RecState(h=h_final, conv=full[:, -(kw - 1):])
    return out


def rec_init_state(cfg: ModelConfig, bsz: int, dtype) -> RecState:
    return RecState(
        h=jnp.zeros((bsz, cfg.lru_width), jnp.float32),
        conv=jnp.zeros((bsz, cfg.conv_width - 1, cfg.lru_width), dtype),
    )


def rec_decode_step(p, cfg: ModelConfig, x, state: RecState):
    """x [bsz, 1, d] -> (out [bsz, 1, d], new state)."""
    adt = x.dtype
    gate = jax.nn.gelu(x @ p["w_gate"].astype(adt), approximate=True)
    u = x @ p["w_x"].astype(adt)  # [bsz,1,w]
    conv_in = jnp.concatenate([state.conv.astype(adt), u], axis=1)  # [b,K,w]
    w = p["conv_w"].astype(adt)
    u_c = (jnp.einsum("bkc,kc->bc", conv_in, w) + p["conv_b"].astype(adt))[:, None]
    y, h_new = lru_ref.rglru_decode_step(
        u_c[:, 0], p["lru_wa"], p["lru_ba"], p["lru_wx"], p["lru_bx"],
        p["log_lambda"], state.h)
    out = (gate * y[:, None]) @ p["w_out"].astype(adt)
    return out, RecState(h=h_new, conv=conv_in[:, 1:])

"""Mamba-2 block (SSD / state-space duality), attention-free.

Layer structure (n_groups = 1):
  in_proj: d -> [z (d_in), x (d_in), B (N), C (N), dt (H)]
  causal depthwise conv width-4 over (x, B, C)
  SSD scan over heads (P = headdim, N = ssm_state)
  gated RMSNorm(y * silu(z)), out_proj: d_in -> d
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .base import ModelConfig
from .layers import dense_init, rms_norm
from repro.kernels.ssd_scan import ref as ssd_ref


class SSMState(NamedTuple):
    ssm: jax.Array   # [b, h, p, n] fp32
    conv: jax.Array  # [b, conv_width-1, conv_channels]


def conv_channels(cfg: ModelConfig) -> int:
    return cfg.d_inner + 2 * cfg.ssm_state


def init_ssm(key, cfg: ModelConfig, dtype):
    d, d_in, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    ks = jax.random.split(key, 5)
    proj_out = 2 * d_in + 2 * n + h
    return {
        "in_proj": dense_init(ks[0], (d, proj_out), dtype),
        "conv_w": dense_init(ks[1], (cfg.ssm_conv_width, conv_channels(cfg)), dtype, scale=0.5),
        "conv_b": jnp.zeros((conv_channels(cfg),), dtype),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "norm_w": jnp.ones((d_in,), dtype),
        "out_proj": dense_init(ks[2], (d_in, d), dtype),
    }


def _causal_conv(xbc, w, b):
    """xbc [bsz, s, ch], depthwise causal conv, width K.  w [K, ch]."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1]] * w[i][None, None] for i in range(k))
    return jax.nn.silu(out + b[None, None])


def _split_proj(cfg, zxbcdt):
    d_in, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :d_in]
    xbc = zxbcdt[..., d_in:d_in + d_in + 2 * n]
    dt = zxbcdt[..., d_in + d_in + 2 * n:]
    return z, xbc, dt


def ssm_forward(p, cfg: ModelConfig, u, *, return_state: bool = False,
                init_state: SSMState | None = None):
    """u [bsz, s, d] -> [bsz, s, d]."""
    bsz, s, _ = u.shape
    d_in, n, h, pd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    adt = u.dtype
    zxbcdt = u @ p["in_proj"].astype(adt)
    z, xbc, dt = _split_proj(cfg, zxbcdt)
    if init_state is not None:
        pad = jnp.concatenate([init_state.conv.astype(adt), xbc], axis=1)
        k = p["conv_w"].shape[0]
        conv_in = pad[:, -(s + k - 1):]
        # re-implement causal conv with provided history
        out = sum(conv_in[:, i:i + s] * p["conv_w"].astype(adt)[i][None, None]
                  for i in range(k))
        xbc_c = jax.nn.silu(out + p["conv_b"].astype(adt)[None, None])
    else:
        xbc_c = _causal_conv(xbc, p["conv_w"].astype(adt), p["conv_b"].astype(adt))
    x = xbc_c[..., :d_in].reshape(bsz, s, h, pd)
    B = xbc_c[..., d_in:d_in + n]
    C = xbc_c[..., d_in + n:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None])
    A = -jnp.exp(p["A_log"])
    y = ssd_ref.ssd_chunked(
        x, dt, A, B, C, p["D"], chunk=cfg.ssm_chunk,
        init_state=init_state.ssm if init_state is not None else None,
        return_final_state=return_state)
    if return_state:
        y, final = y
    y = y.reshape(bsz, s, d_in)
    y = rms_norm((y * jax.nn.silu(z.astype(jnp.float32))).astype(adt), p["norm_w"], cfg.norm_eps)
    out = y @ p["out_proj"].astype(adt)
    if return_state:
        kw = p["conv_w"].shape[0]
        full = (jnp.concatenate([init_state.conv.astype(adt), xbc], axis=1)
                if init_state is not None else
                jnp.pad(xbc, ((0, 0), (kw - 1, 0), (0, 0))))
        conv_state = full[:, -(kw - 1):]
        return out, SSMState(ssm=final, conv=conv_state)
    return out


def ssm_init_state(cfg: ModelConfig, bsz: int, dtype) -> SSMState:
    return SSMState(
        ssm=jnp.zeros((bsz, cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state), jnp.float32),
        conv=jnp.zeros((bsz, cfg.ssm_conv_width - 1, conv_channels(cfg)), dtype),
    )


def ssm_decode_step(p, cfg: ModelConfig, u, state: SSMState):
    """u [bsz, 1, d] -> (out [bsz, 1, d], new state)."""
    bsz = u.shape[0]
    d_in, n, h, pd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    adt = u.dtype
    zxbcdt = u @ p["in_proj"].astype(adt)
    z, xbc, dt = _split_proj(cfg, zxbcdt)
    # rolling conv state: [b, K-1, ch] + new token
    conv_in = jnp.concatenate([state.conv.astype(adt), xbc], axis=1)  # [b,K,ch]
    w = p["conv_w"].astype(adt)
    out = jnp.einsum("bkc,kc->bc", conv_in, w)
    xbc_c = jax.nn.silu(out + p["conv_b"].astype(adt))[:, None]  # [b,1,ch]
    x = xbc_c[..., :d_in].reshape(bsz, h, pd)
    B = xbc_c[:, 0, d_in:d_in + n]
    C = xbc_c[:, 0, d_in + n:]
    dtv = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"][None])
    A = -jnp.exp(p["A_log"])
    y, new_ssm = ssd_ref.ssd_decode_step(x, dtv, A, B, C, p["D"], state.ssm)
    y = y.reshape(bsz, 1, d_in)
    y = rms_norm((y * jax.nn.silu(z.astype(jnp.float32))).astype(adt), p["norm_w"], cfg.norm_eps)
    out = y @ p["out_proj"].astype(adt)
    return out, SSMState(ssm=new_ssm, conv=conv_in[:, 1:])

"""AdamW with cosine schedule and global-norm clipping (pure pytree impl)."""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    end_lr: float = 3e-5
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0


class OptState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def cosine_lr(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / jnp.maximum(cfg.warmup_steps, 1)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.end_lr + 0.5 * (cfg.peak_lr - cfg.end_lr) * (1 + jnp.cos(jnp.pi * frac))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params) -> OptState:
    zeros = lambda t: jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), t)
    return OptState(step=jnp.zeros((), jnp.int32), mu=zeros(params), nu=zeros(params))


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, params, grads, state: OptState):
    step = state.step + 1
    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
    lr = cosine_lr(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh, vh = m / b1c, v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only (norms/bias exempt)
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_params, OptState(step=step, mu=new_mu, nu=new_nu), {
        "lr": lr, "grad_norm": gnorm}

"""AsyncEcoreService: the ``asyncio`` facade over ``EcoreService``.

The sync service resolves ``concurrent.futures.Future``s from two places —
inline (a full batch flushes during ``submit``) and the background flusher
thread (a deadline expires).  This facade bridges both to awaitables: each
submit wraps the service future in an ``asyncio`` future belonging to the
RUNNING loop, and completion crosses the thread boundary through
``loop.call_soon_threadsafe`` — the only asyncio API that is safe to call
from a foreign thread.  An awaiting task therefore wakes the moment the
flusher serves its batch, with no polling on either side.

Determinism is preserved end to end: the injectable ``clock`` passes
through to the dispatch queues, ``wake()`` passes through to the flusher,
and submissions happen inline on the loop thread (never offloaded to an
executor) so decision order is exactly submission order.  The trade-off is
the same one the sync service makes: a FULL batch serves inline during
``submit`` — batching, not intra-service parallelism, is the throughput
lever.  ``drain``/``close`` run in the default executor, since they block
on real backend work.

Errors: the facade's only consumption plane is futures, so the underlying
service is built with ``buffer_errors=False`` — a backend error fails
exactly the awaited futures of its batch (and a direct ``drain`` caller),
never the event loop, and ``close()`` does not re-raise what an awaiter
already consumed.
"""
from __future__ import annotations

import asyncio
import time
from concurrent.futures import Future
from typing import Callable, List, Optional, Sequence

from repro.core.policy import Observation, RouteDecision, RouteRequest
from repro.serving.service import EcoreService, Served


class AsyncEcoreService:
    """``async submit -> Served`` over any ``RoutingPolicy``; one facade,
    the same policies, queues, backends and observation plane as the sync
    service."""

    def __init__(self, policy, backend_factory: Callable[[RouteDecision],
                                                         object], *,
                 max_wait_ms: Optional[float] = None,
                 clock: Callable[[], float] = time.monotonic):
        self._svc = EcoreService(policy, backend_factory,
                                 max_wait_ms=max_wait_ms, clock=clock,
                                 retain_results=False, buffer_errors=False)

    # ------------------------------------------------------------- bridge

    @staticmethod
    def _bridge(cfut: "Future[Served]") -> "asyncio.Future[Served]":
        loop = asyncio.get_running_loop()
        afut: "asyncio.Future[Served]" = loop.create_future()

        def _done(f: "Future[Served]") -> None:
            # may fire in the flusher thread (deadline flush), the loop
            # thread (inline flush), or any thread calling drain/close
            def _copy() -> None:
                if afut.cancelled():
                    return
                exc = f.exception()
                if exc is not None:
                    afut.set_exception(exc)
                else:
                    afut.set_result(f.result())
            loop.call_soon_threadsafe(_copy)

        cfut.add_done_callback(_done)
        return afut

    # ------------------------------------------------------------- submit

    def submit_nowait(self, req: RouteRequest) -> "asyncio.Future[Served]":
        """Route + enqueue now (inline, deterministic order); returns an
        awaitable that resolves when the request's batch flushes.

        Futures-only error contract: if the submit itself fails — the sync
        service re-raises when THIS request triggers a full-batch inline
        flush and the backend blows up (it also raises for routing/caller
        errors) — the error comes back as a FAILED future, never a
        synchronous throw into the submitting coroutine."""
        loop = asyncio.get_running_loop()
        try:
            return self._bridge(self._svc.submit(req))
        except Exception as exc:
            afut: "asyncio.Future[Served]" = loop.create_future()
            # repro-lint: disable=ECO302 -- submit_nowait runs ON the loop
            # thread (get_running_loop above); only the cross-thread done-
            # callback path must hop through _bridge's call_soon_threadsafe
            afut.set_exception(exc)
            return afut

    def submit_batch_nowait(self, reqs: Sequence[RouteRequest]
                            ) -> List["asyncio.Future[Served]"]:
        """One tensorized ``decide_batch`` call for the whole workload.
        Raises synchronously when the BATCH cannot be submitted (routing /
        caller errors happen before any future exists, so there is nothing
        to fail); a backend error after enqueue is carried by the affected
        futures as usual."""
        return [self._bridge(f) for f in self._svc.submit_batch(reqs)]

    async def submit(self, req: RouteRequest) -> Served:
        """Submit and await completion (gather many to pipeline a stream)."""
        return await self.submit_nowait(req)

    async def submit_batch(self, reqs: Sequence[RouteRequest]) -> List[Served]:
        futs = self.submit_batch_nowait(reqs)
        return list(await asyncio.gather(*futs))

    def observe(self, obs: Observation) -> None:
        """The single feedback plane (same as the sync service)."""
        self._svc.observe(obs)

    # -------------------------------------------------------------- drain

    async def drain(self) -> None:
        """Flush every pending partial batch (in the default executor — a
        flush runs real backend work) so all awaited futures resolve.  A
        flush error propagates here AND to the affected futures."""
        loop = asyncio.get_running_loop()
        await loop.run_in_executor(None, self._svc.drain)

    async def close(self) -> None:
        """Flush, resolve every outstanding future, stop the flusher.
        Idempotent; afterwards ``submit``/``submit_nowait`` resolve to a
        failed future carrying ``ServiceClosed`` (the sync service's
        structured terminal error), and any future the flush could not
        resolve fails with it too rather than dangling forever."""
        loop = asyncio.get_running_loop()
        await loop.run_in_executor(None, self._svc.close)

    async def __aenter__(self) -> "AsyncEcoreService":
        return self

    async def __aexit__(self, *exc) -> None:
        await self.close()

    # ------------------------------------------------------------- mirror

    def wake(self) -> None:
        """Fake-clock tests: make the flusher re-check deadlines now."""
        self._svc.wake()

    def stats(self) -> dict:
        return self._svc.stats()

    @property
    def policy(self):
        return self._svc.policy

    @property
    def deadline_flushes(self) -> int:
        return self._svc.deadline_flushes

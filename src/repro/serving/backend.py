"""ExecutionBackend: ONE execution protocol for every workload.

ECORE's premise is a single router in front of *heterogeneous*
(model, device) pairs, so the execution layer must expose exactly one
dispatch surface no matter what the backend computes.  A backend is
anything with:

  * ``name``        — identifies the (model, device/mesh) pair it serves
  * ``max_batch``   — dispatch capacity per ``serve_batch`` call (the
                      ``DispatchQueue`` batches up to this)
  * ``serve_batch`` — consumes the queued form of ``RouteRequest``s
                      (``engine.Request``: uid + payload in ``prompt`` +
                      routed ``group``) and returns one ``engine.Result``
                      per request
  * ``profile_row`` — the offline-profile facts routing consumed to pick
                      this backend (model, device, nominal cost columns)

``EcoreService`` dispatches over any of them through its per-pair
``DispatchQueue``s; a new workload implements this protocol (and registers
a factory) instead of forking another serving loop.  Two faces ship here:

  * the LLM ``engine.Backend`` (prefill+decode over a model config) —
    registered as ``"llm"``
  * ``DetectorBackend`` — the detection fleet face: runs a detector over a
    batch of frames and charges the profiled edge-device cost (optionally
    through a ``DriftingFleet``, using each request's ``uid`` as the fleet
    timestep) — registered as ``"detector"``
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional, Protocol, runtime_checkable

import numpy as np

from repro.serving.engine import Backend, Request, Result


@runtime_checkable
class ExecutionBackend(Protocol):
    """The one execution surface every workload implements."""
    name: str
    #: dispatch capacity: DispatchQueue flushes at this batch size
    max_batch: int

    def serve_batch(self, requests: List[Request]) -> List[Result]: ...

    def profile_row(self) -> Dict[str, object]: ...


#: kind -> factory.  ``make_backend`` validates what the factory builds, so
#: a registered workload cannot silently miss part of the protocol.
_REGISTRY: Dict[str, Callable[..., ExecutionBackend]] = {}


def register_backend(kind: str, factory: Optional[Callable] = None):
    """Register a backend factory under ``kind`` (usable as a decorator)."""
    def _register(f):
        if kind in _REGISTRY and _REGISTRY[kind] is not f:
            raise ValueError(f"backend kind {kind!r} is already registered")
        _REGISTRY[kind] = f
        return f
    return _register(factory) if factory is not None else _register


def backend_kinds() -> List[str]:
    return sorted(_REGISTRY)


def ensure_backend(obj) -> ExecutionBackend:
    """Raise a TypeError naming every missing protocol member."""
    missing = [m for m in ("name", "max_batch", "serve_batch", "profile_row")
               if not hasattr(obj, m)]
    if missing:
        raise TypeError(
            f"{type(obj).__name__} does not implement ExecutionBackend: "
            f"missing {', '.join(missing)}")
    return obj


def make_backend(kind: str, *args, **kwargs) -> ExecutionBackend:
    """Build a registered backend and validate it against the protocol.

    ``"faulty:<inner>"`` builds ``<inner>`` through its registered factory
    and wraps it in the fault-injection plane's ``FaultyBackend``; the
    ``faults`` kwarg (a sequence of ``FaultSpec``) belongs to the wrapper,
    everything else goes to the inner factory."""
    if kind.startswith("faulty:"):
        # lazy: faults.py imports this module, so the wrapper cannot be a
        # top-level import here
        from repro.serving.faults import FaultyBackend
        faults = kwargs.pop("faults", ())
        inner = make_backend(kind[len("faulty:"):], *args, **kwargs)
        return ensure_backend(FaultyBackend(inner, faults))
    try:
        factory = _REGISTRY[kind]
    except KeyError:
        raise KeyError(f"unknown backend kind {kind!r}; registered: "
                       f"{backend_kinds()}") from None
    return ensure_backend(factory(*args, **kwargs))


register_backend("llm", Backend)


def null_run(params, images) -> List[tuple]:
    """Detector stub (real shapes, zero detections) for load benches and
    examples that exercise routing/dispatch dynamics without trained
    detectors — pass as ``DetectorBackend(run_fn=null_run)``."""
    none = np.zeros((0, 4), np.float32)
    return [(none, np.zeros(0, np.float32), np.zeros(0, np.int32))
            for _ in range(len(images))]


class DetectorBackend:
    """One (detector model, edge device) pair behind the execution protocol.

    Adapts the detection fleet (``detection/devices.py``) to
    ``ExecutionBackend`` so the Gateway's per-frame traffic flows through
    ``EcoreService``'s dispatch queues instead of a workload-private loop:
    ``serve_batch`` stacks the queued frames, runs the detector ONCE for the
    whole batch, and charges each request the profiled device cost — through
    a ``DriftingFleet`` when one is given, with the request ``uid`` as the
    fleet timestep (the Gateway numbers requests by stream position, so
    fleet costs are identical no matter how dispatch batches or reorders).

    Frames in one dispatch batch need not share a shape: ``serve_batch``
    groups ragged frames into pad-and-mask buckets
    (``kernels.canny_fused.bucket_shape``) and runs the detector once per
    bucket — a uniform batch is a single exact-shape bucket and takes the
    old one-``np.stack``-one-launch path unchanged.  ``edge_stage=True``
    additionally runs the fused Canny gateway stage over the whole dispatch
    batch first (ONE ``pallas_call`` per size bucket via
    ``canny_edge_batch``) and records each frame's edge density in
    ``self.edge_density`` keyed by request uid — the EdgeNet-style
    pre-detector complexity signal the router can consult.

    ``run_fn`` defaults to the trained-detector path
    (``detection.train.run_detector``); tests and benches inject stubs.
    ``realtime_scale`` > 0 makes ``serve_batch`` occupy wall-clock time for
    the modeled device latency (``scale`` seconds per modeled second) — the
    cluster bench uses it to turn the analytic fleet into real concurrent
    load.  ``table`` (optional) is the routing profile this backend was
    picked from: ``profile_row`` then reports the LIVE adapted cost columns
    (what routing actually consults — kept fresh by ``observe``/the scanned
    closed loop's ``ProfileState`` folds) instead of the static device
    model."""

    def __init__(self, model: str, device: str, params=None, *,
                 max_batch: int = 1, fleet=None,
                 run_fn: Optional[Callable] = None,
                 realtime_scale: float = 0.0, table=None,
                 edge_stage: bool = False):
        from repro.detection.detectors import DETECTOR_CONFIGS
        from repro.detection.devices import DEVICES
        self.name = f"{model}@{device}"
        self.model = model
        self.device = device
        self.params = params
        self.max_batch = max_batch
        self.fleet = fleet
        self.realtime_scale = realtime_scale
        self.table = table
        self.edge_stage = edge_stage
        #: uid -> fraction of edge pixels, filled when edge_stage is on
        self.edge_density: Dict[int, float] = {}
        self._device = DEVICES[device]
        self._flops = DETECTOR_CONFIGS[model].flops
        if run_fn is None:
            from repro.detection.train import run_detector
            run_fn = run_detector
        self._run = run_fn

    def cost(self, step: int):
        """(time_ms, energy_mwh) one request pays at fleet timestep ``step``
        (the offline profile when no fleet is attached)."""
        if self.fleet is not None:
            return self.fleet.cost(self.device, self._flops, step)
        return (self._device.time_ms(self._flops),
                self._device.energy_mwh(self._flops))

    def _run_buckets(self, frames: List[np.ndarray]) -> List[tuple]:
        """Run the detector over ragged frames: group by pad-and-mask
        bucket shape, ONE ``self._run`` per bucket, results in input
        order.  A uniform batch is a single bucket with zero padding, so
        it degenerates to the old one-stack-one-launch path."""
        if len({f.shape for f in frames}) == 1:
            # uniform batch (any payload rank): the old exact-shape path
            return self._run(self.params, np.stack(frames))
        from repro.kernels.canny_fused import bucket_shape
        buckets: Dict[tuple, List[int]] = {}
        for i, f in enumerate(frames):
            if f.ndim < 2:
                raise ValueError(
                    "ragged serve_batch needs [H, W(, C)] frame payloads; "
                    f"got a {f.ndim}-d payload of shape {f.shape}")
            buckets.setdefault(bucket_shape(*f.shape[:2]) + f.shape[2:],
                               []).append(i)
        out: List[tuple] = [None] * len(frames)  # type: ignore[list-item]
        for shape, idxs in buckets.items():
            batch = np.zeros((len(idxs),) + shape, np.float32)
            for j, i in enumerate(idxs):
                h, w = frames[i].shape[:2]
                batch[j, :h, :w] = frames[i]
            for i, dets in zip(idxs, self._run(self.params, batch)):
                out[i] = dets
        return out

    def serve_batch(self, requests: List[Request]) -> List[Result]:
        assert requests
        frames = [np.asarray(r.prompt) for r in requests]
        t0 = time.perf_counter()
        if self.edge_stage:
            from repro.kernels.canny_fused import canny_edge_batch
            for r, edge in zip(requests,
                               canny_edge_batch([f if f.ndim == 2 else
                                                 f.mean(axis=-1)
                                                 for f in frames])):
                # the maps are host-side numpy already: np.mean is an
                # explicit host reduction, not a per-item device sync
                self.edge_density[r.uid] = float(np.mean(edge))
        detections = self._run_buckets(frames)
        wall_s = time.perf_counter() - t0
        results = []
        total_modeled_ms = 0.0
        for r, dets in zip(requests, detections):
            t_ms, e_mwh = self.cost(r.uid)
            total_modeled_ms += t_ms
            results.append(Result(
                uid=r.uid, tokens=np.zeros(0, np.int32),
                prefill_s=wall_s, decode_s=0.0, backend=self.name,
                batch_size=len(requests), detections=dets,
                time_ms=t_ms, energy_mwh=e_mwh))
        if self.realtime_scale > 0.0:
            # an edge device serves its batch sequentially: occupy the wall
            # clock for the modeled busy time (scaled), so pods genuinely
            # contend/overlap in cluster benches
            # repro-lint: disable=ECO304 -- this sleep IS the simulated
            # device busy time (opt-in realtime_scale), not a retry/poll
            # that must ride the injectable clock
            time.sleep(total_modeled_ms / 1e3 * self.realtime_scale)
        return results

    def profile_row(self) -> Dict[str, object]:
        # prefer the LIVE adapted row (latency/energy are group-replicated,
        # so any group row of the pair carries the pair-wide EWMA value)
        entry = None if self.table is None else next(
            (e for e in self.table.entries
             if e.pair == (self.model, self.device)), None)
        if entry is not None:
            t_ms, e_mwh = entry.time_ms, entry.energy_mwh
        else:
            t_ms, e_mwh = self.cost(0)
        return {"kind": "detector", "model": self.model,
                "device": self.device, "flops": self._flops,
                "time_ms": t_ms, "energy_mwh": e_mwh,
                "max_batch": self.max_batch}


register_backend("detector", DetectorBackend)

"""EcoreCluster: N EcoreService pods behind ONE request plane.

Scaling ECORE out means standing up many (policy + dispatch queues +
backends) pods and sharding the request stream across them — the serving
analog of the paper's multi-gateway deployment and AyE-Edge's
deployment-space search.  The cluster owns:

  * shard selection — a JITTED, tensorized step over the per-pod
    queue-depth array (one XLA call assigns a whole batch), with an
    exact-parity scalar reference (``select_pods_reference``) used on the
    per-request path and in tests.  Two policies:

      - ``least_loaded``: sequential greedy argmin over live depths
        (a ``lax.scan`` — each assignment sees the depths the previous
        ones produced, exactly like the scalar loop);
      - ``rendezvous``: highest-random-weight hashing of (uid, pod) via a
        splitmix-style 32-bit avalanche — stable request->pod affinity
        that survives pod count changes with minimal reshuffling.

  * observe() fan-in — an ``Observation`` carrying the request ``uid`` is
    folded into the OWNING pod's policy (the pod whose decision produced
    the measurement); without a uid it is a pair-wide signal and broadcasts
    to every pod.

  * per-pod ``stats()`` aggregation and concurrent ``drain``/``close``.

Pods are fully independent (own policy, own queues, own backends, own
lock), so ``submit_batch`` fans each pod's shard out on a small thread
pool: pods serve concurrently — XLA releases the GIL during backend
execution — which is where the multi-pod throughput scaling comes from
(``benchmarks/run.py --only cluster``).
"""
from __future__ import annotations

import collections
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core.policy import (Observation, RouteDecision, RouteRequest,
                               RoutingPolicy)
from repro.serving.service import EcoreService, Served

SHARD_MODES = ("least_loaded", "rendezvous")

#: bound on the uid -> owning-pod map (a long-lived cluster must not grow
#: per-request state; observations normally arrive right after completion)
OWNER_LIMIT = 8192


# ------------------------------------------------------- shard selection

def _mix32(x, xp):
    """splitmix32-style avalanche on uint32 arrays; ``xp`` is numpy or
    jax.numpy — SAME integer ops in both, so the jitted kernel and the
    scalar reference agree bit for bit."""
    x = x ^ (x >> 16)
    x = x * xp.uint32(0x7FEB352D)
    x = x ^ (x >> 15)
    x = x * xp.uint32(0x846CA68B)
    x = x ^ (x >> 16)
    return x


_kernels = None


def _shard_kernels():
    global _kernels
    if _kernels is None:
        import jax
        import jax.numpy as jnp

        @jax.jit
        def rendezvous(uids_u32, pod_ids_u32):
            # highest-random-weight: score every (request, pod), argmax rows
            scores = _mix32(uids_u32[:, None] ^ _mix32(pod_ids_u32, jnp)[None, :],
                            jnp)
            return jnp.argmax(scores, axis=1)

        @jax.jit
        def least_loaded(uids_u32, depths_i32):
            # sequential greedy: each pick sees the depths the previous
            # picks produced (ties -> lowest pod index, like np.argmin)
            def step(depth, _):
                p = jnp.argmin(depth)
                return depth.at[p].add(1), p
            _, picks = jax.lax.scan(step, depths_i32, uids_u32)
            return picks

        _kernels = {"rendezvous": rendezvous, "least_loaded": least_loaded}
    return _kernels


def select_pods(uids: Sequence[int], depths: Sequence[int],
                mode: str = "least_loaded") -> np.ndarray:
    """Assign a batch of request uids to pods in ONE jitted XLA call.

    ``depths`` is the live per-pod queue depth (least-loaded consumes it;
    rendezvous ignores it).  Exactly matches ``select_pods_reference``
    (tested): pure uint32/int32 arithmetic on both paths."""
    if mode not in SHARD_MODES:
        raise ValueError(f"unknown shard mode {mode!r}; one of {SHARD_MODES}")
    import jax.numpy as jnp
    uids_u32 = jnp.asarray(np.asarray(uids, np.uint32))
    k = _shard_kernels()[mode]
    if mode == "rendezvous":
        pod_ids = jnp.asarray(np.arange(len(depths), dtype=np.uint32))
        return np.asarray(k(uids_u32, pod_ids))
    return np.asarray(k(uids_u32, jnp.asarray(np.asarray(depths, np.int32))))


def select_pods_reference(uids: Sequence[int], depths: Sequence[int],
                          mode: str = "least_loaded") -> np.ndarray:
    """Scalar reference: one request at a time, plain numpy.  The jitted
    ``select_pods`` must match this exactly."""
    if mode not in SHARD_MODES:
        raise ValueError(f"unknown shard mode {mode!r}; one of {SHARD_MODES}")
    uids = list(uids)   # materialize ONCE: a generator must not be exhausted
    depths = np.asarray(depths, np.int32).copy()
    pod_ids = np.arange(len(depths), dtype=np.uint32)
    picks = np.zeros(len(uids), np.int64)
    for i, uid in enumerate(uids):
        if mode == "least_loaded":
            p = int(np.argmin(depths))
            depths[p] += 1
        else:
            u = np.asarray([uid], np.uint32)  # arrays: silent uint32 wrap
            p = int(np.argmax(_mix32(u ^ _mix32(pod_ids, np), np)))
        picks[i] = p
    return picks


# --------------------------------------------------------------- cluster

class EcoreCluster:
    """Shard one request stream over N independent ``EcoreService`` pods.

    ``policy_factory(pod_index)`` builds each pod's OWN policy (adaptive
    state must not be shared — observations fold into the owning pod);
    ``backend_factory`` is per-decision, as in ``EcoreService``.  Requests
    need cluster-unique uids (the owner map and each pod's inflight check
    key on them)."""

    def __init__(self, policy_factory: Callable[[int], RoutingPolicy],
                 backend_factory: Callable[[RouteDecision], object], *,
                 pods: int = 2, shard: str = "least_loaded",
                 max_wait_ms: Optional[float] = None,
                 clock: Callable[[], float] = time.monotonic,
                 retain_results: bool = True):
        if pods < 1:
            raise ValueError(f"pods={pods}: need at least one pod")
        if shard not in SHARD_MODES:
            raise ValueError(
                f"unknown shard mode {shard!r}; one of {SHARD_MODES}")
        self.shard = shard
        self.pods: List[EcoreService] = [
            EcoreService(policy_factory(i), backend_factory,
                         max_wait_ms=max_wait_ms, clock=clock,
                         retain_results=retain_results)
            for i in range(pods)]
        self._lock = threading.Lock()
        #: live queue depth per pod (in-flight requests; shard input)
        self._depth = np.zeros(pods, np.int64)
        #: total requests ever assigned per pod (stats)
        self.shard_counts = np.zeros(pods, np.int64)
        self._owner: Dict[int, int] = {}
        self._owner_order: collections.deque = collections.deque()
        #: uid-keyed observations dropped because the owner was unknown
        self.stale_observations = 0
        self._exec = ThreadPoolExecutor(max_workers=pods,
                                        thread_name_prefix="ecore-pod")
        self._closed = False

    # ------------------------------------------------------------ submit

    def _assign(self, uids: Sequence[int], batched: bool) -> np.ndarray:
        with self._lock:
            picks = (select_pods if batched else select_pods_reference)(
                uids, self._depth, self.shard)
            np.add.at(self._depth, picks, 1)
            np.add.at(self.shard_counts, picks, 1)
            for uid, p in zip(uids, picks):
                if uid not in self._owner:
                    self._owner_order.append(uid)
                self._owner[uid] = int(p)
            while len(self._owner_order) > OWNER_LIMIT:
                self._owner.pop(self._owner_order.popleft(), None)
        return picks

    def _release(self, pod: int) -> None:
        with self._lock:
            self._depth[pod] -= 1

    def _watch(self, fut: "Future[Served]", pod: int) -> "Future[Served]":
        fut.add_done_callback(lambda _f: self._release(pod))
        return fut

    def submit(self, req: RouteRequest) -> "Future[Served]":
        """Shard one request (scalar reference path) and submit it to its
        pod; the pod routes, queues and batches as usual.  If the pod's
        submit raises (inline-flush backend error, routing error), the
        request is un-counted from the depth accounting before the error
        propagates — same invariant as ``submit_batch``'s error path."""
        pod = int(self._assign([req.uid], batched=False)[0])
        try:
            fut = self.pods[pod].submit(req)
        except Exception:
            with self._lock:
                self._depth[pod] -= 1
            raise
        return self._watch(fut, pod)

    def submit_batch(self, reqs: Sequence[RouteRequest]
                     ) -> List["Future[Served]"]:
        """One jitted shard-selection call for the whole batch, then each
        pod's shard is submitted CONCURRENTLY (thread pool) — pods route
        and serve in parallel.  Futures return in request order.

        Error semantics mirror ``EcoreService.submit_batch``: if a pod's
        inline flush raises, the error re-raises here AFTER every healthy
        pod's futures have their depth watchers attached and the failing
        pod's shard is released from the depth accounting (its service
        already failed the affected futures) — a blown backend must not
        skew least-loaded sharding for the cluster's lifetime."""
        reqs = list(reqs)
        if not reqs:
            return []
        picks = self._assign([r.uid for r in reqs], batched=True)
        shards: Dict[int, List[int]] = {}
        for i, p in enumerate(picks):
            shards.setdefault(int(p), []).append(i)
        pending = {
            pod: self._exec.submit(self.pods[pod].submit_batch,
                                   [reqs[i] for i in idxs])
            for pod, idxs in shards.items()}
        out: List[Optional[Future]] = [None] * len(reqs)
        first_exc = None
        for pod, idxs in shards.items():
            try:
                futs = pending[pod].result()
            except Exception as exc:
                first_exc = first_exc or exc
                # nothing watchable came back, so un-count the whole shard.
                # This is an APPROXIMATION: requests the pod had already
                # enqueued on healthy queues before the flush blew up are
                # still in flight but no longer counted (they resolve at
                # drain without a watcher, so no double-decrement) — depth
                # errs toward routing TOWARD a blown pod until drain, never
                # permanently away from it.
                with self._lock:
                    self._depth[pod] -= len(idxs)
                continue
            for i, fut in zip(idxs, futs):
                out[i] = self._watch(fut, pod)
        if first_exc is not None:
            raise first_exc
        return out  # type: ignore[return-value]

    # ----------------------------------------------------------- observe

    def observe(self, obs: Observation) -> None:
        """Fold a measurement into the OWNING pod's policy (by ``obs.uid``);
        an observation without a uid is pair-wide evidence and broadcasts
        to every pod.  A uid-keyed observation whose owner is UNKNOWN
        (evicted past ``OWNER_LIMIT``, or never routed here) is DROPPED and
        counted in ``stats()["stale_observations"]`` — pod-specific
        evidence must not be smeared across every pod's profile."""
        if obs.uid is not None:
            with self._lock:
                pod = self._owner.get(obs.uid)
                if pod is None:
                    self.stale_observations += 1
                    return
            self.pods[pod].observe(obs)
        else:
            for p in self.pods:
                p.observe(obs)

    # ----------------------------------------------------------- results

    def results(self) -> List[Served]:
        out: List[Served] = []
        for p in self.pods:
            out += p.results()
        return out

    def drain(self) -> List[Served]:
        """Drain every pod CONCURRENTLY; completions are merged.  The first
        pod error re-raises after all pods finished draining."""
        futs = [self._exec.submit(p.drain) for p in self.pods]
        out: List[Served] = []
        first_exc = None
        for f in futs:
            try:
                out += f.result()
            except Exception as exc:
                first_exc = first_exc or exc
        if first_exc is not None:
            raise first_exc
        return out

    def close(self) -> None:
        if self._closed:
            return
        first_exc = None
        for f in [self._exec.submit(p.close) for p in self.pods]:
            try:
                f.result()
            except Exception as exc:
                first_exc = first_exc or exc
        self._closed = True
        self._exec.shutdown(wait=True)
        if first_exc is not None:
            raise first_exc

    def __enter__(self) -> "EcoreCluster":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def wake(self) -> None:
        for p in self.pods:
            p.wake()

    # ------------------------------------------------------------- stats

    def stats(self) -> Dict:
        per_pod = [p.stats() for p in self.pods]
        return {
            "pods": len(self.pods),
            "shard_mode": self.shard,
            "shard_counts": self.shard_counts.tolist(),
            "backends": sum(s["backends"] for s in per_pod),
            "serve_calls": sum(s["serve_calls"] for s in per_pod),
            "served": sum(s["served"] for s in per_pod),
            "deadline_flushes": sum(s["deadline_flushes"] for s in per_pod),
            "stale_observations": self.stale_observations,
            "per_pod": per_pod,
        }

"""EcoreCluster: N EcoreService pods behind ONE request plane.

Scaling ECORE out means standing up many (policy + dispatch queues +
backends) pods and sharding the request stream across them — the serving
analog of the paper's multi-gateway deployment and AyE-Edge's
deployment-space search.  The cluster owns:

  * shard selection — a JITTED, tensorized step over the per-pod
    queue-depth array (one XLA call assigns a whole batch), with an
    exact-parity scalar reference (``select_pods_reference``) used on the
    per-request path and in tests.  Two policies:

      - ``least_loaded``: sequential greedy argmin over live depths
        (a ``lax.scan`` — each assignment sees the depths the previous
        ones produced, exactly like the scalar loop);
      - ``rendezvous``: highest-random-weight hashing of (uid, pod) via a
        splitmix-style 32-bit avalanche — stable request->pod affinity
        that survives pod count changes with minimal reshuffling.

  * observe() fan-in — an ``Observation`` carrying the request ``uid`` is
    folded into the OWNING pod's policy (the pod whose decision produced
    the measurement); without a uid it is a pair-wide signal and broadcasts
    to every pod.

  * per-pod ``stats()`` aggregation and concurrent ``drain``/``close``.

Pods are fully independent (own policy, own queues, own backends, own
lock), so ``submit_batch`` fans each pod's shard out on a small thread
pool: pods serve concurrently — XLA releases the GIL during backend
execution — which is where the multi-pod throughput scaling comes from
(``benchmarks/run.py --only cluster``).
"""
from __future__ import annotations

import collections
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core.policy import (Observation, RouteDecision, RouteRequest,
                               RoutingPolicy)
from repro.serving.service import EcoreService, Served

SHARD_MODES = ("least_loaded", "rendezvous")

#: bound on the uid -> owning-pod map (a long-lived cluster must not grow
#: per-request state; observations normally arrive right after completion)
OWNER_LIMIT = 8192


# ------------------------------------------------------- shard selection

def _mix32(x, xp):
    """splitmix32-style avalanche on uint32 arrays; ``xp`` is numpy or
    jax.numpy — SAME integer ops in both, so the jitted kernel and the
    scalar reference agree bit for bit."""
    x = x ^ (x >> 16)
    x = x * xp.uint32(0x7FEB352D)
    x = x ^ (x >> 15)
    x = x * xp.uint32(0x846CA68B)
    x = x ^ (x >> 16)
    return x


#: a dead pod's masked queue depth: larger than any real depth, far from
#: int32 overflow even after a whole batch of .add(1)s
_DEAD_DEPTH = 2 ** 30


def _masked_scores(scores, alive, xp):
    """Rendezvous scores with dead pods forced to lose: live scores map
    monotonically into [2^31, 2^32) (>> 1 then set the top bit), dead pods
    score 0.  Same uint32 ops for numpy and jnp — no int64, which jax
    would silently downcast with x64 disabled."""
    live = (scores >> xp.uint32(1)) | xp.uint32(0x80000000)
    return xp.where(alive, live, xp.uint32(0))


_kernels = None


def _shard_kernels():
    global _kernels
    if _kernels is None:
        import jax
        import jax.numpy as jnp

        @jax.jit
        def rendezvous(uids_u32, pod_ids_u32):
            # highest-random-weight: score every (request, pod), argmax rows
            scores = _mix32(uids_u32[:, None] ^ _mix32(pod_ids_u32, jnp)[None, :],
                            jnp)
            return jnp.argmax(scores, axis=1)

        @jax.jit
        def rendezvous_masked(uids_u32, pod_ids_u32, alive):
            scores = _mix32(uids_u32[:, None] ^ _mix32(pod_ids_u32, jnp)[None, :],
                            jnp)
            return jnp.argmax(_masked_scores(scores, alive[None, :], jnp),
                              axis=1)

        @jax.jit
        def least_loaded(uids_u32, depths_i32):
            # sequential greedy: each pick sees the depths the previous
            # picks produced (ties -> lowest pod index, like np.argmin)
            def step(depth, _):
                p = jnp.argmin(depth)
                return depth.at[p].add(1), p
            _, picks = jax.lax.scan(step, depths_i32, uids_u32)
            return picks

        @jax.jit
        def least_loaded_masked(uids_u32, depths_i32, alive):
            dead = jnp.int32(_DEAD_DEPTH)
            def step(depth, _):
                p = jnp.argmin(jnp.where(alive, depth, dead))
                return depth.at[p].add(1), p
            _, picks = jax.lax.scan(step, depths_i32, uids_u32)
            return picks

        _kernels = {"rendezvous": rendezvous, "least_loaded": least_loaded,
                    "rendezvous_masked": rendezvous_masked,
                    "least_loaded_masked": least_loaded_masked}
    return _kernels


def select_pods(uids: Sequence[int], depths: Sequence[int],
                mode: str = "least_loaded",
                alive: Optional[Sequence[bool]] = None) -> np.ndarray:
    """Assign a batch of request uids to pods in ONE jitted XLA call.

    ``depths`` is the live per-pod queue depth (least-loaded consumes it;
    rendezvous ignores it).  ``alive`` (optional bool mask) excludes dead
    pods: least-loaded sees their depth as unbeatable, rendezvous forces
    their score below every live pod's — graceful degradation without a
    separate kernel family; ``None`` runs the original unmasked kernels
    bit-identically.  Exactly matches ``select_pods_reference`` (tested):
    pure uint32/int32 arithmetic on both paths."""
    if mode not in SHARD_MODES:
        raise ValueError(f"unknown shard mode {mode!r}; one of {SHARD_MODES}")
    import jax.numpy as jnp
    uids_u32 = jnp.asarray(np.asarray(uids, np.uint32))
    k = _shard_kernels()[mode if alive is None else mode + "_masked"]
    if mode == "rendezvous":
        pod_ids = jnp.asarray(np.arange(len(depths), dtype=np.uint32))
        if alive is None:
            return np.asarray(k(uids_u32, pod_ids))
        return np.asarray(k(uids_u32, pod_ids,
                            jnp.asarray(np.asarray(alive, bool))))
    depths_i32 = jnp.asarray(np.asarray(depths, np.int32))
    if alive is None:
        return np.asarray(k(uids_u32, depths_i32))
    return np.asarray(k(uids_u32, depths_i32,
                        jnp.asarray(np.asarray(alive, bool))))


def select_pods_reference(uids: Sequence[int], depths: Sequence[int],
                          mode: str = "least_loaded",
                          alive: Optional[Sequence[bool]] = None
                          ) -> np.ndarray:
    """Scalar reference: one request at a time, plain numpy.  The jitted
    ``select_pods`` must match this exactly (masked or not)."""
    if mode not in SHARD_MODES:
        raise ValueError(f"unknown shard mode {mode!r}; one of {SHARD_MODES}")
    uids = list(uids)   # materialize ONCE: a generator must not be exhausted
    depths = np.asarray(depths, np.int32).copy()
    pod_ids = np.arange(len(depths), dtype=np.uint32)
    alive_mask = None if alive is None else np.asarray(alive, bool)
    picks = np.zeros(len(uids), np.int64)
    for i, uid in enumerate(uids):
        if mode == "least_loaded":
            visible = (depths if alive_mask is None
                       else np.where(alive_mask, depths,
                                     np.int32(_DEAD_DEPTH)))
            p = int(np.argmin(visible))
            depths[p] += 1
        else:
            u = np.asarray([uid], np.uint32)  # arrays: silent uint32 wrap
            scores = _mix32(u ^ _mix32(pod_ids, np), np)
            if alive_mask is not None:
                scores = _masked_scores(scores, alive_mask, np)
            p = int(np.argmax(scores))
        picks[i] = p
    return picks


# --------------------------------------------------------------- cluster

class NoLivePods(RuntimeError):
    """Every pod has been marked failed — the cluster cannot place work."""


class EcoreCluster:
    """Shard one request stream over N independent ``EcoreService`` pods.

    ``policy_factory(pod_index)`` builds each pod's OWN policy (adaptive
    state must not be shared — observations fold into the owning pod);
    ``backend_factory`` is per-decision, as in ``EcoreService``.  Requests
    need cluster-unique uids (the owner map and each pod's inflight check
    key on them).

    ``pod_fail_after`` (optional) arms graceful degradation: after that
    many CONSECUTIVE failed completions a pod is marked dead
    (``mark_pod_failed``), masked out of shard selection, and every
    request that failed on it is RESUBMITTED to a surviving pod (the
    cluster then owns the returned future and resolves it from whichever
    pod finally answers; the owner map follows the move, so uid-keyed
    observations fold into the pod that actually served).  Off (None),
    behavior is identical to the non-degrading cluster: pod futures are
    returned directly and errors propagate untouched."""

    def __init__(self, policy_factory: Callable[[int], RoutingPolicy],
                 backend_factory: Callable[[RouteDecision], object], *,
                 pods: int = 2, shard: str = "least_loaded",
                 max_wait_ms: Optional[float] = None,
                 clock: Callable[[], float] = time.monotonic,
                 retain_results: bool = True,
                 pod_fail_after: Optional[int] = None,
                 max_pods: Optional[int] = None,
                 flusher: bool = True):
        if pods < 1:
            raise ValueError(f"pods={pods}: need at least one pod")
        if shard not in SHARD_MODES:
            raise ValueError(
                f"unknown shard mode {shard!r}; one of {SHARD_MODES}")
        self.max_pods = pods if max_pods is None else max_pods
        if self.max_pods < pods:
            raise ValueError(
                f"max_pods={max_pods} below initial pods={pods}")
        self.shard = shard
        # kept so add_pod() can stand up new pods with identical wiring
        self._policy_factory = policy_factory
        self._backend_factory = backend_factory
        self._max_wait_ms = max_wait_ms
        self._clock = clock
        self._retain = retain_results
        self._pod_flusher = flusher
        self.pods: List[EcoreService] = [
            self._make_pod(i) for i in range(pods)]
        self._lock = threading.Condition()
        #: live queue depth per pod (in-flight requests; shard input)
        self._depth = np.zeros(pods, np.int64)
        #: total requests ever assigned per pod (stats)
        self.shard_counts = np.zeros(pods, np.int64)
        self._owner: Dict[int, int] = {}
        self._owner_order: collections.deque = collections.deque()
        #: uid-keyed observations dropped because the owner was unknown
        self.stale_observations = 0
        self.pod_fail_after = pod_fail_after
        self._alive = np.ones(pods, bool)
        self._consec_errors = np.zeros(pods, np.int64)
        self.resubmitted = 0          # requests moved off a failed pod
        self._moving = 0              # resubmissions not yet re-enqueued
        #: pods drained by the autoscaler (alive=False but healthy — the
        #: first to revive on scale-up, unlike FAILED pods which stay dead)
        self._retired: set = set()
        # sized for the elastic ceiling: ThreadPoolExecutor cannot grow
        self._exec = ThreadPoolExecutor(max_workers=self.max_pods,
                                        thread_name_prefix="ecore-pod")
        self._closed = False

    def _make_pod(self, index: int) -> EcoreService:
        return EcoreService(self._policy_factory(index),
                            self._backend_factory,
                            max_wait_ms=self._max_wait_ms,
                            clock=self._clock,
                            retain_results=self._retain,
                            flusher=self._pod_flusher)

    # ------------------------------------------------------------ submit

    def _assign(self, uids: Sequence[int], batched: bool) -> np.ndarray:
        with self._lock:
            if not self._alive.any():
                raise NoLivePods(
                    f"all {len(self.pods)} pods are marked failed")
            # the mask only enters selection once degradation is armed (or
            # a pod actually died) — the unmasked kernels stay bit-
            # identical to the non-degrading cluster
            alive = None if self._alive.all() else self._alive
            picks = (select_pods if batched else select_pods_reference)(
                uids, self._depth, self.shard, alive=alive)
            np.add.at(self._depth, picks, 1)
            np.add.at(self.shard_counts, picks, 1)
            for uid, p in zip(uids, picks):
                if uid not in self._owner:
                    self._owner_order.append(uid)
                self._owner[uid] = int(p)
            while len(self._owner_order) > OWNER_LIMIT:
                self._owner.pop(self._owner_order.popleft(), None)
        return picks

    def _release(self, pod: int) -> None:
        with self._lock:
            self._depth[pod] -= 1

    def _watch(self, fut: "Future[Served]", pod: int) -> "Future[Served]":
        fut.add_done_callback(lambda _f: self._release(pod))
        return fut

    # ------------------------------------------------------- degradation

    def mark_pod_failed(self, pod: int) -> None:
        """Mask ``pod`` out of shard selection (manual override or called
        by the consecutive-error detector).  Its queued work is not
        recalled wholesale — each failed completion resubmits itself — but
        nothing NEW lands on it."""
        with self._lock:
            self._alive[pod] = False
            self._lock.notify_all()

    def _record_outcome(self, pod: int, failed: bool) -> None:
        """Consecutive-failure pod detector (degradation armed only)."""
        with self._lock:
            if failed:
                self._consec_errors[pod] += 1
                if (self.pod_fail_after is not None and self._alive[pod]
                        and self._consec_errors[pod] >= self.pod_fail_after):
                    self._alive[pod] = False
            else:
                self._consec_errors[pod] = 0
            self._lock.notify_all()

    def _guard(self, fut: "Future[Served]", pod: int, req: RouteRequest,
               outer: "Future[Served]", hops: int) -> None:
        """Bridge a pod future to the cluster-owned ``outer`` future,
        recording outcomes and resubmitting failures to survivors.  The
        pod resolves its futures while holding its OWN condition, so the
        resubmission (which must take another pod's condition) hops
        through the executor — pod-to-pod lock cycles are impossible."""
        def _done(f: "Future[Served]") -> None:
            self._release(pod)
            exc = f.exception()
            if exc is None:
                self._record_outcome(pod, failed=False)
                outer.set_result(f.result())
                return
            self._recover(pod, req, outer, exc, hops)
        fut.add_done_callback(_done)

    def _recover(self, pod: int, req: RouteRequest, outer: "Future[Served]",
                 exc: BaseException, hops: int) -> None:
        """One failed attempt on ``pod``: feed the detector, then either
        move the request to a survivor (pod is dead, hop budget left) or
        surface the error on the outer future."""
        self._record_outcome(pod, failed=True)
        with self._lock:
            can_move = (not self._alive[pod] and not self._closed
                        and hops + 1 < len(self.pods)
                        and self._alive.any())
            if can_move:
                self.resubmitted += 1
                self._moving += 1
        if can_move:
            self._exec.submit(self._resubmit, req, outer, hops + 1)
        else:
            outer.set_exception(exc)

    def _submit_guarded(self, pod: int, shard_reqs: List[RouteRequest],
                        outers: List["Future[Served]"]) -> None:
        """Armed-mode shard submission: one ``pod.submit`` per request, so
        an inline-flush backend error surfaces HERE for exactly the
        request that triggered it (co-batched failures come back through
        the futures ``_guard`` already watches) and recovery never loses a
        request the way a whole-shard ``submit_batch`` raise would."""
        for req, outer in zip(shard_reqs, outers):
            try:
                fut = self.pods[pod].submit(req)
            except Exception as exc:
                self._release(pod)
                self._recover(pod, req, outer, exc, hops=0)
            else:
                self._guard(fut, pod, req, outer, hops=0)

    def _resubmit(self, req: RouteRequest, outer: "Future[Served]",
                  hops: int) -> None:
        """Re-place one request that failed on a dead pod (executor
        thread: holds no lock while entering the survivor pod)."""
        try:
            try:
                pod = int(self._assign([req.uid], batched=False)[0])
            except Exception as exc:
                outer.set_exception(exc)
                return
            try:
                fut = self.pods[pod].submit(req)
            except Exception as exc:
                self._release(pod)
                outer.set_exception(exc)
                return
            self._guard(fut, pod, req, outer, hops)
        finally:
            with self._lock:
                self._moving -= 1
                self._lock.notify_all()

    def submit(self, req: RouteRequest) -> "Future[Served]":
        """Shard one request (scalar reference path) and submit it to its
        pod; the pod routes, queues and batches as usual.  If the pod's
        submit raises (inline-flush backend error, routing error), the
        request is un-counted from the depth accounting before the error
        propagates — same invariant as ``submit_batch``'s error path."""
        pod = int(self._assign([req.uid], batched=False)[0])
        if self.pod_fail_after is None:
            try:
                fut = self.pods[pod].submit(req)
            except Exception:
                with self._lock:
                    self._depth[pod] -= 1
                raise
            return self._watch(fut, pod)
        outer: "Future[Served]" = Future()
        try:
            fut = self.pods[pod].submit(req)
        except Exception as exc:
            self._release(pod)
            self._recover(pod, req, outer, exc, hops=0)
        else:
            self._guard(fut, pod, req, outer, hops=0)
        return outer

    def submit_batch(self, reqs: Sequence[RouteRequest]
                     ) -> List["Future[Served]"]:
        """One jitted shard-selection call for the whole batch, then each
        pod's shard is submitted CONCURRENTLY (thread pool) — pods route
        and serve in parallel.  Futures return in request order.

        Error semantics mirror ``EcoreService.submit_batch``: if a pod's
        inline flush raises, the error re-raises here AFTER every healthy
        pod's futures have their depth watchers attached and the failing
        pod's shard is released from the depth accounting (its service
        already failed the affected futures) — a blown backend must not
        skew least-loaded sharding for the cluster's lifetime."""
        reqs = list(reqs)
        if not reqs:
            return []
        picks = self._assign([r.uid for r in reqs], batched=True)
        shards: Dict[int, List[int]] = {}
        for i, p in enumerate(picks):
            shards.setdefault(int(p), []).append(i)
        if self.pod_fail_after is not None:
            # degradation armed: per-request pod submission (still batched
            # at the dispatch queues) so inline backend errors recover
            # per-request instead of losing a whole shard's futures
            outers: List["Future[Served]"] = [Future() for _ in reqs]
            tasks = [self._exec.submit(self._submit_guarded, pod,
                                       [reqs[i] for i in idxs],
                                       [outers[i] for i in idxs])
                     for pod, idxs in shards.items()]
            for t in tasks:
                t.result()
            return outers
        pending = {
            pod: self._exec.submit(self.pods[pod].submit_batch,
                                   [reqs[i] for i in idxs])
            for pod, idxs in shards.items()}
        out: List[Optional[Future]] = [None] * len(reqs)
        first_exc = None
        for pod, idxs in shards.items():
            try:
                futs = pending[pod].result()
            except Exception as exc:
                first_exc = first_exc or exc
                # nothing watchable came back, so un-count the whole shard.
                # This is an APPROXIMATION: requests the pod had already
                # enqueued on healthy queues before the flush blew up are
                # still in flight but no longer counted (they resolve at
                # drain without a watcher, so no double-decrement) — depth
                # errs toward routing TOWARD a blown pod until drain, never
                # permanently away from it.
                with self._lock:
                    self._depth[pod] -= len(idxs)
                continue
            if self.pod_fail_after is None:
                for i, fut in zip(idxs, futs):
                    out[i] = self._watch(fut, pod)
            else:
                for i, fut in zip(idxs, futs):
                    outer: "Future[Served]" = Future()
                    self._guard(fut, pod, reqs[i], outer, hops=0)
                    out[i] = outer
        if first_exc is not None:
            raise first_exc
        return out  # type: ignore[return-value]

    # -------------------------------------------------------- elasticity

    def can_add_pod(self) -> bool:
        """True when scale-up is possible: a retired pod can revive, or the
        fleet is still below ``max_pods``."""
        with self._lock:
            return bool(self._retired) or len(self.pods) < self.max_pods

    def add_pod(self) -> int:
        """Grow the fleet by one pod and return its index.  A RETIRED pod
        (drained by ``retire_pod``, still healthy) revives in place —
        lowest index first, so grow/shrink cycles reuse warm pods and their
        adapted policies — otherwise a fresh pod is appended, up to
        ``max_pods``."""
        with self._lock:
            if self._closed:
                raise RuntimeError("cluster is closed")
            if self._retired:
                pod = min(self._retired)
                self._retired.discard(pod)
                self._alive[pod] = True
                self._consec_errors[pod] = 0
                self._lock.notify_all()
                return pod
            pod = len(self.pods)
            if pod >= self.max_pods:
                raise RuntimeError(
                    f"cluster is at max_pods={self.max_pods}")
            self.pods.append(self._make_pod(pod))
            self._depth = np.append(self._depth, 0)
            self.shard_counts = np.append(self.shard_counts, 0)
            self._alive = np.append(self._alive, True)
            self._consec_errors = np.append(self._consec_errors, 0)
            self._lock.notify_all()
            return pod

    def retire_pod(self, pod: Optional[int] = None) -> int:
        """Shrink the fleet by one pod: mask it out of shard selection,
        remember it as retired (revivable), then DRAIN it so every queued
        request completes — a scale-down never drops work.  Default victim
        is the highest-index live pod; the last live pod is never retired."""
        with self._lock:
            live = [i for i, a in enumerate(self._alive) if a]
            if pod is None:
                if not live:
                    raise NoLivePods("no live pod to retire")
                pod = live[-1]
            if not (0 <= pod < len(self.pods)) or not self._alive[pod]:
                raise ValueError(f"pod {pod} is not live")
            if len(live) <= 1:
                raise ValueError("refusing to retire the last live pod")
            self._alive[pod] = False
            self._retired.add(pod)
            self._lock.notify_all()
        # outside the cluster lock: drain takes the pod's own condition and
        # resolves futures (whose callbacks may re-enter cluster state)
        self.pods[pod].drain()
        return pod

    def live_pods(self) -> List[int]:
        with self._lock:
            return [i for i, a in enumerate(self._alive) if a]

    def queue_depths(self) -> List[int]:
        """Live in-flight depth per pod (the shard-selection input)."""
        with self._lock:
            return self._depth.tolist()

    def owner_of(self, uid: int) -> Optional[int]:
        """Pod that owns ``uid``'s decision (None if unknown/evicted)."""
        with self._lock:
            return self._owner.get(uid)

    def next_deadline(self) -> Optional[float]:
        """Earliest ``max_wait_ms`` expiry across every pod's queues (the
        virtual-time driver's next flush event), or None."""
        deadlines = [d for p in list(self.pods)
                     if (d := p.next_deadline()) is not None]
        return min(deadlines) if deadlines else None

    def flush_due(self, now: Optional[float] = None) -> int:
        """Synchronously flush every pod queue whose deadline expired."""
        return sum(p.flush_due(now) for p in list(self.pods))

    # ----------------------------------------------------------- observe

    def observe(self, obs: Observation) -> None:
        """Fold a measurement into the OWNING pod's policy (by ``obs.uid``);
        an observation without a uid is pair-wide evidence and broadcasts
        to every pod.  A uid-keyed observation whose owner is UNKNOWN
        (evicted past ``OWNER_LIMIT``, or never routed here) is DROPPED and
        counted in ``stats()["stale_observations"]`` — pod-specific
        evidence must not be smeared across every pod's profile."""
        if obs.uid is not None:
            with self._lock:
                pod = self._owner.get(obs.uid)
                if pod is None:
                    self.stale_observations += 1
                    return
            self.pods[pod].observe(obs)
        else:
            for p in self.pods:
                p.observe(obs)

    # ----------------------------------------------------------- results

    def results(self) -> List[Served]:
        out: List[Served] = []
        for p in self.pods:
            out += p.results()
        return out

    def drain(self) -> List[Served]:
        """Drain every pod CONCURRENTLY; completions are merged.  The first
        pod error re-raises after all pods finished draining.  Under
        degradation a drained failure may RESUBMIT to a survivor, so the
        drain loops until no resubmission is still moving between pods
        (bounded: each request moves at most pods-1 times)."""
        out: List[Served] = []
        first_exc = None
        while True:
            futs = [self._exec.submit(p.drain) for p in self.pods]
            for f in futs:
                try:
                    out += f.result()
                except Exception as exc:
                    first_exc = first_exc or exc
            with self._lock:
                while self._moving:
                    self._lock.wait(timeout=1.0)
            if not any(p.pending_requests for p in self.pods):
                break
        if first_exc is not None:
            raise first_exc
        return out

    def close(self) -> None:
        if self._closed:
            return
        first_exc = None
        for f in [self._exec.submit(p.close) for p in self.pods]:
            try:
                f.result()
            except Exception as exc:
                first_exc = first_exc or exc
        self._closed = True
        self._exec.shutdown(wait=True)
        if first_exc is not None:
            raise first_exc

    def __enter__(self) -> "EcoreCluster":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def wake(self) -> None:
        for p in self.pods:
            p.wake()

    # ------------------------------------------------------------- stats

    def stats(self) -> Dict:
        per_pod = [p.stats() for p in self.pods]
        with self._lock:
            alive = self._alive.tolist()
            resubmitted = self.resubmitted
            retired = sorted(self._retired)
        return {
            "pods": len(self.pods),
            "max_pods": self.max_pods,
            "retired": retired,
            "shard_mode": self.shard,
            "shard_counts": self.shard_counts.tolist(),
            "backends": sum(s["backends"] for s in per_pod),
            "serve_calls": sum(s["serve_calls"] for s in per_pod),
            "served": sum(s["served"] for s in per_pod),
            "deadline_flushes": sum(s["deadline_flushes"] for s in per_pod),
            "stale_observations": self.stale_observations,
            "alive": alive,
            "availability": sum(alive) / len(alive),
            "resubmitted": resubmitted,
            "per_pod": per_pod,
        }


# ------------------------------------------------------------ autoscaler

class Autoscaler:
    """Queue-depth-driven fleet elasticity with hysteresis, entirely on the
    injectable clock — no background thread, no wall-clock sleeps.

    The owner of time (``repro.traffic.LoadDriver``, or any event loop)
    calls ``tick(backlog)`` whenever the backlog signal changes.  Backlog
    is normalized per LIVE pod and compared against two watermarks:

      * backlog/pod >= ``high_backlog_per_pod``  -> ``add_pod`` (revive a
        retired pod, else append, up to ``max_pods``);
      * backlog/pod <= ``low_backlog_per_pod``   -> ``retire_pod`` (drain
        the highest-index live pod, down to ``min_pods``).

    The gap between the watermarks plus ``cooldown_s`` between actions is
    the hysteresis: a backlog oscillating inside the band changes nothing,
    and a spike cannot flap the fleet faster than one pod per cooldown.
    Every action is appended to ``events`` (virtual timestamp, action, pod,
    backlog, resulting live count) — the bench's audit trail."""

    def __init__(self, cluster: EcoreCluster,
                 clock: Callable[[], float] = time.monotonic, *,
                 min_pods: int = 1, max_pods: Optional[int] = None,
                 high_backlog_per_pod: float = 8.0,
                 low_backlog_per_pod: float = 1.0,
                 cooldown_s: float = 2.0):
        if min_pods < 1:
            raise ValueError(f"min_pods={min_pods}: need >= 1")
        self.max_pods = (cluster.max_pods if max_pods is None
                         else min(max_pods, cluster.max_pods))
        if self.max_pods < min_pods:
            raise ValueError(
                f"max_pods={self.max_pods} below min_pods={min_pods}")
        if low_backlog_per_pod >= high_backlog_per_pod:
            raise ValueError(
                f"watermarks must leave a hysteresis band: "
                f"low={low_backlog_per_pod} >= high={high_backlog_per_pod}")
        self.cluster = cluster
        self.clock = clock
        self.min_pods = min_pods
        self.high = high_backlog_per_pod
        self.low = low_backlog_per_pod
        self.cooldown_s = cooldown_s
        self._last_action_t = -float("inf")
        self.events: List[Dict] = []

    def tick(self, backlog: int) -> Optional[str]:
        """Evaluate the watermarks against ``backlog``; returns "add",
        "retire", or None (in cooldown / inside the hysteresis band)."""
        now = self.clock()
        if now - self._last_action_t < self.cooldown_s:
            return None
        live = self.cluster.live_pods()
        n = len(live)
        per_pod = backlog / max(n, 1)
        if (per_pod >= self.high and n < self.max_pods
                and self.cluster.can_add_pod()):
            pod = self.cluster.add_pod()
            action = "add"
        elif per_pod <= self.low and n > self.min_pods:
            pod = self.cluster.retire_pod()
            action = "retire"
        else:
            return None
        self._last_action_t = now
        self.events.append({
            "t_s": now, "action": action, "pod": pod, "backlog": backlog,
            "live_pods": len(self.cluster.live_pods()),
        })
        return action

"""EcoreCluster: N EcoreService pods behind ONE request plane.

Scaling ECORE out means standing up many (policy + dispatch queues +
backends) pods and sharding the request stream across them — the serving
analog of the paper's multi-gateway deployment and AyE-Edge's
deployment-space search.  The cluster owns:

  * shard selection — a JITTED, tensorized step over the per-pod
    queue-depth array (one XLA call assigns a whole batch), with an
    exact-parity scalar reference (``select_pods_reference``) used on the
    per-request path and in tests.  Two policies:

      - ``least_loaded``: sequential greedy argmin over live depths
        (a ``lax.scan`` — each assignment sees the depths the previous
        ones produced, exactly like the scalar loop);
      - ``rendezvous``: highest-random-weight hashing of (uid, pod) via a
        splitmix-style 32-bit avalanche — stable request->pod affinity
        that survives pod count changes with minimal reshuffling.

  * observe() fan-in — an ``Observation`` carrying the request ``uid`` is
    folded into the OWNING pod's policy (the pod whose decision produced
    the measurement); without a uid it is a pair-wide signal and broadcasts
    to every pod.

  * per-pod ``stats()`` aggregation and concurrent ``drain``/``close``.

Pods are fully independent (own policy, own queues, own backends, own
lock), so ``submit_batch`` fans each pod's shard out on a small thread
pool: pods serve concurrently — XLA releases the GIL during backend
execution — which is where the multi-pod throughput scaling comes from
(``benchmarks/run.py --only cluster``).
"""
from __future__ import annotations

import collections
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core.policy import (Observation, RouteDecision, RouteRequest,
                               RoutingPolicy)
from repro.serving.service import EcoreService, Served

SHARD_MODES = ("least_loaded", "rendezvous")

#: bound on the uid -> owning-pod map (a long-lived cluster must not grow
#: per-request state; observations normally arrive right after completion)
OWNER_LIMIT = 8192


# ------------------------------------------------------- shard selection

def _mix32(x, xp):
    """splitmix32-style avalanche on uint32 arrays; ``xp`` is numpy or
    jax.numpy — SAME integer ops in both, so the jitted kernel and the
    scalar reference agree bit for bit."""
    x = x ^ (x >> 16)
    x = x * xp.uint32(0x7FEB352D)
    x = x ^ (x >> 15)
    x = x * xp.uint32(0x846CA68B)
    x = x ^ (x >> 16)
    return x


#: a dead pod's masked queue depth: larger than any real depth, far from
#: int32 overflow even after a whole batch of .add(1)s
_DEAD_DEPTH = 2 ** 30


def _masked_scores(scores, alive, xp):
    """Rendezvous scores with dead pods forced to lose: live scores map
    monotonically into [2^31, 2^32) (>> 1 then set the top bit), dead pods
    score 0.  Same uint32 ops for numpy and jnp — no int64, which jax
    would silently downcast with x64 disabled."""
    live = (scores >> xp.uint32(1)) | xp.uint32(0x80000000)
    return xp.where(alive, live, xp.uint32(0))


_kernels = None


def _shard_kernels():
    global _kernels
    if _kernels is None:
        import jax
        import jax.numpy as jnp

        @jax.jit
        def rendezvous(uids_u32, pod_ids_u32):
            # highest-random-weight: score every (request, pod), argmax rows
            scores = _mix32(uids_u32[:, None] ^ _mix32(pod_ids_u32, jnp)[None, :],
                            jnp)
            return jnp.argmax(scores, axis=1)

        @jax.jit
        def rendezvous_masked(uids_u32, pod_ids_u32, alive):
            scores = _mix32(uids_u32[:, None] ^ _mix32(pod_ids_u32, jnp)[None, :],
                            jnp)
            return jnp.argmax(_masked_scores(scores, alive[None, :], jnp),
                              axis=1)

        @jax.jit
        def least_loaded(uids_u32, depths_i32):
            # sequential greedy: each pick sees the depths the previous
            # picks produced (ties -> lowest pod index, like np.argmin)
            def step(depth, _):
                p = jnp.argmin(depth)
                return depth.at[p].add(1), p
            _, picks = jax.lax.scan(step, depths_i32, uids_u32)
            return picks

        @jax.jit
        def least_loaded_masked(uids_u32, depths_i32, alive):
            dead = jnp.int32(_DEAD_DEPTH)
            def step(depth, _):
                p = jnp.argmin(jnp.where(alive, depth, dead))
                return depth.at[p].add(1), p
            _, picks = jax.lax.scan(step, depths_i32, uids_u32)
            return picks

        _kernels = {"rendezvous": rendezvous, "least_loaded": least_loaded,
                    "rendezvous_masked": rendezvous_masked,
                    "least_loaded_masked": least_loaded_masked}
    return _kernels


def select_pods(uids: Sequence[int], depths: Sequence[int],
                mode: str = "least_loaded",
                alive: Optional[Sequence[bool]] = None) -> np.ndarray:
    """Assign a batch of request uids to pods in ONE jitted XLA call.

    ``depths`` is the live per-pod queue depth (least-loaded consumes it;
    rendezvous ignores it).  ``alive`` (optional bool mask) excludes dead
    pods: least-loaded sees their depth as unbeatable, rendezvous forces
    their score below every live pod's — graceful degradation without a
    separate kernel family; ``None`` runs the original unmasked kernels
    bit-identically.  Exactly matches ``select_pods_reference`` (tested):
    pure uint32/int32 arithmetic on both paths."""
    if mode not in SHARD_MODES:
        raise ValueError(f"unknown shard mode {mode!r}; one of {SHARD_MODES}")
    import jax.numpy as jnp
    uids_u32 = jnp.asarray(np.asarray(uids, np.uint32))
    k = _shard_kernels()[mode if alive is None else mode + "_masked"]
    if mode == "rendezvous":
        pod_ids = jnp.asarray(np.arange(len(depths), dtype=np.uint32))
        if alive is None:
            return np.asarray(k(uids_u32, pod_ids))
        return np.asarray(k(uids_u32, pod_ids,
                            jnp.asarray(np.asarray(alive, bool))))
    depths_i32 = jnp.asarray(np.asarray(depths, np.int32))
    if alive is None:
        return np.asarray(k(uids_u32, depths_i32))
    return np.asarray(k(uids_u32, depths_i32,
                        jnp.asarray(np.asarray(alive, bool))))


def select_pods_reference(uids: Sequence[int], depths: Sequence[int],
                          mode: str = "least_loaded",
                          alive: Optional[Sequence[bool]] = None
                          ) -> np.ndarray:
    """Scalar reference: one request at a time, plain numpy.  The jitted
    ``select_pods`` must match this exactly (masked or not)."""
    if mode not in SHARD_MODES:
        raise ValueError(f"unknown shard mode {mode!r}; one of {SHARD_MODES}")
    uids = list(uids)   # materialize ONCE: a generator must not be exhausted
    depths = np.asarray(depths, np.int32).copy()
    pod_ids = np.arange(len(depths), dtype=np.uint32)
    alive_mask = None if alive is None else np.asarray(alive, bool)
    picks = np.zeros(len(uids), np.int64)
    for i, uid in enumerate(uids):
        if mode == "least_loaded":
            visible = (depths if alive_mask is None
                       else np.where(alive_mask, depths,
                                     np.int32(_DEAD_DEPTH)))
            p = int(np.argmin(visible))
            depths[p] += 1
        else:
            u = np.asarray([uid], np.uint32)  # arrays: silent uint32 wrap
            scores = _mix32(u ^ _mix32(pod_ids, np), np)
            if alive_mask is not None:
                scores = _masked_scores(scores, alive_mask, np)
            p = int(np.argmax(scores))
        picks[i] = p
    return picks


# --------------------------------------------------------------- cluster

class NoLivePods(RuntimeError):
    """Every pod has been marked failed — the cluster cannot place work."""


class EcoreCluster:
    """Shard one request stream over N independent ``EcoreService`` pods.

    ``policy_factory(pod_index)`` builds each pod's OWN policy (adaptive
    state must not be shared — observations fold into the owning pod);
    ``backend_factory`` is per-decision, as in ``EcoreService``.  Requests
    need cluster-unique uids (the owner map and each pod's inflight check
    key on them).

    ``pod_fail_after`` (optional) arms graceful degradation: after that
    many CONSECUTIVE failed completions a pod is marked dead
    (``mark_pod_failed``), masked out of shard selection, and every
    request that failed on it is RESUBMITTED to a surviving pod (the
    cluster then owns the returned future and resolves it from whichever
    pod finally answers; the owner map follows the move, so uid-keyed
    observations fold into the pod that actually served).  Off (None),
    behavior is identical to the non-degrading cluster: pod futures are
    returned directly and errors propagate untouched."""

    def __init__(self, policy_factory: Callable[[int], RoutingPolicy],
                 backend_factory: Callable[[RouteDecision], object], *,
                 pods: int = 2, shard: str = "least_loaded",
                 max_wait_ms: Optional[float] = None,
                 clock: Callable[[], float] = time.monotonic,
                 retain_results: bool = True,
                 pod_fail_after: Optional[int] = None):
        if pods < 1:
            raise ValueError(f"pods={pods}: need at least one pod")
        if shard not in SHARD_MODES:
            raise ValueError(
                f"unknown shard mode {shard!r}; one of {SHARD_MODES}")
        self.shard = shard
        self.pods: List[EcoreService] = [
            EcoreService(policy_factory(i), backend_factory,
                         max_wait_ms=max_wait_ms, clock=clock,
                         retain_results=retain_results)
            for i in range(pods)]
        self._lock = threading.Condition()
        #: live queue depth per pod (in-flight requests; shard input)
        self._depth = np.zeros(pods, np.int64)
        #: total requests ever assigned per pod (stats)
        self.shard_counts = np.zeros(pods, np.int64)
        self._owner: Dict[int, int] = {}
        self._owner_order: collections.deque = collections.deque()
        #: uid-keyed observations dropped because the owner was unknown
        self.stale_observations = 0
        self.pod_fail_after = pod_fail_after
        self._alive = np.ones(pods, bool)
        self._consec_errors = np.zeros(pods, np.int64)
        self.resubmitted = 0          # requests moved off a failed pod
        self._moving = 0              # resubmissions not yet re-enqueued
        self._exec = ThreadPoolExecutor(max_workers=pods,
                                        thread_name_prefix="ecore-pod")
        self._closed = False

    # ------------------------------------------------------------ submit

    def _assign(self, uids: Sequence[int], batched: bool) -> np.ndarray:
        with self._lock:
            if not self._alive.any():
                raise NoLivePods(
                    f"all {len(self.pods)} pods are marked failed")
            # the mask only enters selection once degradation is armed (or
            # a pod actually died) — the unmasked kernels stay bit-
            # identical to the non-degrading cluster
            alive = None if self._alive.all() else self._alive
            picks = (select_pods if batched else select_pods_reference)(
                uids, self._depth, self.shard, alive=alive)
            np.add.at(self._depth, picks, 1)
            np.add.at(self.shard_counts, picks, 1)
            for uid, p in zip(uids, picks):
                if uid not in self._owner:
                    self._owner_order.append(uid)
                self._owner[uid] = int(p)
            while len(self._owner_order) > OWNER_LIMIT:
                self._owner.pop(self._owner_order.popleft(), None)
        return picks

    def _release(self, pod: int) -> None:
        with self._lock:
            self._depth[pod] -= 1

    def _watch(self, fut: "Future[Served]", pod: int) -> "Future[Served]":
        fut.add_done_callback(lambda _f: self._release(pod))
        return fut

    # ------------------------------------------------------- degradation

    def mark_pod_failed(self, pod: int) -> None:
        """Mask ``pod`` out of shard selection (manual override or called
        by the consecutive-error detector).  Its queued work is not
        recalled wholesale — each failed completion resubmits itself — but
        nothing NEW lands on it."""
        with self._lock:
            self._alive[pod] = False
            self._lock.notify_all()

    def _record_outcome(self, pod: int, failed: bool) -> None:
        """Consecutive-failure pod detector (degradation armed only)."""
        with self._lock:
            if failed:
                self._consec_errors[pod] += 1
                if (self.pod_fail_after is not None and self._alive[pod]
                        and self._consec_errors[pod] >= self.pod_fail_after):
                    self._alive[pod] = False
            else:
                self._consec_errors[pod] = 0
            self._lock.notify_all()

    def _guard(self, fut: "Future[Served]", pod: int, req: RouteRequest,
               outer: "Future[Served]", hops: int) -> None:
        """Bridge a pod future to the cluster-owned ``outer`` future,
        recording outcomes and resubmitting failures to survivors.  The
        pod resolves its futures while holding its OWN condition, so the
        resubmission (which must take another pod's condition) hops
        through the executor — pod-to-pod lock cycles are impossible."""
        def _done(f: "Future[Served]") -> None:
            self._release(pod)
            exc = f.exception()
            if exc is None:
                self._record_outcome(pod, failed=False)
                outer.set_result(f.result())
                return
            self._recover(pod, req, outer, exc, hops)
        fut.add_done_callback(_done)

    def _recover(self, pod: int, req: RouteRequest, outer: "Future[Served]",
                 exc: BaseException, hops: int) -> None:
        """One failed attempt on ``pod``: feed the detector, then either
        move the request to a survivor (pod is dead, hop budget left) or
        surface the error on the outer future."""
        self._record_outcome(pod, failed=True)
        with self._lock:
            can_move = (not self._alive[pod] and not self._closed
                        and hops + 1 < len(self.pods)
                        and self._alive.any())
            if can_move:
                self.resubmitted += 1
                self._moving += 1
        if can_move:
            self._exec.submit(self._resubmit, req, outer, hops + 1)
        else:
            outer.set_exception(exc)

    def _submit_guarded(self, pod: int, shard_reqs: List[RouteRequest],
                        outers: List["Future[Served]"]) -> None:
        """Armed-mode shard submission: one ``pod.submit`` per request, so
        an inline-flush backend error surfaces HERE for exactly the
        request that triggered it (co-batched failures come back through
        the futures ``_guard`` already watches) and recovery never loses a
        request the way a whole-shard ``submit_batch`` raise would."""
        for req, outer in zip(shard_reqs, outers):
            try:
                fut = self.pods[pod].submit(req)
            except Exception as exc:
                self._release(pod)
                self._recover(pod, req, outer, exc, hops=0)
            else:
                self._guard(fut, pod, req, outer, hops=0)

    def _resubmit(self, req: RouteRequest, outer: "Future[Served]",
                  hops: int) -> None:
        """Re-place one request that failed on a dead pod (executor
        thread: holds no lock while entering the survivor pod)."""
        try:
            try:
                pod = int(self._assign([req.uid], batched=False)[0])
            except Exception as exc:
                outer.set_exception(exc)
                return
            try:
                fut = self.pods[pod].submit(req)
            except Exception as exc:
                self._release(pod)
                outer.set_exception(exc)
                return
            self._guard(fut, pod, req, outer, hops)
        finally:
            with self._lock:
                self._moving -= 1
                self._lock.notify_all()

    def submit(self, req: RouteRequest) -> "Future[Served]":
        """Shard one request (scalar reference path) and submit it to its
        pod; the pod routes, queues and batches as usual.  If the pod's
        submit raises (inline-flush backend error, routing error), the
        request is un-counted from the depth accounting before the error
        propagates — same invariant as ``submit_batch``'s error path."""
        pod = int(self._assign([req.uid], batched=False)[0])
        if self.pod_fail_after is None:
            try:
                fut = self.pods[pod].submit(req)
            except Exception:
                with self._lock:
                    self._depth[pod] -= 1
                raise
            return self._watch(fut, pod)
        outer: "Future[Served]" = Future()
        try:
            fut = self.pods[pod].submit(req)
        except Exception as exc:
            self._release(pod)
            self._recover(pod, req, outer, exc, hops=0)
        else:
            self._guard(fut, pod, req, outer, hops=0)
        return outer

    def submit_batch(self, reqs: Sequence[RouteRequest]
                     ) -> List["Future[Served]"]:
        """One jitted shard-selection call for the whole batch, then each
        pod's shard is submitted CONCURRENTLY (thread pool) — pods route
        and serve in parallel.  Futures return in request order.

        Error semantics mirror ``EcoreService.submit_batch``: if a pod's
        inline flush raises, the error re-raises here AFTER every healthy
        pod's futures have their depth watchers attached and the failing
        pod's shard is released from the depth accounting (its service
        already failed the affected futures) — a blown backend must not
        skew least-loaded sharding for the cluster's lifetime."""
        reqs = list(reqs)
        if not reqs:
            return []
        picks = self._assign([r.uid for r in reqs], batched=True)
        shards: Dict[int, List[int]] = {}
        for i, p in enumerate(picks):
            shards.setdefault(int(p), []).append(i)
        if self.pod_fail_after is not None:
            # degradation armed: per-request pod submission (still batched
            # at the dispatch queues) so inline backend errors recover
            # per-request instead of losing a whole shard's futures
            outers: List["Future[Served]"] = [Future() for _ in reqs]
            tasks = [self._exec.submit(self._submit_guarded, pod,
                                       [reqs[i] for i in idxs],
                                       [outers[i] for i in idxs])
                     for pod, idxs in shards.items()]
            for t in tasks:
                t.result()
            return outers
        pending = {
            pod: self._exec.submit(self.pods[pod].submit_batch,
                                   [reqs[i] for i in idxs])
            for pod, idxs in shards.items()}
        out: List[Optional[Future]] = [None] * len(reqs)
        first_exc = None
        for pod, idxs in shards.items():
            try:
                futs = pending[pod].result()
            except Exception as exc:
                first_exc = first_exc or exc
                # nothing watchable came back, so un-count the whole shard.
                # This is an APPROXIMATION: requests the pod had already
                # enqueued on healthy queues before the flush blew up are
                # still in flight but no longer counted (they resolve at
                # drain without a watcher, so no double-decrement) — depth
                # errs toward routing TOWARD a blown pod until drain, never
                # permanently away from it.
                with self._lock:
                    self._depth[pod] -= len(idxs)
                continue
            if self.pod_fail_after is None:
                for i, fut in zip(idxs, futs):
                    out[i] = self._watch(fut, pod)
            else:
                for i, fut in zip(idxs, futs):
                    outer: "Future[Served]" = Future()
                    self._guard(fut, pod, reqs[i], outer, hops=0)
                    out[i] = outer
        if first_exc is not None:
            raise first_exc
        return out  # type: ignore[return-value]

    # ----------------------------------------------------------- observe

    def observe(self, obs: Observation) -> None:
        """Fold a measurement into the OWNING pod's policy (by ``obs.uid``);
        an observation without a uid is pair-wide evidence and broadcasts
        to every pod.  A uid-keyed observation whose owner is UNKNOWN
        (evicted past ``OWNER_LIMIT``, or never routed here) is DROPPED and
        counted in ``stats()["stale_observations"]`` — pod-specific
        evidence must not be smeared across every pod's profile."""
        if obs.uid is not None:
            with self._lock:
                pod = self._owner.get(obs.uid)
                if pod is None:
                    self.stale_observations += 1
                    return
            self.pods[pod].observe(obs)
        else:
            for p in self.pods:
                p.observe(obs)

    # ----------------------------------------------------------- results

    def results(self) -> List[Served]:
        out: List[Served] = []
        for p in self.pods:
            out += p.results()
        return out

    def drain(self) -> List[Served]:
        """Drain every pod CONCURRENTLY; completions are merged.  The first
        pod error re-raises after all pods finished draining.  Under
        degradation a drained failure may RESUBMIT to a survivor, so the
        drain loops until no resubmission is still moving between pods
        (bounded: each request moves at most pods-1 times)."""
        out: List[Served] = []
        first_exc = None
        while True:
            futs = [self._exec.submit(p.drain) for p in self.pods]
            for f in futs:
                try:
                    out += f.result()
                except Exception as exc:
                    first_exc = first_exc or exc
            with self._lock:
                while self._moving:
                    self._lock.wait(timeout=1.0)
            if not any(p.pending_requests for p in self.pods):
                break
        if first_exc is not None:
            raise first_exc
        return out

    def close(self) -> None:
        if self._closed:
            return
        first_exc = None
        for f in [self._exec.submit(p.close) for p in self.pods]:
            try:
                f.result()
            except Exception as exc:
                first_exc = first_exc or exc
        self._closed = True
        self._exec.shutdown(wait=True)
        if first_exc is not None:
            raise first_exc

    def __enter__(self) -> "EcoreCluster":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def wake(self) -> None:
        for p in self.pods:
            p.wake()

    # ------------------------------------------------------------- stats

    def stats(self) -> Dict:
        per_pod = [p.stats() for p in self.pods]
        with self._lock:
            alive = self._alive.tolist()
            resubmitted = self.resubmitted
        return {
            "pods": len(self.pods),
            "shard_mode": self.shard,
            "shard_counts": self.shard_counts.tolist(),
            "backends": sum(s["backends"] for s in per_pod),
            "serve_calls": sum(s["serve_calls"] for s in per_pod),
            "served": sum(s["served"] for s in per_pod),
            "deadline_flushes": sum(s["deadline_flushes"] for s in per_pod),
            "stale_observations": self.stale_observations,
            "alive": alive,
            "availability": sum(alive) / len(alive),
            "resubmitted": resubmitted,
            "per_pod": per_pod,
        }

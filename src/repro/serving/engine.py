"""Batched serving engine: prefill + decode over any model backend.

A ``Backend`` wraps (config, params, jitted prefill/decode) and serves
batches of requests; the pool layer (pool.py) profiles backends and lets the
ECORE gateway route requests among them.  On this CPU container backends run
reduced configs on the host mesh; on a TPU pod the same code runs the full
configs under the production mesh.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import ModelConfig, decode_step, init_params, prefill
from repro.data.tokens import modality_inputs


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray          # [S] int32
    max_new_tokens: int = 8
    # complexity metadata (the serving analog of the paper's object count):
    group: Optional[int] = None


@dataclasses.dataclass
class Result:
    uid: int
    tokens: np.ndarray
    prefill_s: float            # wall time of the WHOLE batch's prefill
    decode_s: float             # wall time of the WHOLE batch's decode
    backend: str
    batch_size: int = 1         # divide the times by this for per-request cost
    # workload-specific extras (the detection face fills these; LLM serving
    # leaves them None): per-request (boxes, scores, classes) plus the
    # modeled device cost actually charged
    detections: Optional[tuple] = None
    time_ms: Optional[float] = None
    energy_mwh: Optional[float] = None


class Backend:
    """One (model x placement) pair exposing an inference API.

    Implements the ``ExecutionBackend`` protocol (serving/backend.py);
    registered under kind ``"llm"``."""

    def __init__(self, name: str, cfg: ModelConfig, params=None, *,
                 max_batch: int = 8, max_seq: int = 256, seed: int = 0):
        self.name = name
        self.cfg = cfg
        self.params = params if params is not None else init_params(
            cfg, jax.random.PRNGKey(seed))
        self.max_batch = max_batch
        self.max_seq = max_seq
        self._prefill = jax.jit(
            lambda p, t, pe: prefill(p, cfg, t, pe, max_seq=max_seq))
        self._decode = jax.jit(lambda p, t, c: decode_step(p, cfg, t, c))
        self._rng = np.random.default_rng(seed)

    def serve_batch(self, requests: List[Request]) -> List[Result]:
        """Greedy-decode a batch of requests (piggybacked, like the paper's
        Locust loop: one batch at a time).

        Prompts should share ONE length: shorter prompts are right-padded
        and the first generated token comes from the batch-wide last
        position (prefill only returns last-position logits), so mixed
        lengths corrupt the shorter requests' outputs — ``DispatchQueue``
        groups by length automatically."""
        assert requests
        b = len(requests)
        max_prompt = max(len(r.prompt) for r in requests)
        tokens = np.zeros((b, max_prompt), np.int32)
        for i, r in enumerate(requests):  # left-pad-free simple right align
            tokens[i, :len(r.prompt)] = r.prompt % self.cfg.vocab_size
        extra = modality_inputs(self.cfg, b, self._rng)
        pe = extra.get("prefix_embeds")

        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, jnp.asarray(tokens), pe)
        next_tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        jax.block_until_ready(next_tok)
        t1 = time.perf_counter()

        max_new = max(r.max_new_tokens for r in requests)
        out = [next_tok]
        for _ in range(max_new - 1):
            logits, cache = self._decode(self.params, next_tok, cache)
            next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            out.append(next_tok)
        jax.block_until_ready(next_tok)
        t2 = time.perf_counter()

        gen = np.concatenate([np.asarray(t) for t in out], axis=1)
        return [Result(uid=r.uid, tokens=gen[i], prefill_s=t1 - t0,
                       decode_s=t2 - t1, backend=self.name, batch_size=b)
                for i, r in enumerate(requests)]

    def profile_row(self) -> Dict[str, object]:
        return {"kind": "llm", "model": self.name,
                "num_layers": self.cfg.num_layers, "d_model": self.cfg.d_model,
                "max_batch": self.max_batch, "max_seq": self.max_seq}


class DispatchQueue:
    """Per-backend request queue with batched, latency-bounded flush.

    Requests accumulate until ``backend.max_batch`` is reached, then go out
    batched — the driver-side half of the engine's batching support (the
    engine always could batch; the serving loop never fed it more than one
    request at a time).  Each flush makes one ``serve_batch`` call per
    distinct prompt LENGTH: ``serve_batch`` right-pads to the longest prompt
    and reads the first generated token from the batch-wide last position,
    so a mixed-length batch would corrupt the shorter requests' outputs —
    homogeneous sub-batches keep batched results identical to solo serving.

    ``max_wait_ms`` bounds how long the OLDEST pending request waits for the
    batch to fill: once the deadline passes, the next ``submit`` or
    ``poll`` serves the partial batch instead of holding it for stragglers.
    The deadline is checked cooperatively (no background thread) — a serving
    loop calls ``poll()`` on its idle ticks.  ``clock`` is injectable for
    deterministic tests (defaults to ``time.monotonic``, seconds)."""

    def __init__(self, backend: Backend, *,
                 max_wait_ms: Optional[float] = None, clock=time.monotonic):
        self.backend = backend
        self.max_wait_ms = max_wait_ms
        self._clock = clock
        self._oldest: Optional[float] = None
        self.pending: List[Request] = []
        self.calls = 0
        self.served = 0
        #: partial batches served because the deadline expired — via submit,
        #: poll, OR the service's background flusher (which bumps it before
        #: flushing), so the metric is path-independent
        self.deadline_flushes = 0

    def _deadline_passed(self) -> bool:
        return (self.max_wait_ms is not None and self._oldest is not None
                and (self._clock() - self._oldest) * 1e3 >= self.max_wait_ms)

    def next_deadline(self) -> Optional[float]:
        """Absolute clock time (seconds, same units as ``clock``) when the
        oldest pending request's wait bound expires; None when there is no
        deadline or nothing is pending.  The threaded flusher
        (``serving.service.EcoreService``) sleeps until the earliest of
        these instead of cooperatively polling."""
        if self.max_wait_ms is None or self._oldest is None or not self.pending:
            return None
        return self._oldest + self.max_wait_ms / 1e3

    def submit(self, req: Request) -> List[Result]:
        """Enqueue; returns flushed results when the batch fills (or the
        oldest pending request's deadline has passed), else []."""
        if not self.pending:
            self._oldest = self._clock()
        self.pending.append(req)
        if len(self.pending) >= self.backend.max_batch:
            return self.flush()
        if self._deadline_passed():
            self.deadline_flushes += 1
            return self.flush()
        return []

    def poll(self) -> List[Result]:
        """Serve the pending partial batch if it has waited past
        ``max_wait_ms``; [] otherwise.  No-op without a deadline."""
        if self.pending and self._deadline_passed():
            self.deadline_flushes += 1
            return self.flush()
        return []

    def flush(self) -> List[Result]:
        if not self.pending:
            return []
        batch, self.pending = self.pending, []
        self._oldest = None
        by_len: Dict[int, List[Request]] = {}
        for r in batch:
            by_len.setdefault(len(r.prompt), []).append(r)
        results: List[Result] = []
        for _, group in sorted(by_len.items()):
            self.calls += 1
            self.served += len(group)
            results += self.backend.serve_batch(group)
        return results

"""Fault injection plane: deterministic failures for any ExecutionBackend.

Chaos testing the serving stack needs failures that are REPRODUCIBLE — a
flaky test that injects faults at random times is worse than no test.  So
every fault here is a pure function of the request ``uid``: a ``FaultSpec``
hashes (uid, seed, kind) through the same splitmix32 avalanche the cluster
uses for rendezvous sharding and fires when the hash lands under ``rate``.
Two runs over the same uid stream inject byte-identical fault sequences, no
matter how dispatch batches or reorders — the same uid-keyed determinism
``DetectorBackend`` relies on for fleet drift.

Four fault kinds, matching how edge devices actually die:

  * ``error``        — the device throws: ``serve_batch`` raises
                       ``InjectedFault`` (the whole batch dies with it,
                       exactly like a real backend exception in
                       ``EcoreService._dispatch``)
  * ``stall``        — the device answers LATE: the result's modeled
                       ``time_ms`` is inflated by ``stall_ms`` (a deadline
                       miss for the resilience layer, not an exception)
  * ``corrupt``      — the device answers GARBAGE: payload zeroed and
                       ``time_ms`` = NaN, the detectable corruption marker
                       the resilience layer's validator rejects
  * ``crash_window`` — the device is down for every uid in
                       [``start``, ``end``): the uid-space analog of
                       ``DriftEvent(kind="dropout", hard=True)``

``FaultyBackend`` wraps any registered backend with a list of specs;
``make_backend("faulty:<inner>", ..., faults=[...])`` builds the wrapped
form through the ordinary registry, so every bench/test factory can switch
a healthy fleet to a faulty one by changing one string.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.serving.backend import ExecutionBackend, ensure_backend
from repro.serving.cluster import _mix32
from repro.serving.engine import Request, Result

FAULT_KINDS = ("error", "stall", "corrupt", "crash_window")

#: per-kind hash salt so one seed drives independent streams per fault kind
_KIND_SALT = {"error": 0x9E3779B9, "stall": 0x85EBCA6B,
              "corrupt": 0xC2B2AE35, "crash_window": 0x27D4EB2F}


class InjectedFault(RuntimeError):
    """A deterministically injected backend failure (the fault plane's
    analog of a device throwing mid-batch)."""

    def __init__(self, kind: str, uid: int, backend: str):
        super().__init__(f"injected {kind} fault on {backend!r} "
                         f"(fired by uid {uid})")
        self.kind = kind
        self.uid = uid
        self.backend = backend


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One fault mode, deterministically seeded per request uid.

    ``rate`` is the per-uid firing probability for ``error``/``stall``/
    ``corrupt`` (evaluated by hashing, so it is exact-in-distribution and
    reproducible, not sampled); ``crash_window`` ignores it and fires for
    every uid in [``start``, ``end``)."""
    kind: str
    rate: float = 1.0
    seed: int = 0
    stall_ms: float = 250.0     # modeled extra latency for a stall
    start: int = 0              # crash window [start, end) in uid space
    end: Optional[int] = None   # exclusive; None = never recovers

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"one of {FAULT_KINDS}")
        if not 0.0 <= self.rate <= 1.0:
            raise ValueError(f"rate={self.rate}: probability in [0, 1]")

    def fires(self, uid: int) -> bool:
        """Does this fault hit request ``uid``?  Pure, stateless,
        reproducible — the whole point of the injection plane."""
        if self.kind == "crash_window":
            return uid >= self.start and (self.end is None
                                          or uid < self.end)
        if self.rate <= 0.0:
            return False
        if self.rate >= 1.0:
            return True
        # arrays, not scalars: uint32 arithmetic must wrap silently
        salt = _mix32(np.asarray([self.seed], np.uint32)
                      ^ np.uint32(_KIND_SALT[self.kind]), np)
        h = _mix32(np.asarray([uid], np.uint32) ^ salt, np)
        return int(h[0]) < int(self.rate * 4294967296.0)


class FaultyBackend:
    """Wrap any ``ExecutionBackend`` with deterministic fault injection.

    ``error``/``crash_window`` faults fire BEFORE the inner backend runs —
    the device never answered, so no result exists and the whole batch
    fails (matching real backend-exception semantics in the dispatch
    plane).  ``stall``/``corrupt`` faults rewrite the inner backend's
    results after the fact.  ``injected`` counts fired faults per kind for
    bench/test observability."""

    def __init__(self, inner: ExecutionBackend,
                 faults: Sequence[FaultSpec] = ()):
        self.inner = ensure_backend(inner)
        self.faults = tuple(faults)
        self.name = self.inner.name
        self.max_batch = self.inner.max_batch
        self.injected: Dict[str, int] = {k: 0 for k in FAULT_KINDS}

    def serve_batch(self, requests: List[Request]) -> List[Result]:
        for r in requests:
            for spec in self.faults:
                if (spec.kind in ("error", "crash_window")
                        and spec.fires(r.uid)):
                    self.injected[spec.kind] += 1
                    raise InjectedFault(spec.kind, r.uid, self.name)
        results = self.inner.serve_batch(requests)
        out = []
        for res in results:
            for spec in self.faults:
                if spec.kind == "stall" and spec.fires(res.uid):
                    self.injected["stall"] += 1
                    res = dataclasses.replace(
                        res, time_ms=(res.time_ms or 0.0) + spec.stall_ms)
                elif spec.kind == "corrupt" and spec.fires(res.uid):
                    self.injected["corrupt"] += 1
                    res = dataclasses.replace(
                        res, tokens=np.zeros_like(res.tokens),
                        detections=None, time_ms=float("nan"))
            out.append(res)
        return out

    def profile_row(self) -> Dict[str, object]:
        row = dict(self.inner.profile_row())
        row["faults"] = [f.kind for f in self.faults]
        return row

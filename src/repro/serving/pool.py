"""Backend pool + ECORE routing for the TPU serving framework.

This is the production-framework face of the paper: the 'heterogeneous edge
pool' becomes a pool of (architecture x mesh-slice) serving backends whose
profiles come from the compiled dry-run roofline (latency = max of the three
terms, energy = term-weighted chip power).  Request 'complexity' is the
prompt-length bucket (the LLM analog of the paper's object count — see
DESIGN.md §2b), and the same Algorithm 1 greedy router picks the cheapest
backend within the accuracy tolerance.

Accuracy proxy: in lieu of task accuracy for hypothetical deployments, each
backend carries a capability score derived from log10(active params) scaled
to a 0..100 'mAP-like' range, attenuated for prompt buckets beyond the
backend's efficient context (sub-quadratic archs keep their score at long
context; full-attention archs pay a latency/energy penalty instead).  The
scores parameterize the SAME trade-off structure the paper's testbed has:
no backend dominates every bucket.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.profiles import ProfileEntry, ProfileTable
from repro.core.router import feasible_set, route_batch

# prompt-length buckets = the serving "object count groups"
LENGTH_BUCKETS = ((0, 512, 0), (513, 2048, 1), (2049, 8192, 2),
                  (8193, 32768, 3), (32769, None, 4))


def bucket_of(prompt_len: int) -> int:
    for lo, hi, label in LENGTH_BUCKETS:
        if prompt_len >= lo and (hi is None or prompt_len <= hi):
            return label
    return LENGTH_BUCKETS[-1][2]


#: quality saturation per bucket: short prompts are EASY — a 1B model ties a
#: 34B one (the paper's Fig. 2 crossover, transplanted to serving); long
#: prompts discriminate by capacity.
_BUCKET_CAP = {0: 72.0, 1: 78.0, 2: 84.0, 3: 92.0, 4: 99.0}


def capability_score(params_active: int, subquadratic: bool,
                     bucket: int) -> float:
    """0..100 'accuracy' proxy: larger active models score higher, but each
    complexity bucket saturates (easy requests don't reward capacity); very
    long prompts favor architectures that handle them natively."""
    base = 20.0 * math.log10(max(params_active, 1) / 1e8 + 1.0) + 40.0
    if bucket >= 4 and not subquadratic:
        base -= 6.0  # degraded effective quality at extreme context
    return min(base, _BUCKET_CAP.get(bucket, 99.0))


def pool_table_from_dryrun(dryrun_jsonl: str,
                           shapes: Sequence[str] = ("prefill_32k",),
                           mesh: str = "16x16") -> ProfileTable:
    """Build a routing ProfileTable from dry-run roofline rows."""
    from repro.configs import get_config

    rows = [json.loads(l) for l in open(dryrun_jsonl)]
    entries: List[ProfileEntry] = []
    for r in rows:
        if r.get("status") != "ok" or r["mesh"] != mesh:
            continue
        if r["shape"] not in shapes:
            continue
        cfg = get_config(r["arch"])
        n_req = {"prefill_32k": 32, "decode_32k": 128, "long_500k": 1,
                 "train_4k": 256}[r["shape"]]
        time_ms = r["t_step_s"] * 1e3 / n_req
        energy_mwh = r["energy_j"] / 3.6 / n_req
        for _, _, bucket in LENGTH_BUCKETS:
            entries.append(ProfileEntry(
                model=r["arch"], device=f"pod-{mesh}", group=bucket,
                map_pct=capability_score(r["params_active"],
                                         cfg.is_subquadratic, bucket),
                time_ms=time_ms, energy_mwh=energy_mwh))
    return ProfileTable(entries)


@dataclasses.dataclass
class PoolDecision:
    arch: str
    bucket: int
    time_ms: float
    energy_mwh: float
    score: float
    device: str = "pod"   # mesh slice the profile row belongs to


class ServingPool:
    """ECORE gateway over dry-run-profiled serving backends."""

    def __init__(self, table: ProfileTable, delta: float = 5.0):
        self.table = table
        self.delta = delta

    def route(self, prompt_len: int) -> PoolDecision:
        bucket = bucket_of(prompt_len)
        # buckets ARE the profile groups here — the shared Algorithm-1
        # feasible set applies directly, then the greedy argmin-energy pick
        feasible = feasible_set(bucket, self.table, self.delta)
        e = min(feasible, key=lambda e: e.energy_mwh)
        return PoolDecision(arch=e.model, bucket=bucket, time_ms=e.time_ms,
                            energy_mwh=e.energy_mwh, score=e.map_pct,
                            device=e.device)

    def route_batch(self, prompt_lens: Sequence[int]) -> List[PoolDecision]:
        """Route a whole batch of requests in ONE XLA call: the tensorized
        Algorithm 1 over the length buckets (which are the profile groups),
        decision-for-decision identical to per-request ``route``."""
        idx = route_batch(prompt_lens, self.table, self.delta,
                          group_rules=LENGTH_BUCKETS)
        out = []
        for i in idx:
            e = self.table.entries[i]
            out.append(PoolDecision(arch=e.model, bucket=e.group,
                                    time_ms=e.time_ms,
                                    energy_mwh=e.energy_mwh,
                                    score=e.map_pct, device=e.device))
        return out

    def observe(self, arch: str, *, time_ms: Optional[float] = None,
                energy_mwh: Optional[float] = None,
                map_pct: Optional[float] = None,
                bucket: Optional[int] = None,
                alpha: float = 0.1) -> None:
        """Closed loop: EWMA-fold measured serving signals back into the
        profile.  Latency/energy touch every device/mesh row of ``arch``,
        all buckets (they are bucket-independent in the dry-run profile,
        like the paper's per-group replication).  A measured QUALITY signal
        (``map_pct``) is bucket-specific — pass the ``bucket`` it was
        measured on and only that row moves."""
        if map_pct is not None and bucket is None:
            raise ValueError(
                "map_pct is per-bucket: pass bucket= with the measurement")
        matched = False
        for pair in self.table.pairs():
            if pair[0] == arch:
                if time_ms is not None or energy_mwh is not None:
                    self.table.observe_pair(pair, time_ms=time_ms,
                                            energy_mwh=energy_mwh,
                                            alpha=alpha)
                if map_pct is not None:
                    self.table.observe(pair, bucket, map_pct=map_pct,
                                       alpha=alpha)
                matched = True
        if not matched:
            raise KeyError(arch)

"""Resilience plane: deadline, bounded retry, and hedged re-dispatch.

``EcoreService`` is exactly as reliable as its backends: a thrown batch
fails every co-batched future and that is the end of the story.  On an
edge fleet that story is wrong — devices drop off, stall, and return
garbage (``serving/faults.py`` injects all three deterministically) — so
``ResilientService`` wraps the dispatch plane with the three standard
recovery moves, each grounded in what the router already knows:

  * **deadline**   — a completed request whose modeled ``time_ms`` exceeds
                     ``RetryPolicy.deadline_ms`` is a MISS, not a success:
                     late answers count as failures (the paper's real-time
                     detection setting) and are retried elsewhere
  * **retry**      — failed attempts re-dispatch up to ``max_retries``
                     times with exponential backoff + deterministic
                     per-(uid, attempt) jitter, scheduled on the service's
                     INJECTABLE clock (the retrier thread mirrors the
                     flusher's condition-wait idiom — no wall-clock sleeps,
                     so fake-clock tests stay instant and deterministic)
  * **hedging**    — a retry does not hammer the pair that just failed: it
                     re-routes to the RUNNER-UP feasible pair of the
                     request's group under Algorithm-1's masked ranking
                     (``runner_up_route``: the cheapest remaining pair
                     whose mAP clears the same ``delta`` threshold),
                     excluding every pair that already failed this request

The scalar-path analog of the scanned closed loop's quarantine breaker:
there, ``quarantine_after`` consecutive inf-sentinel steps exclude a
(group, pair) cell from ``decide_state``'s mask; here, a failed attempt
excludes the pair from ITS OWN retries immediately.  Both consult the same
Algorithm-1 ranking for the fallback, so a hedged request lands exactly
where the jitted router would have sent it had the profile already known.

Lock discipline: the wrapper NEVER calls into the inner service while
holding its own condition.  Inner futures resolve under the inner service
lock and their done-callbacks need ours, so holding ours across an inner
call is an ABBA deadlock with the flusher thread.  Every dispatch happens
outside the lock; the lock only guards bookkeeping.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import Future
from typing import Callable, Dict, FrozenSet, List, Optional, Sequence, Set

import numpy as np

from repro.core.policy import (Observation, RouteDecision, RouteRequest,
                               RoutingPolicy)
from repro.core.router import runner_up_route
from repro.serving.service import EcoreService, Served, ServiceClosed


class DeadlineExceeded(RuntimeError):
    """The request completed but too late (modeled ``time_ms`` over the
    deadline), or its retry budget ran out of wall-clock deadline."""

    def __init__(self, uid: int, time_ms: float, deadline_ms: float):
        super().__init__(f"request uid {uid}: {time_ms:.1f} ms exceeds "
                         f"the {deadline_ms:.1f} ms deadline")
        self.uid = uid
        self.time_ms = time_ms
        self.deadline_ms = deadline_ms


class CorruptResult(RuntimeError):
    """The backend answered, but the result fails validation (NaN modeled
    time — the fault plane's corruption marker)."""

    def __init__(self, uid: int, backend: str):
        super().__init__(f"request uid {uid}: corrupt result from "
                         f"{backend!r} (non-finite time_ms)")
        self.uid = uid
        self.backend = backend


class RetriesExhausted(RuntimeError):
    """Every attempt failed; ``__cause__`` carries the last failure."""

    def __init__(self, uid: int, attempts: int, last: BaseException):
        super().__init__(f"request uid {uid} failed after {attempts} "
                         f"attempts: {last}")
        self.uid = uid
        self.attempts = attempts


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """How hard to try: deadline, retry budget, backoff shape, hedging."""
    deadline_ms: Optional[float] = None  # modeled per-request deadline
    max_retries: int = 2                 # re-dispatches after the 1st try
    backoff_ms: float = 10.0             # first retry delay
    backoff_mult: float = 2.0            # exponential growth per attempt
    jitter: float = 0.5                  # +[0, jitter) fraction, per (uid,
    #                                      attempt) hash — deterministic
    hedge: bool = True                   # re-route retries to the runner-up

    def delay_s(self, uid: int, attempt: int) -> float:
        """Backoff before retry ``attempt`` (1-based), jittered by the same
        splitmix32 hash the fault/shard planes use so two runs of the same
        workload retry at identical (fake-)clock times."""
        from repro.serving.cluster import _mix32  # lazy: no import cycle
        base = self.backoff_ms * self.backoff_mult ** (attempt - 1)
        h = _mix32(np.asarray([uid], np.uint32) ^ np.uint32(attempt), np)
        u = int(h[0]) / 4294967296.0
        return base * (1.0 + self.jitter * u) / 1e3


@dataclasses.dataclass
class _Attempt:
    """Bookkeeping for one in-flight request across its attempts."""
    req: RouteRequest
    decision: RouteDecision
    future: "Future[Served]"
    t_first: float                       # injectable-clock submit time
    attempts: int = 1
    excluded: Set = dataclasses.field(default_factory=set)
    due: float = 0.0                     # retry-due time when queued


#: reroute hook: (request, failed decision, excluded pairs) -> decision or
#: None (None = retry the original pair; covers transient faults)
RerouteFn = Callable[[RouteRequest, RouteDecision, FrozenSet],
                     Optional[RouteDecision]]


class ResilientService:
    """``EcoreService`` + deadline/retry/hedging.  Same surface (``submit``
    -> ``Future[Served]``, ``observe``, ``drain``, ``close``), but a
    returned future only fails after the whole recovery budget is spent."""

    RETRY_TICK_S = 0.05  # real-time safety tick (mirrors FLUSH_TICK_S)

    def __init__(self, policy: RoutingPolicy,
                 backend_factory: Callable[[RouteDecision], object], *,
                 retry: RetryPolicy = RetryPolicy(),
                 max_wait_ms: Optional[float] = None,
                 clock: Callable[[], float] = time.monotonic,
                 reroute: Optional[RerouteFn] = None):
        self.policy = policy
        self.retry = retry
        self._clock = clock
        self._reroute = reroute if reroute is not None else self._runner_up
        # futures are the wrapper's only consumption plane: the inner
        # service must not buffer errors (they would double-report at
        # close) nor retain results
        self._inner = EcoreService(policy, backend_factory,
                                   max_wait_ms=max_wait_ms, clock=clock,
                                   retain_results=False, buffer_errors=False)
        self._cond = threading.Condition()
        self._recs: Dict[int, _Attempt] = {}   # uid -> live request
        self._pending: List[_Attempt] = []     # subset waiting out backoff
        self._closed = False
        self.retries = 0
        self.hedges = 0
        self.deadline_misses = 0
        self.completed = 0
        self.failed = 0
        self._retrier = threading.Thread(target=self._retry_loop,
                                         name="ecore-retrier", daemon=True)
        self._retrier.start()

    # ------------------------------------------------------------- submit

    def submit(self, req: RouteRequest) -> "Future[Served]":
        with self._cond:
            self._ensure_open()
            decision = self.policy.decide(req)
            rec = _Attempt(req=req, decision=decision, future=Future(),
                           t_first=self._clock())
            self._recs[req.uid] = rec
        self._dispatch(rec, decision)   # outside the lock (lock discipline)
        return rec.future

    def submit_batch(self, reqs: Sequence[RouteRequest]
                     ) -> List["Future[Served]"]:
        """Route the workload in one ``decide_batch`` call; every request
        still recovers independently."""
        reqs = list(reqs)
        with self._cond:
            self._ensure_open()
            decisions = self.policy.decide_batch(reqs)
            recs = []
            for req, decision in zip(reqs, decisions):
                rec = _Attempt(req=req, decision=decision, future=Future(),
                               t_first=self._clock())
                self._recs[req.uid] = rec
                recs.append(rec)
        for rec in recs:
            self._dispatch(rec, rec.decision)
        return [rec.future for rec in recs]

    def observe(self, obs: Observation) -> None:
        self._inner.observe(obs)

    # -------------------------------------------------------------- pump

    def drain(self) -> None:
        """Dispatch every backoff-pending retry NOW (drain means finish,
        not wait out timers), flush the inner service, and repeat until
        every outer future is resolved.  Terminates because attempts per
        request are bounded by ``max_retries``."""
        while True:
            with self._cond:
                due, self._pending = list(self._pending), []
            for rec in due:
                self._redispatch(rec)
            try:
                self._inner.drain()
            # repro-lint: disable=ECO303 -- not dropped: the inner drain
            # re-raises a batch error whose failed futures ALREADY ran
            # _on_done (rescheduling or failing each request); the outer
            # futures carry the outcome, and drain must keep pumping
            except Exception:
                pass
            with self._cond:
                if not self._pending and not self._recs:
                    return

    def close(self) -> None:
        """Finish what can finish (one full drain), then stop the retrier,
        close the inner service, and fail anything left with
        ``ServiceClosed``.  Idempotent."""
        with self._cond:
            if self._closed:
                return
        self.drain()
        with self._cond:
            self._closed = True
            leftovers = list(self._recs.values())
            self._recs.clear()
            self._pending.clear()
            self._cond.notify_all()
        self._retrier.join(timeout=5.0)
        self._inner.close()
        for rec in leftovers:
            rec.future.set_exception(ServiceClosed(
                f"ResilientService closed with request uid "
                f"{rec.req.uid} unresolved"))

    def __enter__(self) -> "ResilientService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def wake(self) -> None:
        """Fake-clock tests: re-check retry timers and flush deadlines."""
        with self._cond:
            self._cond.notify_all()
        self._inner.wake()

    def stats(self) -> Dict:
        with self._cond:
            out = {"retries": self.retries, "hedges": self.hedges,
                   "deadline_misses": self.deadline_misses,
                   "completed": self.completed, "failed": self.failed,
                   "pending": len(self._recs)}
        out["inner"] = self._inner.stats()
        return out

    # ---------------------------------------------------------- internals

    def _ensure_open(self) -> None:
        if self._closed:
            raise ServiceClosed("ResilientService is closed")

    def _runner_up(self, req: RouteRequest, decision: RouteDecision,
                   excluded: FrozenSet) -> Optional[RouteDecision]:
        """Default hedge: Algorithm-1's runner-up feasible pair for the
        request's group, profile-ranked, minus every pair that already
        failed this request.  Needs a table-backed policy (the detection
        face); None otherwise — the retry then re-tries the same pair."""
        table = getattr(self.policy, "table", None)
        router = getattr(self.policy, "router", None)
        if table is None or router is None or not excluded:
            return None
        count = decision.est_complexity
        if count is None:
            count = req.true_complexity
        if count is None:
            return None
        entry = runner_up_route(int(count), table, router.delta,
                                exclude=excluded,
                                group_rules=self.policy.rules)
        if entry is None:
            return None
        return RouteDecision(
            uid=req.uid, pair=entry.pair, group=entry.group,
            est_complexity=decision.est_complexity,
            time_ms=entry.time_ms, energy_mwh=entry.energy_mwh,
            score=entry.map_pct)

    def _dispatch(self, rec: _Attempt, decision: RouteDecision) -> None:
        """One attempt.  MUST be called without holding ``self._cond``."""
        try:
            cfut = self._inner.submit_batch([rec.req],
                                            decisions=[decision])[0]
        except Exception as exc:
            # inline full-batch flush blew up during submit: the inner
            # future (never returned) already carries the error; recover
            # through the same path as a callback failure
            self._attempt_failed(rec, exc)
            return
        cfut.add_done_callback(lambda f, r=rec: self._on_done(r, f))

    def _on_done(self, rec: _Attempt, cfut: "Future[Served]") -> None:
        # runs wherever the inner future resolves: flusher thread, a
        # submitting thread's inline flush, or drain/close
        exc = cfut.exception()
        if exc is not None:
            self._attempt_failed(rec, exc)
            return
        served = cfut.result()
        failure = self._validate(served)
        if failure is not None:
            self._attempt_failed(rec, failure)
            return
        with self._cond:
            self._recs.pop(rec.req.uid, None)
            self.completed += 1
            self._cond.notify_all()
        rec.future.set_result(served)

    def _validate(self, served: Served) -> Optional[Exception]:
        t_ms = served.result.time_ms
        if t_ms is not None and not np.isfinite(t_ms):
            return CorruptResult(served.request.uid, served.result.backend)
        dl = self.retry.deadline_ms
        if dl is not None and t_ms is not None and t_ms > dl:
            return DeadlineExceeded(served.request.uid, t_ms, dl)
        return None

    def _attempt_failed(self, rec: _Attempt, failure: Exception) -> None:
        fail_outer: Optional[Exception] = None
        with self._cond:
            if rec.req.uid not in self._recs:
                return      # already resolved (close raced a late callback)
            if isinstance(failure, DeadlineExceeded):
                self.deadline_misses += 1
            budget_left = rec.attempts <= self.retry.max_retries
            dl = self.retry.deadline_ms
            # wall-clock deadline check at retry SCHEDULING: no point
            # re-dispatching a request whose deadline already passed on
            # the (injectable) clock
            if (dl is not None and budget_left
                    and (self._clock() - rec.t_first) * 1e3 > dl):
                budget_left = False
                failure = DeadlineExceeded(
                    rec.req.uid, (self._clock() - rec.t_first) * 1e3, dl)
            if not budget_left or self._closed:
                self._recs.pop(rec.req.uid, None)
                self.failed += 1
                fail_outer = RetriesExhausted(rec.req.uid, rec.attempts,
                                              failure)
                fail_outer.__cause__ = failure
            else:
                if self.retry.hedge:
                    rec.excluded.add(rec.decision.pair)
                rec.due = (self._clock()
                           + self.retry.delay_s(rec.req.uid, rec.attempts))
                rec.attempts += 1
                self._pending.append(rec)
            self._cond.notify_all()
        if fail_outer is not None:
            rec.future.set_exception(fail_outer)

    def _redispatch(self, rec: _Attempt) -> None:
        """Retry one request: hedge to the runner-up pair when enabled and
        one exists, else the original pair.  Called without the lock."""
        decision = None
        if self.retry.hedge:
            decision = self._reroute(rec.req, rec.decision,
                                     frozenset(rec.excluded))
        hedged = decision is not None and decision.pair != rec.decision.pair
        if decision is None:
            decision = rec.decision
        with self._cond:
            if rec.req.uid not in self._recs:
                return
            rec.decision = decision
            self.retries += 1
            if hedged:
                self.hedges += 1
        self._dispatch(rec, decision)

    def _retry_loop(self) -> None:
        # the flusher idiom: condition-wait until the earliest retry is
        # due on the injectable clock (or a wake), dispatch OUTSIDE the
        # lock, repeat — never a wall-clock sleep
        while True:
            with self._cond:
                if self._closed:
                    return
                if not self._pending:
                    self._cond.wait()
                    continue
                now = self._clock()
                due = [r for r in self._pending if r.due <= now]
                if not due:
                    wait_s = min(r.due for r in self._pending) - now
                    self._cond.wait(min(wait_s, self.RETRY_TICK_S))
                    continue
                for r in due:
                    self._pending.remove(r)
            for rec in due:
                self._redispatch(rec)

"""EcoreService: ONE request-centric serving surface over any RoutingPolicy.

Maps the paper's Fig. 3 pipeline onto four typed stages:

  estimate + route  ``RoutingPolicy.decide`` / ``decide_batch`` turn a
                    ``RouteRequest`` (frame or prompt + complexity signal)
                    into a ``RouteDecision`` (the (model, device) pair, the
                    group it was routed under, profiled costs);
  dispatch          the service owns one ``DispatchQueue`` per routed
                    (model, device) pair and lazily builds backends through
                    ``backend_factory`` —
                    ``submit`` enqueues and returns a ``Future[Served]``
                    that resolves when the request's batch flushes;
  observe           ``observe(Observation)`` is the single feedback plane:
                    measured latency/energy/quality EWMA-fold into the
                    policy's profile (the ``ProfileState``-backed table
                    facade), closing the routing loop.  The scanned closed
                    loop folds its observations inside ``decide_scan``
                    instead and hands ``submit_batch`` pre-routed decisions.

Flushing is genuinely async: a background flusher thread watches the oldest
pending request of every queue and serves a PARTIAL batch the moment its
``max_wait_ms`` deadline expires — no cooperative ``poll()`` calls from the
driver, ever.  The clock is injectable: deterministic tests drive a manual
clock and call ``wake()`` after advancing it (the flusher also re-checks on
a small real-time tick, so a forgotten ``wake`` degrades to polling rather
than deadlocking).

``serve_batch`` runs under the service lock, so decisions, flushes and
observations are serialized — batching, not intra-service parallelism, is
the throughput lever (matching the paper's one-batch-at-a-time Locust loop).
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from concurrent.futures import Future
from typing import Callable, Deque, Dict, List, Optional, Sequence, Tuple

from repro.core.policy import (Observation, RouteDecision, RouteRequest,
                               RoutingPolicy)
from repro.serving.engine import DispatchQueue, Request, Result


@dataclasses.dataclass
class Served:
    """One completed request: what was asked, where it went, what came back."""
    request: RouteRequest
    decision: RouteDecision
    result: Result


class ServiceClosed(RuntimeError):
    """The service was closed: raised by ``submit``/``submit_batch`` after
    ``close()``, and set on any future still pending when ``close()``
    finishes flushing — a structured terminal error callers can
    distinguish from a backend failure (nothing is retryable here)."""


class EcoreService:
    """Request-centric serving: ``submit -> Future``, ``results``,
    ``drain``, ``close``, with deadline-bounded threaded flushing."""

    #: real-time re-check tick for the flusher (safety net under fake clocks
    #: and the wake granularity under the real one)
    FLUSH_TICK_S = 0.05

    def __init__(self, policy: RoutingPolicy,
                 backend_factory: Callable[[RouteDecision], object], *,
                 max_wait_ms: Optional[float] = None,
                 clock: Callable[[], float] = time.monotonic,
                 retain_results: bool = True,
                 buffer_errors: bool = True,
                 flusher: bool = True):
        self.policy = policy
        self.max_wait_ms = max_wait_ms
        self._factory = backend_factory
        self._clock = clock
        # completions are buffered for results()/drain(); a driver that only
        # consumes futures should pass retain_results=False so a long-lived
        # service doesn't grow per-request state
        self._retain = retain_results
        # flusher-thread backend errors re-raise at drain()/close() so a
        # results()-driven driver can't lose a batch silently; a driver whose
        # ONLY consumption plane is futures (AsyncEcoreService) passes
        # buffer_errors=False — the futures already carry every error, and
        # re-raising at close would double-report it
        self._buffer_errors = buffer_errors
        self._cond = threading.Condition()
        #: one queue per ROUTED PAIR — the same model on two devices/meshes
        #: must not collapse onto one backend
        self._queues: Dict[Tuple[str, str], DispatchQueue] = {}
        #: uid -> (request, decision, future, submit_time, queue key)
        self._inflight: Dict[int, Tuple[RouteRequest, RouteDecision,
                                        Future, float, Tuple[str, str]]] = {}
        self._completed: List[Served] = []
        # bounded: a long-lived service must not grow per-request state.
        # Two separate planes per request: queue_wait (submit -> its flush
        # TRIGGERED: deadline expiry / batch full / drain — bounded by
        # max_wait_ms under a healthy flusher) and service (trigger ->
        # completion: lock wait behind other serves + the serve itself).
        # Folding the second into the first made p95 "queue wait" report
        # seconds of jit-compile head-of-line blocking against a 25 ms
        # deadline.
        self._queue_wait_ms: Deque[float] = collections.deque(maxlen=4096)
        self._service_ms: Deque[float] = collections.deque(maxlen=4096)
        # backend errors caught in the flusher thread: futures carry them,
        # but results()-driven drivers never look — re-raised at
        # drain()/close() so a lost batch cannot pass silently
        self._errors: Deque[Exception] = collections.deque(maxlen=16)
        self.flusher_passes = 0     # loop iterations (test observability)
        self._closed = False
        self._flusher: Optional[threading.Thread] = None
        # flusher=False keeps deadline semantics but hands WHEN to the
        # caller: a virtual-time driver (repro.traffic.LoadDriver) advances
        # its clock to next_deadline() and calls flush_due() itself, so
        # batch composition is a pure function of the workload
        if max_wait_ms is not None and flusher:
            self._flusher = threading.Thread(target=self._flush_loop,
                                             name="ecore-flusher",
                                             daemon=True)
            self._flusher.start()

    # ------------------------------------------------------------ submit

    def submit(self, req: RouteRequest) -> "Future[Served]":
        """Route one request and enqueue it on its backend's dispatch queue.
        The returned future resolves to a ``Served`` when the batch flushes
        (full batch, deadline expiry, ``drain`` or ``close``)."""
        with self._cond:
            self._ensure_open()
            fut = self._enqueue(req, self.policy.decide(req))
            self._cond.notify_all()   # new deadline for the flusher
            return fut

    def submit_batch(self, reqs: Sequence[RouteRequest],
                     decisions: Optional[Sequence[RouteDecision]] = None
                     ) -> List["Future[Served]"]:
        """Route a whole workload in one ``decide_batch`` call (one XLA
        launch for batchable policies) and enqueue every request.

        ``decisions`` (optional, one per request) enqueues PRE-ROUTED
        requests instead: the scanned closed loop decides — and folds its
        observations — inside one jitted ``lax.scan``
        (``DetectionPolicy.decide_scan``), so the service must dispatch
        exactly those decisions rather than re-deciding against the
        already-updated profile."""
        with self._cond:
            self._ensure_open()
            if decisions is None:
                decisions = self.policy.decide_batch(list(reqs))
            elif len(decisions) != len(reqs):
                raise ValueError(
                    f"{len(decisions)} decisions for {len(reqs)} requests")
            futs = [self._enqueue(r, d) for r, d in zip(reqs, decisions)]
            self._cond.notify_all()
            return futs

    def observe(self, obs: Observation) -> None:
        """The single feedback plane: fold measured signals into the
        policy's profile (next decisions see them immediately)."""
        with self._cond:
            self.policy.observe(obs)

    # ------------------------------------------------------------ results

    def results(self) -> List[Served]:
        """Completed requests since the last ``results``/``drain`` call."""
        with self._cond:
            out, self._completed = self._completed, []
            return out

    def drain(self) -> List[Served]:
        """Flush every pending partial batch and return all unconsumed
        completions.  Raises the first backend error the flusher thread
        swallowed since the last drain — a results()-driven driver must not
        lose requests silently."""
        with self._cond:
            self._flush_all()
            if self._errors:
                raise self._errors.popleft()
            out, self._completed = self._completed, []
            return out

    def close(self) -> None:
        """Flush whatever is pending (no future is left dangling: results
        resolve, backend errors become future exceptions, anything still
        unresolved fails with ``ServiceClosed``), stop the flusher thread,
        then re-raise the first flush error.  Idempotent; completions
        remain readable via ``results()``."""
        exc = None
        with self._cond:
            if self._closed:
                return
            try:
                self._flush_all()
            except Exception as e:
                exc = e
            if exc is None and self._errors:
                exc = self._errors.popleft()
            # the flush resolved or failed every normal future; whatever is
            # STILL pending (a backend that returned a partial batch, a
            # cancelled flush) must not dangle past close
            for uid, (_, _, fut, _, _) in list(self._inflight.items()):
                del self._inflight[uid]
                fut.set_exception(ServiceClosed(
                    f"EcoreService closed with request uid {uid} unserved"))
            self._closed = True
            self._cond.notify_all()
        if self._flusher is not None:
            self._flusher.join(timeout=5.0)
        if exc is not None:
            raise exc

    def __enter__(self) -> "EcoreService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def wake(self) -> None:
        """Make the flusher re-check deadlines now (fake-clock tests call
        this after advancing their clock)."""
        with self._cond:
            self._cond.notify_all()

    def next_deadline(self) -> Optional[float]:
        """Earliest pending ``max_wait_ms`` expiry across all queues, or
        None when nothing is batched (or no deadline is configured).  A
        virtual-time driver advances its clock here, then ``flush_due``."""
        with self._cond:
            deadlines = [d for q in self._queues.values()
                         if (d := q.next_deadline()) is not None]
            return min(deadlines) if deadlines else None

    def flush_due(self, now: Optional[float] = None) -> int:
        """Flush every queue whose deadline has expired by ``now``
        (default: the injected clock) — the flusher thread's one pass,
        callable synchronously.  Returns the number of queues flushed;
        backend errors follow the same plane as the thread (buffered for
        drain()/close() when ``buffer_errors``, and the batch's futures
        always carry them)."""
        with self._cond:
            return self._flush_due_locked(self._clock() if now is None
                                          else now)

    @property
    def pending_requests(self) -> int:
        """Requests enqueued but not yet flushed (cluster drain uses this
        to decide whether resubmitted work still needs another pass)."""
        with self._cond:
            return sum(len(q.pending) for q in self._queues.values())

    @property
    def deadline_flushes(self) -> int:
        """Partial batches served because a deadline expired — counted on
        the queues, so inline (submit-path) and flusher-thread deadline
        flushes both register."""
        return sum(q.deadline_flushes for q in self._queues.values())

    def stats(self) -> Dict:
        with self._cond:
            return {
                "backends": len(self._queues),
                "serve_calls": sum(q.calls for q in self._queues.values()),
                "served": sum(q.served for q in self._queues.values()),
                "deadline_flushes": self.deadline_flushes,
                "queue_wait_ms": list(self._queue_wait_ms),
                "service_ms": list(self._service_ms),
            }

    # ----------------------------------------------------------- internals

    def _ensure_open(self) -> None:
        if self._closed:
            raise ServiceClosed("EcoreService is closed")

    def _enqueue(self, req: RouteRequest,
                 decision: RouteDecision) -> "Future[Served]":
        if req.uid in self._inflight:
            raise ValueError(f"request uid {req.uid} is already in flight")
        key = decision.pair
        q = self._queues.get(key)
        if q is None:
            q = DispatchQueue(self._factory(decision),
                              max_wait_ms=self.max_wait_ms,
                              clock=self._clock)
            self._queues[key] = q
        fut: "Future[Served]" = Future()
        self._inflight[req.uid] = (req, decision, fut, self._clock(), key)
        self._dispatch(key, q, lambda: q.submit(
            Request(uid=req.uid, prompt=req.payload,
                    max_new_tokens=req.max_new_tokens,
                    group=decision.group)))
        return fut

    def _dispatch(self, key: Tuple[str, str], q: DispatchQueue, fn,
                  t_trigger: Optional[float] = None) -> None:
        """Run one queue operation that may serve a batch.  ``t_trigger``
        is the moment the flush became DUE (deadline expiry, drain entry;
        defaults to now for inline full-batch flushes) — queue wait ends
        there, everything after is service time.  A backend error must not
        kill the flusher thread or dangle futures: every inflight future of
        the failing backend gets the exception (the flushed batch was
        already popped, and any same-flush sub-batch results are lost with
        it), then the error propagates to a direct caller."""
        if t_trigger is None:
            t_trigger = self._clock()
        try:
            self._complete(fn(), t_trigger)
        except Exception as exc:
            for uid, (_, _, fut, _, k) in list(self._inflight.items()):
                if k == key:
                    del self._inflight[uid]
                    fut.set_exception(exc)
            raise

    def _complete(self, results: List[Result],
                  t_trigger: Optional[float] = None) -> None:
        t_done = self._clock()
        if t_trigger is None:
            t_trigger = t_done
        for res in results:
            req, decision, fut, t_submit, _ = self._inflight.pop(res.uid)
            # time spent QUEUED for batching vs time being SERVED (incl.
            # waiting behind other flushes under the service lock)
            self._queue_wait_ms.append(max(t_trigger - t_submit, 0.0) * 1e3)
            self._service_ms.append((t_done - t_trigger) * 1e3)
            served = Served(request=req, decision=decision, result=res)
            if self._retain:
                self._completed.append(served)
            fut.set_result(served)

    def _flush_all(self) -> None:
        first_exc = None
        # one trigger stamp for the whole drain: queues flushed later must
        # not book earlier queues' serve time as their own queue wait
        t_trigger = self._clock()
        for key, q in self._queues.items():
            try:
                self._dispatch(key, q, q.flush, t_trigger=t_trigger)
            except Exception as exc:  # futures already carry it; drain the
                first_exc = first_exc or exc        # healthy queues anyway
        if first_exc is not None:
            raise first_exc

    def _flush_loop(self) -> None:
        with self._cond:
            while not self._closed:
                self.flusher_passes += 1
                deadlines = [d for q in self._queues.values()
                             if (d := q.next_deadline()) is not None]
                if not deadlines:
                    # idle: submit()/close() notify, so no timed tick needed
                    self._cond.wait()
                    continue
                wait_s = min(deadlines) - self._clock()
                if wait_s > 0:
                    self._cond.wait(min(wait_s, self.FLUSH_TICK_S))
                    continue
                self._flush_due_locked(self._clock())

    def _flush_due_locked(self, now: float) -> int:
        """Flush queues whose deadline expired by ``now``; caller holds
        ``_cond``.  Shared by the flusher thread and ``flush_due``."""
        flushed = 0
        for key, q in list(self._queues.items()):
            nd = q.next_deadline()
            if nd is not None and nd <= now:
                q.deadline_flushes += 1
                flushed += 1
                try:
                    # wait ended when the deadline EXPIRED, not when
                    # the flush got the lock
                    self._dispatch(key, q, q.flush, t_trigger=nd)
                except Exception as exc:
                    # futures carry the backend error and drain()/
                    # close() re-raise it; flushing must survive
                    # to serve the other queues
                    if self._buffer_errors:
                        self._errors.append(exc)
        return flushed

"""Trace-time activation-sharding hints.

GSPMD occasionally prefers propagating a *weight* sharding into activations
(e.g. the FSDP-sharded embedding table's d_model axis), silently replicating
the batch dim across the mesh.  Model code calls ``constrain_batch`` at block
boundaries; the launcher activates the hints for the duration of tracing via
``activation_sharding(batch_axes)``.  Outside that context (CPU tests,
single-device runs) the calls are no-ops.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

_batch_axes: contextvars.ContextVar[Optional[Tuple[str, ...]]] = \
    contextvars.ContextVar("repro_batch_axes", default=None)


@contextlib.contextmanager
def activation_sharding(batch_axes: Tuple[str, ...], n_shards: int,
                        mesh=None, mode: str = "train"):
    token = _batch_axes.set((tuple(batch_axes), n_shards, mesh, mode))
    try:
        yield
    finally:
        _batch_axes.reset(token)


def batch_axes() -> Optional[Tuple[str, ...]]:
    v = _batch_axes.get()
    return v[0] if v else None


def current_mesh():
    v = _batch_axes.get()
    return v[2] if v else None


def current_mode() -> str:
    v = _batch_axes.get()
    return v[3] if v and len(v) > 3 else "train"


def constrain_batch(x):
    """Pin dim0 of ``x`` to the batch mesh axes (no-op outside the context
    or when the dim does not divide)."""
    v = _batch_axes.get()
    if not v or x.ndim == 0:
        return x
    axes, n = v[0], v[1]
    if x.shape[0] % n != 0:
        return x
    return jax.lax.with_sharding_constraint(
        x, P(axes, *([None] * (x.ndim - 1))))

"""PartitionSpec rules for params, optimizer state, inputs, and caches.

Baseline scheme (MaxText-style 2-D):
  * 'data'  axis = batch parallelism AND FSDP shard axis for training params
  * 'model' axis = tensor parallelism (heads / ff / vocab / experts-ff)
  * 'pod'   axis = pure data parallelism across pods (params replicated)

For serving (``mode='serve'``) the FSDP axis is dropped: params are
replicated over 'data' and sharded over 'model' only, so decode steps incur
no per-step parameter all-gathers.  The §Perf hillclimb iterates on these
choices; this module is the paper-faithful baseline.
"""
from __future__ import annotations

import re
from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# (regex on param path, spec for the *unstacked* param)
_RULES: Tuple[Tuple[str, Tuple], ...] = (
    # embeddings / unembedding
    (r"embed/table$", ("model", "data")),
    (r"(vision_proj|frame_proj)$", (None, "data")),
    # attention
    (r"attn/w[qkv]$", ("data", "model")),
    (r"attn/wo$", ("model", "data")),
    (r"attn/b[qkv]$", ("model",)),
    (r"xattn/w[qkv]$", ("data", "model")),
    (r"xattn/wo$", ("model", "data")),
    # MLA
    (r"mla/wq$", ("data", "model")),
    (r"mla/w_dkv$", ("data", None)),
    (r"mla/w_kr$", ("data", None)),
    (r"mla/w_uk$", (None, "model")),
    (r"mla/w_uv$", (None, "model")),
    (r"mla/wo$", ("model", "data")),
    # MLP
    (r"mlp/w_(gate|up)$", ("data", "model")),
    (r"mlp/w_down$", ("model", "data")),
    (r"shared/w_(gate|up)$", ("data", "model")),
    (r"shared/w_down$", ("model", "data")),
    # MoE (experts stacked on dim 0; ff dim tensor-parallel)
    (r"moe/router$", ("data", None)),
    (r"moe/w_(gate|up)$", (None, "data", "model")),
    (r"moe/w_down$", (None, "model", "data")),
    # RG-LRU recurrent block
    (r"rec/w_(gate|x)$", ("data", "model")),
    (r"rec/w_out$", ("model", "data")),
    (r"rec/lru_w[ax]$", ("data", "model")),
    (r"rec/(lru_b[ax]|log_lambda|conv_b)$", ("model",)),
    (r"rec/conv_w$", (None, "model")),
    # Mamba2 SSD (baseline: data/fsdp sharding only; see §Perf for TP variant)
    (r"ssm/in_proj$", ("data", None)),
    (r"ssm/out_proj$", (None, "data")),
    (r"ssm/conv_w$", (None, None)),
)

_STACKED = re.compile(r"(^|/)(blocks|trailing|enc_blocks|dec_blocks)(/|$)")


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
    return "/".join(parts)


def spec_for_param(path_str: str, shape: Tuple[int, ...], *, mode: str,
                   mesh: Optional[Mesh] = None) -> P:
    ndim = len(shape)
    stacked = bool(_STACKED.search(path_str))
    base_ndim = ndim - 1 if stacked else ndim
    spec: Optional[Tuple] = None
    for pat, s in _RULES:
        if re.search(pat, path_str):
            spec = s
            break
    if spec is None or len(spec) != base_ndim:
        spec = (None,) * base_ndim  # norms, scalars, odd shapes: replicate
    if mode == "serve":  # drop FSDP axis
        spec = tuple(None if s == "data" else s for s in spec)
    if stacked:
        spec = (None,) + spec
    if mesh is not None:  # drop axes that do not divide the dim evenly
        spec = tuple(
            a if (a is None or (a in mesh.shape
                                and shape[i] % mesh.shape[a] == 0)) else None
            for i, a in enumerate(spec))
    return P(*spec)


def param_specs(params: Any, *, mode: str = "train",
                mesh: Optional[Mesh] = None) -> Any:
    """PartitionSpec pytree matching ``params``."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = [spec_for_param(_path_str(p), getattr(l, "shape", ()), mode=mode,
                            mesh=mesh)
             for p, l in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def batch_axes(mesh: Mesh):
    """The composite batch-sharding axis tuple for this mesh."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def batch_spec(mesh: Mesh, global_batch: int, ndim: int) -> P:
    """Shard dim 0 over (pod, data) when divisible, else replicate."""
    axes = batch_axes(mesh)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    if global_batch % n == 0:
        return P(axes, *([None] * (ndim - 1)))
    return P(*([None] * ndim))


def decode_cache_layout(num_kv_heads: int, seq: int, mesh: Mesh) -> str:
    """How attention K/V caches use the 'model' axis at decode time.

    'kv'   — kv_heads % model == 0: shard the KV-HEAD dim.  Attention and
             the per-token column write are fully shard-local (best).
    'seq'  — otherwise shard the SEQUENCE dim; attention runs as a
             shard_map flash-decode with an [B,H,D]-sized partial-softmax
             merge (§Perf); the column write crosses a sharded dim, which
             GSPMD lowers to a masked full-slice select (the residual cost
             visible in the roofline table).
    'none' — neither divides: replicate over 'model'.
    """
    if "model" not in mesh.axis_names:
        return "none"
    m = mesh.shape["model"]
    if num_kv_heads % m == 0:
        return "kv"
    if seq % m == 0:
        return "seq"
    return "none"


def cache_specs(cache: Any, mesh: Mesh, global_batch: int) -> Any:
    """Decode caches: batch over (pod, data); attention K/V use the 'model'
    axis per ``decode_cache_layout``; states/pos bookkeeping replicated."""
    mdl = "model" if "model" in mesh.axis_names else None

    def spec(path, leaf):
        nd = leaf.ndim
        ps = _path_str(path)
        if nd == 0 or ps.endswith("pos") or "pos_buf" in ps:
            return P(*([None] * nd))
        bspec = tuple(batch_spec(mesh, global_batch, nd - 1))
        if ps.startswith("cross_"):  # [L, B, T(1500: not 16-divisible), KV, hd]
            return P(None, *bspec)
        if (ps.endswith("/k") or ps.endswith("/v")) and nd == 5:
            # AttnCache k/v [n, B, W, KV, hd]
            layout = decode_cache_layout(leaf.shape[3], leaf.shape[2], mesh)
            if layout == "kv":
                return P(None, bspec[0], None, mdl, None)
            if layout == "seq":
                return P(None, bspec[0], mdl, None, None)
        if (ps.endswith("/c") or ps.endswith("/kr")) and nd == 4:
            # MLACache [n, B, S, r]: latent is head-less; keep seq sharding
            if mdl and leaf.shape[2] % mesh.shape["model"] == 0:
                return P(None, bspec[0], mdl, None)
        if nd >= 2:  # stacked states [n_blocks, B, ...]
            return P(None, *bspec)
        return P(*([None] * nd))

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    return jax.tree_util.tree_unflatten(
        treedef, [spec(p, l) for p, l in flat])


def shard(mesh: Mesh, spec_tree: Any) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))

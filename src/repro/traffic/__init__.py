"""repro.traffic: open-loop load harness + SLO plane.

Production gateways see OPEN-LOOP traffic — requests arrive whether or
not the fleet keeps up — while everything the paper measures is
closed-loop (fixed-size streams, next request waits for the last).  This
package closes that gap deterministically:

  * ``arrivals``  — arrival processes (Poisson, diurnal sinusoid, flash
                    crowd) as pure functions of a seed, plus the
                    ``ManualClock`` every component rides;
  * ``workload``  — multi-tenant request mixes (detector tenants seeded
                    from the drift scenarios in ``detection/scenes.py``,
                    LLM tenants over the serving pool's prompt-length
                    distribution) and the ``LoadDriver`` that pushes them
                    into an ``EcoreService``/``EcoreCluster`` at their
                    arrival times — no backpressure, late service means
                    queue growth;
  * ``slo``       — streaming windowed percentile sketches (p50/p95/p99
                    end-to-end latency split into queue wait and service
                    time), goodput under per-tenant deadlines, and
                    joules-per-request.

Everything is virtual-time: no wall-clock sleeps anywhere (lint rule
ECO304 covers this package), so a 10-minute diurnal episode replays in
milliseconds, bit-identically, in CI.
"""
from repro.traffic.arrivals import (ARRIVAL_PATTERNS, ManualClock,
                                    diurnal_arrivals, flash_crowd_arrivals,
                                    make_arrivals, poisson_arrivals)
from repro.traffic.slo import Completion, LatencySketch, WindowedSLO
from repro.traffic.workload import (LoadDriver, Tenant, TimedRequest,
                                    detector_tenant, llm_tenant,
                                    merge_tenants)

__all__ = [
    "ARRIVAL_PATTERNS", "ManualClock", "diurnal_arrivals",
    "flash_crowd_arrivals", "make_arrivals", "poisson_arrivals",
    "Completion", "LatencySketch", "WindowedSLO",
    "LoadDriver", "Tenant", "TimedRequest", "detector_tenant",
    "llm_tenant", "merge_tenants",
]

"""Deterministic open-loop arrival processes on the injectable clock.

Every generator is a pure function of ``(rate, duration, seed)`` returning
ABSOLUTE arrival times (float seconds, sorted ascending) — the same seed
always yields the same stream, so a load episode is replayable
bit-for-bit.  Inhomogeneous processes (diurnal sinusoid, flash crowd) are
built by Lewis-Shedler thinning of a homogeneous Poisson process at the
peak rate: candidates are kept with probability ``rate(t) / peak``, which
preserves both determinism and the exact Poisson counting statistics.

``ManualClock`` is the virtual clock the whole traffic plane rides: the
``LoadDriver`` advances it to each arrival/deadline event, services see it
through their injectable ``clock`` parameter, and nothing ever sleeps on
the wall clock (lint rule ECO304 enforces that for this package).
"""
from __future__ import annotations

from typing import Callable, Dict, List

import numpy as np

RateFn = Callable[[np.ndarray], np.ndarray]


class ManualClock:
    """A settable monotonic clock (seconds).  Drop-in for ``time.monotonic``
    wherever a ``clock`` parameter is injectable; the driver owns time."""

    def __init__(self, t0: float = 0.0):
        self._t = float(t0)

    def __call__(self) -> float:
        return self._t

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError(f"cannot advance a monotonic clock by {dt}")
        self._t += dt
        return self._t

    def advance_to(self, t: float) -> float:
        """Move to absolute time ``t``; earlier-than-now is clamped (events
        may be processed slightly late, never in the past)."""
        self._t = max(self._t, float(t))
        return self._t


def _homogeneous(rng: np.random.Generator, rate_hz: float,
                 duration_s: float) -> np.ndarray:
    """Cumulative-sum-of-exponential-gaps Poisson process on [0, duration).
    Gaps are drawn in chunks until the horizon is passed (the loop is
    bounded: every chunk advances time by a positive amount a.s.)."""
    chunks: List[np.ndarray] = []
    t = 0.0
    size = max(int(rate_hz * duration_s * 1.25) + 16, 16)
    while t < duration_s:
        ts = t + np.cumsum(rng.exponential(1.0 / rate_hz, size=size))
        chunks.append(ts)
        t = float(ts[-1])
    ts = np.concatenate(chunks)
    return ts[ts < duration_s]


def poisson_arrivals(rate_hz: float, duration_s: float, *, seed: int = 0,
                     t0: float = 0.0) -> np.ndarray:
    """Homogeneous Poisson arrivals at ``rate_hz`` on [t0, t0+duration)."""
    if rate_hz <= 0 or duration_s <= 0:
        return np.empty(0, np.float64)
    rng = np.random.default_rng(seed)
    return t0 + _homogeneous(rng, rate_hz, duration_s)


def thinned_arrivals(rate_fn: RateFn, peak_rate_hz: float,
                     duration_s: float, *, seed: int = 0,
                     t0: float = 0.0) -> np.ndarray:
    """Inhomogeneous Poisson arrivals with intensity ``rate_fn(t)`` (which
    must never exceed ``peak_rate_hz``), by thinning a homogeneous process
    at the peak rate.  One rng drives both the candidates and the keep
    draws, so the stream is a pure function of the seed."""
    if peak_rate_hz <= 0 or duration_s <= 0:
        return np.empty(0, np.float64)
    rng = np.random.default_rng(seed)
    cand = _homogeneous(rng, peak_rate_hz, duration_s)
    keep = rng.uniform(size=len(cand)) * peak_rate_hz < rate_fn(cand)
    return t0 + cand[keep]


def diurnal_rate(base_hz: float, *, amplitude: float = 0.5,
                 period_s: float = 60.0, phase: float = 0.0) -> RateFn:
    """Sinusoidal day/night intensity: mean ``base_hz``, swinging by
    ``amplitude`` (fraction of base, <= 1 so the rate stays nonnegative)."""
    if not 0.0 <= amplitude <= 1.0:
        raise ValueError(f"amplitude={amplitude}: need 0 <= a <= 1")

    def rate(t: np.ndarray) -> np.ndarray:
        return base_hz * (1.0 + amplitude
                          * np.sin(2.0 * np.pi * t / period_s + phase))
    return rate


def diurnal_arrivals(base_hz: float, duration_s: float, *,
                     amplitude: float = 0.5, period_s: float = 60.0,
                     phase: float = 0.0, seed: int = 0,
                     t0: float = 0.0) -> np.ndarray:
    """Diurnal-cycle arrivals (the smart-city day/night swing, compressed
    to ``period_s``).  Over whole periods the mean rate is ``base_hz``."""
    fn = diurnal_rate(base_hz, amplitude=amplitude, period_s=period_s,
                      phase=phase)
    return thinned_arrivals(fn, base_hz * (1.0 + amplitude), duration_s,
                            seed=seed, t0=t0)


def flash_crowd_rate(base_hz: float, spike_hz: float, spike_start_s: float,
                     spike_len_s: float) -> RateFn:
    """Step intensity: ``base_hz`` everywhere except a ``spike_hz`` plateau
    on [spike_start, spike_start + spike_len) — the stadium-exit burst."""

    def rate(t: np.ndarray) -> np.ndarray:
        in_spike = (t >= spike_start_s) & (t < spike_start_s + spike_len_s)
        return np.where(in_spike, spike_hz, base_hz)
    return rate


def flash_crowd_arrivals(base_hz: float, duration_s: float, *,
                         spike_hz: float = None, spike_start_s: float = None,
                         spike_len_s: float = None, seed: int = 0,
                         t0: float = 0.0) -> np.ndarray:
    """Flash-crowd arrivals: steady ``base_hz`` with one rate spike
    (default: 4x base for the middle fifth of the episode)."""
    spike_hz = 4.0 * base_hz if spike_hz is None else spike_hz
    if spike_hz < base_hz:
        raise ValueError(f"spike_hz={spike_hz} below base_hz={base_hz}")
    spike_start_s = (0.4 * duration_s if spike_start_s is None
                     else spike_start_s)
    spike_len_s = 0.2 * duration_s if spike_len_s is None else spike_len_s
    fn = flash_crowd_rate(base_hz, spike_hz, spike_start_s, spike_len_s)
    return thinned_arrivals(fn, spike_hz, duration_s, seed=seed, t0=t0)


#: name -> generator(rate_hz, duration_s, *, seed, t0); the CLI surface
#: (``repro.launch.serve --pattern``) and benches resolve through this
ARRIVAL_PATTERNS: Dict[str, Callable[..., np.ndarray]] = {
    "poisson": poisson_arrivals,
    "diurnal": diurnal_arrivals,
    "flash": flash_crowd_arrivals,
}


def make_arrivals(pattern: str, rate_hz: float, duration_s: float, *,
                  seed: int = 0, t0: float = 0.0) -> np.ndarray:
    """Build an arrival stream by registry name (each pattern's optional
    shape knobs stay at their defaults; call the generator directly for
    custom spikes/periods)."""
    try:
        fn = ARRIVAL_PATTERNS[pattern]
    except KeyError:
        raise ValueError(f"unknown arrival pattern {pattern!r}; one of "
                         f"{sorted(ARRIVAL_PATTERNS)}") from None
    return fn(rate_hz, duration_s, seed=seed, t0=t0)

"""SLO plane: streaming windowed percentile sketches + goodput accounting.

A load episode produces one ``Completion`` per request (the driver books
virtual queue-wait / service / end-to-end times from the modeled backend
costs).  ``WindowedSLO`` folds completions into per-window log-bucket
sketches the moment they are recorded — O(1) memory per window regardless
of traffic volume — and reports, per window and overall:

  * p50/p95/p99 end-to-end latency, split into queue wait and service;
  * goodput under per-tenant deadlines (completions within deadline / s);
  * joules per request (backend + gateway energy, mWh -> J via
    ``core.energy.mwh_to_joules``).

``LatencySketch`` is a DDSketch-style relative-accuracy histogram:
geometric buckets with ratio gamma = (1+a)/(1-a), so any quantile is
within relative error ``a`` of the exact value — deterministic,
mergeable, and insertion-order independent (the properties a percentile
in a benchmark trajectory needs; a sampled reservoir has none of them).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional

from repro.core.energy import mwh_to_joules


class LatencySketch:
    """Log-bucket quantile sketch with bounded RELATIVE error.

    Values at or below ``min_value`` land in a dedicated zero bucket and
    report as 0.0 (a queue wait of exactly zero is common and meaningful).
    ``merge`` sums bucket counts — combining per-window sketches into an
    episode-wide one loses nothing."""

    def __init__(self, *, rel_err: float = 0.01, min_value: float = 1e-3):
        if not 0.0 < rel_err < 1.0:
            raise ValueError(f"rel_err={rel_err}: need 0 < a < 1")
        self.rel_err = rel_err
        self.min_value = min_value
        self._log_gamma = math.log((1.0 + rel_err) / (1.0 - rel_err))
        self._buckets: Dict[int, int] = {}
        self._zero = 0
        self.count = 0
        self.total = 0.0

    def add(self, value: float) -> None:
        if value < 0 or not math.isfinite(value):
            raise ValueError(f"sketch values must be finite >= 0: {value}")
        self.count += 1
        self.total += value
        if value <= self.min_value:
            self._zero += 1
            return
        key = math.ceil(math.log(value / self.min_value) / self._log_gamma)
        self._buckets[key] = self._buckets.get(key, 0) + 1

    def quantile(self, q: float) -> float:
        """Value at quantile ``q`` in [0, 1], within ``rel_err`` relative
        error (bucket midpoint in log space); 0.0 on an empty sketch."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"q={q}: need 0 <= q <= 1")
        if self.count == 0:
            return 0.0
        rank = q * (self.count - 1)
        seen = self._zero
        if rank < seen:
            return 0.0
        for key in sorted(self._buckets):
            seen += self._buckets[key]
            if rank < seen:
                return self.min_value * math.exp((key - 0.5)
                                                 * self._log_gamma)
        return self.min_value * math.exp((max(self._buckets) - 0.5)
                                         * self._log_gamma)

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def merge(self, other: "LatencySketch") -> "LatencySketch":
        if (other.rel_err != self.rel_err
                or other.min_value != self.min_value):
            raise ValueError("cannot merge sketches with different layouts")
        out = LatencySketch(rel_err=self.rel_err, min_value=self.min_value)
        out._zero = self._zero + other._zero
        out.count = self.count + other.count
        out.total = self.total + other.total
        for src in (self._buckets, other._buckets):
            for k, n in src.items():
                out._buckets[k] = out._buckets.get(k, 0) + n
        return out


@dataclasses.dataclass(frozen=True)
class Completion:
    """One request's fate in VIRTUAL time (seconds on the manual clock):
    arrival -> service start (queue wait) -> done (service), with the
    energy actually charged and the tenant's deadline verdict."""
    uid: int
    tenant: str
    t_arrival: float
    t_start: float
    t_done: float
    service_ms: float
    energy_mwh: float
    deadline_ms: Optional[float]
    ok: bool                      # served without a backend error
    pod: int = 0
    pair: Optional[tuple] = None

    @property
    def queue_wait_ms(self) -> float:
        return (self.t_start - self.t_arrival) * 1e3

    @property
    def e2e_ms(self) -> float:
        return (self.t_done - self.t_arrival) * 1e3

    @property
    def within_deadline(self) -> bool:
        """Goodput verdict: served AND under the tenant's deadline (no
        deadline means any successful completion counts)."""
        return self.ok and (self.deadline_ms is None
                            or self.e2e_ms <= self.deadline_ms)


class _Window:
    def __init__(self, rel_err: float):
        self.e2e = LatencySketch(rel_err=rel_err)
        self.queue_wait = LatencySketch(rel_err=rel_err)
        self.service = LatencySketch(rel_err=rel_err)
        self.n = 0
        self.good = 0
        self.failed = 0
        self.energy_mwh = 0.0
        self.tenants: Dict[str, Dict[str, int]] = {}


class WindowedSLO:
    """Streaming SLO tracker: completions fold into the sketch of the
    virtual-time window they COMPLETE in (an overloaded minute shows up in
    that minute's percentiles, not smeared across the episode)."""

    def __init__(self, *, window_s: float = 1.0, rel_err: float = 0.01):
        if window_s <= 0:
            raise ValueError(f"window_s={window_s}: need > 0")
        self.window_s = window_s
        self.rel_err = rel_err
        self._windows: Dict[int, _Window] = {}

    def record(self, c: Completion) -> None:
        idx = int(c.t_done // self.window_s)
        w = self._windows.get(idx)
        if w is None:
            w = self._windows[idx] = _Window(self.rel_err)
        w.n += 1
        w.energy_mwh += c.energy_mwh
        per = w.tenants.setdefault(c.tenant, {"n": 0, "good": 0})
        per["n"] += 1
        if not c.ok:
            w.failed += 1
        if c.within_deadline:
            w.good += 1
            per["good"] += 1
        w.e2e.add(c.e2e_ms)
        w.queue_wait.add(c.queue_wait_ms)
        w.service.add(c.service_ms)

    @staticmethod
    def _percentiles(w: "_Window") -> Dict[str, float]:
        return {
            "p50_ms": w.e2e.quantile(0.50),
            "p95_ms": w.e2e.quantile(0.95),
            "p99_ms": w.e2e.quantile(0.99),
            "queue_wait_p50_ms": w.queue_wait.quantile(0.50),
            "queue_wait_p99_ms": w.queue_wait.quantile(0.99),
            "service_p50_ms": w.service.quantile(0.50),
        }

    def window_records(self) -> List[Dict]:
        """One record per non-empty window, in time order — what the load
        bench appends to the trajectory."""
        out = []
        for idx in sorted(self._windows):
            w = self._windows[idx]
            out.append({
                "t_start_s": idx * self.window_s,
                "n": w.n,
                "failed": w.failed,
                "goodput_rps": w.good / self.window_s,
                "joules_per_request": (mwh_to_joules(w.energy_mwh) / w.n
                                       if w.n else 0.0),
                "tenants": {t: dict(v) for t, v in w.tenants.items()},
                **self._percentiles(w),
            })
        return out

    def summary(self) -> Dict:
        """Episode-wide aggregate: merged sketches + total goodput."""
        windows = [self._windows[i] for i in sorted(self._windows)]
        agg = _Window(self.rel_err)
        for w in windows:
            agg.e2e = agg.e2e.merge(w.e2e)
            agg.queue_wait = agg.queue_wait.merge(w.queue_wait)
            agg.service = agg.service.merge(w.service)
            agg.n += w.n
            agg.good += w.good
            agg.failed += w.failed
            agg.energy_mwh += w.energy_mwh
        span_s = len(windows) * self.window_s
        return {
            "completions": agg.n,
            "failed": agg.failed,
            "windows": len(windows),
            "goodput_fraction": agg.good / agg.n if agg.n else 0.0,
            "goodput_rps": agg.good / span_s if span_s else 0.0,
            "joules_per_request": (mwh_to_joules(agg.energy_mwh) / agg.n
                                   if agg.n else 0.0),
            **self._percentiles(agg),
        }

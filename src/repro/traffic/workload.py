"""Multi-tenant open-loop workloads + the LoadDriver that replays them.

A ``Tenant`` is an arrival stream (from ``traffic.arrivals``) plus a
request factory and an SLO deadline: detector tenants draw object counts
from the drifting scene mix (``detection/scenes.py`` — the sparse
COCO-like distribution flipping to its crowded mirror mid-stream), LLM
tenants draw prompt lengths from the serving pool's distribution.
``merge_tenants`` interleaves any number of them into one time-ordered
stream with globally unique uids.

``LoadDriver`` replays that stream OPEN-LOOP against an ``EcoreService``
or ``EcoreCluster`` on a shared ``ManualClock``: it advances virtual time
to each arrival, submits the request, and fires every ``max_wait_ms``
dispatch deadline at its exact virtual expiry (``service.flush_due``) —
no background flusher thread, no wall-clock sleeps, bit-reproducible.

There is deliberately NO backpressure.  Service capacity is modeled in
virtual time: each (pod, routed pair) is one sequential server — an edge
device serves its batch one frame at a time — so a flushed request starts
when its server frees up (``busy_until``) and occupies it for the modeled
backend latency.  When arrivals outpace capacity, ``busy_until`` runs
ahead of the clock and queue waits grow without bound — which is exactly
the signal the SLO plane and the cluster ``Autoscaler`` exist to see.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
import threading
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.policy import RouteRequest
from repro.detection import scenes as sc
from repro.traffic.arrivals import ManualClock
from repro.traffic.slo import Completion, WindowedSLO


@dataclasses.dataclass(frozen=True)
class TimedRequest:
    """One arrival: WHEN it lands, WHO sent it, WHAT it asks."""
    t: float
    tenant: str
    request: RouteRequest
    deadline_ms: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class Tenant:
    """An arrival stream + request factory + per-tenant deadline.

    ``make_request(uid, i)`` builds the i-th arrival's request with the
    globally-assigned ``uid``; factories index PRE-GENERATED per-tenant
    draws by ``i``, so the stream is independent of merge order."""
    name: str
    arrivals: np.ndarray
    make_request: Callable[[int, int], RouteRequest]
    deadline_ms: Optional[float] = None


def detector_tenant(name: str, arrivals: np.ndarray, *, seed: int = 0,
                    deadline_ms: Optional[float] = None,
                    shift_frac: float = 0.5,
                    scene_images: bool = False,
                    frame_hw: Tuple[int, int] = (8, 8)) -> Tenant:
    """Detection-face tenant seeded from the drift scenario: object counts
    follow the sparse COCO-like mix until ``shift_frac`` of the stream,
    then flip to its crowded mirror (``scenes.drifting_dataset``'s rush
    hour), so the dominant routed group changes mid-episode.

    ``scene_images=True`` renders a real synthetic scene per request
    (needed when the backend actually detects); the default ships a shared
    zero frame of ``frame_hw`` — the routing/dispatch dynamics are
    identical and the stream is cheap enough for big episodes."""
    rng = np.random.default_rng(seed)
    n = len(arrivals)
    shift_at = int(n * shift_frac)
    sparse, crowded = sc.COUNT_PROBS, sc.COUNT_PROBS[::-1]
    counts = np.concatenate([
        rng.choice(len(sparse), p=sparse, size=shift_at),
        rng.choice(len(crowded), p=crowded, size=n - shift_at),
    ]).astype(np.int64)
    if scene_images:
        frames = [sc.make_scene(rng, count=int(c)).image for c in counts]
    else:
        shared = np.zeros(frame_hw, np.float32)
        frames = [shared] * n

    def make_request(uid: int, i: int) -> RouteRequest:
        return RouteRequest(uid=uid, payload=frames[i],
                            true_complexity=int(counts[i]))
    return Tenant(name=name, arrivals=np.asarray(arrivals, np.float64),
                  make_request=make_request, deadline_ms=deadline_ms)


def llm_tenant(name: str, arrivals: np.ndarray, *, seed: int = 0,
               deadline_ms: Optional[float] = None,
               prompt_lens: Sequence[int] = (32, 128, 1024, 4096, 40_000),
               probs: Sequence[float] = (.3, .3, .2, .1, .1),
               prompt_cap: int = 48, max_new_tokens: int = 4) -> Tenant:
    """Serving-face tenant: prompt lengths from the pool drivers'
    long-tailed mix (the router buckets on the full length; the
    materialized prompt is capped like ``launch/serve.py``)."""
    rng = np.random.default_rng(seed)
    n = len(arrivals)
    plens = rng.choice(np.asarray(prompt_lens), p=np.asarray(probs), size=n)
    payloads = [rng.integers(0, 1000, size=min(int(p), prompt_cap))
                for p in plens]

    def make_request(uid: int, i: int) -> RouteRequest:
        return RouteRequest(uid=uid, complexity=int(plens[i]),
                            payload=payloads[i],
                            max_new_tokens=max_new_tokens)
    return Tenant(name=name, arrivals=np.asarray(arrivals, np.float64),
                  make_request=make_request, deadline_ms=deadline_ms)


def merge_tenants(tenants: Sequence[Tenant]) -> List[TimedRequest]:
    """Interleave tenant streams into one time-ordered workload with
    globally unique uids (assigned in arrival order; ties break by tenant
    position then arrival index, so the merge is deterministic)."""
    events = [(float(t), ti, i) for ti, tenant in enumerate(tenants)
              for i, t in enumerate(tenant.arrivals)]
    events.sort()
    out = []
    for uid, (t, ti, i) in enumerate(events):
        tenant = tenants[ti]
        out.append(TimedRequest(t=t, tenant=tenant.name,
                                request=tenant.make_request(uid, i),
                                deadline_ms=tenant.deadline_ms))
    return out


class LoadDriver:
    """Replay a merged workload open-loop against a service/cluster.

    The target must share this driver's ``clock`` and run WITHOUT the
    background flusher (``EcoreService(..., clock=clock, flusher=False)``)
    — the driver fires dispatch deadlines itself at their exact virtual
    expiry, so batch composition is a pure function of the workload.

    Completion accounting rides the futures: every submit's done-callback
    books the request onto its (pod, pair) virtual server — requests in
    one flushed batch start when the server frees and run back-to-back for
    their modeled per-request latency (an edge device serves its batch
    sequentially, exactly the ``DetectorBackend.realtime_scale`` model,
    minus the wall-clock sleep).  ``backlog()`` is the number of requests
    submitted but not yet virtually completed — the queue-depth signal an
    ``Autoscaler`` ticks on.
    """

    def __init__(self, service, clock: ManualClock, *,
                 slo: Optional[WindowedSLO] = None, window_s: float = 1.0,
                 autoscaler=None):
        self.service = service
        self.clock = clock
        self.slo = slo if slo is not None else WindowedSLO(window_s=window_s)
        self.autoscaler = autoscaler
        self.completions: List[Completion] = []
        self._lock = threading.Lock()
        #: (pod, pair) -> virtual time its sequential server frees up
        self._busy: Dict[Tuple[int, Tuple[str, str]], float] = {}
        self._ends: List[float] = []      # heap of virtual completion times
        self._submitted = 0
        self._done_virtual = 0

    # ------------------------------------------------------------- driving

    def run(self, timed: Sequence[TimedRequest]) -> List[Completion]:
        """Replay the whole workload; returns completions sorted by
        virtual completion time.  Anything still batched when the last
        deadline fired is flushed by a final ``drain`` at end time."""
        timed = sorted(timed, key=lambda tr: (tr.t, tr.request.uid))
        for tr in timed:
            self._fire_deadlines(until=tr.t)
            self.clock.advance_to(tr.t)
            self._submit(tr)
            self._tick()
        self._fire_deadlines(until=None)
        self.service.drain()
        with self._lock:
            if self._ends:                 # run the clock out: the episode
                last = max(self._ends)     # ends when the last booked
            else:                          # request virtually completes
                last = self.clock()
        self.clock.advance_to(last)
        self._tick()
        with self._lock:
            self.completions.sort(key=lambda c: (c.t_done, c.uid))
            return list(self.completions)

    def backlog(self) -> int:
        """Requests submitted but not yet virtually complete (queued for
        dispatch, or booked on a server whose work extends past now)."""
        now = self.clock()
        with self._lock:
            while self._ends and self._ends[0] <= now:
                heapq.heappop(self._ends)
                self._done_virtual += 1
            return self._submitted - self._done_virtual

    # ----------------------------------------------------------- internals

    def _tick(self) -> None:
        if self.autoscaler is not None:
            self.autoscaler.tick(self.backlog())

    def _fire_deadlines(self, until: Optional[float]) -> None:
        while True:
            nd = self.service.next_deadline()
            if nd is None or (until is not None and nd > until):
                break
            self.clock.advance_to(nd)
            self.service.flush_due(nd)
            self._tick()

    def _submit(self, tr: TimedRequest) -> None:
        self._submitted += 1
        fut = self.service.submit(tr.request)
        fut.add_done_callback(lambda f, tr=tr: self._on_done(tr, f))

    def _modeled(self, served) -> Tuple[float, float]:
        """(service_ms, energy_mwh) for one served request: the backend's
        modeled per-request cost when it reports one (detector results),
        else the profiled cost routing decided on (LLM pool), else the
        measured wall time — first finite value wins."""
        res, dec = served.result, served.decision
        t_ms = res.time_ms
        if t_ms is None or not math.isfinite(t_ms):
            t_ms = dec.time_ms
        if t_ms is None or not math.isfinite(t_ms):
            t_ms = ((res.prefill_s + res.decode_s) * 1e3
                    / max(res.batch_size, 1))
        e_mwh = res.energy_mwh
        if e_mwh is None or not math.isfinite(e_mwh):
            e_mwh = dec.energy_mwh if dec.energy_mwh is not None else 0.0
        return float(t_ms), float(e_mwh) + dec.gateway_energy_mwh

    def _on_done(self, tr: TimedRequest, fut) -> None:
        trigger = self.clock()
        if fut.exception() is not None:
            c = Completion(uid=tr.request.uid, tenant=tr.tenant,
                           t_arrival=tr.t, t_start=trigger, t_done=trigger,
                           service_ms=0.0, energy_mwh=0.0,
                           deadline_ms=tr.deadline_ms, ok=False)
            with self._lock:
                self.completions.append(c)
                self.slo.record(c)
            return
        s = fut.result()
        owner_of = getattr(self.service, "owner_of", None)
        pod = owner_of(tr.request.uid) if owner_of is not None else 0
        pod = 0 if pod is None else pod
        t_ms, e_mwh = self._modeled(s)
        key = (pod, s.decision.pair)
        with self._lock:
            start = max(self._busy.get(key, 0.0), trigger)
            end = start + t_ms / 1e3
            self._busy[key] = end
            heapq.heappush(self._ends, end)
            c = Completion(uid=tr.request.uid, tenant=tr.tenant,
                           t_arrival=tr.t, t_start=start, t_done=end,
                           service_ms=t_ms, energy_mwh=e_mwh,
                           deadline_ms=tr.deadline_ms, ok=True, pod=pod,
                           pair=s.decision.pair)
            self.completions.append(c)
            self.slo.record(c)

"""Property-testing compat layer: real hypothesis when installed, otherwise
a tiny deterministic example-based substitute.

The fallback draws ``max_examples`` pseudo-random examples from a fixed seed
(plus boundary values for scalar strategies), so the property tests still
exercise many inputs on containers without ``hypothesis`` — with reproducible
failures — while dev machines with the real package keep full shrinking.

Only the strategy subset this suite uses is implemented: ``integers``,
``floats``, ``sampled_from``, ``tuples``, ``lists``.
"""
from __future__ import annotations

try:
    # the ONE sanctioned hypothesis import: this module IS the compat shim
    from hypothesis import given, settings  # noqa: F401  # repro-lint: disable=ECO503
    import hypothesis.strategies as st      # noqa: F401  # repro-lint: disable=ECO503
except ImportError:
    import functools
    import inspect
    import random

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            bounds = (min_value, max_value)

            def draw(rng):
                u = rng.random()
                if u < 0.08:
                    return bounds[rng.random() < 0.5]
                return rng.randint(min_value, max_value)
            return _Strategy(draw)

        @staticmethod
        def floats(min_value, max_value, allow_nan=False):
            bounds = (float(min_value), float(max_value))

            def draw(rng):
                u = rng.random()
                if u < 0.08:
                    return bounds[rng.random() < 0.5]
                return rng.uniform(*bounds)
            return _Strategy(draw)

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda rng: rng.choice(elements))

        @staticmethod
        def tuples(*strategies):
            return _Strategy(
                lambda rng: tuple(s.draw(rng) for s in strategies))

        @staticmethod
        def lists(elements, min_size=0, max_size=10, unique_by=None):
            def draw(rng):
                size = rng.randint(min_size, max_size)
                out, seen = [], set()
                for _ in range(50 * max(size, 1)):
                    if len(out) >= size:
                        break
                    x = elements.draw(rng)
                    if unique_by is not None:
                        key = unique_by(x)
                        if key in seen:
                            continue
                        seen.add(key)
                    out.append(x)
                assert len(out) >= min_size, "strategy cannot fill min_size"
                return out
            return _Strategy(draw)

    st = _Strategies()

    def given(*arg_strategies, **kw_strategies):
        def decorate(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                rng = random.Random(0)
                for _ in range(getattr(wrapper, "_pc_max_examples", 25)):
                    drawn_args = tuple(s.draw(rng) for s in arg_strategies)
                    drawn_kw = {k: s.draw(rng)
                                for k, s in kw_strategies.items()}
                    fn(*args, *drawn_args, **kwargs, **drawn_kw)
            # strategy-fed params must not look like pytest fixtures
            del wrapper.__wrapped__
            wrapper.__signature__ = inspect.Signature()
            return wrapper
        return decorate

    def settings(max_examples=25, **_ignored):
        def decorate(fn):
            fn._pc_max_examples = max_examples
            return fn
        return decorate

"""Closed-loop routing core: shared feasible set, drift scenarios, EWMA
adaptation through the gateway and serving pool, batched dispatch."""
import numpy as np
import pytest

from repro.core.groups import group_of
from repro.core.profiles import ProfileEntry, ProfileTable
from repro.core.router import (ParetoRouter, WeightedRouter,
                               feasible_for_count, feasible_set, greedy_route)
from repro.detection import scenes as sc
from repro.detection.devices import (DEVICES, DriftEvent, DriftingFleet,
                                     drift_scenario)
from repro.serving.engine import DispatchQueue, Request, Result
from repro.serving.pool import LENGTH_BUCKETS, ServingPool


def make_table(rows):
    return ProfileTable([ProfileEntry(*r) for r in rows])


@pytest.fixture
def table():
    rows = []
    for g in range(5):
        rows += [
            ("cheap", "d1", g, 80.0 - g, 20.0, 0.01),
            ("fast", "d2", g, 80.0 - g, 2.0, 0.05),
            ("acc", "d3", g, 95.0 - g, 30.0, 0.09),
        ]
    return make_table(rows)


# ----------------------------------------------------- shared feasible set

def test_feasible_set_parity_with_inline_filter(table):
    """The extracted helper must match the filter the routers used to
    inline: group rows -> mAP >= mAP_max - delta."""
    for count in range(8):
        for delta in (0.0, 5.0, 14.0, 100.0):
            rows = table.for_group(group_of(count))
            max_map = max(e.map_pct for e in rows)
            inline = [e for e in rows if e.map_pct >= max_map - delta]
            assert feasible_for_count(count, table, delta) == inline


def test_all_router_faces_share_the_feasible_set(table):
    """Weighted/Pareto picks always come from the shared feasible set."""
    for count in (0, 2, 7):
        feas = {e.pair for e in feasible_for_count(count, table, 14.0)}
        assert greedy_route(count, table, 14.0).pair in feas
        assert WeightedRouter(table, 14.0).route(estimated_count=count) in feas
        assert ParetoRouter(table, 14.0).route(estimated_count=count) in feas


def test_pool_route_uses_shared_feasible_set():
    entries = [ProfileEntry(a, "pod", b, score, 1.0, energy)
               for a, score, energy in (("small", 80.0, 1.0),
                                        ("big", 84.0, 5.0))
               for _, _, b in LENGTH_BUCKETS]
    pool = ServingPool(ProfileTable(entries), delta=5.0)
    d = pool.route(100)
    feas = feasible_set(0, pool.table, 5.0)
    assert d.arch == min(feas, key=lambda e: e.energy_mwh).model == "small"


def test_pool_route_unprofiled_bucket_is_a_clear_error():
    entries = [ProfileEntry("only", "pod", 0, 80.0, 1.0, 1.0)]
    pool = ServingPool(ProfileTable(entries), delta=5.0)
    with pytest.raises(ValueError, match="no profile rows for group 4"):
        pool.route(40_000)


# ------------------------------------------------------------ drift model

def test_thermal_ramp_monotone_and_saturates():
    ev = DriftEvent("orin_nano", "thermal", start=10, severity=4.0, ramp=20)
    ms = [ev.multiplier(t) for t in range(0, 60)]
    assert ms[:10] == [1.0] * 10
    assert all(b >= a for a, b in zip(ms[10:], ms[11:]))
    assert ms[30] == ms[59] == 4.0


def test_background_load_oscillates():
    ev = DriftEvent("pi5", "background", severity=3.0, period=10)
    assert ev.multiplier(0) == 3.0 and ev.multiplier(5) == 1.0
    assert ev.multiplier(10) == 3.0  # periodic


def test_dropout_window():
    ev = DriftEvent("pi4", "dropout", start=5, end=8, severity=30.0)
    assert [ev.multiplier(t) for t in (4, 5, 7, 8)] == [1.0, 30.0, 30.0, 1.0]


def test_fleet_composes_events_and_scales_energy():
    fleet = DriftingFleet([
        DriftEvent("pi5", "dropout", start=0, severity=2.0),
        DriftEvent("pi5", "background", severity=3.0, period=10),
    ])
    assert fleet.multiplier("pi5", 0) == 6.0
    assert fleet.multiplier("orin_nano", 0) == 1.0
    t0, e0 = fleet.cost("pi5", 1e9, 5)   # background off-phase: 2x only
    t1, e1 = fleet.cost("pi5", 1e9, 0)   # both active: 6x
    assert t1 / t0 == pytest.approx(3.0)
    assert e1 / e0 == pytest.approx(3.0)  # energy tracks busy time


def test_drifting_dataset_shifts_count_distribution():
    ds = sc.drifting_dataset(n=160, seed=9)
    first = np.mean([s.count for s in ds[:80]])
    second = np.mean([s.count for s in ds[80:]])
    assert second - first > 1.0


# --------------------------------------------------------- EWMA adaptation

def test_observe_pair_updates_every_group(table):
    table.observe_pair(("cheap", "d1"), time_ms=100.0, alpha=0.5)
    for g in range(5):
        assert table.entry(("cheap", "d1"), g).time_ms == 60.0
        assert table.entry(("fast", "d2"), g).time_ms == 2.0  # untouched
    with pytest.raises(KeyError):
        table.observe_pair(("nope", "d9"), time_ms=1.0)


def test_copy_isolates_ewma_updates(table):
    frozen = table.copy()
    table.observe_pair(("cheap", "d1"), energy_mwh=9.0, alpha=0.5)
    assert frozen.entry(("cheap", "d1"), 0).energy_mwh == 0.01
    assert table.entry(("cheap", "d1"), 0).energy_mwh > 0.01


def test_observe_converges_to_drifted_cost(table):
    """Feeding fleet-measured costs through observe_pair tracks the drifted
    value within a few time constants."""
    fleet = drift_scenario("thermal", device="orin_nano", start=0)
    flops = 1e9
    target_t, target_e = fleet.cost("orin_nano", flops, 1000)  # saturated
    for t in range(120):
        t_ms, e_mwh = fleet.cost("orin_nano", flops, t)
        table.observe_pair(("cheap", "d1"), time_ms=t_ms, energy_mwh=e_mwh,
                           alpha=0.2)
    got = table.entry(("cheap", "d1"), 2)
    assert got.time_ms == pytest.approx(target_t, rel=0.02)
    assert got.energy_mwh == pytest.approx(target_e, rel=0.02)


# ------------------------------------------------- gateway closed loop

def _fake_run_detector(params, images):
    none = np.zeros((0, 4), np.float32)
    return [(none, np.zeros(0, np.float32), np.zeros(0, np.int32))
            for _ in range(len(images))]


def _gateway_episode(monkeypatch, *, adapt):
    from repro.core.gateway import Gateway
    from repro.core.router import OracleRouter
    from repro.detection import train
    from repro.detection.detectors import DETECTOR_CONFIGS

    monkeypatch.setattr(train, "run_detector", _fake_run_detector)
    rows = []
    for g in range(5):  # same mAP -> both pairs always feasible
        for m, d in (("ssd_v1", "orin_nano"), ("yolov8_n", "pi5")):
            flops = DETECTOR_CONFIGS[m].flops  # what the gateway charges
            rows.append(ProfileEntry(m, d, g, 60.0,
                                     DEVICES[d].time_ms(flops),
                                     DEVICES[d].energy_mwh(flops)))
    table = ProfileTable(rows)
    base_pick = greedy_route(1, table, 5.0)
    fleet = DriftingFleet([DriftEvent(base_pick.device, "thermal",
                                      severity=40.0, ramp=5)])
    gw = Gateway(OracleRouter(table, 5.0), table,
                 {"ssd_v1": None, "yolov8_n": None}, None,
                 fleet=fleet, adapt=adapt, alpha=0.3)
    scenes = [sc.make_scene(np.random.default_rng(i), count=1)
              for i in range(40)]
    return gw.process_stream(scenes), base_pick


def test_gateway_closed_loop_reroutes_away_from_throttled_device(
        monkeypatch):
    stats, base_pick = _gateway_episode(monkeypatch, adapt=True)
    other = {"orin_nano": "yolov8_n@pi5",
             "pi5": "ssd_v1@orin_nano"}[base_pick.device]
    # adaptation notices the throttled favorite and switches
    assert stats.pair_histogram.get(other, 0) > 25


def test_gateway_static_profile_never_reroutes(monkeypatch):
    stats, base_pick = _gateway_episode(monkeypatch, adapt=False)
    assert stats.pair_histogram == {base_pick.pair_name: 40}


def test_gateway_adaptive_beats_static_on_energy(monkeypatch):
    adaptive, _ = _gateway_episode(monkeypatch, adapt=True)
    static, _ = _gateway_episode(monkeypatch, adapt=False)
    assert adaptive.backend_energy_mwh < static.backend_energy_mwh


def test_gateway_exploration_recovers_from_transient_drift(monkeypatch):
    """Pure exploitation abandons a pair whose cost spiked and never
    re-measures it; periodic exploration refreshes its rows after the
    device recovers."""
    from repro.core.gateway import Gateway
    from repro.core.router import OracleRouter
    from repro.detection import train
    from repro.detection.detectors import DETECTOR_CONFIGS

    monkeypatch.setattr(train, "run_detector", _fake_run_detector)

    def episode(explore_every):
        rows = []
        for g in range(5):
            for m, d in (("ssd_v1", "orin_nano"), ("yolov8_n", "pi5")):
                flops = DETECTOR_CONFIGS[m].flops
                rows.append(ProfileEntry(m, d, g, 60.0,
                                         DEVICES[d].time_ms(flops),
                                         DEVICES[d].energy_mwh(flops)))
        table = ProfileTable(rows)
        favorite = greedy_route(1, table, 5.0)
        fleet = DriftingFleet([DriftEvent(favorite.device, "dropout",
                                          start=0, end=30, severity=50.0)])
        gw = Gateway(OracleRouter(table, 5.0), table,
                     {"ssd_v1": None, "yolov8_n": None}, None,
                     fleet=fleet, adapt=True, alpha=0.3,
                     explore_every=explore_every)
        scenes = [sc.make_scene(np.random.default_rng(i), count=1)
                  for i in range(150)]
        gw.process_stream(scenes)
        return table.entry(favorite.pair, 1).energy_mwh, favorite

    poisoned, fav = episode(explore_every=0)
    recovered, _ = episode(explore_every=4)
    assert poisoned > 5 * fav.energy_mwh   # abandoned: stuck at spike value
    assert recovered < 2 * fav.energy_mwh  # explored: re-converged to healthy


def test_gateway_adapt_rejects_unshared_table(monkeypatch, table):
    """adapt=True with a router holding a DIFFERENT table would be a silent
    no-op (observations never reach routing) — must fail loudly."""
    from repro.core.gateway import Gateway
    from repro.core.router import OracleRouter
    from repro.detection import train

    monkeypatch.setattr(train, "run_detector", _fake_run_detector)
    with pytest.raises(ValueError, match="same object"):
        Gateway(OracleRouter(table.copy(), 5.0), table, {}, adapt=True)
    Gateway(OracleRouter(table, 5.0), table, {}, adapt=True)  # shared: fine


# ------------------------------------------------------ serving closed loop

def test_pool_observe_closes_the_loop():
    entries = [ProfileEntry(a, "pod", b, 80.0, 1.0, energy)
               for a, energy in (("small", 1.0), ("big", 5.0))
               for _, _, b in LENGTH_BUCKETS]
    pool = ServingPool(ProfileTable(entries), delta=5.0)
    assert pool.route(100).arch == "small"
    for _ in range(30):  # 'small' measured far more expensive than profiled
        pool.observe("small", energy_mwh=50.0, alpha=0.3)
    assert pool.route(100).arch == "big"
    with pytest.raises(KeyError):
        pool.observe("unknown-arch", time_ms=1.0)


# --------------------------------------------------------- batched dispatch

class _StubBackend:
    def __init__(self, name="stub", max_batch=3):
        self.name = name
        self.max_batch = max_batch
        self.batch_sizes = []

    def serve_batch(self, requests):
        self.batch_sizes.append(len(requests))
        return [Result(uid=r.uid, tokens=np.zeros(1, np.int32),
                       prefill_s=.01, decode_s=.01, backend=self.name,
                       batch_size=len(requests)) for r in requests]


def test_dispatch_queue_batches_up_to_max_batch():
    be = _StubBackend(max_batch=3)
    q = DispatchQueue(be)
    got = []
    for uid in range(7):
        got += q.submit(Request(uid=uid, prompt=np.arange(4)))
    got += q.flush()
    assert be.batch_sizes == [3, 3, 1]
    assert q.calls == 3 and q.served == 7
    assert [r.uid for r in got] == list(range(7))
    assert q.flush() == []  # idempotent when drained


def test_serve_driver_batches_fewer_calls_than_requests(monkeypatch):
    from repro.launch import serve

    built = []

    def stub_backend(name, cfg, *, max_batch=8, max_seq=256, seed=0):
        be = _StubBackend(name, max_batch)
        built.append(be)
        return be

    monkeypatch.setattr(serve, "Backend", stub_backend)
    assert serve.main(["--requests", "12", "--max-batch", "4",
                       "--archs", "qwen2.5-3b", "mamba2-370m",
                       "--dryrun-artifact", "/nonexistent"]) == 0
    calls = sum(len(be.batch_sizes) for be in built)
    served = sum(sum(be.batch_sizes) for be in built)
    assert served == 12
    assert calls < 12  # true batching: fewer engine calls than requests


def test_serve_adapt_observes_energy_scaled_by_slowdown(monkeypatch):
    """--adapt must move the ENERGY column (what greedy routing minimizes),
    scaled by the backend's slowdown relative to its fastest batch."""
    from repro.launch import serve

    class SlowingBackend(_StubBackend):
        def serve_batch(self, requests):
            results = super().serve_batch(requests)
            slow = 0.005 * len(self.batch_sizes)  # each batch slower
            return [Result(uid=r.uid, tokens=r.tokens, prefill_s=slow,
                           decode_s=0.01, backend=r.backend,
                           batch_size=r.batch_size) for r in results]

    observed = []
    real_observe = serve.ServingPool.observe

    def spy(self, arch, **kw):
        observed.append((arch, kw))
        return real_observe(self, arch, **kw)

    monkeypatch.setattr(serve.ServingPool, "observe", spy)
    monkeypatch.setattr(
        serve, "Backend",
        lambda name, cfg, *, max_batch=8, max_seq=256, seed=0:
        SlowingBackend(name, max_batch))
    assert serve.main(["--requests", "8", "--max-batch", "2",
                       "--archs", "qwen2.5-3b",
                       "--dryrun-artifact", "/nonexistent", "--adapt"]) == 0
    assert observed
    assert all({"time_ms", "energy_mwh"} <= set(kw) for _, kw in observed)
    energies = [kw["energy_mwh"] for _, kw in observed]
    # per-shape baselines: each shape's first observation sits at the
    # profiled value; repeated shapes see the growing slowdown
    assert max(energies) > min(energies) > 0


def test_serve_async_adapt_closes_the_loop_between_submissions(monkeypatch):
    """--async --adapt must interleave observations with routing (a batch's
    measurements fold in BEFORE later requests are decided), not defer every
    observe until the stream has been fully routed."""
    from repro.launch import serve

    events = []
    real_observe = serve.ServingPool.observe
    real_route = serve.ServingPool.route

    def spy_observe(self, arch, **kw):
        events.append("observe")
        return real_observe(self, arch, **kw)

    def spy_route(self, plen):
        events.append("route")
        return real_route(self, plen)

    monkeypatch.setattr(serve.ServingPool, "observe", spy_observe)
    monkeypatch.setattr(serve.ServingPool, "route", spy_route)
    monkeypatch.setattr(
        serve, "Backend",
        lambda name, cfg, *, max_batch=8, max_seq=256, seed=0:
        _StubBackend(name, max_batch))
    assert serve.main(["--requests", "8", "--max-batch", "2",
                       "--archs", "qwen2.5-3b",
                       "--dryrun-artifact", "/nonexistent",
                       "--adapt", "--async"]) == 0
    assert "observe" in events
    # closed loop: at least one observation lands before the final route
    assert events.index("observe") < len(events) - 1 - \
        events[::-1].index("route")


def test_serve_profile_out_persists_adapted_profile(monkeypatch, tmp_path):
    """--profile-out writes the (adapted) routing profile as json, and the
    written file round-trips through ProfileTable.from_json."""
    from repro.core.profiles import ProfileTable
    from repro.launch import serve

    class SlowingBackend(_StubBackend):
        def serve_batch(self, requests):
            results = super().serve_batch(requests)
            slow = 0.005 * len(self.batch_sizes)
            return [Result(uid=r.uid, tokens=r.tokens, prefill_s=slow,
                           decode_s=0.01, backend=r.backend,
                           batch_size=r.batch_size) for r in results]

    monkeypatch.setattr(
        serve, "Backend",
        lambda name, cfg, *, max_batch=8, max_seq=256, seed=0:
        SlowingBackend(name, max_batch))
    out = str(tmp_path / "profile.json")
    assert serve.main(["--requests", "8", "--max-batch", "2",
                       "--archs", "qwen2.5-3b",
                       "--dryrun-artifact", "/nonexistent",
                       "--adapt", "--profile-out", out]) == 0
    reloaded = ProfileTable.from_json(out)
    pristine = serve.synthetic_pool_table(["qwen2.5-3b"])
    assert {e.pair for e in reloaded.entries} == \
        {e.pair for e in pristine.entries}
    # the slowdown observations actually reached the persisted profile
    assert any(r.energy_mwh != p.energy_mwh
               for r, p in zip(reloaded.entries, pristine.entries))


def test_serve_batch_equivalent_to_single_requests():
    """Batched serve_batch returns the same tokens as serving each request
    alone (equal-length prompts: no padding divergence)."""
    from repro.configs import get_config
    from repro.serving.engine import Backend

    cfg = get_config("qwen2.5-3b").reduced()
    be = Backend("qwen", cfg, max_seq=64)
    reqs = [Request(uid=i, prompt=np.arange(9) * (i + 2), max_new_tokens=3)
            for i in range(3)]
    batched = be.serve_batch(reqs)
    for req, res in zip(reqs, batched):
        solo = be.serve_batch([req])[0]
        assert res.batch_size == 3 and solo.batch_size == 1
        np.testing.assert_array_equal(res.tokens, solo.tokens)


def test_dispatch_queue_mixed_lengths_match_solo_serving():
    """Regression: a mixed-length flush must split into homogeneous
    serve_batch calls — right-padding a short prompt next to a longer one
    makes its first generated token come from a PAD position."""
    from repro.configs import get_config
    from repro.serving.engine import Backend

    cfg = get_config("qwen2.5-3b").reduced()
    q = DispatchQueue(Backend("qwen", cfg, max_batch=4, max_seq=64))
    reqs = [Request(uid=0, prompt=np.arange(5), max_new_tokens=3),
            Request(uid=1, prompt=np.arange(9), max_new_tokens=3),
            Request(uid=2, prompt=np.arange(5) + 7, max_new_tokens=3)]
    got = []
    for r in reqs:
        got += q.submit(r)
    got += q.flush()
    assert q.calls == 2 and q.served == 3  # one call per length group
    by_uid = {r.uid: r for r in got}
    for req in reqs:
        solo = q.backend.serve_batch([req])[0]
        np.testing.assert_array_equal(by_uid[req.uid].tokens, solo.tokens)

"""AsyncEcoreService: awaitable serving over the same policies/queues.

No pytest-asyncio in the container: each test drives a real event loop via
``asyncio.run`` (marker ``asyncio`` groups them)."""
import asyncio

import numpy as np
import pytest

from repro.core.policy import Observation, PoolPolicy, RouteRequest
from repro.core.profiles import ProfileEntry, ProfileTable
from repro.serving.aio import AsyncEcoreService
from repro.serving.engine import Result
from repro.serving.pool import LENGTH_BUCKETS, ServingPool
from repro.serving.service import EcoreService


def _pool(delta=5.0):
    entries = [ProfileEntry(a, "pod", b, score - drop * b, 1.0, energy)
               for a, score, drop, energy in (("small", 80.0, 3.0, 1.0),
                                              ("big", 84.0, 1.0, 5.0))
               for _, _, b in LENGTH_BUCKETS]
    return ServingPool(ProfileTable(entries), delta=delta)


class _StubBackend:
    def __init__(self, name="stub", max_batch=4):
        self.name = name
        self.max_batch = max_batch
        self.batch_sizes = []

    def serve_batch(self, requests):
        self.batch_sizes.append(len(requests))
        return [Result(uid=r.uid, tokens=np.asarray([r.uid], np.int32),
                       prefill_s=.01, decode_s=.01, backend=self.name,
                       batch_size=len(requests)) for r in requests]

    def profile_row(self):
        return {"kind": "stub", "model": self.name,
                "max_batch": self.max_batch}


class _FailingBackend(_StubBackend):
    def serve_batch(self, requests):
        raise RuntimeError("backend exploded")


class ManualClock:
    def __init__(self):
        self.t = 100.0

    def __call__(self):
        return self.t

    def advance_ms(self, ms):
        self.t += ms / 1e3


def _req(uid, plen):
    return RouteRequest(uid=uid, complexity=plen, payload=np.arange(8),
                        max_new_tokens=4)


# ---------------------------------------------------------------- parity

@pytest.mark.asyncio
def test_async_submit_await_parity_with_sync_submit():
    """submit -> await must produce the same Served (same decisions, same
    backend results) the sync service produces for the same stream."""
    reqs = [_req(i, plen) for i, plen in enumerate(
        [1, 100, 2049, 600_000, 64, 8193])]

    sync_svc = EcoreService(PoolPolicy(_pool()),
                            lambda d: _StubBackend(d.backend, 2))
    with sync_svc:
        sync_futs = [sync_svc.submit(r) for r in reqs]
        sync_svc.drain()
        want = [f.result() for f in sync_futs]

    async def drive():
        async with AsyncEcoreService(
                PoolPolicy(_pool()),
                lambda d: _StubBackend(d.backend, 2)) as svc:
            futs = [svc.submit_nowait(r) for r in reqs]
            await svc.drain()
            return await asyncio.gather(*futs)

    got = asyncio.run(drive())
    assert len(got) == len(want)
    for g, w in zip(got, want):
        assert g.request.uid == w.request.uid
        assert g.decision.pair == w.decision.pair
        assert g.decision.group == w.decision.group
        assert g.result.backend == w.result.backend
        assert g.result.batch_size == w.result.batch_size
        np.testing.assert_array_equal(g.result.tokens, w.result.tokens)


@pytest.mark.asyncio
def test_async_submit_batch_is_one_decide_batch_call(monkeypatch):
    scalar_decides = []
    orig = PoolPolicy.decide
    monkeypatch.setattr(PoolPolicy, "decide",
                        lambda self, r: scalar_decides.append(r.uid)
                        or orig(self, r))

    async def drive():
        async with AsyncEcoreService(
                PoolPolicy(_pool()),
                lambda d: _StubBackend(d.backend, 4)) as svc:
            served = await svc.submit_batch([_req(i, 64) for i in range(4)])
            return served, svc.stats()

    served, stats = asyncio.run(drive())
    assert [s.result.uid for s in served] == [0, 1, 2, 3]
    assert scalar_decides == []            # tensorized path only
    assert stats["serve_calls"] == 1


# ----------------------------------------------------- deadline flush wakes

@pytest.mark.asyncio
@pytest.mark.threads
def test_deadline_flush_wakes_awaiting_tasks():
    """An await on a partial batch must resolve the moment the flusher
    thread serves the deadline-expired batch — the bridge crosses the
    thread boundary via call_soon_threadsafe, no polling anywhere."""
    clock = ManualClock()
    be = _StubBackend(max_batch=4)

    async def drive():
        svc = AsyncEcoreService(PoolPolicy(_pool()), lambda d: be,
                                max_wait_ms=50.0, clock=clock)
        try:
            futs = [svc.submit_nowait(_req(i, 64)) for i in range(2)]
            await asyncio.sleep(0)              # let any completions land
            assert not any(f.done() for f in futs)   # 2/4, deadline pending
            clock.advance_ms(50.1)              # oldest waited past 50 ms
            svc.wake()
            served = await asyncio.wait_for(asyncio.gather(*futs),
                                            timeout=5.0)
            assert [s.result.uid for s in served] == [0, 1]
            assert be.batch_sizes == [2]        # ONE partial deadline flush
            assert svc.deadline_flushes == 1
        finally:
            await svc.close()

    asyncio.run(drive())


# ------------------------------------------------------------ error plane

@pytest.mark.asyncio
@pytest.mark.threads
def test_backend_error_fails_awaited_future_not_the_loop():
    """A backend blowing up during a deadline flush must surface on exactly
    the awaited futures of that batch; the loop, the flusher and the other
    backends keep serving, and close() does not re-raise what the awaiter
    already consumed."""
    clock = ManualClock()

    def factory(decision):
        cls = _FailingBackend if decision.backend == "small" else _StubBackend
        return cls(decision.backend, max_batch=4)

    async def drive():
        svc = AsyncEcoreService(PoolPolicy(_pool()), factory,
                                max_wait_ms=50.0, clock=clock)
        bad = svc.submit_nowait(_req(0, 64))          # -> failing 'small'
        good = svc.submit_nowait(_req(1, 600_000))    # -> healthy 'big'
        clock.advance_ms(51)
        svc.wake()
        with pytest.raises(RuntimeError, match="backend exploded"):
            await asyncio.wait_for(bad, timeout=5.0)
        served = await asyncio.wait_for(good, timeout=5.0)
        assert served.result.uid == 1
        # the loop survived: more work to the healthy backend still serves
        fut2 = svc.submit_nowait(_req(2, 600_000))
        clock.advance_ms(51)              # manual clock: arm the deadline
        svc.wake()
        again = await asyncio.wait_for(fut2, timeout=5.0)
        assert again.result.uid == 2
        await svc.close()      # buffer_errors=False: no double-report

    asyncio.run(drive())


@pytest.mark.asyncio
def test_inline_flush_backend_error_comes_back_as_failed_future():
    """The futures-only contract also covers the INLINE path: when a submit
    fills the batch and the backend blows up during the inline flush, the
    error must come back on the returned future — never as a synchronous
    throw into the submitting coroutine."""
    async def drive():
        async with AsyncEcoreService(
                PoolPolicy(_pool()),
                lambda d: _FailingBackend(d.backend, max_batch=2)) as svc:
            f0 = svc.submit_nowait(_req(0, 64))
            f1 = svc.submit_nowait(_req(1, 64))   # fills batch -> inline boom
            with pytest.raises(RuntimeError, match="backend exploded"):
                await asyncio.wait_for(f1, timeout=5.0)
            with pytest.raises(RuntimeError, match="backend exploded"):
                await asyncio.wait_for(f0, timeout=5.0)

    asyncio.run(drive())


@pytest.mark.asyncio
def test_async_observe_closes_the_loop():
    entries = [ProfileEntry(a, "pod", b, 80.0, 1.0, energy)
               for a, energy in (("small", 1.0), ("big", 5.0))
               for _, _, b in LENGTH_BUCKETS]
    pool = ServingPool(ProfileTable(entries), delta=5.0)

    async def drive():
        async with AsyncEcoreService(
                PoolPolicy(pool, alpha=0.3),
                lambda d: _StubBackend(d.backend, 1)) as svc:
            first = await svc.submit(_req(0, 100))
            assert first.decision.backend == "small"
            for _ in range(30):    # 'small' measured far costlier
                svc.observe(Observation(pair=("small", "pod"),
                                        energy_mwh=50.0))
            second = await svc.submit(_req(1, 100))
            assert second.decision.backend == "big"

    asyncio.run(drive())


@pytest.mark.asyncio
def test_close_is_idempotent_and_submit_after_close_fails_structured():
    """The sync service's ServiceClosed mirrors through the facade: submit
    after close resolves to a FAILED future carrying it (futures-only error
    contract — never a synchronous throw into the coroutine)."""
    from repro.serving.service import ServiceClosed

    async def scenario():
        svc = AsyncEcoreService(PoolPolicy(_pool()),
                                lambda d: _StubBackend(d.backend, 1))
        await svc.submit(_req(0, 64))
        await svc.close()
        await svc.close()               # idempotent
        with pytest.raises(ServiceClosed):
            await svc.submit(_req(1, 64))

    asyncio.run(scenario())


@pytest.mark.asyncio
def test_aexit_closes_the_facade():
    from repro.serving.service import ServiceClosed

    async def scenario():
        async with AsyncEcoreService(
                PoolPolicy(_pool()),
                lambda d: _StubBackend(d.backend, 1)) as svc:
            assert (await svc.submit(_req(0, 64))).result.uid == 0
        with pytest.raises(ServiceClosed):
            await svc.submit(_req(1, 64))

    asyncio.run(scenario())

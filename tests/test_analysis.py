"""repro.analysis: per-rule good/bad fixtures + CLI surface.

Every rule family gets at least one snippet it must flag and one it must
not (the sanctioned idiom).  Fixtures are in-memory sources pushed through
``check_source``/``check_sources`` with virtual paths, so each one chooses
which plane it pretends to live in.  The CLI tests cover the acceptance
surface: JSON schema stability, nonzero exit on violation, zero exit on
the clean tree, and the suppression round-trip.

Stdlib-only: nothing here imports jax.
"""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.analysis import check_source, check_sources
from repro.analysis.cli import main
from repro.analysis.engine import match_path

pytestmark = pytest.mark.analysis

REPO = Path(__file__).resolve().parent.parent

CORE = "src/repro/core/mod.py"
SERVING = "src/repro/serving/mod.py"


def rules_of(violations):
    return [v.rule for v in violations]


def src(text):
    return textwrap.dedent(text)


# ---------------------------------------------------------------- engine


def test_match_path_anchors_relative_and_absolute():
    assert match_path("src/repro/core/router.py", ("*/repro/core/*.py",))
    assert match_path("/abs/src/repro/core/router.py",
                      ("*/repro/core/*.py",))
    assert not match_path("src/repro/serving/engine.py",
                          ("*/repro/core/*.py",))


def test_syntax_error_becomes_e001():
    vs = check_source("def broken(:\n")
    assert rules_of(vs) == ["E001"]
    assert "syntax error" in vs[0].message


# ------------------------------------------------- family 1: jit purity


def test_eco101_flags_host_sync_in_jit_scope():
    vs = check_source(src("""
        import jax

        @jax.jit
        def f(x):
            y = float(x)
            z = x.item()
            w = np.asarray(x)
            return y + z + w
    """), select=["ECO101"])
    assert rules_of(vs) == ["ECO101", "ECO101", "ECO101"]


def test_eco101_pure_function_names_are_jit_scopes():
    vs = check_source(src("""
        def decide_state(state, count):
            return int(count)
    """), select=["ECO101"])
    assert rules_of(vs) == ["ECO101"]


def test_eco101_clean_outside_jit_scope():
    vs = check_source(src("""
        def helper(x):
            return float(x.sum())
    """), select=["ECO101"])
    assert vs == []


def test_eco101_partial_jit_decorator_detected():
    vs = check_source(src("""
        import functools, jax

        @functools.partial(jax.jit, static_argnames=("n",))
        def f(x, n):
            return int(x) + n
    """), select=["ECO101"])
    assert rules_of(vs) == ["ECO101"]


def test_eco102_flags_impure_calls_in_jit_scope():
    vs = check_source(src("""
        import jax, random, time

        @jax.jit
        def f(x):
            print(x)
            t = time.time()
            r = random.random()
            return x + t + r
    """), select=["ECO102"])
    assert rules_of(vs) == ["ECO102", "ECO102", "ECO102"]


def test_eco103_flags_python_mutation_in_jit_scope():
    vs = check_source(src("""
        import jax

        @jax.jit
        def f(x, d, xs):
            global g
            d["k"] = 1
            xs.append(x)
            return x
    """), select=["ECO103"])
    assert rules_of(vs) == ["ECO103", "ECO103", "ECO103"]


def test_eco103_at_updates_and_kernel_refs_are_sanctioned():
    good = src("""
        import jax

        @jax.jit
        def f(x, i):
            return x.at[i].add(1)
    """)
    assert check_source(good, select=["ECO103"]) == []
    # pallas kernels assign o_ref[...] by design: path-exempt
    kernel = src("""
        import jax

        @jax.jit
        def kernel(o_ref, x):
            o_ref[...] = x
    """)
    assert check_source(kernel, path="src/repro/kernels/foo/foo.py",
                        select=["ECO103"]) == []


def test_eco110_flags_per_item_scalarization_in_loop():
    vs = check_source(src("""
        def f(items):
            out = []
            for s in items:
                out.append(int((s >= 0.5).sum()))
            return out
    """), path=CORE, select=["ECO110"])
    assert rules_of(vs) == ["ECO110"]


def test_eco110_np_rooted_and_unlooped_reductions_are_fine():
    vs = check_source(src("""
        import numpy as np

        def f(items, x):
            out = [int(np.count_nonzero(s >= 0.5)) for s in items]
            depths = [int(np.argmin(s)) for s in items]
            total = int(x.sum())
            return out, depths, total
    """), path=CORE, select=["ECO110"])
    assert vs == []


# ------------------------------------------ family 2: hot-path discipline


def test_eco201_flags_python_loop_in_hot_function():
    vs = check_source(src("""
        def route_batch(counts):
            out = []
            for c in counts:
                out.append(c)
            return out
    """), path="src/repro/core/router.py", select=["ECO201"])
    assert rules_of(vs) == ["ECO201"]


def test_eco201_literal_unrolls_and_cold_functions_are_fine():
    vs = check_source(src("""
        def route_batch(x):
            for name in ("a", "b"):
                x += len(name)
            return x

        def cold_helper(xs):
            for x in xs:
                pass
    """), path="src/repro/core/router.py", select=["ECO201"])
    assert vs == []


def test_eco202_flags_profile_facade_in_hot_module():
    vs = check_source(src("""
        def f(table, state):
            table.observe("pair", 1, time_ms=2.0)
            table.entries[0] = None
            return table.load_state(state)
    """), path="src/repro/core/closed_loop.py", select=["ECO202"])
    assert rules_of(vs) == ["ECO202", "ECO202", "ECO202"]


def test_eco203_flags_serve_batch_outside_dispatch_plane():
    snippet = "def f(be, reqs):\n    return be.serve_batch(reqs)\n"
    assert rules_of(check_source(snippet, path="src/repro/core/driver.py",
                                 select=["ECO203"])) == ["ECO203"]
    # the dispatch plane itself and tests/ are sanctioned
    assert check_source(snippet, path="src/repro/serving/engine.py",
                        select=["ECO203"]) == []
    assert check_source(snippet, path="tests/test_x.py",
                        select=["ECO203"]) == []


# ------------------------------------------- family 3: thread/async safety


def test_eco301_flags_blocking_calls_under_lock():
    vs = check_source(src("""
        import time

        def f(self, fut):
            with self._lock:
                r = fut.result()
                time.sleep(0.1)
            return r
    """), path=SERVING, select=["ECO301"])
    assert rules_of(vs) == ["ECO301", "ECO301"]


def test_eco301_condition_wait_is_sanctioned():
    vs = check_source(src("""
        def f(self):
            with self._cond:
                self._cond.wait(0.5)
    """), path=SERVING, select=["ECO301"])
    assert vs == []


def test_eco302_flags_future_completion_off_loop():
    vs = check_source(src("""
        def f(loop):
            afut = loop.create_future()
            afut.set_result(1)
            return afut
    """), path=SERVING, select=["ECO302"])
    assert rules_of(vs) == ["ECO302"]


def test_eco302_call_soon_threadsafe_callback_is_sanctioned():
    vs = check_source(src("""
        def bridge(loop, cfut):
            afut = loop.create_future()

            def _copy():
                afut.set_result(cfut.result())

            loop.call_soon_threadsafe(_copy)
            return afut
    """), path=SERVING, select=["ECO302"])
    assert vs == []


def test_eco303_flags_blind_except_shapes():
    vs = check_source(src("""
        def f():
            try:
                g()
            except:
                h()
            try:
                g()
            except BaseException:
                h()
            try:
                g()
            except ValueError:
                pass
    """), path=SERVING, select=["ECO303"])
    assert rules_of(vs) == ["ECO303", "ECO303", "ECO303"]
    good = src("""
        def f(log):
            try:
                g()
            except Exception as exc:
                log(exc)
    """)
    assert check_source(good, path=SERVING, select=["ECO303"]) == []


def test_eco304_flags_wall_clock_sleep_and_unbounded_spin():
    vs = check_source(src("""
        import time
        from time import sleep

        def retry(fn):
            while True:
                try:
                    return fn()
                except Exception:
                    time.sleep(0.5)

        def poll(q):
            while True:
                sleep(0.01)
                q.flush()
    """), path=SERVING, select=["ECO304"])
    # retry's loop has a return (bounded); poll's does not — plus the two
    # sleeps themselves
    assert rules_of(vs) == ["ECO304", "ECO304", "ECO304"]


def test_eco304_condition_wait_loop_with_exit_is_sanctioned():
    vs = check_source(src("""
        def retry_loop(self):
            while True:
                with self._cond:
                    if self._closed:
                        return
                    self._cond.wait(0.05)
    """), path=SERVING, select=["ECO304"])
    assert vs == []


def test_eco304_nested_loop_break_does_not_bound_outer():
    vs = check_source(src("""
        def pump(self):
            while True:
                for item in self._queue:
                    if item is None:
                        break
    """), path=SERVING, select=["ECO304"])
    assert rules_of(vs) == ["ECO304"]


def test_eco304_covers_traffic_plane():
    # the traffic plane is virtual-time by contract: wall-clock sleeps are
    # flagged there exactly like in serving, with the same suppression
    TRAFFIC = "src/repro/traffic/mod.py"
    sleepy = src("""
        import time

        def pace(self, dt):
            time.sleep(dt)
    """)
    assert rules_of(check_source(sleepy, path=TRAFFIC,
                                 select=["ECO304"])) == ["ECO304"]
    suppressed = src("""
        import time

        def pace(self, dt):
            # repro-lint: disable=ECO304 -- wall-clock pacing demo
            time.sleep(dt)
    """)
    assert check_source(suppressed, path=TRAFFIC, select=["ECO304"]) == []
    # the OTHER serving rules stay serving-only: the traffic plane has no
    # flusher thread to protect
    assert check_source(src("""
        def f():
            try:
                g()
            except:
                pass
    """), path=TRAFFIC, select=["ECO303"]) == []


def test_eco304_only_applies_to_serving_and_suppression_works():
    sleepy = src("""
        import time

        def bench():
            time.sleep(1.0)
    """)
    assert check_source(sleepy, path=CORE, select=["ECO304"]) == []
    suppressed = src("""
        import time

        def simulate(self, ms):
            # repro-lint: disable=ECO304 -- simulated device busy time
            time.sleep(ms / 1e3)
    """)
    assert check_source(suppressed, path=SERVING, select=["ECO304"]) == []


# ---------------------------------------------- family 4: kernel contract


def _kernel_files(**overrides):
    files = {
        "src/repro/kernels/foo/__init__.py": "from .ops import foo\n",
        "src/repro/kernels/foo/ops.py": "def foo(x):\n    return x\n",
        "src/repro/kernels/foo/ref.py": "import jax.numpy as jnp\n",
        "tests/test_foo.py": "import repro.kernels.foo\n",
    }
    files.update(overrides)
    return {k: v for k, v in files.items() if v is not None}


def test_eco4xx_complete_kernel_package_is_clean():
    report = check_sources(_kernel_files(), select=["ECO4"])
    assert report.violations == []


def test_eco401_missing_init():
    report = check_sources(
        _kernel_files(**{"src/repro/kernels/foo/__init__.py": None}),
        select=["ECO401"])
    assert rules_of(report.violations) == ["ECO401"]
    assert report.violations[0].path.endswith("foo/__init__.py")


def test_eco402_missing_ref():
    report = check_sources(
        _kernel_files(**{"src/repro/kernels/foo/ref.py": None}),
        select=["ECO402"])
    assert rules_of(report.violations) == ["ECO402"]
    assert "ref.py" in report.violations[0].message


def test_eco403_kernel_without_parity_test():
    report = check_sources(
        _kernel_files(**{"tests/test_foo.py": "import repro.core\n"}),
        select=["ECO403"])
    assert rules_of(report.violations) == ["ECO403"]
    # no tests collected at all -> nothing to assert, no violation
    report = check_sources(
        _kernel_files(**{"tests/test_foo.py": None}), select=["ECO403"])
    assert report.violations == []


def test_eco404_oracle_importing_pallas():
    ref = "from jax.experimental import pallas as pl\n"
    report = check_sources(
        _kernel_files(**{"src/repro/kernels/foo/ref.py": ref}),
        select=["ECO404"])
    assert rules_of(report.violations) == ["ECO404"]


def test_eco405_flags_shape_guarded_impl_rewrite():
    ops = src("""
        from . import ref

        def foo(img, *, impl="auto"):
            if impl == "auto":
                from .kern import MAX_WIDTH
                impl = "pallas"
                if img.shape[-1] > MAX_WIDTH:
                    impl = "xla"
            if impl == "xla":
                return ref.foo(img)
            return img
    """)
    report = check_sources(
        _kernel_files(**{"src/repro/kernels/foo/ops.py": ops}),
        select=["ECO405"])
    assert rules_of(report.violations) == ["ECO405"]
    assert "silently falls back" in report.violations[0].message


def test_eco405_flags_shape_guarded_oracle_return():
    ops = src("""
        from . import ref

        def foo(img):
            if img.shape[-1] > 4096:
                return ref.foo(img)
            return img
    """)
    report = check_sources(
        _kernel_files(**{"src/repro/kernels/foo/ops.py": ops}),
        select=["ECO405"])
    assert rules_of(report.violations) == ["ECO405"]


def test_eco405_clean_dispatch_and_justified_fallback_pass():
    # backend choice alone (no geometry in the test) is sanctioned...
    ops = src("""
        from . import ref
        import jax

        def foo(img, *, impl="auto"):
            if impl == "auto":
                impl = "pallas" if jax.default_backend() == "tpu" else "xla"
            if impl == "xla":
                return ref.foo(img)
            return img
    """)
    report = check_sources(
        _kernel_files(**{"src/repro/kernels/foo/ops.py": ops}),
        select=["ECO405"])
    assert report.violations == []
    # ...and a justified shape fallback is suppressed, not silent
    ops = src("""
        from . import ref

        def foo(img, *, impl="auto"):
            # repro-lint: disable=ECO405 -- interpret mode cannot fit 8K
            if img.shape[-1] > 8192:
                return ref.foo(img)
            return img
    """)
    report = check_sources(
        _kernel_files(**{"src/repro/kernels/foo/ops.py": ops}),
        select=["ECO405"])
    assert report.violations == []
    assert report.suppressed == 1


# --------------------------------------------- family 5: environment pins


def test_eco501_axistype_access_and_import():
    vs = check_source(src("""
        import jax
        from jax.sharding import AxisType

        def f():
            return jax.sharding.AxisType.Auto
    """), select=["ECO501"])
    assert rules_of(vs) == ["ECO501", "ECO501"]
    # the version-gated getattr idiom is the sanctioned form
    good = "import jax\nx = getattr(jax.sharding, 'AxisType', None)\n"
    assert check_source(good, select=["ECO501"]) == []


def test_eco502_bare_make_mesh():
    vs = check_source(src("""
        import jax
        from jax import make_mesh

        def f():
            return jax.make_mesh((1,), ("x",))
    """), select=["ECO502"])
    assert rules_of(vs) == ["ECO502", "ECO502"]


def test_eco503_hypothesis_imports():
    vs = check_source(src("""
        import hypothesis
        import hypothesis.strategies as st
        from hypothesis import given
    """), select=["ECO503"])
    assert rules_of(vs) == ["ECO503", "ECO503", "ECO503"]


# ------------------------------------------------------------ suppressions


def test_suppression_inline_and_standalone_roundtrip():
    bad = "from hypothesis import given\n"
    assert rules_of(check_source(bad, select=["ECO503"])) == ["ECO503"]

    inline = "from hypothesis import given  # repro-lint: disable=ECO503\n"
    report = check_sources({"x.py": inline}, select=["ECO503"])
    assert report.violations == [] and report.suppressed == 1

    standalone = src("""
        # repro-lint: disable=ECO503 -- exercising the shim fallback;
        # a justification block may run on before the flagged line
        from hypothesis import given
    """)
    report = check_sources({"x.py": standalone}, select=["ECO503"])
    assert report.violations == [] and report.suppressed == 1


def test_suppression_is_per_rule_and_file_wide_forms():
    # suppressing a DIFFERENT rule must not hide the finding
    wrong = "from hypothesis import given  # repro-lint: disable=ECO502\n"
    assert rules_of(check_source(wrong, select=["ECO503"])) == ["ECO503"]

    file_wide = ("# repro-lint: disable-file=ECO503\n"
                 "from hypothesis import given\n"
                 "import hypothesis\n")
    report = check_sources({"x.py": file_wide}, select=["ECO503"])
    assert report.violations == [] and report.suppressed == 2


# -------------------------------------------------------------------- CLI


def _write(tmp_path, name, text):
    p = tmp_path / name
    p.write_text(text)
    return p


def test_cli_nonzero_exit_and_text_output(tmp_path, capsys):
    bad = _write(tmp_path, "bad.py", "from hypothesis import given\n")
    assert main([str(bad)]) == 1
    out = capsys.readouterr().out
    assert "ECO503" in out and "bad.py:1:" in out


def test_cli_zero_exit_on_clean_file(tmp_path, capsys):
    clean = _write(tmp_path, "clean.py", "x = 1\n")
    assert main([str(clean)]) == 0
    assert "0 violations" in capsys.readouterr().out


def test_cli_json_schema(tmp_path, capsys):
    bad = _write(tmp_path, "bad.py", "from hypothesis import given\n")
    assert main([str(bad), "--format", "json"]) == 1
    doc = json.loads(capsys.readouterr().out)
    assert set(doc) == {"version", "files", "rules", "violations",
                        "counts", "suppressed"}
    assert doc["version"] == 1
    assert doc["files"] == 1
    assert doc["counts"] == {"ECO503": 1}
    assert doc["suppressed"] == 0
    (v,) = doc["violations"]
    assert set(v) == {"rule", "path", "line", "col", "message"}
    assert (v["rule"], v["line"]) == ("ECO503", 1)


def test_cli_suppression_roundtrip(tmp_path, capsys):
    _write(tmp_path, "bad.py",
           "from hypothesis import given  # repro-lint: disable=ECO503\n")
    assert main([str(tmp_path), "--format", "json"]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert doc["violations"] == [] and doc["suppressed"] == 1


def test_cli_select_and_ignore(tmp_path, capsys):
    bad = _write(tmp_path, "bad.py", "from hypothesis import given\n")
    assert main([str(bad), "--select", "ECO1"]) == 0
    capsys.readouterr()
    assert main([str(bad), "--ignore", "ECO503"]) == 0
    capsys.readouterr()
    assert main([str(bad), "--select", "ECO5"]) == 1
    capsys.readouterr()


def test_cli_list_rules_and_usage_errors(tmp_path, capsys):
    assert main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for family_rep in ("ECO101", "ECO201", "ECO301", "ECO401", "ECO501"):
        assert family_rep in out
    assert main([str(tmp_path / "nope")]) == 2


def test_cli_clean_on_this_repo(capsys):
    """The acceptance gate, in-process: the final tree lints clean."""
    paths = [str(REPO / d) for d in ("src", "tests", "benchmarks",
                                     "examples") if (REPO / d).exists()]
    assert main(paths) == 0, capsys.readouterr().out


def test_module_entrypoint_subprocess(tmp_path):
    """``python -m repro.analysis`` works and exit codes propagate."""
    bad = _write(tmp_path, "bad.py", "import jax\nx = jax.make_mesh((1,))\n")
    env = {"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"}
    r = subprocess.run([sys.executable, "-m", "repro.analysis", str(bad)],
                       capture_output=True, text=True, timeout=120, env=env)
    assert r.returncode == 1, r.stderr
    assert "ECO502" in r.stdout


# --------------------------------------- project graph (src/repro/analysis)


def _project(named):
    from repro.analysis.engine import parse_source
    from repro.analysis.project import build_project
    sources = []
    for path, text in named.items():
        s, err = parse_source(path, textwrap.dedent(text))
        assert err is None, err
        sources.append(s)
    return build_project(sources)


def test_project_call_cycles_terminate():
    proj = _project({"src/repro/core/mod.py": """
        import threading
        _lock = threading.Lock()

        def a():
            with _lock:
                pass
            return b()

        def b():
            return a()
    """})
    fa = proj.functions["repro.core.mod:a"]
    fb = proj.functions["repro.core.mod:b"]
    reach = proj.reachable([fa])
    assert set(reach) == {"repro.core.mod:a", "repro.core.mod:b"}
    # fix-points terminate on the a <-> b cycle and still see a's lock
    assert "repro.core.mod._lock" in proj.acquired_closure(fb)
    assert proj.may_block(fa) is None


def test_project_resolves_aliased_imports():
    proj = _project({
        "src/repro/pkgx/util.py": """
            def helper():
                return 1
        """,
        "src/repro/pkgx/mainmod.py": """
            from repro.pkgx.util import helper as h

            def run():
                return h()
        """})
    (call,) = [c for c in
               proj.functions["repro.pkgx.mainmod:run"].calls
               if c.target is not None]
    assert call.target.qualname == "repro.pkgx.util:helper"


def test_project_resolves_self_methods_and_opaque_calls():
    proj = _project({"src/repro/serving/mod.py": """
        class Svc:
            def top(self):
                self.unknown_external.thing()
                return self.inner()

            def inner(self):
                return 1
    """})
    calls = proj.functions["repro.serving.mod:Svc.top"].calls
    resolved = [c.target.qualname for c in calls if c.target is not None]
    assert resolved == ["repro.serving.mod:Svc.inner"]
    # the unresolved receiver stays opaque: a call site with no edge
    assert any(c.target is None for c in calls)


# ----------------------------- family 12: transitive purity (ECO120/121)


def test_eco120_host_sync_reached_through_call_chain():
    bad = src("""
        import jax
        import numpy as np

        @jax.jit
        def entry(x):
            return helper(x)

        def helper(x):
            return np.sum(x)
    """)
    report = check_sources({CORE: bad}, select=["ECO120"], project=True)
    assert rules_of(report.violations) == ["ECO120"]
    assert "entry -> " in report.violations[0].message

    good = src("""
        import jax
        import jax.numpy as jnp

        @jax.jit
        def entry(x):
            return helper(x)

        def helper(x):
            return jnp.sum(x)
    """)
    assert check_sources({CORE: good}, select=["ECO120"],
                         project=True).violations == []


def test_eco120_without_project_flag_stays_silent():
    bad = "import numpy as np\n\ndef add_pair(s):\n    return np.sum(s)\n"
    assert check_sources({CORE: bad}, select=["ECO120"]).violations == []


def test_eco120_follows_factory_and_scan_step_chain():
    # the scan_stream shape: a factory returns a jit kernel whose step
    # function is passed to lax.scan by VALUE — a deferred edge the walk
    # must still follow into the helper
    bad = src("""
        import jax
        from jax import lax

        def _factory():
            @jax.jit
            def kernel(xs):
                def step(c, x):
                    return helper(c), x
                return lax.scan(step, 0, xs)
            return kernel

        def helper(c):
            return int(c)
    """)
    report = check_sources({CORE: bad}, select=["ECO120"], project=True)
    assert rules_of(report.violations) == ["ECO120"]
    assert "kernel -> " in report.violations[0].message


def test_eco120_transitive_root_bodies_are_scanned():
    bad = "def add_pair(state):\n    return int(state.max())\n"
    report = check_sources({CORE: bad}, select=["ECO120"], project=True)
    assert rules_of(report.violations) == ["ECO120"]


def test_eco121_impure_call_reached_through_call_chain():
    bad = src("""
        import jax
        import time

        @jax.jit
        def entry(x):
            return helper(x)

        def helper(x):
            return x * time.time()
    """)
    report = check_sources({CORE: bad}, select=["ECO121"], project=True)
    assert rules_of(report.violations) == ["ECO121"]

    good = bad.replace("time.time()", "2.0")
    assert check_sources({CORE: good}, select=["ECO121"],
                         project=True).violations == []


# ------------------------------- family 6: concurrency (ECO601/602/603)


def test_eco601_lock_order_inversion_across_calls():
    bad = src("""
        import threading

        class Pool:
            def __init__(self):
                self._lock = threading.Lock()
                self._cond = threading.Condition()

            def a(self):
                with self._lock:
                    self.takes_cond()

            def takes_cond(self):
                with self._cond:
                    pass

            def b(self):
                with self._cond:
                    with self._lock:
                        pass
    """)
    report = check_sources({SERVING: bad}, select=["ECO601"], project=True)
    assert rules_of(report.violations) == ["ECO601"]
    assert "inversion" in report.violations[0].message

    good = bad.replace("with self._cond:\n            with self._lock:",
                       "with self._lock:\n            with self._cond:")
    assert good != bad
    assert check_sources({SERVING: good}, select=["ECO601"],
                         project=True).violations == []


def test_eco602_blocking_reachable_under_lock():
    bad = src("""
        import threading

        class Svc:
            def __init__(self):
                self._lock = threading.Lock()

            def close(self):
                with self._lock:
                    self._stop()

            def _stop(self):
                self.fut.result()
    """)
    report = check_sources({SERVING: bad}, select=["ECO602"], project=True)
    assert rules_of(report.violations) == ["ECO602"]
    assert "_stop" in report.violations[0].message

    good = src("""
        import threading

        class Svc:
            def __init__(self):
                self._lock = threading.Lock()

            def close(self):
                with self._lock:
                    fut = self.fut
                self._stop(fut)

            def _stop(self, fut):
                fut.result()
    """)
    assert check_sources({SERVING: good}, select=["ECO602"],
                         project=True).violations == []


def test_eco602_lexical_drain_under_lock_and_sanctioned_wait():
    bad = src("""
        class Cluster:
            def retire(self):
                with self._lock:
                    self.pod.drain()
    """)
    report = check_sources({SERVING: bad}, select=["ECO602"], project=True)
    assert rules_of(report.violations) == ["ECO602"]

    # Condition.wait on the lock being held is the consumer idiom
    good = src("""
        class Svc:
            def wait_done(self):
                with self._cond:
                    while not self.done:
                        self._cond.wait(0.1)
    """)
    assert check_sources({SERVING: good}, select=["ECO602"],
                         project=True).violations == []


def test_eco603_future_completed_from_thread_entry():
    bad = src("""
        import threading

        class Bridge:
            def __init__(self, loop):
                self.loop = loop
                self.fut = loop.create_future()
                threading.Thread(target=self._worker).start()

            def _worker(self):
                self._finish()

            def _finish(self):
                self.fut.set_result(1)
    """)
    report = check_sources({SERVING: bad}, select=["ECO603"], project=True)
    assert rules_of(report.violations) == ["ECO603"]
    assert "_worker" in report.violations[0].message

    good = bad.replace("self._finish()",
                       "self.loop.call_soon_threadsafe(self._finish)")
    assert check_sources({SERVING: good}, select=["ECO603"],
                         project=True).violations == []


# ------------------------------ family 7: contracts (ECO701/702/703/704)


def test_eco701_backend_conformance():
    bad = src("""
        from repro.serving.backend import register_backend

        class Bad:
            name = "bad"
            max_batch = 4

            def serve_batch(self):
                return []

            def profile_row(self):
                return {}

        register_backend("bad", Bad)
    """)
    report = check_sources({SERVING: bad}, select=["ECO701"], project=True)
    assert rules_of(report.violations) == ["ECO701"]
    assert "serve_batch" in report.violations[0].message

    duck = src("""
        class Duck:
            def serve_batch(self, requests):
                return list(requests)

            def profile_row(self):
                return {}
    """)
    report = check_sources({SERVING: duck}, select=["ECO701"], project=True)
    assert sorted(rules_of(report.violations)) == ["ECO701", "ECO701"]
    assert {("name" in v.message or "max_batch" in v.message)
            for v in report.violations} == {True}

    good = src("""
        class Good:
            def __init__(self, name):
                self.name = name
                self.max_batch = 8

            def serve_batch(self, requests):
                return list(requests)

            def profile_row(self):
                return {"name": self.name}
    """)
    assert check_sources({SERVING: good}, select=["ECO701"],
                         project=True).violations == []


def test_eco702_policy_conformance():
    bad = src("""
        class HalfPolicy:
            batchable = False

            def decide(self, request):
                return request

            def observe(self, observation):
                pass
    """)
    report = check_sources({CORE: bad}, select=["ECO702"], project=True)
    assert rules_of(report.violations) == ["ECO702", "ECO702"]

    good = src("""
        class FullPolicy:
            batchable = False

            def decide(self, request):
                return request

            def decide_batch(self, requests):
                return [None for _ in requests]

            def observe(self, observation):
                pass

            def reset(self):
                pass
    """)
    assert check_sources({CORE: good}, select=["ECO702"],
                         project=True).violations == []


def test_eco703_batchable_honesty():
    looped = src("""
        class P:
            batchable = %s

            def decide(self, request):
                return request

            def decide_batch(self, requests):
                return [self.decide(r) for r in requests]

            def observe(self, observation):
                pass

            def reset(self):
                pass
    """)
    report = check_sources({CORE: looped % "True"}, select=["ECO703"],
                           project=True)
    assert rules_of(report.violations) == ["ECO703"]
    # an honest batchable = False may loop all it wants
    assert check_sources({CORE: looped % "False"}, select=["ECO703"],
                         project=True).violations == []


def _contract_kernel(ops):
    return {
        "src/repro/kernels/foo/__init__.py": "",
        "src/repro/kernels/foo/ops.py": src(ops),
        "src/repro/kernels/foo/ref.py": src("""
            def run(x, scale=1.0):
                return x * scale
        """),
    }


def test_eco704_entry_without_oracle_dispatch():
    report = check_sources(_contract_kernel("""
        from . import ref

        def run(x):
            return x + 1
    """), select=["ECO704"], project=True)
    assert rules_of(report.violations) == ["ECO704"]
    assert "never dispatches" in report.violations[0].message


def test_eco704_signature_mismatches():
    report = check_sources(_contract_kernel("""
        from . import ref

        def run(x):
            return ref.run(x, mode=3)

        def gone(x):
            return ref.vanished(x)
    """), select=["ECO704"], project=True)
    assert rules_of(report.violations) == ["ECO704", "ECO704"]
    msgs = " | ".join(v.message for v in report.violations)
    assert "mode" in msgs and "vanished" in msgs


def test_eco704_conforming_dispatch_and_jit_alias():
    report = check_sources(_contract_kernel("""
        import jax
        from . import ref

        def run(x, scale=1.0):
            return ref.run(x, scale=scale)

        run_fast = jax.jit(ref.run)
    """), select=["ECO704"], project=True)
    assert report.violations == []

    report = check_sources(_contract_kernel("""
        import jax
        from . import ref

        def run(x):
            return ref.run(x)

        broken = jax.jit(ref.vanished)
    """), select=["ECO704"], project=True)
    assert rules_of(report.violations) == ["ECO704"]


# ----------------------------- family 9: suppression hygiene (ECO900)


def test_eco900_flags_unused_suppression():
    report = check_sources(
        {"x.py": "x = 1  # repro-lint: disable=ECO503\n"},
        select=["ECO900", "ECO503"], project=True)
    assert rules_of(report.violations) == ["ECO900"]
    assert "no ECO503 finding" in report.violations[0].message


def test_eco900_used_suppression_is_silent():
    report = check_sources(
        {"x.py": "from hypothesis import given"
                 "  # repro-lint: disable=ECO503\n"},
        select=["ECO900", "ECO503"], project=True)
    assert report.violations == [] and report.suppressed == 1


def test_eco900_unknown_id_and_blanket_marker():
    report = check_sources(
        {"x.py": "# repro-lint: disable=ECO999 -- typo\nx = 1\n"},
        select=["ECO900"], project=True)
    assert rules_of(report.violations) == ["ECO900"]
    assert "ECO999" in report.violations[0].message

    report = check_sources(
        {"x.py": "x = 1  # repro-lint: disable=all\n"},
        select=["ECO900", "ECO503"], project=True)
    assert rules_of(report.violations) == ["ECO900"]


def test_eco900_skips_ids_of_disabled_rules():
    # under --select there is no way to judge a marker for a rule that
    # did not run, so it must not be called unused
    report = check_sources(
        {"x.py": "x = 1  # repro-lint: disable=ECO503\n"},
        select=["ECO900"], project=True)
    assert report.violations == []


# ------------------------------------------- suppression parsing edges


def test_suppression_standalone_above_decorated_def():
    # ECO702 reports at the class line; the marker sits above the
    # decorator stack and must cover the decorated line too
    fixture = src("""
        import dataclasses

        # repro-lint: disable=ECO702 -- intentionally partial face
        @dataclasses.dataclass
        class Partial:
            batchable: bool = False

            def decide(self, request):
                return request

            def observe(self, observation):
                pass
    """)
    report = check_sources({CORE: fixture}, select=["ECO702"], project=True)
    assert report.violations == [] and report.suppressed == 2


def test_suppression_multiple_ids_in_one_marker():
    fixture = src("""
        import jax
        import time

        @jax.jit
        def f(x):
            # repro-lint: disable=ECO101, ECO102 -- fixture for both
            y = float(time.time())
            return x + y
    """)
    report = check_sources({CORE: fixture}, select=["ECO101", "ECO102"])
    assert report.violations == [] and report.suppressed == 2


def test_suppression_disable_file_mid_file():
    fixture = ("import hypothesis\n"
               "# repro-lint: disable-file=ECO503\n"
               "from hypothesis import given\n")
    report = check_sources({"x.py": fixture}, select=["ECO503"])
    assert report.violations == [] and report.suppressed == 2


def test_suppression_marker_inside_string_is_inert():
    fixture = ('"""docs quoting the grammar:\n\n'
               "    # repro-lint: disable-file=ECO503\n"
               '"""\n'
               "import hypothesis\n")
    report = check_sources({"x.py": fixture}, select=["ECO503"])
    assert rules_of(report.violations) == ["ECO503"]
    assert report.suppressed == 0


# ------------------------------------------------------- CLI (project era)


def test_run_paths_skips_pycache_hidden_and_non_utf8(tmp_path, capsys):
    pkg = tmp_path / "pkg"
    cache = pkg / "__pycache__"
    hidden = tmp_path / ".hidden"
    for d in (pkg, cache, hidden):
        d.mkdir()
    (pkg / "good.py").write_text("x = 1\n")
    (cache / "stale.py").write_text("from hypothesis import given\n")
    (hidden / "secret.py").write_text("from hypothesis import given\n")
    (pkg / "blob.py").write_bytes(b"\xff\xfe\x00 not utf8")
    assert main([str(tmp_path)]) == 0
    out = capsys.readouterr().out
    assert "1 files" in out


def test_cli_format_github_annotations(tmp_path, capsys):
    bad = _write(tmp_path, "bad.py", "from hypothesis import given\n")
    assert main([str(bad), "--format", "github"]) == 1
    out = capsys.readouterr().out
    assert "::error file=" in out
    assert "line=1," in out and "ECO503" in out


def test_cli_report_file_written_regardless_of_format(tmp_path, capsys):
    bad = _write(tmp_path, "bad.py", "from hypothesis import given\n")
    dest = tmp_path / "lint-report.json"
    assert main([str(bad), "--format", "github",
                 "--report", str(dest)]) == 1
    capsys.readouterr()
    doc = json.loads(dest.read_text())
    assert doc["version"] == 1
    assert doc["counts"] == {"ECO503": 1}


def test_cli_project_flag_enables_interprocedural_rules(tmp_path, capsys):
    bad = _write(tmp_path, "mod.py", src("""
        import jax
        import numpy as np

        @jax.jit
        def entry(x):
            return helper(x)

        def helper(x):
            return np.sum(x)
    """))
    assert main([str(bad)]) == 0
    capsys.readouterr()
    assert main([str(bad), "--project"]) == 1
    assert "ECO120" in capsys.readouterr().out


def test_cli_project_clean_and_fast_on_this_repo(capsys):
    """The acceptance gate: the whole-tree interprocedural pass is clean
    and completes well inside the 5 s budget."""
    import time
    paths = [str(REPO / d) for d in ("src", "tests", "benchmarks",
                                     "examples") if (REPO / d).exists()]
    t0 = time.monotonic()
    rc = main(["--project", *paths])
    elapsed = time.monotonic() - t0
    assert rc == 0, capsys.readouterr().out
    assert elapsed < 5.0, f"--project pass took {elapsed:.2f}s"


def test_cli_list_rules_markdown_and_rules_md_drift(capsys):
    from repro.analysis.cli import catalogue_markdown
    assert main(["--list-rules", "--format", "markdown"]) == 0
    out = capsys.readouterr().out
    assert out == catalogue_markdown()
    for rid in ("ECO120", "ECO601", "ECO701", "ECO900"):
        assert rid in out
    # docs/RULES.md is generated from this exact output
    assert (REPO / "docs" / "RULES.md").read_text() == out
    # --format markdown without --list-rules is a usage error
    assert main([str(REPO / "src" / "repro" / "analysis"),
                 "--format", "markdown"]) == 2
    capsys.readouterr()

"""repro.analysis: per-rule good/bad fixtures + CLI surface.

Every rule family gets at least one snippet it must flag and one it must
not (the sanctioned idiom).  Fixtures are in-memory sources pushed through
``check_source``/``check_sources`` with virtual paths, so each one chooses
which plane it pretends to live in.  The CLI tests cover the acceptance
surface: JSON schema stability, nonzero exit on violation, zero exit on
the clean tree, and the suppression round-trip.

Stdlib-only: nothing here imports jax.
"""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.analysis import check_source, check_sources
from repro.analysis.cli import main
from repro.analysis.engine import match_path

pytestmark = pytest.mark.analysis

REPO = Path(__file__).resolve().parent.parent

CORE = "src/repro/core/mod.py"
SERVING = "src/repro/serving/mod.py"


def rules_of(violations):
    return [v.rule for v in violations]


def src(text):
    return textwrap.dedent(text)


# ---------------------------------------------------------------- engine


def test_match_path_anchors_relative_and_absolute():
    assert match_path("src/repro/core/router.py", ("*/repro/core/*.py",))
    assert match_path("/abs/src/repro/core/router.py",
                      ("*/repro/core/*.py",))
    assert not match_path("src/repro/serving/engine.py",
                          ("*/repro/core/*.py",))


def test_syntax_error_becomes_e001():
    vs = check_source("def broken(:\n")
    assert rules_of(vs) == ["E001"]
    assert "syntax error" in vs[0].message


# ------------------------------------------------- family 1: jit purity


def test_eco101_flags_host_sync_in_jit_scope():
    vs = check_source(src("""
        import jax

        @jax.jit
        def f(x):
            y = float(x)
            z = x.item()
            w = np.asarray(x)
            return y + z + w
    """), select=["ECO101"])
    assert rules_of(vs) == ["ECO101", "ECO101", "ECO101"]


def test_eco101_pure_function_names_are_jit_scopes():
    vs = check_source(src("""
        def decide_state(state, count):
            return int(count)
    """), select=["ECO101"])
    assert rules_of(vs) == ["ECO101"]


def test_eco101_clean_outside_jit_scope():
    vs = check_source(src("""
        def helper(x):
            return float(x.sum())
    """), select=["ECO101"])
    assert vs == []


def test_eco101_partial_jit_decorator_detected():
    vs = check_source(src("""
        import functools, jax

        @functools.partial(jax.jit, static_argnames=("n",))
        def f(x, n):
            return int(x) + n
    """), select=["ECO101"])
    assert rules_of(vs) == ["ECO101"]


def test_eco102_flags_impure_calls_in_jit_scope():
    vs = check_source(src("""
        import jax, random, time

        @jax.jit
        def f(x):
            print(x)
            t = time.time()
            r = random.random()
            return x + t + r
    """), select=["ECO102"])
    assert rules_of(vs) == ["ECO102", "ECO102", "ECO102"]


def test_eco103_flags_python_mutation_in_jit_scope():
    vs = check_source(src("""
        import jax

        @jax.jit
        def f(x, d, xs):
            global g
            d["k"] = 1
            xs.append(x)
            return x
    """), select=["ECO103"])
    assert rules_of(vs) == ["ECO103", "ECO103", "ECO103"]


def test_eco103_at_updates_and_kernel_refs_are_sanctioned():
    good = src("""
        import jax

        @jax.jit
        def f(x, i):
            return x.at[i].add(1)
    """)
    assert check_source(good, select=["ECO103"]) == []
    # pallas kernels assign o_ref[...] by design: path-exempt
    kernel = src("""
        import jax

        @jax.jit
        def kernel(o_ref, x):
            o_ref[...] = x
    """)
    assert check_source(kernel, path="src/repro/kernels/foo/foo.py",
                        select=["ECO103"]) == []


def test_eco110_flags_per_item_scalarization_in_loop():
    vs = check_source(src("""
        def f(items):
            out = []
            for s in items:
                out.append(int((s >= 0.5).sum()))
            return out
    """), path=CORE, select=["ECO110"])
    assert rules_of(vs) == ["ECO110"]


def test_eco110_np_rooted_and_unlooped_reductions_are_fine():
    vs = check_source(src("""
        import numpy as np

        def f(items, x):
            out = [int(np.count_nonzero(s >= 0.5)) for s in items]
            depths = [int(np.argmin(s)) for s in items]
            total = int(x.sum())
            return out, depths, total
    """), path=CORE, select=["ECO110"])
    assert vs == []


# ------------------------------------------ family 2: hot-path discipline


def test_eco201_flags_python_loop_in_hot_function():
    vs = check_source(src("""
        def route_batch(counts):
            out = []
            for c in counts:
                out.append(c)
            return out
    """), path="src/repro/core/router.py", select=["ECO201"])
    assert rules_of(vs) == ["ECO201"]


def test_eco201_literal_unrolls_and_cold_functions_are_fine():
    vs = check_source(src("""
        def route_batch(x):
            for name in ("a", "b"):
                x += len(name)
            return x

        def cold_helper(xs):
            for x in xs:
                pass
    """), path="src/repro/core/router.py", select=["ECO201"])
    assert vs == []


def test_eco202_flags_profile_facade_in_hot_module():
    vs = check_source(src("""
        def f(table, state):
            table.observe("pair", 1, time_ms=2.0)
            table.entries[0] = None
            return table.load_state(state)
    """), path="src/repro/core/closed_loop.py", select=["ECO202"])
    assert rules_of(vs) == ["ECO202", "ECO202", "ECO202"]


def test_eco203_flags_serve_batch_outside_dispatch_plane():
    snippet = "def f(be, reqs):\n    return be.serve_batch(reqs)\n"
    assert rules_of(check_source(snippet, path="src/repro/core/driver.py",
                                 select=["ECO203"])) == ["ECO203"]
    # the dispatch plane itself and tests/ are sanctioned
    assert check_source(snippet, path="src/repro/serving/engine.py",
                        select=["ECO203"]) == []
    assert check_source(snippet, path="tests/test_x.py",
                        select=["ECO203"]) == []


# ------------------------------------------- family 3: thread/async safety


def test_eco301_flags_blocking_calls_under_lock():
    vs = check_source(src("""
        import time

        def f(self, fut):
            with self._lock:
                r = fut.result()
                time.sleep(0.1)
            return r
    """), path=SERVING, select=["ECO301"])
    assert rules_of(vs) == ["ECO301", "ECO301"]


def test_eco301_condition_wait_is_sanctioned():
    vs = check_source(src("""
        def f(self):
            with self._cond:
                self._cond.wait(0.5)
    """), path=SERVING, select=["ECO301"])
    assert vs == []


def test_eco302_flags_future_completion_off_loop():
    vs = check_source(src("""
        def f(loop):
            afut = loop.create_future()
            afut.set_result(1)
            return afut
    """), path=SERVING, select=["ECO302"])
    assert rules_of(vs) == ["ECO302"]


def test_eco302_call_soon_threadsafe_callback_is_sanctioned():
    vs = check_source(src("""
        def bridge(loop, cfut):
            afut = loop.create_future()

            def _copy():
                afut.set_result(cfut.result())

            loop.call_soon_threadsafe(_copy)
            return afut
    """), path=SERVING, select=["ECO302"])
    assert vs == []


def test_eco303_flags_blind_except_shapes():
    vs = check_source(src("""
        def f():
            try:
                g()
            except:
                h()
            try:
                g()
            except BaseException:
                h()
            try:
                g()
            except ValueError:
                pass
    """), path=SERVING, select=["ECO303"])
    assert rules_of(vs) == ["ECO303", "ECO303", "ECO303"]
    good = src("""
        def f(log):
            try:
                g()
            except Exception as exc:
                log(exc)
    """)
    assert check_source(good, path=SERVING, select=["ECO303"]) == []


def test_eco304_flags_wall_clock_sleep_and_unbounded_spin():
    vs = check_source(src("""
        import time
        from time import sleep

        def retry(fn):
            while True:
                try:
                    return fn()
                except Exception:
                    time.sleep(0.5)

        def poll(q):
            while True:
                sleep(0.01)
                q.flush()
    """), path=SERVING, select=["ECO304"])
    # retry's loop has a return (bounded); poll's does not — plus the two
    # sleeps themselves
    assert rules_of(vs) == ["ECO304", "ECO304", "ECO304"]


def test_eco304_condition_wait_loop_with_exit_is_sanctioned():
    vs = check_source(src("""
        def retry_loop(self):
            while True:
                with self._cond:
                    if self._closed:
                        return
                    self._cond.wait(0.05)
    """), path=SERVING, select=["ECO304"])
    assert vs == []


def test_eco304_nested_loop_break_does_not_bound_outer():
    vs = check_source(src("""
        def pump(self):
            while True:
                for item in self._queue:
                    if item is None:
                        break
    """), path=SERVING, select=["ECO304"])
    assert rules_of(vs) == ["ECO304"]


def test_eco304_covers_traffic_plane():
    # the traffic plane is virtual-time by contract: wall-clock sleeps are
    # flagged there exactly like in serving, with the same suppression
    TRAFFIC = "src/repro/traffic/mod.py"
    sleepy = src("""
        import time

        def pace(self, dt):
            time.sleep(dt)
    """)
    assert rules_of(check_source(sleepy, path=TRAFFIC,
                                 select=["ECO304"])) == ["ECO304"]
    suppressed = src("""
        import time

        def pace(self, dt):
            # repro-lint: disable=ECO304 -- wall-clock pacing demo
            time.sleep(dt)
    """)
    assert check_source(suppressed, path=TRAFFIC, select=["ECO304"]) == []
    # the OTHER serving rules stay serving-only: the traffic plane has no
    # flusher thread to protect
    assert check_source(src("""
        def f():
            try:
                g()
            except:
                pass
    """), path=TRAFFIC, select=["ECO303"]) == []


def test_eco304_only_applies_to_serving_and_suppression_works():
    sleepy = src("""
        import time

        def bench():
            time.sleep(1.0)
    """)
    assert check_source(sleepy, path=CORE, select=["ECO304"]) == []
    suppressed = src("""
        import time

        def simulate(self, ms):
            # repro-lint: disable=ECO304 -- simulated device busy time
            time.sleep(ms / 1e3)
    """)
    assert check_source(suppressed, path=SERVING, select=["ECO304"]) == []


# ---------------------------------------------- family 4: kernel contract


def _kernel_files(**overrides):
    files = {
        "src/repro/kernels/foo/__init__.py": "from .ops import foo\n",
        "src/repro/kernels/foo/ops.py": "def foo(x):\n    return x\n",
        "src/repro/kernels/foo/ref.py": "import jax.numpy as jnp\n",
        "tests/test_foo.py": "import repro.kernels.foo\n",
    }
    files.update(overrides)
    return {k: v for k, v in files.items() if v is not None}


def test_eco4xx_complete_kernel_package_is_clean():
    report = check_sources(_kernel_files(), select=["ECO4"])
    assert report.violations == []


def test_eco401_missing_init():
    report = check_sources(
        _kernel_files(**{"src/repro/kernels/foo/__init__.py": None}),
        select=["ECO401"])
    assert rules_of(report.violations) == ["ECO401"]
    assert report.violations[0].path.endswith("foo/__init__.py")


def test_eco402_missing_ref():
    report = check_sources(
        _kernel_files(**{"src/repro/kernels/foo/ref.py": None}),
        select=["ECO402"])
    assert rules_of(report.violations) == ["ECO402"]
    assert "ref.py" in report.violations[0].message


def test_eco403_kernel_without_parity_test():
    report = check_sources(
        _kernel_files(**{"tests/test_foo.py": "import repro.core\n"}),
        select=["ECO403"])
    assert rules_of(report.violations) == ["ECO403"]
    # no tests collected at all -> nothing to assert, no violation
    report = check_sources(
        _kernel_files(**{"tests/test_foo.py": None}), select=["ECO403"])
    assert report.violations == []


def test_eco404_oracle_importing_pallas():
    ref = "from jax.experimental import pallas as pl\n"
    report = check_sources(
        _kernel_files(**{"src/repro/kernels/foo/ref.py": ref}),
        select=["ECO404"])
    assert rules_of(report.violations) == ["ECO404"]


# --------------------------------------------- family 5: environment pins


def test_eco501_axistype_access_and_import():
    vs = check_source(src("""
        import jax
        from jax.sharding import AxisType

        def f():
            return jax.sharding.AxisType.Auto
    """), select=["ECO501"])
    assert rules_of(vs) == ["ECO501", "ECO501"]
    # the version-gated getattr idiom is the sanctioned form
    good = "import jax\nx = getattr(jax.sharding, 'AxisType', None)\n"
    assert check_source(good, select=["ECO501"]) == []


def test_eco502_bare_make_mesh():
    vs = check_source(src("""
        import jax
        from jax import make_mesh

        def f():
            return jax.make_mesh((1,), ("x",))
    """), select=["ECO502"])
    assert rules_of(vs) == ["ECO502", "ECO502"]


def test_eco503_hypothesis_imports():
    vs = check_source(src("""
        import hypothesis
        import hypothesis.strategies as st
        from hypothesis import given
    """), select=["ECO503"])
    assert rules_of(vs) == ["ECO503", "ECO503", "ECO503"]


# ------------------------------------------------------------ suppressions


def test_suppression_inline_and_standalone_roundtrip():
    bad = "from hypothesis import given\n"
    assert rules_of(check_source(bad, select=["ECO503"])) == ["ECO503"]

    inline = "from hypothesis import given  # repro-lint: disable=ECO503\n"
    report = check_sources({"x.py": inline}, select=["ECO503"])
    assert report.violations == [] and report.suppressed == 1

    standalone = src("""
        # repro-lint: disable=ECO503 -- exercising the shim fallback;
        # a justification block may run on before the flagged line
        from hypothesis import given
    """)
    report = check_sources({"x.py": standalone}, select=["ECO503"])
    assert report.violations == [] and report.suppressed == 1


def test_suppression_is_per_rule_and_file_wide_forms():
    # suppressing a DIFFERENT rule must not hide the finding
    wrong = "from hypothesis import given  # repro-lint: disable=ECO502\n"
    assert rules_of(check_source(wrong, select=["ECO503"])) == ["ECO503"]

    file_wide = ("# repro-lint: disable-file=ECO503\n"
                 "from hypothesis import given\n"
                 "import hypothesis\n")
    report = check_sources({"x.py": file_wide}, select=["ECO503"])
    assert report.violations == [] and report.suppressed == 2


# -------------------------------------------------------------------- CLI


def _write(tmp_path, name, text):
    p = tmp_path / name
    p.write_text(text)
    return p


def test_cli_nonzero_exit_and_text_output(tmp_path, capsys):
    bad = _write(tmp_path, "bad.py", "from hypothesis import given\n")
    assert main([str(bad)]) == 1
    out = capsys.readouterr().out
    assert "ECO503" in out and "bad.py:1:" in out


def test_cli_zero_exit_on_clean_file(tmp_path, capsys):
    clean = _write(tmp_path, "clean.py", "x = 1\n")
    assert main([str(clean)]) == 0
    assert "0 violations" in capsys.readouterr().out


def test_cli_json_schema(tmp_path, capsys):
    bad = _write(tmp_path, "bad.py", "from hypothesis import given\n")
    assert main([str(bad), "--format", "json"]) == 1
    doc = json.loads(capsys.readouterr().out)
    assert set(doc) == {"version", "files", "rules", "violations",
                        "counts", "suppressed"}
    assert doc["version"] == 1
    assert doc["files"] == 1
    assert doc["counts"] == {"ECO503": 1}
    assert doc["suppressed"] == 0
    (v,) = doc["violations"]
    assert set(v) == {"rule", "path", "line", "col", "message"}
    assert (v["rule"], v["line"]) == ("ECO503", 1)


def test_cli_suppression_roundtrip(tmp_path, capsys):
    _write(tmp_path, "bad.py",
           "from hypothesis import given  # repro-lint: disable=ECO503\n")
    assert main([str(tmp_path), "--format", "json"]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert doc["violations"] == [] and doc["suppressed"] == 1


def test_cli_select_and_ignore(tmp_path, capsys):
    bad = _write(tmp_path, "bad.py", "from hypothesis import given\n")
    assert main([str(bad), "--select", "ECO1"]) == 0
    capsys.readouterr()
    assert main([str(bad), "--ignore", "ECO503"]) == 0
    capsys.readouterr()
    assert main([str(bad), "--select", "ECO5"]) == 1
    capsys.readouterr()


def test_cli_list_rules_and_usage_errors(tmp_path, capsys):
    assert main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for family_rep in ("ECO101", "ECO201", "ECO301", "ECO401", "ECO501"):
        assert family_rep in out
    assert main([str(tmp_path / "nope")]) == 2


def test_cli_clean_on_this_repo(capsys):
    """The acceptance gate, in-process: the final tree lints clean."""
    paths = [str(REPO / d) for d in ("src", "tests", "benchmarks",
                                     "examples") if (REPO / d).exists()]
    assert main(paths) == 0, capsys.readouterr().out


def test_module_entrypoint_subprocess(tmp_path):
    """``python -m repro.analysis`` works and exit codes propagate."""
    bad = _write(tmp_path, "bad.py", "import jax\nx = jax.make_mesh((1,))\n")
    env = {"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"}
    r = subprocess.run([sys.executable, "-m", "repro.analysis", str(bad)],
                       capture_output=True, text=True, timeout=120, env=env)
    assert r.returncode == 1, r.stderr
    assert "ECO502" in r.stdout

"""ExecutionBackend protocol + registry, DetectorBackend, and the
cross-face guarantee: detection served through EcoreService's dispatch
queues is stats-identical to the gateway's longhand stream loop."""
import numpy as np
import pytest

from repro.core.energy import gateway_cost
from repro.core.estimators import EdgeDetectionEstimator
from repro.core.metrics import MAPAccumulator
from repro.core.policy import DetectionPolicy, RouteRequest
from repro.core.profiles import ProfileEntry, ProfileTable
from repro.core.router import GreedyEstimateRouter, greedy_route
from repro.detection import scenes as sc
from repro.detection.devices import DEVICES, DriftingFleet, DriftEvent
from repro.detection.detectors import DETECTOR_CONFIGS
from repro.serving.backend import (DetectorBackend, ExecutionBackend,
                                   backend_kinds, ensure_backend,
                                   make_backend, register_backend)
from repro.serving.engine import Request
from repro.serving.service import EcoreService


def _fake_run(params, images):
    none = np.zeros((0, 4), np.float32)
    return [(none, np.zeros(0, np.float32), np.zeros(0, np.int32))
            for _ in range(len(images))]


def _table():
    rows = []
    for g in range(5):  # cheap pair falls out of the feasible set as g grows
        for m, d, mp in (("ssd_v1", "orin_nano", 60.0 - 3 * g),
                         ("yolov8_n", "pi5", 60.0)):
            flops = DETECTOR_CONFIGS[m].flops
            rows.append(ProfileEntry(m, d, g, mp, DEVICES[d].time_ms(flops),
                                     DEVICES[d].energy_mwh(flops)))
    return ProfileTable(rows)


# ------------------------------------------------------- protocol + registry

def test_registry_has_both_faces():
    assert {"llm", "detector"} <= set(backend_kinds())


def test_make_backend_unknown_kind_is_a_clear_error():
    with pytest.raises(KeyError, match="unknown backend kind"):
        make_backend("nope")


def test_register_backend_rejects_conflicting_kind():
    with pytest.raises(ValueError, match="already registered"):
        register_backend("detector", lambda: None)


def test_ensure_backend_names_every_missing_member():
    class Half:
        name = "h"
        max_batch = 1
    with pytest.raises(TypeError, match="serve_batch, profile_row"):
        ensure_backend(Half())


def test_detector_backend_implements_protocol():
    be = make_backend("detector", "ssd_v1", "orin_nano", run_fn=_fake_run)
    assert isinstance(be, ExecutionBackend)
    row = be.profile_row()
    assert row["model"] == "ssd_v1" and row["device"] == "orin_nano"
    assert row["time_ms"] > 0 and row["energy_mwh"] > 0


def test_detector_backend_charges_profiled_device_cost():
    be = DetectorBackend("ssd_v1", "orin_nano", run_fn=_fake_run, max_batch=4)
    flops = DETECTOR_CONFIGS["ssd_v1"].flops
    frames = [Request(uid=i, prompt=np.zeros((8, 8), np.float32))
              for i in range(3)]
    results = be.serve_batch(frames)
    assert [r.uid for r in results] == [0, 1, 2]
    for r in results:
        assert r.batch_size == 3 and r.backend == "ssd_v1@orin_nano"
        assert r.time_ms == DEVICES["orin_nano"].time_ms(flops)
        assert r.energy_mwh == DEVICES["orin_nano"].energy_mwh(flops)
        boxes, scores, classes = r.detections
        assert boxes.shape == (0, 4)


def test_detector_backend_fleet_cost_keyed_on_request_uid():
    """The request uid IS the fleet timestep, so drifted costs are
    identical however dispatch batches or reorders the frames."""
    fleet = DriftingFleet([DriftEvent("pi5", "dropout", start=2, end=3,
                                      severity=10.0)])
    be = DetectorBackend("yolov8_n", "pi5", fleet=fleet, run_fn=_fake_run,
                         max_batch=8)
    flops = DETECTOR_CONFIGS["yolov8_n"].flops
    # uids 3,1,2 served in ONE batch, out of stream order
    results = be.serve_batch([Request(uid=u, prompt=np.zeros((8, 8)))
                              for u in (3, 1, 2)])
    by_uid = {r.uid: r for r in results}
    base = DEVICES["pi5"].time_ms(flops)
    assert by_uid[1].time_ms == base
    assert by_uid[3].time_ms == base
    assert by_uid[2].time_ms == pytest.approx(10.0 * base)  # dropout step


def test_detector_backend_serves_ragged_batch_in_buckets():
    """Frames of mixed sizes in ONE dispatch batch: serve_batch pads each
    size bucket and launches the detector once per bucket, yielding results
    in request order with the per-frame profiled cost untouched."""
    launches = []

    def spy_run(params, images):
        launches.append(images.shape)
        return _fake_run(params, images)

    be = DetectorBackend("ssd_v1", "orin_nano", run_fn=spy_run, max_batch=8)
    shapes = [(8, 8), (40, 200), (8, 8), (37, 41)]
    reqs = [Request(uid=i, prompt=np.zeros(s, np.float32))
            for i, s in enumerate(shapes)]
    results = be.serve_batch(reqs)
    assert [r.uid for r in results] == [0, 1, 2, 3]
    # (8,8) and (37,41) share the (64,128) bucket; (40,200) gets (64,256):
    # 2 launches for 4 ragged frames, never 4
    assert sorted(launches) == [(1, 64, 256), (3, 64, 128)]
    flops = DETECTOR_CONFIGS["ssd_v1"].flops
    for r in results:
        assert r.time_ms == DEVICES["orin_nano"].time_ms(flops)


def test_detector_backend_uniform_batch_is_one_unpadded_launch():
    """A uniform batch must keep the old exact-shape single-stack path —
    no padding, one launch."""
    launches = []

    def spy_run(params, images):
        launches.append(images.shape)
        return _fake_run(params, images)

    be = DetectorBackend("ssd_v1", "orin_nano", run_fn=spy_run, max_batch=4)
    be.serve_batch([Request(uid=i, prompt=np.zeros((8, 8), np.float32))
                    for i in range(3)])
    assert launches == [(3, 8, 8)]


def test_detector_backend_edge_stage_records_density_per_uid():
    """edge_stage=True runs the fused Canny gateway stage over the whole
    dispatch batch (ragged sizes included) and records per-frame edge
    density keyed by uid."""
    rng = np.random.default_rng(3)
    be = DetectorBackend("ssd_v1", "orin_nano", run_fn=_fake_run,
                         max_batch=8, edge_stage=True)
    reqs = [Request(uid=u, prompt=rng.random(s).astype(np.float32))
            for u, s in ((7, (32, 32)), (9, (40, 200)))]
    be.serve_batch(reqs)
    assert set(be.edge_density) == {7, 9}
    for uid, req in ((7, reqs[0]), (9, reqs[1])):
        from repro.kernels.canny_fused import ref
        import jax.numpy as jnp
        want = float(np.asarray(
            ref.canny_edge(jnp.asarray(req.prompt)[None])).mean())
        assert be.edge_density[uid] == pytest.approx(want)


# -------------------------------------------------- cross-face parity test

def _longhand_episode(scenes, table):
    """The paper pipeline written out longhand (estimate -> route ->
    dispatch -> account), straight off Fig. 3 — the pre-service loop."""
    est = EdgeDetectionEstimator()
    acc = MAPAccumulator(sc.NUM_CLASSES)
    be_e = be_t = gw_e = gw_t = 0.0
    hist = {}
    for s in scenes:
        count, est_flops = est.estimate(s.image)
        gc = gateway_cost(est_flops)
        gw_e += gc["energy_mwh"]
        gw_t += gc["time_ms"]
        m, d = greedy_route(int(count), table, 5.0).pair
        hist[f"{m}@{d}"] = hist.get(f"{m}@{d}", 0) + 1
        boxes, scores, classes = _fake_run(None, s.image[None])[0]
        acc.add_image(boxes, scores, classes, s.boxes, s.classes)
        flops = DETECTOR_CONFIGS[m].flops
        be_t += DEVICES[d].time_ms(flops)
        be_e += DEVICES[d].energy_mwh(flops)
    return acc.map(), be_e, be_t, gw_e, gw_t, hist


@pytest.mark.parametrize("max_batch", [1, 4])
def test_detector_backend_via_service_matches_longhand_gateway(max_batch):
    """Acceptance: a DetectorBackend dispatched through EcoreService's
    queues (including genuinely BATCHED detector execution) produces stats
    identical to the gateway's longhand stream loop — exact float equality,
    same accumulation order."""
    scenes = [sc.make_scene(np.random.default_rng(i), count=i % 6)
              for i in range(24)]
    ref_map, be_e, be_t, gw_e, gw_t, hist = _longhand_episode(
        scenes, _table())

    table = _table()
    policy = DetectionPolicy(GreedyEstimateRouter(table, 5.0), table,
                             EdgeDetectionEstimator())
    service = EcoreService(
        policy,
        lambda d: DetectorBackend(d.pair[0], d.pair[1], None,
                                  max_batch=max_batch, run_fn=_fake_run))
    reqs = [RouteRequest(uid=i, payload=s.image, true_complexity=s.count)
            for i, s in enumerate(scenes)]
    with service:
        service.submit_batch(reqs)
        served = service.results() + service.drain()

    acc = MAPAccumulator(sc.NUM_CLASSES)
    got_be_e = got_be_t = got_gw_e = got_gw_t = 0.0
    got_hist = {}
    for s in sorted(served, key=lambda s: s.request.uid):
        scene = scenes[s.request.uid]
        boxes, scores, classes = s.result.detections
        acc.add_image(boxes, scores, classes, scene.boxes, scene.classes)
        got_be_e += s.result.energy_mwh
        got_be_t += s.result.time_ms
        got_gw_e += s.decision.gateway_energy_mwh
        got_gw_t += s.decision.gateway_time_ms
        got_hist[s.decision.pair_name] = got_hist.get(s.decision.pair_name,
                                                      0) + 1
    assert acc.map() == ref_map
    assert got_be_e == be_e and got_be_t == be_t
    assert got_gw_e == gw_e and got_gw_t == gw_t
    assert got_hist == hist
    if max_batch > 1:
        # the dispatch queues actually batched detector execution
        assert any(s.result.batch_size > 1 for s in served)


def test_gateway_process_stream_is_service_backed(monkeypatch):
    """No workload-private serving loop: the Gateway's stream must flow
    through EcoreService dispatch (every detector launch happens inside a
    DetectorBackend.serve_batch call)."""
    from repro.core.gateway import Gateway
    from repro.detection import train

    monkeypatch.setattr(train, "run_detector", _fake_run)
    calls = []
    orig = DetectorBackend.serve_batch

    def spy(self, requests):
        calls.append(len(requests))
        return orig(self, requests)

    monkeypatch.setattr(DetectorBackend, "serve_batch", spy)
    table = _table()
    gw = Gateway(GreedyEstimateRouter(table, 5.0), table,
                 {"ssd_v1": None, "yolov8_n": None},
                 EdgeDetectionEstimator(), max_batch=4)
    scenes = [sc.make_scene(np.random.default_rng(i), count=i % 6)
              for i in range(12)]
    stats = gw.process_stream(scenes)
    assert sum(calls) == 12                  # every frame went through it
    assert max(calls) > 1                    # and dispatch really batched
    assert stats.map_pct >= 0

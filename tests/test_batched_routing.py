"""Tensorized routing (route_batch) ≡ scalar Algorithm 1, the gateway's
batched hot path, the latency-bounded dispatch flush, and the mAP closed
loop."""
import numpy as np
import pytest
from _propcheck import given, settings, st

from repro.core.profiles import ProfileEntry, ProfileTable
from repro.core.router import (GreedyEstimateRouter, OracleRouter,
                               greedy_route, route_batch)
from repro.detection import scenes as sc
from repro.detection.devices import DEVICES
from repro.serving.engine import DispatchQueue, Request, Result
from repro.serving.pool import LENGTH_BUCKETS, ServingPool


def make_table(rows):
    return ProfileTable([ProfileEntry(*r) for r in rows])


@pytest.fixture
def table():
    rows = []
    for g in range(5):
        rows += [
            ("tiny", "devA", g, 50.0 - 4 * g, 5.0, 0.010),
            ("mid", "devB", g, 55.0 - 2 * g, 9.0, 0.025),
            ("big", "devC", g, 60.0, 20.0, 0.060),
        ]
    return make_table(rows)


# ------------------------------------------------- route_batch ≡ greedy_route

def test_route_batch_matches_scalar_per_count(table):
    counts = list(range(9)) + [50, 0, 7]
    for delta in (0.0, 5.0, 14.0, 100.0):
        idx = route_batch(counts, table, delta)
        for c, i in zip(counts, idx):
            assert table.entries[i] is greedy_route(c, table, delta)


def test_route_batch_unprofiled_group_raises_like_scalar():
    table = make_table([("tiny", "devA", 0, 50.0, 5.0, 0.010)])
    with pytest.raises(ValueError, match="no profile rows for group 4"):
        route_batch([0, 7], table, 5.0)


def test_route_batch_sees_observe_updates(table):
    """The cached array view must be invalidated by EWMA observations."""
    before = route_batch([0], table, 100.0)[0]
    assert table.entries[before].pair == ("tiny", "devA")
    table.observe_pair(("tiny", "devA"), energy_mwh=9.0, alpha=1.0)
    after = route_batch([0], table, 100.0)[0]
    assert table.entries[after].pair == ("mid", "devB")
    assert table.entries[after] is greedy_route(0, table, 100.0)


# values are small dyadic rationals (exact in f32 AND f64), so the f32
# tensorized path and the float64 scalar path see literally the same numbers
# and must agree even at exact feasibility-threshold ties
entry_strategy = st.tuples(
    st.sampled_from(["m1", "m2", "m3", "m4"]),
    st.sampled_from(["d1", "d2"]),
    st.integers(0, 800),     # map_pct * 8
    st.integers(1, 800),     # time_ms * 8
    st.integers(1, 1024),    # energy_mwh * 1024
)


@settings(max_examples=150, deadline=None)
@given(
    entries=st.lists(entry_strategy, min_size=1, max_size=20,
                     unique_by=lambda e: (e[0], e[1])),
    counts=st.lists(st.integers(0, 12), min_size=1, max_size=16),
    delta8=st.integers(0, 400),
)
def test_route_batch_property(entries, counts, delta8):
    rows = []
    for m, d, mp8, t8, e1024 in entries:
        for g in range(5):
            rows.append(ProfileEntry(m, d, g, (mp8 - 8 * g) / 8, t8 / 8,
                                     e1024 / 1024))
    table = ProfileTable(rows)
    delta = delta8 / 8
    idx = route_batch(counts, table, delta)
    for c, i in zip(counts, idx):
        assert table.entries[i] is greedy_route(c, table, delta)


def test_router_route_batch_faces(table):
    counts = [0, 3, 7, 1, 12]
    greedy = GreedyEstimateRouter(table, 5.0)
    assert greedy.route_batch(estimated_counts=counts) == \
        [greedy.route(estimated_count=c) for c in counts]
    orc = OracleRouter(table, 5.0)
    assert orc.route_batch(true_counts=counts) == \
        [orc.route(true_count=c) for c in counts]


def test_non_batchable_routers_honest_flags_and_batch_parity(table):
    """Every router without a tensorized route_batch must say so
    (batchable=False) and still route correctly through the generic
    per-item fallback: batch == the scalar loop, state reset in between
    (stateful routers: RR advances an index, Rnd consumes an RNG)."""
    from repro.core.router import (HighestMAPPerGroupRouter, HighestMAPRouter,
                                   LowestEnergyRouter, LowestInferenceRouter,
                                   ParetoRouter, RandomRouter,
                                   RoundRobinRouter, WeightedRouter)

    counts = [0, 3, 7, 1, 12, 2, 2, 5]
    for cls in (RoundRobinRouter, RandomRouter, LowestEnergyRouter,
                LowestInferenceRouter, HighestMAPRouter,
                HighestMAPPerGroupRouter, WeightedRouter, ParetoRouter):
        r = cls(table, 5.0)
        assert r.batchable is False, cls.name
        r.reset()
        batch = r.route_batch(estimated_counts=counts, true_counts=counts)
        r.reset()
        scalar = [r.route(estimated_count=c, true_count=c) for c in counts]
        assert batch == scalar, cls.name


# ------------------------------------------------------ pool batched routing

def _pool():
    entries = [ProfileEntry(a, "pod", b, score - b, 1.0, energy)
               for a, score, energy in (("small", 80.0, 1.0),
                                        ("big", 84.0, 5.0))
               for _, _, b in LENGTH_BUCKETS]
    return ServingPool(ProfileTable(entries), delta=5.0)


def test_pool_route_batch_matches_scalar():
    pool = _pool()
    lens = [1, 100, 512, 513, 2048, 2049, 8192, 8193, 32768, 32769, 600_000]
    assert pool.route_batch(lens) == [pool.route(n) for n in lens]


def test_pool_route_batch_unprofiled_bucket_raises():
    pool = ServingPool(ProfileTable([ProfileEntry("only", "pod", 0,
                                                  80.0, 1.0, 1.0)]), 5.0)
    with pytest.raises(ValueError, match="no profile rows for group 4"):
        pool.route_batch([100, 40_000])


# --------------------------------------------------- gateway batched hot path

def _fake_run_detector(params, images):
    none = np.zeros((0, 4), np.float32)
    return [(none, np.zeros(0, np.float32), np.zeros(0, np.int32))
            for _ in range(len(images))]


def _grouped_table():
    from repro.detection.detectors import DETECTOR_CONFIGS
    rows = []
    for g in range(5):  # cheap pair falls out of the feasible set as g grows
        for m, d, mp in (("ssd_v1", "orin_nano", 60.0 - 3 * g),
                         ("yolov8_n", "pi5", 60.0)):
            flops = DETECTOR_CONFIGS[m].flops
            rows.append(ProfileEntry(m, d, g, mp, DEVICES[d].time_ms(flops),
                                     DEVICES[d].energy_mwh(flops)))
    return ProfileTable(rows)


def test_gateway_batched_routing_identical_to_scalar(monkeypatch):
    from repro.core.estimators import EdgeDetectionEstimator
    from repro.core.gateway import Gateway
    from repro.detection import train

    monkeypatch.setattr(train, "run_detector", _fake_run_detector)
    scenes = [sc.make_scene(np.random.default_rng(i), count=i % 6)
              for i in range(24)]
    params = {"ssd_v1": None, "yolov8_n": None}

    def episode(batch_routing):
        table = _grouped_table()
        gw = Gateway(GreedyEstimateRouter(table, 5.0), table, params,
                     EdgeDetectionEstimator(), batch_routing=batch_routing)
        return gw.process_stream(scenes)

    batched, scalar = episode(True), episode(False)
    assert batched == scalar  # decisions, costs and accounting all identical
    assert len(batched.pair_histogram) == 2  # routing actually varied


def test_process_stream_matches_handwritten_reference(monkeypatch):
    """Acceptance (PR 3): process_stream rebuilt on DetectionPolicy produces
    EpisodeStats IDENTICAL (mAP, energy, time, pair histogram — exact float
    equality, same accumulation order) to the paper pipeline written out
    longhand (what the pre-refactor loop inlined), on both the scalar and
    the batched path, on a fixed-seed stream."""
    from repro.core.energy import gateway_cost
    from repro.core.estimators import EdgeDetectionEstimator
    from repro.core.gateway import Gateway
    from repro.core.metrics import MAPAccumulator
    from repro.detection import train
    from repro.detection.detectors import DETECTOR_CONFIGS

    monkeypatch.setattr(train, "run_detector", _fake_run_detector)
    scenes = [sc.make_scene(np.random.default_rng(i), count=i % 6)
              for i in range(24)]
    params = {"ssd_v1": None, "yolov8_n": None}

    # longhand: estimate -> route -> dispatch -> account, straight off Fig. 3
    table = _grouped_table()
    est = EdgeDetectionEstimator()
    acc = MAPAccumulator(sc.NUM_CLASSES)
    be_e = be_t = gw_e = gw_t = 0.0
    hist = {}
    for s in scenes:
        count, est_flops = est.estimate(s.image)
        gc = gateway_cost(est_flops)
        gw_e += gc["energy_mwh"]
        gw_t += gc["time_ms"]
        m, d = greedy_route(int(count), table, 5.0).pair
        hist[f"{m}@{d}"] = hist.get(f"{m}@{d}", 0) + 1
        boxes, scores, classes = _fake_run_detector(None, s.image[None])[0]
        acc.add_image(boxes, scores, classes, s.boxes, s.classes)
        flops = DETECTOR_CONFIGS[m].flops
        be_t += DEVICES[d].time_ms(flops)
        be_e += DEVICES[d].energy_mwh(flops)

    for batch_routing in (True, False):
        table2 = _grouped_table()
        gw = Gateway(GreedyEstimateRouter(table2, 5.0), table2, params,
                     EdgeDetectionEstimator(), batch_routing=batch_routing)
        stats = gw.process_stream(scenes)
        assert stats.map_pct == acc.map()
        assert stats.backend_energy_mwh == be_e
        assert stats.backend_time_ms == be_t
        assert stats.gateway_energy_mwh == gw_e
        assert stats.gateway_time_ms == gw_t
        assert stats.pair_histogram == hist


def test_gateway_two_episodes_deterministic_with_random_router(monkeypatch):
    """Back-to-back process_stream episodes on ONE RandomRouter must be
    identical: reset() reseeds the RNG (used to be a silent no-op)."""
    from repro.core.gateway import Gateway
    from repro.core.router import RandomRouter
    from repro.detection import train

    monkeypatch.setattr(train, "run_detector", _fake_run_detector)
    table = _grouped_table()
    gw = Gateway(RandomRouter(table, seed=3), table,
                 {"ssd_v1": None, "yolov8_n": None}, None)
    scenes = [sc.make_scene(np.random.default_rng(i), count=i % 6)
              for i in range(30)]
    first = gw.process_stream(scenes)
    second = gw.process_stream(scenes)
    assert first == second
    assert len(first.pair_histogram) == 2  # the router actually randomized


def test_gateway_adapt_forces_scalar_path(monkeypatch):
    """The closed loop mutates the table per request, so the batched
    single-shot routing must be bypassed when adapt=True."""
    from repro.core.estimators import EdgeDetectionEstimator
    from repro.core.gateway import Gateway
    from repro.detection import train

    monkeypatch.setattr(train, "run_detector", _fake_run_detector)
    table = _grouped_table()
    gw = Gateway(GreedyEstimateRouter(table, 5.0), table,
                 {"ssd_v1": None, "yolov8_n": None},
                 EdgeDetectionEstimator(), adapt=True)
    assert gw.policy.batchable is False


# ------------------------------------------------------- mAP closed loop

def test_gateway_observe_updates_map_for_one_group(table):
    from repro.core.gateway import Gateway
    gw = Gateway(OracleRouter(table, 5.0), table,
                 {}, None, adapt=True, alpha=0.5)
    gw.observe(("big", "devC"), 2, map_pct=20.0)
    assert table.entry(("big", "devC"), 2).map_pct == 40.0  # EWMA'd
    assert table.entry(("big", "devC"), 0).map_pct == 60.0  # other groups
    assert table.entry(("mid", "devB"), 2).map_pct == 51.0  # other pairs


def test_gateway_adapt_map_closes_quality_loop(monkeypatch):
    """A backend that measures WORSE quality than profiled loses its row's
    mAP via the EWMA — the routing table's third closed-loop column."""
    from repro.core.gateway import Gateway
    from repro.detection import train

    monkeypatch.setattr(train, "run_detector", _fake_run_detector)
    table = _grouped_table()
    before = {(e.pair, e.group): e.map_pct for e in table.entries}
    gw = Gateway(OracleRouter(table, 5.0), table,
                 {"ssd_v1": None, "yolov8_n": None}, None,
                 adapt=True, adapt_map=True, alpha=0.3)
    scenes = [sc.make_scene(np.random.default_rng(i), count=2)
              for i in range(10)]
    stats = gw.process_stream(scenes)
    served = [p for p, n in stats.pair_histogram.items() if n > 0]
    assert served
    model, device = served[0].split("@")
    # fake detector finds nothing -> measured quality 0 -> row EWMAs down,
    # and ONLY the observed group's row moves
    assert table.entry((model, device), 2).map_pct \
        < before[((model, device), 2)]
    assert table.entry((model, device), 0).map_pct \
        == before[((model, device), 0)]


def test_gateway_adapt_map_honors_router_group_rules(monkeypatch):
    """Regression: the measured-quality observation must land in the group
    the ROUTER's rules assign, not DEFAULT_GROUP_RULES — custom labels
    would otherwise KeyError (or hit the wrong row) mid-stream."""
    from repro.core.gateway import Gateway
    from repro.detection import train
    from repro.detection.detectors import DETECTOR_CONFIGS

    monkeypatch.setattr(train, "run_detector", _fake_run_detector)
    rules = ((0, 1, 10), (2, None, 20))  # two coarse groups, custom labels
    rows = []
    for g in (10, 20):
        for m, d in (("ssd_v1", "orin_nano"), ("yolov8_n", "pi5")):
            flops = DETECTOR_CONFIGS[m].flops
            rows.append(ProfileEntry(m, d, g, 60.0,
                                     DEVICES[d].time_ms(flops),
                                     DEVICES[d].energy_mwh(flops)))
    table = ProfileTable(rows)
    gw = Gateway(OracleRouter(table, 5.0, group_rules=rules), table,
                 {"ssd_v1": None, "yolov8_n": None}, None,
                 adapt=True, adapt_map=True, alpha=0.5)
    scenes = [sc.make_scene(np.random.default_rng(i), count=3)
              for i in range(4)]
    stats = gw.process_stream(scenes)  # must not KeyError
    model, device = next(iter(stats.pair_histogram)).split("@")
    assert table.entry((model, device), 20).map_pct < 60.0  # observed group
    assert table.entry((model, device), 10).map_pct == 60.0


def test_gateway_explore_without_adapt_keeps_batched_path(monkeypatch):
    """explore_every only fires under adapt, so it must not disable the
    batched fast path on an open-loop stream."""
    from repro.core.estimators import EdgeDetectionEstimator
    from repro.core.gateway import Gateway
    from repro.detection import train

    monkeypatch.setattr(train, "run_detector", _fake_run_detector)
    table = _grouped_table()
    gw = Gateway(GreedyEstimateRouter(table, 5.0), table,
                 {"ssd_v1": None, "yolov8_n": None},
                 EdgeDetectionEstimator(), explore_every=5)
    assert gw.policy.batchable is True


def test_gateway_adapt_map_requires_adapt(table):
    from repro.core.gateway import Gateway
    with pytest.raises(ValueError, match="adapt_map"):
        Gateway(OracleRouter(table, 5.0), table, {}, None, adapt_map=True)


def test_pool_observe_map_is_bucket_specific():
    pool = _pool()
    with pytest.raises(ValueError, match="bucket"):
        pool.observe("small", map_pct=10.0)
    pool.observe("small", map_pct=0.0, bucket=0, alpha=0.5)
    assert pool.table.entry(("small", "pod"), 0).map_pct == 40.0
    assert pool.table.entry(("small", "pod"), 1).map_pct == 79.0  # untouched
    # quality drop big enough that bucket 0 routing flips to 'big'
    pool.observe("small", map_pct=0.0, bucket=0, alpha=1.0)
    assert pool.route(100).arch == "big"
    assert pool.route(1000).arch == "small"  # other buckets unaffected


# ------------------------------------------------- latency-bounded dispatch

class _StubBackend:
    def __init__(self, name="stub", max_batch=4):
        self.name = name
        self.max_batch = max_batch
        self.batch_sizes = []

    def serve_batch(self, requests):
        self.batch_sizes.append(len(requests))
        return [Result(uid=r.uid, tokens=np.zeros(1, np.int32),
                       prefill_s=.01, decode_s=.01, backend=self.name,
                       batch_size=len(requests)) for r in requests]


class _FakeClock:
    def __init__(self):
        self.t = 100.0

    def __call__(self):
        return self.t

    def advance_ms(self, ms):
        self.t += ms / 1e3


def test_dispatch_queue_deadline_serves_partial_batch():
    clock = _FakeClock()
    be = _StubBackend(max_batch=4)
    q = DispatchQueue(be, max_wait_ms=50.0, clock=clock)
    assert q.submit(Request(uid=0, prompt=np.arange(4))) == []
    assert q.poll() == []                    # deadline not reached
    clock.advance_ms(49.9)
    assert q.poll() == []
    clock.advance_ms(0.2)                    # oldest waited past 50ms
    got = q.poll()
    assert [r.uid for r in got] == [0]
    assert be.batch_sizes == [1]             # partial batch went out
    assert q.poll() == []                    # queue drained, deadline reset


def test_dispatch_queue_deadline_checked_on_submit():
    clock = _FakeClock()
    be = _StubBackend(max_batch=4)
    q = DispatchQueue(be, max_wait_ms=10.0, clock=clock)
    q.submit(Request(uid=0, prompt=np.arange(4)))
    clock.advance_ms(11)
    got = q.submit(Request(uid=1, prompt=np.arange(4)))
    assert [r.uid for r in got] == [0, 1]    # flushed at 2/4: deadline won
    # deadline restarts with the next first-pending request
    assert q.submit(Request(uid=2, prompt=np.arange(4))) == []
    clock.advance_ms(9)
    assert q.poll() == []


def test_dispatch_queue_without_deadline_waits_for_full_batch():
    clock = _FakeClock()
    be = _StubBackend(max_batch=2)
    q = DispatchQueue(be, clock=clock)
    q.submit(Request(uid=0, prompt=np.arange(4)))
    clock.advance_ms(10_000)
    assert q.poll() == []                    # no max_wait_ms: poll is a no-op
    assert len(q.submit(Request(uid=1, prompt=np.arange(4)))) == 2

"""Beyond-paper features: multi-objective routers + dynamic profiling
(the paper's own §6 future-work list)."""
import pytest

from repro.core.profiles import ProfileEntry, ProfileTable
from repro.core.router import (ParetoRouter, WeightedRouter, greedy_route)


@pytest.fixture
def table():
    rows = []
    for g in range(5):
        # cheap-slow, fast-hungry, dominated, accurate
        rows += [
            ProfileEntry("cheap", "d1", g, 80.0, 20.0, 0.01),
            ProfileEntry("fast", "d2", g, 80.0, 2.0, 0.05),
            ProfileEntry("bad", "d3", g, 80.0, 25.0, 0.06),  # dominated
            ProfileEntry("acc", "d4", g, 95.0, 30.0, 0.09),
        ]
    return ProfileTable(rows)


def test_weighted_router_interpolates(table):
    # energy-only == Algorithm 1
    w_e = WeightedRouter(table, delta_map=100.0, w_energy=1.0, w_time=0.0)
    assert w_e.route(estimated_count=0) == \
        greedy_route(0, table, 100.0).pair == ("cheap", "d1")
    # time-only -> fastest
    w_t = WeightedRouter(table, delta_map=100.0, w_energy=0.0, w_time=1.0)
    assert w_t.route(estimated_count=0) == ("fast", "d2")
    # accuracy constraint still binds
    w0 = WeightedRouter(table, delta_map=5.0, w_energy=1.0, w_time=0.0)
    assert w0.route(estimated_count=0) == ("acc", "d4")


def test_pareto_router_excludes_dominated(table):
    r = ParetoRouter(table, delta_map=100.0)
    # 'bad' is dominated by 'cheap' (energy) and 'fast' (time):
    # the pick must come off the front
    assert r.route(estimated_count=2) in [("cheap", "d1"), ("fast", "d2")]


def test_dynamic_profile_ewma(table):
    pair = ("cheap", "d1")
    before = table.entry(pair, 0).time_ms
    for _ in range(50):
        table.observe(pair, 0, time_ms=100.0, alpha=0.2)
    after = table.entry(pair, 0).time_ms
    assert before < after <= 100.0
    assert after > 95.0  # converges to the observed value
    # routing adapts: cheap became slow; time-weighted router now avoids it
    w = WeightedRouter(table, delta_map=100.0, w_energy=0.0, w_time=1.0)
    assert w.route(estimated_count=0) == ("fast", "d2")


def test_observe_unknown_pair_raises(table):
    with pytest.raises(KeyError):
        table.observe(("nope", "d9"), 0, time_ms=1.0)

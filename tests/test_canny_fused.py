"""Fused Canny megakernel: bit-exact parity with the jnp oracle.

All Pallas runs use interpret mode (CPU) — marked ``pallas`` so a TPU CI
lane can select them; they stay in tier-1 (fast, not ``slow``).  The 2D
lane-tiled grid means there is no width limit any more: the cases below
cover lane tiling, the column halo, frames narrower than one lane tile,
widths straddling the tile boundary, a >4096-wide frame (the old
``MAX_WIDTH`` fallback territory), and the ragged pad-and-mask batch path.
"""
import numpy as np
import jax.numpy as jnp
import pytest
from _propcheck import given, settings, st

from repro.kernels.canny_fused import ref
from repro.kernels.canny_fused.canny_fused import (
    HALO, VMEM_BUDGET_BYTES, canny_edge_pallas, pick_tiles, tile_bytes)
from repro.kernels.canny_fused.ops import (bucket_shape, canny_edge,
                                           canny_edge_batch)

pytestmark = pytest.mark.pallas


def _rand(shape, seed=0):
    return jnp.asarray(np.random.default_rng(seed).random(shape, np.float32))


@pytest.mark.parametrize("shape,tiles", [
    ((1, 32, 32), {}),                  # single program, whole frame
    ((3, 64, 64), {}),                  # batch, whole frame (the scene size)
    ((1, 96, 64), dict(tile_rows=32)),  # row-tiled: 3 even row tiles
    ((2, 40, 56), dict(tile_rows=16)),  # ragged last row tile
    ((1, 37, 41), dict(tile_rows=13)),  # odd, non-square
    ((1, 64, 200), dict(tile_rows=32, tile_lanes=64)),   # 2x4 lane grid
    ((2, 80, 600), dict(tile_rows=32, tile_lanes=256)),  # 3x3, ragged both
    ((1, 48, 31), dict(tile_lanes=64)),  # frame NARROWER than one lane tile
    ((1, 48, 65), dict(tile_lanes=64)),  # width = tile_lanes + 1
    ((1, 48, 63), dict(tile_lanes=64)),  # width = tile_lanes - 1
    ((1, 48, 64), dict(tile_lanes=64)),  # width exactly tile_lanes
])
def test_fused_bit_identical_to_oracle(shape, tiles):
    img = _rand(shape, seed=sum(shape))
    got = np.asarray(canny_edge_pallas(img, interpret=True, **tiles))
    want = np.asarray(ref.canny_edge(img))
    np.testing.assert_array_equal(got, want)


def test_frame_wider_than_old_limit_is_served():
    """w > 4096 used to raise in the row-tiled kernel and silently fall
    back to the staged oracle under impl='auto'; the 2D grid serves it."""
    img = _rand((1, 24, 4224), seed=11)
    got = np.asarray(canny_edge_pallas(img, tile_rows=24, tile_lanes=1024,
                                       interpret=True))
    np.testing.assert_array_equal(got, np.asarray(ref.canny_edge(img)))
    # and the dispatch wrapper has no width-based impl rewrite left
    got = np.asarray(canny_edge(img, impl="interpret", tile_rows=24,
                                tile_lanes=1024))
    np.testing.assert_array_equal(got, np.asarray(ref.canny_edge(img)))


def test_4k_frame_bit_identical():
    """The acceptance shape: one 2160x3840 frame, no width guard."""
    img = _rand((1, 2160, 3840), seed=4)
    got = np.asarray(canny_edge_pallas(img, tile_rows=1088, tile_lanes=1984,
                                       interpret=True))
    np.testing.assert_array_equal(got, np.asarray(ref.canny_edge(img)))


def test_fused_thresholds_forwarded():
    img = _rand((1, 48, 48), seed=7)
    got = np.asarray(canny_edge_pallas(img, lo=0.2, hi=0.5, tile_rows=16,
                                       interpret=True))
    want = np.asarray(ref.canny_edge(img, lo=0.2, hi=0.5))
    np.testing.assert_array_equal(got, want)
    # different thresholds must actually change the map (guard against the
    # kernel silently ignoring lo/hi)
    assert got.any()
    assert not np.array_equal(got, np.asarray(ref.canny_edge(img)))


def test_tile_smaller_than_halo_is_an_error():
    with pytest.raises(ValueError, match="HALO"):
        canny_edge_pallas(_rand((1, 32, 32)), tile_rows=HALO - 1,
                          interpret=True)
    with pytest.raises(ValueError, match="HALO"):
        canny_edge_pallas(_rand((1, 32, 32)), tile_lanes=HALO - 1,
                          interpret=True)


def test_pick_tiles_respects_vmem_budget():
    """Auto-picked tiles fit the VMEM working-set model at every size the
    bench exercises, and never shrink below the halo."""
    for h, w in ((64, 64), (1080, 1920), (1440, 2560), (2160, 3840),
                 (4320, 7680), (17, 9)):
        tr, tl = pick_tiles(h, w)
        assert tr >= HALO and tl >= HALO
        assert tile_bytes(tr, tl) <= VMEM_BUDGET_BYTES
    # explicit tiles are honored untouched
    assert pick_tiles(256, 256, tile_rows=40, tile_lanes=72) == (40, 72)


def test_ragged_batch_parity_and_masking():
    """canny_edge_batch pads mixed frame sizes into buckets, serves each
    with ONE launch, and crops — every frame must match its solo oracle
    run exactly (the pad-and-mask plane leaks nothing across frames)."""
    rng = np.random.default_rng(9)
    shapes = [(37, 41), (64, 64), (40, 200), (64, 64)]
    frames = [rng.random(s, np.float32) for s in shapes]
    for impl in ("xla", "interpret"):
        maps = canny_edge_batch(frames, impl=impl)
        assert [m.shape for m in maps] == shapes
        for m, f in zip(maps, frames):
            want = np.asarray(ref.canny_edge(jnp.asarray(f)[None]))[0]
            np.testing.assert_array_equal(m, want)


def test_padded_region_output_is_false():
    """Out-of-frame output from the masked kernel is guaranteed False —
    the host crop merely drops it, it never hides garbage."""
    f = np.random.default_rng(10).random((37, 41), np.float32)
    dims = jnp.asarray([[37, 41]], jnp.int32)
    padded = np.zeros((1, 64, 128), np.float32)
    padded[0, :37, :41] = f
    out = np.asarray(canny_edge_pallas(jnp.asarray(padded), dims,
                                       tile_rows=16, tile_lanes=64,
                                       interpret=True))
    assert not out[0, 37:, :].any() and not out[0, :, 41:].any()


def test_bucket_shape_granularity():
    assert bucket_shape(1, 1) == (64, 128)
    assert bucket_shape(64, 128) == (64, 128)
    assert bucket_shape(65, 129) == (128, 256)
    assert bucket_shape(1080, 1920) == (1088, 1920)


def test_ops_dispatch():
    img = _rand((2, 32, 32), seed=3)
    want = np.asarray(ref.canny_edge(img))
    np.testing.assert_array_equal(
        np.asarray(canny_edge(img, impl="xla")), want)
    np.testing.assert_array_equal(
        np.asarray(canny_edge(img, impl="interpret")), want)


def test_staged_baseline_matches_fused_oracle():
    img = _rand((2, 48, 40), seed=5)
    np.testing.assert_array_equal(np.asarray(ref.canny_edge_staged(img)),
                                  np.asarray(ref.canny_edge(img)))


@settings(max_examples=8, deadline=None)
@given(h=st.integers(16, 70), w=st.integers(8, 70),
       tile=st.integers(HALO, 48), seed=st.integers(0, 10_000))
def test_fused_parity_property(h, w, tile, seed):
    """Any frame size (odd / non-square / non-tile-multiple) and any legal
    tile height produce bit-identical edge maps in interpret mode."""
    img = _rand((1, h, w), seed=seed)
    got = np.asarray(canny_edge_pallas(img, tile_rows=tile, interpret=True))
    np.testing.assert_array_equal(got, np.asarray(ref.canny_edge(img)))


@settings(max_examples=8, deadline=None)
@given(h=st.integers(13, 60), w=st.integers(8, 300),
       tile_r=st.integers(HALO, 32), tile_l=st.integers(HALO, 128),
       seed=st.integers(0, 10_000))
def test_fused_parity_property_2d(h, w, tile_r, tile_l, seed):
    """The 2D property: any (frame, tile) geometry — lane tiles narrower
    or wider than the frame, ragged in both dims — stays bit-identical."""
    img = _rand((1, h, w), seed=seed)
    got = np.asarray(canny_edge_pallas(img, tile_rows=tile_r,
                                       tile_lanes=tile_l, interpret=True))
    np.testing.assert_array_equal(got, np.asarray(ref.canny_edge(img)))

"""Fused Canny megakernel: bit-exact parity with the jnp oracle.

All Pallas runs use interpret mode (CPU) — marked ``pallas`` so a TPU CI
lane can select them; they stay in tier-1 (fast, not ``slow``).
"""
import numpy as np
import jax.numpy as jnp
import pytest
from _propcheck import given, settings, st

from repro.kernels.canny_fused import ref
from repro.kernels.canny_fused.canny_fused import (HALO, MAX_WIDTH,
                                                   canny_edge_pallas)
from repro.kernels.canny_fused.ops import canny_edge

pytestmark = pytest.mark.pallas


def _rand(shape, seed=0):
    return jnp.asarray(np.random.default_rng(seed).random(shape, np.float32))


@pytest.mark.parametrize("shape,tile_rows", [
    ((1, 32, 32), None),    # single tile, whole frame
    ((3, 64, 64), None),    # batch, whole frame (the scene size)
    ((1, 96, 64), 32),      # row-tiled: 3 even tiles
    ((2, 40, 56), 16),      # row-tiled, non-tile-multiple height (3rd ragged)
    ((1, 37, 41), 13),      # odd, non-square, ragged last tile
])
def test_fused_bit_identical_to_oracle(shape, tile_rows):
    img = _rand(shape, seed=sum(shape))
    got = np.asarray(canny_edge_pallas(img, tile_rows=tile_rows,
                                       interpret=True))
    want = np.asarray(ref.canny_edge(img))
    np.testing.assert_array_equal(got, want)


def test_fused_thresholds_forwarded():
    img = _rand((1, 48, 48), seed=7)
    got = np.asarray(canny_edge_pallas(img, lo=0.2, hi=0.5, tile_rows=16,
                                       interpret=True))
    want = np.asarray(ref.canny_edge(img, lo=0.2, hi=0.5))
    np.testing.assert_array_equal(got, want)
    # different thresholds must actually change the map (guard against the
    # kernel silently ignoring lo/hi)
    assert got.any()
    assert not np.array_equal(got, np.asarray(ref.canny_edge(img)))


def test_tile_smaller_than_halo_is_an_error():
    with pytest.raises(ValueError, match="HALO"):
        canny_edge_pallas(_rand((1, 32, 32)), tile_rows=HALO - 1,
                          interpret=True)


def test_frame_wider_than_column_limit_is_a_clear_error():
    """The row-tiled kernel keeps whole rows in VMEM; frames wider than the
    column limit must fail with a pointer at the ROADMAP's lane-tiling
    item, not opaquely inside pallas_call."""
    wide = jnp.zeros((1, 16, MAX_WIDTH + 128), jnp.float32)
    with pytest.raises(ValueError, match="lane-dim \\(width\\) tiling"):
        canny_edge_pallas(wide, tile_rows=16, interpret=True)
    # the staged oracle remains the documented wide-frame fallback
    assert np.asarray(canny_edge(wide, impl="xla")).shape == wide.shape


def test_auto_dispatches_wide_frames_to_xla_fallback():
    """impl='auto' must SERVE a wide frame (xla fallback) instead of
    surfacing the Pallas kernel's column-limit ValueError; the fail-fast
    behavior stays with explicit impl='pallas'."""
    wide = _rand((1, 16, MAX_WIDTH + 128), seed=2)
    got = canny_edge(wide, impl="auto")
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ref.canny_edge(wide)))
    with pytest.raises(ValueError, match="lane-dim \\(width\\) tiling"):
        canny_edge(wide, impl="pallas")


def test_ops_dispatch():
    img = _rand((2, 32, 32), seed=3)
    want = np.asarray(ref.canny_edge(img))
    np.testing.assert_array_equal(
        np.asarray(canny_edge(img, impl="xla")), want)
    np.testing.assert_array_equal(
        np.asarray(canny_edge(img, impl="interpret")), want)


def test_staged_baseline_matches_fused_oracle():
    img = _rand((2, 48, 40), seed=5)
    np.testing.assert_array_equal(np.asarray(ref.canny_edge_staged(img)),
                                  np.asarray(ref.canny_edge(img)))


@settings(max_examples=8, deadline=None)
@given(h=st.integers(16, 70), w=st.integers(8, 70),
       tile=st.integers(HALO, 48), seed=st.integers(0, 10_000))
def test_fused_parity_property(h, w, tile, seed):
    """Any frame size (odd / non-square / non-tile-multiple) and any legal
    tile height produce bit-identical edge maps in interpret mode."""
    img = _rand((1, h, w), seed=seed)
    got = np.asarray(canny_edge_pallas(img, tile_rows=tile, interpret=True))
    np.testing.assert_array_equal(got, np.asarray(ref.canny_edge(img)))

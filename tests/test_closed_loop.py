"""ProfileState plane + the fused closed loop: state<->table round trip,
pure-op mirrors, and exact scan-vs-scalar parity under drift."""
import os

import numpy as np
import pytest

from repro.core.closed_loop import (StreamMeasurements,
                                    measurements_from_fleet, scan_stream)
from repro.core.estimators import (EdgeDetectionEstimator,
                                   OutputBasedEstimator)
from repro.core.gateway import Gateway
from repro.core.policy import DetectionPolicy, Observation, RouteRequest
from repro.core.profiles import (ProfileEntry, ProfileTable, observe_state)
from repro.core.router import (GreedyEstimateRouter, OracleRouter,
                               greedy_route, route_batch)
from repro.detection import scenes as sc
from repro.detection.detectors import DETECTOR_CONFIGS
from repro.detection.devices import (DriftEvent, DriftingFleet,
                                     TESTBED_PAIRS, drift_scenario,
                                     nominal_profile_table)


def f32(x):
    return float(np.float32(x))


# ------------------------------------------------- state <-> table round trip

def test_state_table_round_trip():
    table = nominal_profile_table()
    state = table.as_state()
    back = table.copy()
    back.load_state(state)
    # load_state rounds through f32 (the state dtype) but nothing else
    for a, b in zip(table.entries, back.entries):
        assert (a.model, a.device, a.group) == (b.model, b.device, b.group)
        assert b.map_pct == f32(a.map_pct)
        assert b.time_ms == f32(a.time_ms)
        assert b.energy_mwh == f32(a.energy_mwh)
    # a second export is a fixed point: f32 values survive exactly
    again = back.with_state(back.as_state())
    assert again.entries == back.entries


def test_state_round_trip_through_json(tmp_path):
    table = nominal_profile_table()
    state = table.as_state()
    # fold a runtime observation into the state, persist, reload
    state = observe_state(state, 0, 2, time_ms=99.0, energy_mwh=7.0,
                          map_pct=41.0, alpha=0.5)
    adapted = table.with_state(state)
    path = os.path.join(tmp_path, "profile.json")
    adapted.to_json(path)
    reloaded = ProfileTable.from_json(path)
    assert reloaded.entries == adapted.entries
    np.testing.assert_array_equal(np.asarray(reloaded.as_state().map_pct),
                                  np.asarray(state.map_pct))


def test_load_state_rejects_foreign_layout():
    table = nominal_profile_table()
    other = ProfileTable([ProfileEntry("m", "d", 0, 50.0, 1.0, 1.0)])
    with pytest.raises(ValueError, match="as_state"):
        table.load_state(other.as_state())


def test_load_state_invalidates_cached_views():
    table = nominal_profile_table()
    arrays = table.as_arrays()
    before = route_batch([1], table, 5.0)[0]
    favorite = arrays.pairs.index(table.entries[before].pair)
    state = observe_state(arrays.state, favorite, 0, energy_mwh=1e6,
                          alpha=1.0)
    table.load_state(state)
    after = route_batch([1], table, 5.0)[0]
    assert table.entries[after] is greedy_route(1, table, 5.0)
    assert after != before  # the poisoned favorite lost the argmin


def test_route_batch_accepts_state_snapshot():
    """Routers consume either face: the table or its ProfileArrays/state."""
    table = nominal_profile_table()
    counts = [0, 2, 5, 7, 1]
    np.testing.assert_array_equal(route_batch(counts, table, 5.0),
                                  route_batch(counts, table.as_arrays(), 5.0))


# ----------------------------------------------------- observe_state mirrors

def test_observe_state_mirrors_observe_pair():
    table = nominal_profile_table()
    arrays = table.as_arrays()
    pair = arrays.pairs[3]
    state = observe_state(arrays.state, 3, 0, time_ms=123.0, energy_mwh=9.0,
                          alpha=0.3)
    table.observe_pair(pair, time_ms=123.0, energy_mwh=9.0, alpha=0.3)
    want = table.as_arrays().state
    np.testing.assert_allclose(np.asarray(state.time_ms),
                               np.asarray(want.time_ms), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(state.energy_mwh),
                               np.asarray(want.energy_mwh), rtol=1e-6)
    # map untouched by a latency/energy observation
    np.testing.assert_array_equal(np.asarray(state.map_pct),
                                  np.asarray(arrays.state.map_pct))


def test_observe_state_map_touches_one_cell():
    table = nominal_profile_table()
    arrays = table.as_arrays()
    state = observe_state(arrays.state, 2, 4, map_pct=10.0, alpha=0.5)
    diff = np.asarray(state.map_pct) != np.asarray(arrays.state.map_pct)
    assert diff.sum() == 1
    g, p = map(int, np.argwhere(diff)[0])
    assert g == 4 and int(np.asarray(arrays.state.pair_id)[g, p]) == 2


def test_observe_state_nan_is_the_traced_no_op():
    table = nominal_profile_table()
    state = table.as_state()
    same = observe_state(state, 0, 0, time_ms=np.nan, energy_mwh=np.nan,
                         map_pct=np.nan, alpha=0.9)
    for a, b in zip(state, same):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------ scan_stream ≡ scalar loop

def _drift_measurements(fleet, pairs, steps):
    """Scalar reference builder: one fleet.cost call per (step, pair) —
    what measurements_from_fleet must reproduce vectorized."""
    t = np.empty((steps, len(pairs)))
    e = np.empty((steps, len(pairs)))
    for j, (m, d) in enumerate(pairs):
        flops = DETECTOR_CONFIGS[m].flops
        for step in range(steps):
            t[step, j], e[step, j] = fleet.cost(d, flops, step)
    return StreamMeasurements(time_ms=t, energy_mwh=e)


@pytest.mark.parametrize("scenario", ["thermal", "background", "dropout"])
def test_measurements_from_fleet_matches_scalar_costs(scenario):
    """The ONE shared measurement builder (gateway + bench use it) equals
    the per-step scalar fleet.cost for every drift kind, and composed
    events; without a fleet it equals the offline device model."""
    pairs = nominal_profile_table().as_arrays().pairs
    fleet = drift_scenario(scenario, device="pi5_tpu", start=7)
    got = measurements_from_fleet(pairs, 60, fleet)
    want = _drift_measurements(fleet, pairs, 60)
    np.testing.assert_allclose(got.time_ms, want.time_ms, rtol=1e-12)
    np.testing.assert_allclose(got.energy_mwh, want.energy_mwh, rtol=1e-12)
    composed = DriftingFleet([
        DriftEvent("pi5_tpu", "background", severity=3.0, period=10),
        DriftEvent("pi5_tpu", "dropout", start=5, end=20, severity=4.0)])
    got = measurements_from_fleet(pairs, 40, composed)
    want = _drift_measurements(composed, pairs, 40)
    np.testing.assert_allclose(got.energy_mwh, want.energy_mwh, rtol=1e-12)
    static = measurements_from_fleet(pairs, 3)
    want = _drift_measurements(DriftingFleet([]), pairs, 3)
    np.testing.assert_allclose(static.energy_mwh, want.energy_mwh,
                               rtol=1e-12)


def _scalar_closed_loop(table, counts, meas, delta, alpha):
    """The longhand scalar reference: greedy_route -> observe_pair, exactly
    what DetectionPolicy runs frame-at-a-time under adapt=True."""
    pairs = table.pairs()
    picks = []
    for t, c in enumerate(counts):
        entry = greedy_route(int(c), table, delta)
        picks.append(entry.pair)
        j = pairs.index(entry.pair)
        table.observe_pair(entry.pair, time_ms=meas.time_ms[t, j],
                           energy_mwh=meas.energy_mwh[t, j], alpha=alpha)
    return picks


@pytest.mark.parametrize("scenario", ["thermal", "background", "dropout"])
def test_scan_stream_exact_parity_under_drift(scenario):
    """Acceptance: on a drifting 200-frame stream the scanned closed loop
    routes the SAME pairs and lands on the same profile state (allclose —
    f32 vs float64 EWMA rounding) as the scalar loop, for every DriftEvent
    kind."""
    steps, delta, alpha = 200, 5.0, 0.15
    rng = np.random.default_rng(11)
    counts = rng.choice(len(sc.COUNT_PROBS), p=sc.COUNT_PROBS, size=steps)
    table = nominal_profile_table()
    favorite = greedy_route(int(np.argmax(np.bincount(counts))), table,
                            delta).device
    fleet = drift_scenario(scenario, device=favorite, start=steps // 4)
    arrays = table.as_arrays()
    meas = _drift_measurements(fleet, arrays.pairs, steps)

    ref_table = table.copy()
    scalar_picks = _scalar_closed_loop(ref_table, counts, meas, delta, alpha)

    state, trace = scan_stream(arrays.state, counts, meas, arrays=arrays,
                               delta=delta, alpha=alpha)
    scan_picks = [arrays.pairs[j] for j in trace.pair_idx]
    assert scan_picks == scalar_picks
    assert len(set(scan_picks)) > 1  # the drift actually forced a reroute
    want = ref_table.as_arrays().state
    np.testing.assert_allclose(np.asarray(state.energy_mwh),
                               np.asarray(want.energy_mwh), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(state.time_ms),
                               np.asarray(want.time_ms), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(state.map_pct),
                               np.asarray(want.map_pct), rtol=1e-5)
    # the trace maps back into table identity
    for t, (g, i) in enumerate(zip(trace.group_row, trace.entry_idx)):
        assert table.entries[i].pair == scan_picks[t]
        assert arrays.groups[g] == table.entries[i].group


def test_scan_stream_unprofiled_group_raises_eagerly():
    table = ProfileTable([ProfileEntry("m", "d", 0, 50.0, 1.0, 1.0)])
    arrays = table.as_arrays()
    meas = StreamMeasurements(time_ms=np.ones((2, 1)),
                              energy_mwh=np.ones((2, 1)))
    with pytest.raises(ValueError, match="no profile rows for group 4"):
        scan_stream(arrays.state, [0, 7], meas, arrays=arrays, delta=5.0)


def test_scan_stream_rejects_misshapen_measurements():
    table = nominal_profile_table()
    arrays = table.as_arrays()
    meas = StreamMeasurements(time_ms=np.ones((3, 2)),
                              energy_mwh=np.ones((3, 2)))
    with pytest.raises(ValueError, match="one row per step"):
        scan_stream(arrays.state, [1, 1, 1], meas, arrays=arrays, delta=5.0)


# -------------------------------------------- decide_scan ≡ scalar decide

def _policy(table, *, explore_every=0, est=True):
    router = (GreedyEstimateRouter if est else OracleRouter)(table, 5.0)
    return DetectionPolicy(router, table,
                           EdgeDetectionEstimator() if est else None,
                           adapt=True, alpha=0.2,
                           explore_every=explore_every)


@pytest.mark.parametrize("explore_every,est", [(0, True), (4, True),
                                               (0, False), (4, False)])
def test_decide_scan_matches_scalar_decide_observe_loop(explore_every, est):
    """decide_scan returns the SAME RouteDecisions (pair, est_complexity,
    gateway cost, explored flag) and leaves the SAME adapted table as the
    scalar decide/observe interleave it compiles."""
    steps = 60
    scenes = sc.drifting_dataset(n=steps, seed=3)
    reqs = [RouteRequest(uid=i, payload=s.image, true_complexity=s.count)
            for i, s in enumerate(scenes)]
    table = nominal_profile_table()
    fleet = DriftingFleet([DriftEvent("pi5_aihat", "thermal", start=10,
                                      severity=5.0, ramp=15)])
    arrays = table.as_arrays()
    meas = _drift_measurements(fleet, arrays.pairs, steps)

    # scalar reference: the exact per-frame interleave
    ref_table = table.copy()
    ref = _policy(ref_table, explore_every=explore_every, est=est)
    ref_pairs = ref_table.pairs()
    want = []
    for t, req in enumerate(reqs):
        d = ref.decide(req)
        want.append(d)
        j = ref_pairs.index(d.pair)
        ref.observe(Observation(pair=d.pair,
                                group=ref.group_for(req.true_complexity),
                                time_ms=meas.time_ms[t, j],
                                energy_mwh=meas.energy_mwh[t, j]))

    policy = _policy(table, explore_every=explore_every, est=est)
    assert policy.scannable
    got = policy.decide_scan(reqs, meas)
    assert got == want
    if explore_every:
        assert any(d.explored for d in got)
    np.testing.assert_allclose(
        np.asarray(table.as_arrays().state.energy_mwh),
        np.asarray(ref_table.as_arrays().state.energy_mwh), rtol=1e-5)


def test_decide_scan_requires_scannable():
    table = nominal_profile_table()
    policy = DetectionPolicy(OracleRouter(table, 5.0), table,
                             OutputBasedEstimator())
    assert not policy.scannable  # open loop: use decide_batch, not the scan
    with pytest.raises(ValueError, match="scannable"):
        policy.decide_scan([], None)


def test_ob_estimator_is_not_scannable():
    """OB's counts are per-frame feedback from the served result — the one
    estimator whose closed loop must stay scalar."""
    table = nominal_profile_table()
    policy = DetectionPolicy(GreedyEstimateRouter(table, 5.0), table,
                             OutputBasedEstimator(), adapt=True)
    assert not policy.scannable


# -------------------------------------------------- gateway scanned path

def _fake_run_detector(params, images):
    none = np.zeros((0, 4), np.float32)
    return [(none, np.zeros(0, np.float32), np.zeros(0, np.int32))
            for _ in range(len(images))]


def test_gateway_scanned_closed_loop_identical_to_scalar(monkeypatch):
    """Gateway(adapt=True, max_batch=N) routes through one lax.scan and
    batches dispatch — EpisodeStats and the adapted profile are IDENTICAL
    to the frame-at-a-time scalar loop on a drifting stream."""
    from repro.detection import train
    monkeypatch.setattr(train, "run_detector", _fake_run_detector)
    params = {m: None for m, _ in TESTBED_PAIRS}
    scenes = sc.drifting_dataset(n=80, seed=5)
    modal = int(np.argmax(np.bincount([s.count for s in scenes])))
    favorite = greedy_route(modal, nominal_profile_table(), 5.0).device
    fleet = drift_scenario("thermal", device=favorite, start=20)

    def episode(batch_routing, max_batch):
        table = nominal_profile_table()
        gw = Gateway(GreedyEstimateRouter(table, 5.0), table, params,
                     EdgeDetectionEstimator(), fleet=fleet, adapt=True,
                     alpha=0.2, explore_every=6,
                     batch_routing=batch_routing, max_batch=max_batch)
        assert gw.policy.scannable is batch_routing
        return gw.process_stream(scenes), table

    scanned, t_scan = episode(True, max_batch=8)
    scalar, t_scal = episode(False, max_batch=8)
    assert scanned == scalar  # decisions, costs, mAP, histogram — exact
    assert len(scanned.pair_histogram) > 1
    np.testing.assert_allclose(
        np.asarray(t_scan.as_arrays().state.energy_mwh),
        np.asarray(t_scal.as_arrays().state.energy_mwh), rtol=1e-5)


def test_service_submit_batch_rejects_mismatched_decisions():
    from repro.serving.service import EcoreService
    table = nominal_profile_table()
    policy = DetectionPolicy(OracleRouter(table, 5.0), table)
    service = EcoreService(policy, lambda d: None)
    try:
        with pytest.raises(ValueError, match="decisions for"):
            service.submit_batch(
                [RouteRequest(uid=0, true_complexity=1)], decisions=[])
    finally:
        service.close()


def test_detector_backend_profile_row_reads_live_table():
    from repro.serving.backend import DetectorBackend
    table = nominal_profile_table()
    be = DetectorBackend("ssd_v1", "orin_nano", None,
                         run_fn=_fake_run_detector, table=table)
    nominal = be.profile_row()["energy_mwh"]
    table.observe_pair(("ssd_v1", "orin_nano"), energy_mwh=nominal * 10,
                       alpha=1.0)
    assert be.profile_row()["energy_mwh"] == pytest.approx(nominal * 10)
    # without a table the static device model answers, as before
    static = DetectorBackend("ssd_v1", "orin_nano", None,
                             run_fn=_fake_run_detector)
    assert static.profile_row()["energy_mwh"] == pytest.approx(nominal)


# ------------------------------------------------------ batched OB feedback

def test_observe_batch_ob_keeps_last_count():
    ob = OutputBasedEstimator(default=0)
    ob.observe_batch([3, 9, 5])
    assert ob.estimate(None)[0] == 5  # telescoped fold: last count wins
    ob.observe_batch([])
    assert ob.estimate(None)[0] == 5  # empty feedback is a no-op
    loop = OutputBasedEstimator(default=0)
    for c in [3, 9, 5]:
        loop.observe(c)
    assert loop.estimate(None) == ob.estimate(None)


def test_observe_batch_generic_fallback_loops_observe():
    calls = []

    class Spy(EdgeDetectionEstimator):
        def observe(self, c):
            calls.append(c)

    Spy().observe_batch([1, 2])
    assert calls == [1, 2]

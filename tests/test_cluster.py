"""EcoreCluster: jitted shard selection (exact parity vs the scalar
reference), observe() fan-in to the owning pod, aggregated stats, and
concurrent drain/close over independent pods."""
import numpy as np
import pytest
from _propcheck import given, settings, st

from repro.core.policy import Observation, PoolPolicy, RouteRequest
from repro.core.profiles import ProfileEntry, ProfileTable
from repro.serving.cluster import (EcoreCluster, select_pods,
                                   select_pods_reference)
from repro.serving.engine import Result
from repro.serving.pool import LENGTH_BUCKETS, ServingPool


def _pool(delta=5.0):
    entries = [ProfileEntry(a, "pod", b, score - drop * b, 1.0, energy)
               for a, score, drop, energy in (("small", 80.0, 3.0, 1.0),
                                              ("big", 84.0, 1.0, 5.0))
               for _, _, b in LENGTH_BUCKETS]
    return ServingPool(ProfileTable(entries), delta=delta)


class _StubBackend:
    def __init__(self, name="stub", max_batch=4):
        self.name = name
        self.max_batch = max_batch
        self.batch_sizes = []

    def serve_batch(self, requests):
        self.batch_sizes.append(len(requests))
        return [Result(uid=r.uid, tokens=np.asarray([r.uid], np.int32),
                       prefill_s=.01, decode_s=.01, backend=self.name,
                       batch_size=len(requests)) for r in requests]

    def profile_row(self):
        return {"kind": "stub", "model": self.name,
                "max_batch": self.max_batch}


def _req(uid, plen=64):
    return RouteRequest(uid=uid, complexity=plen, payload=np.arange(8),
                        max_new_tokens=4)


# --------------------------------------------------- shard-selection parity

def test_shard_selection_batch_matches_scalar_reference():
    rng = np.random.default_rng(0)
    for pods in (1, 2, 4, 7):
        for n in (1, 5, 64):
            uids = rng.integers(0, 2**31, size=n)
            depths = rng.integers(0, 9, size=pods)
            for mode in ("least_loaded", "rendezvous"):
                got = select_pods(uids, depths, mode)
                want = select_pods_reference(uids, depths, mode)
                np.testing.assert_array_equal(got, want), (mode, pods, n)


@settings(max_examples=60, deadline=None)
@given(uids=st.lists(st.integers(0, 2**32 - 1), min_size=1, max_size=24),
       depths=st.lists(st.integers(0, 20), min_size=1, max_size=6),
       mode_idx=st.integers(0, 1))
def test_shard_selection_parity_property(uids, depths, mode_idx):
    mode = ("least_loaded", "rendezvous")[mode_idx]
    np.testing.assert_array_equal(select_pods(uids, depths, mode),
                                  select_pods_reference(uids, depths, mode))


def test_least_loaded_is_sequential_greedy():
    """Each assignment must see the depths the previous ones produced —
    a batch over equal depths round-robins instead of piling on pod 0."""
    picks = select_pods(np.arange(8), np.zeros(4, int), "least_loaded")
    assert picks.tolist() == [0, 1, 2, 3, 0, 1, 2, 3]
    # unequal start: fills the valleys first (ties -> lowest pod index)
    picks = select_pods(np.arange(3), np.asarray([2, 0, 1]), "least_loaded")
    assert picks.tolist() == [1, 1, 2]


def test_rendezvous_is_stable_and_spread():
    uids = np.arange(256)
    first = select_pods(uids, np.zeros(4, int), "rendezvous")
    second = select_pods(uids, np.ones(4, int) * 7, "rendezvous")
    np.testing.assert_array_equal(first, second)   # depth-independent
    counts = np.bincount(first, minlength=4)
    assert (counts > 32).all()                     # no pod starved
    # pod-count change reshuffles only partially (HRW affinity)
    three = select_pods(uids, np.zeros(3, int), "rendezvous")
    moved = (three != first).mean()
    assert moved < 0.5


def test_unknown_shard_mode_is_a_clear_error():
    with pytest.raises(ValueError, match="unknown shard mode"):
        select_pods([1], [0, 0], "hash_ring")
    with pytest.raises(ValueError, match="unknown shard mode"):
        EcoreCluster(lambda i: PoolPolicy(_pool()), lambda d: _StubBackend(),
                     pods=2, shard="hash_ring")


# ------------------------------------------------------------ cluster plane

def test_cluster_serves_across_pods_and_aggregates_stats():
    built = []

    def factory(decision):
        be = _StubBackend(decision.backend, max_batch=2)
        built.append(be)
        return be

    with EcoreCluster(lambda i: PoolPolicy(_pool()), factory,
                      pods=2) as cluster:
        futs = cluster.submit_batch([_req(i) for i in range(8)])
        cluster.drain()
        served = [f.result(timeout=5.0) for f in futs]
        assert [s.result.uid for s in served] == list(range(8))  # req order
        stats = cluster.stats()
    assert stats["pods"] == 2 and stats["served"] == 8
    assert sum(stats["shard_counts"]) == 8
    assert all(c == 4 for c in stats["shard_counts"])   # least-loaded split
    assert len(stats["per_pod"]) == 2
    # pods are independent: each built its own backend for the same pair
    assert len(built) == 2


def test_cluster_scalar_submit_matches_batch_sharding():
    """Under rendezvous (assignment depends only on the uid, not on live
    depths) the per-request path (scalar reference) and the batch path
    (jitted) must assign every uid to the SAME pod."""
    def factory(decision):
        return _StubBackend(decision.backend, max_batch=1)

    uids = list(range(9))
    expected = select_pods_reference(uids, np.zeros(3, int), "rendezvous")

    with EcoreCluster(lambda i: PoolPolicy(_pool()), factory,
                      pods=3, shard="rendezvous") as scalar_c:
        for i in uids:
            scalar_c.submit(_req(i)).result(timeout=5.0)
        scalar_owner = dict(scalar_c._owner)
        scalar_counts = scalar_c.stats()["shard_counts"]

    with EcoreCluster(lambda i: PoolPolicy(_pool()), factory,
                      pods=3, shard="rendezvous") as batch_c:
        futs = batch_c.submit_batch([_req(i) for i in uids])
        [f.result(timeout=5.0) for f in futs]
        batch_owner = dict(batch_c._owner)
        batch_counts = batch_c.stats()["shard_counts"]

    want = {u: int(p) for u, p in zip(uids, expected)}
    assert scalar_owner == batch_owner == want
    assert scalar_counts == batch_counts


def test_cluster_observe_folds_into_owning_pod():
    pools = [_pool(), _pool()]

    def factory(decision):
        # deep queues: requests stay IN FLIGHT, so least-loaded sees live
        # depths and spreads uid 0 -> pod 0, uid 1 -> pod 1
        return _StubBackend(decision.backend, max_batch=8)

    with EcoreCluster(lambda i: PoolPolicy(pools[i], alpha=1.0), factory,
                      pods=2) as cluster:
        f0 = cluster.submit(_req(0))         # pod 0 (least loaded, tie -> 0)
        f1 = cluster.submit(_req(1))         # pod 1 (pod 0 busy)
        cluster.drain()
        assert f0.result(5.0) and f1.result(5.0)
        # uid-tagged: folds ONLY into the owning pod's policy
        cluster.observe(Observation(pair=("small", "pod"), uid=1,
                                    energy_mwh=99.0))
        assert pools[1].table.entry(("small", "pod"), 0).energy_mwh == 99.0
        assert pools[0].table.entry(("small", "pod"), 0).energy_mwh == 1.0
        # un-tagged: pair-wide evidence broadcasts to every pod
        cluster.observe(Observation(pair=("small", "pod"), energy_mwh=50.0))
        assert pools[0].table.entry(("small", "pod"), 0).energy_mwh == 50.0
        assert pools[1].table.entry(("small", "pod"), 0).energy_mwh == 50.0
        # uid-tagged but owner unknown: DROPPED (counted), never smeared
        # across every pod as if it were pair-wide evidence
        cluster.observe(Observation(pair=("small", "pod"), uid=999,
                                    energy_mwh=0.001))
        assert pools[0].table.entry(("small", "pod"), 0).energy_mwh == 50.0
        assert pools[1].table.entry(("small", "pod"), 0).energy_mwh == 50.0
        assert cluster.stats()["stale_observations"] == 1


class _FailingBackend(_StubBackend):
    def serve_batch(self, requests):
        raise RuntimeError("backend exploded")


def test_cluster_submit_error_does_not_leak_depth():
    """A failing inline flush on the scalar path must un-count the request,
    or least-loaded routes away from the pod for the cluster's lifetime."""
    with EcoreCluster(lambda i: PoolPolicy(_pool()),
                      lambda d: _FailingBackend(d.backend, max_batch=1),
                      pods=2) as cluster:
        with pytest.raises(RuntimeError, match="backend exploded"):
            cluster.submit(_req(0))
        assert cluster._depth.tolist() == [0, 0]   # no phantom load


def test_cluster_rejects_bad_pod_count():
    with pytest.raises(ValueError, match="at least one pod"):
        EcoreCluster(lambda i: PoolPolicy(_pool()), lambda d: _StubBackend(),
                     pods=0)


def test_cluster_drain_flushes_partial_batches_everywhere():
    def factory(decision):
        return _StubBackend(decision.backend, max_batch=8)

    with EcoreCluster(lambda i: PoolPolicy(_pool()), factory,
                      pods=2) as cluster:
        futs = cluster.submit_batch([_req(i) for i in range(5)])
        assert not any(f.done() for f in futs)   # 8-deep queues: all pending
        drained = cluster.drain()
        assert len(drained) == 5
        assert all(f.done() for f in futs)


# ------------------------------------------------- graceful degradation

from repro.core.policy import RouteDecision  # noqa: E402
from repro.serving.cluster import NoLivePods  # noqa: E402


class _PinnedPolicy:
    """Per-pod policy that routes everything to ONE fixed pair — the model
    name encodes the pod, so a Served's backend identifies who served it."""
    batchable = True

    def __init__(self, pair):
        self.pair = pair
        self.observed = []

    def decide(self, req):
        return RouteDecision(uid=req.uid, pair=self.pair, group=0)

    def decide_batch(self, reqs):
        return [self.decide(r) for r in reqs]

    def observe(self, obs):
        self.observed.append(obs)


def test_shard_selection_masked_parity_and_avoids_dead():
    rng = np.random.default_rng(1)
    for pods in (2, 4, 7):
        alive = np.ones(pods, bool)
        alive[0] = False
        uids = rng.integers(0, 2**31, size=40)
        depths = rng.integers(0, 9, size=pods)
        for mode in ("least_loaded", "rendezvous"):
            got = select_pods(uids, depths, mode, alive=alive)
            want = select_pods_reference(uids, depths, mode, alive=alive)
            np.testing.assert_array_equal(got, want), (mode, pods)
            assert alive[got].all()          # never a dead pod
    # alive=None is the original unmasked kernel, bit-identical to seed
    uids = rng.integers(0, 2**31, size=64)
    for mode in ("least_loaded", "rendezvous"):
        np.testing.assert_array_equal(
            select_pods(uids, np.zeros(4, int), mode, alive=None),
            select_pods(uids, np.zeros(4, int), mode))


def test_mark_pod_failed_masks_shard_selection():
    with EcoreCluster(lambda i: PoolPolicy(_pool()),
                      lambda d: _StubBackend(d.backend, max_batch=1),
                      pods=2) as cluster:
        cluster.mark_pod_failed(0)
        futs = cluster.submit_batch([_req(u) for u in range(6)])
        cluster.drain()
        assert all(f.exception() is None for f in futs)
        stats = cluster.stats()
        assert stats["alive"] == [False, True]
        assert stats["availability"] == 0.5
        assert cluster.shard_counts.tolist()[0] == 0   # all on pod 1


@pytest.mark.threads
def test_cluster_masks_failed_pod_and_resubmits_inflight():
    """Pod 0's device dies outright; after ``pod_fail_after`` consecutive
    errors the pod is masked out, its failed in-flight requests move to
    survivors, and uid-keyed observations follow the move."""
    n, fail_after = 40, 2
    policies = [_PinnedPolicy((f"m{i}", "dead" if i == 0 else "ok"))
                for i in range(3)]

    def backend_factory(decision):
        cls = (_FailingBackend if decision.pair[1] == "dead"
               else _StubBackend)
        return cls(decision.backend, max_batch=1)

    cluster = EcoreCluster(lambda i: policies[i], backend_factory,
                           pods=3, pod_fail_after=fail_after)
    futs = cluster.submit_batch([_req(u) for u in range(n)])
    cluster.drain()
    served = [f.result(5.0) for f in futs if f.exception() is None]
    stats = cluster.stats()
    # at most fail_after - 1 requests may fail before detection trips
    assert len(served) >= n - (fail_after - 1)
    assert stats["alive"] == [False, True, True]
    assert stats["availability"] == pytest.approx(2 / 3)
    assert stats["resubmitted"] >= 1
    assert not any(s.result.backend == "m0" for s in served)
    # Observation fan-in after the move: the owner map follows the
    # resubmission, so uid-keyed evidence folds into the pod that
    # ACTUALLY served — never the dead pod, never dropped as stale
    for s in served:
        cluster.observe(Observation(pair=s.decision.pair,
                                    uid=s.request.uid, time_ms=1.0))
    assert cluster.stats()["stale_observations"] == 0
    assert not policies[0].observed             # dead pod got nothing
    for i in (1, 2):
        got = {o.uid for o in policies[i].observed}
        want = {s.request.uid for s in served
                if s.result.backend == f"m{i}"}
        assert got == want
    cluster.close()


@pytest.mark.threads
def test_cluster_all_pods_dead_raises_no_live_pods():
    cluster = EcoreCluster(lambda i: _PinnedPolicy((f"m{i}", "dead")),
                           lambda d: _FailingBackend(d.backend, max_batch=1),
                           pods=2, pod_fail_after=1)
    futs = cluster.submit_batch([_req(u) for u in range(6)])
    cluster.drain()
    assert all(f.exception() is not None for f in futs)
    assert cluster.stats()["alive"] == [False, False]
    assert cluster.stats()["availability"] == 0.0
    with pytest.raises(NoLivePods):
        cluster.submit(_req(100))
    cluster.close()

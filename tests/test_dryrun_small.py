"""Dry-run machinery on a small simulated mesh (subprocess: jax device
count is locked at first init, so the 8-device test must run isolated)."""
import json
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
# force the host platform BEFORE jax import: the 8 simulated devices only
# exist on CPU, and without this a libtpu install probes GCP instance
# metadata with minutes of retries (the stripped subprocess env drops the
# JAX_PLATFORMS=cpu this container's shell exports)
os.environ["JAX_PLATFORMS"] = "cpu"
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
from repro.configs import get_config
from repro.launch.dryrun import lower_combo
from repro.launch import hlo_cost
from repro.launch.mesh import make_mesh
from repro.models.base import InputShape
from repro.sharding import specs as sp

mesh = make_mesh((2, 4), ("data", "model"))
cfg = get_config("qwen2.5-3b").reduced(d_model=256, num_heads=8,
                                       num_kv_heads=4, head_dim=32,
                                       vocab_size=512, d_ff=512)
out = {}
for shape in (InputShape("t", 64, 8, "train"), InputShape("p", 64, 8, "prefill"),
              InputShape("d", 64, 8, "decode")):
    lowered = lower_combo(cfg, shape, mesh)
    compiled = lowered.compile()
    cost = hlo_cost.analyze(compiled.as_text())
    mem = compiled.memory_analysis()
    out[shape.kind] = {"flops": cost.flops, "bytes": cost.bytes,
                       "coll": cost.coll_bytes,
                       "temp": float(getattr(mem, "temp_size_in_bytes", 0))}
print("RESULT " + json.dumps(out))
"""


@pytest.mark.slow
def test_dryrun_lowers_on_small_mesh():
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=420,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    assert r.returncode == 0, r.stderr[-2000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT ")][-1]
    out = json.loads(line[len("RESULT "):])
    for kind in ("train", "prefill", "decode"):
        assert out[kind]["flops"] > 0
        assert out[kind]["bytes"] > 0
    # training does ~3x the flops of prefill (fwd+bwd) on same token count
    assert out["train"]["flops"] > 1.5 * out["prefill"]["flops"]
    # training on a sharded mesh must communicate (FSDP gathers / grad AR)
    assert out["train"]["coll"] > 0

"""Estimator (ED/SF/OB) and scene-generator tests."""
import numpy as np
import pytest
from _propcheck import given, settings, st

from repro.core.estimators import (EdgeDetectionEstimator, OracleEstimator,
                                   OutputBasedEstimator)
from repro.detection import scenes as sc


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 8), st.integers(0, 10_000))
def test_scene_invariants(count, seed):
    s = sc.make_scene(np.random.default_rng(seed), count=count)
    assert s.count == count == len(s.boxes) == len(s.classes)
    assert s.image.shape == (sc.IMG, sc.IMG)
    assert s.image.min() >= 0 and s.image.max() <= 1
    for b in s.boxes:
        assert 0 <= b[0] < b[2] <= sc.IMG
        assert 0 <= b[1] < b[3] <= sc.IMG


def test_balanced_sorted_structure():
    ds = sc.balanced_sorted_dataset(per_group=5, seed=0)
    assert len(ds) == 25
    groups = [min(s.count, 4) for s in ds]
    assert groups == sorted(groups)
    assert groups[:5] == [0] * 5


def test_video_temporal_continuity():
    ds = sc.video_dataset(n_frames=60, seed=0)
    counts = [s.count for s in ds]
    jumps = [abs(a - b) for a, b in zip(counts, counts[1:])]
    assert max(jumps) <= 1  # counts random-walk by one


def test_ed_estimator_correlates():
    scenes = sc.full_dataset(30, seed=3)
    est = EdgeDetectionEstimator()
    preds = []
    for s in scenes:
        c, flops = est.estimate(s.image)
        assert flops > 0
        preds.append(c)
    true = np.array([s.count for s in scenes])
    preds = np.array(preds)
    # coarse but informative: correlation and bounded error
    assert np.corrcoef(true, preds)[0, 1] > 0.5
    assert np.abs(true - preds).mean() < 2.5


def test_ob_estimator_reuses_feedback():
    ob = OutputBasedEstimator(default=0)
    img = np.zeros((8, 8), np.float32)
    c, flops = ob.estimate(img)
    assert c == 0 and flops == 0
    ob.observe(3)
    assert ob.estimate(img)[0] == 3
    ob.reset()
    assert ob.estimate(img)[0] == 0


def test_oracle_estimator_passthrough():
    o = OracleEstimator()
    o.true_count = 5
    assert o.estimate(np.zeros((4, 4)))[0] == 5

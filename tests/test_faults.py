"""The fault plane end to end: deterministic injection (FaultSpec /
FaultyBackend / hard dropout), the resilience layer (deadline, bounded
retry, hedged re-dispatch), and the scanned closed loop's quarantine
breaker (exact zero-fault parity, exclusion, half-open recovery).

The acceptance scenario lives here too: under a fault storm (error +
stall + crash-window on the fleet's energy favorite) the resilient
service completes >= 99% of requests within the deadline while the bare
service measurably does not.  Everything is uid-keyed and hash-seeded,
so every run injects byte-identical fault sequences."""
import numpy as np
import pytest

from repro.core.policy import DetectionPolicy, RouteRequest
from repro.core.profiles import probe_state, quarantine_state, with_fails
from repro.core.router import OracleRouter, runner_up_route
from repro.detection.devices import (DeviceDropout, DriftEvent,
                                     DriftingFleet, nominal_profile_table)
from repro.serving.backend import make_backend, null_run
from repro.serving.engine import Request, Result
from repro.serving.faults import (FAULT_KINDS, FaultSpec, FaultyBackend,
                                  InjectedFault)
from repro.serving.resilience import (CorruptResult, DeadlineExceeded,
                                      ResilientService, RetriesExhausted,
                                      RetryPolicy)
from repro.serving.service import ServiceClosed


class _StubBackend:
    def __init__(self, name="stub", max_batch=4):
        self.name = name
        self.max_batch = max_batch
        self.calls = 0

    def serve_batch(self, requests):
        self.calls += 1
        return [Result(uid=r.uid, tokens=np.asarray([r.uid], np.int32),
                       prefill_s=.01, decode_s=.01, backend=self.name,
                       batch_size=len(requests), time_ms=10.0)
                for r in requests]

    def profile_row(self):
        return {"kind": "stub", "model": self.name,
                "max_batch": self.max_batch}


def _requests(uids):
    return [Request(uid=u, prompt=np.zeros(4, np.int32), max_new_tokens=1)
            for u in uids]


# ------------------------------------------------------------- FaultSpec

def test_fault_spec_validates_kind_and_rate():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultSpec("meteor")
    with pytest.raises(ValueError, match="probability"):
        FaultSpec("error", rate=1.5)
    for kind in FAULT_KINDS:
        FaultSpec(kind)   # every documented kind constructs


def test_fault_spec_firing_is_deterministic_and_rate_exact():
    spec = FaultSpec("error", rate=0.3, seed=7)
    fired = [spec.fires(u) for u in range(4000)]
    # pure function of uid: a second pass is byte-identical
    assert fired == [spec.fires(u) for u in range(4000)]
    frac = sum(fired) / len(fired)
    assert 0.25 < frac < 0.35    # hash-thresholded, exact-in-distribution
    # a different seed fires on a different uid set
    other = [FaultSpec("error", rate=0.3, seed=8).fires(u)
             for u in range(4000)]
    assert other != fired
    # rate edges short-circuit
    assert not FaultSpec("error", rate=0.0).fires(1)
    assert FaultSpec("error", rate=1.0).fires(1)


def test_fault_kinds_draw_independent_streams_from_one_seed():
    uids = range(4000)
    streams = {k: [FaultSpec(k, rate=0.5, seed=3).fires(u) for u in uids]
               for k in ("error", "stall", "corrupt")}
    assert streams["error"] != streams["stall"]
    assert streams["stall"] != streams["corrupt"]


def test_crash_window_fires_exactly_in_uid_window():
    spec = FaultSpec("crash_window", start=10, end=20)
    assert [spec.fires(u) for u in range(25)] == \
        [10 <= u < 20 for u in range(25)]
    forever = FaultSpec("crash_window", start=5)   # end=None: no recovery
    assert forever.fires(5) and forever.fires(10 ** 6)
    assert not forever.fires(4)


# -------------------------------------------------------- FaultyBackend

def test_error_fault_raises_before_the_inner_backend_runs():
    inner = _StubBackend()
    fb = FaultyBackend(inner, [FaultSpec("error", rate=1.0)])
    with pytest.raises(InjectedFault) as exc:
        fb.serve_batch(_requests([3, 4]))
    assert inner.calls == 0           # the device never answered
    assert exc.value.kind == "error" and exc.value.uid == 3
    assert fb.injected["error"] == 1


def test_stall_and_corrupt_rewrite_results_per_uid():
    fb = FaultyBackend(_StubBackend(), [
        FaultSpec("stall", rate=1.0, stall_ms=500.0),
        FaultSpec("corrupt", rate=0.3, seed=2)])
    uids = list(range(8))
    corrupt = {u for u in uids if FaultSpec("corrupt", rate=0.3,
                                            seed=2).fires(u)}
    assert corrupt and len(corrupt) < len(uids)   # the split is real
    out = fb.serve_batch(_requests(uids))
    for res in out:
        if res.uid in corrupt:
            # corruption is detectable: NaN time, zeroed payload
            assert np.isnan(res.time_ms)
            assert not res.tokens.any()
        else:
            assert res.time_ms == 10.0 + 500.0    # stalled, not corrupted
    assert fb.injected["stall"] == len(uids)
    assert fb.injected["corrupt"] == len(corrupt)


def test_make_backend_faulty_prefix_wraps_the_registry():
    fb = make_backend("faulty:detector", "yolov8_n", "pi5_tpu", max_batch=2,
                      run_fn=null_run, faults=[FaultSpec("error", rate=1.0)])
    assert fb.name == "yolov8_n@pi5_tpu" and fb.max_batch == 2
    assert fb.profile_row()["faults"] == ["error"]
    with pytest.raises(InjectedFault):
        fb.serve_batch(_requests([0]))
    # no faults = transparent wrapper
    clean = make_backend("faulty:detector", "yolov8_n", "pi5_tpu",
                         max_batch=2, run_fn=null_run)
    res = clean.serve_batch(_requests([0]))[0]
    assert np.isfinite(res.time_ms)


# ----------------------------------------------------- hard dropout

def test_hard_dropout_raises_and_soft_dropout_penalizes():
    hard = DriftingFleet([DriftEvent("pi5_tpu", "dropout", start=5, end=9,
                                     hard=True)])
    assert hard.cost("pi5_tpu", 1e9, 4)[0] > 0       # before the window
    with pytest.raises(DeviceDropout) as exc:
        hard.cost("pi5_tpu", 1e9, 5)
    assert exc.value.device == "pi5_tpu" and exc.value.step == 5
    assert np.isfinite(hard.cost("pi5_tpu", 1e9, 9)[0])   # recovered
    # the vectorized face reports the scan's failure sentinel instead
    t, _ = hard.cost_profile("pi5_tpu", 1e9, 12)
    assert np.isinf(t[5:9]).all() and np.isfinite(t[:5]).all()
    # soft dropout (hard=False) keeps the flat penalty semantics
    soft = DriftingFleet([DriftEvent("pi5_tpu", "dropout", start=5, end=9,
                                     severity=3.0)])
    assert soft.cost("pi5_tpu", 1e9, 6)[0] == \
        pytest.approx(3.0 * soft.cost("pi5_tpu", 1e9, 0)[0])


# ------------------------------------------------------- RetryPolicy

def test_retry_delay_is_deterministic_exponential_and_jitter_bounded():
    p = RetryPolicy(backoff_ms=10.0, backoff_mult=2.0, jitter=0.5)
    assert p.delay_s(42, 1) == p.delay_s(42, 1)      # pure in (uid, attempt)
    assert p.delay_s(42, 1) != p.delay_s(43, 1)      # jitter varies by uid
    for attempt in (1, 2, 3):
        base = 10.0 * 2.0 ** (attempt - 1) / 1e3
        assert base <= p.delay_s(7, attempt) < base * 1.5
    flat = RetryPolicy(backoff_ms=10.0, jitter=0.0)
    assert flat.delay_s(1, 2) == pytest.approx(0.02)


# -------------------------------------------------- resilience harness

def _storm(n, device="orin_nano"):
    """error + stall + crash-window on one device, uid-deterministic."""
    return {device: [
        FaultSpec("error", rate=0.4, seed=3),
        FaultSpec("stall", rate=0.3, seed=5, stall_ms=10_000.0),
        FaultSpec("crash_window", start=n // 2, end=n // 2 + n // 5)]}


def _factory(faults_by_device):
    def factory(decision):
        model, device = decision.pair
        return make_backend("faulty:detector", model, device, max_batch=4,
                            run_fn=null_run,
                            faults=faults_by_device.get(device, []))
    return factory


def _policy(delta=2.0):
    table = nominal_profile_table()
    return DetectionPolicy(OracleRouter(table, delta), table)


def _reqs(n, seed=1):
    rng = np.random.default_rng(seed)
    return [RouteRequest(uid=u, payload=np.zeros((4, 4), np.float32),
                         true_complexity=int(rng.integers(1, 20)))
            for u in range(n)]


def _fake_clock():
    fake = [0.0]
    return fake, (lambda: fake[0])


@pytest.mark.threads
def test_chaos_storm_resilient_meets_deadline_baseline_does_not():
    """THE acceptance scenario: >= 99% goodput under the storm with the
    resilience layer, measurably broken without it."""
    n, deadline = 300, 500.0
    _, clock = _fake_clock()
    svc = ResilientService(_policy(), _factory(_storm(n)), clock=clock,
                           retry=RetryPolicy(deadline_ms=deadline,
                                             max_retries=3))
    futs = [svc.submit(r) for r in _reqs(n)]
    svc.drain()
    ok = sum(1 for f in futs if f.exception() is None
             and np.isfinite(f.result().result.time_ms)
             and f.result().result.time_ms <= deadline)
    stats = svc.stats()
    svc.close()
    assert ok / n >= 0.99, f"goodput {ok}/{n} under the storm"
    assert stats["failed"] == 0 and stats["pending"] == 0
    assert stats["retries"] > 0 and stats["hedges"] > 0

    # bare service, same storm, same uids: no recovery plane
    from repro.serving.service import EcoreService
    bare = EcoreService(_policy(), _factory(_storm(n)), clock=clock,
                        retain_results=False, buffer_errors=False)
    futs, inline_errors = [], 0
    for r in _reqs(n):
        try:
            futs.append(bare.submit(r))
        except InjectedFault:   # inline full-batch flush raises to submitter
            inline_errors += 1
    try:
        bare.drain()
    except InjectedFault:
        pass
    bare_ok = sum(1 for f in futs if f.exception() is None
                  and np.isfinite(f.result().result.time_ms)
                  and f.result().result.time_ms <= deadline)
    bare.close()
    assert bare_ok / n < 0.5, "the storm must actually hurt the baseline"
    assert ok > bare_ok


@pytest.mark.threads
def test_chaos_storm_is_reproducible_run_to_run():
    n = 120
    def run():
        _, clock = _fake_clock()
        svc = ResilientService(_policy(), _factory(_storm(n)), clock=clock,
                               retry=RetryPolicy(deadline_ms=500.0,
                                                 max_retries=3))
        futs = [svc.submit(r) for r in _reqs(n)]
        svc.drain()
        stats = svc.stats()
        svc.close()
        return (stats["retries"], stats["hedges"], stats["completed"],
                stats["failed"])
    assert run() == run()


@pytest.mark.threads
def test_hedged_retry_lands_on_the_runner_up_pair():
    # the favorite device errors on EVERY uid: attempt 1 always fails,
    # the hedge must move to Algorithm-1's runner-up feasible pair
    policy = _policy()
    favorite = policy.decide(_reqs(1)[0]).pair
    faults = {favorite[1]: [FaultSpec("error", rate=1.0)]}
    want = runner_up_route(int(_reqs(1)[0].true_complexity), policy.table,
                           policy.router.delta, exclude=[favorite]).pair
    _, clock = _fake_clock()
    svc = ResilientService(policy, _factory(faults), clock=clock,
                           retry=RetryPolicy(max_retries=2))
    fut = svc.submit(_reqs(1)[0])
    svc.drain()
    served = fut.result(timeout=5)
    stats = svc.stats()
    svc.close()
    assert served.decision.pair == want != favorite
    assert stats["retries"] >= 1 and stats["hedges"] >= 1


@pytest.mark.threads
def test_retries_exhausted_carries_the_last_failure():
    # every device errors: the whole retry budget burns, the outer future
    # fails with RetriesExhausted chaining the terminal InjectedFault
    devices = {e.device for e in nominal_profile_table().entries}
    faults = {d: [FaultSpec("error", rate=1.0)] for d in devices}
    _, clock = _fake_clock()
    svc = ResilientService(_policy(), _factory(faults), clock=clock,
                           retry=RetryPolicy(max_retries=2))
    fut = svc.submit(_reqs(1)[0])
    svc.drain()
    with pytest.raises(RetriesExhausted) as exc:
        fut.result(timeout=5)
    assert exc.value.attempts == 3            # 1 try + max_retries
    assert isinstance(exc.value.__cause__, InjectedFault)
    stats = svc.stats()
    svc.close()
    assert stats["failed"] == 1 and stats["completed"] == 0


@pytest.mark.threads
def test_stall_past_deadline_is_a_miss_and_retries_elsewhere():
    policy = _policy()
    favorite = policy.decide(_reqs(1)[0]).pair
    faults = {favorite[1]: [FaultSpec("stall", rate=1.0, stall_ms=10_000.0)]}
    _, clock = _fake_clock()
    svc = ResilientService(policy, _factory(faults), clock=clock,
                           retry=RetryPolicy(deadline_ms=500.0,
                                             max_retries=2))
    fut = svc.submit(_reqs(1)[0])
    svc.drain()
    served = fut.result(timeout=5)
    stats = svc.stats()
    svc.close()
    assert served.result.time_ms <= 500.0
    assert served.decision.pair != favorite
    assert stats["deadline_misses"] >= 1


@pytest.mark.threads
def test_corrupt_result_is_rejected_and_retried():
    policy = _policy()
    favorite = policy.decide(_reqs(1)[0]).pair
    faults = {favorite[1]: [FaultSpec("corrupt", rate=1.0)]}
    _, clock = _fake_clock()
    svc = ResilientService(policy, _factory(faults), clock=clock,
                           retry=RetryPolicy(max_retries=2))
    fut = svc.submit(_reqs(1)[0])
    svc.drain()
    served = fut.result(timeout=5)
    svc.close()
    assert np.isfinite(served.result.time_ms)
    assert served.decision.pair != favorite


@pytest.mark.threads
def test_wall_clock_deadline_stops_retry_scheduling():
    # the injectable clock jumps past the deadline between attempts: the
    # retry is NOT scheduled, the request fails as a deadline miss
    devices = {e.device for e in nominal_profile_table().entries}
    faults = {d: [FaultSpec("error", rate=1.0)] for d in devices}
    fake, clock = _fake_clock()
    svc = ResilientService(_policy(), _factory(faults), clock=clock,
                           retry=RetryPolicy(deadline_ms=500.0,
                                             max_retries=5))
    fut = svc.submit(_reqs(1)[0])
    fake[0] = 10.0            # 10 s later on the injectable clock
    svc.drain()
    with pytest.raises(RetriesExhausted) as exc:
        fut.result(timeout=5)
    assert isinstance(exc.value.__cause__, DeadlineExceeded)
    assert exc.value.attempts < 6   # budget NOT burned: deadline cut it
    svc.close()


@pytest.mark.threads
def test_resilient_close_is_idempotent_and_structured():
    _, clock = _fake_clock()
    svc = ResilientService(_policy(), _factory({}), clock=clock)
    fut = svc.submit(_reqs(1)[0])
    svc.close()
    assert fut.result(timeout=5).result.time_ms is not None
    svc.close()                        # idempotent
    with pytest.raises(ServiceClosed):
        svc.submit(_reqs(1)[0])
    with ResilientService(_policy(), _factory({}), clock=clock) as ctx:
        ctx.submit_batch(_reqs(3))
    with pytest.raises(ServiceClosed):
        ctx.submit(_reqs(1)[0])        # __exit__ closed it


# ------------------------------------- quarantine breaker (pure ops)

def _arrays():
    return nominal_profile_table().as_arrays()


def test_quarantine_state_counts_consecutive_failures_per_cell():
    st = with_fails(_arrays().state)
    assert not np.asarray(st.fails).any()          # all breakers closed
    st = quarantine_state(st, 3, 0, True)
    st = quarantine_state(st, 3, 0, True)
    fails = np.asarray(st.fails)
    assert fails.sum() == 2 and fails[0].max() == 2   # one cell, row 0
    st = quarantine_state(st, 3, 0, False)            # success resets
    assert not np.asarray(st.fails).any()


def test_probe_state_closes_the_breaker_pair_wide():
    st = with_fails(_arrays().state)
    for row in (0, 1, 2):
        for _ in range(3):
            st = quarantine_state(st, 3, row, True)
    assert np.asarray(st.fails).sum() == 9
    st_fail = probe_state(st, 3, False)     # failed probe: identity
    np.testing.assert_array_equal(np.asarray(st_fail.fails),
                                  np.asarray(st.fails))
    st_ok = probe_state(st, 3, True)        # success: every row clears
    assert not np.asarray(st_ok.fails).any()


# --------------------------------- quarantine inside the jitted scan

def _scan(quarantine_after=None, fleet=None, steps=160, explore=None):
    from repro.core.closed_loop import (measurements_from_fleet,
                                        scan_stream)
    table = nominal_profile_table()
    arrays = table.as_arrays()
    rng = np.random.default_rng(0)
    counts = rng.integers(1, 20, size=steps)
    meas = measurements_from_fleet(arrays.pairs, steps, fleet)
    state, dec = scan_stream(arrays.state, counts, meas, arrays=arrays,
                             delta=2.0, quarantine_after=quarantine_after,
                             explore_pairs=explore)
    return arrays, state, dec


def test_scan_zero_fault_parity_quarantine_on_vs_off():
    """No failures -> arming the breaker changes NOTHING: same decisions,
    same state numbers (the off mode compiles to an unreachable
    threshold, so parity is structural)."""
    arrays, st_off, dec_off = _scan(quarantine_after=None)
    _, st_on, dec_on = _scan(quarantine_after=3)
    np.testing.assert_array_equal(dec_on.pair_idx, dec_off.pair_idx)
    np.testing.assert_array_equal(dec_on.entry_idx, dec_off.entry_idx)
    for name in ("map_pct", "time_ms", "energy_mwh"):
        np.testing.assert_array_equal(np.asarray(getattr(st_on, name)),
                                      np.asarray(getattr(st_off, name)))
    assert not np.asarray(st_on.fails).any()   # no failure ever counted


def test_scan_quarantine_excludes_the_dead_pair():
    steps, dead_at, q = 160, 30, 3
    fleet = DriftingFleet([DriftEvent("orin_nano", "dropout",
                                      start=dead_at, hard=True)])
    arrays, state, dec = _scan(quarantine_after=q, fleet=fleet, steps=steps)
    dead = [j for j, (_, d) in enumerate(arrays.pairs) if d == "orin_nano"]
    routed = np.asarray(dec.pair_idx)
    assert np.isin(routed[:dead_at], dead).any()   # favorite before death
    # each (group, pair) cell may burn at most q consecutive failures
    # before its breaker opens; afterwards the scan routes around it
    after = routed[dead_at:]
    n_rows = np.asarray(arrays.state.pair_id).shape[0]
    assert 0 < np.isin(after, dead).sum() <= q * n_rows * len(dead)
    assert not np.isin(after[-40:], dead).any()    # steady state: excluded
    # versus: without the breaker the loop keeps feeding the dead device
    _, _, dec_off = _scan(quarantine_after=None, fleet=fleet, steps=steps)
    off_after = np.asarray(dec_off.pair_idx)[dead_at:]
    assert np.isin(after, dead).sum() < np.isin(off_after, dead).sum()


def test_scan_half_open_probe_reopens_a_recovered_pair():
    steps, q = 200, 3
    window = DriftEvent("orin_nano", "dropout", start=30, end=90, hard=True)
    arrays, _, probe_free = _scan(quarantine_after=q, steps=steps,
                                  fleet=DriftingFleet([window]))
    dead = [j for j, (_, d) in enumerate(arrays.pairs) if d == "orin_nano"]
    favorite = int(np.asarray(probe_free.pair_idx)[0])
    assert favorite in dead
    # without probes the breaker stays open after recovery: voluntary
    # routes to the pair never fully resume (only still-closed cells may)
    late_free = np.asarray(probe_free.pair_idx)[150:]
    # with a probe schedule hitting the favorite pair after the window,
    # one SUCCESSFUL probe closes the breaker pair-wide and voluntary
    # routing returns to it
    explore = np.full(steps, -1, np.int32)
    explore[100] = favorite                 # one probe, after recovery
    _, _, probed = _scan(quarantine_after=q, steps=steps,
                         fleet=DriftingFleet([window]), explore=explore)
    late = np.asarray(probed.pair_idx)[150:]
    assert (late == favorite).sum() > (late_free == favorite).sum()
    assert (late == favorite).sum() > 30    # the favorite is favorite again

"""HLO cost analyzer: trip-count multiplication, dot flops, DUS slicing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_cost as H


def _compile_text(fn, *specs):
    return jax.jit(fn).lower(*specs).compile().as_text()


def test_scan_trip_count_multiplies_flops():
    def body(x, w):
        return jnp.tanh(x @ w), None

    def scanned(x, ws):
        y, _ = jax.lax.scan(body, x, ws)
        return y

    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    ws = jax.ShapeDtypeStruct((8, 256, 256), jnp.float32)
    cost = H.analyze(_compile_text(scanned, x, ws))
    expected_dot = 8 * 2 * 128 * 256 * 256
    assert cost.flops >= expected_dot
    assert cost.flops < expected_dot * 1.5  # elementwise tanh etc on top


def test_dot_flops_exact():
    def f(a, b):
        return a @ b
    a = jax.ShapeDtypeStruct((64, 32), jnp.float32)
    b = jax.ShapeDtypeStruct((32, 48), jnp.float32)
    cost = H.analyze(_compile_text(f, a, b))
    assert cost.flops == pytest.approx(2 * 64 * 32 * 48, rel=0.05)


def test_dus_counts_slice_not_buffer():
    def f(cache, upd):
        def body(c, xs):
            u, i = xs
            return jax.lax.dynamic_update_slice_in_dim(c, u[None] * 2.0,
                                                       i * 4, axis=0), ()
        out, _ = jax.lax.scan(body, cache,
                              (upd, jnp.arange(4)))
        return out
    cache = jax.ShapeDtypeStruct((4096, 256), jnp.float32)
    upd = jax.ShapeDtypeStruct((4, 256), jnp.float32)
    cost = H.analyze(_compile_text(f, cache, upd))
    buffer_bytes = 4096 * 256 * 4
    # full-buffer-per-iteration would be >= 4 x buffer; slices are tiny
    assert cost.bytes < 2.5 * buffer_bytes


def test_shape_parsing():
    assert H.shape_bytes("bf16[16,512]{1,0}") == 16 * 512 * 2
    assert H.shape_bytes("(f32[8]{0}, s32[])") == 8 * 4 + 4
    assert H.shape_elems("f32[2,3,4]{2,1,0}") == 24
    assert H.shape_dims("bf16[7,9]{1,0}") == [7, 9]


def test_collective_factors():
    hlo = """
ENTRY %main (p: f32[16]) -> f32[16] {
  %p = f32[16]{0} parameter(0)
  %ag = f32[64]{0} all-gather(%p), channel_id=1, replica_groups=[2,4]<=[8]
  %ar = f32[64]{0} all-reduce(%ag), to_apply=%add, channel_id=2
  ROOT %out = f32[16]{0} reduce-scatter(%ar), channel_id=3
}
"""
    cost = H.analyze(hlo)
    assert cost.coll["all-gather"] == 64 * 4
    assert cost.coll["all-reduce"] == 2 * 64 * 4
    assert cost.coll["reduce-scatter"] == 16 * 4

"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import mha_reference
from repro.kernels.decode_attention.decode_attention import decode_attention
from repro.kernels.decode_attention.ref import decode_reference
from repro.kernels.rglru_scan.rglru_scan import rglru_scan_pallas
from repro.kernels.rglru_scan import ref as lru_ref
from repro.kernels.ssd_scan.ssd_scan import ssd_pallas
from repro.kernels.ssd_scan import ref as ssd_ref
from repro.kernels.sobel.sobel import sobel_grad_pallas
from repro.kernels.sobel import ref as sobel_ref

# every parity test here drives the Pallas kernel in interpret mode on CPU;
# a TPU lane can select the same tests with `-m pallas` (still tier-1 fast)
pytestmark = pytest.mark.pallas


def tol_for(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


# ----------------------------------------------------------- flash attention

@pytest.mark.parametrize("shape", [
    (1, 2, 1, 128, 64),    # MQA
    (2, 4, 2, 256, 64),    # GQA
    (1, 4, 4, 128, 128),   # MHA, d=128
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("kw", [dict(), dict(window=64),
                                dict(softcap=30.0)])
def test_flash_attention(shape, dtype, kw):
    b, h, kv, s, d = shape
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, h, s, d), dtype)
    k = jax.random.normal(ks[1], (b, kv, s, d), dtype)
    v = jax.random.normal(ks[2], (b, kv, s, d), dtype)
    out = flash_attention(q, k, v, interpret=True, block_q=64, block_k=64,
                          **kw)
    ref = mha_reference(q, k, v, **kw)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol_for(dtype), rtol=1e-2)


# ----------------------------------------------------------- decode attention

@pytest.mark.parametrize("shape", [(2, 4, 2, 256, 64), (1, 8, 1, 512, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("kw", [dict(), dict(window=128), dict(softcap=25.0)])
def test_decode_attention(shape, dtype, kw):
    b, h, kv, t, d = shape
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (b, h, d), dtype)
    k = jax.random.normal(ks[1], (b, kv, t, d), dtype)
    v = jax.random.normal(ks[2], (b, kv, t, d), dtype)
    lengths = jnp.asarray(
        np.random.default_rng(0).integers(1, t + 1, size=b), jnp.int32)
    out = decode_attention(q, k, v, lengths, interpret=True, block_k=128, **kw)
    ref = decode_reference(q, k, v, lengths, **kw)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol_for(dtype), rtol=1e-2)


# ----------------------------------------------------------------- rglru scan

@pytest.mark.parametrize("shape", [(1, 16, 128), (2, 33, 256)])
def test_rglru_scan(shape):
    b, s, w = shape
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    a = jax.random.uniform(ks[0], shape, minval=0.3, maxval=0.999)
    bb = jax.random.normal(ks[1], shape)
    h0 = jax.random.normal(ks[2], (b, w))
    # block_w must divide w; exercise both full and split blocks
    out = rglru_scan_pallas(a, bb, h0, interpret=True, block_w=128)
    ref = lru_ref.linear_scan(a, bb, h0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_rglru_layer_matches_sequential():
    b, s, w = 2, 24, 64
    ks = jax.random.split(jax.random.PRNGKey(3), 6)
    x = jax.random.normal(ks[0], (b, s, w))
    wa = jax.random.normal(ks[1], (w, w)) * 0.05
    wx = jax.random.normal(ks[2], (w, w)) * 0.05
    ba = jnp.zeros(w); bx = jnp.zeros(w)
    lam = jax.random.uniform(ks[3], (w,), minval=0.5, maxval=2.0)
    full = lru_ref.rglru(x, wa, ba, wx, bx, lam)
    # sequential oracle
    h = jnp.zeros((b, w))
    outs = []
    for t in range(s):
        y, h = lru_ref.rglru_decode_step(x[:, t], wa, ba, wx, bx, lam, h)
        outs.append(y)
    seq = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(seq), atol=1e-5)


# ------------------------------------------------------------------- ssd scan

@pytest.mark.parametrize("shape", [(1, 32, 2, 8, 4), (2, 64, 4, 16, 8)])
@pytest.mark.parametrize("chunk", [8, 16])
def test_ssd_scan(shape, chunk):
    b, s, h, p, n = shape
    ks = jax.random.split(jax.random.PRNGKey(4), 6)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)))
    B = jax.random.normal(ks[3], (b, s, n))
    C = jax.random.normal(ks[4], (b, s, n))
    D = jax.random.normal(ks[5], (h,))
    out = ssd_pallas(x, dt, A, B, C, D, chunk=chunk, interpret=True)
    ref = ssd_ref.ssd_chunked(x, dt, A, B, C, D, chunk=chunk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-4,
                               rtol=1e-3)


def test_ssd_chunked_matches_sequential():
    b, s, h, p, n = 1, 24, 2, 4, 4
    ks = jax.random.split(jax.random.PRNGKey(5), 6)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)))
    B = jax.random.normal(ks[3], (b, s, n))
    C = jax.random.normal(ks[4], (b, s, n))
    D = jax.random.normal(ks[5], (h,))
    y_chunked, st_c = ssd_ref.ssd_chunked(x, dt, A, B, C, D, chunk=8,
                                          return_final_state=True)
    st = jnp.zeros((b, h, p, n))
    ys = []
    for t in range(s):
        y, st = ssd_ref.ssd_decode_step(x[:, t], dt[:, t], A, B[:, t],
                                        C[:, t], D, st)
        ys.append(y)
    y_seq = jnp.stack(ys, 1)
    np.testing.assert_allclose(np.asarray(y_chunked), np.asarray(y_seq),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(st_c), np.asarray(st), atol=1e-4)


# --------------------------------------------------------------------- sobel

@pytest.mark.parametrize("shape", [(1, 32, 32), (3, 64, 64)])
def test_sobel(shape):
    img = jnp.asarray(np.random.default_rng(0).random(shape, np.float32))
    m1, d1 = sobel_grad_pallas(img, interpret=True)
    m2, d2 = sobel_ref.sobel_grad(img)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m2), atol=1e-5)
    assert (np.asarray(d1) == np.asarray(d2)).mean() > 0.999

"""mAP metric unit + property tests."""
import numpy as np
from _propcheck import given, settings, st

from repro.core.metrics import MAPAccumulator, average_precision, iou


def test_iou_basic():
    a = np.array([0, 0, 10, 10.0])
    assert iou(a, a) == 1.0
    assert iou(a, np.array([20, 20, 30, 30.0])) == 0.0
    assert abs(iou(a, np.array([5, 0, 15, 10.0])) - 1 / 3) < 1e-9


def test_perfect_predictions_give_100():
    acc = MAPAccumulator(2)
    boxes = np.array([[0, 0, 10, 10], [20, 20, 40, 40.0]])
    classes = np.array([0, 1])
    acc.add_image(boxes, np.array([0.9, 0.8]), classes, boxes, classes)
    assert acc.map() == 100.0


def test_misses_reduce_map():
    acc = MAPAccumulator(1)
    gt = np.array([[0, 0, 10, 10], [30, 30, 40, 40.0]])
    acc.add_image(gt[:1], np.array([0.9]), np.array([0]), gt, np.array([0, 0]))
    assert 0 < acc.map() < 100


def test_empty_scene_convention():
    acc = MAPAccumulator(1)
    none = np.zeros((0, 4))
    acc.add_image(none, np.zeros(0), np.zeros(0), none, np.zeros(0))
    assert acc.map() == 100.0
    acc.add_image(np.array([[0, 0, 5, 5.0]]), np.array([0.9]), np.array([0]),
                  none, np.zeros(0))
    assert acc.map() == 50.0  # one clean empty image of two


def test_false_positives_reduce_ap():
    acc = MAPAccumulator(1)
    gt = np.array([[0, 0, 10, 10.0]])
    preds = np.array([[0, 0, 10, 10], [30, 30, 40, 40.0]])
    acc.add_image(preds, np.array([0.5, 0.9]), np.array([0, 0]), gt,
                  np.array([0]))
    # high-scoring FP ranked first: AP < 1
    assert acc.map() < 100.0


@settings(max_examples=50, deadline=None)
@given(st.lists(st.floats(0.01, 1.0), min_size=1, max_size=20))
def test_ap_bounds(scores):
    tp = [True] * len(scores)
    ap = average_precision(scores, tp, n_gt=len(scores))
    assert abs(ap - 1.0) < 1e-9  # all TP, all gt found -> AP 1
    ap2 = average_precision(scores, [False] * len(scores), n_gt=5)
    assert ap2 == 0.0

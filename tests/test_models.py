"""Model substrate: decode-vs-full-forward consistency for every family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import (ModelConfig, decode_step, forward, init_params,
                          loss_fn, prefill)

BASE = dict(d_model=64, num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
            head_dim=16, remat=False, activ_dtype="float32")

CASES = {
    "dense": ModelConfig(name="dense", family="dense", num_layers=2,
                         block_layout=("attn",), **BASE),
    "swa+softcap": ModelConfig(name="g2", family="dense", num_layers=2,
                               block_layout=("local", "attn"),
                               sliding_window=6, post_norm=True,
                               attn_softcap=50.0, final_softcap=30.0,
                               embed_scale=True, **BASE),
    "qkv_bias": ModelConfig(name="qw", family="dense", num_layers=2,
                            block_layout=("attn",), qkv_bias=True, **BASE),
    "moe": ModelConfig(name="moe", family="moe", num_layers=2,
                       block_layout=("attn",), num_experts=4, moe_top_k=2,
                       moe_d_ff=32, num_shared_experts=1, **BASE),
    "mla+moe": ModelConfig(name="mla", family="moe", num_layers=2,
                           block_layout=("attn",), num_experts=4, moe_top_k=2,
                           moe_d_ff=32, num_shared_experts=2, use_mla=True,
                           kv_lora_rank=32, qk_rope_dim=8, qk_nope_dim=16,
                           v_head_dim=16, **BASE),
    "ssm": ModelConfig(name="ssm", family="ssm", num_layers=2,
                       block_layout=("ssm",), ssm_state=16, ssm_headdim=16,
                       ssm_chunk=8, **BASE),
    "hybrid": ModelConfig(name="hyb", family="hybrid", num_layers=5,
                          block_layout=("rec", "rec", "local"),
                          trailing_layout=("rec", "rec"), sliding_window=6,
                          lru_width=48, **BASE),
    "encdec": ModelConfig(name="whs", family="encdec", num_layers=4,
                          block_layout=("attn",), use_rope=False,
                          enc_layers=2, dec_layers=2, enc_seq=8,
                          vision_dim=32,
                          **{**BASE, "num_kv_heads": 4}),
    "vlm": ModelConfig(name="vlm", family="vlm", num_layers=2,
                       block_layout=("attn",), num_prefix_embeds=8,
                       vision_dim=32, **BASE),
}


@pytest.mark.parametrize("name", list(CASES))
def test_decode_matches_forward(name):
    cfg = CASES[name]
    S, B = 12, 2
    params = init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    prefix = None
    if cfg.family == "vlm":
        prefix = jax.random.normal(jax.random.PRNGKey(2),
                                   (B, cfg.num_prefix_embeds, cfg.vision_dim))
    if cfg.family == "encdec":
        prefix = jax.random.normal(jax.random.PRNGKey(2),
                                   (B, cfg.enc_seq, cfg.vision_dim))
    full = forward(params, cfg, tokens, prefix)
    assert bool(jnp.isfinite(full).all())
    off = cfg.num_prefix_embeds if cfg.family == "vlm" else 0
    lg, cache = prefill(params, cfg, tokens[:, :S - 2], prefix,
                        max_seq=S + off + 4)
    np.testing.assert_allclose(np.asarray(lg[:, 0]),
                               np.asarray(full[:, S - 3 + off]), atol=1e-3)
    for step in (S - 2, S - 1):
        lg, cache = decode_step(params, cfg, tokens[:, step:step + 1], cache)
        np.testing.assert_allclose(np.asarray(lg[:, 0]),
                                   np.asarray(full[:, step + off]),
                                   atol=1e-3)


@pytest.mark.parametrize("name", ["dense", "moe", "ssm", "hybrid"])
def test_gradients_flow(name):
    cfg = CASES[name]
    params = init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                cfg.vocab_size)
    (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
        params, cfg, {"tokens": tokens, "labels": tokens})
    assert np.isfinite(float(loss))
    gnorms = [float(jnp.abs(g).max()) for g in jax.tree.leaves(grads)]
    assert all(np.isfinite(g) for g in gnorms)
    assert max(gnorms) > 0

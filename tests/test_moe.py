"""MoE dispatch backends: ragged (dropless oracle) vs capacity-local."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import moe as M
from repro.models.base import ModelConfig


def make_cfg(**kw):
    base = dict(name="m", family="moe", num_layers=1, d_model=32, num_heads=2,
                num_kv_heads=2, d_ff=0, moe_d_ff=16, num_experts=8,
                moe_top_k=2, vocab_size=64, block_layout=("attn",))
    base.update(kw)
    return ModelConfig(**base)


@pytest.mark.parametrize("t,e,k", [(64, 8, 2), (128, 4, 1), (96, 16, 4)])
def test_capacity_matches_ragged_when_no_drops(t, e, k):
    cfg = make_cfg(num_experts=e, moe_top_k=k, moe_capacity_factor=float(e))
    p = M.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (t, 32))
    o1, a1 = M.moe_ragged(p, cfg, x)
    o2, a2 = M.moe_capacity_local(p, cfg, x)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)
    assert abs(float(a1) - float(a2)) < 1e-6


def test_capacity_drops_bounded():
    cfg = make_cfg(moe_capacity_factor=1.0)
    p = M.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 32))
    o1, _ = M.moe_ragged(p, cfg, x)
    o2, _ = M.moe_capacity_local(p, cfg, x)
    # dropped tokens give zero contribution, not garbage
    assert np.isfinite(np.asarray(o2)).all()
    assert float(jnp.abs(o2).max()) <= float(jnp.abs(o1).max()) * 3


def test_aux_loss_balanced_router_is_one():
    # uniform router probs -> aux = E * E*(1/E * 1/E) ... = 1 at balance
    cfg = make_cfg()
    t, e, k = 512, cfg.num_experts, cfg.moe_top_k
    ids = jnp.arange(t * k).reshape(t, k) % e  # perfectly balanced
    probs = jnp.full((t, e), 1.0 / e)
    aux = M._aux_loss(cfg, ids, probs, t)
    assert abs(float(aux) - 1.0) < 1e-5


def test_apply_moe_end_to_end():
    cfg = make_cfg(num_shared_experts=1)
    p = M.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    out, aux = M.apply_moe(p, cfg, x, return_aux=True)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all() and np.isfinite(float(aux))

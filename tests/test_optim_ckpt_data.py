"""Optimizer, checkpoint, data pipeline units."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.data.tokens import DataConfig, TokenStream
from repro.models.base import ModelConfig
from repro.optim.adamw import (AdamWConfig, adamw_update, cosine_lr,
                               init_opt_state)


def test_adamw_minimizes_quadratic():
    params = {"w": jnp.array([3.0, -2.0])}
    opt = init_opt_state(params)
    cfg = AdamWConfig(peak_lr=0.2, warmup_steps=5, total_steps=100,
                      weight_decay=0.0)
    loss = lambda p: jnp.sum(jnp.square(p["w"]))
    for _ in range(100):
        g = jax.grad(loss)(params)
        params, opt, m = adamw_update(cfg, params, g, opt)
    assert float(loss(params)) < 1e-2
    assert int(opt.step) == 100


def test_cosine_schedule_shape():
    cfg = AdamWConfig(peak_lr=1.0, end_lr=0.1, warmup_steps=10,
                      total_steps=100)
    lrs = [float(cosine_lr(cfg, jnp.asarray(s))) for s in range(101)]
    assert lrs[0] == 0.0
    assert abs(lrs[10] - 1.0) < 1e-6
    assert lrs[100] <= 0.1 + 1e-6
    assert all(a >= b - 1e-9 for a, b in zip(lrs[10:], lrs[11:]))  # decreasing


def test_grad_clipping():
    params = {"w": jnp.zeros(3)}
    opt = init_opt_state(params)
    cfg = AdamWConfig(clip_norm=1.0, total_steps=10)
    g = {"w": jnp.full(3, 1e6)}
    _, _, m = adamw_update(cfg, params, g, opt)
    assert float(m["grad_norm"]) > 1e5  # reported pre-clip


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": {"b": jnp.arange(6).reshape(2, 3).astype(jnp.float32)},
            "c": [jnp.ones(4), jnp.zeros((2, 2))]}
    path = os.path.join(tmp_path, "t.npz")
    ckpt.save(path, tree)
    like = jax.tree.map(jnp.zeros_like, tree)
    back = ckpt.load(path, like)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_checkpoint_shape_mismatch(tmp_path):
    path = os.path.join(tmp_path, "t.npz")
    ckpt.save(path, {"w": jnp.ones(3)})
    import pytest
    with pytest.raises(ValueError):
        ckpt.load(path, {"w": jnp.ones(4)})


def test_token_stream_learnable_structure():
    cfg = ModelConfig(name="t", family="dense", num_layers=1, d_model=32,
                      num_heads=2, num_kv_heads=2, d_ff=64, vocab_size=512,
                      block_layout=("attn",))
    stream = TokenStream(cfg, DataConfig(seq_len=32, batch_size=4, seed=0))
    b = next(stream.batches())
    assert b["tokens"].shape == (4, 32) and b["labels"].shape == (4, 32)
    # labels are next-token shifted
    np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                  np.asarray(b["labels"][:, :-1]))
    assert int(b["tokens"].max()) < 512

"""Roofline dataclass + serving-pool profile construction tests."""
import json

import pytest

from repro.launch.roofline import (CHIP_POWER_IDLE, CHIP_POWER_PEAK,
                                   Roofline, count_params, model_flops)
from repro.models.base import INPUT_SHAPES
from repro.configs import get_config
from repro.serving.pool import pool_table_from_dryrun


def mk(flops=1e12, bytes_=1e11, coll=1e9, chips=256):
    return Roofline(arch="a", shape="s", mesh="16x16", chips=chips,
                    flops=flops, bytes_accessed=bytes_, coll_bytes=coll,
                    coll_by_kind={}, per_device_memory=8e9,
                    model_flops=flops * chips * 0.5)


def test_terms_and_bottleneck():
    r = mk(flops=197e12, bytes_=819e9, coll=50e9)
    assert r.t_compute == pytest.approx(1.0)
    assert r.t_memory == pytest.approx(1.0)
    assert r.t_collective == pytest.approx(1.0)
    r2 = mk(bytes_=819e9 * 10)
    assert r2.bottleneck == "memory" and r2.t_step == pytest.approx(10.0)


def test_useful_flops_ratio():
    r = mk()
    assert r.useful_flops_ratio == pytest.approx(0.5)


def test_energy_monotone_in_utilization():
    lo = mk(flops=1e10, bytes_=819e9)   # memory-bound, low util
    hi = mk(flops=197e12 * 0.9, bytes_=819e9)  # near compute-bound
    # same step time; higher utilization draws more power
    p_lo = lo.energy_j / (lo.t_step * lo.chips)
    p_hi = hi.energy_j / (hi.t_step * hi.chips)
    assert CHIP_POWER_IDLE <= p_lo < p_hi <= CHIP_POWER_PEAK


def test_model_flops_active_params():
    cfg = get_config("granite-moe-1b-a400m")
    c = count_params(cfg)
    assert c["active"] < c["total"] * 0.5  # top-8 of 32 experts
    t = model_flops(cfg, INPUT_SHAPES["train_4k"], c["total"], c["active"])
    p = model_flops(cfg, INPUT_SHAPES["prefill_32k"], c["total"], c["active"])
    assert t / p == pytest.approx(3.0)  # 6ND vs 2ND, same token count


def test_pool_table_from_dryrun(tmp_path):
    rows = [
        {"status": "ok", "mesh": "16x16", "shape": "prefill_32k",
         "arch": "llama3-8b", "t_step_s": 0.5, "energy_j": 100.0,
         "params_active": 7_000_000_000},
        {"status": "ok", "mesh": "16x16", "shape": "prefill_32k",
         "arch": "mamba2-370m", "t_step_s": 0.05, "energy_j": 8.0,
         "params_active": 320_000_000},
        {"status": "skip", "mesh": "16x16", "shape": "prefill_32k",
         "arch": "x"},
        {"status": "ok", "mesh": "2x16x16", "shape": "prefill_32k",
         "arch": "ignored", "t_step_s": 1, "energy_j": 1,
         "params_active": 1},
    ]
    p = tmp_path / "d.jsonl"
    p.write_text("\n".join(json.dumps(r) for r in rows))
    table = pool_table_from_dryrun(str(p))
    pairs = table.pairs()
    assert ("llama3-8b", "pod-16x16") in pairs
    assert ("mamba2-370m", "pod-16x16") in pairs
    assert len(pairs) == 2  # skip + wrong-mesh rows excluded
    # 5 buckets per backend
    assert len(table.entries) == 10
    # bigger model scores higher in the long bucket
    assert table.entry(("llama3-8b", "pod-16x16"), 4).map_pct > \
        table.entry(("mamba2-370m", "pod-16x16"), 4).map_pct

"""Algorithm 1 unit tests + the Theorem 3.1 optimality property."""
import pytest
from _propcheck import given, settings, st

from repro.core.groups import DEFAULT_GROUP_RULES, group_of
from repro.core.profiles import ProfileEntry, ProfileTable
from repro.core.router import (GreedyEstimateRouter, HighestMAPPerGroupRouter,
                               HighestMAPRouter, LowestEnergyRouter,
                               LowestInferenceRouter, OracleRouter,
                               RandomRouter, RoundRobinRouter, greedy_route)


def table_from(rows):
    return ProfileTable([ProfileEntry(*r) for r in rows])


@pytest.fixture
def toy_table():
    # (model, device, group, mAP, time_ms, energy_mwh)
    rows = []
    for g in range(5):
        rows += [
            ("tiny", "devA", g, 50.0 - 4 * g, 5.0, 0.010),
            ("mid", "devB", g, 55.0 - 2 * g, 9.0, 0.025),
            ("big", "devC", g, 60.0, 20.0, 0.060),
        ]
    return table_from(rows)


def test_greedy_group0_prefers_cheap_within_delta(toy_table):
    # group 0: tiny=50, mid=55, big=60; delta=5 -> feasible {mid, big} ->
    # mid is cheaper
    e = greedy_route(0, toy_table, delta_map=5.0)
    assert e.pair == ("mid", "devB")


def test_greedy_delta0_is_accuracy_centric(toy_table):
    e = greedy_route(0, toy_table, delta_map=0.0)
    assert e.pair == ("big", "devC")


def test_greedy_large_delta_is_energy_centric(toy_table):
    e = greedy_route(0, toy_table, delta_map=100.0)
    assert e.pair == ("tiny", "devA")


def test_greedy_group_dependence(toy_table):
    # group 4: tiny=34, mid=47, big=60; delta=5 -> only big
    e = greedy_route(7, toy_table, delta_map=5.0)  # count 7 -> group 4
    assert e.pair == ("big", "devC")


def test_greedy_unprofiled_group_names_the_group():
    # regression: used to surface as a bare `max() arg is an empty sequence`
    # when the profile (e.g. a dry-run table filtered by --archs) had no rows
    # for the requested group
    table = table_from([("tiny", "devA", 0, 50.0, 5.0, 0.010)])
    with pytest.raises(ValueError, match="no profile rows for group 4"):
        greedy_route(7, table, delta_map=5.0)


def test_group_rules():
    assert group_of(0) == 0
    assert group_of(3) == 3
    assert group_of(4) == 4
    assert group_of(250) == 4


# ---------------------------------------------------------- Theorem 3.1

entry_strategy = st.tuples(
    st.sampled_from(["m1", "m2", "m3", "m4"]),
    st.sampled_from(["d1", "d2"]),
    st.floats(0, 100, allow_nan=False),
    st.floats(0.1, 100, allow_nan=False),
    st.floats(1e-4, 1.0, allow_nan=False),
)


@settings(max_examples=200, deadline=None)
@given(
    entries=st.lists(entry_strategy, min_size=1, max_size=20, unique_by=lambda e: (e[0], e[1])),
    count=st.integers(0, 12),
    delta=st.floats(0, 50, allow_nan=False),
)
def test_greedy_optimality(entries, count, delta):
    """Theorem 3.1: the greedy pick is the global optimum of
    min energy s.t. group match and mAP >= mAP_max - delta."""
    rows = []
    for m, d, mp, t, e in entries:
        for g in range(5):
            rows.append(ProfileEntry(m, d, g, mp, t, e))
    table = ProfileTable(rows)
    pick = greedy_route(count, table, delta)
    g = group_of(count)
    feasible = [r for r in table.for_group(g)
                if r.map_pct >= max(x.map_pct for x in table.for_group(g)) - delta]
    # exhaustive check: no feasible row has lower energy
    assert pick in feasible
    assert all(pick.energy_mwh <= r.energy_mwh for r in feasible)


def test_random_router_reset_reseeds(toy_table):
    """Regression: reset() used to be a no-op, so back-to-back episodes with
    one RandomRouter were not reproducible."""
    rnd = RandomRouter(toy_table, seed=7)
    first = [rnd.route() for _ in range(20)]
    rnd.reset()
    second = [rnd.route() for _ in range(20)]
    assert first == second
    assert len(set(first)) > 1  # the stream actually varies


def test_baseline_routers(toy_table):
    assert LowestEnergyRouter(toy_table).route() == ("tiny", "devA")
    assert LowestInferenceRouter(toy_table).route() == ("tiny", "devA")
    assert HighestMAPRouter(toy_table).route() == ("big", "devC")
    assert HighestMAPPerGroupRouter(toy_table).route(true_count=0) == ("big", "devC")
    rr = RoundRobinRouter(toy_table)
    seq = [rr.route() for _ in range(6)]
    assert seq[0] != seq[1] and seq[0] == seq[3]
    rnd = RandomRouter(toy_table, seed=1)
    assert all(rnd.route() in toy_table.pairs() for _ in range(10))
    orc = OracleRouter(toy_table, delta_map=5.0)
    assert orc.route(true_count=0) == ("mid", "devB")
    gr = GreedyEstimateRouter(toy_table, delta_map=5.0)
    assert gr.route(estimated_count=0) == ("mid", "devB")
